"""Shared benchmark harness for ``benchmarks/run.py`` (and future drivers).

Everything stateful lives here so sections can be split across files without
forking the row sink: ``ROWS`` / ``CONFIGS`` are the single mutable
collectors every ``emit``/``record_cfg`` call feeds, ``_write_json`` dumps
them with run metadata, and the timing helpers (``_timeit`` one-config
windows, ``_paired_times`` interleaved per-config medians) encode the
methodology the compare gates rely on.  The shared fixture is the paper's
Fig-8 payload: the 44-byte :class:`Ray44` and its 8-way mesh.
"""
import dataclasses
import json
import os
import platform
import sys
import time

# Must run before jax locks the backend on first init (idempotent with
# run.py's own setdefault — whichever module imports first wins).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import work_item

ROWS = []
CONFIGS = {}  # tag -> ForwardConfig fields + mesh shape (JSON provenance)


def record_cfg(tag: str, cfg, mesh=None) -> None:
    """Register a benchmarked ForwardConfig (+ its mesh shape) for the JSON
    dump's provenance block — every BENCH_*.json names the exact configs it
    measured, not just the row names."""
    d = dataclasses.asdict(cfg)
    if mesh is not None:
        d["mesh_shape"] = {str(k): int(v) for k, v in dict(mesh.shape).items()}
    CONFIGS.setdefault(tag, d)


def _git_sha():
    import subprocess

    try:
        out = subprocess.run(
            ["git", "-C", os.path.dirname(os.path.abspath(__file__)),
             "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
        )
        return out.stdout.strip() or None
    except Exception:
        return None


def _parse_derived(derived: str):
    """'k=v;k2=v2' → dict with floats where they parse."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append(
        {"name": name, "us_per_call": us_per_call, "derived": _parse_derived(derived)}
    )
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


# ----------------------------------------------------------- shared fixture
@dataclasses.dataclass
class Ray44:
    """The paper's Fig-8 payload: a 44-byte ray (11 × f32/i32)."""

    origin: jax.Array
    direction: jax.Array
    tmin: jax.Array
    pixel: jax.Array
    integral: jax.Array
    extra: jax.Array


Ray44 = work_item(Ray44)


def _ray_proto():
    return Ray44(
        origin=jnp.zeros(3), direction=jnp.zeros(3), tmin=jnp.zeros(()),
        pixel=jnp.zeros((), jnp.int32), integral=jnp.zeros(()), extra=jnp.zeros(2),
    )


def _mesh8():
    return compat.make_mesh((8,), ("data",))


def _emit_kernel(cfg, n_emit, cap, ballast_iters=0):
    from repro.core import enqueue, forward_work, make_queue
    from repro.core.forwarding import flatten_axis_names

    def kernel(x):
        me = jax.lax.axis_index(flatten_axis_names(cfg.axis_name))
        q = make_queue(_ray_proto(), cap)
        lane = jnp.arange(n_emit)
        rays = Ray44(
            origin=jnp.ones((n_emit, 3)), direction=jnp.ones((n_emit, 3)),
            tmin=lane.astype(jnp.float32), pixel=lane.astype(jnp.int32),
            integral=jnp.zeros(n_emit), extra=jnp.zeros((n_emit, 2)),
        )
        dest = ((me * 7 + lane * 131) % cfg.num_ranks).astype(jnp.int32)
        q = enqueue(q, rays, dest, jnp.ones(n_emit, bool))
        res = forward_work(q, cfg)
        nq = res[0]
        if cfg.telemetry:
            # add every stats leaf into the output VALUE (no ×0 that XLA
            # could fold away) so the telemetry-on timing pays for the full
            # capture; nothing reads the kernel's value, only its walltime
            telem_sum = sum(jnp.sum(l) for l in jax.tree.leaves(res[-1]))
        else:
            telem_sum = jnp.int32(0)
        if cfg.overflow == "retain":
            # same trick: the age vector keeps the spill compaction live
            telem_sum = telem_sum + jnp.sum(res[2])
        if cfg.flow == "credit":
            # and the returned credit vector keeps the advert/grant plumbing
            # live (credits=None: the uncontended full-capacity assumption)
            telem_sum = telem_sum + jnp.sum(res[3])
        if ballast_iters:
            # app-realistic per-round compute (a ray-march-shaped loop over
            # received payload) folded in through a branch XLA cannot
            # constant-fold — the overlap-law sweep must ballast the round
            # the same way the ckpt gate ballasts the drive (see
            # _ballast_round_fn): a bare round overstates the exchange's
            # relative cost by an order of magnitude
            z = nq.items.tmin[:256, None] * jnp.ones((1, 16)) + 1.0
            z = jax.lax.fori_loop(
                0, ballast_iters, lambda i, v: v * 0.999 + jnp.sin(v) * 1e-3, z
            )
            telem_sum = telem_sum + jnp.where(
                jnp.isnan(jnp.sum(z)), jnp.int32(1), jnp.int32(0)
            )
        # depend on the payload so the exchange isn't DCE'd out of the HLO
        checksum = (
            jnp.sum(nq.items.tmin) + jnp.sum(nq.items.origin) + jnp.sum(nq.items.extra)
        )
        return (
            nq.count[None] + (checksum * 0).astype(jnp.int32)
            + telem_sum.astype(jnp.int32) + x[:1].astype(jnp.int32) * 0
        )

    return kernel


def _paired_times(cfgs, mesh, axes, n_emit, cap, samples, ballast_iters=0,
                  raw=False):
    """Time several configs of one mesh point INTERLEAVED (a, b, a, b, …)
    and report the per-config MEDIAN: on a shared CPU host the load drifts
    on second scales, so timing the variants in separate windows (as
    ``_timeit`` would) swings their ratio by far more than a 5% gate margin
    — interleaving cancels the drift, and the median is robust to the
    scheduler spikes that dominate these ~2 ms programs.  Returns
    ``{name: us}``, or ``({name: us}, {name: samples})`` with ``raw=True``
    for gates that need a per-sample estimator (see ``_pair_ratio``)."""
    fns, x = {}, jnp.arange(8.0)
    for name, cfg in cfgs.items():
        f = jax.jit(
            compat.shard_map(
                _emit_kernel(cfg, n_emit, cap, ballast_iters), mesh=mesh,
                in_specs=P(axes), out_specs=P(axes),
            )
        )
        jax.block_until_ready(f(x))  # compile + warm
        jax.block_until_ready(f(x))
        fns[name] = f
    ts = {name: [] for name in cfgs}
    for _ in range(samples):
        for name in cfgs:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[name](x))
            ts[name].append((time.perf_counter() - t0) * 1e6)
    med = {m: float(np.median(v)) for m, v in ts.items()}
    if raw:
        return med, {m: np.asarray(v) for m, v in ts.items()}
    return med


def _pair_ratio(samples_us, num, den):
    """Median of ADJACENT-PAIR ratios ``num[i] / den[i]`` from one
    interleaved ``_paired_times(raw=True)`` window.  Sample i of both
    variants ran back-to-back, so each pair saw the same instantaneous host
    load and its ratio cancels drift that even the per-variant median
    cannot: when the load ramps mid-window the two medians land on samples
    from DIFFERENT load regimes and their quotient swings by several
    percent, while the pair-ratio median stays put.  This is the estimator
    the tight (≤1.0×) gates quote."""
    return float(np.median(np.asarray(samples_us[num]) / np.asarray(samples_us[den])))


def _write_json(path: str, **extra_meta) -> None:
    """Machine-readable dump of ROWS with run metadata (perf trajectory)."""
    payload = {
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "platform": platform.platform(),
            "git_sha": _git_sha(),
            "argv": sys.argv[1:],
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
            "configs": CONFIGS,
            **extra_meta,
        },
        "rows": ROWS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path}")

"""Benchmark harness — one entry per paper table/figure.

  fig8_efficiency_*      Fig. 8 analogue: forwarding bandwidth efficiency vs
                         rays-per-rank (useful payload ÷ total wire bytes,
                         from the lowered production-mesh HLO), for the
                         padded and ragged exchanges.
  sort_cost_*            §6.1 claim "all of [sort/marshal] are trivially
                         cheap": sort-stage FLOPs+bytes vs exchange bytes.
  fwd_walltime_*         forward_work wall time on 8 CPU devices (us/call).
  fwd_walltime_hier_*    flat vs hierarchical two-stage exchange on 2-D
                         (node, device) meshes (2×4, 4×2), with the modeled
                         slow-axis byte volume per route.
  fwd_walltime_hier3_*   flat vs 2-level vs 3-level route on the (2, 2, 2)
                         (pod, node, device) mesh, with modeled per-tier
                         bytes.
  fwd_walltime_marshal_* sort vs scatter marshal (ISSUE 4) on the flat 8-way
                         and the (2, 2, 2) hierarchical mesh, with the
                         modeled marshal plan bytes (the scatter deletes the
                         O(C log C) key-sort traffic; both modes keep the
                         one-payload-pass law).
  fwd_walltime_pipeline_* ISSUE 8: bulk-synchronous vs micro-shard pipelined
                         (``pipeline_shards=4``) padded round on ballasted
                         rounds over growing working sets (quoted ratio =
                         adjacent-pair median; ``gated=1`` marks the
                         cache-exceeding points the compare gate covers),
                         plus an ungated 3-level trend point; with
                         ``--profile`` the bulk phase breakdown feeds the
                         overlap_efficiency_model (perfect-overlap ICI bound
                         vs sync-fabric bound) bracketing the measured ratio.
  fwd_profile_*          only with ``--profile``: per-phase breakdown of a
                         padded round — marshal (plan + send-buffer build) /
                         count collective / payload collective / unmarshal —
                         each phase timed as its own jitted program (the sum
                         can exceed the fused round, which runs all phases in
                         one XLA program; the split shows WHERE time goes).
  rebalance_skew_*       skewed-load rebalance (flat / topology-aware /
                         intra scope) with per-tier payload bytes from the
                         lowered HLO — intra must put zero below the
                         fastest tier.
  autotune_drift_*       ISSUE 5: drifting hot-spot scenario (the hot
                         destination rotates mid-run) driven by
                         ``tune.autotune_forward`` — per-burst rows show the
                         capacities/drops trajectory; the final row compares
                         the tuned config's modeled padded wire bytes against
                         the §6.3 worst-case static sizing that achieves the
                         same zero drops.  The section FAILS unless the tuner
                         converges drop-free at ≤ the static wire cost.
  fwd_walltime_telemetry_* only with ``--compare off,telemetry``: forwarding
                         walltime with the flight recorder off vs on
                         (interleaved medians, like the marshal gate).
  fwd_walltime_overflow_* overflow drop vs retain walltime on the happy path
                         (ample capacity, zero spill pressure) — retention
                         must be free when nothing spills.
  chaos_*                ISSUE 6: every deterministic fault-injection
                         scenario (drought / hot-spot / burst / convergecast)
                         run retain vs drop under starved send budgets, with
                         full loss accounting per row.  The section FAILS
                         unless retain loses NOTHING (age within the
                         spill_drain_model bound) while drop loses >20% of
                         the convergecast.
  fwd_walltime_ckpt_*    ISSUE 7: segmented-drive walltime with the
                         checkpoint writer off vs on (checkpoint_every=8)
                         on ballasted convergecast bursts — the recovery
                         law's amortized-overhead measurement.
  chaos_recovery_*       ISSUE 7: the recovery acceptance — preempt at
                         round 5 / resume must be bit-exact with the
                         uninterrupted run at every common checkpoint
                         boundary (SHA-256 of every carry leaf), and a
                         mid-burst two-rank brownout must drain lossless,
                         matching the numpy twin's trajectory.  FAILS on
                         any violation.
  fwd_walltime_flow_*    ISSUE 9: retain-mode forwarding walltime with
                         ``flow`` open vs credit on the fully-credited happy
                         path — the advert column and grant arithmetic must
                         be ~free when nobody is starved.
  chaos_backpressure_*   ISSUE 9: the two overload scenarios (fixed hot-pair
                         saturation, full-width incast) run open vs credit
                         with goodput/waste accounting per row.  The section
                         FAILS unless credit delivers everything with zero
                         receiver drops, bounded occupancy, and an
                         advert-only first round where open flow wastes >30%
                         of its wire rows.
  fwd_walltime_obs_*     ISSUE 10: the same compiled chaos burst with the
                         ambient span tracer + per-burst metrics snapshot
                         off vs on (the lowered HLO is identical — this
                         times the host bookkeeping).
  obs_flight_report_*    ISSUE 10 acceptance: the incast-collapse overload
                         pair captured through the tracer and replayed
                         through the ``repro.obs.report`` flight-data
                         analyzer — the report must reproduce the driver's
                         goodput/waste numbers and flag only the open-flow
                         run as degraded.  FAILS on any mismatch.
  sort_throughput_*      §4.2.1 key pack+sort throughput (keys/s), XLA vs
                         Pallas(interpret) paths.
  app_*                  §5 application throughputs (CPU, small scenes).
  moe_dispatch_*         paper technique on the LM side: RaFI-EP dispatch vs
                         dense-TP baseline wall time (tokens/s).

Output: ``name,us_per_call,derived`` CSV on stdout, and optionally a
machine-readable JSON file (``--json PATH``) so successive PRs can track the
perf trajectory::

    {"meta": {...}, "rows": [{"name": ..., "us_per_call": ...,
                              "derived": {"rays_per_s": 1.6e6, ...}}, ...]}

``--smoke`` runs only the fast forwarding-walltime subset (the regression
canary); ``--only SUBSTR`` filters sections by name; ``--compare
flat,hierarchical`` is the CI gate that fails (exit 1) when the hierarchical
exchange regresses the flat one by >5% walltime on a single-node mesh;
``--compare flat,hierarchical2,hierarchical3`` is the PR-3 gate: the 3-way
(2, 2, 2)-mesh sweep + the skewed rebalance benchmark, failing unless the
3-level route's modeled slowest-tier bytes undercut both alternatives;
``--compare sort,scatter`` is the PR-4 gate: the marshal sweep on the flat
and (2, 2, 2) meshes, failing if the scatter marshal regresses the sort path
by >5% walltime at any point (BENCH_PR4.json is this gate's ``--json`` dump);
``--compare off,telemetry`` is the PR-5 gate: telemetry-on walltime must stay
within a 1.05× geomean of telemetry-off across the sweep, and the
autotune_drift section must converge — BENCH_PR5.json is this gate's dump.
``--compare drop,retain`` is the PR-6 gate: retain-mode walltime must stay
within a 1.05× geomean of drop mode on the happy path, and the
chaos_lossless acceptance must hold — BENCH_PR6.json is this gate's dump.
``--compare nockpt,ckpt`` is the PR-7 gate: the checkpointed drive
(checkpoint_every=8) must stay within a 1.05× walltime geomean of the
save-free segmented drive on ballasted bursts, and the chaos_recovery
acceptance must hold (preempt-resume bit-exact, brownout lossless) —
BENCH_PR7.json is this gate's dump.
``--compare bulk,pipelined`` is the PR-8 gate: the micro-shard pipelined
round must hold a ≤1.0× walltime geomean against the bulk round on the
ballasted flat points whose buffers exceed the cache — where the locality
mechanism applies; pipelining exists only for walltime, so ANY regression
there defeats it — with the phase-profile overlap model bracketing the
measured ratio.  BENCH_PR8.json is this gate's dump.
``--compare open,credit`` is the PR-9 gate: credit-flow walltime must stay
within a 1.05× geomean of open flow on the fully-credited happy path, and
the chaos_backpressure acceptance must hold (credit lossless with bounded
occupancy on both overload scenarios where open wastes >30% of its wire
rows) — BENCH_PR9.json is this gate's dump.
``--compare off,obs`` is the PR-10 gate: a traced + metered burst must stay
within a 1.05× walltime geomean of the untraced one (the device program is
bit-identical by construction; the gate covers the host span/metrics cost),
and the obs_flight_report acceptance must hold (the flight-data analyzer
reproduces the chaos driver's goodput/waste numbers from the capture alone
and flags only the open-flow overload run as degraded) — BENCH_PR10.json is
this gate's dump.
``--autotune`` runs the autotune_drift section alone; ``--chaos`` runs the
chaos_lossless + chaos_recovery + chaos_backpressure acceptance sections
alone.

Every ``--json`` dump carries provenance: git SHA, jax version, platform,
the command line, and the ``ForwardConfig`` fields + mesh shape of each
benchmarked configuration (``meta.configs``) — enough to re-run any row.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

# Shared harness (row sink, provenance, timing methodology, Ray44 fixture)
# — split out so new sweeps extend _harness.py instead of this file.
from _harness import (  # noqa: E402
    CONFIGS,
    ROWS,
    Ray44,
    _emit_kernel,
    _git_sha,
    _pair_ratio,
    _mesh8,
    _paired_times,
    _parse_derived,
    _ray_proto,
    _timeit,
    _write_json,
    emit,
    record_cfg,
)

PROFILE = False  # --profile: per-phase fwd_profile_* rows (see docstring)


# ------------------------------------------------- Fig. 8: wire efficiency
def fig8_efficiency():
    """Useful payload bytes ÷ total collective bytes, from the lowered HLO of
    the production 256-chip mesh — the structural analogue of Fig. 8's
    bandwidth-utilization curve (no TPU wall clock exists here)."""
    from repro.core import ForwardConfig, item_nbytes
    from repro.roofline.analysis import collective_bytes

    # AbstractMesh: lower for the 256-chip production mesh without devices
    mesh = compat.abstract_mesh((16, 16), ("data", "model"))
    if mesh is None:
        print("# fig8_efficiency skipped: no AbstractMesh in this JAX")
        return
    R = 256
    item_b = item_nbytes(_ray_proto())
    for n_emit in (64, 512, 4096, 32768):
        exchanges = ["padded"] + (
            ["ragged"] if compat.HAS_RAGGED_ALL_TO_ALL else []
        )
        for exchange in exchanges:
            cap = max(n_emit, 256)
            cfg = ForwardConfig(
                ("data", "model"), R, cap, exchange=exchange,
                peer_capacity=max(1, -(-n_emit * 2 // R)),
            )
            kern = _emit_kernel(cfg, n_emit, cap)
            t0 = time.perf_counter()
            low = jax.jit(
                compat.shard_map(kern, mesh=mesh, in_specs=P(("data", "model")),
                                 out_specs=P(("data", "model")))
            ).lower(jnp.arange(512.0))
            lower_us = (time.perf_counter() - t0) * 1e6
            coll = collective_bytes(low.as_text())
            useful = n_emit * item_b  # per rank
            if exchange == "ragged":
                # ragged payload bytes are data-dependent == useful; static
                # HLO only bounds the receive buffer.  Wire = payload +
                # control plane (the count collective).
                control = sum(v for k, v in coll.items() if k != "ragged-all-to-all")
                total = useful + control
            else:
                total = sum(coll.values())
            eff = useful / total if total else 0.0
            emit(
                f"fig8_efficiency_{exchange}_n{n_emit}", lower_us,
                f"useful_frac={eff:.3f};useful_B={useful};wire_B={total};item_B={item_b}",
            )


# --------------------------------------------- §6.1: sort stage is ~free
def sort_cost():
    from repro.core import sorting as S

    for n in (4096, 65536):
        dest = jnp.array(np.random.default_rng(0).integers(0, 256, n), jnp.int32)
        rays = jax.tree.map(lambda l: jnp.zeros((n,) + l.shape, l.dtype), _ray_proto())
        f = jax.jit(lambda r, d: S.sort_by_destination(r, d, jnp.int32(n), 256))
        us, _ = _timeit(f, rays, dest)
        cost = f.lower(rays, dest).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        flops = cost.get("flops", 0.0)
        byts = cost.get("bytes accessed", 0.0)
        wire = n * 44  # what the exchange must move anyway
        emit(
            f"sort_cost_n{n}", us,
            f"sort_bytes_over_wire_bytes={byts/max(wire,1):.2f};flops={flops:.2e}",
        )


# ------------------------------------------------ forward_work wall time
def fwd_walltime():
    from repro.core import ForwardConfig

    mesh = _mesh8()
    for n_emit in (256, 2048):
        for exchange in ("padded", "onehot"):
            cap = max(256, n_emit * 2)
            # peer_capacity only exists for padded slots (onehot rejects it)
            kw = {"peer_capacity": cap} if exchange == "padded" else {}
            cfg = ForwardConfig("data", 8, cap, exchange=exchange, **kw)
            record_cfg(f"fwd_walltime_{exchange}_n{n_emit}", cfg, mesh)
            f = jax.jit(
                compat.shard_map(_emit_kernel(cfg, n_emit, cap), mesh=mesh,
                                 in_specs=P("data"), out_specs=P("data"))
            )
            us, _ = _timeit(f, jnp.arange(8.0))
            rays_s = 8 * n_emit / (us / 1e6)
            emit(f"fwd_walltime_{exchange}_n{n_emit}", us, f"rays_per_s={rays_s:.2e}")
            if PROFILE and exchange == "padded":
                _profile_phases(f"padded_n{n_emit}", cfg, mesh, n_emit, cap)


def _profile_phases(tag, cfg, mesh, n_emit, cap):
    """--profile: thin consumer of :func:`repro.obs.phases.profile_phases`
    (PR 10 promoted the phase split into the observation law's library,
    growing it from the flat padded four to hierarchical / pipelined /
    ragged rounds).  Row names ``fwd_profile_{tag}_{phase}`` and the
    ``marshal_mode=…;n_emit=…`` derived string are STABLE since PR 8; the
    bench ``_timeit`` methodology is passed through."""
    from repro.obs.phases import profile_phases

    phase_us = profile_phases(
        cfg, mesh, n_emit=n_emit, cap=cap, proto=_ray_proto(), timeit=_timeit
    )
    for phase, us in phase_us.items():
        emit(
            f"fwd_profile_{tag}_{phase}", us,
            f"marshal_mode={cfg.marshal};n_emit={n_emit}",
        )
    return phase_us


# ------------------------------------- ISSUE 2: hierarchical vs flat route
def _hier_pair(nodes, devs, n_emit, cap):
    """(flat_cfg, hier_cfg, mesh) for one 2-D (node, device) mesh point."""
    from repro.core import ForwardConfig
    from repro.launch.mesh import make_node_mesh

    mesh = make_node_mesh(nodes, devs)
    axes = ("node", "device")
    flat = ForwardConfig(axes, nodes * devs, cap, exchange="padded")
    hier = ForwardConfig(axes, nodes * devs, cap, exchange="hierarchical", fast_size=devs)
    return flat, hier, mesh


def _time_fwd(cfg, mesh, n_emit, cap, iters=5):
    f = jax.jit(
        compat.shard_map(
            _emit_kernel(cfg, n_emit, cap), mesh=mesh,
            in_specs=P(cfg.axis_name), out_specs=P(cfg.axis_name),
        )
    )
    us, _ = _timeit(f, jnp.arange(8.0), iters=iters)
    return us


def fwd_walltime_hier():
    """Flat-vs-hierarchical forwarding walltime sweep over 2-D (node, device)
    meshes (2×4 and 4×2 on the 8-device CPU platform), plus the modeled bulk
    bytes each route pushes across the slow inter-node fabric — the term the
    two-stage exchange exists to shrink (CPU walltime treats all links as
    equal; the slow-byte model is where multi-node wins show)."""
    from repro.core import item_nbytes
    from repro.roofline.analysis import slow_axis_bytes_model

    item_b = item_nbytes(_ray_proto())
    for nodes, devs in ((2, 4), (4, 2)):
        for n_emit in (256, 2048):
            cap = max(256, n_emit * 2)
            flat, hier, mesh = _hier_pair(nodes, devs, n_emit, cap)
            R = nodes * devs
            for tag, cfg in (("flat", flat), ("hier", hier)):
                record_cfg(f"fwd_walltime_hier_{tag}_{nodes}x{devs}", cfg, mesh)
                us = _time_fwd(cfg, mesh, n_emit, cap)
                slow_b = slow_axis_bytes_model(
                    cfg.exchange if tag == "hier" else "padded",
                    num_ranks=R, fast_size=devs, item_bytes=item_b,
                    peer_capacity=cfg.peer_capacity,
                    node_capacity=getattr(cfg, "node_capacity", 0),
                )
                rays_s = 8 * n_emit / (us / 1e6)
                # burst_rows: the hot-spot burst one destination absorbs
                # without drops at this slow-byte budget.  At the default
                # load-proportional capacities the two routes' total slow
                # bytes coincide, so the discriminating metric is the slow
                # bytes PAID PER ROW of burst tolerance: (R-F)·item_B flat vs
                # (N-1)·item_B hierarchical — per-node padding makes it
                # devs× cheaper (= R/N×, since R-F = F·(N-1)).
                burst = cfg.node_capacity if tag == "hier" else cfg.peer_capacity
                emit(
                    f"fwd_walltime_hier_{tag}_{nodes}x{devs}_n{n_emit}", us,
                    f"rays_per_s={rays_s:.2e};slow_axis_B={slow_b:.0f}"
                    f";burst_rows={burst};slow_B_per_burst_row={slow_b / burst:.1f}",
                )


def _pod_configs(cap):
    """(flat, hier2, hier3, mesh) for the (2, 2, 2) three-tier mesh: flat
    routes one joint all_to_all over everything; hier2 treats (pod, node) as
    one joint slow fabric; hier3 is the full 3-level route."""
    from repro.core import ForwardConfig
    from repro.launch.mesh import make_pod_mesh

    mesh = make_pod_mesh(2, 2, 2)
    axes = ("pod", "node", "device")
    flat = ForwardConfig(axes, 8, cap, exchange="padded")
    hier2 = ForwardConfig(
        (("pod", "node"), "device"), 8, cap, exchange="hierarchical",
        level_sizes=(4, 2),
    )
    hier3 = ForwardConfig(
        axes, 8, cap, exchange="hierarchical", level_sizes=(2, 2, 2)
    )
    return flat, hier2, hier3, mesh


def _stage_crossing_rows(sub_sizes, slot_rows):
    """Rows ONE rank's padded stage pushes across each sub-tier of its
    fabric: the stage fans out prod(sub_sizes) slots of ``slot_rows``; a slot
    whose digit first differs at sub-tier j crosses fabric j (and nothing
    slower).  Returns one entry per sub-tier, slowest first."""
    out, remaining = [], 1
    for a in sub_sizes:
        remaining *= a
    for a in sub_sizes:
        out.append((remaining - remaining // a) * slot_rows)
        remaining //= a
    return out


def _route_tier_rows(tag, cfg, n_tiers=3):
    """Padded rows one rank puts on each physical fabric tier per round,
    attributed by where each slot/segment's destination digit FIRST differs
    (a flat slot to another pod crosses only the DCN hop of the route)."""
    if tag == "flat":
        return _stage_crossing_rows((2, 2, 2), cfg.peer_capacity)
    tiers = [0.0] * n_tiers
    if len(cfg.level_sizes) == 2 and cfg.level_sizes[0] == 4:
        # hier2: the joint (pod, node) slow stage spans two physical fabrics
        t0, t1 = _stage_crossing_rows((2, 2), cfg.level_capacities[0])
        tiers[0], tiers[1] = t0, t1
        tiers[2] = _stage_crossing_rows((2,), cfg.level_capacities[1])[0]
    else:
        for l, (a, s) in enumerate(zip(cfg.level_sizes, cfg.level_capacities)):
            tiers[l] = _stage_crossing_rows((a,), s)[0]
    return tiers


def _time_fwd_axes(cfg, mesh, axes, n_emit, cap, iters=5):
    """Like _time_fwd but with explicit shard_map axes (the config's level
    axes may be nested tuples, which PartitionSpec cannot carry)."""
    f = jax.jit(
        compat.shard_map(
            _emit_kernel(cfg, n_emit, cap), mesh=mesh,
            in_specs=P(axes), out_specs=P(axes),
        )
    )
    us, _ = _timeit(f, jnp.arange(8.0), iters=iters)
    return us


def fwd_walltime_hier3():
    """ISSUE 3 sweep: flat vs 2-level vs 3-level route on the (2, 2, 2)
    (pod, node, device) mesh, with the modeled bytes each route pushes across
    every fabric tier (CPU walltime treats all links as equal; the byte model
    is where the N-level win shows).  At the default load-proportional
    capacities the routes' total slowest-tier bytes can coincide, so the
    discriminating metric — as in the PR-2 2-level sweep — is the slowest-
    tier bytes PAID PER ROW of burst tolerance: 4·item_B flat (4 of 7 slots
    cross the pod fabric) vs 2·item_B hier2 (2 of 3 joint-tier segments) vs
    1·item_B hier3 (exactly the one off-pod segment)."""
    from repro.core import item_nbytes

    item_b = item_nbytes(_ray_proto())
    axes = ("pod", "node", "device")
    for n_emit in (256, 2048):
        cap = max(256, n_emit * 2)
        flat, hier2, hier3, mesh = _pod_configs(cap)
        for tag, cfg in (("flat", flat), ("hier2", hier2), ("hier3", hier3)):
            record_cfg(f"fwd_walltime_hier3_{tag}", cfg, mesh)
            us = _time_fwd_axes(cfg, mesh, axes, n_emit, cap)
            tiers = [r * item_b for r in _route_tier_rows(tag, cfg)]
            # burst_rows: the hot-spot burst one destination absorbs without
            # drops at this budget (per-slot flat, per slowest-segment hier)
            burst = (
                cfg.peer_capacity if tag == "flat" else cfg.level_capacities[0]
            )
            rays_s = 8 * n_emit / (us / 1e6)
            emit(
                f"fwd_walltime_hier3_{tag}_2x2x2_n{n_emit}", us,
                f"rays_per_s={rays_s:.2e};tier0_B={tiers[0]:.0f}"
                f";tier1_B={tiers[1]:.0f};tier2_B={tiers[2]:.0f}"
                f";burst_rows={burst}"
                f";tier0_B_per_burst_row={tiers[0] / burst:.1f}",
            )


def rebalance_skew():
    """ISSUE 3: skewed-load rebalance on the (2, 2, 2) mesh — flat global
    plan vs topology-aware plan vs intra-tier scope, with the payload bytes
    the lowered program puts on each fabric tier (from the HLO replica
    groups).  The intra route must show ZERO bytes below the fastest tier."""
    from repro.core import DISCARD, ForwardConfig, WorkQueue, rebalance
    from repro.core import types as T
    from repro.launch.mesh import make_pod_mesh
    from repro.roofline.analysis import per_tier_collective_bytes

    sizes = (2, 2, 2)
    axes = ("pod", "node", "device")
    mesh = make_pod_mesh(*sizes)
    cap = 512
    words = T.pack_spec(_ray_proto()).total_words
    flat_cfg = ForwardConfig(axes, 8, cap, exchange="padded")
    hier_cfg = ForwardConfig(
        axes, 8, cap, exchange="hierarchical", level_sizes=sizes
    )

    def bench(tag, cfg, scope):
        def bal(_x):
            me = jax.lax.axis_index(axes)
            n = jnp.where(me % 2 == 0, 300, 4)  # node-local hoarders
            rays = jax.tree.map(
                lambda l: jnp.zeros((cap,) + l.shape, l.dtype), _ray_proto()
            )
            q = WorkQueue(
                items=rays, dest=jnp.full((cap,), DISCARD, jnp.int32),
                count=n.astype(jnp.int32), drops=jnp.zeros((), jnp.int32),
            )
            nq, total = rebalance(q, cfg, scope=scope)
            checksum = jnp.sum(nq.items.tmin) * 0
            return nq.count[None] + checksum.astype(jnp.int32)

        f = jax.jit(
            compat.shard_map(bal, mesh=mesh, in_specs=P(axes), out_specs=P(axes))
        )
        us, _ = _timeit(f, jnp.arange(8.0))
        per_tier = per_tier_collective_bytes(
            f.lower(jnp.arange(8.0)).as_text(), sizes, min_bytes=words * 4 * 8
        )
        emit(
            f"rebalance_skew_{tag}_2x2x2", us,
            f"tier0_B={per_tier[0]};tier1_B={per_tier[1]}"
            f";tier2_B={per_tier[2]};cross_B={per_tier['cross']}",
        )
        return per_tier

    bench("flat", flat_cfg, "global")
    bench("hier", hier_cfg, "global")
    intra = bench("intra", hier_cfg, "intra")
    if intra[0] or intra[1] or intra["cross"]:
        raise RuntimeError(
            f"intra-scope rebalance leaked payload bytes off the fastest "
            f"tier: {intra}"
        )


# ------------------------------------- ISSUE 5: drifting hot-spot autotune
def _drift_run_burst(mesh, axes, num_ranks, cap, n_emit, rounds, times):
    """``tune.autotune_forward`` burst driver for the drifting hot-spot
    scenario: every round, half of each rank's emits chase a hot destination
    that ROTATES every 2 rounds — a workload no single static observation
    sizes correctly, which is exactly what the flight recorder's windowed
    max is for.  Each distinct config re-jits (configs are static);
    per-burst walltimes are appended to ``times``."""
    from repro import telemetry as TM
    from repro.core import DISCARD, enqueue, make_queue, run_until_done

    def emits(me, rnd):
        lane = jnp.arange(n_emit)
        hot = (rnd // 2) % num_ranks
        dest = jnp.where(lane % 2 == 0, hot, (me + lane) % num_ranks)
        rays = Ray44(
            origin=jnp.ones((n_emit, 3)), direction=jnp.ones((n_emit, 3)),
            tmin=lane.astype(jnp.float32), pixel=lane.astype(jnp.int32),
            integral=jnp.zeros(n_emit), extra=jnp.zeros((n_emit, 2)),
        )
        return rays, dest.astype(jnp.int32)

    compiled = {}

    def run_burst(cfg):
        if cfg not in compiled:
            def round_fn(q_in, acc, rnd):
                me = jax.lax.axis_index(axes)
                rays, dest = emits(me, rnd + 1)
                out = make_queue(_ray_proto(), cap)
                out = enqueue(
                    out, rays, jnp.where(rnd + 1 < rounds, dest, DISCARD),
                    jnp.ones(n_emit, bool),
                )
                return out, acc

            def drive(_x):
                me = jax.lax.axis_index(axes)
                rays, dest = emits(me, 0)
                q0 = enqueue(
                    make_queue(_ray_proto(), cap), rays, dest,
                    jnp.ones(n_emit, bool),
                )
                q, _acc, _r, _done, ring = run_until_done(
                    round_fn, q0, jnp.zeros((), jnp.int32), cfg,
                    max_rounds=rounds + 2,
                )
                return q.drops[None], TM.stack_ring(ring)

            ring_spec = jax.tree.map(
                lambda _: P(axes),
                TM.make_ring(
                    TM.num_tiers(cfg), window=cfg.telemetry_window,
                    buckets=cfg.telemetry_buckets,
                ),
            )
            compiled[cfg] = jax.jit(
                compat.shard_map(
                    drive, mesh=mesh, in_specs=P(axes),
                    out_specs=(P(axes), ring_spec),
                )
            )
        t0 = time.perf_counter()
        drops, ring = jax.block_until_ready(compiled[cfg](jnp.arange(8.0)))
        times.append((time.perf_counter() - t0) * 1e6)
        return int(np.asarray(drops).sum()), ring

    return run_burst


def autotune_drift():
    """ISSUE 5 acceptance: on the drifting hot-spot, ``autotune_forward``
    must converge from a deliberately undersized config to VERIFIED zero
    clamp drops, with modeled padded wire bytes ≤ the §6.3 worst-case static
    sizing that achieves the same (per tier, a slot concatenates the emits
    of every source sub-segment feeding it — n_emit × that fan-in is the
    provable bound and the tuner's ceiling)."""
    from repro import telemetry as TM
    from repro.core import ForwardConfig, item_nbytes
    from repro.launch.mesh import make_pod_mesh
    from repro.roofline.analysis import occupancy_waste_model
    from repro.tune import TunePolicy, autotune_forward

    item_b = item_nbytes(_ray_proto())
    cap, n_emit, rounds = 1024, 96, 8
    axes3 = ("pod", "node", "device")
    scenarios = (
        (
            "flat", _mesh8(), "data", (8,), (n_emit,),
            dict(exchange="padded", peer_capacity=8),
        ),
        (
            "hier3", make_pod_mesh(2, 2, 2), axes3, (2, 2, 2),
            (4 * n_emit, 2 * n_emit, n_emit),
            dict(
                exchange="hierarchical", level_sizes=(2, 2, 2),
                level_capacities=(8, 8, 8),
            ),
        ),
    )
    for tag, mesh, axes, sizes, bounds, kw in scenarios:
        times = []
        run_burst = _drift_run_burst(mesh, axes, 8, cap, n_emit, rounds, times)
        cfg0 = ForwardConfig(
            axes, 8, cap, telemetry=True, telemetry_window=rounds + 2, **kw
        )
        final, report = autotune_forward(
            run_burst, cfg0,
            policy=TunePolicy(headroom=1.25, granularity=8),
            bounds=bounds, max_bursts=6,
        )
        for s, us in zip(report.steps, times):
            emit(
                f"autotune_drift_{tag}_burst{s.burst}", us,
                f"drops={s.drops}"
                f";caps={'/'.join(map(str, s.capacities))}"
                f";planned={'/'.join(map(str, s.planned))}"
                f";demand_max={'/'.join(map(str, s.demand_max))}",
            )
        tuned = occupancy_waste_model(
            sizes, TM.tier_capacities(final), item_b
        )
        static = occupancy_waste_model(sizes, bounds, item_b)
        record_cfg(f"autotune_drift_{tag}_final", final, mesh)
        emit(
            f"autotune_drift_{tag}_final", float(np.mean(times)),
            f"converged={int(report.converged)};final_drops={report.final_drops}"
            f";bursts={report.bursts}"
            f";tuned_wire_B={tuned['wire_B']:.0f}"
            f";static_wire_B={static['wire_B']:.0f}"
            f";caps={'/'.join(map(str, TM.tier_capacities(final)))}",
        )
        if (
            not report.converged
            or report.final_drops != 0
            or tuned["wire_B"] > static["wire_B"]
        ):
            raise RuntimeError(
                f"autotune_drift_{tag} failed: converged={report.converged} "
                f"final_drops={report.final_drops} tuned_wire_B="
                f"{tuned['wire_B']:.0f} static_wire_B={static['wire_B']:.0f}"
            )


# ------------------------------------- ISSUE 5: telemetry overhead gate
def fwd_walltime_telemetry(samples=8):
    """Flight-recorder overhead sweep: the same forwarding round with
    ``telemetry`` off vs on (flat padded + 3-level hierarchical), timed
    interleaved per point (see :func:`_paired_times`).  Returns
    ``{(tag, variant, n_emit): us}`` for the ``--compare off,telemetry``
    gate (on/off walltime geomean must stay ≤ 1.05)."""
    from repro.core import ForwardConfig
    from repro.launch.mesh import make_pod_mesh

    mesh_flat = _mesh8()
    mesh_pod = make_pod_mesh(2, 2, 2)
    axes3 = ("pod", "node", "device")
    times = {}
    for n_emit in (256, 2048):
        cap = max(256, n_emit * 2)
        points = (
            (
                "flat", mesh_flat, "data",
                lambda t: ForwardConfig(
                    "data", 8, cap, exchange="padded", telemetry=t
                ),
            ),
            (
                "hier3", mesh_pod, axes3,
                lambda t: ForwardConfig(
                    axes3, 8, cap, exchange="hierarchical",
                    level_sizes=(2, 2, 2), telemetry=t,
                ),
            ),
        )
        for tag, mesh, axes, mk_cfg in points:
            best = _paired_times(
                {"off": mk_cfg(False), "telemetry": mk_cfg(True)},
                mesh, axes, n_emit, cap, samples,
            )
            record_cfg(f"telemetry_{tag}_n{n_emit}", mk_cfg(True), mesh)
            for variant, us in best.items():
                times[(tag, variant, n_emit)] = us
                rays_s = 8 * n_emit / (us / 1e6)
                emit(
                    f"fwd_walltime_telemetry_{tag}_{variant}_n{n_emit}", us,
                    f"rays_per_s={rays_s:.2e}",
                )
    return times


# --------------------------------- ISSUE 6: lossless forwarding (chaos)
def fwd_walltime_overflow(samples=8):
    """Retain-mode overhead sweep on the HAPPY PATH (capacity ample, zero
    spill pressure): the same forwarding round with ``overflow`` drop vs
    retain (flat padded + 3-level hierarchical), timed interleaved per point.
    Returns ``{(tag, variant, n_emit): us}`` for the ``--compare drop,retain``
    gate (retain/drop walltime geomean must stay ≤ 1.05 — retention must be
    free when nothing spills)."""
    from repro.core import ForwardConfig
    from repro.launch.mesh import make_pod_mesh

    mesh_flat = _mesh8()
    mesh_pod = make_pod_mesh(2, 2, 2)
    axes3 = ("pod", "node", "device")
    times = {}
    for n_emit in (256, 2048):
        cap = max(256, n_emit * 2)
        points = (
            (
                "flat", mesh_flat, "data",
                lambda o: ForwardConfig(
                    "data", 8, cap, exchange="padded", overflow=o
                ),
            ),
            (
                "hier3", mesh_pod, axes3,
                lambda o: ForwardConfig(
                    axes3, 8, cap, exchange="hierarchical",
                    level_sizes=(2, 2, 2), overflow=o,
                ),
            ),
        )
        for tag, mesh, axes, mk_cfg in points:
            best = _paired_times(
                {"drop": mk_cfg("drop"), "retain": mk_cfg("retain")},
                mesh, axes, n_emit, cap, samples,
            )
            record_cfg(f"overflow_{tag}_n{n_emit}", mk_cfg("retain"), mesh)
            for variant, us in best.items():
                times[(tag, variant, n_emit)] = us
                rays_s = 8 * n_emit / (us / 1e6)
                emit(
                    f"fwd_walltime_overflow_{tag}_{variant}_n{n_emit}", us,
                    f"rays_per_s={rays_s:.2e}",
                )
    return times


def chaos_lossless():
    """The ISSUE-6 acceptance run: every chaos scenario, retain vs drop,
    under deliberately starved send budgets (peer slots of 2 rows where the
    convergecast backlogs 48 per sender).  Records per-scenario loss
    accounting and RAISES unless (a) retain mode loses NOTHING anywhere
    (drops == lost == 0, clean drain, age within the spill_drain_model
    bound) while (b) drop mode — the same traffic, same capacities — loses
    >20%% of the convergecast.  That contrast is the subsystem's reason to
    exist; a silent regression here must trip CI, not trend a row."""
    from repro.chaos import all_scenarios, run_scenario
    from repro.roofline.analysis import spill_drain_model

    mesh = _mesh8()
    S, C = 2, 128
    problems = []
    for sc in all_scenarios(8):
        rows = {}
        for mode in ("drop", "retain"):
            t0 = time.perf_counter()
            res = run_scenario(
                mesh, sc, capacity=C, peer_capacity=S, overflow=mode,
                max_rounds=64,
            )
            dt = time.perf_counter() - t0
            rows[mode] = res
            loss_frac = (res["drops"] + res["lost"]) / res["emitted"]
            emit(
                f"chaos_{sc.name}_{mode}", dt * 1e6,
                f"emitted={res['emitted']};delivered={res['delivered_total']}"
                f";drops={res['drops']};lost={res['lost']}"
                f";loss_frac={loss_frac:.3f};rounds={res['rounds']}"
                f";age_max={res.get('age_max', 0)}",
            )
            if res["lost"] != 0:  # conservation broken in EITHER mode
                problems.append(f"{sc.name}/{mode}: lost={res['lost']}")
        ret = rows["retain"]
        if ret["drops"] != 0 or not ret["done"]:
            problems.append(
                f"{sc.name}/retain: drops={ret['drops']} done={ret['done']}"
            )
        bound = (
            spill_drain_model(sc.rounds * sc.emits_per_round, S)["age_bound"]
            + sc.rounds
        )
        if ret["age_max"] > bound:
            problems.append(
                f"{sc.name}/retain: age_max={ret['age_max']} > bound={bound}"
            )
        if sc.name == "convergecast":
            frac = rows["drop"]["drops"] / sc.emitted
            if frac <= 0.2:
                problems.append(
                    f"convergecast/drop: loses only {frac:.1%} — the starved "
                    "budgets no longer demonstrate the retain win"
                )
    if problems:
        raise RuntimeError("chaos gate failed: " + "; ".join(problems))
    print("# chaos ok: retain lossless on all scenarios, drop >20% loss "
          "on convergecast, ages within drain bound")


# --------------------------------- ISSUE 7: recovery law (ckpt / brownout)
def _ballast_round_fn(base, width=48, iters=512):
    """Wrap a chaos ``round_fn`` with app-realistic per-round compute (a
    ray-march-shaped ``fori_loop`` over a per-lane scratch).  The overhead
    gate must amortize the checkpoint writer against rounds that DO WORK —
    the bare chaos probe rounds are ~1 ms microbenchmarks, an order of
    magnitude under any real per-round app kernel (trace, integrate, shade),
    and would overstate the writer's relative cost by that same factor.  The
    ballast folds into the aux through a branch XLA cannot constant-fold
    (``isnan`` of a finite sum is 0 only at runtime) without perturbing any
    checksum."""

    def round_fn(q_in, aux, rnd):
        x = q_in.items.val[:, :1] * jnp.ones((1, width)) + 1.0
        x = jax.lax.fori_loop(
            0, iters, lambda i, v: v * 0.999 + jnp.sin(v) * 1e-3, x
        )
        out, (cnt, s, s2) = base(q_in, aux, rnd)
        cnt = cnt + jnp.where(
            jnp.isnan(jnp.sum(x)), jnp.uint32(1), jnp.uint32(0)
        )
        return out, (cnt, s, s2)

    return round_fn


def fwd_walltime_ckpt(samples=3):
    """Segmented-drive walltime with the checkpoint writer OFF vs ON
    (``ckpt_dir=None`` vs a real directory) at the ISSUE-7 amortization
    point ``checkpoint_every=8``, on two convergecast burst lengths with
    ballasted rounds (:func:`_ballast_round_fn`).  Both variants run the
    SAME compiled start/segment programs and the same host boundary loop —
    the delta is exactly what the writer adds per boundary (serialize +
    fsync + retention sweep), amortized over the W rounds between saves.
    Timed interleaved with per-variant medians (the runs are seconds long;
    interleaving cancels the host's slow load drift).  Returns
    ``{(tag, variant): us}`` for the ``--compare nockpt,ckpt`` gate."""
    import tempfile

    from repro.chaos import convergecast
    from repro.chaos.driver import _aux0, _make_ctx, _make_round_fn, _seed_queue
    from repro.core import recovery

    mesh = _mesh8()
    S, C, W, max_rounds = 2, 128, 8, 64
    times = {}
    for tag, sc in (
        ("short", convergecast(8)),
        ("long", convergecast(8, rounds=8)),
    ):
        ctx = _make_ctx(
            mesh, capacity=C, peer_capacity=S, overflow="retain",
            max_rounds=max_rounds,
        )
        spec = ctx._spec
        start_p, segment_p = ctx.checkpoint_drive_programs(
            _ballast_round_fn(_make_round_fn(ctx, sc)),
            aux_specs=(spec, spec, spec), accounting=True,
        )
        carry0 = start_p(_seed_queue(sc, C), _aux0(8), np.ones((8,), bool))
        jax.block_until_ready(jax.tree.leaves(carry0))
        ckpt_root = tempfile.mkdtemp(prefix=f"rafi_bench_ckpt_{tag}_")
        record_cfg(f"ckpt_{tag}", ctx.cfg, mesh)

        def run(ckpt_dir):
            # reuse the REAL boundary loop (not a replica) against the one
            # pair of compiled programs, so the variants differ only in the
            # writer work — recompiling per call would drown the delta
            res = recovery._drive_loop(
                ctx, segment_p, carry0, ckpt_dir=ckpt_dir,
                checkpoint_every=W, max_rounds=max_rounds,
                health=None, keep=3, halt_after_round=None,
            )
            assert res["done"]
            return res

        res = run(None)
        run(ckpt_root)  # publish once: later samples measure the overwrite
        rounds = res["rounds"]  # steady state (replace + retention sweep)
        saves = rounds // W + 1 + (1 if rounds % W else 0)
        ts = {"nockpt": [], "ckpt": []}
        for _ in range(samples):
            for variant, d in (("nockpt", None), ("ckpt", ckpt_root)):
                t0 = time.perf_counter()
                run(d)
                ts[variant].append((time.perf_counter() - t0) * 1e6)
        for variant, v in ts.items():
            us = float(np.median(v))
            times[(tag, variant)] = us
            emit(
                f"fwd_walltime_ckpt_{tag}_{variant}", us,
                f"rounds={rounds};boundaries={saves};W={W}"
                f";rounds_per_s={rounds / (us / 1e6):.1f}",
            )
    return times


def chaos_recovery():
    """The ISSUE-7 acceptance run: the recovery law, end to end, RAISING on
    any violation (like :func:`chaos_lossless`, this must trip CI, not trend
    a row).

    * **Preempt/resume bit-exactness** — the capacity-drought burst driven
      through the checkpointed drive uninterrupted vs killed at round 5 and
      resumed from disk: both runs must publish the SAME boundary rounds
      with IDENTICAL per-leaf SHA-256 digests at every common boundary
      (``boundary_digests`` — byte equality of the full forwarding state,
      no tolerance), and both must drain lossless to the schedule's
      checksums.
    * **Brownout losslessness** — the rank-brownout burst with two ranks
      going dark at round 3 (health re-read each segment boundary): zero
      drops, zero lost, clean drain, and the whole trajectory — deliveries
      AND round count — equal to the numpy twin evaluated under the
      device's segment-boundary health timing."""
    import tempfile

    from repro.chaos import (
        boundary_digests,
        brownout_mask,
        capacity_drought,
        expected_by_rank,
        rank_brownout,
        run_scenario_checkpointed,
        simulate_flat_retain,
    )

    mesh = _mesh8()
    S, C, W = 2, 128, 3
    problems = []

    # --- preempt at round 5, resume, compare boundary digests
    sc = capacity_drought(8)
    kw = dict(
        capacity=C, peer_capacity=S, overflow="retain", max_rounds=64,
        checkpoint_every=W, keep=99,
    )
    with tempfile.TemporaryDirectory() as da, tempfile.TemporaryDirectory() as db:
        t0 = time.perf_counter()
        a = run_scenario_checkpointed(mesh, sc, ckpt_dir=da, **kw)
        b = run_scenario_checkpointed(
            mesh, sc, ckpt_dir=db, preempt_at=5, **kw
        )
        dt = time.perf_counter() - t0
        dga, dgb = boundary_digests(da), boundary_digests(db)
        common = sorted(set(dga) & set(dgb))
        emit(
            f"chaos_recovery_preempt_{sc.name}", dt * 1e6,
            f"rounds={a['rounds']};boundaries={len(dga)}"
            f";common={len(common)};preempted={b['preempted']}",
        )
        if not b["preempted"]:
            problems.append("preempt: halt_after_round=5 did not preempt")
        if a["steps"] != b["steps"]:
            problems.append(
                f"preempt: boundary rounds diverge {a['steps']} vs {b['steps']}"
            )
        if len(common) < 3:
            problems.append(f"preempt: only {len(common)} common boundaries")
        for s in common:
            if dga[s] != dgb[s]:
                problems.append(f"preempt: digest mismatch at boundary {s}")
        for tag, r in (("uninterrupted", a), ("resumed", b)):
            if r["drops"] or r["lost"] or not r["done"]:
                problems.append(
                    f"preempt/{tag}: drops={r['drops']} lost={r['lost']} "
                    f"done={r['done']}"
                )
        if not np.array_equal(a["delivered"], expected_by_rank(sc)):
            problems.append("preempt: delivered checksums != schedule oracle")

    # --- brownout: ranks 2 and 5 go dark at round 3, nothing is lost
    sc = rank_brownout(8)
    health = brownout_mask(8, down=(2, 5), down_from=3)

    def twin_health(f):
        # the device re-reads health at segment boundaries: forward 0 routes
        # under health(0); forward f >= 1 (body round f-1) under the mask of
        # the boundary that launched its segment
        return health(0) if f == 0 else health(W * ((f - 1) // W))

    sim = simulate_flat_retain(
        sc, peer_capacity=S, capacity=C, health=twin_health
    )
    with tempfile.TemporaryDirectory() as dc:
        t0 = time.perf_counter()
        res = run_scenario_checkpointed(
            mesh, sc, ckpt_dir=dc, capacity=C, peer_capacity=S,
            overflow="retain", max_rounds=64, checkpoint_every=W,
            keep=99, health=health,
        )
        dt = time.perf_counter() - t0
        emit(
            f"chaos_recovery_brownout_{sc.name}", dt * 1e6,
            f"emitted={res['emitted']};delivered={res['delivered_total']}"
            f";drops={res['drops']};lost={res['lost']}"
            f";rounds={res['rounds']}",
        )
        if res["drops"] or res["lost"] or not res["done"]:
            problems.append(
                f"brownout: drops={res['drops']} lost={res['lost']} "
                f"done={res['done']}"
            )
        if res["delivered_total"] != sc.emitted:
            problems.append(
                f"brownout: delivered {res['delivered_total']} != emitted "
                f"{sc.emitted}"
            )
        if not np.array_equal(res["delivered"], sim["delivered"]):
            problems.append("brownout: device checksums != numpy twin")
        if res["rounds"] != sim["rounds"]:
            problems.append(
                f"brownout: rounds {res['rounds']} != twin {sim['rounds']}"
            )
    if problems:
        raise RuntimeError("recovery gate failed: " + "; ".join(problems))
    print(
        "# recovery ok: preempt-resume bit-exact at every common boundary, "
        "brownout lossless and twin-exact"
    )


# --------------------------------- ISSUE 9: backpressure (credit flow)
def fwd_walltime_flow(samples=8):
    """Credit-flow overhead sweep on the HAPPY PATH (every receiver fully
    credited, nothing gated): the same retain-mode forwarding round with
    ``flow`` open vs credit (flat padded + 3-level hierarchical), timed
    interleaved per point.  Returns ``{(tag, variant, n_emit): us}`` for the
    ``--compare open,credit`` gate (credit/open walltime geomean must stay
    ≤ 1.05 — the advert column and the grant arithmetic must be ~free when
    nobody is starved)."""
    from repro.core import ForwardConfig
    from repro.launch.mesh import make_pod_mesh

    mesh_flat = _mesh8()
    mesh_pod = make_pod_mesh(2, 2, 2)
    axes3 = ("pod", "node", "device")
    times = {}
    for n_emit in (256, 2048):
        cap = max(256, n_emit * 2)
        points = (
            (
                "flat", mesh_flat, "data",
                lambda f: ForwardConfig(
                    "data", 8, cap, exchange="padded", overflow="retain",
                    flow=f,
                ),
            ),
            (
                "hier3", mesh_pod, axes3,
                lambda f: ForwardConfig(
                    axes3, 8, cap, exchange="hierarchical",
                    level_sizes=(2, 2, 2), overflow="retain", flow=f,
                ),
            ),
        )
        for tag, mesh, axes, mk_cfg in points:
            best = _paired_times(
                {"open": mk_cfg("open"), "credit": mk_cfg("credit")},
                mesh, axes, n_emit, cap, samples,
            )
            record_cfg(f"flow_{tag}_n{n_emit}", mk_cfg("credit"), mesh)
            for variant, us in best.items():
                times[(tag, variant, n_emit)] = us
                rays_s = 8 * n_emit / (us / 1e6)
                emit(
                    f"fwd_walltime_flow_{tag}_{variant}_n{n_emit}", us,
                    f"rays_per_s={rays_s:.2e}",
                )
    return times


def chaos_backpressure():
    """The ISSUE-9 acceptance run: the two overload scenarios (fixed
    hot-pair saturation, full-width incast) under queue capacities their
    offered load overwhelms, open vs credit flow.  Records per-scenario
    goodput/waste accounting and RAISES unless (a) OPEN flow wastes >30%%
    of its wire rows on receiver drops — the configs must keep demonstrating
    the collapse — while (b) CREDIT flow on the IDENTICAL schedule delivers
    every row with zero receiver drops, zero emission overflow, a
    payload-free first round (the zero-credit cold start), occupancy
    bounded by the configured queues, and a clean drain.  Graceful
    degradation must trip CI when it regresses, not trend a row."""
    from repro.chaos import overload_scenarios, run_scenario

    mesh = _mesh8()
    # per-scenario (capacity, slot): each pins open-flow waste >30% while
    # staying large enough that the gated emitter never clips a seed row
    caps = {"sustained_overload": (16, 4), "incast_collapse": (32, 8)}
    problems = []
    for sc in overload_scenarios(8):
        C, S = caps[sc.name]
        rows = {}
        for flow in ("open", "credit"):
            t0 = time.perf_counter()
            res = run_scenario(
                mesh, sc, capacity=C, peer_capacity=S, overflow="retain",
                flow=flow, max_rounds=256,
            )
            dt = time.perf_counter() - t0
            rows[flow] = res
            waste = res["wasted_wire_rows"] / max(res["wire_rows"], 1)
            emit(
                f"chaos_backpressure_{sc.name}_{flow}", dt * 1e6,
                f"emitted={res['emitted']};delivered={res['delivered_total']}"
                f";drops={res['drops']};lost={res['lost']}"
                f";goodput={res['goodput']:.3f};waste_frac={waste:.3f}"
                f";emit_overflow={res['emit_overflow']}"
                f";rounds={res['rounds']};age_max={res.get('age_max', 0)}",
            )
            if res["lost"] != 0:  # conservation broken in EITHER mode
                problems.append(f"{sc.name}/{flow}: lost={res['lost']}")
        op, cr = rows["open"], rows["credit"]
        waste = op["wasted_wire_rows"] / max(op["wire_rows"], 1)
        if waste <= 0.30:
            problems.append(
                f"{sc.name}/open: wastes only {waste:.1%} of wire rows — the "
                "overload no longer demonstrates the credit win"
            )
        if cr["drops"] != 0 or cr["emit_overflow"] != 0 or not cr["done"]:
            problems.append(
                f"{sc.name}/credit: drops={cr['drops']} "
                f"emit_overflow={cr['emit_overflow']} done={cr['done']}"
            )
        if cr["delivered_total"] != sc.emitted:
            problems.append(
                f"{sc.name}/credit: delivered {cr['delivered_total']} != "
                f"emitted {sc.emitted}"
            )
        if cr["goodput"] < op["goodput"] or cr["goodput"] != 1.0:
            problems.append(
                f"{sc.name}: credit goodput {cr['goodput']:.3f} must be 1.0 "
                f"(open: {op['goodput']:.3f})"
            )
        if int(np.asarray(cr["recv_trace"])[0]) != 0:
            problems.append(
                f"{sc.name}/credit: first round shipped payload before any "
                "receiver advertised"
            )
        if int(np.asarray(cr["retained_trace"]).max()) > 8 * C:
            problems.append(
                f"{sc.name}/credit: retained rows exceed the configured "
                f"queues ({int(np.asarray(cr['retained_trace']).max())} > "
                f"{8 * C}) — occupancy unbounded"
            )
    if problems:
        raise RuntimeError("backpressure gate failed: " + "; ".join(problems))
    print(
        "# backpressure ok: open flow wastes >30% wire rows on both overload "
        "scenarios, credit flow drains both lossless with goodput 1.0, "
        "bounded occupancy, and an advert-only first round"
    )


# --------------------------------- ISSUE 10: the observation law (obs)
def fwd_walltime_obs(samples=8):
    """Observation-law overhead sweep: the SAME compiled chaos burst timed
    with the ambient tracer OFF vs ON — the ON arm pays the ambient cost of
    the toggle (the drive-entry span hooks recording into the ring buffer).
    The lowered device program is shared by construction (obs is host-only;
    HLO bit-identity is guarded in ``tests/test_collective_budget.py``), so
    the delta is exactly the host bookkeeping.  Interleaved samples,
    per-variant medians (see :func:`_paired_times` for why).

    The metrics EXPORT (``obs.metrics.from_summary`` + the Prometheus
    render on the burst's flight-recorder summary) is an explicit user
    call, not part of the toggle — its cost is emitted as an informational
    ``_metrics`` row per scenario, outside the overhead gate.  Returns
    ``{(tag, variant): us}`` for the ``--compare off,obs`` gate."""
    from repro.chaos.driver import _aux0, _make_ctx, _make_round_fn, _seed_queue
    from repro.chaos.scenarios import burst_storm, rotating_hotspot
    from repro.obs import metrics as OM
    from repro.obs import trace as OT
    from repro.telemetry import stats as TS

    mesh = _mesh8()
    times = {}
    for tag, sc in (("hotspot", rotating_hotspot(8)), ("burst", burst_storm(8))):
        ctx = _make_ctx(mesh, capacity=256, peer_capacity=64, max_rounds=32)
        rfn = _make_round_fn(ctx, sc)
        spec = ctx._spec
        drive = ctx.run_until_done(rfn, aux_specs=(spec,) * 3, max_rounds=32)
        q0 = _seed_queue(sc, 256)
        aux0 = _aux0(8)
        caps = TS.tier_capacities(ctx.cfg)

        def burst():
            out = drive(q0, aux0)
            jax.block_until_ready(jax.tree.leaves(out))
            return out

        burst()
        out = burst()  # compile + warm
        ts = {"off": [], "obs": []}
        for _ in range(samples):
            t0 = time.perf_counter()
            burst()
            ts["off"].append((time.perf_counter() - t0) * 1e6)
            with OT.capture():
                t0 = time.perf_counter()
                burst()
                ts["obs"].append((time.perf_counter() - t0) * 1e6)
        record_cfg(f"obs_{tag}", ctx.cfg, mesh)
        for variant, v in ts.items():
            us = float(np.median(v))
            times[(tag, variant)] = us
            emit(
                f"fwd_walltime_obs_{tag}_{variant}", us,
                f"scenario={sc.name};rounds_max=32",
            )
        # metrics export cost — explicit user call, informational (ungated)
        mts = []
        for _ in range(max(samples, 5)):
            t0 = time.perf_counter()
            summary = TS.summarize(out[-1], tier_capacities=caps)
            OM.to_prometheus(OM.from_summary(summary))
            mts.append((time.perf_counter() - t0) * 1e6)
        emit(
            f"fwd_walltime_obs_{tag}_metrics", float(np.median(mts)),
            f"scenario={sc.name};rounds_max=32;gated=no",
        )
    return times


def obs_flight_report():
    """The ISSUE-10 acceptance run: capture the incast-collapse overload
    pair (open vs credit, the PR-9 gauntlet point) with the ambient tracer
    on, build the flight capture, and run the ``repro.obs.report`` analyzer
    over it.  RAISES unless the report (a) reproduces the chaos driver's
    goodput and wasted-wire-row numbers exactly from the capture alone and
    (b) flags the open-flow run — and ONLY it — as degraded.  Like the other
    acceptance sections this must trip CI, not trend a row."""
    import tempfile
    from pathlib import Path

    from repro.chaos import run_scenario
    from repro.chaos.scenarios import incast_collapse
    from repro.obs import report as OR
    from repro.obs import trace as OT

    mesh = _mesh8()
    sc = incast_collapse(8)
    C, S = 32, 8  # the chaos_backpressure gauntlet's incast point
    runs, events, driver = [], [], {}
    for flow in ("open", "credit"):
        t0 = time.perf_counter()
        with OT.capture() as tr:
            res = run_scenario(
                mesh, sc, capacity=C, peer_capacity=S, overflow="retain",
                flow=flow, max_rounds=256,
            )
        dt = time.perf_counter() - t0
        driver[flow] = res
        runs.append(OR.chaos_capture(
            f"{sc.name}_{flow}", res, flow=flow, tier_capacities=(S,),
            capacity=C,
        ))
        events.extend(tr.events)
        emit(
            f"obs_flight_{sc.name}_{flow}", dt * 1e6,
            f"goodput={res['goodput']:.3f};wasted={res['wasted_wire_rows']}"
            f";wire={res['wire_rows']};rounds={res['rounds']}",
        )
    problems = []
    with tempfile.TemporaryDirectory() as d:
        path = OR.save_capture(
            Path(d) / "capture.json", runs, events=events,
            meta={"source": "benchmarks.obs_flight_report"},
        )
        report = OR.analyze(OR.load_capture(path))
    for rr in report["runs"]:
        res = driver[rr["flow"]]
        if abs(rr["goodput"] - res["goodput"]) > 1e-9:
            problems.append(
                f"{rr['name']}: report goodput {rr['goodput']:.6f} != driver "
                f"{res['goodput']:.6f}"
            )
        if rr["wasted_wire_rows"] != res["wasted_wire_rows"]:
            problems.append(
                f"{rr['name']}: report wasted {rr['wasted_wire_rows']} != "
                f"driver {res['wasted_wire_rows']}"
            )
        bad = [c["check"] for c in rr["checks"] if not c["ok"]]
        if bad:
            problems.append(f"{rr['name']}: failed checks {bad}")
    deg = set(report["degraded_runs"])
    if deg != {f"{sc.name}_open"}:
        problems.append(
            f"degraded set {sorted(deg)} != exactly the open run"
        )
    if problems:
        raise RuntimeError("obs flight gate failed: " + "; ".join(problems))
    print(
        "# obs flight ok: report reproduces driver goodput/waste on both "
        "incast runs and flags only the open run as degraded"
    )


# ------------------------------------- ISSUE 4: sort vs scatter marshal
def _paired_marshal_times(mk_cfg, mesh, axes, n_emit, cap, samples):
    return _paired_times(
        {m: mk_cfg(m) for m in ("sort", "scatter")},
        mesh, axes, n_emit, cap, samples,
    )


def fwd_walltime_marshal(samples=8):
    """Sort vs scatter marshal sweep: the flat padded exchange on the 8-way
    mesh and the 3-level hierarchical route on the (2, 2, 2) pod mesh, both
    marshal modes, with the modeled marshal plan bytes alongside (the scatter
    deletes the key pack + O(C log C) sort traffic; payload passes stay at
    the one-pass law in both modes).  Per point the two modes are timed
    interleaved and the per-mode MEDIAN over ``samples`` is recorded (see
    :func:`_paired_marshal_times`).  Returns ``{(tag, marshal, n_emit): us}``
    for the ``--compare sort,scatter`` gate."""
    from repro.core import ForwardConfig, item_nbytes
    from repro.launch.mesh import make_pod_mesh
    from repro.roofline.analysis import marshal_cost_model

    item_b = item_nbytes(_ray_proto())
    mesh_flat = _mesh8()
    mesh_pod = make_pod_mesh(2, 2, 2)
    axes3 = ("pod", "node", "device")
    times = {}
    for n_emit in (256, 2048):
        cap = max(256, n_emit * 2)
        points = (
            (
                "flat", mesh_flat, "data",
                lambda m: ForwardConfig("data", 8, cap, exchange="padded", marshal=m),
            ),
            (
                "hier3", mesh_pod, axes3,
                lambda m: ForwardConfig(
                    axes3, 8, cap, exchange="hierarchical",
                    level_sizes=(2, 2, 2), marshal=m,
                ),
            ),
        )
        for tag, mesh, axes, mk_cfg in points:
            best = _paired_marshal_times(mk_cfg, mesh, axes, n_emit, cap, samples)
            for marshal, us in best.items():
                times[(tag, marshal, n_emit)] = us
                cfg = mk_cfg(marshal)
                record_cfg(f"fwd_walltime_marshal_{tag}_{marshal}_n{n_emit}", cfg, mesh)
                send_rows = (
                    8 * cfg.peer_capacity if tag == "flat"
                    else 2 * cfg.level_capacities[-1]
                )
                model = marshal_cost_model(
                    marshal, capacity=cap, item_bytes=item_b,
                    send_rows=send_rows, num_ranks=8,
                )
                rays_s = 8 * n_emit / (us / 1e6)
                emit(
                    f"fwd_walltime_marshal_{tag}_{marshal}_n{n_emit}", us,
                    f"rays_per_s={rays_s:.2e}"
                    f";marshal_plan_B={model['plan_bytes']:.0f}"
                    f";marshal_total_B={model['total_bytes']:.0f}"
                    f";payload_passes={model['payload_passes']:.0f}",
                )
                if PROFILE and tag == "flat":
                    _profile_phases(
                        f"marshal_{marshal}_n{n_emit}", cfg, mesh_flat, n_emit, cap
                    )
    return times


PIPELINE_GATE_MIN_EMIT = 16384  # flat points at/above this gate the geomean


def fwd_walltime_pipeline(samples=8, profile=None):
    """Bulk-synchronous vs micro-shard pipelined forwarding (ISSUE 8): the
    flat padded round at ``pipeline_shards=4`` on compute-ballasted rounds
    (``ballast_iters=128`` — the exchange must amortize against rounds that
    DO WORK, same reasoning as the ckpt gate's ``_ballast_round_fn``), swept
    over growing working sets, timed interleaved with the quoted ratio
    being the ADJACENT-PAIR median (``_pair_ratio``) — the only estimator
    stable enough for a ≤1.0× gate on a drifting host.

    On this CPU backend collectives are synchronous memcpys, so the overlap
    model's async term is 0 and the measured pipelined win is the locality
    corollary: each 1/S chunk is marshalled, shipped and compacted while
    still cache-resident, which starts paying once the round's buffers
    outgrow the cache.  The gate therefore covers only the flat points at
    ``n_emit >= PIPELINE_GATE_MIN_EMIT`` — where the per-device buffers
    exceed the cache and the mechanism applies; the smaller flat point and
    a 3-level trend point ride along UNGATED (sub-cache rounds are
    launch-overhead-bound on this fabric, and the hier route's per-tier
    chunks are S× smaller still — both rows document the CPU limitation
    that the overlap model's ``async_fraction=1`` (TPU ICI) bound removes).
    With ``--profile`` (always on in the gate) the bulk round's four phases
    are timed standalone at the gate's anchor point and
    :func:`repro.roofline.analysis.overlap_efficiency_model` brackets the
    measured ratio between perfect overlap (a=1, the ICI target) and no
    overlap (a=0, this fabric).  Returns ``(times, ratios)`` —
    ``{(tag, variant, n_emit): median_us}`` and
    ``{(tag, n_emit): pair_ratio}`` — for the ``--compare bulk,pipelined``
    gate."""
    from repro.core import ForwardConfig
    from repro.launch.mesh import make_pod_mesh
    from repro.roofline.analysis import overlap_efficiency_model

    if profile is None:
        profile = PROFILE
    S, ballast = 4, 128
    mesh = _mesh8()
    times, ratios = {}, {}
    for n_emit in (8192, 16384, 32768):
        cap = n_emit * 2
        cfgs = {
            "bulk": ForwardConfig("data", 8, cap, exchange="padded"),
            "pipelined": ForwardConfig(
                "data", 8, cap, exchange="padded", pipeline_shards=S
            ),
        }
        med, raw = _paired_times(
            cfgs, mesh, "data", n_emit, cap, samples, ballast_iters=ballast,
            raw=True,
        )
        ratio = _pair_ratio(raw, "pipelined", "bulk")
        ratios[("flat", n_emit)] = ratio
        for variant, us in med.items():
            times[("flat", variant, n_emit)] = us
            record_cfg(
                f"fwd_walltime_pipeline_flat_{variant}_n{n_emit}",
                cfgs[variant], mesh,
            )
            emit(
                f"fwd_walltime_pipeline_flat_{variant}_n{n_emit}", us,
                f"rays_per_s={8 * n_emit / (us / 1e6):.2e}"
                f";shards={cfgs[variant].pipeline_shards}"
                f";ballast_iters={ballast}"
                f";ratio={ratio if variant == 'pipelined' else 1.0:.3f}"
                f";gated={int(n_emit >= PIPELINE_GATE_MIN_EMIT)}",
            )
        if profile and n_emit == 32768:
            phase_us = _profile_phases(
                f"pipeline_bulk_n{n_emit}", cfgs["bulk"], mesh, n_emit, cap
            )
            ici = overlap_efficiency_model(phase_us, S, async_fraction=1.0)
            sync = overlap_efficiency_model(phase_us, S, async_fraction=0.0)
            emit(
                f"fwd_profile_pipeline_overlap_n{n_emit}",
                ici["pipelined_us"],
                f"bulk_us={ici['bulk_us']:.1f};wire_us={ici['wire_us']:.1f}"
                f";compute_us={ici['compute_us']:.1f}"
                f";ici_bound_ratio={ici['pipelined_us'] / ici['bulk_us']:.3f}"
                f";sync_fabric_ratio={sync['pipelined_us'] / sync['bulk_us']:.3f}"
                f";measured_ratio={ratio:.3f}"
                f";ici_speedup={ici['speedup']:.3f}",
            )
    # hier3 trend point (ungated — see docstring)
    mesh_pod = make_pod_mesh(2, 2, 2)
    axes3 = ("pod", "node", "device")
    n_emit = 8192
    cap = n_emit * 2
    cfgs = {
        "bulk": ForwardConfig(
            axes3, 8, cap, exchange="hierarchical", level_sizes=(2, 2, 2)
        ),
        "pipelined": ForwardConfig(
            axes3, 8, cap, exchange="hierarchical", level_sizes=(2, 2, 2),
            pipeline_shards=2,
        ),
    }
    med, raw = _paired_times(
        cfgs, mesh_pod, axes3, n_emit, cap, max(4, samples // 2),
        ballast_iters=ballast, raw=True,
    )
    ratio = _pair_ratio(raw, "pipelined", "bulk")
    ratios[("hier3", n_emit)] = ratio
    for variant, us in med.items():
        times[("hier3", variant, n_emit)] = us
        record_cfg(
            f"fwd_walltime_pipeline_hier3_{variant}_n{n_emit}",
            cfgs[variant], mesh_pod,
        )
        emit(
            f"fwd_walltime_pipeline_hier3_{variant}_n{n_emit}", us,
            f"rays_per_s={8 * n_emit / (us / 1e6):.2e}"
            f";shards={cfgs[variant].pipeline_shards}"
            f";ballast_iters={ballast}"
            f";ratio={ratio if variant == 'pipelined' else 1.0:.3f}"
            f";gated=0",
        )
    return times, ratios


def compare_backends(spec: str) -> int:
    """The CI gates for the hierarchical routes.

    ``--compare flat,hierarchical`` (PR-2 gate): on a SINGLE-NODE mesh (slow
    axis of extent 1 — the slow stage degenerates to a local copy) the
    hierarchical exchange must not regress the flat padded exchange by more
    than 5% walltime; a regression there means pure multi-stage overhead, not
    topology routing.

    ``--compare flat,hierarchical2,hierarchical3`` (PR-3 gate): runs the
    (2, 2, 2)-mesh sweep plus the skewed-load rebalance benchmark, and fails
    unless the 3-level route's modeled slowest-tier bytes PER ROW OF BURST
    TOLERANCE strictly undercut both the flat route's and the 2-level
    route's.  (At load-proportional default capacities the routes' absolute
    slowest-tier bytes coincide — the structural win, as in the PR-2 2-level
    sweep, is how few DCN-crossing padded rows a unit of per-destination
    burst absorption costs: 4 flat, 2 hier2, 1 hier3.)  Returns a nonzero
    exit code on gate failure."""
    names = tuple(s.strip() for s in spec.split(","))
    if names == ("off", "telemetry"):
        # PR-5 gate: the flight recorder must be ~free — telemetry-on
        # walltime within a 1.05× GEOMEAN of telemetry-off across the sweep
        # (same per-point interleaved-median methodology as the marshal
        # gate) — and the autotune_drift section must converge drop-free at
        # ≤ the static worst-case wire cost (it raises otherwise).
        times = fwd_walltime_telemetry(samples=40)
        ratios = []
        for (tag, variant, n_emit), us in sorted(times.items()):
            if variant != "telemetry":
                continue
            ratio = us / times[(tag, "off", n_emit)]
            ratios.append(ratio)
            emit(f"compare_telemetry_{tag}_n{n_emit}", us, f"ratio={ratio:.3f}")
        geomean = float(np.exp(np.mean(np.log(ratios))))
        emit("compare_telemetry_geomean", 0.0, f"ratio={geomean:.3f}")
        if geomean > 1.05:
            print(
                f"# COMPARE FAILED: telemetry-on regresses telemetry-off by "
                f"{geomean:.2f}x > 1.05x (geomean over the sweep)"
            )
            return 1
        print(
            f"# compare ok: telemetry/off walltime geomean {geomean:.3f} "
            f"(per-point: {', '.join(f'{r:.3f}' for r in ratios)})"
        )
        try:
            autotune_drift()
        except RuntimeError as e:
            # gate contract: nonzero exit + the JSON dump still written
            # (with compare_failed=true), like every other compare mode —
            # never a traceback that loses the collected rows
            print(f"# COMPARE FAILED: {e}")
            return 1
        return 0
    if names == ("drop", "retain"):
        # PR-6 gate: spill-and-retry must be free when nothing spills —
        # retain-mode walltime within a 1.05× GEOMEAN of drop mode across
        # the happy-path sweep — and the chaos_lossless acceptance must hold
        # (retain loses nothing where drop loses >20%; it raises otherwise).
        times = fwd_walltime_overflow(samples=40)
        ratios = []
        for (tag, variant, n_emit), us in sorted(times.items()):
            if variant != "retain":
                continue
            ratio = us / times[(tag, "drop", n_emit)]
            ratios.append(ratio)
            emit(f"compare_overflow_{tag}_n{n_emit}", us, f"ratio={ratio:.3f}")
        geomean = float(np.exp(np.mean(np.log(ratios))))
        emit("compare_overflow_geomean", 0.0, f"ratio={geomean:.3f}")
        if geomean > 1.05:
            print(
                f"# COMPARE FAILED: retain mode regresses drop mode by "
                f"{geomean:.2f}x > 1.05x on the happy path (geomean)"
            )
            return 1
        print(
            f"# compare ok: retain/drop walltime geomean {geomean:.3f} "
            f"(per-point: {', '.join(f'{r:.3f}' for r in ratios)})"
        )
        try:
            chaos_lossless()
        except RuntimeError as e:
            print(f"# COMPARE FAILED: {e}")
            return 1
        return 0
    if names == ("open", "credit"):
        # PR-9 gate: credit flow must be ~free when nobody is starved —
        # credit-mode walltime within a 1.05× GEOMEAN of open flow across
        # the fully-credited happy-path sweep — and the chaos_backpressure
        # acceptance must hold (credit lossless with bounded occupancy on
        # both overload scenarios where open wastes >30% of its wire rows;
        # it raises otherwise).
        times = fwd_walltime_flow(samples=40)
        ratios = []
        for (tag, variant, n_emit), us in sorted(times.items()):
            if variant != "credit":
                continue
            ratio = us / times[(tag, "open", n_emit)]
            ratios.append(ratio)
            emit(f"compare_flow_{tag}_n{n_emit}", us, f"ratio={ratio:.3f}")
        geomean = float(np.exp(np.mean(np.log(ratios))))
        emit("compare_flow_geomean", 0.0, f"ratio={geomean:.3f}")
        if geomean > 1.05:
            print(
                f"# COMPARE FAILED: credit flow regresses open flow by "
                f"{geomean:.2f}x > 1.05x on the fully-credited happy path "
                f"(geomean)"
            )
            return 1
        print(
            f"# compare ok: credit/open walltime geomean {geomean:.3f} "
            f"(per-point: {', '.join(f'{r:.3f}' for r in ratios)})"
        )
        try:
            chaos_backpressure()
        except RuntimeError as e:
            print(f"# COMPARE FAILED: {e}")
            return 1
        return 0
    if names == ("off", "obs"):
        # PR-10 gate: observation must be ~free — a traced + metered burst
        # within a 1.05× walltime GEOMEAN of the untraced one (the lowered
        # HLO is bit-identical by construction; this gates the host
        # bookkeeping) — and the flight-data analyzer acceptance must hold
        # (the report reproduces the chaos driver's goodput/waste numbers
        # from the capture alone and flags only the open-flow overload run
        # as degraded; it raises otherwise).
        times = fwd_walltime_obs(samples=40)
        ratios = []
        for (tag, variant), us in sorted(times.items()):
            if variant != "obs":
                continue
            ratio = us / times[(tag, "off")]
            ratios.append(ratio)
            emit(f"compare_obs_{tag}", us, f"ratio={ratio:.3f}")
        geomean = float(np.exp(np.mean(np.log(ratios))))
        emit("compare_obs_geomean", 0.0, f"ratio={geomean:.3f}")
        if geomean > 1.05:
            print(
                f"# COMPARE FAILED: tracing+metrics regresses the untraced "
                f"burst by {geomean:.2f}x > 1.05x (geomean)"
            )
            return 1
        print(
            f"# compare ok: obs/off walltime geomean {geomean:.3f} "
            f"(per-point: {', '.join(f'{r:.3f}' for r in ratios)})"
        )
        try:
            obs_flight_report()
        except RuntimeError as e:
            print(f"# COMPARE FAILED: {e}")
            return 1
        return 0
    if names == ("nockpt", "ckpt"):
        # PR-7 gate: recovery must be amortized — the segmented drive WITH
        # the checkpoint writer (W=8 rounds between saves) within a 1.05×
        # walltime GEOMEAN of the save-free segmented drive on ballasted
        # bursts — and the chaos_recovery acceptance must hold
        # (preempt-resume bit-exact, brownout lossless; it raises otherwise).
        times = fwd_walltime_ckpt(samples=5)
        ratios = []
        for (tag, variant), us in sorted(times.items()):
            if variant != "ckpt":
                continue
            ratio = us / times[(tag, "nockpt")]
            ratios.append(ratio)
            emit(f"compare_ckpt_{tag}", us, f"ratio={ratio:.3f}")
        geomean = float(np.exp(np.mean(np.log(ratios))))
        emit("compare_ckpt_geomean", 0.0, f"ratio={geomean:.3f}")
        if geomean > 1.05:
            print(
                f"# COMPARE FAILED: checkpointing every 8 rounds regresses "
                f"the save-free drive by {geomean:.2f}x > 1.05x (geomean)"
            )
            return 1
        print(
            f"# compare ok: ckpt/nockpt walltime geomean {geomean:.3f} "
            f"(per-point: {', '.join(f'{r:.3f}' for r in ratios)})"
        )
        try:
            chaos_recovery()
        except RuntimeError as e:
            print(f"# COMPARE FAILED: {e}")
            return 1
        return 0
    if names == ("bulk", "pipelined"):
        # PR-8 gate: micro-shard pipelining must never cost walltime where
        # its mechanism applies — pipelined (S=4) within a 1.0× GEOMEAN of
        # the bulk round over the ballasted flat points whose buffers exceed
        # the cache (n_emit >= PIPELINE_GATE_MIN_EMIT; the gate is ≤ 1.0,
        # not 1.05: unlike the feature gates, pipelining exists ONLY for
        # walltime, so any regression defeats it).  Ratios are adjacent-pair
        # medians (see _pair_ratio) — per-variant medians drift by more than
        # the gate margin on this host.  The sub-cache flat point and the
        # hier3 rows are reported but not gated (see fwd_walltime_pipeline).
        # The phase-profile overlap model must bracket the measurement: the
        # perfect-overlap (ICI) bound is a floor no fabric can beat.
        times, pair_ratios = fwd_walltime_pipeline(samples=40, profile=True)
        ratios = []
        for (tag, n_emit), ratio in sorted(pair_ratios.items()):
            us = times[(tag, "pipelined", n_emit)]
            in_gate = tag == "flat" and n_emit >= PIPELINE_GATE_MIN_EMIT
            emit(
                f"compare_pipeline_{tag}_n{n_emit}", us,
                f"ratio={ratio:.3f};gated={int(in_gate)}",
            )
            if in_gate:
                ratios.append(ratio)
        geomean = float(np.exp(np.mean(np.log(ratios))))
        emit("compare_pipeline_geomean", 0.0, f"ratio={geomean:.3f}")
        overlap_rows = [
            r for r in ROWS if r["name"].startswith("fwd_profile_pipeline_overlap")
        ]
        for r in overlap_rows:
            lb = float(r["derived"]["ici_bound_ratio"])
            measured = float(r["derived"]["measured_ratio"])
            if measured < lb - 0.05:
                print(
                    f"# COMPARE FAILED: measured pipelined ratio {measured:.3f} "
                    f"beats the perfect-overlap bound {lb:.3f} — the "
                    f"measurement or the phase model is broken"
                )
                return 1
        if geomean > 1.0:
            print(
                f"# COMPARE FAILED: pipelined regresses bulk by "
                f"{geomean:.3f}x > 1.0x (pair-ratio geomean over the "
                f"ballasted flat points with n_emit >= "
                f"{PIPELINE_GATE_MIN_EMIT})"
            )
            return 1
        print(
            f"# compare ok: pipelined/bulk walltime geomean {geomean:.3f} "
            f"(per-point: {', '.join(f'{r:.3f}' for r in ratios)})"
        )
        return 0
    if names == ("sort", "scatter"):
        # PR-4 gate: across the sweep the scatter marshal must be no more
        # than 5% slower than the sort path — a regression there means the
        # "one payload pass, no sort" pipeline lost to the thing it
        # replaces.  Gated on the GEOMEAN of the per-point interleaved-median
        # ratios: a single ~2 ms CPU point still wobbles a few percent
        # run-to-run from scheduler noise, but the sweep-level geomean is
        # stable to <1% (per-point ratios are all emitted as rows).  On TPU
        # the deleted lax.sort is worth strictly more.
        times = fwd_walltime_marshal(samples=40)
        ratios = []
        for (tag, marshal, n_emit), us in sorted(times.items()):
            if marshal != "scatter":
                continue
            ratio = us / times[(tag, "sort", n_emit)]
            ratios.append(ratio)
            emit(
                f"compare_marshal_{tag}_n{n_emit}", us, f"ratio={ratio:.3f}"
            )
        geomean = float(np.exp(np.mean(np.log(ratios))))
        emit("compare_marshal_geomean", 0.0, f"ratio={geomean:.3f}")
        if geomean > 1.05:
            print(
                f"# COMPARE FAILED: scatter marshal regresses sort by "
                f"{geomean:.2f}x > 1.05x (geomean over the sweep)"
            )
            return 1
        print(
            f"# compare ok: scatter/sort walltime geomean {geomean:.3f} "
            f"(per-point: {', '.join(f'{r:.3f}' for r in ratios)})"
        )
        return 0
    if names == ("flat", "hierarchical2", "hierarchical3"):
        from repro.core import item_nbytes

        fwd_walltime_hier3()
        rebalance_skew()
        item_b = item_nbytes(_ray_proto())
        flat, hier2, hier3, _mesh = _pod_configs(4096)
        per_burst = {}
        for tag, cfg in (("flat", flat), ("hier2", hier2), ("hier3", hier3)):
            burst = (
                cfg.peer_capacity if tag == "flat" else cfg.level_capacities[0]
            )
            per_burst[tag] = _route_tier_rows(tag, cfg)[0] * item_b / burst
        emit(
            "compare3_slowest_tier_bytes_per_burst_row", 0.0,
            f"flat_B={per_burst['flat']:.1f};hier2_B={per_burst['hier2']:.1f}"
            f";hier3_B={per_burst['hier3']:.1f}",
        )
        if not (
            per_burst["hier3"] < per_burst["hier2"] < per_burst["flat"]
        ):
            print(
                "# COMPARE FAILED: slowest-tier bytes per burst row not "
                f"strictly decreasing flat > hier2 > hier3: {per_burst}"
            )
            return 1
        print(
            "# compare ok: slowest-tier bytes per burst row "
            f"flat {per_burst['flat']:.1f} > hier2 {per_burst['hier2']:.1f} "
            f"> hier3 {per_burst['hier3']:.1f} on 2x2x2"
        )
        return 0
    if names != ("flat", "hierarchical"):
        raise SystemExit(
            "error: --compare supports 'flat,hierarchical', "
            "'flat,hierarchical2,hierarchical3', 'sort,scatter', "
            "'off,telemetry', 'drop,retain', 'nockpt,ckpt', "
            f"'bulk,pipelined', 'open,credit', or 'off,obs', got {spec!r}"
        )
    n_emit, cap = 2048, 4096
    flat, hier, mesh = _hier_pair(1, 8, n_emit, cap)
    flat_us = _time_fwd(flat, mesh, n_emit, cap, iters=10)
    hier_us = _time_fwd(hier, mesh, n_emit, cap, iters=10)
    ratio = hier_us / flat_us
    emit(f"compare_flat_1x8_n{n_emit}", flat_us, f"ratio=1.0")
    emit(f"compare_hierarchical_1x8_n{n_emit}", hier_us, f"ratio={ratio:.3f}")
    if ratio > 1.05:
        print(
            f"# COMPARE FAILED: hierarchical {hier_us:.0f}us vs flat "
            f"{flat_us:.0f}us on single-node 1x8 mesh ({ratio:.2f}x > 1.05x)"
        )
        return 1
    print(f"# compare ok: hierarchical/flat = {ratio:.3f} on single-node 1x8 mesh")
    return 0


# ------------------------------------------------- §4.2.1 sort throughput
def sort_throughput():
    from repro.core import sorting as S
    from repro.kernels.sort_keys import ops as sk

    n = 65536
    dest = jnp.array(np.random.default_rng(1).integers(0, 256, n), jnp.int32)
    items = {"x": jnp.zeros((n, 4))}
    for name, fn in (
        ("xla_pack", jax.jit(lambda d: S.sort_by_destination(items, d, jnp.int32(n), 256, method="pack"))),
        ("xla_argsort", jax.jit(lambda d: S.sort_by_destination(items, d, jnp.int32(n), 256, method="argsort"))),
        ("pallas_interp", jax.jit(lambda d: sk.sort_by_destination(items, d, jnp.int32(n), 256))),
    ):
        us, _ = _timeit(fn, dest)
        emit(f"sort_throughput_{name}", us, f"keys_per_s={n/(us/1e6):.2e}")


# ----------------------------------------------------------- §5 app rates
def app_rates():
    from repro.apps import vopat
    from repro.apps import streamlines as sl
    from repro.apps import nbody

    mesh = _mesh8()
    scene = vopat.VopatScene(width=32, height=32, spp=1)
    t0 = time.perf_counter()
    img, stats = vopat.render(mesh, scene)
    dt = time.perf_counter() - t0
    emit("app_vopat_32x32", dt * 1e6,
         f"rays={scene.width*scene.height};rounds={stats['rounds']}")

    cfg = sl.StreamlineConfig(num_particles=64, max_steps=64, dt=0.1)
    t0 = time.perf_counter()
    tr, lens, st = sl.run(mesh, cfg)
    dt = time.perf_counter() - t0
    emit("app_streamlines_64p", dt * 1e6,
         f"particle_steps={int(lens.sum())};steps_per_s={lens.sum()/dt:.2e}")

    ncfg = nbody.NBodyConfig(num_particles=128, steps=4)
    t0 = time.perf_counter()
    nbody.run(mesh, ncfg)
    dt = time.perf_counter() - t0
    inter = ncfg.num_particles * (ncfg.num_particles + 9 * 8) * ncfg.steps
    emit("app_nbody_128p", dt * 1e6, f"interactions_per_s={inter/dt:.2e}")


# --------------------------------- paper technique on the LM side: MoE
def moe_dispatch():
    import dataclasses as dc

    from repro.configs import get_smoke_config
    from repro.models import moe
    from repro.models.common import init_params
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh()
    cfg = get_smoke_config("dbrx-132b")
    n_tok = 2048
    x = jax.random.normal(jax.random.PRNGKey(0), (8, n_tok // 8, cfg.d_model), jnp.float32)
    params = init_params(moe.moe_defs(cfg), jax.random.PRNGKey(1), jnp.float32)
    for plane in ("rafi_ep", "dense_tp"):
        c = dc.replace(cfg, moe_dispatch=plane, capacity_factor=2.0)
        f = jax.jit(lambda p, x: moe.moe_block(p, x, c, mesh=mesh))
        us, _ = _timeit(f, params, x)
        emit(f"moe_dispatch_{plane}", us, f"tokens_per_s={n_tok/(us/1e6):.2e}")


SECTIONS = [
    ("fig8_efficiency", fig8_efficiency),
    ("sort_cost", sort_cost),
    ("fwd_walltime", fwd_walltime),
    ("fwd_walltime_hier", fwd_walltime_hier),
    ("fwd_walltime_hier3", fwd_walltime_hier3),
    ("fwd_walltime_marshal", fwd_walltime_marshal),
    ("fwd_walltime_pipeline", fwd_walltime_pipeline),
    ("fwd_walltime_telemetry", fwd_walltime_telemetry),
    ("fwd_walltime_overflow", fwd_walltime_overflow),
    ("fwd_walltime_ckpt", fwd_walltime_ckpt),
    ("fwd_walltime_flow", fwd_walltime_flow),
    ("chaos_lossless", chaos_lossless),
    ("chaos_recovery", chaos_recovery),
    ("chaos_backpressure", chaos_backpressure),
    ("fwd_walltime_obs", fwd_walltime_obs),
    ("obs_flight_report", obs_flight_report),
    ("rebalance_skew", rebalance_skew),
    ("autotune_drift", autotune_drift),
    ("sort_throughput", sort_throughput),
    ("app_rates", app_rates),
    ("moe_dispatch", moe_dispatch),
]

SMOKE_SECTIONS = (
    "fwd_walltime", "fwd_walltime_hier", "fwd_walltime_marshal", "sort_throughput"
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write results as machine-readable JSON")
    ap.add_argument("--smoke", action="store_true",
                    help=f"fast subset only: {', '.join(SMOKE_SECTIONS)}")
    ap.add_argument("--only", metavar="SUBSTR", default=None,
                    help="run only sections whose name contains SUBSTR")
    ap.add_argument("--profile", action="store_true",
                    help="per-phase breakdown (marshal / count collective / "
                         "payload collective / unmarshal) of the padded "
                         "fwd_walltime_* rounds, as fwd_profile_* rows")
    ap.add_argument("--autotune", action="store_true",
                    help="run only the ISSUE-5 autotune_drift section "
                         "(drifting hot-spot + adaptive capacity controller)")
    ap.add_argument("--chaos", action="store_true",
                    help="run only the chaos acceptance sections: the ISSUE-6 "
                         "chaos_lossless gauntlet (retain mode must lose "
                         "nothing where drop mode loses >20%%), the ISSUE-7 "
                         "chaos_recovery run (preempt-resume bit-exact, rank "
                         "brownout lossless), and the ISSUE-9 "
                         "chaos_backpressure overload pair (credit flow "
                         "lossless with bounded occupancy where open flow "
                         "wastes >30%% of its wire rows)")
    ap.add_argument("--compare", metavar="A,B[,C]", default=None,
                    help="regression gate: 'flat,hierarchical' times both "
                         "exchanges on a single-node mesh and exits nonzero "
                         "if hierarchical regresses flat by >5%%; "
                         "'flat,hierarchical2,hierarchical3' runs the "
                         "(2,2,2)-mesh sweep + rebalance_skew and gates on "
                         "the modeled slowest-tier bytes; 'sort,scatter' "
                         "runs the marshal sweep and gates on scatter "
                         "regressing sort by >5%% walltime; 'off,telemetry' "
                         "gates the flight recorder at a 1.05x walltime "
                         "geomean and runs the autotune_drift acceptance; "
                         "'drop,retain' gates spill-and-retry at a 1.05x "
                         "happy-path geomean and runs the chaos_lossless "
                         "acceptance; 'nockpt,ckpt' gates the checkpointed "
                         "drive (W=8) at a 1.05x walltime geomean over the "
                         "save-free segmented drive and runs the "
                         "chaos_recovery acceptance; 'bulk,pipelined' gates "
                         "micro-shard pipelining at a 1.0x geomean over the "
                         "bulk round on ballasted cache-exceeding rounds, "
                         "with the phase-profile overlap model bracketing "
                         "the measurement; 'open,credit' gates credit flow "
                         "at a 1.05x walltime geomean over open flow on the "
                         "fully-credited happy path and runs the "
                         "chaos_backpressure acceptance; 'off,obs' gates "
                         "the observation law (tracer + metrics snapshot) "
                         "at a 1.05x walltime geomean over the untraced "
                         "burst and runs the obs_flight_report acceptance "
                         "(the analyzer must reproduce the chaos driver's "
                         "goodput/waste numbers and flag only the open-flow "
                         "overload run as degraded)")
    args = ap.parse_args(argv)

    global PROFILE
    PROFILE = args.profile
    if args.autotune:
        args.only = "autotune_drift"
    if args.chaos:
        args.only = "chaos"  # chaos_lossless + chaos_recovery + chaos_backpressure

    print("name,us_per_call,derived")
    if args.compare:
        t0 = time.perf_counter()
        rc = compare_backends(args.compare)
        if args.json:
            _write_json(
                args.json, compare=args.compare, compare_failed=bool(rc),
                compare_walltime_s=round(time.perf_counter() - t0, 3),
            )
        raise SystemExit(rc)
    failures = []
    selected = [
        (name, fn)
        for name, fn in SECTIONS
        if (not args.smoke or name in SMOKE_SECTIONS)
        and (not args.only or args.only in name)
    ]
    if not selected:  # a typo'd --only must not record an empty "green" run
        only_hits = [n for n, _ in SECTIONS if not args.only or args.only in n]
        if args.smoke and only_hits:
            raise SystemExit(
                f"error: --only {args.only!r} matches only non-smoke sections "
                f"{only_hits}; drop --smoke to run them"
            )
        raise SystemExit(f"error: no benchmark section matches --only {args.only!r}")
    section_walltime_s = {}
    for name, fn in selected:
        t0 = time.perf_counter()
        try:
            fn()
        except Exception as e:  # a broken section must not hide the others' rows
            failures.append(name)
            print(f"# section {name} failed: {type(e).__name__}: {e}", flush=True)
        finally:
            # per-section wall time rides the JSON dump (the trajectory files
            # show WHERE a slow bench run spent its minutes, not just rows)
            section_walltime_s[name] = round(time.perf_counter() - t0, 3)
    print(f"# {len(ROWS)} benchmarks complete" + (f"; failed sections: {failures}" if failures else ""))

    if args.json:
        _write_json(
            args.json, smoke=bool(args.smoke), failed_sections=failures,
            section_walltime_s=section_walltime_s,
        )

    if failures:  # the canary must trip CI, not just leave a comment
        raise SystemExit(1)


if __name__ == "__main__":
    main()

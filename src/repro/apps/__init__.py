"""Sample applications (paper §5) built on the repro.core forwarding layer.

  vopat.py        §5.1 data-parallel volume path tracer (Woodcock tracking,
                  wavefront self-forwarding, distributed framebuffer)
  lander.py       §5.2 non-convex-partition volume renderer: RaFI forwarding
                  vs the deep-compositing baseline it replaces
  schlieren.py    §5.3 data-parallel Schlieren renderer (knife-edge filters)
  streamlines.py  §5.4 RK4 particle advection with particle forwarding
  nbody.py        §5.5 multi-phase N-body with three simultaneous contexts
"""

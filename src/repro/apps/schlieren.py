"""SchlieRaFI — data-parallel Schlieren renderer (§5.3).

Straight-ray Schlieren (Yates' formulation): each ray integrates the
projected density gradient along its path,

    I_u = ∫ (∇σ(p) · u) ds      I_v = ∫ (∇σ(p) · v) ds

where (u, v) are the camera's right/up axes.  A *knife edge* then filters
the integral into an image — a "U" knife edge emphasizes horizontal
gradients, "V" vertical ones (paper Fig. 5).

The forwarded state mirrors the paper's Listing 1 (FWDRay: origin,
direction, restart parameter, pixelID, partial integral): rays march a
globally-aligned sample grid through the slab partition and forward
themselves at partition boundaries carrying their partial integrals.
Schlieren *adds* contributions (no compositing order), so — as §6.1 notes —
a sort-last implementation is also correct; the forwarding version exists
for generality (refracted rays) and is validated to be R-invariant.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.apps import fields as F
from repro.core import (
    DISCARD,
    ForwardConfig,
    enqueue,
    make_queue,
    run_until_done,
    work_item,
)

AXIS = "data"
MARCH_PER_ROUND = 32


@work_item
@dataclasses.dataclass
class SchlierenRay:
    """Paper Listing 1's FWDRay, adapted: two knife-edge partial integrals."""

    origin: jax.Array   # (3,)
    dir: jax.Array      # (3,)
    t_entry: jax.Array  # () f32 "restart parameter" analogue (grid anchor)
    k: jax.Array        # () i32 next sample index
    pixel: jax.Array    # () i32 framebuffer index
    slab: jax.Array     # () i32
    iu: jax.Array       # () f32 accumulated u-gradient integral
    iv: jax.Array       # () f32 accumulated v-gradient integral


def _proto():
    z, zi = jnp.zeros(()), jnp.zeros((), jnp.int32)
    return SchlierenRay(jnp.zeros(3), jnp.zeros(3), z, zi, zi, zi, z, z)


@dataclasses.dataclass(frozen=True)
class SchlierenScene:
    width: int = 32
    height: int = 32
    num_slabs: int = 32
    samples_per_slab: int = 8
    gain: float = 0.15
    seed: int = 2
    num_blobs: int = 6


def _camera_axes():
    fwd = jnp.asarray([1.0, 0.0, 0.0])
    up0 = jnp.asarray([0.0, 0.0, 1.0])
    right = jnp.cross(fwd, up0)
    right = right / jnp.linalg.norm(right)
    up = jnp.cross(right, fwd)
    return right, up


def _round_fn(q_in, fb2, rnd, *, part, blobs, ds, cap, right, up):
    r = q_in.items
    lane = jnp.arange(cap)
    valid = lane < q_in.count

    lo, hi = part.bounds(r.slab)
    t_cur = r.t_entry + r.k.astype(jnp.float32) * ds
    t_exit, axis, pos_side = F.ray_box_exit(r.origin, r.dir, t_cur, lo, hi)

    k, iu, iv = r.k, r.iu, r.iv
    for _ in range(MARCH_PER_ROUND):
        t_k = r.t_entry + (k.astype(jnp.float32) + 0.5) * ds
        inside = t_k < t_exit
        p = r.origin + t_k[:, None] * r.dir
        g = F.density_gradient(p, blobs)
        iu = jnp.where(inside, iu + jnp.dot(g, right) * ds, iu)
        iv = jnp.where(inside, iv + jnp.dot(g, up) * ds, iv)
        k = k + inside.astype(jnp.int32)
    t_next = r.t_entry + (k.astype(jnp.float32) + 0.5) * ds
    done_seg = t_next >= t_exit

    next_slab = r.slab + jnp.where(pos_side, 1, -1)
    stays = (next_slab >= 0) & (next_slab < part.num_slabs) & (axis == 0)
    finish = valid & done_seg & ~stays
    cross = valid & done_seg & stays
    again = valid & ~done_seg

    dep = jnp.stack([jnp.where(finish, iu, 0.0), jnp.where(finish, iv, 0.0)], -1)
    fb2 = fb2.at[r.pixel].add(jnp.where(valid[:, None], dep, 0.0), mode="drop")

    new = SchlierenRay(
        origin=r.origin, dir=r.dir, t_entry=r.t_entry, k=k, pixel=r.pixel,
        slab=jnp.where(cross, next_slab, r.slab), iu=iu, iv=iv,
    )
    alive = cross | again
    dest = jnp.where(
        cross,
        part.owner_of_slab(next_slab),
        jnp.where(again, jax.lax.axis_index(AXIS), DISCARD),
    ).astype(jnp.int32)
    out = make_queue(_proto(), cap)
    out = enqueue(out, new, dest, alive)
    return out, fb2


def render(
    mesh, scene: SchlierenScene = SchlierenScene(), *, blobs=None,
    max_rounds: int = 4096, exchange: str = "padded",
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Returns (knife_u image, knife_v image, stats) — paper Fig. 5's pair."""
    R = mesh.shape[AXIS]
    if blobs is None:
        blobs = F.default_blobs(scene.num_blobs, scene.seed)
    part = F.SlabPartition(num_slabs=scene.num_slabs, num_ranks=R)
    ds = part.width / scene.samples_per_slab
    hw = scene.width * scene.height
    cap = max(256, hw)
    # peer slots only exist for the padded exchange (ragged/onehot reject it)
    slots = {"peer_capacity": cap} if exchange == "padded" else {}
    cfg = ForwardConfig(AXIS, R, cap, exchange=exchange, **slots)
    right, up = _camera_axes()

    round_fn = partial(
        _round_fn, part=part, blobs=blobs, ds=ds, cap=cap, right=right, up=up
    )

    def drive(_x):
        me = jax.lax.axis_index(AXIS)
        ppr = hw // R
        pix = me * ppr + jnp.arange(ppr)
        o, d = F.camera_rays(scene.width, scene.height)
        o, d = o[pix], d[pix]
        t_entry, hits = F.ray_domain_entry(o, d)
        fb2 = jnp.zeros((hw, 2), jnp.float32)
        p_in = o + (t_entry[:, None] + 1e-4) * d
        slab = part.slab_of(jnp.clip(p_in[:, 0], 0.0, 1.0 - 1e-6))
        n = pix.shape[0]
        rays = SchlierenRay(
            origin=o, dir=d, t_entry=t_entry, k=jnp.zeros(n, jnp.int32),
            pixel=pix.astype(jnp.int32), slab=slab,
            iu=jnp.zeros(n), iv=jnp.zeros(n),
        )
        dest = jnp.where(hits, part.owner_of_slab(slab), DISCARD).astype(jnp.int32)
        q0 = make_queue(_proto(), cap)
        q0 = enqueue(q0, rays, dest, jnp.ones(n, bool))
        q, fb2, rounds, _done = run_until_done(round_fn, q0, fb2, cfg, max_rounds=max_rounds)
        return jax.lax.psum(fb2, AXIS), rounds[None], q.drops[None]

    f = jax.jit(compat.shard_map(drive, mesh=mesh, in_specs=P(AXIS),
                              out_specs=(P(), P(AXIS), P(AXIS))))
    fb2, rounds, drops = f(jnp.arange(R, dtype=jnp.float32))
    fb2 = np.asarray(fb2)
    # knife-edge filter: mid-gray plus the (signed) projected gradient integral
    img_u = np.clip(0.5 + scene.gain * fb2[:, 0], 0, 1).reshape(scene.height, scene.width)
    img_v = np.clip(0.5 + scene.gain * fb2[:, 1], 0, 1).reshape(scene.height, scene.width)
    return img_u, img_v, {
        "rounds": int(np.max(np.asarray(rounds))),
        "drops": int(np.sum(np.asarray(drops))),
        "raw": fb2,
    }

"""rafi/StreamLines — data-parallel particle advection (§5.4).

Round-based structure exactly as the paper describes: each rank advances the
particles that currently overlap its spatial domain by one RK4 step (the
Pallas ``rk4_advect`` kernel — "one GPU thread per particle" becomes one
vector lane per particle), records the new position into the particle's
trace, then determines the destination rank by projecting the position onto
the partition ("if the space partitioning uses a grid, the neighboring rank
is found by projecting the position onto the grid") and calls
``emitOutgoing(P, destination)``.  ``forward_work`` plays ``forwardRays()``;
termination is the paper's distributed criterion (no particles anywhere, or
per-particle step budget exhausted).

The "ray type" is the paper's particle verbatim: a unique ID (so we can
track them across ranks) plus position — we add the per-particle step count.

Domain: [0, 2π]³ with an ABC / tornado / Taylor-Green analytic field; slab
partition along x.  Because a particle's trajectory depends only on its own
position, an R-rank run reproduces the R=1 trajectories bitwise.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import (
    DISCARD,
    ForwardConfig,
    enqueue,
    make_queue,
    run_until_done,
    work_item,
)
from repro.kernels.rk4_advect import ops as rk4

AXIS = "data"
TWO_PI = 2.0 * np.pi


@work_item
@dataclasses.dataclass
class Particle:
    """§5.4: 'a unique ID … and a 3D position (float3)' (+ step counter)."""

    uid: jax.Array    # () i32
    pos: jax.Array    # (3,) f32
    steps: jax.Array  # () i32


def _proto():
    return Particle(jnp.zeros((), jnp.int32), jnp.zeros(3), jnp.zeros((), jnp.int32))


@dataclasses.dataclass(frozen=True)
class StreamlineConfig:
    num_particles: int = 64
    max_steps: int = 128
    dt: float = 0.1
    field_id: int = rk4.ABC
    params: tuple = (1.0, 0.8, 0.6)
    seed: int = 0


def _owner(x, num_ranks):
    return jnp.clip(
        (x / (TWO_PI / num_ranks)).astype(jnp.int32), 0, num_ranks - 1
    )


def _inside(p):
    return jnp.all((p >= 0.0) & (p <= TWO_PI), axis=-1)


def run(
    mesh, cfg: StreamlineConfig = StreamlineConfig(), *, exchange: str = "padded",
    use_pallas_rk4: bool = True,
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Advect. Returns (traces (N, max_steps+1, 3) with NaN padding,
    lengths (N,), stats)."""
    R = mesh.shape[AXIS]
    n = cfg.num_particles
    cap = max(64, n)
    # peer slots only exist for the padded exchange (ragged/onehot reject it)
    slots = {"peer_capacity": cap} if exchange == "padded" else {}
    fcfg = ForwardConfig(AXIS, R, cap, exchange=exchange, **slots)

    def step_kernel(pos):
        if use_pallas_rk4:
            new_pos, _ = rk4.rk4_step(
                pos, dt=cfg.dt, field_id=cfg.field_id, params=cfg.params
            )
            return new_pos
        from repro.kernels.rk4_advect import ref

        new_pos, _ = ref.rk4_step(pos, dt=cfg.dt, field_id=cfg.field_id, params=cfg.params)
        return new_pos

    def round_fn(q_in, traces, rnd):
        p = q_in.items
        lane = jnp.arange(cap)
        valid = lane < q_in.count
        new_pos = step_kernel(p.pos)
        steps = p.steps + 1
        # record: traces[uid, steps] = new_pos  (uids are globally unique;
        # invalid lanes scatter to index n which mode="drop" discards)
        uid_idx = jnp.where(valid, p.uid, traces.shape[0])
        traces = traces.at[uid_idx, steps].set(new_pos, mode="drop")
        alive = valid & _inside(new_pos) & (steps < cfg.max_steps)
        dest = jnp.where(alive, _owner(new_pos[:, 0], R), DISCARD).astype(jnp.int32)
        out = make_queue(_proto(), cap)
        out = enqueue(out, Particle(uid=p.uid, pos=new_pos, steps=steps), dest, valid)
        return out, traces

    def drive(_x):
        me = jax.lax.axis_index(AXIS)
        key = jax.random.PRNGKey(cfg.seed)
        seeds = jax.random.uniform(key, (n, 3), minval=0.5, maxval=TWO_PI - 0.5)
        uid = jnp.arange(n, dtype=jnp.int32)
        traces = jnp.full((n, cfg.max_steps + 1, 3), jnp.nan)
        # every rank computes all seeds (cheap, deterministic) but only emits
        # the ones it owns — the §5.1 ray-gen pattern applied to particles.
        mine = _owner(seeds[:, 0], R) == me
        traces = jnp.where(mine[:, None, None] & (jnp.arange(cfg.max_steps + 1) == 0)[None, :, None],
                           seeds[:, None, :], traces)
        q0 = make_queue(_proto(), cap)
        q0 = enqueue(
            q0,
            Particle(uid=uid, pos=seeds, steps=jnp.zeros(n, jnp.int32)),
            jnp.where(mine, me, DISCARD).astype(jnp.int32),
            jnp.ones(n, bool),
        )
        q, traces, rounds, _done = run_until_done(
            round_fn, q0, traces, fcfg, max_rounds=cfg.max_steps + 2
        )
        # traces are disjoint across ranks (NaN elsewhere) — merge via min
        merged = jax.lax.pmin(jnp.where(jnp.isnan(traces), jnp.inf, traces), AXIS)
        return merged, rounds[None], q.drops[None]

    # check_vma=False: interpret-mode pallas_call inside shard_map cannot
    # track varying-manual-axes (Mosaic-compiled kernels on real TPU can).
    f = jax.jit(compat.shard_map(drive, mesh=mesh, in_specs=P(AXIS),
                              out_specs=(P(), P(AXIS), P(AXIS)), check_vma=False))
    merged, rounds, drops = f(jnp.arange(R, dtype=jnp.float32))
    traces = np.array(merged)
    traces[~np.isfinite(traces)] = np.nan
    lengths = np.sum(np.isfinite(traces[:, :, 0]), axis=1)
    return traces, lengths, {
        "rounds": int(np.max(np.asarray(rounds))),
        "drops": int(np.sum(np.asarray(drops))),
    }


def oracle(cfg: StreamlineConfig = StreamlineConfig()) -> np.ndarray:
    """Single-device direct integration (no forwarding) — the ground truth.

    Positions are padded to the distributed run's queue capacity so the RK4
    op sees the same lane shape (XLA's vectorized libm can differ by an ulp
    across shapes, which 60 RK4 steps would amplify) — bitwise comparability
    is part of the contract under test."""
    key = jax.random.PRNGKey(cfg.seed)
    n = cfg.num_particles
    cap = max(64, n)
    seeds = jax.random.uniform(key, (n, 3), minval=0.5, maxval=TWO_PI - 0.5)
    traces = np.full((n, cfg.max_steps + 1, 3), np.nan, np.float32)
    traces[:, 0] = np.asarray(seeds)
    pos = jnp.zeros((cap, 3)).at[:n].set(seeds)
    alive = np.ones(n, bool)
    for s in range(1, cfg.max_steps + 1):
        new_pos, _ = rk4.rk4_step(pos, dt=cfg.dt, field_id=cfg.field_id, params=cfg.params)
        npos = np.asarray(new_pos[:n])
        traces[alive, s] = npos[alive]
        inside = np.all((npos >= 0) & (npos <= TWO_PI), axis=-1)
        alive = alive & inside
        pos = new_pos
    return traces

"""rafi/Lander — volume rendering of NON-CONVEX partitions (§5.2).

The Mars-Lander problem: with the solver's native partitioning, one rank's
domain is not convex, so a ray enters and leaves the same rank many times.
We reproduce the structure with interleaved slab ownership: ``num_slabs =
k·R`` x-slabs, rank r owning slabs {r, r+R, r+2R, ...} — every ray crosses
every rank up to k times.

Two renderers over the same partition and the same globally-aligned sample
grid (samples at t_entry + (k+½)·Δs, so partitioning cannot change *where*
the field is sampled):

* ``render_forwarding`` — the RaFI realization: each ray carries its
  accumulated (L, T) emission-absorption state slab-to-slab via
  ``forward_work``; segments per ray are unlimited; non-straight extensions
  (shadow/scatter) would be possible (not exercised here — VoPaT covers
  scattering).
* ``render_deep_compositing`` — the baseline it replaced (Sahistan et al.):
  every rank integrates each of its *owned segments* independently into a
  fixed-depth fragment list (max ``max_fragments`` per pixel per rank —
  fragments past that are DROPPED, the paper's artifact mechanism), then a
  depth-sorted composite merges all ranks' fragments.

With ``max_fragments >= slabs_per_rank`` the two agree to float tolerance;
with fewer fragments the compositor mis-renders exactly as §5.2 describes
while the forwarding renderer stays correct.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.apps import fields as F
from repro.core import (
    DISCARD,
    ForwardConfig,
    enqueue,
    make_queue,
    run_until_done,
    work_item,
)

AXIS = "data"
MARCH_PER_ROUND = 32


@work_item
@dataclasses.dataclass
class EARay:
    """Emission-absorption ray state forwarded between partitions."""

    origin: jax.Array   # (3,)
    dir: jax.Array      # (3,)
    t_entry: jax.Array  # () domain entry (sample-grid anchor)
    k: jax.Array        # () i32 next sample index
    pixel: jax.Array    # () i32
    slab: jax.Array     # () i32
    radiance: jax.Array # () f32 accumulated L
    trans: jax.Array    # () f32 accumulated transmittance T


def _proto():
    z, zi = jnp.zeros(()), jnp.zeros((), jnp.int32)
    return EARay(jnp.zeros(3), jnp.zeros(3), z, zi, zi, zi, z, z)


@dataclasses.dataclass(frozen=True)
class LanderScene:
    width: int = 32
    height: int = 32
    num_slabs: int = 32        # total slabs — independent of R so the sample
    samples_per_slab: int = 8  # grid (and hence the image) is R-invariant
    seed: int = 1
    num_blobs: int = 6


def _delta_s(part: F.SlabPartition, scene: LanderScene) -> float:
    return part.width / scene.samples_per_slab


def _march_segment(ray: EARay, t_hi, blobs, ds, steps: int):
    """Advance ≤ ``steps`` samples while t_k < t_hi; returns updated (k, L, T)."""
    k, L, T = ray.k, ray.radiance, ray.trans
    for _ in range(steps):
        t_k = ray.t_entry + (k.astype(jnp.float32) + 0.5) * ds
        inside = t_k < t_hi
        p = ray.origin + t_k[:, None] * ray.dir
        sigma = F.density(p, blobs)
        a = 1.0 - jnp.exp(-sigma * ds)
        L = jnp.where(inside, L + T * a, L)
        T = jnp.where(inside, T * (1.0 - a), T)
        k = k + inside.astype(jnp.int32)
    return k, L, T


def _round_fn(q_in, fb, rnd, *, part, blobs, ds, cap):
    r = q_in.items
    lane = jnp.arange(cap)
    valid = lane < q_in.count

    lo, hi = part.bounds(r.slab)
    t_cur = r.t_entry + r.k.astype(jnp.float32) * ds  # lower bound on position
    t_exit, axis, pos_side = F.ray_box_exit(r.origin, r.dir, t_cur, lo, hi)

    k, L, T = _march_segment(r, t_exit, blobs, ds, MARCH_PER_ROUND)
    t_next = r.t_entry + (k.astype(jnp.float32) + 0.5) * ds
    done_seg = t_next >= t_exit  # consumed the whole in-slab segment

    next_slab = r.slab + jnp.where(pos_side, 1, -1)
    stays = (next_slab >= 0) & (next_slab < part.num_slabs) & (axis == 0)
    finish = valid & done_seg & ~stays
    cross = valid & done_seg & stays
    again = valid & ~done_seg  # more samples needed in this slab

    deposit = jnp.where(finish, L + T * F.sky(r.dir), 0.0)
    fb = fb.at[r.pixel].add(jnp.where(valid, deposit, 0.0), mode="drop")

    new = EARay(
        origin=r.origin, dir=r.dir, t_entry=r.t_entry, k=k, pixel=r.pixel,
        slab=jnp.where(cross, next_slab, r.slab), radiance=L, trans=T,
    )
    alive = cross | again
    dest = jnp.where(
        cross,
        part.owner_of_slab(next_slab),
        jnp.where(again, jax.lax.axis_index(AXIS), DISCARD),
    ).astype(jnp.int32)
    out = make_queue(_proto(), cap)
    out = enqueue(out, new, dest, alive)
    return out, fb


def _primary_rays(scene: LanderScene):
    o, d = F.camera_rays(scene.width, scene.height)
    t_entry, hits = F.ray_domain_entry(o, d)
    return o, d, t_entry, hits


def render_forwarding(
    mesh, scene: LanderScene = LanderScene(), *, blobs=None, max_rounds: int = 4096,
    exchange: str = "padded",
) -> Tuple[np.ndarray, dict]:
    """RaFI-style renderer. Returns (image (H,W), stats)."""
    R = mesh.shape[AXIS]
    if blobs is None:
        blobs = F.default_blobs(scene.num_blobs, scene.seed)
    part = F.SlabPartition(num_slabs=scene.num_slabs, num_ranks=R)
    ds = _delta_s(part, scene)
    hw = scene.width * scene.height
    cap = max(256, hw)
    # peer slots only exist for the padded exchange (ragged/onehot reject it)
    slots = {"peer_capacity": cap} if exchange == "padded" else {}
    cfg = ForwardConfig(AXIS, R, cap, exchange=exchange, **slots)

    round_fn = partial(_round_fn, part=part, blobs=blobs, ds=ds, cap=cap)

    def drive(_x):
        me = jax.lax.axis_index(AXIS)
        ppr = hw // R
        pix = me * ppr + jnp.arange(ppr)
        o, d, t_entry, hits = _primary_rays(scene)
        o, d, t_entry, hits = o[pix], d[pix], t_entry[pix], hits[pix]
        fb = jnp.zeros((hw,), jnp.float32)
        fb = fb.at[pix].add(jnp.where(hits, 0.0, F.sky(d)), mode="drop")
        p_in = o + (t_entry[:, None] + 1e-4) * d
        slab = part.slab_of(jnp.clip(p_in[:, 0], 0.0, 1.0 - 1e-6))
        n = pix.shape[0]
        rays = EARay(
            origin=o, dir=d, t_entry=t_entry, k=jnp.zeros(n, jnp.int32),
            pixel=pix.astype(jnp.int32), slab=slab,
            radiance=jnp.zeros(n), trans=jnp.ones(n),
        )
        dest = jnp.where(hits, part.owner_of_slab(slab), DISCARD).astype(jnp.int32)
        q0 = make_queue(_proto(), cap)
        q0 = enqueue(q0, rays, dest, jnp.ones(n, bool))
        q, fb, rounds, _done = run_until_done(round_fn, q0, fb, cfg, max_rounds=max_rounds)
        return jax.lax.psum(fb, AXIS), rounds[None], q.drops[None]

    f = jax.jit(compat.shard_map(drive, mesh=mesh, in_specs=P(AXIS),
                              out_specs=(P(), P(AXIS), P(AXIS))))
    img, rounds, drops = f(jnp.arange(R, dtype=jnp.float32))
    return (
        np.asarray(img).reshape(scene.height, scene.width),
        {"rounds": int(np.max(np.asarray(rounds))), "drops": int(np.sum(np.asarray(drops)))},
    )


def render_deep_compositing(
    mesh, scene: LanderScene = LanderScene(), *, blobs=None, max_fragments: int = 4,
) -> Tuple[np.ndarray, dict]:
    """The §5.2 baseline: per-rank fragment lists + depth-sorted compositing.

    Every rank integrates each of its owned segments of every ray locally
    (no forwarding), keeping at most ``max_fragments`` (L, T, depth) triples
    per pixel — excess fragments are dropped, which is the artifact mechanism
    the paper describes.  An all-gather + depth sort then composites.
    """
    R = mesh.shape[AXIS]
    if blobs is None:
        blobs = F.default_blobs(scene.num_blobs, scene.seed)
    part = F.SlabPartition(num_slabs=scene.num_slabs, num_ranks=R)
    ds = _delta_s(part, scene)
    hw = scene.width * scene.height
    FMAX = max_fragments

    def rank_fragments(_x):
        me = jax.lax.axis_index(AXIS)
        o, d, t_entry, hits = _primary_rays(scene)
        # integrate every owned slab for every ray (sort-last: no forwarding)
        fragL = jnp.zeros((hw, FMAX))
        fragT = jnp.ones((hw, FMAX))
        fragD = jnp.full((hw, FMAX), jnp.inf)
        nfrag = jnp.zeros((hw,), jnp.int32)
        dropped = jnp.zeros((), jnp.int32)
        for j in range(-(-scene.num_slabs // R)):  # owned slabs: me, me+R, ...
            # dynamic slab id: me + j*R (owned, in paper's round-robin layout)
            sid = me + j * R
            slab = sid * jnp.ones((hw,), jnp.int32)
            lo, hi = part.bounds(slab)
            # in-slab param range along each ray (x is monotone for d_x ≠ 0)
            eps = 1e-12
            dx = jnp.where(jnp.abs(d[:, 0]) < eps, eps, d[:, 0])
            ta = (lo - o[:, 0]) / dx
            tb = (hi - o[:, 0]) / dx
            t0s = jnp.maximum(jnp.minimum(ta, tb), t_entry)
            # clip by domain y/z exit
            _, far = F.ray_domain_entry(o, d)
            inv = 1.0 / jnp.where(jnp.abs(d) < eps, jnp.where(d >= 0, eps, -eps), d)
            tfar = jnp.min(
                jnp.where(d >= 0, (1.0 - o) * inv, (0.0 - o) * inv), axis=-1
            )
            t1s = jnp.minimum(jnp.maximum(ta, tb), tfar)
            seg_ok = hits & (t1s > t0s)
            # globally aligned samples: k in [ceil((t0-te)/ds - .5), …)
            k0 = jnp.ceil((t0s - t_entry) / ds - 0.5).astype(jnp.int32)
            k0 = jnp.maximum(k0, 0)
            L = jnp.zeros((hw,))
            T = jnp.ones((hw,))
            k = k0
            for _ in range(scene.samples_per_slab + 2):
                t_k = t_entry + (k.astype(jnp.float32) + 0.5) * ds
                inside = seg_ok & (t_k < t1s)
                p = o + t_k[:, None] * d
                sigma = F.density(p, blobs)
                a = 1.0 - jnp.exp(-sigma * ds)
                L = jnp.where(inside, L + T * a, L)
                T = jnp.where(inside, T * (1.0 - a), T)
                k = k + inside.astype(jnp.int32)
            has = seg_ok & (k > k0)
            slot = jnp.minimum(nfrag, FMAX - 1)
            fits = has & (nfrag < FMAX)
            dropped = dropped + jnp.sum(has & ~fits)
            fragL = fragL.at[jnp.arange(hw), slot].set(
                jnp.where(fits, L, fragL[jnp.arange(hw), slot])
            )
            fragT = fragT.at[jnp.arange(hw), slot].set(
                jnp.where(fits, T, fragT[jnp.arange(hw), slot])
            )
            fragD = fragD.at[jnp.arange(hw), slot].set(
                jnp.where(fits, t0s, fragD[jnp.arange(hw), slot])
            )
            nfrag = nfrag + fits.astype(jnp.int32)
        return fragL, fragT, fragD, dropped[None]

    f = jax.jit(compat.shard_map(rank_fragments, mesh=mesh, in_specs=P(AXIS),
                              out_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS))))
    allL, allT, allD, dropped = f(jnp.arange(R, dtype=jnp.float32))
    # host-side composite (the "sort-last" stage): depth-sort, front-to-back
    allL = np.asarray(allL).reshape(R, hw, -1).transpose(1, 0, 2).reshape(hw, -1)
    allT = np.asarray(allT).reshape(R, hw, -1).transpose(1, 0, 2).reshape(hw, -1)
    allD = np.asarray(allD).reshape(R, hw, -1).transpose(1, 0, 2).reshape(hw, -1)
    order = np.argsort(allD, axis=1)
    L = np.take_along_axis(allL, order, 1)
    T = np.take_along_axis(allT, order, 1)
    img = np.zeros(hw)
    Tacc = np.ones(hw)
    for i in range(L.shape[1]):
        img += Tacc * L[:, i]
        Tacc *= T[:, i]
    # background through remaining transmittance (+ pure misses)
    o, d = F.camera_rays(scene.width, scene.height)
    _, hits = F.ray_domain_entry(o, d)
    sky = np.asarray(F.sky(d))
    img = np.where(np.asarray(hits), img + Tacc * sky, sky)
    return (
        img.reshape(scene.height, scene.width),
        {"dropped_fragments": int(np.sum(np.asarray(dropped)))},
    )

"""Shared scene infrastructure for the sample apps.

* a procedural scalar field (Gaussian-blob mixture) with analytic gradient —
  the stand-in for the papers' volume data (rotstrat / thunderstorm / Mars
  Lander); procedural fields keep TPU kernels gather-free (DESIGN.md §2);
* slab domain partitions (the 1-D special case of VoPaT's k-d partitioning)
  with *proxy* arithmetic: every rank knows every slab's bounds, so "tracing
  against proxies" (OptiX in the paper) becomes closed-form slab arithmetic;
* a pinhole camera for the renderers.

Domain: the unit cube [0,1]³ unless stated otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------------------ fields

def default_blobs(num: int = 6, seed: int = 0) -> jax.Array:
    """(G, 5) rows (cx, cy, cz, sigma, amplitude) inside the unit cube."""
    rng = np.random.default_rng(seed)
    c = rng.uniform(0.2, 0.8, size=(num, 3))
    s = rng.uniform(0.05, 0.15, size=(num, 1))
    a = rng.uniform(1.0, 3.0, size=(num, 1))
    return jnp.asarray(np.concatenate([c, s, a], axis=1), jnp.float32)


def density(p: jax.Array, blobs: jax.Array) -> jax.Array:
    """σ(p) for p (..., 3); blobs (G,5)."""
    d = p[..., None, :] - blobs[..., :, :3]
    r2 = jnp.sum(d * d, axis=-1)
    s2 = blobs[..., :, 3] ** 2
    return jnp.sum(blobs[..., :, 4] * jnp.exp(-0.5 * r2 / s2), axis=-1)


def density_gradient(p: jax.Array, blobs: jax.Array) -> jax.Array:
    """∇σ(p) (..., 3), closed form for the Gaussian mixture."""
    d = p[..., None, :] - blobs[..., :, :3]
    r2 = jnp.sum(d * d, axis=-1)
    s2 = blobs[..., :, 3] ** 2
    w = blobs[..., :, 4] * jnp.exp(-0.5 * r2 / s2) / s2  # (..., G)
    return -jnp.sum(w[..., None] * d, axis=-2)


def majorant(blobs: jax.Array) -> float:
    """A safe global majorant: Σ amplitudes (blob peaks can coincide)."""
    return float(jnp.sum(blobs[:, 4]) * 1.05)


# ------------------------------------------------------------- slab proxies

@dataclasses.dataclass(frozen=True)
class SlabPartition:
    """``num_slabs`` equal x-slabs of [0,1]³, owned round-robin by R ranks.

    ``num_slabs == R`` gives convex per-rank domains (VoPaT §5.1);
    ``num_slabs == k·R`` with k > 1 gives the *non-convex* interleaved
    ownership of the Mars-Lander scenario (§5.2): rank r owns slabs
    {r, r+R, r+2R, ...} so a ray re-enters the same rank many times.
    """

    num_slabs: int
    num_ranks: int

    @property
    def width(self) -> float:
        return 1.0 / self.num_slabs

    def slab_of(self, x) -> jax.Array:
        return jnp.clip((x / self.width).astype(jnp.int32), 0, self.num_slabs - 1)

    def owner_of_slab(self, slab) -> jax.Array:
        return (slab % self.num_ranks).astype(jnp.int32)

    def owner_of(self, p) -> jax.Array:
        return self.owner_of_slab(self.slab_of(p[..., 0]))

    def bounds(self, slab) -> Tuple[jax.Array, jax.Array]:
        lo = slab.astype(jnp.float32) * self.width
        return lo, lo + self.width


def ray_box_exit(o, d, t, lo_x, hi_x):
    """First exit of ray p = o + t·d (current param ``t``) from the box
    [lo_x,hi_x]×[0,1]×[0,1].  Returns (t_exit, axis, positive_side):
    axis ∈ {0,1,2}; for axis 0 the ray crosses an x-plane (slab face)."""
    eps = 1e-12
    inv = 1.0 / jnp.where(jnp.abs(d) < eps, jnp.where(d >= 0, eps, -eps), d)
    lo = jnp.stack([lo_x, jnp.zeros_like(lo_x), jnp.zeros_like(lo_x)], -1)
    hi = jnp.stack([hi_x, jnp.ones_like(hi_x), jnp.ones_like(hi_x)], -1)
    t_far = jnp.where(d >= 0, (hi - o) * inv, (lo - o) * inv)  # (..., 3)
    t_exit = jnp.min(t_far, axis=-1)
    axis = jnp.argmin(t_far, axis=-1).astype(jnp.int32)
    pos_side = jnp.take_along_axis(d, axis[..., None], axis=-1)[..., 0] >= 0
    return jnp.maximum(t_exit, t), axis, pos_side


def ray_domain_entry(o, d):
    """Entry parameter of the ray into [0,1]³ (-inf..; clip at 0), and a hit
    mask.  Rays starting inside enter at t=0."""
    eps = 1e-12
    inv = 1.0 / jnp.where(jnp.abs(d) < eps, jnp.where(d >= 0, eps, -eps), d)
    t0 = (0.0 - o) * inv
    t1 = (1.0 - o) * inv
    t_near = jnp.max(jnp.minimum(t0, t1), axis=-1)
    t_far = jnp.min(jnp.maximum(t0, t1), axis=-1)
    t_entry = jnp.maximum(t_near, 0.0)
    return t_entry, (t_far > t_entry)


# ----------------------------------------------------------------- camera

def camera_rays(width: int, height: int, *, eye=(-1.2, 0.5, 0.5), look=(1.0, 0.0, 0.0), fov: float = 0.9):
    """Pinhole camera: returns (origins (H·W,3), dirs (H·W,3) normalized)."""
    eye = jnp.asarray(eye, jnp.float32)
    fwd = jnp.asarray(look, jnp.float32)
    fwd = fwd / jnp.linalg.norm(fwd)
    up0 = jnp.asarray([0.0, 0.0, 1.0], jnp.float32)
    right = jnp.cross(fwd, up0)
    right = right / jnp.linalg.norm(right)
    up = jnp.cross(right, fwd)
    ys, xs = jnp.meshgrid(
        jnp.linspace(-1, 1, height), jnp.linspace(-1, 1, width), indexing="ij"
    )
    d = fwd[None, :] + jnp.tan(fov / 2) * (
        xs.reshape(-1)[:, None] * right[None, :] + ys.reshape(-1)[:, None] * up[None, :]
    )
    d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
    o = jnp.broadcast_to(eye, d.shape)
    return o, d


def sky(d: jax.Array) -> jax.Array:
    """Simple gradient environment light (grayscale)."""
    return 0.5 + 0.5 * jnp.clip(d[..., 2], -1.0, 1.0)


def write_ppm(path: str, img: np.ndarray) -> None:
    """Write a grayscale or RGB float image in [0,1] as binary PPM."""
    img = np.asarray(img)
    if img.ndim == 2:
        img = np.repeat(img[..., None], 3, axis=-1)
    u8 = (np.clip(img, 0, 1) * 255).astype(np.uint8)
    h, w, _ = u8.shape
    with open(path, "wb") as f:
        f.write(f"P6\n{w} {h}\n255\n".encode())
        f.write(u8.tobytes())

"""rafi/NBody — distributed Barnes-Hut-style N-body (§5.5).

The paper's defining demonstration: a *multi-phase* distributed algorithm
where THREE different work-item types travel through three simultaneous
forwarding contexts (its Listing 2, reproduced here field-for-field):

  Particle       migration after integration (pos, vel, force, mass [+uid])
  VirtualParticle adaptive essential-tree exchange (com, mass, size, sourceRank)
  RefinementReq  requests for finer remote data (senderRank)

Per timestep (all inside one jitted, shard_mapped program — fixed number of
forwarding rounds, no host round-trips):

  1. every rank aggregates its region's monopole (center-of-mass, mass,
     node size) and its 8 octant monopoles — the two-level essential tree;
  2. roots are broadcast to all peers via the VirtualParticle context;
  3. peers apply the multipole-acceptance criterion (size/dist > θ) and send
     a RefinementReq back to owners that are too close;
  4. owners answer each request with their 8 octant VirtualParticles;
  5. forces: the Pallas ``pairwise_accel`` kernel sums gravity from local
     particles ∪ accepted roots ∪ received octants (zero-mass padding lanes
     are inert);
  6. leapfrog kick-drift with reflective walls;
  7. particles migrate to ``owner(new_pos)`` via the Particle context — the
     owner is computed directly on device from the position (the property
     the paper gets from its Morton decomposition; our grid decomposition
     keeps it).

Domain: [0,1]³ split into a (gx, gy, gz) rank grid (R = gx·gy·gz).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import (
    DISCARD,
    ForwardConfig,
    enqueue,
    forward_work,
    make_queue,
    work_item,
)
from repro.kernels.nbody_forces import ops as nb

AXIS = "data"


@work_item
@dataclasses.dataclass
class Particle:
    """Paper Listing 2: pos, vel, force, mass (+uid for cross-rank tracking)."""

    pos: jax.Array    # (3,)
    vel: jax.Array    # (3,)
    force: jax.Array  # (3,)
    mass: jax.Array   # ()
    uid: jax.Array    # () i32


@work_item
@dataclasses.dataclass
class VirtualParticle:
    """Paper Listing 2: center of mass, mass, node size (0 = leaf), source."""

    pos: jax.Array         # (3,)
    mass: jax.Array        # ()
    size: jax.Array        # ()
    source_rank: jax.Array # () i32


@work_item
@dataclasses.dataclass
class RefinementReq:
    """Paper Listing 2: the rank requesting refinement."""

    sender_rank: jax.Array  # () i32


def _p_proto():
    z, zi = jnp.zeros(()), jnp.zeros((), jnp.int32)
    return Particle(jnp.zeros(3), jnp.zeros(3), jnp.zeros(3), z, zi)


def _vp_proto():
    z, zi = jnp.zeros(()), jnp.zeros((), jnp.int32)
    return VirtualParticle(jnp.zeros(3), z, z, zi)


def _rq_proto():
    return RefinementReq(jnp.zeros((), jnp.int32))


@dataclasses.dataclass(frozen=True)
class NBodyConfig:
    num_particles: int = 128
    steps: int = 4
    dt: float = 1e-3
    theta: float = 0.6     # MAC opening angle; larger ⇒ more refinement
    g: float = 1.0
    eps2: float = 1e-3
    seed: int = 0
    use_pallas: bool = True


def _grid_dims(R: int) -> Tuple[int, int, int]:
    dims = [1, 1, 1]
    i = 0
    while R > 1:
        assert R % 2 == 0, "rank count must be a power of two"
        dims[i % 3] *= 2
        R //= 2
        i += 1
    return tuple(dims)


def _owner(pos, dims):
    gx, gy, gz = dims
    ix = jnp.clip((pos[..., 0] * gx).astype(jnp.int32), 0, gx - 1)
    iy = jnp.clip((pos[..., 1] * gy).astype(jnp.int32), 0, gy - 1)
    iz = jnp.clip((pos[..., 2] * gz).astype(jnp.int32), 0, gz - 1)
    return ix + gx * (iy + gy * iz)


def _region_center(me, dims):
    gx, gy, gz = dims
    ix = me % gx
    iy = (me // gx) % gy
    iz = me // (gx * gy)
    return (
        jnp.stack(
            [
                (ix.astype(jnp.float32) + 0.5) / gx,
                (iy.astype(jnp.float32) + 0.5) / gy,
                (iz.astype(jnp.float32) + 0.5) / gz,
            ]
        ),
        jnp.asarray([1.0 / gx, 1.0 / gy, 1.0 / gz], jnp.float32),
    )


def _octant_monopoles(pos, mass, center):
    """8 octant (com, mass) pairs of the local region, by position-bit index."""
    bits = (pos >= center[None, :]).astype(jnp.int32)  # (n, 3)
    oct_id = bits[:, 0] + 2 * bits[:, 1] + 4 * bits[:, 2]
    m_oct = jnp.zeros(8).at[oct_id].add(mass)
    wx = jnp.zeros((8, 3)).at[oct_id].add(mass[:, None] * pos)
    com = wx / jnp.maximum(m_oct[:, None], 1e-20)
    return com, m_oct


def run(mesh, cfg: NBodyConfig = NBodyConfig()) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Simulate. Returns (final positions (N,3), final velocities (N,3), stats).

    Positions/velocities are returned in uid order (globally merged).
    """
    R = mesh.shape[AXIS]
    dims = _grid_dims(R)
    n = cfg.num_particles
    cap_p = max(64, n)                      # all particles may cluster on one rank
    cap_vp = max(16, 9 * R)                 # R roots + 8·R octants worst case
    cap_rq = max(8, R)
    pcfg = ForwardConfig(AXIS, R, cap_p, peer_capacity=cap_p, exchange="padded")
    vcfg = ForwardConfig(AXIS, R, cap_vp, peer_capacity=cap_vp, exchange="padded")
    rcfg = ForwardConfig(AXIS, R, cap_rq, peer_capacity=cap_rq, exchange="padded")

    def accel(xi, xj, mj):
        if cfg.use_pallas:
            return cfg.g * nb.pairwise_accel(xi, xj, mj, eps2=cfg.eps2)
        from repro.kernels.nbody_forces import ref

        return cfg.g * ref.pairwise_accel(xi, xj, mj, eps2=cfg.eps2)

    def timestep(pq, _):
        me = jax.lax.axis_index(AXIS)
        lane_p = jnp.arange(cap_p)
        pvalid = lane_p < pq.count
        p = pq.items
        mass = jnp.where(pvalid, p.mass, 0.0)

        # ---- 1. local essential tree (root + 8 octants) --------------------
        center, ext = _region_center(me, dims)
        m_tot = jnp.sum(mass)
        com = jnp.sum(mass[:, None] * p.pos, axis=0) / jnp.maximum(m_tot, 1e-20)
        node_size = jnp.linalg.norm(ext)
        oct_com, oct_m = _octant_monopoles(p.pos, mass, center)

        # ---- 2. broadcast roots (VirtualParticle context) -------------------
        vq = make_queue(_vp_proto(), cap_vp)
        peers = jnp.arange(R, dtype=jnp.int32)
        roots = VirtualParticle(
            pos=jnp.broadcast_to(com, (R, 3)),
            mass=jnp.full((R,), m_tot),
            size=jnp.full((R,), node_size),
            source_rank=jnp.full((R,), me, jnp.int32),
        )
        vq = enqueue(vq, roots, peers, peers != me)
        vq, _ = forward_work(vq, vcfg)

        # ---- 3. MAC test → refinement requests ------------------------------
        lane_v = jnp.arange(cap_vp)
        vvalid = lane_v < vq.count
        vp = vq.items
        dist = jnp.linalg.norm(vp.pos - center[None, :], axis=-1)
        too_close = vvalid & (vp.size > cfg.theta * dist) & (vp.mass > 0)
        rq = make_queue(_rq_proto(), cap_rq)
        rq = enqueue(
            rq,
            RefinementReq(sender_rank=jnp.full((cap_vp,), me, jnp.int32)),
            jnp.where(too_close, vp.source_rank, DISCARD).astype(jnp.int32),
            vvalid,
        )
        rq, _ = forward_work(rq, rcfg)

        # roots we asked to refine are replaced by their octants when they come
        refined_src = jnp.zeros((R,), bool).at[
            jnp.where(too_close, vp.source_rank, R)
        ].set(True, mode="drop")
        keep_root = vvalid & ~refined_src[jnp.clip(vp.source_rank, 0, R - 1)]

        # ---- 4. answer requests with octants ---------------------------------
        lane_r = jnp.arange(cap_rq)
        rvalid = lane_r < rq.count
        req = rq.items
        vq2 = make_queue(_vp_proto(), cap_vp)
        # emit 8 octants per request: flatten (cap_rq, 8)
        reps = jnp.repeat(req.sender_rank, 8)
        rmask = jnp.repeat(rvalid, 8)
        oct_items = VirtualParticle(
            pos=jnp.tile(oct_com, (cap_rq, 1)),
            mass=jnp.tile(oct_m, cap_rq),
            size=jnp.full((cap_rq * 8,), node_size * 0.5),
            source_rank=jnp.full((cap_rq * 8,), me, jnp.int32),
        )
        vq2 = enqueue(vq2, oct_items, reps.astype(jnp.int32), rmask)
        vq2, _ = forward_work(vq2, vcfg)

        lane_v2 = jnp.arange(cap_vp)
        v2valid = lane_v2 < vq2.count

        # ---- 5. forces: local ∪ kept roots ∪ octants -------------------------
        src_pos = jnp.concatenate(
            [p.pos, vp.pos, vq2.items.pos], axis=0
        )
        src_m = jnp.concatenate(
            [
                mass,
                jnp.where(keep_root, vp.mass, 0.0),
                jnp.where(v2valid, vq2.items.mass, 0.0),
            ]
        )
        a = accel(p.pos, src_pos, src_m)

        # ---- 6. leapfrog + reflective walls ----------------------------------
        vel = p.vel + cfg.dt * a
        pos = p.pos + cfg.dt * vel
        vel = jnp.where((pos < 0) | (pos > 1), -vel, vel)
        pos = jnp.abs(pos)
        pos = 1.0 - jnp.abs(1.0 - pos)

        # ---- 7. migration (Particle context) ---------------------------------
        out = make_queue(_p_proto(), cap_p)
        moved = Particle(pos=pos, vel=vel, force=a, mass=p.mass, uid=p.uid)
        dest = jnp.where(pvalid, _owner(pos, dims), DISCARD).astype(jnp.int32)
        out = enqueue(out, moved, dest, pvalid)
        new_pq, total = forward_work(out, pcfg)
        return new_pq, total

    def drive(_x):
        me = jax.lax.axis_index(AXIS)
        key = jax.random.PRNGKey(cfg.seed)
        pos0 = 0.5 + 0.15 * jax.random.normal(key, (n, 3))
        pos0 = jnp.clip(pos0, 0.05, 0.95)
        vel0 = 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (n, 3))
        mass0 = jax.random.uniform(jax.random.fold_in(key, 2), (n,), minval=0.5, maxval=1.5)
        uid = jnp.arange(n, dtype=jnp.int32)
        mine = _owner(pos0, dims) == me
        q0 = make_queue(_p_proto(), cap_p)
        q0 = enqueue(
            q0,
            Particle(pos=pos0, vel=vel0, force=jnp.zeros((n, 3)), mass=mass0, uid=uid),
            jnp.where(mine, me, DISCARD).astype(jnp.int32),
            jnp.ones(n, bool),
        )

        def body(pq, _):
            new_pq, total = timestep(pq, None)
            return new_pq, total

        pq, totals = jax.lax.scan(body, q0, None, length=cfg.steps)

        # merge final state by uid (disjoint ownership — pmin over +inf pad)
        lane = jnp.arange(cap_p)
        pvalid = lane < pq.count
        big = jnp.float32(jnp.inf)
        posb = jnp.full((n, 3), big)
        velb = jnp.full((n, 3), big)
        uid_idx = jnp.where(pvalid, pq.items.uid, n)
        posb = posb.at[uid_idx].min(
            jnp.where(pvalid[:, None], pq.items.pos, big), mode="drop"
        )
        velb = velb.at[uid_idx].min(
            jnp.where(pvalid[:, None], pq.items.vel, big), mode="drop"
        )
        pos = jax.lax.pmin(posb, AXIS)
        vel = jax.lax.pmin(velb, AXIS)
        return pos, vel, totals, pq.drops[None]

    f = jax.jit(
        compat.shard_map(
            drive, mesh=mesh, in_specs=P(AXIS),
            out_specs=(P(), P(), P(), P(AXIS)), check_vma=False,
        )
    )
    pos, vel, totals, drops = f(jnp.arange(R, dtype=jnp.float32))
    return (
        np.asarray(pos),
        np.asarray(vel),
        {
            "totals": np.asarray(totals).tolist(),
            "drops": int(np.sum(np.asarray(drops))),
            "dims": dims,
        },
    )


def oracle(cfg: NBodyConfig = NBodyConfig()) -> Tuple[np.ndarray, np.ndarray]:
    """Single-device direct-sum leapfrog — ground truth for force accuracy."""
    from repro.kernels.nbody_forces import ref

    key = jax.random.PRNGKey(cfg.seed)
    n = cfg.num_particles
    pos = jnp.clip(0.5 + 0.15 * jax.random.normal(key, (n, 3)), 0.05, 0.95)
    vel = 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (n, 3))
    mass = jax.random.uniform(jax.random.fold_in(key, 2), (n,), minval=0.5, maxval=1.5)
    for _ in range(cfg.steps):
        a = cfg.g * ref.pairwise_accel(pos, pos, mass, eps2=cfg.eps2)
        vel = vel + cfg.dt * a
        pos = pos + cfg.dt * vel
        vel = jnp.where((pos < 0) | (pos > 1), -vel, vel)
        pos = jnp.abs(pos)
        pos = 1.0 - jnp.abs(1.0 - pos)
    return np.asarray(pos), np.asarray(vel)

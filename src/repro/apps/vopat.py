"""VoPaT — data-parallel volume path tracer on the forwarding core (§5.1).

Faithful wavefront structure (paper Fig. 1):

  1. every rank holds the same slab partition ("proxies") and generates its
     share of primary rays (the paper generates all rays everywhere and
     discards foreign ones — generating disjoint subsets is the equivalent,
     cheaper formulation);
  2. per round, a render kernel advances each ray by ONE Woodcock event:
     * no pending flight → draw a tentative free-flight from the *global*
       majorant (one RNG event, keyed by (pixel, events) so the walk is
       bit-identical at any rank count);
     * flight ends inside the slab → acceptance test: real collision scatters
       isotropically (with albedo Russian roulette) and re-emits TO ITSELF
       (Fig. 1: "scattered, then passed to RaFI for forwarding to itself");
       null collision re-arms from the new position;
     * flight crosses the slab face → the ray moves to the boundary and is
       forwarded to the neighbour rank *carrying its remaining flight*
       (exponential flights are memoryless, and carrying the pending target
       keeps the multi-rank walk bitwise equal to the single-rank walk);
     * leaving [0,1]³ → deposit throughput·sky into the distributed
       framebuffer and terminate;
  3. ``forward_work`` moves rays; the on-device while_loop repeats until the
     global in-flight count is zero (§4.2.3 distributed termination);
  4. the per-rank framebuffers are reduced with a psum — the "distributed
     frame buffer" of BriX/VoPaT.

Because the RNG is keyed by (pixel, event) and boundary crossings consume no
events, rendering with R ranks reproduces the R=1 image exactly — the
paper's "the rendered images will not differ in any way" claim, promoted to
a bitwise test (spp=1) in tests/test_apps_vopat.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.apps import fields as F
from repro.core import (
    DISCARD,
    ForwardConfig,
    enqueue,
    make_queue,
    run_until_done,
    work_item,
)

AXIS = "data"


@work_item
@dataclasses.dataclass
class PathRay:
    """44-byte forwardable path state (cf. the paper's 44-byte rays, Fig. 8)."""

    origin: jax.Array      # (3,) f32 current path-segment origin
    dir: jax.Array         # (3,) f32
    t: jax.Array           # () f32 current param along segment
    t_tgt: jax.Array       # () f32 pending tentative-collision param
    u2: jax.Array          # () f32 carried acceptance uniform
    throughput: jax.Array  # () f32
    pixel: jax.Array       # () i32
    events: jax.Array      # () i32 RNG event counter
    bounces: jax.Array     # () i32
    slab: jax.Array        # () i32 current slab index
    in_flight: jax.Array   # () i32 pending flight valid?


def _proto():
    z, zi = jnp.zeros(()), jnp.zeros((), jnp.int32)
    return PathRay(jnp.zeros(3), jnp.zeros(3), z, z, z, z, zi, zi, zi, zi, zi)


@dataclasses.dataclass(frozen=True)
class VopatScene:
    width: int = 64
    height: int = 64
    spp: int = 1
    albedo: float = 0.8
    max_bounces: int = 3
    seed: int = 0
    num_blobs: int = 6


def _event_uniforms(key, pixel, events, n):
    """(lanes, n) uniforms keyed by (pixel, events) — rank-count invariant."""

    def one(px, ev):
        return jax.random.uniform(
            jax.random.fold_in(jax.random.fold_in(key, px), ev), (n,)
        )

    return jax.vmap(one)(pixel, events)


def _round_fn(q_in, fb, rnd, *, part: F.SlabPartition, blobs, mu, key, scene, cap):
    r = q_in.items
    lane = jnp.arange(cap)
    valid = lane < q_in.count

    # --- arm pending flights (one RNG event) -------------------------------
    draw = valid & (r.in_flight == 0)
    u = _event_uniforms(key, r.pixel, r.events, 2)
    t_tgt = jnp.where(draw, r.t - jnp.log1p(-u[:, 0]) / mu, r.t_tgt)
    u2 = jnp.where(draw, u[:, 1], r.u2)
    events = r.events + draw.astype(jnp.int32)

    # --- slab geometry ------------------------------------------------------
    lo, hi = part.bounds(r.slab)
    t_exit, axis, pos_side = F.ray_box_exit(r.origin, r.dir, r.t, lo, hi)
    arrives = valid & (t_tgt <= t_exit)
    crosses = valid & ~arrives

    # --- arrivals: acceptance test ------------------------------------------
    p_tgt = r.origin + t_tgt[:, None] * r.dir
    dens = F.density(p_tgt, blobs)
    hit = arrives & (u2 * mu < dens)
    null = arrives & ~hit

    # --- real collisions: Russian-roulette scatter (one RNG event) ----------
    su = _event_uniforms(key, r.pixel, events, 3)
    events = events + hit.astype(jnp.int32)
    absorbed = hit & (su[:, 2] >= scene.albedo)
    exhausted = hit & ~absorbed & (r.bounces + 1 > scene.max_bounces)
    scattered = hit & ~absorbed & ~exhausted
    z = 1.0 - 2.0 * su[:, 0]
    phi = 2.0 * jnp.pi * su[:, 1]
    s = jnp.sqrt(jnp.maximum(0.0, 1.0 - z * z))
    new_dir = jnp.stack([s * jnp.cos(phi), s * jnp.sin(phi), z], axis=-1)

    # --- boundary crossings --------------------------------------------------
    next_slab = r.slab + jnp.where(pos_side, 1, -1)
    stays_in = (next_slab >= 0) & (next_slab < part.num_slabs)
    to_neighbor = crosses & (axis == 0) & stays_in
    escapes = crosses & ~((axis == 0) & stays_in)

    # --- terminal deposits ----------------------------------------------------
    deposit = jnp.where(escapes, r.throughput * F.sky(r.dir), 0.0)
    fb = fb.at[r.pixel].add(jnp.where(valid, deposit, 0.0), mode="drop")

    # --- assemble next-round rays ---------------------------------------------
    alive = null | scattered | to_neighbor
    new = PathRay(
        origin=jnp.where(scattered[:, None], p_tgt, r.origin),
        dir=jnp.where(scattered[:, None], new_dir, r.dir),
        t=jnp.where(scattered, 0.0, jnp.where(null, t_tgt, t_exit)),
        t_tgt=t_tgt,
        u2=u2,
        throughput=r.throughput,
        pixel=r.pixel,
        events=events,
        bounces=r.bounces + scattered.astype(jnp.int32),
        slab=jnp.where(to_neighbor, next_slab, r.slab),
        in_flight=to_neighbor.astype(jnp.int32),
    )
    dest = jnp.where(
        to_neighbor,
        part.owner_of_slab(next_slab),
        jnp.where(alive, jax.lax.axis_index(AXIS), DISCARD),
    ).astype(jnp.int32)

    out = make_queue(_proto(), cap)
    out = enqueue(out, new, dest, alive)
    return out, fb


def _raygen(me, *, part, blobs, key, scene, cap, num_ranks):
    """Per-rank primary rays (disjoint pixel range) + direct sky for misses."""
    hw = scene.width * scene.height * scene.spp
    ppr = hw // num_ranks
    pix = me * ppr + jnp.arange(ppr)
    o_all, d_all = F.camera_rays(scene.width, scene.height)
    o = o_all[(pix // scene.spp) % (scene.width * scene.height)]
    d = d_all[(pix // scene.spp) % (scene.width * scene.height)]
    t_entry, hits = F.ray_domain_entry(o, d)

    fb = jnp.zeros((scene.width * scene.height,), jnp.float32)
    fb = fb.at[pix // scene.spp].add(jnp.where(hits, 0.0, F.sky(d)), mode="drop")

    p_in = o + (t_entry[:, None] + 1e-4) * d
    slab = part.slab_of(jnp.clip(p_in[:, 0], 0.0, 1.0 - 1e-6))
    z, zi = jnp.zeros(ppr), jnp.zeros(ppr, jnp.int32)
    rays = PathRay(
        origin=o,
        dir=d,
        t=t_entry,
        t_tgt=z,
        u2=z,
        throughput=jnp.ones(ppr),
        pixel=(pix // scene.spp).astype(jnp.int32),
        events=(pix % scene.spp) * jnp.int32(1 << 20) + zi,
        bounces=zi,
        slab=slab,
        in_flight=zi,
    )
    dest = jnp.where(hits, part.owner_of_slab(slab), DISCARD).astype(jnp.int32)
    q0 = make_queue(_proto(), cap)
    q0 = enqueue(q0, rays, dest, jnp.ones(ppr, bool))
    return q0, fb


def render(
    mesh,
    scene: VopatScene = VopatScene(),
    *,
    blobs=None,
    max_rounds: int = 512,
    exchange: str = "padded",
    marshal: str = "sort",
    use_pallas: bool = False,
    telemetry: bool = False,
    telemetry_window: int = 32,
) -> Tuple[np.ndarray, dict]:
    """Distributed render. Returns (image (H,W) float, stats dict).

    With ``telemetry`` the drive loop carries the flight-recorder ring and
    the stats dict gains a ``"telemetry"`` summary (per-tier demand
    histogram/max, clamp drops — see ``repro.telemetry.summarize``): the
    measured basis for replacing this module's worst-case §6.3 queue sizing
    with ``repro.tune``-planned capacities."""
    R = mesh.shape[AXIS]
    if blobs is None:
        blobs = F.default_blobs(scene.num_blobs, scene.seed)
    mu = F.majorant(blobs)
    part = F.SlabPartition(num_slabs=R, num_ranks=R)
    hw = scene.width * scene.height * scene.spp
    # Worst-case wavefront: the whole camera frustum can enter one slab, so a
    # single rank may momentarily own every ray.  The paper's §6.3 guidance —
    # "it was always possible to compute an upper bound ... so queues could be
    # sized accordingly" — for a pinhole camera that bound is all rays.
    cap = max(256, hw)
    # peer slots only exist for the padded exchange (ragged/onehot reject it)
    slots = {"peer_capacity": cap} if exchange == "padded" else {}
    cfg = ForwardConfig(
        AXIS, R, cap, exchange=exchange, marshal=marshal,
        use_pallas=use_pallas, telemetry=telemetry,
        telemetry_window=telemetry_window, **slots
    )
    key = jax.random.PRNGKey(scene.seed)

    round_fn = partial(
        _round_fn, part=part, blobs=blobs, mu=mu, key=key, scene=scene, cap=cap
    )

    def drive(_x):
        me = jax.lax.axis_index(AXIS)
        q0, fb = _raygen(
            me, part=part, blobs=blobs, key=key, scene=scene, cap=cap, num_ranks=R
        )
        if telemetry:
            from repro.telemetry import stats as TS

            q, fb, rounds, _done, ring = run_until_done(
                round_fn, q0, fb, cfg, max_rounds=max_rounds
            )
            img = jax.lax.psum(fb, AXIS)
            return img, rounds[None], q.drops[None], TS.stack_ring(ring)
        q, fb, rounds, _done = run_until_done(round_fn, q0, fb, cfg, max_rounds=max_rounds)
        img = jax.lax.psum(fb, AXIS)
        return img, rounds[None], q.drops[None]

    out_specs = (P(), P(AXIS), P(AXIS))
    if telemetry:
        from repro.telemetry import stats as TS

        ring_proto = TS.make_ring(
            TS.num_tiers(cfg), window=cfg.telemetry_window,
            buckets=cfg.telemetry_buckets,
        )
        out_specs = out_specs + (jax.tree.map(lambda _: P(AXIS), ring_proto),)
    f = jax.jit(
        compat.shard_map(
            drive, mesh=mesh, in_specs=P(AXIS), out_specs=out_specs,
            # interpret-mode pallas_call can't track varying-manual-axes
            check_vma=not use_pallas,
        )
    )
    out = f(jnp.arange(R, dtype=jnp.float32))
    img, rounds, drops = out[:3]
    img = np.asarray(img).reshape(scene.height, scene.width) / scene.spp
    stats = {
        "rounds": int(np.max(np.asarray(rounds))),
        "drops": int(np.sum(np.asarray(drops))),
        "majorant": mu,
        "capacity": cap,
    }
    if telemetry:
        from repro import telemetry as TM

        stats["telemetry"] = TM.summarize(
            out[3], tier_capacities=TM.tier_capacities(cfg)
        )
    return img, stats

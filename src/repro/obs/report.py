"""The flight-data analyzer — ``python -m repro.obs.report capture.json``.

The third leg of the observation law: :mod:`obs.trace` records WHEN,
:mod:`obs.metrics` records HOW MUCH, and this module reads one combined
capture back and answers IS IT HEALTHY — every check cross-referencing a
measured number against the law that governs it:

* **ledger identity** (the conservation law, PR 4/7): per run,
  ``Σ emitted == Σ delivered + in-flight + Σ drops`` with zero unaccounted
  loss, straight off the accounting dict;
* **waste split** (the backpressure law, PR 9): under open flow the counted
  drops must decompose exactly as ``emit_overflow + wasted_wire_rows`` —
  both first-class recorder fields since PR 10;
* **saturation** (the telemetry law, PR 5): per-tier max demand vs the
  configured segment capacity — a tier at ≥ 1.0 is being clamped;
* **spill age** (the lossless law, PR 6): measured ``age_max`` vs the
  ``roofline.spill_drain_model`` bound for the observed peak backlog;
* **goodput** (PR 9): recomputed from the per-round trace
  (``1 - Σ wasted / Σ wire``) and checked against both the run's own
  recorded number and, when the capture carries the scenario's
  offered/drain rates, the ``goodput_model`` prediction;
* **overlap** (the overlap law, PR 8): a measured ``phase_us`` split is
  bracketed by ``overlap_efficiency_model`` at ``async_fraction`` 0 and 1;
* **liveness**: livelock (rounds exhausted, backlog resident, nothing
  moving over the tail of the ring window), starvation (a rank's delivered
  share collapsed vs the per-rank median — only flagged when a healthy majority
  exists; a single-sink incast/convergecast shape is topology, not
  starvation), straggler spans from the host trace.

A run is flagged **degraded** when any of: the ledger does not balance,
goodput < ``GOODPUT_DEGRADED``, the spill-age bound is violated, or a
livelock signature is present.  The exit code of the CLI is the number of
degraded runs — scriptable as a health gate.

Capture format — one JSON object::

    {"meta": {...},
     "runs": [{"name", "flow", "ledger": {...}, "trace": {...},
               "tier_capacities", "capacity", "metrics": [...],
               "delivered_by_rank": [...], "model": {...}}, ...],
     "events": [...],            # optional obs.trace event list
     "phase_us": {...}, "phase_meta": {...}}   # optional obs.phases split

:func:`chaos_capture` builds a run entry from a ``repro.chaos.run_scenario``
result dict; :func:`save_capture` / :func:`load_capture` round-trip the file.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

import numpy as np

GOODPUT_DEGRADED = 0.9  # the PR-9 gate: open overload sits below, credit at 1
SATURATION_HOT = 1.0    # demand_max / capacity at or past the clamp
STARVATION_SHARE = 0.25  # rank delivered < this × median ⇒ starved
LIVELOCK_TAIL = 4        # trailing rounds with no receives ⇒ nothing moving

__all__ = [
    "analyze",
    "chaos_capture",
    "load_capture",
    "main",
    "render",
    "save_capture",
]


# ------------------------------------------------------------ capture side
def chaos_capture(
    name: str,
    res: Dict[str, Any],
    *,
    flow: str,
    tier_capacities,
    capacity: int,
    offered: Optional[int] = None,
    drain: Optional[int] = None,
    metrics: Optional[List[Dict[str, Any]]] = None,
) -> Dict[str, Any]:
    """One ``repro.chaos.run_scenario`` result → a capture run entry."""
    run: Dict[str, Any] = {
        "name": name,
        "flow": flow,
        "scenario": res.get("scenario", ""),
        "tier_capacities": [int(c) for c in tier_capacities],
        "capacity": int(capacity),
        "ledger": {
            "emitted": int(res["emitted"]),
            "delivered": int(res["delivered_total"]),
            "resident": int(res["resident"]),
            "drops": int(res["drops"]),
            "lost": int(res["lost"]),
            "rounds": int(res["rounds"]),
            "done": bool(res["done"]),
            "emit_overflow": int(res.get("emit_overflow", 0)),
            "wasted_wire_rows": int(res.get("wasted_wire_rows", 0)),
            "wire_rows": int(res.get("wire_rows", 0)),
            "goodput": float(res.get("goodput", 1.0)),
            "retained_rows": int(res.get("retained_rows", 0)),
            "age_max": int(res.get("age_max", 0)),
        },
        "trace": {
            k: np.asarray(res[src]).astype(int).tolist()
            for k, src in (
                ("recv_total", "recv_trace"),
                ("wasted_wire_rows", "wasted_trace"),
                ("retained_rows", "retained_trace"),
                ("age_max", "age_trace"),
            )
            if src in res
        },
    }
    if "delivered" in res:
        run["delivered_by_rank"] = (
            np.asarray(res["delivered"])[:, 0].astype(int).tolist()
        )
    model: Dict[str, Any] = {}
    if offered is not None:
        model["offered_rows_per_round"] = int(offered)
    if drain is not None:
        model["drain_rows_per_round"] = int(drain)
    if model:
        run["model"] = model
    if metrics is not None:
        run["metrics"] = metrics
    return run


def save_capture(path, runs: List[Dict[str, Any]], *, events=None,
                 phase_us=None, phase_meta=None, meta=None) -> str:
    cap: Dict[str, Any] = {"meta": dict(meta or {}), "runs": list(runs)}
    if events is not None:
        cap["events"] = [
            {**e, "args": {k: _plain(v) for k, v in (e.get("args") or {}).items()}}
            for e in events
        ]
    if phase_us is not None:
        cap["phase_us"] = {k: float(v) for k, v in phase_us.items()}
        cap["phase_meta"] = dict(phase_meta or {})
    with open(path, "w") as f:
        json.dump(cap, f)
    return str(path)


def _plain(v):
    a = np.asarray(v)
    if a.dtype == object:
        return str(v)
    return a.item() if a.ndim == 0 else a.tolist()


def load_capture(path) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


# ------------------------------------------------------------ analysis side
def _check(name: str, ok: bool, detail: str) -> Dict[str, Any]:
    return {"check": name, "ok": bool(ok), "detail": detail}


def _analyze_run(run: Dict[str, Any]) -> Dict[str, Any]:
    from repro.roofline.analysis import goodput_model, spill_drain_model

    led = run["ledger"]
    flow = run.get("flow", "open")
    checks: List[Dict[str, Any]] = []
    flags: List[str] = []

    # 1. conservation: emitted == delivered + resident + drops, lost == 0
    balance = (
        led["emitted"] - led["delivered"] - led["resident"] - led["drops"]
    )
    ok = balance == 0 and led["lost"] == 0
    checks.append(_check(
        "ledger",
        ok,
        f"emitted {led['emitted']} = delivered {led['delivered']} + "
        f"resident {led['resident']} + drops {led['drops']} "
        f"(residual {balance}, lost {led['lost']})",
    ))
    if not ok:
        flags.append("ledger_violation")

    # 2. the open-flow waste split (credit must have nothing to split)
    split = led["emit_overflow"] + led["wasted_wire_rows"]
    ok = split == led["drops"]
    checks.append(_check(
        "waste_split",
        ok,
        f"drops {led['drops']} = emit_overflow {led['emit_overflow']} + "
        f"wasted_wire_rows {led['wasted_wire_rows']}",
    ))
    if not ok:
        flags.append("waste_split_violation")

    # 3. goodput — recomputed from the per-round trace when present, and
    # cross-checked against the model prediction when the capture carries
    # the scenario's offered/drain rates
    tr = run.get("trace", {})
    goodput = led["goodput"]
    if tr.get("recv_total") and "wasted_wire_rows" in tr:
        wire = int(np.sum(tr["recv_total"]))
        wasted = int(np.sum(tr["wasted_wire_rows"]))
        goodput = 1.0 if wire == 0 else 1.0 - wasted / wire
        ok = abs(goodput - led["goodput"]) < 1e-9
        checks.append(_check(
            "goodput_trace",
            ok,
            f"trace recomputation 1 - {wasted}/{wire} = {goodput:.4f} vs "
            f"recorded {led['goodput']:.4f}",
        ))
        if not ok:
            flags.append("goodput_mismatch")
    model = run.get("model", {})
    if "offered_rows_per_round" in model and "drain_rows_per_round" in model:
        gm = goodput_model(
            model["offered_rows_per_round"], model["drain_rows_per_round"]
        )
        predicted = gm["credit" if flow == "credit" else "open"]["goodput"]
        # the analytic number is a steady-state asymptote; ramp-up rounds
        # pull the measurement up, so the check is one-sided per flow
        ok = goodput >= predicted - 1e-9 if flow == "credit" else (
            goodput <= 1.0 and goodput >= min(predicted, GOODPUT_DEGRADED) - 0.35
        )
        checks.append(_check(
            "goodput_model",
            ok,
            f"{flow} flow measured {goodput:.4f} vs model {predicted:.4f} "
            f"(offered {model['offered_rows_per_round']}/round, drain "
            f"{model['drain_rows_per_round']}/round)",
        ))
    if goodput < GOODPUT_DEGRADED:
        flags.append("degraded_goodput")

    # 4. per-tier saturation from the metrics snapshot
    saturation = []
    for m in run.get("metrics", []):
        if m["name"].endswith("_demand_max_rows"):
            tier = int(m["labels"].get("tier", 0))
            cap_t = run["tier_capacities"][tier] if tier < len(
                run["tier_capacities"]) else run["capacity"]
            sat = m["value"] / cap_t if cap_t else 0.0
            saturation.append({"tier": tier, "demand_max": m["value"],
                               "capacity": cap_t, "ratio": sat})
    hot = [s for s in saturation if s["ratio"] >= SATURATION_HOT]
    if saturation:
        checks.append(_check(
            "saturation",
            True,  # informational: saturation is a cause, not a failure
            "; ".join(
                f"tier {s['tier']}: demand_max {int(s['demand_max'])} / "
                f"cap {s['capacity']} = {s['ratio']:.2f}"
                + (" HOT" if s["ratio"] >= SATURATION_HOT else "")
                for s in saturation
            ),
        ))
        if hot:
            flags.append("saturated")

    # 5. spill age vs the lossless-law drain bound: the backlog observed at
    # its peak must drain within ceil(backlog / allowance) rounds, plus the
    # rounds over which the backlog was still being fed (the model drains a
    # standing backlog; the scenario builds it incrementally)
    if tr.get("retained_rows"):
        backlog = int(np.max(tr["retained_rows"]))
        age = led["age_max"]
        if backlog > 0:
            allowance = max(1, min(run["tier_capacities"]))
            bound = spill_drain_model(backlog, allowance)["age_bound"]
            feed = int(np.sum(np.asarray(tr["retained_rows"]) > 0))
            ok = age <= bound + feed
            checks.append(_check(
                "spill_age",
                ok,
                f"age_max {age} vs drain bound ceil({backlog}/{allowance}) "
                f"= {bound} + {feed} feeding rounds",
            ))
            if not ok:
                flags.append("spill_age_exceeds_model")

    # 6. liveness: livelock / starvation signatures
    if not led["done"]:
        recv = tr.get("recv_total", [])
        tail = recv[-LIVELOCK_TAIL:] if recv else []
        moving = any(int(v) > 0 for v in tail)
        stuck = led["resident"] > 0 and not moving
        checks.append(_check(
            "liveness",
            not stuck,
            f"not done after {led['rounds']} rounds, resident "
            f"{led['resident']}, last {len(tail)} rounds receive "
            f"{[int(v) for v in tail]}",
        ))
        if stuck:
            flags.append("livelock")
    by_rank = run.get("delivered_by_rank")
    if by_rank and len(by_rank) > 1 and sum(by_rank) > 0:
        # baseline on the MEDIAN, not the mean: a couple of hot sinks
        # (sustained overload concentrates traffic by design) inflate the
        # mean until ordinary cold ranks read as starved
        med = float(np.median(np.asarray(by_rank, dtype=float)))
        starved = [r for r, n in enumerate(by_rank)
                   if n < STARVATION_SHARE * med]
        # starvation is a MINORITY collapsing against a healthy majority.
        # When fewer than half the ranks clear the line, the traffic matrix
        # itself is skewed (incast/convergecast delivers everything to one
        # sink) — that is topology, not a health defect, so the check passes
        # and the skew is reported in the detail only.
        skewed = (len(by_rank) - len(starved)) * 2 < len(by_rank)
        checks.append(_check(
            "fairness",
            not starved or skewed,
            f"per-rank delivered {by_rank} (median {med:.1f}"
            + (f"; starved ranks {starved}" if starved else "")
            + ("; skewed traffic matrix — single-sink shape" if skewed else "")
            + ")",
        ))
        if starved and not skewed:
            flags.append("starvation")

    return {
        "name": run.get("name", "?"),
        "flow": flow,
        "goodput": goodput,
        "wasted_wire_rows": led["wasted_wire_rows"],
        "wire_rows": led["wire_rows"],
        "rounds": led["rounds"],
        "checks": checks,
        "saturation": saturation,
        "flags": sorted(set(flags)),
        "degraded": bool(
            {"ledger_violation", "degraded_goodput",
             "spill_age_exceeds_model", "livelock"} & set(flags)
        ),
    }


def _analyze_phases(capture: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    """Bracket a measured phase split with the overlap law's model at
    async_fraction 0 (synchronous fabric) and 1 (DMA fabric)."""
    phase_us = capture.get("phase_us")
    if not phase_us:
        return None
    from repro.roofline.analysis import overlap_efficiency_model

    meta = capture.get("phase_meta", {})
    shards = int(meta.get("shards", 1))
    bulk_keys = {k: v for k, v in phase_us.items()
                 if "_" not in k or not k.split("_")[0].startswith("shard")}
    sync = overlap_efficiency_model(bulk_keys, shards, async_fraction=0.0)
    ici = overlap_efficiency_model(bulk_keys, shards, async_fraction=1.0)
    wire = sync["wire_us"]
    comp = sync["compute_us"]
    total = wire + comp
    return {
        "phase_us": {k: float(v) for k, v in phase_us.items()},
        "shards": shards,
        "compute_us": comp,
        "wire_us": wire,
        "wire_fraction": wire / total if total else 0.0,
        "pipelined_bracket_us": [ici["pipelined_us"], sync["pipelined_us"]],
        "speedup_bracket": [sync["speedup"], ici["speedup"]],
    }


def _analyze_events(capture: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    """Host-trace digest: per-category counts, slowest spans, chaos faults,
    autotune re-plans, checkpoint cadence."""
    events = capture.get("events")
    if not events:
        return None
    by_cat: Dict[str, int] = {}
    spans = []
    for e in events:
        by_cat[e.get("cat", "?")] = by_cat.get(e.get("cat", "?"), 0) + 1
        if e.get("ph") == "X" and e.get("dur", 0) > 0:
            spans.append((float(e["dur"]), e.get("name", "?")))
    spans.sort(reverse=True)
    out: Dict[str, Any] = {
        "events": len(events),
        "by_category": dict(sorted(by_cat.items())),
        "slowest_spans": [
            {"name": n, "dur_us": round(d, 1)} for d, n in spans[:5]
        ],
    }
    saves = [e for e in events
             if e.get("cat") == "recovery" and "save" in e.get("name", "")]
    if saves:
        out["checkpoint_saves"] = len(saves)
    replans = [e for e in events if e.get("cat") == "tune"]
    if replans:
        out["autotune_replans"] = len(replans)
    faults = [e for e in events if e.get("cat") == "chaos"]
    if faults:
        out["chaos_events"] = len(faults)
    return out


def analyze(capture: Dict[str, Any]) -> Dict[str, Any]:
    """Capture → cross-law health report (see module docstring)."""
    runs = [_analyze_run(r) for r in capture.get("runs", [])]
    report: Dict[str, Any] = {
        "meta": capture.get("meta", {}),
        "runs": runs,
        "degraded_runs": [r["name"] for r in runs if r["degraded"]],
    }
    phases = _analyze_phases(capture)
    if phases:
        report["phases"] = phases
    events = _analyze_events(capture)
    if events:
        report["trace_digest"] = events
    return report


# ------------------------------------------------------------- text render
def render(report: Dict[str, Any]) -> str:
    lines: List[str] = ["# RAFI flight-data report", ""]
    for r in report["runs"]:
        verdict = "DEGRADED" if r["degraded"] else "healthy"
        lines.append(
            f"## run `{r['name']}` (flow={r['flow']}) — {verdict}"
        )
        lines.append(
            f"goodput {r['goodput']:.4f} · wasted wire rows "
            f"{r['wasted_wire_rows']} / {r['wire_rows']} · "
            f"rounds {r['rounds']}"
        )
        if r["flags"]:
            lines.append(f"flags: {', '.join(r['flags'])}")
        for c in r["checks"]:
            mark = "ok " if c["ok"] else "FAIL"
            lines.append(f"  [{mark}] {c['check']}: {c['detail']}")
        lines.append("")
    if "phases" in report:
        p = report["phases"]
        lines.append("## phase split (one round)")
        for k, v in p["phase_us"].items():
            lines.append(f"  {k}: {v:.1f} us")
        lines.append(
            f"  wire fraction {p['wire_fraction']:.2f}; pipelined x{p['shards']} "
            f"bracket [{p['pipelined_bracket_us'][0]:.1f}, "
            f"{p['pipelined_bracket_us'][1]:.1f}] us (ici..sync)"
        )
        lines.append("")
    if "trace_digest" in report:
        d = report["trace_digest"]
        lines.append("## host trace digest")
        lines.append(
            f"  {d['events']} events: "
            + ", ".join(f"{k}={v}" for k, v in d["by_category"].items())
        )
        for extra in ("checkpoint_saves", "autotune_replans", "chaos_events"):
            if extra in d:
                lines.append(f"  {extra}: {d[extra]}")
        for s in d["slowest_spans"]:
            lines.append(f"  span {s['name']}: {s['dur_us']} us")
        lines.append("")
    deg = report["degraded_runs"]
    lines.append(
        f"verdict: {len(deg)} degraded run(s)"
        + (f" — {', '.join(deg)}" if deg else " — all healthy")
    )
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="cross-law health report over an obs capture",
    )
    ap.add_argument("capture", help="capture JSON (see module docstring)")
    ap.add_argument("--json", action="store_true",
                    help="emit the raw report dict instead of text")
    args = ap.parse_args(argv)
    report = analyze(load_capture(args.capture))
    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True))
    else:
        print(render(report), end="")
    return len(report["degraded_runs"])


if __name__ == "__main__":
    sys.exit(main())

"""Typed counter/gauge snapshots per burst — the metrics half of the
observation law.

Everything here is DERIVED from values the stack already surfaces on the
host — the ``telemetry.StatsRing`` the drive returns, the recovery carry's
accounting leaves, a checkpoint manifest — so a metered program is the same
program: zero added collectives, lowered HLO bit-identical (guarded in
``tests/test_collective_budget.py``).

The registry is deliberately tiny: a :class:`Metric` is a name, a kind
(``counter`` — monotone over the burst — or ``gauge``), a float value and a
label dict.  Two exporters cover the operational surface:

* :func:`to_prometheus` — the text exposition format a scrape endpoint
  serves (one ``# TYPE`` line per family, labels sorted);
* :func:`to_json` — the machine-readable capture ``repro.obs.report``
  ingests.

:func:`burst_metrics` maps one recorded burst (a ring + its config) onto the
full per-law inventory: per-tier demand histograms and clamp drops (ISSUE 5),
retained rows / spill ages (ISSUE 6), credit adverts, wasted-wire rows and
emission overflow (ISSUE 9), receive totals and goodput.
:func:`accounting_metrics` adds the conservation-watchdog terms of a
segmented drive, :func:`checkpoint_metrics` the bytes/leaves of a published
checkpoint manifest.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.telemetry import stats as TS

__all__ = [
    "Metric",
    "accounting_metrics",
    "burst_metrics",
    "checkpoint_metrics",
    "from_summary",
    "metrics_dict",
    "to_json",
    "to_prometheus",
]

_KINDS = ("counter", "gauge")


@dataclasses.dataclass(frozen=True)
class Metric:
    """One sample: ``name{labels} value`` with a Prometheus kind."""

    name: str
    kind: str  # "counter" | "gauge"
    value: float
    labels: Tuple[Tuple[str, str], ...] = ()
    help: str = ""

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"metric kind must be one of {_KINDS}, got {self.kind!r}")


def _m(name: str, kind: str, value, help: str = "", **labels) -> Metric:
    return Metric(
        name=name, kind=kind, value=float(value),
        labels=tuple(sorted((k, str(v)) for k, v in labels.items())),
        help=help,
    )


def from_summary(summary: Dict[str, Any], *, prefix: str = "rafi") -> List[Metric]:
    """A ``telemetry.summarize`` dict → the per-burst metric inventory."""
    out: List[Metric] = []
    caps = summary["tier_capacities"]
    L = len(caps)
    out.append(_m(f"{prefix}_rounds_total", "counter", summary["rounds"],
                  "forwarding rounds recorded this burst"))
    for l in range(L):
        lab = dict(tier=l)
        out.append(_m(f"{prefix}_tier_capacity_rows", "gauge", caps[l],
                      "configured per-segment slot capacity", **lab))
        out.append(_m(f"{prefix}_demand_max_rows", "gauge",
                      int(summary["demand_max"][l]),
                      "max single-segment demand seen", **lab))
        out.append(_m(f"{prefix}_demand_rows_total", "counter",
                      int(summary["demand_total"][l]),
                      "rows presented to the tier pre-clamp", **lab))
        out.append(_m(f"{prefix}_sent_rows_total", "counter",
                      int(summary["sent_rows"][l]),
                      "rows shipped post-clamp", **lab))
        out.append(_m(f"{prefix}_stage_drops_total", "counter",
                      int(summary["stage_drops"][l]),
                      "rows the tier's send clamp cut", **lab))
        out.append(_m(f"{prefix}_credits_granted_total", "counter",
                      int(summary["credits_granted"][l]),
                      "credit allowance granted (flow=credit)", **lab))
        out.append(_m(f"{prefix}_rows_held_total", "counter",
                      int(summary["rows_held"][l]),
                      "rows the tier's clamp held locally", **lab))
        hist = np.asarray(summary["demand_hist"])[l]
        for b, cnt in enumerate(hist):
            out.append(_m(f"{prefix}_demand_bucket_total", "counter", int(cnt),
                          "segments per demand bucket", tier=l, bucket=b))
    out.append(_m(f"{prefix}_recv_drops_total", "counter", summary["recv_drops"],
                  "rows the receiver compaction cut"))
    out.append(_m(f"{prefix}_wasted_wire_rows_total", "counter",
                  summary["wasted_wire_rows"],
                  "rows that crossed a wire and were then discarded"))
    out.append(_m(f"{prefix}_drops_total", "counter", summary["drops"],
                  "all clamp drops (send + receive)"))
    out.append(_m(f"{prefix}_emit_overflow_total", "counter",
                  summary["emit_overflow"],
                  "local emission rows clipped by the drive"))
    out.append(_m(f"{prefix}_retained_rows_total", "counter",
                  summary["retained_rows"],
                  "row-rounds retained by spill-and-retry"))
    out.append(_m(f"{prefix}_spill_age_max_rounds", "gauge", summary["age_max"],
                  "oldest retained lane's rounds-waiting counter"))
    out.append(_m(f"{prefix}_recv_rows_max", "gauge", summary["recv_total_max"],
                  "max rows arriving in one round"))
    out.append(_m(f"{prefix}_goodput_ratio", "gauge", summary["goodput"],
                  "admitted wire rows / shipped wire rows"))
    return out


def burst_metrics(ring: TS.StatsRing, cfg: Any, *,
                  prefix: str = "rafi") -> List[Metric]:
    """One burst's ring (per-rank or rank-stacked) → metrics, using the
    config's tier-capacity law for the demand buckets."""
    summary = TS.summarize(ring, tier_capacities=TS.tier_capacities(cfg))
    return from_summary(summary, prefix=prefix)


def accounting_metrics(res: Dict[str, Any], *, prefix: str = "rafi") -> List[Metric]:
    """Conservation-watchdog terms of a segmented-drive result dict
    (``recovery.run_checkpointed``/``resume_run``): Σ emitted, Σ delivered,
    in-flight residue, Σ drops — the ledger every boundary re-proves."""
    out: List[Metric] = []
    for key, kind, hlp in (
        ("emitted", "counter", "rows entering the system (drive-counted)"),
        ("delivered", "counter", "rows handed to round_fn as arrivals"),
    ):
        if key in res:
            out.append(_m(f"{prefix}_{key}_rows_total", kind,
                          int(np.asarray(res[key], dtype=np.uint64).sum()), hlp))
    if "rounds" in res:
        out.append(_m(f"{prefix}_drive_rounds_total", "counter",
                      int(np.asarray(res["rounds"])), "rounds driven"))
    if "q" in res:
        q = res["q"]
        out.append(_m(f"{prefix}_inflight_rows", "gauge",
                      int(np.asarray(q.count).sum()), "rows still queued"))
        out.append(_m(f"{prefix}_queue_drops_total", "counter",
                      int(np.asarray(q.drops).sum()), "queue-counted drops"))
    return out


def checkpoint_metrics(manifest: Dict[str, Any], *,
                       prefix: str = "rafi") -> List[Metric]:
    """A ``repro.ckpt`` manifest → checkpoint size/armature gauges."""
    leaves = manifest.get("leaves", [])
    # manifest leaves record shape+dtype, not byte counts — derive them
    total = sum(
        int(np.prod(e["shape"]) * np.dtype(e["dtype"]).itemsize)
        for e in leaves
        if "shape" in e and "dtype" in e
    )
    step = int(manifest.get("step", manifest.get("meta", {}).get("round", 0)))
    return [
        _m(f"{prefix}_checkpoint_bytes", "gauge", total,
           "bytes of the last published checkpoint", step=step),
        _m(f"{prefix}_checkpoint_leaves", "gauge", len(leaves),
           "carry leaves in the last published checkpoint", step=step),
    ]


# ------------------------------------------------------------- exporters
def to_prometheus(metrics: List[Metric]) -> str:
    """Prometheus text exposition: families sorted, one TYPE/HELP line per
    family, labels rendered sorted — deterministic output for goldens."""
    by_family: Dict[str, List[Metric]] = {}
    for m in metrics:
        by_family.setdefault(m.name, []).append(m)
    lines: List[str] = []
    for name in sorted(by_family):
        fam = by_family[name]
        if fam[0].help:
            lines.append(f"# HELP {name} {fam[0].help}")
        lines.append(f"# TYPE {name} {fam[0].kind}")
        for m in fam:
            if m.labels:
                lab = ",".join(f'{k}="{v}"' for k, v in m.labels)
                lines.append(f"{name}{{{lab}}} {_fmt(m.value)}")
            else:
                lines.append(f"{name} {_fmt(m.value)}")
    return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


def to_json(metrics: List[Metric]) -> str:
    """The capture encoding ``repro.obs.report`` reads back."""
    return json.dumps(
        [
            {"name": m.name, "kind": m.kind, "value": m.value,
             "labels": dict(m.labels)}
            for m in metrics
        ],
        sort_keys=True,
    )


def metrics_dict(metrics: List[Metric]) -> Dict[str, float]:
    """Flat ``{name{labels}: value}`` view for asserts and quick reads."""
    out: Dict[str, float] = {}
    for m in metrics:
        key = m.name
        if m.labels:
            key += "{" + ",".join(f"{k}={v}" for k, v in m.labels) + "}"
        out[key] = m.value
    return out

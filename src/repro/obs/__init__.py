"""The observation law (ISSUE 10): every law's behavior is observable from
one artifact, at zero collective cost.

Four pieces:

* :mod:`repro.obs.trace` — host-side span tracer over every drive entry
  point; Chrome/Perfetto ``trace_event`` export; ``RAFI_TRACE`` env toggle.
* :mod:`repro.obs.metrics` — typed counter/gauge snapshots per burst from
  already-surfaced telemetry; Prometheus text + JSON exporters.
* :mod:`repro.obs.phases` — per-phase device timing of one forwarding round
  for any backend (promoted from ``benchmarks/run.py --profile``).
* :mod:`repro.obs.report` — the flight-data analyzer
  (``python -m repro.obs.report capture.json``).

``trace`` and ``metrics`` import eagerly (stdlib + telemetry only — core
modules hook the tracer without cycles); ``phases`` and ``report`` pull in
``repro.core`` / ``repro.roofline`` and load lazily on first attribute
access.
"""
from repro.obs import metrics, trace

__all__ = ["metrics", "phases", "report", "trace"]


def __getattr__(name):
    if name in ("phases", "report"):
        import importlib

        mod = importlib.import_module(f"repro.obs.{name}")
        globals()[name] = mod
        return mod
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")

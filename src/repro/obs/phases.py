"""Per-phase device timing of one forwarding round — the stage-graph round
as a measurable timeline, for ANY backend.

Promoted from the padded-only ``benchmarks/run.py::_profile_phases`` (PR 8)
into the observation law's library half: each stage of the exchange
(``stages.Marshal`` / ``CountExchange`` / ``PayloadExchange`` /
``SpillExtract``+``Unmarshal``) is rebuilt as a STANDALONE jitted
``shard_map`` program over the same production primitives
(``exchange.padded_send_buffer``, ``exchange.exchange_counts``,
``exchange._a2a``, ``exchange._compact_blocks``, ``stages.padded_send_shard``,
``stages.compact_shard``, ``stages.ragged_control_plane``) and timed on its
own — the sum can exceed the fused round, which runs all phases in one XLA
program; the split shows WHERE the time goes.

Supported backends and the phase keys they produce (the
``fwd_profile_{tag}_{key}`` bench row names — STABLE since PR 8 for the flat
padded case):

* flat padded, ``pipeline_shards=1``:
  ``marshal`` / ``count_collective`` / ``payload_collective`` / ``unmarshal``
* flat padded, ``pipeline_shards=S>1``: the bulk four plus per-shard
  ``shard{k}_marshal`` / ``shard{k}_payload_collective`` /
  ``shard{k}_unmarshal`` (each shard's count collective ships the full
  vector, so there is exactly one ``count_collective`` key).
* hierarchical: per-tier ``tier{l}_marshal`` / ``tier{l}_count_collective``
  / ``tier{l}_payload_collective`` for every extent>1 tier ``l`` (slowest
  first, fastest runs first), plus the final ``unmarshal``.
* ragged: ``marshal`` / ``count_collective`` (the one-all_gather control
  plane) / ``payload_collective`` (requires ``lax.ragged_all_to_all`` —
  absent on this container's JAX, the key is skipped).

:func:`to_perfetto` lays the measured phase durations out as a merged
multi-rank timeline in Chrome/Perfetto ``trace_event`` JSON — one process
track per rank, one thread track per tier — composable with the host-side
``obs.trace`` span timeline (same track convention).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

__all__ = ["profile_phases", "to_perfetto", "tier_of_phase"]


def _default_timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5):
    """Median-of-iters wall time in us (the benchmarks harness passes its
    own ``_timeit`` so bench rows keep the established methodology)."""
    out = None
    for _ in range(warmup):
        out = jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2], out


def _fill_items(proto: Any, n_emit: int):
    """Generic work-item filler: lane-varying leaves of the proto's shapes
    (values don't matter for timing; lane-derived so nothing folds away)."""
    lane = jnp.arange(n_emit)

    def leaf(a):
        x = lane.astype(a.dtype if jnp.issubdtype(a.dtype, jnp.floating)
                        else jnp.int32).astype(a.dtype)
        return jnp.broadcast_to(
            x.reshape((n_emit,) + (1,) * a.ndim), (n_emit,) + a.shape
        )

    return jax.tree.map(leaf, proto)


def profile_phases(
    cfg: Any,
    mesh,
    *,
    n_emit: int,
    cap: int,
    proto: Any,
    timeit: Optional[Callable] = None,
) -> Dict[str, float]:
    """Time each stage of one ``cfg`` forwarding round standalone; returns
    ``{phase_key: us}`` (see module docstring for the key vocabulary)."""
    if timeit is None:
        timeit = _default_timeit
    if cfg.exchange == "padded":
        phases = _padded_phases(cfg, n_emit, cap, proto)
        if cfg.pipeline_shards > 1:
            phases += _pipelined_phases(cfg, n_emit, cap, proto)
    elif cfg.exchange == "hierarchical":
        phases = _hierarchical_phases(cfg, n_emit, cap, proto)
    elif cfg.exchange == "ragged":
        phases = _ragged_phases(cfg, n_emit, cap, proto)
    else:
        raise ValueError(
            f"profile_phases supports padded/hierarchical/ragged rounds, "
            f"got exchange={cfg.exchange!r}"
        )
    from repro.core.forwarding import flatten_axis_names

    axes = flatten_axis_names(cfg.axis_name)
    phase_us: Dict[str, float] = {}
    for key, kernel in phases:
        f = jax.jit(
            compat.shard_map(
                kernel, mesh=mesh, in_specs=P(axes), out_specs=P(axes)
            )
        )
        us, _ = timeit(f, jnp.arange(float(cfg.num_ranks)))
        phase_us[key] = us
    return phase_us


def _setup(cfg, n_emit, cap, proto):
    """Shared emission: a filled queue with a deterministic scattered
    destination pattern (same law as the PR-8 bench profiler)."""
    from repro.core import enqueue, make_queue

    R = cfg.num_ranks

    def setup(me):
        q = make_queue(proto, cap)
        lane = jnp.arange(n_emit)
        dest = ((me * 7 + lane * 131) % R).astype(jnp.int32)
        return enqueue(q, _fill_items(proto, n_emit), dest, jnp.ones(n_emit, bool))

    return setup


def _marshal_plan(cfg, q):
    """The send-side plan (sort or scatter), shared by every marshal phase."""
    from repro.core import sorting as S

    R = cfg.num_ranks
    if cfg.marshal == "scatter":
        d_clean, rank, hist = S.destination_rank(q.dest, q.count, R)
        return dict(perm=None, counts=hist[:R], dest_clean=d_clean,
                    dest_rank=rank)
    perm, _d, counts = S.sort_permutation(
        q.dest, q.count, R, method=cfg.sort_method
    )
    return dict(perm=perm, counts=counts[:R], dest_clean=None, dest_rank=None)


def _padded_phases(cfg, n_emit, cap, proto) -> Tuple:
    from repro.core import exchange as X
    from repro.core import types as T
    from repro.core.forwarding import flatten_axis_names

    R, slot = cfg.num_ranks, cfg.peer_capacity
    words = T.pack_spec(proto).total_words
    axes = flatten_axis_names(cfg.axis_name)
    setup = _setup(cfg, n_emit, cap, proto)

    def marshal_kernel(x):
        me = jax.lax.axis_index(axes)
        q = setup(me)
        packed, _spec = T.pack_payload(q.items)
        plan = _marshal_plan(cfg, q)
        send = X.padded_send_buffer(
            packed, plan["perm"], plan["counts"], num_ranks=R,
            peer_capacity=slot, marshal=cfg.marshal,
            dest_clean=plan["dest_clean"], dest_rank=plan["dest_rank"],
            use_pallas=cfg.use_pallas,
        )
        return jnp.sum(send, dtype=jnp.uint32)[None] + x[:1].astype(jnp.uint32) * 0

    def count_collective_kernel(x):
        me = jax.lax.axis_index(axes)
        counts = ((me + jnp.arange(R)) % jnp.int32(slot)).astype(jnp.int32)
        recv = X.exchange_counts(counts, cfg.axis_name)
        return jnp.sum(recv)[None] + x[:1].astype(jnp.int32) * 0

    def payload_collective_kernel(x):
        me = jax.lax.axis_index(axes)
        buf = (
            me.astype(jnp.uint32) + jnp.arange(R * slot * words, dtype=jnp.uint32)
        ).reshape(R, slot, words)
        recv = X._a2a(buf, cfg.axis_name)
        return jnp.sum(recv, dtype=jnp.uint32)[None] + x[:1].astype(jnp.uint32) * 0

    def unmarshal_kernel(x):
        me = jax.lax.axis_index(axes)
        buf = (
            me.astype(jnp.uint32) + jnp.arange(R * slot * words, dtype=jnp.uint32)
        ).reshape(R, slot, words)
        counts = jnp.minimum(
            ((me + jnp.arange(R)) % jnp.int32(slot)).astype(jnp.int32), cap // R
        )
        out, new_count, _drops = X._compact_blocks(
            buf, counts, cap, use_pallas=cfg.use_pallas
        )
        return jnp.sum(out, dtype=jnp.uint32)[None] + (
            new_count * 0 + x[:1].astype(jnp.int32) * 0
        ).astype(jnp.uint32)

    return (
        ("marshal", marshal_kernel),
        ("count_collective", count_collective_kernel),
        ("payload_collective", payload_collective_kernel),
        ("unmarshal", unmarshal_kernel),
    )


def _pipelined_phases(cfg, n_emit, cap, proto) -> Tuple:
    """Per-shard slices of the padded round (the overlap law's schedule):
    shard k marshals / ships / compacts slot rows [k·chunk, (k+1)·chunk),
    via the same ``stages.padded_send_shard`` / ``stages.compact_shard``
    primitives the pipelined exchange composes."""
    from repro.core import exchange as X
    from repro.core import stages as ST
    from repro.core import types as T
    from repro.core.forwarding import flatten_axis_names

    R, slot, S = cfg.num_ranks, cfg.peer_capacity, cfg.pipeline_shards
    words = T.pack_spec(proto).total_words
    axes = flatten_axis_names(cfg.axis_name)
    setup = _setup(cfg, n_emit, cap, proto)
    chunk = slot // S  # config law: pipeline_shards divides peer_capacity
    out = []
    for k in range(S):
        def marshal_shard(x, k=k):
            me = jax.lax.axis_index(axes)
            q = setup(me)
            packed, _spec = T.pack_payload(q.items)
            plan = _marshal_plan(cfg, q)
            send = ST.padded_send_shard(
                packed, plan["perm"], plan["counts"], num_ranks=R,
                peer_capacity=slot, shards=S, k=k,
                marshal=cfg.marshal, dest_clean=plan["dest_clean"],
                dest_rank=plan["dest_rank"], use_pallas=cfg.use_pallas,
            )
            return (jnp.sum(send, dtype=jnp.uint32)[None]
                    + x[:1].astype(jnp.uint32) * 0)

        def payload_shard(x):
            me = jax.lax.axis_index(axes)
            buf = (
                me.astype(jnp.uint32)
                + jnp.arange(R * chunk * words, dtype=jnp.uint32)
            ).reshape(R, chunk, words)
            recv = X._a2a(buf, cfg.axis_name)
            return (jnp.sum(recv, dtype=jnp.uint32)[None]
                    + x[:1].astype(jnp.uint32) * 0)

        def unmarshal_shard(x, k=k):
            me = jax.lax.axis_index(axes)
            buf = (
                me.astype(jnp.uint32)
                + jnp.arange(R * chunk * words, dtype=jnp.uint32)
            ).reshape(R, chunk, words)
            counts = jnp.minimum(
                ((me + jnp.arange(R)) % jnp.int32(slot)).astype(jnp.int32),
                cap // R,
            )
            acc = jnp.zeros((cap, words), jnp.uint32)
            out_q = ST.compact_shard(
                acc, buf, counts, cap, row_offset=k * chunk
            )
            return (jnp.sum(out_q, dtype=jnp.uint32)[None]
                    + x[:1].astype(jnp.uint32) * 0)

        out += [
            (f"shard{k}_marshal", marshal_shard),
            (f"shard{k}_payload_collective", payload_shard),
            (f"shard{k}_unmarshal", unmarshal_shard),
        ]
    return tuple(out)


def _hierarchical_phases(cfg, n_emit, cap, proto) -> Tuple:
    """Per-tier marshal/count/payload phases of the N-level route, each on
    its own mesh axis with that tier's (extent, segment-capacity) layout,
    plus the final receive-side compaction."""
    from repro.core import exchange as X
    from repro.core import types as T
    from repro.core.forwarding import flatten_axis_names

    level_sizes = tuple(int(a) for a in cfg.level_sizes)
    level_caps = tuple(int(c) for c in cfg.level_capacities)
    words = T.pack_spec(proto).total_words
    axes = flatten_axis_names(cfg.axis_name)
    out = []
    tiers = [l for l in reversed(range(len(level_sizes))) if level_sizes[l] > 1]
    for l in tiers:
        A, S = level_sizes[l], level_caps[l]
        ax = cfg.axis_name[l]

        def marshal_tier(x, A=A, S=S):
            # the tier's send-side pass: A sub-segments into (A, S) slots —
            # same primitive as the flat marshal at the tier's shape
            me = jax.lax.axis_index(axes)
            buf = (
                me.astype(jnp.uint32)
                + jnp.arange(max(n_emit, A * S) * words, dtype=jnp.uint32)
            ).reshape(max(n_emit, A * S), words)
            cnt = ((me + jnp.arange(A)) % jnp.int32(S)).astype(jnp.int32)
            send = X.padded_send_buffer(
                buf, jnp.arange(buf.shape[0], dtype=jnp.int32), cnt,
                num_ranks=A, peer_capacity=S, use_pallas=cfg.use_pallas,
            )
            return (jnp.sum(send, dtype=jnp.uint32)[None]
                    + x[:1].astype(jnp.uint32) * 0)

        def count_tier(x, A=A, S=S, ax=ax):
            me = jax.lax.axis_index(axes)
            counts = ((me + jnp.arange(A)) % jnp.int32(S)).astype(jnp.int32)
            recv = X.exchange_counts(counts, ax)
            return jnp.sum(recv)[None] + x[:1].astype(jnp.int32) * 0

        def payload_tier(x, A=A, S=S, ax=ax):
            me = jax.lax.axis_index(axes)
            buf = (
                me.astype(jnp.uint32)
                + jnp.arange(A * S * words, dtype=jnp.uint32)
            ).reshape(A, S, words)
            recv = X._a2a(buf, ax)
            return (jnp.sum(recv, dtype=jnp.uint32)[None]
                    + x[:1].astype(jnp.uint32) * 0)

        out += [
            (f"tier{l}_marshal", marshal_tier),
            (f"tier{l}_count_collective", count_tier),
            (f"tier{l}_payload_collective", payload_tier),
        ]
    A, S = level_sizes[tiers[-1]], level_caps[tiers[-1]]

    def unmarshal_kernel(x, A=A, S=S):
        me = jax.lax.axis_index(axes)
        buf = (
            me.astype(jnp.uint32) + jnp.arange(A * S * words, dtype=jnp.uint32)
        ).reshape(A, S, words)
        counts = jnp.minimum(
            ((me + jnp.arange(A)) % jnp.int32(S)).astype(jnp.int32), cap // A
        )
        out_q, new_count, _drops = X._compact_blocks(
            buf, counts, cap, use_pallas=cfg.use_pallas
        )
        return jnp.sum(out_q, dtype=jnp.uint32)[None] + (
            new_count * 0 + x[:1].astype(jnp.int32) * 0
        ).astype(jnp.uint32)

    out.append(("unmarshal", unmarshal_kernel))
    return tuple(out)


def _ragged_phases(cfg, n_emit, cap, proto) -> Tuple:
    from repro.core import exchange as X
    from repro.core import stages as ST
    from repro.core import types as T
    from repro.core.forwarding import flatten_axis_names

    R = cfg.num_ranks
    words = T.pack_spec(proto).total_words
    axes = flatten_axis_names(cfg.axis_name)
    setup = _setup(cfg, n_emit, cap, proto)

    def marshal_kernel(x):
        # ragged send side: the destination sort IS the marshal (rows ship
        # contiguously per segment, no slot padding)
        from repro.core import sorting as S

        me = jax.lax.axis_index(axes)
        q = setup(me)
        packed, _spec = T.pack_payload(q.items)
        perm, _d, _counts = S.sort_permutation(
            q.dest, q.count, R, method=cfg.sort_method
        )
        send = jnp.take(packed, perm, axis=0)
        return jnp.sum(send, dtype=jnp.uint32)[None] + x[:1].astype(jnp.uint32) * 0

    def count_collective_kernel(x):
        # the one-all_gather control plane: count matrix + replicated
        # per-rank ragged layout derivation (clamps, landing offsets)
        me = jax.lax.axis_index(axes)
        counts = ((me + jnp.arange(R)) % jnp.int32(max(n_emit // R, 1))).astype(
            jnp.int32
        )
        cnt = X.exchange_count_matrix(counts, cfg.axis_name)
        send_sizes, output_offsets, recv_sizes = ST.ragged_control_plane(
            cnt, me, cap
        )
        return (jnp.sum(send_sizes) + jnp.sum(output_offsets)
                + jnp.sum(recv_sizes))[None] + x[:1].astype(jnp.int32) * 0

    phases = [
        ("marshal", marshal_kernel),
        ("count_collective", count_collective_kernel),
    ]
    if compat.HAS_RAGGED_ALL_TO_ALL:
        def payload_collective_kernel(x):
            me = jax.lax.axis_index(axes)
            n = max(n_emit, R)
            buf = (
                me.astype(jnp.uint32) + jnp.arange(n * words, dtype=jnp.uint32)
            ).reshape(n, words)
            seg = jnp.full((R,), n // R, jnp.int32)
            off = jnp.cumsum(seg) - seg
            recv = compat.ragged_all_to_all(
                buf, jnp.zeros_like(buf),
                input_offsets=off, send_sizes=seg,
                output_offsets=off, recv_sizes=seg,
                axis_name=cfg.axis_name,
            )
            return (jnp.sum(recv, dtype=jnp.uint32)[None]
                    + x[:1].astype(jnp.uint32) * 0)

        phases.append(("payload_collective", payload_collective_kernel))
    return tuple(phases)


# ----------------------------------------------------------- timeline view
def tier_of_phase(key: str) -> int:
    """Tier index encoded in a phase key (``tier2_marshal`` → 2; flat and
    shard keys → 0)."""
    if key.startswith("tier"):
        return int(key[4:].split("_", 1)[0])
    return 0


def to_perfetto(
    phase_us: Dict[str, float], *, num_ranks: int, tag: str = "round",
    t0_us: float = 0.0,
) -> Dict[str, Any]:
    """Measured phase durations → a merged multi-rank Perfetto timeline:
    every rank runs the same SPMD program, so each rank's process track
    (``pid = rank``) carries the phase sequence laid end to end, on the
    thread track of the phase's tier (``tid = tier``).  Compose with a host
    ``obs.trace`` export by concatenating ``traceEvents``."""
    from repro.obs import trace as OT

    events = []
    for rank in range(num_ranks):
        t = t0_us
        for key, us in phase_us.items():
            events.append({
                "name": f"{tag}:{key}", "cat": OT.CAT_PHASE, "ph": "X",
                "ts": t, "dur": float(us), "rank": rank,
                "tier": tier_of_phase(key), "args": {"us": float(us)},
            })
            t += float(us)
    return OT.to_perfetto(events)

"""Host-side span tracer — the timeline half of the observation law.

Every drive entry point (``RafiContext.run_until_done``, the segmented
``recovery`` loop, ``tune.autotune_forward``, ``rebalance``,
``deliver_by_cycling``, the chaos driver) records typed, wall-clock-stamped
events into the installed :class:`Tracer`: burst and segment boundaries,
checkpoint saves with their manifest digests, autotune re-plans with
old→new capacities, health-mask transitions, chaos fault injections.

The tracer is HOST code and nothing else: it never touches a traced value
beyond reading back outputs the drive already returns, so a traced+metered
program lowers BIT-identically to the untraced one (guarded in
``tests/test_collective_budget.py``) — observation adds zero collectives by
construction, not by audit.

Two ways to turn it on:

* explicitly — ``with trace.capture() as tr: ...; tr.save(path)``;
* ambiently — set ``RAFI_TRACE=1`` (record only) or ``RAFI_TRACE=/path.json``
  (record + flush the Perfetto JSON there at process exit), mirroring the
  ``RAFI_PALLAS_INTERPRET`` CI toggle.  The env tracer is installed lazily
  on the first ``enabled()`` check so merely importing repro costs nothing.

Export is Chrome/Perfetto ``trace_event`` JSON (``chrome://tracing``,
https://ui.perfetto.dev): spans are complete ``"X"`` events, instants are
``"i"``; the track layout (``pid``/``tid``) is one process track per rank
and one thread track per tier — host-only spans live on rank track 0,
tier track 0.  ``obs.phases`` produces per-rank / per-tier device phase
timings in the same layout so both merge into one timeline.

This module imports nothing from the rest of ``repro`` — core modules hook
it at import time without cycles.
"""
from __future__ import annotations

import atexit
import collections
import contextlib
import json
import os
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "Tracer",
    "Span",
    "capture",
    "current",
    "enabled",
    "event",
    "install",
    "span",
    "to_perfetto",
    "uninstall",
]

ENV_VAR = "RAFI_TRACE"

# Event-type vocabulary (the ``cat`` field) — one name per law so the
# analyzer and the Perfetto UI can filter per subsystem.
CAT_DRIVE = "drive"          # run_until_done bursts, segment boundaries
CAT_RECOVERY = "recovery"    # checkpoint saves, resumes, preemptions
CAT_TUNE = "tune"            # autotune re-plans
CAT_HEALTH = "health"        # health-mask transitions
CAT_CHAOS = "chaos"          # scenario runs, fault injections
CAT_ROUTE = "route"          # rebalance / cycling trace-time records
CAT_PHASE = "phase"          # device per-phase timings (obs.phases)


def _now_us() -> float:
    return time.perf_counter() * 1e6


class Span:
    """An open span — ``set(**attrs)`` attaches results before it closes."""

    __slots__ = ("name", "cat", "t0", "args", "rank", "tier", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 rank: int, tier: int, args: Dict[str, Any]):
        self._tracer = tracer
        self.name, self.cat = name, cat
        self.rank, self.tier = rank, tier
        self.args = dict(args)
        self.t0 = _now_us()

    def set(self, **attrs: Any) -> "Span":
        self.args.update(attrs)
        return self

    def close(self) -> None:
        self._tracer._record(
            name=self.name, cat=self.cat, ph="X", ts=self.t0,
            dur=_now_us() - self.t0, rank=self.rank, tier=self.tier,
            args=self.args,
        )


class Tracer:
    """Bounded in-memory event recorder (oldest events evicted past
    ``max_events`` so an ambient tracer can ride a long benchmark run)."""

    def __init__(self, max_events: int = 65536):
        self.events: collections.deque = collections.deque(maxlen=max_events)
        self.t_start = _now_us()

    # -- recording -------------------------------------------------------
    def _record(self, **ev: Any) -> None:
        self.events.append(ev)

    def event(self, name: str, cat: str = CAT_DRIVE, *,
              rank: int = 0, tier: int = 0, **args: Any) -> None:
        """One instant event (``ph="i"``)."""
        self._record(name=name, cat=cat, ph="i", ts=_now_us(), dur=0.0,
                     rank=rank, tier=tier, args=args)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = CAT_DRIVE, *,
             rank: int = 0, tier: int = 0, **args: Any):
        """Timed span; yields the open :class:`Span` for ``.set(...)``."""
        sp = Span(self, name, cat, rank, tier, args)
        try:
            yield sp
        finally:
            sp.close()

    def phase_event(self, name: str, *, ts_us: float, dur_us: float,
                    rank: int = 0, tier: int = 0, **args: Any) -> None:
        """A device phase timing placed explicitly on the (rank, tier)
        track — how ``obs.phases`` merges its measured timeline in."""
        self._record(name=name, cat=CAT_PHASE, ph="X", ts=ts_us, dur=dur_us,
                     rank=rank, tier=tier, args=args)

    # -- views -----------------------------------------------------------
    def select(self, cat: Optional[str] = None,
               name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [
            e for e in self.events
            if (cat is None or e["cat"] == cat)
            and (name is None or e["name"] == name)
        ]

    def to_perfetto(self) -> Dict[str, Any]:
        return to_perfetto(list(self.events), t0=self.t_start)

    def save(self, path: str) -> str:
        """Write the Perfetto ``trace_event`` JSON; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_perfetto(), f)
        return path


def to_perfetto(events: List[Dict[str, Any]], *, t0: float = 0.0) -> Dict[str, Any]:
    """Events → Chrome/Perfetto ``trace_event`` JSON.  Track layout: one
    process per rank (``pid = rank``), one thread per tier (``tid = tier``);
    metadata events name each so the UI shows ``rank N`` / ``tier L``."""
    out: List[Dict[str, Any]] = []
    tracks = set()
    for e in events:
        tracks.add((int(e.get("rank", 0)), int(e.get("tier", 0))))
        rec = {
            "name": e["name"],
            "cat": e["cat"],
            "ph": e["ph"],
            "ts": round(float(e["ts"]) - t0, 3),
            "pid": int(e.get("rank", 0)),
            "tid": int(e.get("tier", 0)),
            "args": {k: _jsonable(v) for k, v in (e.get("args") or {}).items()},
        }
        if e["ph"] == "X":
            rec["dur"] = round(float(e.get("dur", 0.0)), 3)
        if e["ph"] == "i":
            rec["s"] = "t"  # thread-scoped instant
        out.append(rec)
    for rank, tier in sorted(tracks):
        out.append({"name": "process_name", "ph": "M", "pid": rank, "tid": 0,
                    "args": {"name": f"rank {rank}"}})
        out.append({"name": "thread_name", "ph": "M", "pid": rank, "tid": tier,
                    "args": {"name": f"tier {tier}"}})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def _jsonable(v: Any) -> Any:
    """Host attrs may arrive as numpy/jax scalars or small arrays."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    try:
        import numpy as np

        a = np.asarray(v)
        if a.ndim == 0:
            return a.item()
        return a.tolist()
    except Exception:  # noqa: BLE001 — attrs are best-effort labels
        return str(v)


# -------------------------------------------------- installation plumbing
_CURRENT: Optional[Tracer] = None
_ENV_CHECKED = False


def install(tracer: Optional[Tracer] = None) -> Tracer:
    """Make ``tracer`` (a fresh one if ``None``) the ambient tracer."""
    global _CURRENT
    _CURRENT = tracer if tracer is not None else Tracer()
    return _CURRENT


def uninstall() -> None:
    global _CURRENT
    _CURRENT = None


def _check_env() -> None:
    """Lazily honour ``RAFI_TRACE``: any non-empty value installs an ambient
    tracer; a path-looking value ("/" or .json) also flushes there at exit."""
    global _ENV_CHECKED
    if _ENV_CHECKED:
        return
    _ENV_CHECKED = True
    val = os.environ.get(ENV_VAR, "")
    if not val or val == "0":
        return
    tr = install()
    if "/" in val or val.endswith(".json"):
        atexit.register(lambda: tr.save(val))


def current() -> Optional[Tracer]:
    """The ambient tracer, or ``None`` (env toggle consulted lazily)."""
    if _CURRENT is None:
        _check_env()
    return _CURRENT


def enabled() -> bool:
    return current() is not None


@contextlib.contextmanager
def capture(max_events: int = 65536):
    """Install a fresh tracer for the block; restore the previous after."""
    prev = _CURRENT
    tr = install(Tracer(max_events))
    try:
        yield tr
    finally:
        install(prev) if prev is not None else uninstall()


# No-op-when-disabled conveniences — what the drive entry points call.
def event(name: str, cat: str = CAT_DRIVE, **kw: Any) -> None:
    tr = current()
    if tr is not None:
        tr.event(name, cat, **kw)


@contextlib.contextmanager
def span(name: str, cat: str = CAT_DRIVE, **kw: Any):
    """Span on the ambient tracer; yields the :class:`Span` or a no-op
    stand-in when tracing is off (callers ``sp.set(...)`` unconditionally)."""
    tr = current()
    if tr is None:
        yield _NOOP_SPAN
        return
    with tr.span(name, cat, **kw) as sp:
        yield sp


class _NoopSpan:
    __slots__ = ()

    def set(self, **_attrs: Any) -> "_NoopSpan":
        return self


_NOOP_SPAN = _NoopSpan()

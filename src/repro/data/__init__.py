from repro.data.pipeline import SyntheticLM, make_batch_iterator  # noqa: F401

"""Deterministic synthetic data pipeline.

Design constraints from the fault-tolerance story:
  * batches are a pure function of (seed, step) — restart from a checkpoint
    at step k reproduces the exact remaining stream, no iterator state to
    persist;
  * host-sharded: each process materializes only its slice of the global
    batch (data-parallel loading); this container is single-process but the
    slicing logic is exercised through the ``process_index``/``count`` args;
  * double-buffered prefetch thread so host generation overlaps device
    compute.

The synthetic LM task is structured (a noisy integer-sequence grammar), not
uniform noise, so cross-entropy has a learnable signal and the end-to-end
example can show a falling loss curve.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    """Structured synthetic token stream: piecewise arithmetic sequences with
    a vocabulary-dependent stride — next-token is predictable within a
    segment, so CE can drop well below ln(V)."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, *, seed: int = 0,
                 process_index: int = 0, process_count: int = 1):
        assert global_batch % process_count == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // process_count
        self.seed = seed
        self.pidx = process_index

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.pidx])
        )
        b, s = self.local_batch, self.seq
        starts = rng.integers(0, self.vocab, (b, 1))
        strides = rng.integers(1, 8, (b, 1))
        toks = (starts + strides * np.arange(s + 1)[None, :]) % self.vocab
        noise = rng.random((b, s + 1)) < 0.05
        toks = np.where(noise, rng.integers(0, self.vocab, (b, s + 1)), toks)
        return {
            "tokens": toks[:, :s].astype(np.int32),
            "labels": toks[:, 1 : s + 1].astype(np.int32),
        }


def make_batch_iterator(
    ds: SyntheticLM, start_step: int = 0, *, prefetch: int = 2
) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetching iterator starting at ``start_step``."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    def gen():
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    return gen()

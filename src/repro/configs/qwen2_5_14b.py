"""qwen2.5-14b — dense GQA decoder with QKV bias [hf:Qwen/Qwen2.5-14B; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", kind="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    pattern=("global",), source="hf:Qwen/Qwen2.5-14B", fsdp=True, microbatches=2,
)

SMOKE = ModelConfig(
    name="qwen2.5-14b-smoke", kind="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, qkv_bias=True, rope_theta=1e6,
    pattern=("global",), dtype="float32", remat=False,
)

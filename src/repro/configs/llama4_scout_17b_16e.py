"""llama4-scout-17b-16e — MoE 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  All layers MoE
(simplification of the interleaved dense/MoE stack — DESIGN.md).  The MoE
dispatch plane is the paper's forwarding technique (rafi_ep)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e", kind="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048, rope_theta=5e5,
    num_experts=16, top_k=1, moe_dispatch="rafi_ep",
    pattern=("moe",), source="hf:meta-llama/Llama-4-Scout-17B-16E", fsdp=True, microbatches=4,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", kind="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, num_experts=4, top_k=1, moe_dispatch="rafi_ep",
    pattern=("moe",), dtype="float32", remat=False,
)

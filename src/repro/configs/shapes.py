"""The assigned input-shape suite (shared by all 10 LM architectures).

  train_4k      seq 4,096  × global batch 256   → lowers train_step
  prefill_32k   seq 32,768 × global batch 32    → lowers prefill
  decode_32k    KV ctx 32,768 × global batch 128 → lowers serve_step (1 token)
  long_500k     KV ctx 524,288 × global batch 1  → serve_step; SUB-QUADRATIC
                archs only (rwkv6, recurrentgemma) — see DESIGN.md
                §Arch-applicability for the skip rationale per arch.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

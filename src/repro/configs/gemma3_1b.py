"""gemma3-1b — dense, 5:1 local:global attention, 128k-capable
[hf:google/gemma-3-1b-pt; unverified]. Local window 512, global layers use
the 1e6 RoPE base, local layers 1e4 (see models.transformer._theta_for).
long_500k is SKIPPED: the global layers are full attention (not
sub-quadratic) — DESIGN.md §Arch-applicability."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", kind="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144, rope_theta=1e6, window=512,
    pattern=("local", "local", "local", "local", "local", "global"),
    tie_embeddings=True, scale_embed=True, act="gelu",
    source="hf:google/gemma-3-1b-pt",
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke", kind="dense",
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512, rope_theta=1e6, window=8,
    pattern=("local", "local", "local", "local", "local", "global"),
    tie_embeddings=True, scale_embed=True, act="gelu",
    dtype="float32", remat=False,
)

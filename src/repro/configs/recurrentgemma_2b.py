"""recurrentgemma-2b — RG-LRU + local attention, 1 attention : 2 recurrent
[arXiv:2402.19427; hf].  Runs long_500k (bounded-window attention +
O(1)-state recurrence)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", kind="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000, rope_theta=1e4, window=2048,
    pattern=("recurrent", "recurrent", "local"),
    tie_embeddings=True, scale_embed=True, act="gelu",
    source="arXiv:2402.19427",
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", kind="hybrid",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512, window=8,
    pattern=("recurrent", "recurrent", "local"),
    tie_embeddings=True, scale_embed=True, act="gelu",
    dtype="float32", remat=False,
)

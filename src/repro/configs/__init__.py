from repro.configs.registry import ARCHS, get_config, get_smoke_config, input_specs, shape_suite  # noqa: F401

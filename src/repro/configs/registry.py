"""Architecture registry + per-(arch × shape) input specs for the dry-run.

``input_specs(arch, shape, mesh)`` returns ShapeDtypeStructs for every model
input — weak-type-correct, shardable, zero allocation — plus which step
function (train / prefill / decode) the shape lowers, and whether the cell
is skipped (with the reason), per DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models.common import ModelConfig

_MODULES = {
    "qwen2-7b": "qwen2_7b",
    "qwen2.5-14b": "qwen2_5_14b",
    "glm4-9b": "glm4_9b",
    "gemma3-1b": "gemma3_1b",
    "llama4-scout-17b-16e": "llama4_scout_17b_16e",
    "dbrx-132b": "dbrx_132b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "rwkv6-3b": "rwkv6_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCHS = tuple(_MODULES)

# archs that can run 524k-token decode (sub-quadratic sequence mixing)
SUB_QUADRATIC = ("rwkv6-3b", "recurrentgemma-2b")


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def shape_suite(arch: str):
    """(shape_name -> ShapeSpec | skip reason) for one architecture."""
    out: Dict[str, Any] = {}
    for name, spec in SHAPES.items():
        if name == "long_500k" and arch not in SUB_QUADRATIC:
            out[name] = (
                "SKIP: full-range attention layers are quadratic at 524k "
                "context (DESIGN.md §Arch-applicability)"
            )
        else:
            out[name] = spec
    return out


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: ShapeSpec
    step: str                 # train | prefill | decode
    batch: Dict[str, Any]     # ShapeDtypeStructs for step inputs
    skip: Optional[str] = None


def input_specs(arch: str, shape_name: str, cfg: Optional[ModelConfig] = None) -> Cell:
    """ShapeDtypeStruct stand-ins for every input of the (arch × shape) cell."""
    cfg = cfg or get_config(arch)
    suite = shape_suite(arch)
    entry = suite[shape_name]
    if isinstance(entry, str):
        return Cell(arch, SHAPES[shape_name], "skip", {}, skip=entry)
    spec: ShapeSpec = entry
    b, s = spec.global_batch, spec.seq_len
    i32 = jnp.int32

    if spec.step == "train":
        if cfg.kind == "encdec":
            batch = {
                "frames": jax.ShapeDtypeStruct((b, s // 8, cfg.d_model), cfg.jdtype),
                "tokens": jax.ShapeDtypeStruct((b, s // 8), i32),
            }
        elif cfg.frontend == "vision":
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.jdtype),
                "labels": jax.ShapeDtypeStruct((b, s - 1), i32),
            }
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return Cell(arch, spec, "train", batch)

    if spec.step == "prefill":
        if cfg.kind == "encdec":
            batch = {
                "frames": jax.ShapeDtypeStruct((b, s // 8, cfg.d_model), cfg.jdtype),
                "tokens": jax.ShapeDtypeStruct((b, s // 8), i32),
            }
        elif cfg.frontend == "vision":
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.jdtype),
            }
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return Cell(arch, spec, "prefill", batch)

    # decode: one new token against a seq_len-deep cache
    batch = {"token": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.kind == "encdec":
        batch["memory"] = jax.ShapeDtypeStruct((b, 1024, cfg.d_model), cfg.jdtype)
    return Cell(arch, spec, "decode", batch)


def batch_shardings(cell: Cell, mesh, cfg: ModelConfig):
    """NamedShardings for the cell's batch inputs (batch dim over data axes)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    baxes = tuple(a for a in mesh.axis_names if a != "model")

    def shard_first(_path_unused, s):
        return NamedSharding(mesh, P(baxes, *([None] * (len(s.shape) - 1))))

    return {k: shard_first(k, v) for k, v in cell.batch.items()}

"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596; hf].
Audio frontend is a STUB: the encoder consumes precomputed frame embeddings
(B, T, D) from input_specs().  12L encoder + 12L decoder."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", kind="encdec",
    num_layers=12, encoder_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206, rope_theta=1e4, frontend="audio",
    pattern=("global",), source="arXiv:2308.11596", dp_over_model=True,
)

SMOKE = ModelConfig(
    name="seamless-smoke", kind="encdec",
    num_layers=2, encoder_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, frontend="audio",
    pattern=("global",), dtype="float32", remat=False,
)

"""rwkv6-3b "Finch" — attention-free, data-dependent decay
[arXiv:2404.05892; hf].  Runs long_500k (O(1)-state decode)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", kind="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=0, head_dim=64,
    d_ff=8960, vocab_size=65536,
    pattern=("rwkv",), source="arXiv:2404.05892",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", kind="ssm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=0, head_dim=16,
    d_ff=128, vocab_size=256, pattern=("rwkv",), dtype="float32", remat=False,
)

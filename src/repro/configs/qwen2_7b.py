"""qwen2-7b — dense GQA decoder with QKV bias [arXiv:2407.10671; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", kind="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    pattern=("global",), source="arXiv:2407.10671; hf", fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke", kind="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, qkv_bias=True, rope_theta=1e6,
    pattern=("global",), dtype="float32", remat=False,
)

"""dbrx-132b — fine-grained MoE, 16 experts top-4
[hf:databricks/dbrx-base; unverified].  top-4 ⇒ each token emits FOUR work
items into the forwarding plane (§3.3: "threads can emit more than one")."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", kind="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352, rope_theta=5e5,
    num_experts=16, top_k=4, moe_dispatch="rafi_ep",
    pattern=("moe",), source="hf:databricks/dbrx-base", fsdp=True, microbatches=4,
)

SMOKE = ModelConfig(
    name="dbrx-smoke", kind="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, num_experts=4, top_k=2, moe_dispatch="rafi_ep",
    pattern=("moe",), dtype="float32", remat=False,
)

"""glm4-9b — dense GQA decoder, RoPE [hf:THUDM/glm-4-9b; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", kind="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=151552, qkv_bias=True, rope_theta=1e4,
    pattern=("global",), source="hf:THUDM/glm-4-9b", fsdp=True, microbatches=2,
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke", kind="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, qkv_bias=True,
    pattern=("global",), dtype="float32", remat=False,
)

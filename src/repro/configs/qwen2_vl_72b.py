"""qwen2-vl-72b — VLM backbone with M-RoPE [arXiv:2409.12191; hf].
The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings alongside text tokens; the backbone consumes
embeddings directly (frontend="vision")."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", kind="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    rope_kind="mrope", frontend="vision", fsdp=True, microbatches=4,
    pattern=("global",), source="arXiv:2409.12191",
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", kind="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, qkv_bias=True, rope_kind="mrope",
    frontend="vision", pattern=("global",), dtype="float32", remat=False,
)

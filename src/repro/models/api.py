"""Model API: build a config into init / loss / prefill / decode functions.

All entry points are pure functions over explicit params (and caches), ready
for ``jax.jit(..., in_shardings=...)`` with the spec trees provided here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import encdec as ED
from repro.models import transformer as TF
from repro.models.common import (
    ModelConfig,
    abstract_params,
    cross_entropy_loss,
    init_params,
    param_specs,
)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    defs: Dict[str, Any]

    # ---------------------------------------------------------- parameters
    def init(self, key):
        return init_params(self.defs, key, self.cfg.jdtype)

    def abstract(self):
        return abstract_params(self.defs, self.cfg.jdtype)

    def specs(self, *, serve: bool = False):
        """Parameter PartitionSpecs.  ``serve=True`` drops FSDP: inference
        wants weights resident (model-axis sharded), not gathered per layer —
        ZeRO-style data-axis sharding only pays off against optimizer state,
        which serving doesn't have."""
        cfg = self.cfg
        if serve and cfg.fsdp:
            cfg = dataclasses.replace(cfg, fsdp=False)
        return param_specs(self.defs, cfg)

    def param_count(self) -> int:
        import numpy as np

        return int(
            sum(np.prod(l.shape, dtype=np.int64) for l in jax.tree.leaves(self.abstract()))
        )

    # --------------------------------------------------------------- steps
    def loss_fn(self, mesh=None) -> Callable:
        cfg = self.cfg

        if cfg.kind == "encdec":
            def loss(params, batch):
                memory = ED.encode(params, batch["frames"], cfg)
                logits, _ = ED.decode(params, batch["tokens"], memory, cfg)
                return cross_entropy_loss(
                    logits[:, :-1], batch["tokens"][:, 1:], vocab=cfg.vocab_size
                )

            return loss

        def loss(params, batch):
            logits, _, drops = TF.forward(
                params, batch["tokens"], cfg, mesh=mesh,
                frontend_embeds=batch.get("embeds"),
            )
            labels = batch["labels"] if "labels" in batch else batch["tokens"][:, 1:]
            if logits.shape[1] != labels.shape[1]:
                logits = logits[:, : labels.shape[1]]
            return cross_entropy_loss(logits, labels, vocab=cfg.vocab_size)

        return loss

    def prefill_fn(self, mesh=None) -> Callable:
        cfg = self.cfg

        if cfg.kind == "encdec":
            def prefill(params, batch):
                memory = ED.encode(params, batch["frames"], cfg)
                logits, _ = ED.decode(params, batch["tokens"], memory, cfg)
                return logits[:, -1]

            return prefill

        def prefill(params, batch):
            logits, _, _ = TF.forward(
                params, batch["tokens"], cfg, mesh=mesh,
                frontend_embeds=batch.get("embeds"),
            )
            return logits[:, -1]

        return prefill

    def decode_fn(self, mesh=None) -> Callable:
        """One token step with caches: (params, token (B,1), caches) →
        (logits (B,V), new_caches)."""
        cfg = self.cfg

        if cfg.kind == "encdec":
            def step(params, token, caches, memory):
                positions = caches["pos"][0][:, None].astype(jnp.int32)  # (B, 1)
                logits, new_caches = ED.decode(
                    params, token, memory, cfg, caches=caches, positions=positions
                )
                return logits[:, -1], new_caches

            return step

        def step(params, token, caches):
            pos0 = _first_cache_pos(caches, token.shape[0])
            positions = pos0[:, None].astype(jnp.int32)  # (B, 1) per-row depth
            logits, new_caches, _ = TF.forward(
                params, token, cfg, mesh=mesh, caches=caches, positions=positions
            )
            return logits[:, -1], new_caches

        return step

    # --------------------------------------------------------------- caches
    def init_caches(self, batch: int, max_len: int):
        if self.cfg.kind == "encdec":
            return ED.init_dec_caches(self.cfg, batch, max_len)
        return TF.init_caches(self.cfg, batch, max_len)

    def cache_specs(self):
        if self.cfg.kind == "encdec":
            return ED.dec_cache_specs(self.cfg)
        return TF.cache_specs_tree(self.cfg)


def _first_cache_pos(caches, batch: int) -> jax.Array:
    """(B,) current decode positions from any attention cache (all agree)."""
    for key, c in caches["blocks"].items():
        if isinstance(c, dict) and "pos" in c:
            return c["pos"][0]
    for key, c in caches["tail"].items():
        if isinstance(c, dict) and "pos" in c:
            return c["pos"]
    return jnp.zeros((batch,), jnp.int32)  # pure-SSM models: position-free


def build_model(cfg: ModelConfig) -> Model:
    if cfg.kind == "encdec":
        return Model(cfg, ED.encdec_defs(cfg))
    return Model(cfg, TF.model_defs(cfg))

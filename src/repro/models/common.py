"""Shared model machinery: config, params, sharding rules, norms, MLPs.

Everything is functional: a model is (init_fn, apply_fn) over an explicit
params pytree of jnp arrays.  Sharding is expressed as PartitionSpec trees
produced from the *same* path rules used by both init and the dry-run, so
``jax.jit(..., in_shardings=...)`` sees a consistent layout:

  * "model"-axis tensor parallelism: attention heads, FFN hidden, vocab;
  * optional FSDP: the non-TP dim of every large parameter is additionally
    sharded over "data" (needed to fit the 72B configs; gathered per-layer by
    XLA at use);
  * MoE experts: sharded over "model" for expert parallelism (EP).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

DATA_AXIS = "data"
MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                     # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_kind: str = "full"       # full | mrope
    act: str = "silu"             # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = False
    window: int = 0               # local-attention window size
    pattern: Tuple[str, ...] = ("global",)  # repeating per-layer block kinds
    num_experts: int = 0
    top_k: int = 0
    moe_dispatch: str = "rafi_ep"  # rafi_ep (paper technique) | dense_tp
    capacity_factor: float = 1.25
    encoder_layers: int = 0
    frontend: str = "none"        # none | vision | audio (stub embeddings)
    scale_embed: bool = False     # gemma-style sqrt(d_model) embedding scale
    dtype: str = "bfloat16"
    fsdp: bool = False            # shard big params over data axis too
    remat: bool = True            # activation checkpoint each layer
    scan_unroll: bool = False     # fully unroll layer scans (cost probes)
    blocked_attention: bool = True  # online-softmax KV-blocked attention
                                    # (False = paper-faithful naive baseline)
    microbatches: int = 1         # gradient-accumulation splits of the batch
    dp_over_model: bool = False   # TP width policy: fold the model axis into
                                  # data parallelism (right call when d_model
                                  # is too small to amortize TP collectives)
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def layer_kind(self, i: int) -> str:
        return self.pattern[i % len(self.pattern)]


# --------------------------------------------------------------- parameters

def truncated_normal(key, shape, dtype, scale):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


class ParamDef:
    """Declarative parameter: shape + init scale + partition spec."""

    def __init__(self, shape, spec, *, scale=None, init="normal"):
        self.shape = tuple(int(s) for s in shape)
        self.spec = spec
        self.scale = scale
        self.init = init

    def make(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        scale = self.scale if self.scale is not None else 1.0 / np.sqrt(self.shape[0])
        return truncated_normal(key, self.shape, dtype, scale)


def _maybe_fsdp(spec: P, cfg: ModelConfig) -> P:
    """Apply the config's parallelism policy to a parameter spec:
    dp_over_model strips the model axis (params replicated, both mesh axes
    become data parallel); fsdp additionally shards the first free dim over
    "data" (ZeRO-3 style)."""
    if cfg.dp_over_model:
        spec = P(*[None if s == MODEL_AXIS else s for s in spec])
    if not cfg.fsdp:
        return spec
    parts = list(spec) + [None] * 8
    for i, s in enumerate(parts[: len(spec) if len(spec) else 1]):
        if s is None:
            parts[i] = DATA_AXIS
            return P(*parts[: len(spec)])
    return spec


def batch_axes(cfg: Optional[ModelConfig] = None):
    """Mesh axes carrying the batch dim of activations."""
    if cfg is not None and cfg.dp_over_model:
        return (DATA_AXIS, MODEL_AXIS)
    return DATA_AXIS


def init_params(defs: Dict[str, Any], key, dtype) -> Dict[str, Any]:
    """Materialize a (possibly nested) dict of ParamDefs."""
    flat = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(flat))
    it = iter(range(len(flat)))

    def make(d):
        return d.make(keys[next(it)], dtype)

    return jax.tree.map(make, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def param_specs(defs: Dict[str, Any], cfg: ModelConfig):
    """PartitionSpec tree matching init_params' output."""
    return jax.tree.map(
        lambda d: _maybe_fsdp(d.spec, cfg),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def abstract_params(defs: Dict[str, Any], dtype):
    """ShapeDtypeStruct tree (no allocation) — the dry-run path."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


# ------------------------------------------------------------------- layers

def rmsnorm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def dense(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def glu_mlp(x, wi, wg, wo, act: str):
    """Gated MLP (SwiGLU/GeGLU): down( act(gate(x)) * up(x) )."""
    a = jax.nn.silu(x @ wg) if act == "silu" else jax.nn.gelu(x @ wg)
    return (a * (x @ wi)) @ wo


def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    f = d_ff or cfg.d_ff
    d = cfg.d_model
    return {
        "wi": ParamDef((d, f), P(None, MODEL_AXIS)),
        "wg": ParamDef((d, f), P(None, MODEL_AXIS)),
        "wo": ParamDef((f, d), P(MODEL_AXIS, None), scale=1.0 / np.sqrt(f)),
    }


def shard(x, *spec):
    """with_sharding_constraint shortcut (no-op outside jit-with-mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def cross_entropy_loss(logits, labels, *, vocab: int):
    """Mean token CE in f32 (logits may be bf16)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)

"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE splits the head dimension into (temporal, height, width) sections and
rotates each with its own position stream; for the text backbone (vision
frontend stubbed per the assignment spec) all three streams carry the text
position, which makes M-RoPE numerically distinct from RoPE only in its
frequency layout — the structure the 72B config exercises.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

MROPE_SECTIONS = (16, 24, 24)  # qwen2-vl: t/h/w sections of head_dim/2


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_angles(positions, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (..., S) → cos/sin (..., S, head_dim/2)."""
    ang = positions[..., None].astype(jnp.float32) * rope_freqs(head_dim, theta)
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions, head_dim: int, theta: float):
    """M-RoPE: three position streams → per-section frequencies.

    positions: (..., S, 3) (t, h, w) — text-only inputs use the same value in
    all three streams."""
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    sizes = MROPE_SECTIONS
    if sum(sizes) != head_dim // 2:
        # scale sections proportionally for non-128 head dims
        total = head_dim // 2
        s0 = int(round(total * sizes[0] / sum(sizes)))
        s1 = int(round(total * sizes[1] / sum(sizes)))
        sizes = (s0, s1, total - s0 - s1)
    stream = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sizes)]
    )  # (hd/2,) which position stream drives each frequency
    pos = positions[..., stream]  # (..., S, hd/2)
    ang = pos.astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D) rotated pairwise; cos/sin (..., S, D/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)

"""GQA attention with global / local-window masks, KV caches, cross-attention.

Sharding: heads over the "model" axis (q heads and kv heads both divide the
axis for every assigned config), batch over "data".  Decode uses a static
(B, S_max, Hkv, Dh) cache updated with dynamic_update_slice at the current
position.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import rope as R
from repro.models.common import MODEL_AXIS, ModelConfig, ParamDef, batch_axes, shard


def attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h * hd), P(None, MODEL_AXIS)),
        "wk": ParamDef((d, kv * hd), P(None, MODEL_AXIS)),
        "wv": ParamDef((d, kv * hd), P(None, MODEL_AXIS)),
        "wo": ParamDef((h * hd, d), P(MODEL_AXIS, None), scale=1.0 / np.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        defs.update(
            bq=ParamDef((h * hd,), P(MODEL_AXIS), init="zeros"),
            bk=ParamDef((kv * hd,), P(MODEL_AXIS), init="zeros"),
            bv=ParamDef((kv * hd,), P(MODEL_AXIS), init="zeros"),
        )
    return defs


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _angles(cfg: ModelConfig, positions, theta=None):
    theta = theta or cfg.rope_theta
    if cfg.rope_kind == "mrope":
        if positions.ndim == 2:  # text-only: same position in all 3 streams
            positions = jnp.repeat(positions[..., None], 3, axis=-1)
        return R.mrope_angles(positions, cfg.head_dim, theta)
    return R.rope_angles(positions, cfg.head_dim, theta)


def _sdpa(q, k, v, mask, dtype):
    """q (B,S,H,D), k/v (B,T,Hkv,D) with GQA broadcast; mask (B,S,T) or (S,T).

    Reference (materializing) attention — used for decode (S == 1) and tiny
    sequences; long sequences go through :func:`_sdpa_blocked`."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, s, hkv, group, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / np.sqrt(dh)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, dh).astype(dtype)


BLOCK_KV = 1024


def _sdpa_blocked(q, k, v, dtype, *, causal: bool, window: int, block: int = BLOCK_KV):
    """Online-softmax attention, scanned over KV blocks — (S, T) is never
    materialized, which removes the S² f32 temps and the score all-gathers
    that dominated the baseline roofline (§Perf iter 1).

    q (B,S,H,D); k/v (B,T,Hkv,D); T % block == 0.  Accumulation is f32,
    operands stay bf16 on the MXU path.
    """
    b, s, h, dh = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    block = min(block, t)
    while t % block:
        block //= 2
    nb = t // block
    qg = q.reshape(b, s, hkv, g, dh)
    scale = 1.0 / np.sqrt(dh)

    kb = jnp.moveaxis(k.reshape(b, nb, block, hkv, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, block, hkv, dh), 1, 0)
    q_idx = jnp.arange(s)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, j0 = xs
        srow = jnp.einsum(
            "bskgd,btkd->bkgst", qg, kblk, preferred_element_type=jnp.float32
        ) * scale  # (b,hkv,g,s,block)
        kv_idx = j0 + jnp.arange(block)
        ok = jnp.ones((s, block), bool)
        if causal:
            ok &= kv_idx[None, :] <= q_idx[:, None]
        if window > 0:
            ok &= kv_idx[None, :] > q_idx[:, None] - window
        srow = jnp.where(ok[None, None, None], srow, -1e30)
        m_new = jnp.maximum(m, jnp.max(srow, axis=-1))
        p = jnp.exp(srow - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, s, dh), jnp.float32)
    j0s = jnp.arange(nb) * block
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, j0s))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (b,hkv,g,s,dh)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, dh).astype(dtype)


def causal_mask(s: int, window: int = 0) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window > 0:
        m &= j > i - window
    return m


def self_attention(
    params: Dict,
    x: jax.Array,                     # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: jax.Array,             # (B, S) or (B, S, 3) for mrope
    window: int = 0,
    theta: Optional[float] = None,
    cache: Optional[Dict] = None,     # {"k","v": (B,Smax,Hkv,Dh), "pos": ()}
) -> Tuple[jax.Array, Optional[Dict]]:
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    # constrain only the FLAT head×dim axis (always divisible by the model
    # axis) — per-head constraints on small kv-head counts provoked GSPMD
    # "involuntary full rematerialization" resharding (§Perf iter 1)
    if cfg.dp_over_model:
        q = shard(q, batch_axes(cfg), None, None)
        k = shard(k, batch_axes(cfg), None, None)
        v = shard(v, batch_axes(cfg), None, None)
    else:
        q = shard(q, "data", None, MODEL_AXIS)
        k = shard(k, "data", None, MODEL_AXIS)
        v = shard(v, "data", None, MODEL_AXIS)
    q = _split_heads(q, h, hd)
    k = _split_heads(k, kv, hd)
    v = _split_heads(v, kv, hd)

    cos, sin = _angles(cfg, positions, theta)
    q = R.apply_rope(q, cos, sin)
    k = R.apply_rope(k, cos, sin)

    if cache is None:
        if cfg.blocked_attention and s > 1024:
            # context-parallel attention (§Perf iter 2): queries sharded over
            # the model axis on the SEQUENCE dim — legal for any head count,
            # keeps the score contraction local (no all-reduce), and bounds
            # per-chip score temps to s/tp rows.  K/V replicate over model
            # (one bf16 all-gather per layer); GSPMD inserts the in/out
            # reshards.
            if not cfg.dp_over_model:
                q = shard(q, "data", MODEL_AXIS, None, None)
                k = shard(k, "data", None, None, None)
                v = shard(v, "data", None, None, None)
            out = _sdpa_blocked(q, k, v, x.dtype, causal=True, window=window)
        else:
            mask = causal_mask(s, window)
            out = _sdpa(q, k, v, mask, x.dtype)
        new_cache = None
    else:
        # decode: s == 1; write k/v at each row's own position (slots in a
        # serving batch sit at different depths), attend over each prefix
        pos = cache["pos"]  # (B,) int32
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, pos].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, pos].set(v[:, 0].astype(cache["v"].dtype))
        t = ck.shape[1]
        j = jnp.arange(t)[None, :]
        m = j <= pos[:, None]
        if window > 0:
            m &= j > (pos[:, None] - window)
        mask = m[:, None, :]  # (B, 1, T)
        out = _sdpa(q, ck, cv, mask, x.dtype)
        new_cache = {"k": ck, "v": cv, "pos": jnp.minimum(pos + 1, t - 1)}

    out = out.reshape(b, s, h * hd)
    return out @ params["wo"], new_cache


def cross_attention(
    params: Dict,
    x: jax.Array,        # (B, S, D) decoder states
    memory: jax.Array,   # (B, T, D) encoder output
    cfg: ModelConfig,
) -> jax.Array:
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(x @ params["wq"], h, hd)
    k = _split_heads(memory @ params["wk"], kv, hd)
    v = _split_heads(memory @ params["wv"], kv, hd)
    t = memory.shape[1]
    mask = jnp.ones((s, t), bool)
    out = _sdpa(q, k, v, mask, x.dtype).reshape(b, s, h * hd)
    return out @ params["wo"]


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig) -> Dict:
    # KV caches shard along the SEQUENCE dim over the model axis: context
    # lengths always divide the axis (head counts don't), per-chip decode
    # score temps shrink by tp, and the only cross-chip cost is the tiny
    # softmax max/denominator + output partial reductions.  Batch over data.
    return {
        "k": P("data", MODEL_AXIS, None, None),
        "v": P("data", MODEL_AXIS, None, None),
        "pos": P("data"),
    }

"""LM substrate: the assigned architectures as composable JAX modules.

  common.py      ModelConfig, params/sharding rules, norms, MLPs
  rope.py        RoPE / M-RoPE position embeddings
  attention.py   GQA attention (global/local window), cross-attn, KV caches
  moe.py         MoE: RaFI expert-parallel dispatch (the paper's technique)
                 and the dense tensor-parallel baseline
  rwkv6.py       RWKV-6 "Finch" data-dependent-decay linear attention
  griffin.py     RG-LRU recurrent block (RecurrentGemma)
  transformer.py decoder-only assembly (dense / moe / ssm / hybrid)
  encdec.py      encoder-decoder assembly (Seamless-M4T backbone)
  api.py         build_model(config) → init / train / prefill / decode fns
"""

"""RWKV-6 "Finch": linear attention with data-dependent decay (arXiv:2404.05892).

Per head (dk = dv = head size), with receptance r, key k, value v,
data-dependent decay w_t ∈ (0,1) and bonus u:

    o_t = r_t · S_{t-1} + (r_t·k_t·u) v_t
    S_t = diag(w_t)·S_{t-1} + k_tᵀ v_t

Training uses the chunkwise-parallel form (GLA-family chunk algorithm): scan
a (B,H,dk,dv) state over chunks of length ``CHUNK``; within a chunk the
output splits into an inter-chunk term (r decayed to the chunk start times
the carried state) and an intra-chunk term with relative decays
exp(c_{t-1} − c_i) for i < t.  The relative decay is factorized around the
chunk-midpoint (``exp(c−m)·exp(m−c)``) so each factor stays within f32 range
given the per-step log-decay clamp ``W_MIN`` — the stability contract is
|W_MIN|·CHUNK/2 ≲ 80.  Decode carries the state — O(1) in context length,
which is why rwkv6 runs the ``long_500k`` shape.

Simplifications vs the reference implementation (noted in DESIGN.md): the
token-shift/LoRA mixing of r/k/v/w is reduced to direct projections + a
learned per-channel decay bias; the recurrence — what defines the class —
is exact (validated against the naive per-step scan oracle in tests).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import MODEL_AXIS, ModelConfig, ParamDef

CHUNK = 32
W_MIN = -2.5  # per-step log-decay clamp: w ∈ [e^-2.5 ≈ 0.082, ~1)


def rwkv_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    h = _heads(cfg)
    return {
        "wr": ParamDef((d, d), P(None, MODEL_AXIS)),
        "wk": ParamDef((d, d), P(None, MODEL_AXIS)),
        "wv": ParamDef((d, d), P(None, MODEL_AXIS)),
        "ww": ParamDef((d, d), P(None, MODEL_AXIS), scale=0.02),
        "wg": ParamDef((d, d), P(None, MODEL_AXIS)),
        "wo": ParamDef((d, d), P(MODEL_AXIS, None), scale=1.0 / np.sqrt(d)),
        "w_bias": ParamDef((d,), P(MODEL_AXIS), init="zeros"),
        "u": ParamDef((h, d // h), P(MODEL_AXIS, None), scale=0.5),
    }


def _heads(cfg: ModelConfig) -> int:
    return cfg.num_heads if cfg.num_heads > 0 else cfg.d_model // 64


def _project(params, x, cfg: ModelConfig):
    d = cfg.d_model
    h = _heads(cfg)
    dh = d // h
    b, s, _ = x.shape
    r = (x @ params["wr"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, h, dh)
    v = (x @ params["wv"]).reshape(b, s, h, dh)
    logw = -jax.nn.softplus((x @ params["ww"]) + params["w_bias"])
    logw = jnp.clip(logw, W_MIN, -1e-4).reshape(b, s, h, dh)
    g = jax.nn.silu(x @ params["wg"])
    return r, k, v, logw, g, h, dh


def _chunk_scan(r, k, v, logw, u):
    """Chunkwise data-dependent-decay linear attention. All (B,S,H,D), f32 out."""
    b, s, h, dh = r.shape
    L = min(CHUNK, s)
    assert s % L == 0, f"seq {s} must be a multiple of chunk {L}"
    nc = s // L
    shp = (b, nc, L, h, dh)
    r, k, v, logw = (a.astype(jnp.float32).reshape(shp) for a in (r, k, v, logw))

    c = jnp.cumsum(logw, axis=2)          # inclusive in-chunk cumulative decay
    c_prev = c - logw                     # exclusive (c_{t-1}; 0 at t=0)
    c_tot = c[:, :, -1, :, :]             # (b,nc,h,dh) total chunk decay
    m = 0.5 * c_tot[:, :, None]           # midpoint shift for f32 range

    r_in = r * jnp.exp(c_prev - m)        # r_t·A_{t-1}, centered
    k_in = k * jnp.exp(m - c)             # k_i/A_i, centered
    scores = jnp.einsum("bnthd,bnihd->bnhti", r_in, k_in)
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)  # strict: o_t sees i < t
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    o = jnp.einsum("bnhti,bnihd->bnthd", scores, v)
    # diagonal bonus: (r_t·k_t·u) v_t
    o = o + jnp.sum(r * k * u.astype(jnp.float32)[None, None, None], axis=-1, keepdims=True) * v

    # inter-chunk: carry the (b,h,dk,dv) state across chunks
    r_dec = r * jnp.exp(c_prev)           # decays to chunk start (≤ 1, safe)
    k_dec = k * jnp.exp(c_tot[:, :, None] - c)  # decays to chunk end (≤ 1, safe)

    def body(S_prev, xs):
        r_d, k_d, v_c, ct = xs            # (b,L,h,dh)×3, (b,h,dh)
        o_inter = jnp.einsum("bthd,bhde->bthe", r_d, S_prev)
        S_next = S_prev * jnp.exp(ct)[..., None] + jnp.einsum(
            "bthd,bthe->bhde", k_d, v_c
        )
        return S_next, o_inter

    S0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    xs = (
        r_dec.transpose(1, 0, 2, 3, 4),
        k_dec.transpose(1, 0, 2, 3, 4),
        v.transpose(1, 0, 2, 3, 4),
        c_tot.transpose(1, 0, 2, 3),
    )
    _, o_inter = jax.lax.scan(body, S0, xs)
    o = o + o_inter.transpose(1, 0, 2, 3, 4)
    return o.reshape(b, s, h, dh)


def rwkv_block(
    params, x, cfg: ModelConfig, *, state: Optional[jax.Array] = None
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """x (B,S,D). Training (state=None): chunk scan over S.
    Decode (state (B,H,dk,dv)): one recurrent step, S must be 1."""
    b, s, d = x.shape
    r, k, v, logw, g, h, dh = _project(params, x, cfg)
    u = params["u"]
    if state is None:
        o = _chunk_scan(r, k, v, logw, u)
        new_state = None
    else:
        r1, k1, v1 = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
        w1 = jnp.exp(logw[:, 0].astype(jnp.float32))
        kv = jnp.einsum("bhd,bhe->bhde", k1, v1)
        o = jnp.einsum("bhd,bhde->bhe", r1, state) + jnp.sum(
            r1 * k1 * u.astype(jnp.float32)[None], axis=-1, keepdims=True
        ) * v1
        new_state = state * w1[..., None] + kv
        o = o[:, None]
    o = o.reshape(b, s, d).astype(x.dtype) * g
    return o @ params["wo"], new_state


def rwkv_state(cfg: ModelConfig, batch: int):
    h = _heads(cfg)
    dh = cfg.d_model // h
    return jnp.zeros((batch, h, dh, dh), jnp.float32)


def rwkv_state_spec():
    return P("data", MODEL_AXIS, None, None)


def naive_scan_oracle(r, k, v, logw, u):
    """Step-by-step recurrence — ground truth for the chunk algorithm."""
    b, s, h, dh = r.shape
    r, k, v, logw = (a.astype(jnp.float32) for a in (r, k, v, logw))
    u = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, lw = xs
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        ot = jnp.einsum("bhd,bhde->bhe", rt, S) + jnp.sum(
            rt * kt * u[None], axis=-1, keepdims=True
        ) * vt
        S = S * jnp.exp(lw)[..., None] + kv
        return S, ot

    S0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, logw))
    _, o = jax.lax.scan(step, S0, xs)
    return o.transpose(1, 0, 2, 3)

"""Decoder-only LM assembly: dense / MoE / SSM / hybrid wiring.

Layers follow the config's repeating ``pattern`` (e.g. gemma3's 5×local +
1×global, recurrentgemma's 2×recurrent + 1×local, dbrx's all-MoE).  Full
pattern periods are stacked and traversed with ``jax.lax.scan`` so the HLO
contains ONE period regardless of depth (critical for 40–80-layer dry-run
compiles); leftover layers (depth % period) run unrolled.  Each period is
optionally ``jax.checkpoint``-ed (activation rematerialization).

Decode state is a pytree mirroring the block structure: KV caches for
attention layers, (h, conv) for RG-LRU, (dk×dv) state for RWKV.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import griffin as G
from repro.models import moe as M
from repro.models import rwkv6 as W
from repro.models.common import (
    DATA_AXIS,
    MODEL_AXIS,
    ModelConfig,
    ParamDef,
    batch_axes,
    glu_mlp,
    mlp_defs,
    rmsnorm,
    shard,
)


# ----------------------------------------------------------------- defs

def _gamma(cfg):
    return ParamDef((cfg.d_model,), P(None), init="zeros")


def layer_defs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d: Dict[str, Any] = {"ln1": _gamma(cfg), "ln2": _gamma(cfg)}
    if kind in ("global", "local"):
        d["attn"] = A.attn_defs(cfg)
        d["mlp"] = mlp_defs(cfg)
    elif kind == "moe":
        d["attn"] = A.attn_defs(cfg)
        d["moe"] = M.moe_defs(cfg)
    elif kind == "recurrent":
        d["rglru"] = G.griffin_defs(cfg)
        d["mlp"] = mlp_defs(cfg)
    elif kind == "rwkv":
        d["rwkv"] = W.rwkv_defs(cfg)
        d["mlp"] = mlp_defs(cfg)
    else:
        raise ValueError(kind)
    return d


def _stack_defs(defs, n: int):
    return jax.tree.map(
        lambda p: ParamDef((n,) + p.shape, P(*((None,) + tuple(p.spec))),
                           scale=p.scale, init=p.init),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    period = len(cfg.pattern)
    n_blocks = cfg.num_layers // period
    tail = cfg.num_layers % period
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), P(MODEL_AXIS, None), scale=0.02),
        "final_ln": _gamma(cfg),
        "blocks": {
            f"k{j}_{kind}": _stack_defs(layer_defs(cfg, kind), n_blocks)
            for j, kind in enumerate(cfg.pattern)
        },
        "tail": {
            f"k{j}_{cfg.pattern[j]}": layer_defs(cfg, cfg.pattern[j])
            for j in range(tail)
        },
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef(
            (cfg.d_model, cfg.vocab_size), P(None, MODEL_AXIS), scale=0.02
        )
    return defs


# ----------------------------------------------------------------- apply

def _theta_for(cfg: ModelConfig, kind: str):
    # gemma3: local layers use the short-context base (1e4), global the long one
    if kind == "local" and cfg.rope_theta > 1e5:
        return 1e4
    return cfg.rope_theta


def apply_layer(
    params, x, cfg: ModelConfig, kind: str, *,
    positions, mesh=None, cache=None,
):
    """One transformer layer. Returns (x, new_cache, moe_drops)."""
    drops = jnp.zeros((), jnp.int32)
    h = rmsnorm(x, params["ln1"])
    if kind in ("global", "local", "moe"):
        window = cfg.window if kind == "local" else 0
        attn_cache = None if cache is None else cache
        y, new_cache = A.self_attention(
            params["attn"], h, cfg,
            positions=positions, window=window,
            theta=_theta_for(cfg, kind), cache=attn_cache,
        )
    elif kind == "recurrent":
        y, new_cache = G.griffin_block(params["rglru"], h, cfg, state=cache)
    elif kind == "rwkv":
        y, new_cache = W.rwkv_block(params["rwkv"], h, cfg, state=cache)
    else:
        raise ValueError(kind)
    x = x + y
    h = rmsnorm(x, params["ln2"])
    if kind == "moe":
        y, d = M.moe_block(params["moe"], h, cfg, mesh=mesh)
        drops = drops + d.astype(jnp.int32)
    else:
        y = glu_mlp(h, params["mlp"]["wi"], params["mlp"]["wg"], params["mlp"]["wo"], cfg.act)
    x = x + y
    # sequence-parallel residual stream (§Perf iter 3): between matmuls the
    # activations stay sharded over (data, model) on (batch, seq) — TP
    # boundary transitions become s/tp-sized gathers/reduce-scatters instead
    # of full-activation all-gathers.  Decode (s == 1) stays replicated.
    if x.shape[1] > 1 and cfg.blocked_attention and not cfg.dp_over_model:
        x = shard(x, DATA_AXIS, MODEL_AXIS, None)
    else:
        x = shard(x, batch_axes(cfg), None, None)
    return x, new_cache, drops


def _period_apply(block_params, x, cfg, *, positions, mesh, caches=None):
    """Apply one pattern period. caches: dict kind_key -> cache (or None)."""
    new_caches = {}
    drops = jnp.zeros((), jnp.int32)
    for j, kind in enumerate(cfg.pattern):
        key = f"k{j}_{kind}"
        c = None if caches is None else caches.get(key)
        x, nc, d = apply_layer(
            block_params[key], x, cfg, kind,
            positions=positions, mesh=mesh, cache=c,
        )
        drops = drops + d
        if nc is not None:
            new_caches[key] = nc
    return x, (new_caches if caches is not None else None), drops


def forward(
    params, tokens, cfg: ModelConfig, *, mesh=None,
    caches: Optional[Dict] = None, positions=None, frontend_embeds=None,
):
    """tokens (B, S) int32 (or ``frontend_embeds`` (B,S,D) for stub
    modalities).  caches=None → parallel (train/prefill without cache);
    else decode with S==1.  Returns (logits, new_caches, moe_drops)."""
    if frontend_embeds is not None:
        x = frontend_embeds.astype(cfg.jdtype)
    else:
        x = params["embed"][tokens]
        if cfg.scale_embed:
            x = x * np.float32(np.sqrt(cfg.d_model))
        x = x.astype(cfg.jdtype)
    x = shard(x, batch_axes(cfg), None, None)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    period = len(cfg.pattern)
    n_blocks = cfg.num_layers // period
    tail = cfg.num_layers % period
    total_drops = jnp.zeros((), jnp.int32)

    if n_blocks > 0:
        def scan_body(carry, xs):
            x, drops = carry
            block_params, block_caches = xs
            x, new_caches, d = _period_apply(
                block_params, x, cfg, positions=positions, mesh=mesh,
                caches=block_caches,
            )
            return (x, drops + d), new_caches

        body = scan_body
        if cfg.remat:
            body = jax.checkpoint(scan_body)
        block_caches = None if caches is None else caches["blocks"]
        (x, total_drops), new_block_caches = jax.lax.scan(
            body,
            (x, total_drops),
            (params["blocks"], block_caches),
            unroll=n_blocks if cfg.scan_unroll else 1,
        )
    else:
        new_block_caches = None

    new_tail_caches = {}
    for j in range(tail):
        kind = cfg.pattern[j]
        key = f"k{j}_{kind}"
        c = None if caches is None else caches["tail"].get(key)
        x, nc, d = apply_layer(
            params["tail"][key], x, cfg, kind,
            positions=positions, mesh=mesh, cache=c,
        )
        total_drops = total_drops + d
        if nc is not None:
            new_tail_caches[key] = nc

    x = rmsnorm(x, params["final_ln"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if cfg.dp_over_model:
        logits = shard(logits, batch_axes(cfg), None, None)
    else:
        logits = shard(logits, DATA_AXIS, None, MODEL_AXIS)
    new_caches = (
        None if caches is None else {"blocks": new_block_caches, "tail": new_tail_caches}
    )
    return logits, new_caches, total_drops


# ----------------------------------------------------------------- caches

def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("global", "local", "moe"):
        return A.make_cache(cfg, batch, max_len, cfg.jdtype)
    if kind == "recurrent":
        return G.griffin_state(cfg, batch)
    if kind == "rwkv":
        return W.rwkv_state(cfg, batch)
    raise ValueError(kind)


def _layer_cache_spec(cfg: ModelConfig, kind: str):
    if kind in ("global", "local", "moe"):
        return A.cache_specs(cfg)
    if kind == "recurrent":
        return G.griffin_state_spec()
    if kind == "rwkv":
        return W.rwkv_state_spec()
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    period = len(cfg.pattern)
    n_blocks = cfg.num_layers // period
    tail = cfg.num_layers % period
    blocks = {
        f"k{j}_{kind}": jax.tree.map(
            lambda a: jnp.zeros((n_blocks,) + a.shape, a.dtype),
            _layer_cache(cfg, kind, batch, max_len),
        )
        for j, kind in enumerate(cfg.pattern)
    }
    tails = {
        f"k{j}_{cfg.pattern[j]}": _layer_cache(cfg, cfg.pattern[j], batch, max_len)
        for j in range(tail)
    }
    return {"blocks": blocks, "tail": tails}


def cache_specs_tree(cfg: ModelConfig):
    period = len(cfg.pattern)
    tail = cfg.num_layers % period
    def lift(spec_tree):
        return jax.tree.map(
            lambda s: P(*((None,) + tuple(s))),
            spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )
    blocks = {
        f"k{j}_{kind}": lift(_layer_cache_spec(cfg, kind))
        for j, kind in enumerate(cfg.pattern)
    }
    tails = {
        f"k{j}_{cfg.pattern[j]}": _layer_cache_spec(cfg, cfg.pattern[j])
        for j in range(tail)
    }
    return {"blocks": blocks, "tail": tails}

"""Mixture-of-Experts with RaFI forwarding as the dispatch plane.

This is the paper's technique integrated as a first-class LM feature: under
expert parallelism, routed tokens are *work items* that must migrate to the
rank owning their expert — semi-random, data-dependent, batched: precisely
RaFI's domain.  Two dispatch planes are implemented:

* ``rafi_ep`` (paper technique): experts are sharded over the "model" axis.
  Inside a ``shard_map`` over ("data", "model"), each shard takes its token
  slice, *emits* (hidden, slot, weight) items with destination
  ``expert // experts_per_rank`` via the §3 queue API, and one
  ``forward_work`` round (§4.2: sort by destination → count exchange →
  payload all-to-all) moves them.  Local experts run; a second forwarding
  round returns results to the stored origin rank (the ray's ``pixelID``
  pattern), where they are combined by router weight.  Top-k > 1 simply
  emits k items per token — §3.3's "threads can emit more than one ray".
* ``dense_tp`` (baseline, no forwarding): every rank holds every expert,
  sharded over d_ff; dispatch is a local capacity-bucketed gather and the
  only communication is the usual tensor-parallel reduction.

Both planes share the router and the capacity-factor drop rule (queue
overflow == token drop — the same §3.3/§6.3 semantics, observable via the
drop counters).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import DISCARD, ForwardConfig, enqueue, forward_work, make_queue, work_item
from repro.models.common import MODEL_AXIS, ModelConfig, ParamDef, shard


@work_item
@dataclasses.dataclass
class TokenItem:
    """A routed token in flight (the MoE 'ray')."""

    h: jax.Array       # (D,) hidden state
    slot: jax.Array    # () i32 original position in the sender's token slice
    weight: jax.Array  # () f32 router weight
    expert: jax.Array  # () i32 global expert id
    src: jax.Array     # () i32 origin rank (the 'pixelID' for the return trip)


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    if cfg.moe_dispatch == "rafi_ep":
        # expert parallelism: experts over the model axis, full d_ff each
        wi_spec = wg_spec = P(MODEL_AXIS, None, None)
        wo_spec = P(MODEL_AXIS, None, None)
    else:
        # tensor parallelism: every expert everywhere, d_ff over the model axis
        wi_spec = wg_spec = P(None, None, MODEL_AXIS)
        wo_spec = P(None, MODEL_AXIS, None)
    return {
        "router": ParamDef((d, e), P(None, None), scale=0.02),
        "wi": ParamDef((e, d, f), wi_spec),
        "wg": ParamDef((e, d, f), wg_spec),
        "wo": ParamDef((e, f, d), wo_spec, scale=1.0 / np.sqrt(f)),
    }


def _router(params, x2d, cfg: ModelConfig):
    """x2d (N, D) → (topk_idx (N,k), topk_w (N,k)) with softmax-over-topk."""
    logits = (x2d.astype(jnp.float32)) @ params["router"].astype(jnp.float32)
    w, idx = jax.lax.top_k(logits, cfg.top_k)
    w = jax.nn.softmax(w, axis=-1)
    return idx.astype(jnp.int32), w.astype(x2d.dtype)


def _expert_ffn(wi, wg, wo, x, act: str):
    """Batched per-expert GLU: x (E, C, D) → (E, C, D)."""
    gate = jnp.einsum("ecd,edf->ecf", x, wg)
    up = jnp.einsum("ecd,edf->ecf", x, wi)
    a = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
    return jnp.einsum("ecf,efd->ecd", a * up, wo)


# ------------------------------------------------------------ dense_tp plane

def moe_dense_tp(params, x, cfg: ModelConfig):
    """Baseline: local capacity-bucketed dispatch, experts TP-sharded on d_ff."""
    b, s, d = x.shape
    n = b * s
    x2 = x.reshape(n, d)
    idx, w = _router(params, x2, cfg)
    e, k = cfg.num_experts, cfg.top_k
    cap = int(np.ceil(n * k / e * cfg.capacity_factor))

    flat_e = idx.reshape(-1)                      # (N·k,)
    flat_t = jnp.repeat(jnp.arange(n), k)         # token of each assignment
    flat_w = w.reshape(-1)
    # position of each assignment within its expert's bucket (counting sort)
    order = jnp.argsort(flat_e, stable=True)
    ranked = jnp.zeros((n * k,), jnp.int32).at[order].set(
        jnp.arange(n * k, dtype=jnp.int32)
    )
    seg_start = jnp.cumsum(jnp.bincount(flat_e, length=e)) - jnp.bincount(flat_e, length=e)
    pos_in_e = ranked - seg_start[flat_e]
    keep = pos_in_e < cap

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[
        jnp.where(keep, flat_e, e), jnp.where(keep, pos_in_e, 0)
    ].set(x2[flat_t], mode="drop")
    out_buf = _expert_ffn(params["wi"], params["wg"], params["wo"], buf, cfg.act)
    gathered = out_buf[jnp.where(keep, flat_e, 0), jnp.where(keep, pos_in_e, 0)]
    contrib = jnp.where(keep[:, None], gathered * flat_w[:, None], 0.0)
    y = jnp.zeros((n, d), x.dtype).at[flat_t].add(contrib)
    return y.reshape(b, s, d), jnp.sum(~keep)


# ------------------------------------------------------------- rafi_ep plane

def moe_rafi_ep(params, x, cfg: ModelConfig, *, mesh) -> Tuple[jax.Array, jax.Array]:
    """Paper-technique dispatch: forwarding over the model axis.

    ``x`` arrives replicated over "model" (post-attention layout); each model
    rank takes its 1/tp token slice, routes, exchanges, computes its local
    experts, and routes results back; a final all-gather restores the layout.
    """
    b, s, d = x.shape
    tp = mesh.shape[MODEL_AXIS]
    e, k = cfg.num_experts, cfg.top_k
    assert e % tp == 0, "experts must divide the model axis"
    e_loc = e // tp

    def proto():
        return TokenItem(
            h=jnp.zeros((d,), x.dtype),
            slot=jnp.zeros((), jnp.int32),
            weight=jnp.zeros((), x.dtype),
            expert=jnp.zeros((), jnp.int32),
            src=jnp.zeros((), jnp.int32),
        )

    def block(xb, wi, wg, wo, router):
        # xb: (B/dp, S, D) — replicated over model; take my token slice.
        # n_all may not divide tp (decode: one token) — pad with masked lanes.
        me = jax.lax.axis_index(MODEL_AXIS)
        bl, sl, _ = xb.shape
        n_all = bl * sl
        n_loc = -(-n_all // tp)
        x2 = xb.reshape(n_all, d)
        gslot = me * n_loc + jnp.arange(n_loc)
        tok_ok = gslot < n_all
        xs = x2[jnp.clip(gslot, 0, n_all - 1)]
        idx, w = _router({"router": router}, xs, cfg)

        n_emit = n_loc * k
        cap_send = n_emit
        # every peer can receive at most its expert capacity
        cap_e = int(np.ceil(n_all * k / e * cfg.capacity_factor))
        cap_recv = cap_e * e_loc
        cap = max(cap_send, cap_recv)
        # per-(src,dst) slots sized for balanced routing (+2× slack), not the
        # all-to-one worst case — the padded send buffer is R×slot×D, which
        # dominated MoE memory at worst-case sizing (§Perf dbrx iter).  Slot
        # overflow drops are counted (the §3.3 contract); production TPU uses
        # exchange="ragged" where slots don't exist at all.
        fcfg = ForwardConfig(
            MODEL_AXIS, tp, cap,
            peer_capacity=min(cap, max(64, -(-2 * cap // tp))),
            exchange="padded",
        )

        items = TokenItem(
            h=jnp.repeat(xs, k, axis=0),
            slot=jnp.repeat(jnp.arange(n_loc, dtype=jnp.int32), k),
            weight=w.reshape(-1),
            expert=idx.reshape(-1),
            src=jnp.full((n_emit,), me, jnp.int32),
        )
        dest = (items.expert // e_loc).astype(jnp.int32)
        q = make_queue(proto(), fcfg.capacity)
        q = enqueue(q, items, dest, jnp.repeat(tok_ok, k))
        q, _ = forward_work(q, fcfg)  # §4.2 — tokens travel to expert owners

        # local expert compute with per-expert capacity buckets
        lane = jnp.arange(fcfg.capacity)
        valid = lane < q.count
        it = q.items
        le = jnp.where(valid, it.expert - me * e_loc, e_loc)  # local expert id
        le = jnp.clip(le, 0, e_loc)
        order = jnp.argsort(jnp.where(valid, le, e_loc), stable=True)
        ranked = jnp.zeros((fcfg.capacity,), jnp.int32).at[order].set(
            jnp.arange(fcfg.capacity, dtype=jnp.int32)
        )
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[le].add(valid.astype(jnp.int32))
        seg = jnp.cumsum(counts) - counts
        pos = ranked - seg[le]
        keep = valid & (pos < cap_e) & (le < e_loc)
        drops_cap = jnp.sum(valid & ~keep)

        buf = jnp.zeros((e_loc, cap_e, d), x.dtype)
        buf = buf.at[jnp.where(keep, le, e_loc), jnp.where(keep, pos, 0)].set(
            it.h, mode="drop"
        )
        out = _expert_ffn(wi, wg, wo, buf, cfg.act)  # wi/wg/wo already (e_loc,...)
        hout = out[jnp.where(keep, le, 0), jnp.where(keep, pos, 0)]

        # return trip: dest = stored origin rank (the 'pixelID' pattern)
        back = TokenItem(
            h=hout, slot=it.slot, weight=it.weight, expert=it.expert, src=it.src
        )
        q2 = make_queue(proto(), fcfg.capacity)
        q2 = enqueue(q2, back, jnp.where(keep, it.src, DISCARD).astype(jnp.int32), valid)
        q2, _ = forward_work(q2, fcfg)

        lane2 = jnp.arange(fcfg.capacity)
        valid2 = lane2 < q2.count
        r = q2.items
        contrib = jnp.where(valid2[:, None], r.h * r.weight[:, None], 0.0)
        ys = jnp.zeros((n_loc, d), x.dtype).at[
            jnp.where(valid2, r.slot, n_loc)
        ].add(contrib, mode="drop")

        # restore replicated layout
        y_all = jax.lax.all_gather(ys, MODEL_AXIS, axis=0, tiled=True)
        y_all = y_all[:n_all].reshape(bl, sl, d)
        drops = drops_cap + q.drops + q2.drops
        return y_all, drops[None]

    baxes = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)  # pod?, data
    y, drops = compat.shard_map(
        block,
        mesh=mesh,
        in_specs=(
            P(baxes, None, None),
            P(MODEL_AXIS, None, None),
            P(MODEL_AXIS, None, None),
            P(MODEL_AXIS, None, None),
            P(None, None),
        ),
        out_specs=(P(baxes, None, None), P(baxes + (MODEL_AXIS,))),
        check_vma=False,
    )(x, params["wi"], params["wg"], params["wo"], params["router"])
    return y, jnp.sum(drops)


def moe_block(params, x, cfg: ModelConfig, *, mesh=None):
    if cfg.moe_dispatch == "rafi_ep":
        assert mesh is not None, "rafi_ep dispatch needs the mesh"
        return moe_rafi_ep(params, x, cfg, mesh=mesh)
    return moe_dense_tp(params, x, cfg)

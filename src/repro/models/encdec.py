"""Encoder-decoder assembly (Seamless-M4T medium backbone, arXiv:2308.11596).

Per the assignment spec the modality frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, T_enc, D) from ``input_specs()``.  The
backbone is the transformer pair: a bidirectional encoder and a causal
decoder with cross-attention, both 12L / d=1024 / 16H / ff=4096.

Decode: self-attention KV caches plus the static encoder memory.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models.common import (
    DATA_AXIS,
    MODEL_AXIS,
    ModelConfig,
    ParamDef,
    batch_axes,
    glu_mlp,
    mlp_defs,
    rmsnorm,
    shard,
)
from repro.models.transformer import _gamma, _stack_defs


def encdec_defs(cfg: ModelConfig) -> Dict[str, Any]:
    enc_layer = {
        "ln1": _gamma(cfg), "attn": A.attn_defs(cfg),
        "ln2": _gamma(cfg), "mlp": mlp_defs(cfg),
    }
    dec_layer = {
        "ln1": _gamma(cfg), "attn": A.attn_defs(cfg),
        "lnx": _gamma(cfg), "xattn": A.attn_defs(cfg),
        "ln2": _gamma(cfg), "mlp": mlp_defs(cfg),
    }
    return {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), P(MODEL_AXIS, None), scale=0.02),
        "enc_blocks": _stack_defs(enc_layer, cfg.encoder_layers),
        "enc_ln": _gamma(cfg),
        "dec_blocks": _stack_defs(dec_layer, cfg.num_layers),
        "final_ln": _gamma(cfg),
        "lm_head": ParamDef((cfg.d_model, cfg.vocab_size), P(None, MODEL_AXIS), scale=0.02),
    }


def _bidir_attention(params, x, cfg, positions):
    """Encoder self-attention: full (non-causal) mask."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    from repro.models import rope as R

    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    cos, sin = R.rope_angles(positions, hd, cfg.rope_theta)
    q = R.apply_rope(q, cos, sin)
    k = R.apply_rope(k, cos, sin)
    mask = jnp.ones((s, s), bool)
    out = A._sdpa(q, k, v, mask, x.dtype).reshape(b, s, h * hd)
    return out @ params["wo"]


def encode(params, frames, cfg: ModelConfig):
    """frames (B, T, D) stub embeddings → encoder memory (B, T, D)."""
    x = frames.astype(cfg.jdtype)
    x = shard(x, batch_axes(cfg), None, None)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))

    def body(x, blk):
        h = rmsnorm(x, blk["ln1"])
        x = x + _bidir_attention(blk["attn"], h, cfg, positions)
        h = rmsnorm(x, blk["ln2"])
        x = x + glu_mlp(h, blk["mlp"]["wi"], blk["mlp"]["wg"], blk["mlp"]["wo"], cfg.act)
        return shard(x, batch_axes(cfg), None, None), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(
        body_fn, x, params["enc_blocks"],
        unroll=cfg.encoder_layers if cfg.scan_unroll else 1,
    )
    return rmsnorm(x, params["enc_ln"])


def decode(
    params, tokens, memory, cfg: ModelConfig, *,
    caches: Optional[Any] = None, positions=None,
):
    """Causal decoder over ``tokens`` with cross-attention to ``memory``.

    caches=None → parallel (training). Else stacked decoder KV caches."""
    x = params["embed"][tokens].astype(cfg.jdtype)
    x = shard(x, batch_axes(cfg), None, None)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, xs):
        x = carry
        blk, cache = xs
        h = rmsnorm(x, blk["ln1"])
        y, nc = A.self_attention(
            blk["attn"], h, cfg, positions=positions, cache=cache
        )
        x = x + y
        h = rmsnorm(x, blk["lnx"])
        x = x + A.cross_attention(blk["xattn"], h, memory, cfg)
        h = rmsnorm(x, blk["ln2"])
        x = x + glu_mlp(h, blk["mlp"]["wi"], blk["mlp"]["wg"], blk["mlp"]["wo"], cfg.act)
        return shard(x, batch_axes(cfg), None, None), nc

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, new_caches = jax.lax.scan(
        body_fn, x, (params["dec_blocks"], caches),
        unroll=cfg.num_layers if cfg.scan_unroll else 1,
    )
    x = rmsnorm(x, params["final_ln"])
    logits = x @ params["lm_head"].astype(x.dtype)
    if cfg.dp_over_model:
        return shard(logits, batch_axes(cfg), None, None), new_caches
    return shard(logits, DATA_AXIS, None, MODEL_AXIS), new_caches


def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int):
    one = A.make_cache(cfg, batch, max_len, cfg.jdtype)
    return jax.tree.map(
        lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), one
    )


def dec_cache_specs(cfg: ModelConfig):
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))),
        A.cache_specs(cfg),
        is_leaf=lambda x: isinstance(x, P),
    )

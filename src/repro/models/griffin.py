"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrent branch: x → conv1d(width 4) → RG-LRU, gated by a GeLU branch:

    r_t = σ(W_r ξ_t)             (recurrence gate)
    i_t = σ(W_i ξ_t)             (input gate)
    a_t = exp(c·softplus(Λ)·(−r_t))        — i.e. a_t = a^{c·r_t}, a = σ(Λ)
    h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ ξ_t)

Diagonal state ⇒ the training scan is O(S·D) and decode is O(1) in context
(recurrentgemma runs ``long_500k``).  Decode state: (h, conv tail of 3
inputs).  Layer pattern in the full model: recurrent, recurrent, local-attn
(1:2 attention:recurrence, window 2048).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import MODEL_AXIS, ModelConfig, ParamDef

CONV_W = 4
LRU_C = 8.0


def griffin_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    dr = d  # lru width = d_model for recurrentgemma-2b
    return {
        "wa": ParamDef((d, dr), P(None, MODEL_AXIS)),
        "wb": ParamDef((d, dr), P(None, MODEL_AXIS)),
        "conv": ParamDef((CONV_W, dr), P(None, MODEL_AXIS), scale=0.5),
        "wr": ParamDef((dr, dr), P(None, MODEL_AXIS), scale=0.02),
        "wi": ParamDef((dr, dr), P(None, MODEL_AXIS), scale=0.02),
        "lam": ParamDef((dr,), P(MODEL_AXIS), init="ones"),
        "wo": ParamDef((dr, d), P(MODEL_AXIS, None), scale=1.0 / np.sqrt(dr)),
    }


def _lru_coeffs(params, xi):
    r = jax.nn.sigmoid(xi @ params["wr"])
    i = jax.nn.sigmoid(xi @ params["wi"])
    log_a = -LRU_C * jax.nn.softplus(params["lam"]) * r  # log a_t ≤ 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * xi)
    return a, gated


def _causal_conv(x, w, tail: Optional[jax.Array] = None):
    """Depthwise causal conv, width CONV_W. x (B,S,D); tail (B,CONV_W-1,D)."""
    if tail is None:
        pad = jnp.zeros((x.shape[0], CONV_W - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(CONV_W)
    )
    return out, xp[:, -(CONV_W - 1) :]


def griffin_block(
    params, x, cfg: ModelConfig, *, state: Optional[Dict] = None
) -> Tuple[jax.Array, Optional[Dict]]:
    """x (B,S,D). state None → training scan; else {"h": (B,Dr), "conv": (B,3,Dr)}."""
    b, s, d = x.shape
    gate = jax.nn.gelu(x @ params["wa"])
    xb = x @ params["wb"]
    if state is None:
        conv, _ = _causal_conv(xb, params["conv"])
        a, gated = _lru_coeffs(params, conv.astype(jnp.float32))

        def step(h, xs):
            at, gt = xs
            h = at * h + gt
            return h, h

        h0 = jnp.zeros((b, d), jnp.float32)
        _, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
        y = hs.transpose(1, 0, 2).astype(x.dtype)
        new_state = None
    else:
        conv, tail = _causal_conv(xb, params["conv"], state["conv"])
        a, gated = _lru_coeffs(params, conv.astype(jnp.float32))
        h = a[:, 0] * state["h"] + gated[:, 0]
        y = h[:, None].astype(x.dtype)
        new_state = {"h": h, "conv": tail}
    return (gate * y) @ params["wo"], new_state


def griffin_state(cfg: ModelConfig, batch: int) -> Dict:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "conv": jnp.zeros((batch, CONV_W - 1, d), jnp.float32),
    }


def griffin_state_spec() -> Dict:
    return {"h": P("data", MODEL_AXIS), "conv": P("data", None, MODEL_AXIS)}

from repro.ckpt.checkpoint import (  # noqa: F401
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

from repro.ckpt.checkpoint import (  # noqa: F401
    latest_step,
    load_manifest,
    restore_checkpoint,
    save_checkpoint,
)

"""Fault-tolerant checkpointing: atomic, integrity-checked, mesh-elastic.

* **Atomic**: a checkpoint is written to ``step_<k>.tmp/`` and renamed to
  ``step_<k>/`` only after every file (and the manifest) is fsync'd — a
  crash mid-write can never leave a half checkpoint that restore would read.
* **Integrity**: the manifest stores a SHA-256 per tensor file; restore
  verifies before deserializing (detects bit-rot / truncation — at 1000+
  nodes storage corruption is a when, not an if).
* **Elastic**: tensors are saved in their *logical* (unsharded) layout, so
  restore can land them on ANY mesh — restart with a different pod count or
  (data, model) factorization just passes different shardings.  (At real
  scale this becomes per-shard files + resharding on read; the logical-layout
  contract is what matters and is what the elastic test exercises.)
* **Retention**: keep the latest k checkpoints, delete older ones.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _hash(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()


def save_checkpoint(ckpt_dir, step: int, tree: Any, *, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        path = tmp / f"leaf_{i:05d}.npy"
        with open(path, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append(
            {
                "file": path.name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": _hash(path.read_bytes()),
            }
        )
    mpath = tmp / "manifest.json"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish

    # retention
    ckpts = sorted(p for p in ckpt_dir.iterdir() if p.name.startswith("step_") and not p.name.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, step: int, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (a pytree of NamedShardings — the elastic-rescale path)."""
    final = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((final / "manifest.json").read_text())
    leaves_like, treedef = _flatten(like)
    assert len(manifest["leaves"]) == len(leaves_like), "checkpoint/model mismatch"
    out = []
    for i, (entry, ref) in enumerate(zip(manifest["leaves"], leaves_like)):
        raw = (final / entry["file"]).read_bytes()
        if _hash(raw) != entry["sha256"]:
            raise IOError(f"checkpoint corruption in {entry['file']}")
        arr = np.load(final / entry["file"])
        assert list(arr.shape) == list(ref.shape), (
            f"leaf {i}: shape {arr.shape} != expected {ref.shape}"
        )
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree

"""Fault-tolerant checkpointing: atomic, integrity-checked, mesh-elastic.

* **Atomic**: a checkpoint is written to ``step_<k>.tmp/`` and renamed to
  ``step_<k>/`` only after every file (and the manifest) is fsync'd — a
  crash mid-write can never leave a half checkpoint that restore would read.
* **Integrity**: the manifest stores a SHA-256 per tensor file; restore
  verifies before deserializing (detects bit-rot / truncation — at 1000+
  nodes storage corruption is a when, not an if).  Structure / shape / dtype
  mismatches between the checkpoint and the restore target raise
  ``ValueError`` (never ``assert`` — asserts vanish under ``python -O`` and
  would turn a checkpoint/model mismatch into silent corruption).
* **Elastic**: tensors are saved in their *logical* (unsharded) layout, so
  restore can land them on ANY mesh — restart with a different pod count or
  (data, model) factorization just passes different shardings.  (At real
  scale this becomes per-shard files + resharding on read; the logical-layout
  contract is what matters and is what the elastic tests exercise — see
  ``repro.core.recovery`` for the forwarding drive's R → R′ restore.)
* **Retention**: keep the latest k checkpoints, delete older ones — and
  sweep any orphaned ``step_*.tmp`` dirs a crash mid-write left behind
  (they are dead by construction: a tmp dir either renamed at publish or
  its writer died; without the sweep they accumulate forever).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _hash(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()


def save_checkpoint(
    ckpt_dir, step: int, tree: Any, *, keep: int = 3, meta: Optional[Dict] = None
) -> Path:
    """Atomically publish ``tree`` as ``step_<step>/`` under ``ckpt_dir``.

    ``meta`` (optional, JSON-serializable) is embedded in the manifest and
    readable WITHOUT knowing the tree structure via :func:`load_manifest` —
    the hook resume tooling uses to learn the saved run's shape (rank count,
    round counter, …) before it can build a restore target.
    """
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    if meta is not None:
        manifest["meta"] = meta
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        path = tmp / f"leaf_{i:05d}.npy"
        # serialize once and hash the exact bytes written — the save sits on
        # the drive loop's boundary path now, and a read-back per leaf just
        # to digest it doubles the file traffic for the same manifest entry
        buf = io.BytesIO()
        np.save(buf, arr)
        raw = buf.getvalue()
        with open(path, "wb") as f:
            f.write(raw)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append(
            {
                "file": path.name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": _hash(raw),
            }
        )
    mpath = tmp / "manifest.json"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish

    # retention: published checkpoints beyond the newest `keep` go, and so
    # does every orphaned step_*.tmp left by a crash mid-write (ours was just
    # renamed away, so any tmp dir still present has no live writer)
    ckpts = sorted(
        p
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".tmp")
    )
    for old in ckpts[:-keep]:
        shutil.rmtree(old)
    for orphan in ckpt_dir.glob("step_*.tmp"):
        shutil.rmtree(orphan)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def load_manifest(ckpt_dir, step: int) -> Dict:
    """The manifest of a published checkpoint (structure-free: shapes,
    dtypes, hashes, and the saver's ``meta`` — everything resume tooling
    needs before it can construct a ``like`` tree)."""
    final = Path(ckpt_dir) / f"step_{step:08d}"
    mpath = final / "manifest.json"
    if not mpath.exists():
        raise FileNotFoundError(f"no published checkpoint at {final}")
    return json.loads(mpath.read_text())


def restore_checkpoint(ckpt_dir, step: int, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (a pytree of NamedShardings — the elastic-rescale path).

    Raises ``ValueError`` on checkpoint/target structure, shape, or dtype
    mismatch and ``IOError`` on integrity (SHA-256) failure.
    """
    final = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((final / "manifest.json").read_text())
    leaves_like, treedef = _flatten(like)
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint/model mismatch: checkpoint has "
            f"{len(manifest['leaves'])} leaves, restore target has "
            f"{len(leaves_like)}"
        )
    out = []
    for i, (entry, ref) in enumerate(zip(manifest["leaves"], leaves_like)):
        raw = (final / entry["file"]).read_bytes()
        if _hash(raw) != entry["sha256"]:
            raise IOError(f"checkpoint corruption in {entry['file']}")
        arr = np.load(final / entry["file"])
        ref_shape = list(np.shape(ref))
        if list(arr.shape) != ref_shape:
            raise ValueError(
                f"leaf {i}: checkpoint shape {list(arr.shape)} != expected "
                f"{ref_shape}"
            )
        ref_dtype = np.asarray(ref).dtype if not hasattr(ref, "dtype") else ref.dtype
        if np.dtype(arr.dtype) != np.dtype(ref_dtype):
            raise ValueError(
                f"leaf {i}: checkpoint dtype {arr.dtype} != expected {ref_dtype}"
            )
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree

"""Run a chaos scenario through the real on-device drive loop.

The driver turns a :class:`repro.chaos.scenarios.Scenario` into a
``RafiContext.run_until_done`` program: the seed queue carries round 0's
emissions, ``round_fn(…, rnd)`` emits schedule row ``rnd + 1`` (the drive's
initial forward consumes row 0, so body iteration ``rnd`` is emission round
``rnd + 1``) and folds every arrival into per-rank ``(count, Σuid, Σuid²)``
uint32 checksums — the same identity law the oracle computes from the
schedule alone.  Items are never re-forwarded by the app: one emission, one
delivery, so conservation (``emitted == delivered + resident + drops +
lost`` with ``lost == 0``) is checkable in every overflow mode and the
lossless law (``drops == 0`` too, in retain mode) is a pure array compare.

ISSUE 7 adds :func:`run_scenario_checkpointed` — the same scenario driven
through the segmented ``repro.core.recovery`` drive, with an optional
simulated preemption (``preempt_at``), resume on the same or a DIFFERENT
mesh (elastic restore), and a per-segment ``health`` mask (rank draining /
brownout).  Because every checkpoint's manifest carries a SHA-256 per carry
leaf, two runs of the same scenario can be proven bit-identical at every
common boundary by comparing manifests alone — no tolerance, no sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro import ckpt
from repro.chaos.scenarios import Scenario
from repro.core import queue as Q
from repro.core import recovery
from repro.core import work_item
from repro.core.context import RafiContext
from repro.core.forwarding import flatten_axis_names
from repro.telemetry import stats as TS

__all__ = [
    "ChaosItem",
    "boundary_digests",
    "chaos_proto",
    "run_scenario",
    "run_scenario_checkpointed",
]


@work_item
@dataclasses.dataclass
class ChaosItem:
    """A forwardable probe: identity for the checksums, a payload tail so
    the wire format moves more than the control word."""

    uid: jax.Array  # () i32 — the scenario's (round, rank, lane) identity
    val: jax.Array  # (2,) f32 — derived ballast, never checked


def chaos_proto() -> ChaosItem:
    return ChaosItem(uid=jnp.zeros((), jnp.int32), val=jnp.zeros((2,)))


def _val_of(uid):
    """Deterministic ballast from the identity (numpy and jnp both work)."""
    f = uid.astype(np.float32) if isinstance(uid, np.ndarray) else uid.astype(jnp.float32)
    stack = np.stack if isinstance(uid, np.ndarray) else jnp.stack
    return stack([f * 0.5, f % 7.0], axis=-1)


def _seed_queue(sc: Scenario, capacity: int):
    """Round-0 emissions as a rank-stacked global queue (numpy, clipped at
    ``capacity`` with the clip counted — mirrors a device ``enqueue``)."""
    R, C, E = sc.num_ranks, capacity, sc.emits_per_round
    uid = np.zeros((R * C,), np.int32)
    dest = np.full((R * C,), Q.DISCARD, np.int32)
    count = np.zeros((R,), np.int32)
    drops = np.zeros((R,), np.int32)
    for rank in range(R):
        lanes = np.nonzero(sc.dests[0, rank] >= 0)[0]
        n = min(len(lanes), C)
        for j, e in enumerate(lanes[:n]):
            uid[rank * C + j] = sc.uid(0, rank, int(e))
            dest[rank * C + j] = sc.dests[0, rank, e]
        count[rank] = n
        drops[rank] = len(lanes) - n
    return Q.WorkQueue(
        items=ChaosItem(uid=jnp.asarray(uid), val=jnp.asarray(_val_of(uid))),
        dest=jnp.asarray(dest),
        count=jnp.asarray(count),
        drops=jnp.asarray(drops),
    )


def _make_ctx(
    mesh: Mesh,
    *,
    capacity: int,
    axis_name="data",
    overflow: str = "retain",
    exchange: str = "padded",
    marshal: str = "sort",
    sort_method: str = "pack",
    use_pallas: bool = False,
    peer_capacity: int = 0,
    fast_size: int = 0,
    level_sizes=(),
    level_capacities=(),
    telemetry: bool = True,
    max_rounds: int = 64,
    pipeline_shards: int = 1,
    flow: str = "open",
    emit_reserve: int = -1,
) -> RafiContext:
    """The scenario context: ``telemetry_window`` pinned to ``max_rounds+1``
    so the ring records EVERY forward of the burst (the trajectory oracles
    compare against the full trace)."""
    return RafiContext(
        mesh,
        chaos_proto(),
        axis_name=axis_name,
        capacity=capacity,
        peer_capacity=peer_capacity,
        exchange=exchange,
        marshal=marshal,
        sort_method=sort_method,
        use_pallas=use_pallas,
        fast_size=fast_size,
        level_sizes=level_sizes,
        level_capacities=level_capacities,
        telemetry=telemetry,
        telemetry_window=max_rounds + 1,
        overflow=overflow,
        pipeline_shards=pipeline_shards,
        flow=flow,
        emit_reserve=emit_reserve,
    )


def _make_round_fn(ctx: RafiContext, sc: Scenario):
    """Consume arrivals into the (cnt, Σuid, Σuid²) checksums; emit schedule
    row ``rnd + 1``.  The emission law is pinned to the SCENARIO's rank
    count, so a drain-phase resume on a smaller mesh (elastic restore) keeps
    the same uid identities — past the schedule the mask kills emission and
    the round_fn is a pure consumer on any mesh."""
    R, E = sc.num_ranks, sc.emits_per_round
    C = ctx.cfg.capacity
    dests_dev = jnp.asarray(sc.dests)  # (rounds, R, E) — closed over, static
    axes = flatten_axis_names(ctx.cfg.axis_name)

    def round_fn(q_in, aux, rnd):
        me = jax.lax.axis_index(axes)
        lane = jnp.arange(C)
        valid = lane < q_in.count
        u = q_in.items.uid.astype(jnp.uint32)
        z = jnp.zeros_like(u)
        cnt, s, s2 = aux
        cnt = cnt + jnp.sum(valid).astype(jnp.uint32)
        s = s + jnp.sum(jnp.where(valid, u, z))
        s2 = s2 + jnp.sum(jnp.where(valid, u * u, z))
        # body iteration rnd emits schedule row rnd + 1 (row 0 seeded q0);
        # ranks beyond the schedule (elastic resume) emit nothing
        er = rnd + 1
        src = jnp.minimum(me, R - 1)
        row = dests_dev[jnp.clip(er, 0, sc.rounds - 1), src]  # (E,)
        mask = (er < sc.rounds) & (row >= 0) & (me < R)
        uid = ((er * R + src) * E + jnp.arange(E)).astype(jnp.int32)
        out = Q.make_queue(chaos_proto(), C)
        out = Q.enqueue(
            out,
            ChaosItem(uid=uid, val=_val_of(uid)),
            jnp.where(mask, row, Q.DISCARD).astype(jnp.int32),
            mask,
        )
        return out, (cnt, s, s2)

    return round_fn


def _flat_schedule(sc: Scenario):
    """The schedule flattened per rank into emission order — the layout the
    credit-gated emitter walks with a cursor.  Returns ``(dest (R, K) i32,
    uid (R, K) i32, prefix (R, rounds) i32)`` where ``prefix[rank, r]`` is
    the number of schedule entries in rounds ``0..r`` inclusive and ``K`` is
    the longest per-rank entry list (short ranks are zero-padded — the
    cursor never reaches the pad)."""
    R, E = sc.num_ranks, sc.emits_per_round
    per = [[] for _ in range(R)]
    for r in range(sc.rounds):
        for rank in range(R):
            for e in range(E):
                d = int(sc.dests[r, rank, e])
                if d >= 0:
                    per[rank].append((d, int(sc.uid(r, rank, e))))
    K = max(1, max(len(p) for p in per))
    dest = np.zeros((R, K), np.int32)
    uid = np.zeros((R, K), np.int32)
    for rank, p in enumerate(per):
        for k, (d, u) in enumerate(p):
            dest[rank, k] = d
            uid[rank, k] = u
    prefix = (
        np.cumsum((np.asarray(sc.dests) >= 0).sum(axis=2), axis=0)
        .T.astype(np.int32)
    )
    return dest, uid, prefix


def _make_gated_round_fn(ctx: RafiContext, sc: Scenario):
    """The credit-flow emitter: same consumption/checksum law as
    :func:`_make_round_fn`, but emission is CURSOR-based and bounded by the
    drive's ``headroom`` keyword (ISSUE 9).  Instead of firing schedule row
    ``rnd + 1`` unconditionally, the rank keeps a cursor into its flattened
    schedule and each round emits ``min(backlog, headroom)`` entries from
    it — schedule rows the gate defers are emitted later, identities
    unchanged, so the delivered-checksum oracle applies verbatim while the
    emission TIMING adapts to receiver pressure (this is what a well-behaved
    backpressure-aware application does; the drive still counts any excess
    an ill-behaved app emits as ``emit_overflow``)."""
    R = sc.num_ranks
    C = ctx.cfg.capacity
    dest_np, uid_np, prefix_np = _flat_schedule(sc)
    K = dest_np.shape[1]
    dest_dev = jnp.asarray(dest_np)
    uid_dev = jnp.asarray(uid_np)
    prefix_dev = jnp.asarray(prefix_np)
    axes = flatten_axis_names(ctx.cfg.axis_name)

    def round_fn(q_in, aux, rnd, headroom=None):
        me = jax.lax.axis_index(axes)
        lane = jnp.arange(C)
        valid = lane < q_in.count
        u = q_in.items.uid.astype(jnp.uint32)
        z = jnp.zeros_like(u)
        cnt, s, s2, cursor = aux
        cnt = cnt + jnp.sum(valid).astype(jnp.uint32)
        s = s + jnp.sum(jnp.where(valid, u, z))
        s2 = s2 + jnp.sum(jnp.where(valid, u * u, z))
        # due = everything scheduled through row rnd + 1 (row 0 seeded q0);
        # emit the oldest un-emitted entries that fit the round's headroom
        src = jnp.minimum(me, R - 1)
        er = jnp.clip(rnd + 1, 0, sc.rounds - 1)
        want = jnp.where(me < R, prefix_dev[src, er], 0).astype(jnp.int32)
        have = cursor[0].astype(jnp.int32)
        n = jnp.clip(want - have, 0, headroom)
        idx = jnp.clip(have + lane, 0, K - 1)
        mask = lane < n
        uid = jnp.take(uid_dev[src], idx)
        row = jnp.take(dest_dev[src], idx)
        out = Q.make_queue(chaos_proto(), C)
        out = Q.enqueue(
            out,
            ChaosItem(uid=uid, val=_val_of(uid)),
            jnp.where(mask, row, Q.DISCARD).astype(jnp.int32),
            mask,
        )
        return out, (cnt, s, s2, (cursor + n).astype(jnp.int32))

    return round_fn


def _aux0(num_ranks: int):
    return tuple(jnp.zeros((num_ranks,), jnp.uint32) for _ in range(3))


def _cursor0(sc: Scenario):
    """Initial per-rank schedule cursor: row 0 is consumed by the seed queue
    (its capacity clips are counted drops, still 'emitted')."""
    return (np.asarray(sc.dests[0]) >= 0).sum(axis=1).astype(np.int32)


def _result_dict(sc: Scenario, q, aux, rounds, done, *, cfg=None, ring=None) -> Dict:
    cnt, s, s2 = aux[:3]
    delivered = np.stack(
        [np.asarray(cnt), np.asarray(s), np.asarray(s2)], axis=-1
    ).astype(np.uint32)
    # a cursor-gated run (credit flow) may be truncated by max_rounds with
    # schedule entries never emitted: the cursor, not the schedule, says how
    # many rows were actually put in flight (on a completed run they agree)
    emitted = (
        int(np.asarray(aux[3]).astype(np.int64).sum()) if len(aux) > 3
        else sc.emitted
    )
    res = {
        "scenario": sc.name,
        "delivered": delivered,
        "delivered_total": int(delivered[:, 0].sum()),
        "emitted": emitted,
        "resident": int(np.asarray(q.count).sum()),
        "drops": int(np.asarray(q.drops).sum()),
        "rounds": int(np.asarray(rounds)),
        "done": bool(np.asarray(done)),
    }
    res["lost"] = (
        res["emitted"] - res["delivered_total"] - res["resident"] - res["drops"]
    )
    if ring is not None:
        summary = TS.summarize(ring, tier_capacities=TS.tier_capacities(cfg))
        res["retained_rows"] = summary["retained_rows"]
        res["age_max"] = summary["age_max"]
        res["goodput"] = summary["goodput"]
        res["emit_overflow"] = summary["emit_overflow"]
        res["recv_drops"] = summary["recv_drops"]
        trace = TS.ring_trace(ring)
        res["retained_trace"] = trace["retained_rows"]
        res["age_trace"] = trace["age_max"]
        res["recv_trace"] = trace["recv_total"]
        res["wire_rows"] = int(np.asarray(trace["recv_total"]).sum())
        # first-class recorder field since PR 10 (== recv_drops on flat
        # routes; hierarchically it also counts post-first-hop stage cuts)
        res["wasted_wire_rows"] = int(np.asarray(trace["wasted_wire_rows"]).sum())
        res["wasted_trace"] = trace["wasted_wire_rows"]
        # per-round emission clips: with wasted_trace this is the complete
        # drop chronology of a retain-mode run — per round, every dropped
        # row is either an emission clip or a receiver wire cut, so
        # Σ (emit_trace + wasted_trace) must equal the queue's own drop
        # counter (the PR-10 recorder identity, tested in test_obs.py)
        res["emit_trace"] = trace["emit_overflow"]
    return res


def run_scenario(
    mesh: Mesh,
    sc: Scenario,
    *,
    capacity: int,
    health=None,
    max_rounds: int = 64,
    **cfg_kwargs,
) -> Dict:
    """Drive ``sc`` through the configured forwarding stack; return the
    accounting dict (see module docstring for the conservation identity).

    Keys: ``delivered`` (R, 3) uint32 checksums, ``delivered_total``,
    ``emitted``, ``resident``, ``drops``, ``lost``, ``rounds``, ``done`` —
    plus, with telemetry, burst totals ``retained_rows`` / ``age_max`` and
    the per-round ``retained_trace`` / ``age_trace`` / ``recv_trace``
    chronologies from the full-window ring.  ``health`` (optional ``(R,)``
    bool mask, constant for the burst) re-addresses traffic away from
    unhealthy ranks."""
    from repro.obs import trace as OT

    ctx = _make_ctx(mesh, capacity=capacity, max_rounds=max_rounds, **cfg_kwargs)
    R = sc.num_ranks
    if ctx.num_ranks != R:
        raise ValueError(
            f"scenario is laid out for {R} ranks but the mesh axis has "
            f"{ctx.num_ranks}"
        )
    cfg = ctx.cfg
    retain = cfg.overflow == "retain"
    credit = cfg.flow == "credit"
    spec = ctx._spec
    with OT.span(
        "chaos.run_scenario", OT.CAT_CHAOS,
        scenario=sc.name, num_ranks=R, capacity=capacity,
        flow=cfg.flow, overflow=cfg.overflow, exchange=cfg.exchange,
        max_rounds=max_rounds,
    ) as sp:
        if health is not None:
            # fault-injection record: which ranks the burst routes around
            OT.event(
                "chaos.health_mask", OT.CAT_CHAOS, scenario=sc.name,
                unhealthy=[
                    i for i, h in enumerate(np.asarray(health)) if not h
                ],
            )
        rfn = _make_gated_round_fn(ctx, sc) if credit else _make_round_fn(ctx, sc)
        aux_specs = (spec,) * 4 if credit else (spec,) * 3
        aux0 = _aux0(R) + ((jnp.asarray(_cursor0(sc)),) if credit else ())
        drive = ctx.run_until_done(
            rfn,
            aux_specs=aux_specs,
            max_rounds=max_rounds,
            with_health=health is not None,
        )
        args = (_seed_queue(sc, cfg.capacity), aux0)
        if health is not None:
            args = args + (jnp.asarray(np.asarray(health).astype(bool)),)
        out = drive(*args)
        q, aux, rounds, done = out[:4]
        rest = out[4:]
        if retain:
            rest = rest[1:]  # final per-lane ages — accounted via the ring here
        ring = rest[0] if cfg.telemetry else None
        res = _result_dict(sc, q, aux, rounds, done, cfg=cfg, ring=ring)
        sp.set(
            rounds=res["rounds"], done=res["done"], drops=res["drops"],
            delivered_total=res["delivered_total"],
            goodput=res.get("goodput"),
        )
    return res


def run_scenario_checkpointed(
    mesh: Mesh,
    sc: Scenario,
    *,
    capacity: int,
    ckpt_dir,
    checkpoint_every: int = 4,
    preempt_at: Optional[int] = None,
    resume_mesh: Optional[Mesh] = None,
    resume_capacity: Optional[int] = None,
    health=None,
    keep: int = 64,
    max_rounds: int = 64,
    **cfg_kwargs,
) -> Dict:
    """Drive ``sc`` through the checkpointed recovery drive.

    * ``preempt_at=None`` — uninterrupted checkpointed run (the reference
      trajectory; boundaries land on disk every ``checkpoint_every``
      rounds).
    * ``preempt_at=k`` — the drive halts at the last boundary not past
      round ``k`` (simulated preemption), then ``resume_run`` continues it
      from disk — on ``resume_mesh`` / ``resume_capacity`` if given (the
      elastic R → R′ path; the scenario must be in its drain phase by the
      preempt boundary, i.e. all emission rounds complete, since retired
      ranks cannot replay their scheduled emissions).
    * ``health`` — mask or host callable ``rnd → mask``, re-read each
      segment boundary (rank brownout mid-burst).

    Returns the :func:`run_scenario` accounting dict plus ``steps`` (the
    published boundary rounds), ``preempted`` and ``ckpt_dir``.
    """
    from repro.obs import trace as OT

    ctx = _make_ctx(mesh, capacity=capacity, max_rounds=max_rounds, **cfg_kwargs)
    if ctx.num_ranks != sc.num_ranks:
        raise ValueError(
            f"scenario is laid out for {sc.num_ranks} ranks but the mesh "
            f"axis has {ctx.num_ranks}"
        )
    spec = ctx._spec
    credit = ctx.cfg.flow == "credit"

    def _rfn(c):
        return _make_gated_round_fn(c, sc) if credit else _make_round_fn(c, sc)

    def _specs(c):
        return (c._spec,) * (4 if credit else 3)

    aux0 = _aux0(ctx.num_ranks) + (
        (jnp.asarray(_cursor0(sc)),) if credit else ()
    )
    chaos_cm = OT.span(
        "chaos.run_scenario_checkpointed", OT.CAT_CHAOS,
        scenario=sc.name, num_ranks=ctx.num_ranks, capacity=capacity,
        checkpoint_every=checkpoint_every, max_rounds=max_rounds,
        flow=ctx.cfg.flow, overflow=ctx.cfg.overflow,
    )
    chaos_sp = chaos_cm.__enter__()
    if preempt_at is not None:
        OT.event(
            "chaos.preempt_scheduled", OT.CAT_CHAOS,
            scenario=sc.name, preempt_at=preempt_at,
        )
    res = recovery.run_checkpointed(
        ctx,
        _rfn(ctx),
        _seed_queue(sc, ctx.cfg.capacity),
        aux0,
        aux_specs=_specs(ctx),
        ckpt_dir=ckpt_dir,
        checkpoint_every=checkpoint_every,
        max_rounds=max_rounds,
        health=health,
        keep=keep,
        halt_after_round=preempt_at,
    )
    preempted = res is None
    if preempted:
        rmesh = resume_mesh if resume_mesh is not None else mesh
        rcap = resume_capacity if resume_capacity is not None else capacity
        ctx = _make_ctx(rmesh, capacity=rcap, max_rounds=max_rounds, **cfg_kwargs)
        OT.event(
            "chaos.elastic_resume", OT.CAT_CHAOS, scenario=sc.name,
            resume_ranks=ctx.num_ranks, resume_capacity=rcap,
            elastic=(ctx.num_ranks != sc.num_ranks or rcap != capacity),
        )
        spec = ctx._spec
        aux_like = tuple(np.zeros((ctx.num_ranks,), np.uint32) for _ in range(3))
        if credit:
            aux_like = aux_like + (np.zeros((ctx.num_ranks,), np.int32),)
        res = recovery.resume_run(
            ctx,
            _rfn(ctx),
            ckpt_dir,
            aux_specs=_specs(ctx),
            aux_like=aux_like,
            checkpoint_every=checkpoint_every,
            max_rounds=max_rounds,
            health=health,
            keep=keep,
        )
        assert res is not None  # resume passes no halt_after_round
    out = _result_dict(
        sc, res["q"], res["aux"], res["rounds"], res["done"],
        cfg=ctx.cfg, ring=res.get("ring"),
    )
    steps = []
    if ckpt_dir is not None and ckpt.latest_step(ckpt_dir) is not None:
        from pathlib import Path

        steps = sorted(
            int(p.name.split("_")[1])
            for p in Path(ckpt_dir).iterdir()
            if p.name.startswith("step_") and not p.name.endswith(".tmp")
        )
    out["steps"] = steps
    out["preempted"] = preempted
    out["ckpt_dir"] = ckpt_dir
    chaos_sp.set(
        rounds=out["rounds"], done=out["done"], preempted=preempted,
        boundaries=len(steps),
    )
    chaos_cm.__exit__(None, None, None)
    return out


def boundary_digests(ckpt_dir) -> Dict[int, tuple]:
    """``{boundary round: (sha256, …) of every carry leaf}`` for each
    published checkpoint — the bit-exactness witness: two drives whose
    digests agree at a boundary held IDENTICAL forwarding state there
    (queue payloads, dests, ages, checksums, ring, counters — everything the
    trajectory depends on)."""
    from pathlib import Path

    out = {}
    for p in sorted(Path(ckpt_dir).iterdir()):
        if not p.name.startswith("step_") or p.name.endswith(".tmp"):
            continue
        step = int(p.name.split("_")[1])
        man = ckpt.load_manifest(ckpt_dir, step)
        out[step] = tuple(e["sha256"] for e in man["leaves"])
    return out

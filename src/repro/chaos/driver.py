"""Run a chaos scenario through the real on-device drive loop.

The driver turns a :class:`repro.chaos.scenarios.Scenario` into a
``RafiContext.run_until_done`` program: the seed queue carries round 0's
emissions, ``round_fn(…, rnd)`` emits schedule row ``rnd + 1`` (the drive's
initial forward consumes row 0, so body iteration ``rnd`` is emission round
``rnd + 1``) and folds every arrival into per-rank ``(count, Σuid, Σuid²)``
uint32 checksums — the same identity law the oracle computes from the
schedule alone.  Items are never re-forwarded by the app: one emission, one
delivery, so conservation (``emitted == delivered + resident + drops +
lost`` with ``lost == 0``) is checkable in every overflow mode and the
lossless law (``drops == 0`` too, in retain mode) is a pure array compare.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.chaos.scenarios import Scenario
from repro.core import queue as Q
from repro.core import work_item
from repro.core.context import RafiContext
from repro.core.forwarding import flatten_axis_names
from repro.telemetry import stats as TS

__all__ = ["ChaosItem", "chaos_proto", "run_scenario"]


@work_item
@dataclasses.dataclass
class ChaosItem:
    """A forwardable probe: identity for the checksums, a payload tail so
    the wire format moves more than the control word."""

    uid: jax.Array  # () i32 — the scenario's (round, rank, lane) identity
    val: jax.Array  # (2,) f32 — derived ballast, never checked


def chaos_proto() -> ChaosItem:
    return ChaosItem(uid=jnp.zeros((), jnp.int32), val=jnp.zeros((2,)))


def _val_of(uid):
    """Deterministic ballast from the identity (numpy and jnp both work)."""
    f = uid.astype(np.float32) if isinstance(uid, np.ndarray) else uid.astype(jnp.float32)
    stack = np.stack if isinstance(uid, np.ndarray) else jnp.stack
    return stack([f * 0.5, f % 7.0], axis=-1)


def _seed_queue(sc: Scenario, capacity: int):
    """Round-0 emissions as a rank-stacked global queue (numpy, clipped at
    ``capacity`` with the clip counted — mirrors a device ``enqueue``)."""
    R, C, E = sc.num_ranks, capacity, sc.emits_per_round
    uid = np.zeros((R * C,), np.int32)
    dest = np.full((R * C,), Q.DISCARD, np.int32)
    count = np.zeros((R,), np.int32)
    drops = np.zeros((R,), np.int32)
    for rank in range(R):
        lanes = np.nonzero(sc.dests[0, rank] >= 0)[0]
        n = min(len(lanes), C)
        for j, e in enumerate(lanes[:n]):
            uid[rank * C + j] = sc.uid(0, rank, int(e))
            dest[rank * C + j] = sc.dests[0, rank, e]
        count[rank] = n
        drops[rank] = len(lanes) - n
    return Q.WorkQueue(
        items=ChaosItem(uid=jnp.asarray(uid), val=jnp.asarray(_val_of(uid))),
        dest=jnp.asarray(dest),
        count=jnp.asarray(count),
        drops=jnp.asarray(drops),
    )


def run_scenario(
    mesh: Mesh,
    sc: Scenario,
    *,
    capacity: int,
    axis_name="data",
    overflow: str = "retain",
    exchange: str = "padded",
    marshal: str = "sort",
    sort_method: str = "pack",
    use_pallas: bool = False,
    peer_capacity: int = 0,
    fast_size: int = 0,
    level_sizes=(),
    level_capacities=(),
    telemetry: bool = True,
    max_rounds: int = 64,
) -> Dict:
    """Drive ``sc`` through the configured forwarding stack; return the
    accounting dict (see module docstring for the conservation identity).

    Keys: ``delivered`` (R, 3) uint32 checksums, ``delivered_total``,
    ``emitted``, ``resident``, ``drops``, ``lost``, ``rounds``, ``done`` —
    plus ``retained_rows`` / ``age_max`` (burst totals from the telemetry
    ring) when ``telemetry`` is on.  ``telemetry_window`` is pinned to
    ``max_rounds + 1`` so the ring records every forward of the burst (the
    trajectory oracles compare against the full trace)."""
    ctx = RafiContext(
        mesh,
        chaos_proto(),
        axis_name=axis_name,
        capacity=capacity,
        peer_capacity=peer_capacity,
        exchange=exchange,
        marshal=marshal,
        sort_method=sort_method,
        use_pallas=use_pallas,
        fast_size=fast_size,
        level_sizes=level_sizes,
        level_capacities=level_capacities,
        telemetry=telemetry,
        telemetry_window=max_rounds + 1,
        overflow=overflow,
    )
    R, C, E = sc.num_ranks, capacity, sc.emits_per_round
    if ctx.num_ranks != R:
        raise ValueError(
            f"scenario is laid out for {R} ranks but the mesh axis has "
            f"{ctx.num_ranks}"
        )
    dests_dev = jnp.asarray(sc.dests)  # (rounds, R, E) — closed over, static

    axes = flatten_axis_names(axis_name)

    def round_fn(q_in, aux, rnd):
        me = jax.lax.axis_index(axes)
        lane = jnp.arange(C)
        valid = lane < q_in.count
        u = q_in.items.uid.astype(jnp.uint32)
        z = jnp.zeros_like(u)
        cnt, s, s2 = aux
        cnt = cnt + jnp.sum(valid).astype(jnp.uint32)
        s = s + jnp.sum(jnp.where(valid, u, z))
        s2 = s2 + jnp.sum(jnp.where(valid, u * u, z))
        # body iteration rnd emits schedule row rnd + 1 (row 0 seeded q0)
        er = rnd + 1
        row = dests_dev[jnp.clip(er, 0, sc.rounds - 1), me]  # (E,)
        mask = (er < sc.rounds) & (row >= 0)
        uid = ((er * R + me) * E + jnp.arange(E)).astype(jnp.int32)
        out = Q.make_queue(chaos_proto(), C)
        out = Q.enqueue(
            out,
            ChaosItem(uid=uid, val=_val_of(uid)),
            jnp.where(mask, row, Q.DISCARD).astype(jnp.int32),
            mask,
        )
        return out, (cnt, s, s2)

    spec = ctx._spec
    drive = ctx.run_until_done(
        round_fn, aux_specs=(spec, spec, spec), max_rounds=max_rounds
    )
    aux0 = tuple(jnp.zeros((R,), jnp.uint32) for _ in range(3))
    out = drive(_seed_queue(sc, C), aux0)
    q, (cnt, s, s2), rounds, done = out[:4]

    delivered = np.stack(
        [np.asarray(cnt), np.asarray(s), np.asarray(s2)], axis=-1
    ).astype(np.uint32)
    res = {
        "scenario": sc.name,
        "delivered": delivered,
        "delivered_total": int(delivered[:, 0].sum()),
        "emitted": sc.emitted,
        "resident": int(np.asarray(q.count).sum()),
        "drops": int(np.asarray(q.drops).sum()),
        "rounds": int(np.asarray(rounds)),
        "done": bool(np.asarray(done)),
    }
    res["lost"] = (
        res["emitted"] - res["delivered_total"] - res["resident"] - res["drops"]
    )
    if telemetry:
        summary = TS.summarize(
            out[4], tier_capacities=TS.tier_capacities(ctx.cfg)
        )
        res["retained_rows"] = summary["retained_rows"]
        res["age_max"] = summary["age_max"]
    return res

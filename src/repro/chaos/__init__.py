"""Deterministic chaos harness for the lossless-forwarding law (ISSUE 6).

Forwarding under ``overflow="retain"`` promises: NO item is ever lost to a
sender- or tier-capacity clamp, and no retained item starves (bounded age).
That promise is easy to state and easy to break silently — a miscounted
spill, a wrong merge order, a termination psum that misses retained rows.
This package manufactures the adversarial traffic that would expose each of
those bugs, deterministically:

* :mod:`scenarios` — seeded emission schedules (capacity drought, rotating
  hot-spot, burst storm, all-to-one convergecast) as plain numpy arrays, so
  a failure replays bit-identically from the scenario name + seed alone;
* :mod:`oracle` — the ground truth: per-destination delivery checksums
  derived from the schedule (what MUST arrive, independent of any routing
  code) plus an exact numpy FIFO simulator of the flat padded retain
  pipeline (what must arrive WHEN, with which ages);
* :mod:`driver` — runs a schedule through the real on-device drive loop
  (``RafiContext.run_until_done``) accumulating the same checksums on
  arrival, so device vs oracle comparison is a pure array equality.

The property gated by tests and ``benchmarks/run.py --chaos``: retain mode
delivers EVERY emitted item (checksums match, drops stay zero) with
``age_max`` under the :func:`repro.roofline.analysis.spill_drain_model`
bound, on undersized capacities where drop mode loses >20% of the traffic.

ISSUE 7 widens the gauntlet to the recovery law: :func:`rank_brownout` /
:func:`brownout_mask` (mid-burst draining), and the driver's
:func:`run_scenario_checkpointed` (checkpoint every W rounds, simulated
preemption, resume — optionally on a different mesh) with
:func:`boundary_digests` as the bit-exactness witness.

ISSUE 9 widens it again to the backpressure law: :func:`sustained_overload`
/ :func:`incast_collapse` keep the offered load above any bounded drain
rate for the whole schedule, the driver grows a cursor-gated emitter that
respects the drive's ``headroom`` budget, and :func:`simulate_flat_credit`
is the round-for-round numpy twin of the credit pipeline (zero-credit cold
start, reserve + liveness-floor adverts, floor-share apportionment).  The
gate: ``flow="credit"`` delivers everything with ZERO receiver drops and
bounded occupancy on schedules where ``flow="open"`` wastes >30% of its
wire bytes on rows the receiver throws away.
"""
from repro.chaos.scenarios import (
    Scenario,
    all_scenarios,
    brownout_mask,
    burst_storm,
    capacity_drought,
    convergecast,
    incast_collapse,
    overload_scenarios,
    rank_brownout,
    rotating_hotspot,
    sustained_overload,
)
from repro.chaos.oracle import (
    expected_by_rank,
    simulate_flat_credit,
    simulate_flat_retain,
)
from repro.chaos.driver import (
    ChaosItem,
    boundary_digests,
    chaos_proto,
    run_scenario,
    run_scenario_checkpointed,
)

__all__ = [
    "Scenario",
    "all_scenarios",
    "brownout_mask",
    "burst_storm",
    "capacity_drought",
    "convergecast",
    "rank_brownout",
    "rotating_hotspot",
    "sustained_overload",
    "incast_collapse",
    "overload_scenarios",
    "expected_by_rank",
    "simulate_flat_credit",
    "simulate_flat_retain",
    "ChaosItem",
    "boundary_digests",
    "chaos_proto",
    "run_scenario",
    "run_scenario_checkpointed",
]

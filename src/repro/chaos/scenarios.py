"""Deterministic fault-injection traffic schedules.

A :class:`Scenario` is nothing but a numpy destination tensor: round ``r``,
rank ``me``, emit lane ``e`` either targets ``dests[r, me, e]`` or sits out
(``-1``).  Everything downstream — the device drive, the numpy oracle, the
expected checksums — derives from this one tensor, so the whole harness is
replayable from ``(name, seed)``.

Every generator guarantees at least one emission in EVERY round: the drive
loop terminates when the global in-flight count hits zero, so a globally
silent round with a drained pipeline would end the run before later rounds
got to emit (that would be a scenario bug, not a forwarding bug — guarded in
``__post_init__``).

The four shapes target distinct failure modes of the retain machinery:

* ``capacity_drought`` — uniform traffic run (by the harness) under a
  starved ``peer_capacity``: every rank spills every round, exercising the
  steady-state split/merge/age plumbing.
* ``rotating_hotspot`` — the clamp pressure MOVES each round; retained rows
  addressed to the old hot-spot must coexist with fresh rows flooding the
  new one (stale-dest handling, FIFO priority across destinations).
* ``burst_storm`` — quiet rounds punctuated by full-width bursts: the spill
  population collapses to (near) zero and rebuilds, exercising both
  boundary directions of the retained-count arithmetic.
* ``convergecast`` — every rank sends everything to rank 0: the worst-case
  single-destination backlog, the scenario where anti-starvation aging and
  the :func:`repro.roofline.analysis.spill_drain_model` bound bite hardest.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "Scenario",
    "capacity_drought",
    "rotating_hotspot",
    "burst_storm",
    "convergecast",
    "rank_brownout",
    "brownout_mask",
    "sustained_overload",
    "incast_collapse",
    "overload_scenarios",
    "all_scenarios",
]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """An emission schedule: who sends what where, each round.

    Attributes:
      name: stable identifier (test ids, benchmark JSON keys).
      num_ranks: mesh size R the schedule is laid out for.
      rounds: emitting rounds (the drive keeps running past them until the
        pipeline drains).
      emits_per_round: emit lanes E per rank per round.
      dests: ``(rounds, R, E) int32`` — destination rank, or ``-1`` for a
        lane that sits the round out.
    """

    name: str
    num_ranks: int
    rounds: int
    emits_per_round: int
    dests: np.ndarray

    def __post_init__(self):
        d = np.asarray(self.dests)
        if d.shape != (self.rounds, self.num_ranks, self.emits_per_round):
            raise ValueError(
                f"dests shape {d.shape} != (rounds, R, E) = "
                f"({self.rounds}, {self.num_ranks}, {self.emits_per_round})"
            )
        if d.max() >= self.num_ranks or d.min() < -1:
            raise ValueError("dests entries must be in [-1, num_ranks)")
        quiet = np.nonzero((d >= 0).reshape(self.rounds, -1).sum(axis=1) == 0)[0]
        if quiet.size:
            raise ValueError(
                f"round(s) {quiet.tolist()} emit nothing anywhere — the drive "
                "would terminate before reaching them (generators must plant "
                "a heartbeat emission)"
            )

    @property
    def emitted(self) -> int:
        """Total items the schedule puts in flight."""
        return int((np.asarray(self.dests) >= 0).sum())

    def uid(self, rnd: int, rank: int, lane: int):
        """The item identity law — shared verbatim by the device driver, the
        numpy oracle, and the checksums: unique, dense, deterministic."""
        return (rnd * self.num_ranks + rank) * self.emits_per_round + lane


def _heartbeat(dests: np.ndarray) -> np.ndarray:
    """Plant one self-addressed emission from rank 0 into any silent round."""
    for r in range(dests.shape[0]):
        if (dests[r] >= 0).sum() == 0:
            dests[r, 0, 0] = 0
    return dests


def capacity_drought(
    num_ranks: int = 8, rounds: int = 6, emits_per_round: int = 8, seed: int = 0
) -> Scenario:
    """Uniform random traffic, ~70% duty cycle — pressure comes from the
    harness starving ``peer_capacity``, not from the shape."""
    rng = np.random.default_rng(seed)
    d = rng.integers(0, num_ranks, size=(rounds, num_ranks, emits_per_round))
    mask = rng.random((rounds, num_ranks, emits_per_round)) < 0.7
    d = np.where(mask, d, -1).astype(np.int32)
    return Scenario(
        "capacity_drought", num_ranks, rounds, emits_per_round, _heartbeat(d)
    )


def rotating_hotspot(
    num_ranks: int = 8,
    rounds: int = 8,
    emits_per_round: int = 8,
    hot_frac: float = 0.75,
    seed: int = 1,
) -> Scenario:
    """Round ``r``'s traffic concentrates on rank ``r % R``; the backlog
    built against one hot-spot must drain while the next one floods."""
    rng = np.random.default_rng(seed)
    shape = (rounds, num_ranks, emits_per_round)
    uniform = rng.integers(0, num_ranks, size=shape)
    hot = (np.arange(rounds) % num_ranks)[:, None, None]
    d = np.where(rng.random(shape) < hot_frac, hot, uniform).astype(np.int32)
    return Scenario(
        "rotating_hotspot", num_ranks, rounds, emits_per_round, _heartbeat(d)
    )


def burst_storm(
    num_ranks: int = 8,
    rounds: int = 9,
    emits_per_round: int = 16,
    period: int = 3,
    seed: int = 2,
) -> Scenario:
    """Every ``period``-th round every rank fires ALL its lanes (uniform
    destinations); between bursts only a heartbeat trickle flows, so the
    spill population must fully rebuild each storm."""
    rng = np.random.default_rng(seed)
    shape = (rounds, num_ranks, emits_per_round)
    d = rng.integers(0, num_ranks, size=shape).astype(np.int32)
    storm = (np.arange(rounds) % period == 0)[:, None, None]
    trickle = np.zeros(shape, bool)
    trickle[:, 0, 0] = True  # rank 0 lane 0 keeps the drive alive
    d = np.where(storm | trickle, d, -1).astype(np.int32)
    return Scenario("burst_storm", num_ranks, rounds, emits_per_round, _heartbeat(d))


def convergecast(
    num_ranks: int = 8, rounds: int = 4, emits_per_round: int = 12, seed: int = 3
) -> Scenario:
    """All-to-one: every rank's every lane targets rank 0 — the maximal
    single-destination backlog (the aging bound's worst case)."""
    del seed  # fully deterministic; kept for a uniform generator signature
    d = np.zeros((rounds, num_ranks, emits_per_round), np.int32)
    return Scenario("convergecast", num_ranks, rounds, emits_per_round, d)


def rank_brownout(
    num_ranks: int = 8,
    rounds: int = 8,
    emits_per_round: int = 8,
    seed: int = 4,
) -> Scenario:
    """Uniform ~80% duty-cycle traffic that keeps addressing EVERY rank for
    the whole schedule — run it with a ``health`` mask that browns out ranks
    mid-burst (see :func:`brownout_mask`) and the pressure is entirely on
    the ISSUE 7 draining remap: emissions and retained backlog aimed at the
    dark ranks must be re-addressed without losing a row."""
    rng = np.random.default_rng(seed)
    shape = (rounds, num_ranks, emits_per_round)
    d = rng.integers(0, num_ranks, size=shape)
    mask = rng.random(shape) < 0.8
    d = np.where(mask, d, -1).astype(np.int32)
    return Scenario(
        "rank_brownout", num_ranks, rounds, emits_per_round, _heartbeat(d)
    )


def brownout_mask(num_ranks: int, down=(2, 5), down_from: int = 3):
    """Host health schedule for a brownout: every rank healthy until round
    ``down_from``, then the ``down`` ranks go dark for good.  Returns a
    callable ``rnd -> (R,) bool`` in the form ``run_checkpointed`` /
    ``resume_run`` re-evaluate at every segment boundary."""
    down = tuple(int(r) for r in down)
    for r in down:
        if not 0 <= r < num_ranks:
            raise ValueError(f"brownout rank {r} outside [0, {num_ranks})")
    if len(down) >= num_ranks:
        raise ValueError("a brownout must leave at least one healthy rank")

    def health(rnd: int) -> np.ndarray:
        h = np.ones((num_ranks,), bool)
        if rnd >= down_from:
            h[list(down)] = False
        return h

    return health


def sustained_overload(
    num_ranks: int = 8,
    rounds: int = 12,
    emits_per_round: int = 12,
    hot=(0, 1),
    hot_frac: float = 0.67,
    seed: int = 9,
) -> Scenario:
    """Every rank fires EVERY lane EVERY round, with most traffic pinned on
    a FIXED hot pair of ranks — unlike :func:`rotating_hotspot` the pressure
    never moves, so the hot receivers' offered load exceeds their drain
    capacity for the WHOLE schedule.  Open flow keeps shipping the full
    fan-in and sheds the excess at the hot receivers round after round
    (wasted wire); credit flow must hold the excess at the SOURCE and drain
    it losslessly after the schedule ends (the ISSUE 9 graceful-degradation
    gate).  Uniform sustained traffic would not do: consumption keeps up
    with symmetric arrivals, so receivers never overflow — overload that
    wastes wire needs concentration that PERSISTS."""
    rng = np.random.default_rng(seed)
    shape = (rounds, num_ranks, emits_per_round)
    uniform = rng.integers(0, num_ranks, size=shape)
    hot = np.asarray(hot, np.int32)
    hotdest = hot[rng.integers(0, hot.size, size=shape)]
    d = np.where(rng.random(shape) < hot_frac, hotdest, uniform).astype(np.int32)
    return Scenario("sustained_overload", num_ranks, rounds, emits_per_round, d)


def incast_collapse(
    num_ranks: int = 8, rounds: int = 10, emits_per_round: int = 8, seed: int = 10
) -> Scenario:
    """Sustained full-width convergecast: every rank's every lane targets
    rank 0 for ``rounds`` straight rounds — R·E rows per round against ONE
    queue of bounded capacity.  The classic TCP-incast collapse shape: open
    flow ships the full fan-in and throws most of it away at rank 0; credit
    flow apportions rank 0's real free space among the R senders and ships
    nothing it cannot admit."""
    del seed  # fully deterministic; kept for a uniform generator signature
    d = np.zeros((rounds, num_ranks, emits_per_round), np.int32)
    return Scenario("incast_collapse", num_ranks, rounds, emits_per_round, d)


def overload_scenarios(num_ranks: int = 8, seed: int = 0):
    """The backpressure gauntlet (ISSUE 9): sustained aggregate overload and
    single-destination incast — the two shapes where open flow livelocks on
    wasted wire and credit flow must degrade gracefully instead."""
    return [
        sustained_overload(num_ranks, seed=seed + 9),
        incast_collapse(num_ranks, seed=seed + 10),
    ]


def all_scenarios(num_ranks: int = 8, seed: int = 0):
    """The standard gauntlet, one of each shape."""
    return [
        capacity_drought(num_ranks, seed=seed),
        rotating_hotspot(num_ranks, seed=seed + 1),
        burst_storm(num_ranks, seed=seed + 2),
        convergecast(num_ranks, seed=seed + 3),
    ]

"""Ground truth for the chaos harness: what must arrive, and when.

Two independent oracles, deliberately at different abstraction levels:

* :func:`expected_by_rank` reads ONLY the schedule — per-destination
  ``(count, sum(uid), sum(uid²))`` checksums mod 2³². Any lossless routing
  implementation must reproduce these exactly; it knows nothing about
  rounds, capacities, or retention, so it cannot share a bug with the code
  under test.
* :func:`simulate_flat_retain` is an exact round-by-round numpy twin of the
  flat padded retain pipeline (the drive loop's split/merge + the sender
  clamp's FIFO spill + receiver admission), tracking per-forward retained
  counts and ages.  It validates the retain machinery's *trajectory* —
  delivery timing, anti-starvation ages — not just its end state.

Checksum arithmetic is uint32 with wraparound on both sides (the device
accumulates in uint32; here we accumulate in Python ints and reduce mod
2³² at the end — homomorphic, so the results are bit-comparable).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.chaos.scenarios import Scenario

__all__ = ["expected_by_rank", "simulate_flat_credit", "simulate_flat_retain"]

_M32 = 1 << 32


def expected_by_rank(sc: Scenario) -> np.ndarray:
    """``(R, 3) uint32``: per destination rank, the count / uid-sum /
    uid²-sum (mod 2³²) of every item the schedule addresses to it."""
    R = sc.num_ranks
    acc = [[0, 0, 0] for _ in range(R)]
    r_idx, rank_idx, e_idx = np.nonzero(np.asarray(sc.dests) >= 0)
    for r, rank, e in zip(r_idx, rank_idx, e_idx):
        d = int(sc.dests[r, rank, e])
        u = int(sc.uid(int(r), int(rank), int(e)))
        acc[d][0] += 1
        acc[d][1] += u
        acc[d][2] += (u * u) % _M32
    return np.asarray([[c % _M32 for c in row] for row in acc], np.uint32)


def _emit_rows(sc: Scenario, rnd: int) -> List[List[List[int]]]:
    """Round ``rnd``'s fresh emissions per rank as ``[uid, dest, age=0]``
    rows, in emit-lane order (= the stable ``enqueue`` order on device)."""
    rows: List[List[List[int]]] = [[] for _ in range(sc.num_ranks)]
    if not 0 <= rnd < sc.rounds:
        return rows
    for rank in range(sc.num_ranks):
        for e in range(sc.emits_per_round):
            d = int(sc.dests[rnd, rank, e])
            if d >= 0:
                rows[rank].append([int(sc.uid(rnd, rank, e)), d, 0])
    return rows


def _health_table_np(h: np.ndarray) -> np.ndarray:
    """Numpy mirror of ``repro.core.health.health_table`` — ONE remap law,
    verified twice (any divergence here fails the brownout trajectory
    tests, not just an end-state checksum)."""
    h = np.asarray(h, bool)
    R = h.shape[0]
    table = np.arange(R)
    healthy = np.nonzero(h)[0]
    if healthy.size == 0:
        return table
    for d in range(R):
        if not h[d]:
            table[d] = healthy[d % healthy.size]
    return table


def simulate_flat_retain(
    sc: Scenario,
    *,
    peer_capacity: int,
    capacity: int,
    max_rounds: int = 64,
    health=None,
) -> Dict:
    """Exact numpy twin of ``run_until_done`` over a flat padded exchange
    with ``overflow="retain"`` — same event order the device executes:

      seed queue = round-0 emissions (clipped at ``capacity``, clip counted
      as drops) → forward → loop [deliver arrivals; append round ``rnd+1``
      emissions behind the retained front; forward] while the global
      in-flight count is positive and ``rnd < max_rounds``.

    A forward clamps each sender's per-destination traffic at
    ``peer_capacity`` rows in stable lane order (excess rows are retained
    with ``age + 1``), concatenates arrivals in source-rank order, and
    admits them behind the retained front up to ``capacity`` (excess is a
    counted receiver drop — sized away in the lossless gate).

    ``health`` mirrors the device's rank-draining remap: ``None``, a
    constant ``(R,) bool`` mask, or a callable ``forward_idx -> mask``
    (forward 0 is the seed routing; forward ``f >= 1`` is body round
    ``f - 1``'s).  At every forward the CURRENT mask's
    :func:`_health_table_np` rewrite is applied to each row's destination
    and sticks (retained rows carry the remapped dest onward — exactly what
    ``forward_work`` does to the queue's dest vector).

    Returns the final delivered checksums plus the per-forward
    ``retained_rows`` / ``age_max`` trajectories the device telemetry must
    reproduce."""
    R, C, S = sc.num_ranks, capacity, peer_capacity
    delivered = [[0, 0, 0] for _ in range(R)]
    drops = 0
    retained_trace: List[int] = []
    age_trace: List[int] = []
    fwd_idx = [0]

    def _mask_at(f: int):
        if health is None:
            return None
        return np.asarray(health(f) if callable(health) else health, bool)

    def forward(state):
        """state: per-rank [uid, dest, age] rows (retained front + fresh).
        Returns per-rank (retained_rows, arrival_uids) and the global
        in-flight total after the exchange."""
        nonlocal drops
        h = _mask_at(fwd_idx[0])
        fwd_idx[0] += 1
        if h is not None:
            table = _health_table_np(h)
            for rows in state:
                for row in rows:
                    row[1] = int(table[row[1]])
        shipped = [[[] for _ in range(R)] for _ in range(R)]  # [src][dst]
        retained = []
        for src in range(R):
            sent = [0] * R
            keep = []
            for uid, d, age in state[src]:
                if sent[d] < S:
                    sent[d] += 1
                    shipped[src][d].append(uid)
                else:
                    keep.append([uid, d, age + 1])
            retained.append(keep)
        out = []
        total = 0
        for dst in range(R):
            arrivals = [u for src in range(R) for u in shipped[src][dst]]
            keep = retained[dst]
            admit = min(len(arrivals), C - len(keep))
            drops += len(arrivals) - admit
            out.append((keep, arrivals[:admit]))
            total += len(keep) + admit
        retained_trace.append(sum(len(k) for k, _ in out))
        age_trace.append(max((r[2] for k, _ in out for r in k), default=0))
        return out, total

    # seed queue: round-0 emissions, clipped at capacity
    state = []
    for rank in range(R):
        rows = _emit_rows(sc, 0)[rank]
        drops += max(0, len(rows) - C)
        state.append(rows[:C])
    cur, total = forward(state)

    rnd = 0
    while total > 0 and rnd < max_rounds:
        emits = _emit_rows(sc, rnd + 1)
        state = []
        for rank in range(R):
            keep, arrivals = cur[rank]
            for u in arrivals:
                delivered[rank][0] += 1
                delivered[rank][1] += u
                delivered[rank][2] += (u * u) % _M32
            rows = keep + emits[rank]
            drops += max(0, len(rows) - C)
            state.append(rows[:C])
        cur, total = forward(state)
        rnd += 1

    return {
        "delivered": np.asarray(
            [[c % _M32 for c in row] for row in delivered], np.uint32
        ),
        "drops": drops,
        "rounds": rnd,
        "done": total == 0,
        "resident": total,
        "retained_trace": retained_trace,
        "age_trace": age_trace,
        "age_max": max(age_trace, default=0),
        "retained_rows": sum(retained_trace),
    }


def simulate_flat_credit(
    sc: Scenario,
    *,
    peer_capacity: int,
    capacity: int,
    emit_reserve: int = -1,
    max_rounds: int = 64,
) -> Dict:
    """Exact numpy twin of the flat padded CREDIT pipeline (ISSUE 9) driven
    by the cursor-gated emitter — the same event order the device executes,
    round for round:

      * credits cold-start at ZERO (the first forward is advert-only);
      * each forward, sender ``src`` may ship at most
        ``min(peer_capacity, free[d]//R + (src < free[d]%R))`` rows to
        destination ``d`` (``free`` = the receivers' one-round-stale
        adverts), excess retained FIFO with ``age + 1``;
      * each receiver's fresh advert is
        ``max(clip(C - front - reserve, 0), min(C - front, R))`` — room
        behind the retained front, minus the local-emission reserve, floored
        at one credit PER SENDER whenever room exists (the liveness floor);
      * the app's emission is gated at ``max((C - own_advert) - n_ret, 0)``
        and walks the flattened schedule with a cursor (deferred rows keep
        their identities — the delivered checksums equal
        :func:`expected_by_rank` exactly on a completed run).

    The backpressure law this twin witnesses: receiver admission NEVER
    drops a row (``drops`` stays at the seed-clip count), occupancy stays
    bounded by construction, and every schedule entry is eventually
    delivered.  Returns the :func:`simulate_flat_retain` dict plus
    ``recv_trace`` / ``wire_rows`` / ``recv_drops`` (wire accounting) and
    ``advert_trace`` (per-forward fresh adverts, for the apportionment
    property tests)."""
    R, C, S = sc.num_ranks, capacity, peer_capacity
    E = sc.emits_per_round
    reserve = C // 2 if emit_reserve < 0 else emit_reserve
    delivered = [[0, 0, 0] for _ in range(R)]
    drops = 0
    retained_trace: List[int] = []
    age_trace: List[int] = []
    recv_trace: List[int] = []
    recv_drop_trace: List[int] = []
    advert_trace: List[Tuple[int, ...]] = []

    # flattened per-rank schedule + prefix counts (the gated emitter's law)
    flat: List[List[List[int]]] = [[] for _ in range(R)]
    prefix = np.zeros((R, sc.rounds), np.int64)
    for r in range(sc.rounds):
        for rank in range(R):
            for e in range(E):
                d = int(sc.dests[r, rank, e])
                if d >= 0:
                    flat[rank].append([int(sc.uid(r, rank, e)), d])
        prefix[:, r] = [len(flat[rank]) for rank in range(R)]

    def forward(state, credits):
        """One credit forward: grant → clamp/retain → ship → admit → fresh
        adverts.  Returns per-rank (retained, arrivals), total, adverts."""
        nonlocal drops
        free = np.maximum(credits, 0)
        shipped = [[[] for _ in range(R)] for _ in range(R)]  # [src][dst]
        retained = []
        for src in range(R):
            allow = [
                min(S, int(free[d]) // R + (1 if src < int(free[d]) % R else 0))
                for d in range(R)
            ]
            sent = [0] * R
            keep = []
            for uid, d, age in state[src]:
                if sent[d] < allow[d]:
                    sent[d] += 1
                    shipped[src][d].append(uid)
                else:
                    keep.append([uid, d, age + 1])
            retained.append(keep)
        out = []
        total = 0
        fresh = np.zeros((R,), np.int64)
        arrivals_total = 0
        rdrops = 0
        for dst in range(R):
            arrivals = [u for src in range(R) for u in shipped[src][dst]]
            keep = retained[dst]
            room = C - len(keep)
            fresh[dst] = max(max(room - reserve, 0), min(room, R))
            admit = min(len(arrivals), room)
            rdrops += len(arrivals) - admit
            arrivals_total += len(arrivals)
            out.append((keep, arrivals[:admit]))
            total += len(keep) + admit
        drops += rdrops
        retained_trace.append(sum(len(k) for k, _ in out))
        age_trace.append(max((r[2] for k, _ in out for r in k), default=0))
        recv_trace.append(arrivals_total)
        recv_drop_trace.append(rdrops)
        advert_trace.append(tuple(int(f) for f in fresh))
        return out, total, fresh

    # seed queue: round-0 emissions, clipped at capacity; first forward is
    # advert-only (zero credits)
    cursor = prefix[:, 0].copy()
    state = []
    for rank in range(R):
        rows = _emit_rows(sc, 0)[rank]
        drops += max(0, len(rows) - C)
        state.append(rows[:C])
    cur, total, credits = forward(state, np.zeros((R,), np.int64))

    rnd = 0
    while total > 0 and rnd < max_rounds:
        state = []
        for rank in range(R):
            keep, arrivals = cur[rank]
            for u in arrivals:
                delivered[rank][0] += 1
                delivered[rank][1] += u
                delivered[rank][2] += (u * u) % _M32
            # the drive's emission gate: own advert is already promised to
            # in-flight arrivals, so emissions fit in what remains
            headroom = max((C - max(int(credits[rank]), 0)) - len(keep), 0)
            due = int(prefix[rank, min(rnd + 1, sc.rounds - 1)])
            n = min(max(due - int(cursor[rank]), 0), headroom)
            fresh_rows = [
                [uid, d, 0]
                for uid, d in flat[rank][int(cursor[rank]): int(cursor[rank]) + n]
            ]
            cursor[rank] += n
            state.append(keep + fresh_rows)
        cur, total, credits = forward(state, credits)
        rnd += 1

    return {
        "delivered": np.asarray(
            [[c % _M32 for c in row] for row in delivered], np.uint32
        ),
        "drops": drops,
        "rounds": rnd,
        "done": total == 0,
        "resident": total,
        "emitted": int(cursor.sum()),
        "retained_trace": retained_trace,
        "age_trace": age_trace,
        "age_max": max(age_trace, default=0),
        "retained_rows": sum(retained_trace),
        "recv_trace": recv_trace,
        "recv_drops": sum(recv_drop_trace),
        "wire_rows": sum(recv_trace),
        "advert_trace": advert_trace,
    }

"""Work-item "ray type" registry — the JAX analogue of RaFI's C++ templating.

The paper templates its whole library over an opaque, trivially-copyable
``RayT``; RaFI never looks inside the payload (§3.1).  In JAX the natural
equivalent is a *pytree of arrays*: any dataclass whose fields are arrays (or
nested such dataclasses) can be a work item.  The library only ever applies
structural operations (gather / scatter / exchange) leaf-wise, preserving the
paper's "copy, move, transmit — nothing else" contract.

``@work_item`` registers a dataclass as a JAX pytree and attaches helpers the
infrastructure needs (per-item byte size, batched zeros).  Multiple distinct
work-item types can coexist — the N-body app (§5.5) uses three simultaneously.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "work_item",
    "item_nbytes",
    "batched_zeros",
    "item_spec",
    "tree_take",
    "tree_scatter",
    "tree_where",
    "PackSpec",
    "pack_spec",
    "pack_payload",
    "unpack_payload",
]


def work_item(cls):
    """Class decorator: register ``cls`` (a dataclass) as a JAX work-item type.

    All fields are treated as array ("data") fields.  The resulting type is a
    pytree, so it can be carried through ``jit``/``shard_map``/``while_loop``
    and exchanged between ranks — the analogue of "trivially copyable".
    """
    if not dataclasses.is_dataclass(cls):
        cls = dataclasses.dataclass(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    cls = jax.tree_util.register_dataclass(cls, data_fields=fields, meta_fields=[])
    cls.__work_item__ = True
    return cls


def _leaf_spec(x: Any):
    if hasattr(x, "dtype") and hasattr(x, "shape"):
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    raise TypeError(f"work item leaves must be arrays, got {type(x)}")


def item_spec(proto) -> Any:
    """ShapeDtypeStruct pytree describing a *single* item (no batch axis)."""
    return jax.tree.map(_leaf_spec, proto)


def item_nbytes(proto) -> int:
    """Bytes of one work item — the paper's ``sizeof(RayT)`` (44 B for Fig. 8)."""
    leaves = jax.tree.leaves(item_spec(proto))
    return int(sum(np.prod(l.shape, dtype=np.int64) * np.dtype(l.dtype).itemsize for l in leaves))


def batched_zeros(proto, n: int):
    """A (n, ...) zero-filled buffer pytree for ``n`` items shaped like ``proto``."""
    return jax.tree.map(
        lambda l: jnp.zeros((n,) + tuple(l.shape), l.dtype), item_spec(proto)
    )


def tree_take(items, idx, *, fill_garbage: bool = True):
    """Gather ``items[idx]`` leaf-wise along axis 0 (clipped indices)."""
    del fill_garbage  # invalid lanes are masked downstream by counts
    return jax.tree.map(lambda a: jnp.take(a, idx, axis=0, mode="clip"), items)


def tree_scatter(buf, pos, vals, *, capacity: int):
    """``buf.at[pos].set(vals)`` leaf-wise; any ``pos >= capacity`` is dropped.

    This is the vectorised analogue of the paper's overflow rule: emits past
    the queue capacity "simply get dropped" (§3.3).
    """
    del capacity  # encoded by mode="drop" against the buffer extent
    return jax.tree.map(lambda b, v: b.at[pos].set(v, mode="drop"), buf, vals)


# --------------------------------------------------------------------------
# Packed wire format (§4.2 "large contiguous blocks"): the whole work-item
# pytree bitcast into ONE (capacity, words) uint32 buffer.  This is the JAX
# rendering of the paper's trivially-copyable RayT on the wire — the 44-byte
# Fig-8 ray becomes 11 words per row.  Structural hot-path operations
# (sort-permute, marshal, exchange) act on this single buffer, so each round
# needs exactly one payload gather and one payload collective instead of one
# per pytree leaf.
#
# Layout: leaves in treedef order, each flattened to its per-item byte string
# and bitcast to ≥1 whole uint32 words (sub-word dtypes are zero-padded up to
# a word boundary; the pad words travel but carry no information and are
# stripped on unpack).  Pack ∘ unpack is the identity bit-for-bit.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static recipe for packing/unpacking one work-item type.

    Attributes:
      treedef: pytree structure of the item type.
      shapes: per-leaf trailing (per-item) shapes.
      dtypes: per-leaf dtype names.
      words: per-leaf packed word counts (incl. sub-word padding).
    """

    treedef: Any
    shapes: tuple
    dtypes: tuple
    words: tuple

    @property
    def total_words(self) -> int:
        return sum(self.words)

    @property
    def offsets(self) -> tuple:
        out, o = [], 0
        for w in self.words:
            out.append(o)
            o += w
        return tuple(out)


def _leaf_words(shape, dtype) -> int:
    n = int(np.prod(shape, dtype=np.int64))
    b = np.dtype(dtype).itemsize
    return -(-n * b // 4)  # zero-size leaves occupy zero wire words


def pack_spec(proto) -> PackSpec:
    """The :class:`PackSpec` for items shaped like ``proto`` (no batch axis
    required — only leaf trailing shapes and dtypes matter)."""
    leaves, treedef = jax.tree.flatten(item_spec(proto))
    return PackSpec(
        treedef=treedef,
        shapes=tuple(tuple(l.shape) for l in leaves),
        dtypes=tuple(np.dtype(l.dtype).name for l in leaves),
        words=tuple(_leaf_words(l.shape, l.dtype) for l in leaves),
    )


def _leaf_to_words(a: jax.Array) -> jax.Array:
    """(C, ...) leaf → (C, words) uint32, bit-preserving."""
    cap = a.shape[0]
    if a.size == 0:
        return jnp.zeros((cap, 0), jnp.uint32)
    if a.dtype == jnp.bool_:
        a = a.astype(jnp.uint8)
    flat = a.reshape(cap, -1)
    b = np.dtype(flat.dtype).itemsize
    if b == 4:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32)
    if b == 8:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32).reshape(cap, -1)
    # sub-word (1- or 2-byte) dtypes: zero-pad the minor axis to a whole
    # number of words, then bitcast groups of 4//b elements into each word
    per = 4 // b
    pad = (-flat.shape[1]) % per
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return jax.lax.bitcast_convert_type(flat.reshape(cap, -1, per), jnp.uint32)


def _words_to_leaf(seg: jax.Array, shape, dtype) -> jax.Array:
    """(C, words) uint32 → (C, *shape) leaf of ``dtype`` (inverse bitcast)."""
    cap = seg.shape[0]
    dt = np.dtype(dtype)
    n = int(np.prod(shape, dtype=np.int64))
    if n == 0:
        return jnp.zeros((cap,) + tuple(shape), dt)
    wire_dt = jnp.uint8 if dt == np.bool_ else jnp.dtype(dtype)
    b = np.dtype(wire_dt).itemsize
    if b == 4:
        out = jax.lax.bitcast_convert_type(seg, wire_dt)[:, :n]
    elif b == 8:
        out = jax.lax.bitcast_convert_type(seg.reshape(cap, -1, 2), wire_dt)[:, :n]
    else:
        out = jax.lax.bitcast_convert_type(seg, wire_dt).reshape(cap, -1)[:, :n]
    if dt == np.bool_:
        out = out.astype(jnp.bool_)
    return out.reshape((cap,) + tuple(shape))


def pack_payload(items: Any, spec: PackSpec | None = None):
    """Bitcast-concatenate a batched item pytree into one (C, W) uint32
    buffer.  Returns ``(packed, spec)``; ``spec`` round-trips via
    :func:`unpack_payload`."""
    if spec is None:
        spec = pack_spec(jax.tree.map(lambda a: a[0], items))
    cols = [_leaf_to_words(l) for l in jax.tree.leaves(items)]
    packed = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
    return packed, spec


def unpack_payload(packed: jax.Array, spec: PackSpec) -> Any:
    """Inverse of :func:`pack_payload` (bit-exact)."""
    leaves, o = [], 0
    for shape, dtype, w in zip(spec.shapes, spec.dtypes, spec.words):
        leaves.append(_words_to_leaf(packed[:, o : o + w], shape, dtype))
        o += w
    return jax.tree.unflatten(spec.treedef, leaves)


def tree_where(mask, a, b):
    """Leaf-wise select with broadcast of a (n,) mask over item trailing dims."""

    def sel(x, y):
        m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
        return jnp.where(m, x, y)

    return jax.tree.map(sel, a, b)

"""Work-item "ray type" registry — the JAX analogue of RaFI's C++ templating.

The paper templates its whole library over an opaque, trivially-copyable
``RayT``; RaFI never looks inside the payload (§3.1).  In JAX the natural
equivalent is a *pytree of arrays*: any dataclass whose fields are arrays (or
nested such dataclasses) can be a work item.  The library only ever applies
structural operations (gather / scatter / exchange) leaf-wise, preserving the
paper's "copy, move, transmit — nothing else" contract.

``@work_item`` registers a dataclass as a JAX pytree and attaches helpers the
infrastructure needs (per-item byte size, batched zeros).  Multiple distinct
work-item types can coexist — the N-body app (§5.5) uses three simultaneously.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "work_item",
    "item_nbytes",
    "batched_zeros",
    "item_spec",
    "tree_take",
    "tree_scatter",
    "tree_where",
]


def work_item(cls):
    """Class decorator: register ``cls`` (a dataclass) as a JAX work-item type.

    All fields are treated as array ("data") fields.  The resulting type is a
    pytree, so it can be carried through ``jit``/``shard_map``/``while_loop``
    and exchanged between ranks — the analogue of "trivially copyable".
    """
    if not dataclasses.is_dataclass(cls):
        cls = dataclasses.dataclass(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    cls = jax.tree_util.register_dataclass(cls, data_fields=fields, meta_fields=[])
    cls.__work_item__ = True
    return cls


def _leaf_spec(x: Any):
    if hasattr(x, "dtype") and hasattr(x, "shape"):
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    raise TypeError(f"work item leaves must be arrays, got {type(x)}")


def item_spec(proto) -> Any:
    """ShapeDtypeStruct pytree describing a *single* item (no batch axis)."""
    return jax.tree.map(_leaf_spec, proto)


def item_nbytes(proto) -> int:
    """Bytes of one work item — the paper's ``sizeof(RayT)`` (44 B for Fig. 8)."""
    leaves = jax.tree.leaves(item_spec(proto))
    return int(sum(np.prod(l.shape, dtype=np.int64) * np.dtype(l.dtype).itemsize for l in leaves))


def batched_zeros(proto, n: int):
    """A (n, ...) zero-filled buffer pytree for ``n`` items shaped like ``proto``."""
    return jax.tree.map(
        lambda l: jnp.zeros((n,) + tuple(l.shape), l.dtype), item_spec(proto)
    )


def tree_take(items, idx, *, fill_garbage: bool = True):
    """Gather ``items[idx]`` leaf-wise along axis 0 (clipped indices)."""
    del fill_garbage  # invalid lanes are masked downstream by counts
    return jax.tree.map(lambda a: jnp.take(a, idx, axis=0, mode="clip"), items)


def tree_scatter(buf, pos, vals, *, capacity: int):
    """``buf.at[pos].set(vals)`` leaf-wise; any ``pos >= capacity`` is dropped.

    This is the vectorised analogue of the paper's overflow rule: emits past
    the queue capacity "simply get dropped" (§3.3).
    """
    del capacity  # encoded by mode="drop" against the buffer extent
    return jax.tree.map(lambda b, v: b.at[pos].set(v, mode="drop"), buf, vals)


def tree_where(mask, a, b):
    """Leaf-wise select with broadcast of a (n,) mask over item trailing dims."""

    def sel(x, y):
        m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
        return jnp.where(m, x, y)

    return jax.tree.map(sel, a, b)

"""Distributed-termination drive loop (paper §4.2.3 / Chandy-Lamport note).

The paper's applications loop: launch kernel → ``forwardRays()`` → check the
reduced global count → repeat.  Because every stage here is traced JAX, the
whole loop lives on device in one ``jax.lax.while_loop`` — each rank keeps
iterating (possibly with an empty local queue) until the *global* in-flight
count hits zero, which is exactly the paper's observation that "even if a
rank does not receive any work during the current iteration, it may still be
assigned more work from other ranks later on".
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.forwarding import ForwardConfig, flatten_axis_names, forward_work
from repro.core.queue import WorkQueue
from repro.telemetry import stats as TS

__all__ = ["run_until_done"]


def _vary(tree: Any, axis_name) -> Any:
    """Mark every leaf as device-varying over ``axis_name`` so the while-loop
    carry types stay stable even if the app's aux starts out replicated."""
    axes = flatten_axis_names(axis_name)

    def cast(x):
        return compat.pcast_varying(jnp.asarray(x), axes)

    return jax.tree.map(cast, tree)


def run_until_done(
    round_fn: Callable[[WorkQueue, Any, jax.Array], Tuple[WorkQueue, Any]],
    q0: WorkQueue,
    aux0: Any,
    cfg: ForwardConfig,
    *,
    max_rounds: int = 64,
) -> Tuple[WorkQueue, Any, jax.Array]:
    """Iterate ``round_fn`` + ``forward_work`` until global termination.

    Args:
      round_fn: ``(in_queue, aux, round_idx) -> (out_queue, aux)`` — consumes
        the input queue and *emits* into a fresh output queue (the paper's
        separate in/out arrays, §3.2).  ``aux`` is arbitrary app state
        (framebuffer, particle traces, ...).

        Drops contract: the driver owns the cumulative drop count.  Each
        round it accumulates the OUTPUT queue's ``drops`` (the round's own
        enqueue overflows plus the forwarding round's clamps); the input
        queue round_fn receives always carries ``drops == 0``, so a round_fn
        that copies its input queue's ``drops`` into the output queue (a
        natural thing to do when threading queue state through) cannot
        double-count earlier rounds.  round_fn must not invent a nonzero
        starting ``drops`` of its own beyond what its enqueues produce.
      q0: initial queue (already filled by the app's ray-gen stage).
      aux0: initial app state.
      cfg: forwarding configuration.
      max_rounds: hard bound (XLA while loops need no bound, but runaway
        protection mirrors the paper's capacity pragmatism).

    Returns ``(final_queue, final_aux, rounds_executed)``.  With
    ``cfg.telemetry`` a ``telemetry.StatsRing`` of the last
    ``cfg.telemetry_window`` rounds rides the while-loop carry and is
    returned as a fourth output — EVERY forwarding round is recorded,
    including the initial ray-gen routing round (so a drive that runs
    ``rounds`` body iterations returns ``ring.pos == rounds + 1``).
    """
    telem = cfg.telemetry

    def cond(carry):
        total, rnd = carry[2], carry[3]
        return (total > 0) & (rnd < max_rounds)

    def body(carry):
        q, aux, _total, rnd, drops = carry[:5]
        # The input queue's cumulative drops already ride the loop carry;
        # hand round_fn a zero-drop view so a round_fn that threads the input
        # queue's drops into its output cannot double-count them (see the
        # drops contract in the docstring).
        q = WorkQueue(items=q.items, dest=q.dest, count=q.count,
                      drops=jnp.zeros_like(q.drops))
        out_q, aux = round_fn(q, aux, rnd)
        if telem:
            new_q, total, stats = forward_work(out_q, cfg)
        else:
            new_q, total = forward_work(out_q, cfg)
        # Per-round queues are fresh, so cumulative overflow drops must ride
        # the loop carry (observability: silent loss is a capacity bug).
        drops = drops + new_q.drops
        out = (
            _vary(new_q, cfg.axis_name),
            _vary(aux, cfg.axis_name),
            total,
            rnd + 1,
            _vary(drops, cfg.axis_name),
        )
        if telem:
            ring = TS.ring_push(carry[5], stats)
            out = out + (_vary(ring, cfg.axis_name),)
        return out

    # Initial forward: route the ray-gen output to its owners (the paper's
    # VoPaT does exactly this — primary rays are "forwarded to itself").
    if telem:
        q1, total0, stats0 = forward_work(q0, cfg)
        ring0 = TS.ring_push(
            TS.make_ring(
                TS.num_tiers(cfg),
                window=cfg.telemetry_window,
                buckets=cfg.telemetry_buckets,
            ),
            stats0,
        )
    else:
        q1, total0 = forward_work(q0, cfg)
    carry0 = (
        _vary(q1, cfg.axis_name),
        _vary(aux0, cfg.axis_name),
        total0,
        jnp.zeros((), jnp.int32),
        _vary(q1.drops, cfg.axis_name),
    )
    if telem:
        carry0 = carry0 + (_vary(ring0, cfg.axis_name),)
    out = jax.lax.while_loop(cond, body, carry0)
    q, aux, _, rounds, drops = out[:5]
    q = WorkQueue(items=q.items, dest=q.dest, count=q.count, drops=drops)
    if telem:
        return q, aux, rounds, out[5]
    return q, aux, rounds

"""Distributed-termination drive loop (paper §4.2.3 / Chandy-Lamport note).

The paper's applications loop: launch kernel → ``forwardRays()`` → check the
reduced global count → repeat.  Because every stage here is traced JAX, the
whole loop lives on device in one ``jax.lax.while_loop`` — each rank keeps
iterating (possibly with an empty local queue) until the *global* in-flight
count hits zero, which is exactly the paper's observation that "even if a
rank does not receive any work during the current iteration, it may still be
assigned more work from other ranks later on".

Spill-and-retry (``cfg.overflow == "retain"``, ISSUE 6): ``forward_work``
hands back clamp-cut rows compacted at the FRONT of the queue with their
``dest`` intact.  The drive loop keeps them out of ``round_fn``'s way — the
app sees an arrivals-only view — and re-merges them (retained first, so the
marshal's stable source order gives FIFO oldest-first send priority) before
the next forward, threading the per-lane ``age`` counter alongside.  The
termination ``psum`` counts retained rows by construction (they sit in the
queue ``count``), so the loop cannot exit with work still spilled; and since
every nonempty destination ships at least one row per round (every clamp
budget is ≥ 1), the backlog drains in bounded rounds — no livelock.

Segmentation (ISSUE 7, the recovery law): the loop is factored into
``drive_start`` (the initial routing forward → carry) + ``drive_segment``
(run body rounds while ``rnd < seg_end``) + ``drive_finalize`` (carry →
results), with the carry an explicit dict pytree.  ``run_until_done`` is
exactly start + one full-length segment + finalize; the checkpoint/resume
host drive (``repro.core.recovery``) runs W-round segments instead,
snapshotting the carry between them — same traced body, so an uninterrupted
run and a segmented run execute bit-identical programs round for round.
"""
from __future__ import annotations

import inspect

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.forwarding import (
    ForwardConfig,
    credit_reserve_rows,
    flatten_axis_names,
    forward_work,
)
from repro.core.queue import DISCARD, WorkQueue
from repro.telemetry import stats as TS

__all__ = ["drive_finalize", "drive_segment", "drive_start", "run_until_done"]


def _vary(tree: Any, axis_name) -> Any:
    """Mark every leaf as device-varying over ``axis_name`` so the while-loop
    carry types stay stable even if the app's aux starts out replicated."""
    axes = flatten_axis_names(axis_name)

    def cast(x):
        return compat.pcast_varying(jnp.asarray(x), axes)

    return jax.tree.map(cast, tree)


def _split_retained(q: WorkQueue) -> Tuple[jax.Array, WorkQueue]:
    """``(n_ret, arrivals_view)``: retained rows sit at the queue FRONT with
    ``dest >= 0``; the view shifts them out so ``round_fn`` consumes only the
    round's arrivals (dest all DISCARD, zero drops — the drops contract)."""
    C = q.capacity
    lane = jnp.arange(C, dtype=jnp.int32)
    n_ret = jnp.sum(((lane < q.count) & (q.dest >= 0)).astype(jnp.int32))
    src = jnp.clip(lane + n_ret, 0, C - 1)
    # happy path (nothing retained): the shift is the identity — skip the
    # per-leaf gather behind a one-predicate cond
    items = jax.lax.cond(
        n_ret > 0,
        lambda its: jax.tree.map(lambda a: jnp.take(a, src, axis=0), its),
        lambda its: its,
        q.items,
    )
    view = WorkQueue(
        items=items,
        dest=jnp.full((C,), DISCARD, jnp.int32),
        count=q.count - n_ret,
        drops=jnp.zeros_like(q.drops),
    )
    return n_ret, view


def _merge_retained(
    q: WorkQueue, n_ret: jax.Array, out_q: WorkQueue, age: jax.Array,
    limit=None,
) -> Tuple[WorkQueue, jax.Array]:
    """Recombine the retained front of ``q`` with ``round_fn``'s output queue
    (retained FIRST — FIFO priority through the stable marshal).  Emissions
    that don't fit behind the backlog are cut and counted (unreachable when
    the app sizes ``capacity`` for its emission burst plus worst-case spill —
    and surfaced per round as the ``emit_overflow`` telemetry counter).
    Under credit flow the drive passes ``limit = capacity − outstanding
    advert``: emissions may never eat room already promised to in-flight
    arrivals, which is what makes the credit law receiver-drop-free even
    against an app that ignores its emission headroom.  Retained rows are
    never cut — ``limit`` binds emissions only.
    Returns ``(merged_queue, age_in)`` ready for ``forward_work``."""
    C = q.capacity
    lane = jnp.arange(C, dtype=jnp.int32)
    tail = jnp.clip(lane - n_ret, 0, C - 1)
    n_tot = n_ret + out_q.count
    cap = C if limit is None else jnp.maximum(limit, n_ret)
    count = jnp.minimum(n_tot, cap)
    front = lane < n_ret

    def merge(_):
        def merge_leaf(a, b):
            keep = front.reshape((C,) + (1,) * (a.ndim - 1))
            return jnp.where(keep, a, jnp.take(b, tail, axis=0))

        items = jax.tree.map(merge_leaf, q.items, out_q.items)
        valid_tail = (~front) & (tail < out_q.count)
        dest = jnp.where(
            front,
            q.dest,
            jnp.where(valid_tail, jnp.take(out_q.dest, tail), DISCARD),
        ).astype(jnp.int32)
        age_in = jnp.where(front, age, 0).astype(jnp.int32)
        return items, dest, age_in

    def passthrough(_):
        # nothing retained: the merge is out_q verbatim (lanes past count
        # masked to DISCARD, matching the shifted-merge output bit for bit)
        dest = jnp.where(lane < out_q.count, out_q.dest, DISCARD)
        return out_q.items, dest.astype(jnp.int32), jnp.zeros((C,), jnp.int32)

    items, dest, age_in = jax.lax.cond(n_ret > 0, merge, passthrough, None)
    merged = WorkQueue(
        items=items,
        dest=dest,
        count=count.astype(jnp.int32),
        drops=out_q.drops + (n_tot - count).astype(jnp.int32),
    )
    return merged, age_in


def _fwd(q, age, cfg, health, credits=None):
    """Uniform forward_work unpack: ``(new_q, total, age_out, credits_out,
    stats)`` with Nones where the config doesn't produce the value."""
    retain = cfg.overflow == "retain"
    credit = cfg.flow == "credit"
    if credit and cfg.telemetry:
        new_q, total, age_out, credits_out, stats = forward_work(
            q, cfg, age=age, health=health, credits=credits
        )
    elif credit:
        new_q, total, age_out, credits_out = forward_work(
            q, cfg, age=age, health=health, credits=credits
        )
        stats = None
    elif retain and cfg.telemetry:
        new_q, total, age_out, stats = forward_work(q, cfg, age=age, health=health)
        credits_out = None
    elif retain:
        new_q, total, age_out = forward_work(q, cfg, age=age, health=health)
        credits_out = stats = None
    elif cfg.telemetry:
        new_q, total, stats = forward_work(q, cfg, health=health)
        age_out = credits_out = None
    else:
        new_q, total = forward_work(q, cfg, health=health)
        age_out = credits_out = stats = None
    return new_q, total, age_out, credits_out, stats


def drive_start(
    q0: WorkQueue,
    aux0: Any,
    cfg: ForwardConfig,
    *,
    health: Optional[jax.Array] = None,
    accounting: bool = False,
) -> Dict[str, Any]:
    """The drive's initial forward: route the ray-gen output to its owners
    (the paper's VoPaT does exactly this — primary rays are "forwarded to
    itself") and build the loop carry.

    Carry keys: ``q`` (the forwarded queue, per-round drops), ``aux``,
    ``total`` (replicated global in-flight count), ``rnd`` (body iterations
    executed), ``drops`` (cumulative per-rank) — plus ``age`` (retain),
    ``ring`` (telemetry), and, with ``accounting=True``, the per-rank
    ``emitted`` / ``delivered`` conservation counters the recovery watchdog
    closes at every checkpoint boundary (``emitted`` counts ATTEMPTED
    emissions — accepted rows plus their enqueue clips — so the identity
    ``emitted == delivered + in-flight + drops`` holds exactly; both are
    values the loop computes anyway, so the cost is two scalar adds).
    """
    credit = cfg.flow == "credit"
    credits0 = None
    if credit:
        # cold start at ZERO credit: the first forward is advert-only (all
        # rows retained), so no wire byte is risked before any receiver has
        # advertised — the backpressure law holds from round one
        credits0 = jnp.zeros((cfg.num_ranks,), jnp.int32)
    q1, total0, age1, credits1, stats0 = _fwd(q0, None, cfg, health, credits0)
    if cfg.telemetry and stats0 is not None:
        # round 0's local emission loss is the ray-gen enqueue overflow
        stats0 = TS.attach_emit_overflow(stats0, q0.drops)
    carry: Dict[str, Any] = {
        "q": _vary(q1, cfg.axis_name),
        "aux": _vary(aux0, cfg.axis_name),
        "total": total0,
        "rnd": jnp.zeros((), jnp.int32),
        "drops": _vary(q1.drops, cfg.axis_name),
    }
    if cfg.overflow == "retain":
        carry["age"] = _vary(age1, cfg.axis_name)
    if credit:
        carry["credits"] = _vary(credits1, cfg.axis_name)
    if cfg.telemetry:
        ring0 = TS.ring_push(
            TS.make_ring(
                TS.num_tiers(cfg),
                window=cfg.telemetry_window,
                buckets=cfg.telemetry_buckets,
            ),
            stats0,
        )
        carry["ring"] = _vary(ring0, cfg.axis_name)
    if accounting:
        emitted0 = (q0.count + q0.drops).astype(jnp.int32)
        carry["emitted"] = _vary(emitted0, cfg.axis_name)
        carry["delivered"] = _vary(jnp.zeros((), jnp.int32), cfg.axis_name)
    return carry


def drive_segment(
    round_fn: Callable[[WorkQueue, Any, jax.Array], Tuple[WorkQueue, Any]],
    carry: Dict[str, Any],
    cfg: ForwardConfig,
    *,
    seg_end,
    health: Optional[jax.Array] = None,
) -> Dict[str, Any]:
    """Run body rounds while ``total > 0`` and ``rnd < seg_end``.

    ``seg_end`` may be a static int (``run_until_done`` passes
    ``max_rounds``) or a traced scalar (the checkpoint drive passes each
    segment's boundary into ONE compiled program).  The body is identical
    either way, so a segmented run replays the uninterrupted run's rounds
    bit for bit.  Accounting counters ride along iff present in ``carry``.
    """
    telem = cfg.telemetry
    retain = cfg.overflow == "retain"
    credit = cfg.flow == "credit"
    track = "emitted" in carry
    # Emission gate (credit flow): round_fn may declare a ``headroom``
    # keyword to receive its per-round emission budget — the receive room
    # not already owed to retained backlog or outstanding advertised
    # credits.  An app that emits within it never sees emit_overflow; one
    # that ignores it degrades locally (counted), never on the wire.
    wants_headroom = False
    try:
        wants_headroom = "headroom" in inspect.signature(round_fn).parameters
    except (TypeError, ValueError):  # builtins / exotic callables: no gate
        pass

    def cond(c):
        return (c["total"] > 0) & (c["rnd"] < seg_end)

    def body(c):
        q, aux, rnd, drops = c["q"], c["aux"], c["rnd"], c["drops"]
        # The input queue's cumulative drops already ride the loop carry;
        # hand round_fn a zero-drop view so a round_fn that threads the input
        # queue's drops into its output cannot double-count them (see the
        # drops contract in the run_until_done docstring).
        q = WorkQueue(items=q.items, dest=q.dest, count=q.count,
                      drops=jnp.zeros_like(q.drops))
        if retain:
            n_ret, view = _split_retained(q)
            consumed = view.count
            limit = None
            kw = {}
            if credit:
                # my outstanding advert = my own carried entry (the count
                # collective hands every rank its own fresh value back)
                me = jax.lax.axis_index(flatten_axis_names(cfg.axis_name))
                adv = jnp.clip(jnp.take(c["credits"], me), 0)
                limit = (cfg.capacity - adv).astype(jnp.int32)
                if wants_headroom:
                    kw["headroom"] = jnp.maximum(limit - n_ret, 0)
            elif wants_headroom:
                kw["headroom"] = jnp.maximum(cfg.capacity - n_ret, 0)
            out_q, aux = round_fn(view, aux, rnd, **kw)
            fwd_q, age_in = _merge_retained(q, n_ret, out_q, c["age"], limit)
            attempted = out_q.count + out_q.drops
        else:
            consumed = q.count
            kw = {"headroom": jnp.int32(cfg.capacity)} if wants_headroom else {}
            fwd_q, aux = round_fn(q, aux, rnd, **kw)
            age_in = None
            attempted = fwd_q.count + fwd_q.drops
        new_q, total, age_out, credits_out, stats = _fwd(
            fwd_q, age_in, cfg, health, c.get("credits")
        )
        if telem and stats is not None:
            # local emission loss this round: enqueue overflow inside
            # round_fn plus the merge's emission cut — rows lost BEFORE the
            # wire, distinct from every clamp/admission counter
            stats = TS.attach_emit_overflow(stats, fwd_q.drops)
        # Per-round queues are fresh, so cumulative overflow drops must ride
        # the loop carry (observability: silent loss is a capacity bug).
        drops = drops + new_q.drops
        out = {
            "q": _vary(new_q, cfg.axis_name),
            "aux": _vary(aux, cfg.axis_name),
            "total": total,
            "rnd": rnd + 1,
            "drops": _vary(drops, cfg.axis_name),
        }
        if retain:
            out["age"] = _vary(age_out, cfg.axis_name)
        if credit:
            out["credits"] = _vary(credits_out, cfg.axis_name)
        if telem:
            out["ring"] = _vary(TS.ring_push(c["ring"], stats), cfg.axis_name)
        if track:
            out["emitted"] = _vary(
                c["emitted"] + attempted.astype(jnp.int32), cfg.axis_name
            )
            out["delivered"] = _vary(
                c["delivered"] + consumed.astype(jnp.int32), cfg.axis_name
            )
        return out

    return jax.lax.while_loop(cond, body, carry)


def drive_finalize(carry: Dict[str, Any], cfg: ForwardConfig):
    """Carry → results: fold the cumulative drops into the final queue and
    emit the ``run_until_done`` return tuple (see its docstring)."""
    q = carry["q"]
    q = WorkQueue(items=q.items, dest=q.dest, count=q.count, drops=carry["drops"])
    out = (q, carry["aux"], carry["rnd"], carry["total"] == 0)
    if cfg.overflow == "retain":
        out = out + (carry["age"],)
    if cfg.telemetry:
        out = out + (carry["ring"],)
    return out


def run_until_done(
    round_fn: Callable[[WorkQueue, Any, jax.Array], Tuple[WorkQueue, Any]],
    q0: WorkQueue,
    aux0: Any,
    cfg: ForwardConfig,
    *,
    max_rounds: int = 64,
    health: Optional[jax.Array] = None,
) -> Tuple:
    """Iterate ``round_fn`` + ``forward_work`` until global termination.

    Args:
      round_fn: ``(in_queue, aux, round_idx) -> (out_queue, aux)`` — consumes
        the input queue and *emits* into a fresh output queue (the paper's
        separate in/out arrays, §3.2).  ``aux`` is arbitrary app state
        (framebuffer, particle traces, ...).

        Drops contract: the driver owns the cumulative drop count.  Each
        round it accumulates the OUTPUT queue's ``drops`` (the round's own
        enqueue overflows plus the forwarding round's clamps); the input
        queue round_fn receives always carries ``drops == 0``, so a round_fn
        that copies its input queue's ``drops`` into the output queue (a
        natural thing to do when threading queue state through) cannot
        double-count earlier rounds.  round_fn must not invent a nonzero
        starting ``drops`` of its own beyond what its enqueues produce.
      q0: initial queue (already filled by the app's ray-gen stage).
      aux0: initial app state.
      cfg: forwarding configuration.
      max_rounds: hard bound (XLA while loops need no bound, but runaway
        protection mirrors the paper's capacity pragmatism).
      health: optional replicated ``(R,) bool`` rank-health mask, constant
        for the burst — every forward re-addresses traffic away from
        unhealthy ranks via the pure local ``core.health`` remap (zero
        collective-inventory change).  For a mask that CHANGES mid-run, use
        the segmented checkpoint drive (``repro.core.recovery``), which
        re-reads it at every segment boundary.

    Returns ``(final_queue, final_aux, rounds_executed, done)``.  ``done`` is
    the termination verdict: True when the loop exited because the global
    in-flight count hit zero, False when ``max_rounds`` ran out with work
    still in flight (a truncated run).  Under ``overflow="retain"`` the
    final per-lane ``age`` vector is returned as a fifth output — on a
    truncated run these are the REAL rounds-waiting counters of the rows
    still in the queue, so a continuation (``repro.core.recovery`` resume,
    or a manual re-drive threading ``age`` back in) preserves the FIFO
    anti-starvation clock instead of silently resetting it.  With
    ``cfg.telemetry`` a ``telemetry.StatsRing`` of the last
    ``cfg.telemetry_window`` rounds rides the while-loop carry and is
    returned as the last output — EVERY forwarding round is recorded,
    including the initial ray-gen routing round (so a drive that runs
    ``rounds`` body iterations returns ``ring.pos == rounds + 1``).
    """
    carry = drive_start(q0, aux0, cfg, health=health)
    carry = drive_segment(round_fn, carry, cfg, seg_end=max_rounds, health=health)
    return drive_finalize(carry, cfg)

"""Distributed-termination drive loop (paper §4.2.3 / Chandy-Lamport note).

The paper's applications loop: launch kernel → ``forwardRays()`` → check the
reduced global count → repeat.  Because every stage here is traced JAX, the
whole loop lives on device in one ``jax.lax.while_loop`` — each rank keeps
iterating (possibly with an empty local queue) until the *global* in-flight
count hits zero, which is exactly the paper's observation that "even if a
rank does not receive any work during the current iteration, it may still be
assigned more work from other ranks later on".

Spill-and-retry (``cfg.overflow == "retain"``, ISSUE 6): ``forward_work``
hands back clamp-cut rows compacted at the FRONT of the queue with their
``dest`` intact.  The drive loop keeps them out of ``round_fn``'s way — the
app sees an arrivals-only view — and re-merges them (retained first, so the
marshal's stable source order gives FIFO oldest-first send priority) before
the next forward, threading the per-lane ``age`` counter alongside.  The
termination ``psum`` counts retained rows by construction (they sit in the
queue ``count``), so the loop cannot exit with work still spilled; and since
every nonempty destination ships at least one row per round (every clamp
budget is ≥ 1), the backlog drains in bounded rounds — no livelock.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.forwarding import ForwardConfig, flatten_axis_names, forward_work
from repro.core.queue import DISCARD, WorkQueue
from repro.telemetry import stats as TS

__all__ = ["run_until_done"]


def _vary(tree: Any, axis_name) -> Any:
    """Mark every leaf as device-varying over ``axis_name`` so the while-loop
    carry types stay stable even if the app's aux starts out replicated."""
    axes = flatten_axis_names(axis_name)

    def cast(x):
        return compat.pcast_varying(jnp.asarray(x), axes)

    return jax.tree.map(cast, tree)


def _split_retained(q: WorkQueue) -> Tuple[jax.Array, WorkQueue]:
    """``(n_ret, arrivals_view)``: retained rows sit at the queue FRONT with
    ``dest >= 0``; the view shifts them out so ``round_fn`` consumes only the
    round's arrivals (dest all DISCARD, zero drops — the drops contract)."""
    C = q.capacity
    lane = jnp.arange(C, dtype=jnp.int32)
    n_ret = jnp.sum(((lane < q.count) & (q.dest >= 0)).astype(jnp.int32))
    src = jnp.clip(lane + n_ret, 0, C - 1)
    # happy path (nothing retained): the shift is the identity — skip the
    # per-leaf gather behind a one-predicate cond
    items = jax.lax.cond(
        n_ret > 0,
        lambda its: jax.tree.map(lambda a: jnp.take(a, src, axis=0), its),
        lambda its: its,
        q.items,
    )
    view = WorkQueue(
        items=items,
        dest=jnp.full((C,), DISCARD, jnp.int32),
        count=q.count - n_ret,
        drops=jnp.zeros_like(q.drops),
    )
    return n_ret, view


def _merge_retained(
    q: WorkQueue, n_ret: jax.Array, out_q: WorkQueue, age: jax.Array
) -> Tuple[WorkQueue, jax.Array]:
    """Recombine the retained front of ``q`` with ``round_fn``'s output queue
    (retained FIRST — FIFO priority through the stable marshal).  Emissions
    that don't fit behind the backlog are cut and counted (unreachable when
    the app sizes ``capacity`` for its emission burst plus worst-case spill).
    Returns ``(merged_queue, age_in)`` ready for ``forward_work``."""
    C = q.capacity
    lane = jnp.arange(C, dtype=jnp.int32)
    tail = jnp.clip(lane - n_ret, 0, C - 1)
    n_tot = n_ret + out_q.count
    count = jnp.minimum(n_tot, C)
    front = lane < n_ret

    def merge(_):
        def merge_leaf(a, b):
            keep = front.reshape((C,) + (1,) * (a.ndim - 1))
            return jnp.where(keep, a, jnp.take(b, tail, axis=0))

        items = jax.tree.map(merge_leaf, q.items, out_q.items)
        valid_tail = (~front) & (tail < out_q.count)
        dest = jnp.where(
            front,
            q.dest,
            jnp.where(valid_tail, jnp.take(out_q.dest, tail), DISCARD),
        ).astype(jnp.int32)
        age_in = jnp.where(front, age, 0).astype(jnp.int32)
        return items, dest, age_in

    def passthrough(_):
        # nothing retained: the merge is out_q verbatim (lanes past count
        # masked to DISCARD, matching the shifted-merge output bit for bit)
        dest = jnp.where(lane < out_q.count, out_q.dest, DISCARD)
        return out_q.items, dest.astype(jnp.int32), jnp.zeros((C,), jnp.int32)

    items, dest, age_in = jax.lax.cond(n_ret > 0, merge, passthrough, None)
    merged = WorkQueue(
        items=items,
        dest=dest,
        count=count.astype(jnp.int32),
        drops=out_q.drops + (n_tot - count).astype(jnp.int32),
    )
    return merged, age_in


def run_until_done(
    round_fn: Callable[[WorkQueue, Any, jax.Array], Tuple[WorkQueue, Any]],
    q0: WorkQueue,
    aux0: Any,
    cfg: ForwardConfig,
    *,
    max_rounds: int = 64,
) -> Tuple[WorkQueue, Any, jax.Array, jax.Array]:
    """Iterate ``round_fn`` + ``forward_work`` until global termination.

    Args:
      round_fn: ``(in_queue, aux, round_idx) -> (out_queue, aux)`` — consumes
        the input queue and *emits* into a fresh output queue (the paper's
        separate in/out arrays, §3.2).  ``aux`` is arbitrary app state
        (framebuffer, particle traces, ...).

        Drops contract: the driver owns the cumulative drop count.  Each
        round it accumulates the OUTPUT queue's ``drops`` (the round's own
        enqueue overflows plus the forwarding round's clamps); the input
        queue round_fn receives always carries ``drops == 0``, so a round_fn
        that copies its input queue's ``drops`` into the output queue (a
        natural thing to do when threading queue state through) cannot
        double-count earlier rounds.  round_fn must not invent a nonzero
        starting ``drops`` of its own beyond what its enqueues produce.
      q0: initial queue (already filled by the app's ray-gen stage).
      aux0: initial app state.
      cfg: forwarding configuration.
      max_rounds: hard bound (XLA while loops need no bound, but runaway
        protection mirrors the paper's capacity pragmatism).

    Returns ``(final_queue, final_aux, rounds_executed, done)``.  ``done`` is
    the termination verdict: True when the loop exited because the global
    in-flight count hit zero, False when ``max_rounds`` ran out with work
    still in flight (a truncated run — under ``overflow="retain"`` that
    includes retained rows, whose ages are not returned; resume with fresh
    ages if you continue such a run).  With ``cfg.telemetry`` a
    ``telemetry.StatsRing`` of the last ``cfg.telemetry_window`` rounds rides
    the while-loop carry and is returned as a fifth output — EVERY forwarding
    round is recorded, including the initial ray-gen routing round (so a
    drive that runs ``rounds`` body iterations returns ``ring.pos ==
    rounds + 1``).
    """
    telem = cfg.telemetry
    retain = cfg.overflow == "retain"

    def fwd(q, age):
        """Uniform forward_work unpack: ``(new_q, total, age_out, stats)``
        with Nones where the config doesn't produce the value."""
        if retain and telem:
            new_q, total, age_out, stats = forward_work(q, cfg, age=age)
        elif retain:
            new_q, total, age_out = forward_work(q, cfg, age=age)
            stats = None
        elif telem:
            new_q, total, stats = forward_work(q, cfg)
            age_out = None
        else:
            new_q, total = forward_work(q, cfg)
            age_out = stats = None
        return new_q, total, age_out, stats

    n_extra = (1 if retain else 0) + (1 if telem else 0)

    def cond(carry):
        total, rnd = carry[2], carry[3]
        return (total > 0) & (rnd < max_rounds)

    def body(carry):
        q, aux, _total, rnd, drops = carry[:5]
        i = 5
        age = None
        if retain:
            age = carry[i]
            i += 1
        # The input queue's cumulative drops already ride the loop carry;
        # hand round_fn a zero-drop view so a round_fn that threads the input
        # queue's drops into its output cannot double-count them (see the
        # drops contract in the docstring).
        q = WorkQueue(items=q.items, dest=q.dest, count=q.count,
                      drops=jnp.zeros_like(q.drops))
        if retain:
            n_ret, view = _split_retained(q)
            out_q, aux = round_fn(view, aux, rnd)
            fwd_q, age_in = _merge_retained(q, n_ret, out_q, age)
        else:
            fwd_q, aux = round_fn(q, aux, rnd)
            age_in = None
        new_q, total, age_out, stats = fwd(fwd_q, age_in)
        # Per-round queues are fresh, so cumulative overflow drops must ride
        # the loop carry (observability: silent loss is a capacity bug).
        drops = drops + new_q.drops
        out = (
            _vary(new_q, cfg.axis_name),
            _vary(aux, cfg.axis_name),
            total,
            rnd + 1,
            _vary(drops, cfg.axis_name),
        )
        if retain:
            out = out + (_vary(age_out, cfg.axis_name),)
        if telem:
            ring = TS.ring_push(carry[i], stats)
            out = out + (_vary(ring, cfg.axis_name),)
        return out

    # Initial forward: route the ray-gen output to its owners (the paper's
    # VoPaT does exactly this — primary rays are "forwarded to itself").
    q1, total0, age1, stats0 = fwd(q0, None)
    carry0 = (
        _vary(q1, cfg.axis_name),
        _vary(aux0, cfg.axis_name),
        total0,
        jnp.zeros((), jnp.int32),
        _vary(q1.drops, cfg.axis_name),
    )
    if retain:
        carry0 = carry0 + (_vary(age1, cfg.axis_name),)
    if telem:
        ring0 = TS.ring_push(
            TS.make_ring(
                TS.num_tiers(cfg),
                window=cfg.telemetry_window,
                buckets=cfg.telemetry_buckets,
            ),
            stats0,
        )
        carry0 = carry0 + (_vary(ring0, cfg.axis_name),)
    out = jax.lax.while_loop(cond, body, carry0)
    q, aux, total, rounds, drops = out[:5]
    done = total == 0
    q = WorkQueue(items=q.items, dest=q.dest, count=q.count, drops=drops)
    if telem:
        return q, aux, rounds, done, out[4 + n_extra]
    return q, aux, rounds, done

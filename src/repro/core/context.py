"""Host-side RaFI context (paper §3.4) — mesh plumbing around the core.

``RafiContext`` is the JAX analogue of ``HostContext<T>``: it owns the static
configuration (item type, capacities, exchange backend, mesh axis), builds
per-rank queues, and wraps the collective entry points in ``shard_map`` so
applications never touch sharding specs.  The paper's three host operations
map directly:

  resizeRayQueues(N)     → ``capacity``/``peer_capacity`` in the constructor
                           (static shapes; see DESIGN.md on why this is the
                           faithful mapping of the paper's §6.3 contract)
  getDeviceInterface()   → ``repro.core.queue`` (enqueue/get/num_incoming) —
                           plain functions usable inside any traced kernel
  forwardRays()          → :meth:`forward` (single round) /
                           :meth:`run_until_done` (whole drive loop on device)

Multiple contexts with different item types in the same program are fully
supported (the N-body app uses three, §5.5) — contexts are just values.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import queue as Q
from repro.core import termination as term
from repro.core.forwarding import ForwardConfig, flatten_axis_names, forward_work
from repro.core.types import item_nbytes
from repro.telemetry import stats as TS

__all__ = ["RafiContext"]


def _axis_size(mesh: Mesh, axis_name) -> int:
    if isinstance(axis_name, (tuple, list)):
        n = 1
        for a in axis_name:
            n *= _axis_size(mesh, a)  # an entry may be a joint tier (tuple)
        return n
    return mesh.shape[axis_name]


class RafiContext:
    """A typed work-forwarding context bound to one mesh axis."""

    def __init__(
        self,
        mesh: Mesh,
        proto: Any,
        *,
        axis_name: Any = "data",
        capacity: int,
        peer_capacity: int = 0,
        exchange: str = "padded",
        marshal: str = "sort",
        sort_method: str = "pack",
        use_pallas: bool = False,
        fast_size: int = 0,
        node_capacity: int = 0,
        level_sizes=(),
        level_capacities=(),
        telemetry: bool = False,
        telemetry_window: int = 16,
        telemetry_buckets: int = 8,
        overflow: str = "drop",
        pipeline_shards: int = 1,
        flow: str = "open",
        emit_reserve: int = -1,
    ):
        self.mesh = mesh
        self.proto = proto
        self.item_nbytes = item_nbytes(proto)
        if (
            exchange == "hierarchical"
            and not level_sizes
            and fast_size <= 0
            and isinstance(axis_name, (tuple, list))
        ):
            # derive one rank count per tier from the bound mesh (a tier may
            # itself be a tuple of mesh axes — one joint fabric)
            level_sizes = tuple(_axis_size(mesh, a) for a in axis_name)
        self.cfg = ForwardConfig(
            axis_name=axis_name,
            num_ranks=_axis_size(mesh, axis_name),
            capacity=capacity,
            peer_capacity=peer_capacity,
            exchange=exchange,
            marshal=marshal,
            sort_method=sort_method,
            use_pallas=use_pallas,
            fast_size=fast_size,
            node_capacity=node_capacity,
            level_sizes=tuple(level_sizes),
            level_capacities=tuple(level_capacities),
            telemetry=telemetry,
            telemetry_window=telemetry_window,
            telemetry_buckets=telemetry_buckets,
            overflow=overflow,
            pipeline_shards=pipeline_shards,
            flow=flow,
            emit_reserve=emit_reserve,
        )
        # PartitionSpec entries cannot nest: a joint-tier axis_name like
        # (("pod", "node"), "device") shards dim 0 over the flattened axes
        self._spec = P(flatten_axis_names(axis_name))

    # -- queue construction -------------------------------------------------
    @property
    def num_ranks(self) -> int:
        return self.cfg.num_ranks

    def local_queue(self) -> Q.WorkQueue:
        """Per-rank empty queue (for use *inside* shard_map'ed code)."""
        return Q.make_queue(self.proto, self.cfg.capacity)

    def global_queue(self) -> Q.WorkQueue:
        """Global (host-visible) empty queue: leaves (R*capacity, ...) sharded
        over the context axis."""
        q = Q.make_queue(self.proto, self.cfg.capacity * self.num_ranks)
        return jax.device_put(q, jax.NamedSharding(self.mesh, self._spec))

    def queue_specs(self):
        """PartitionSpecs of a global queue (items leaves, dest: sharded;
        count/drops: per-rank scalars stacked — see shard wrappers below)."""
        return Q.WorkQueue(
            items=jax.tree.map(lambda _: self._spec, self.proto),
            dest=self._spec,
            count=self._spec,
            drops=self._spec,
        )

    # -- collective entry points --------------------------------------------
    def shard(self, fn: Callable, *, in_specs, out_specs) -> Callable:
        """shard_map + jit a per-rank function over the context's mesh."""
        return jax.jit(
            compat.shard_map(fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs)
        )

    def forward_rays(self) -> Callable:
        """The paper's ``forwardRays()``: a jitted global function taking a
        stacked global queue and returning ``(forwarded_queue, total)`` —
        plus, with ``overflow="retain"``, the per-lane ``age`` counter
        (sharded ``(R·C,)``; each standalone call starts ages fresh — the
        on-device drive loop is where ages thread across rounds), and the
        round's rank-stacked ``RoundStats`` when the context has
        ``telemetry`` on."""
        cfg = self.cfg
        retain = cfg.overflow == "retain"

        def step(q_stacked):
            q = _unstack_queue(q_stacked)
            if retain and cfg.telemetry:
                new_q, total, age, stats = forward_work(q, cfg)
                return _stack_queue(new_q), total, age, TS.stack_ring(stats)
            if retain:
                new_q, total, age = forward_work(q, cfg)
                return _stack_queue(new_q), total, age
            if cfg.telemetry:
                new_q, total, stats = forward_work(q, cfg)
                return _stack_queue(new_q), total, TS.stack_ring(stats)
            new_q, total = forward_work(q, cfg)
            return _stack_queue(new_q), total

        out_specs = (self._queue_out_specs(), P())
        if retain:
            out_specs = out_specs + (self._spec,)
        if cfg.telemetry:
            out_specs = out_specs + (self._stats_specs(),)
        return self.shard(
            step,
            in_specs=(self._queue_out_specs(),),
            out_specs=out_specs,
        )

    def run_until_done(
        self,
        round_fn: Callable,
        *,
        aux_specs: Any,
        max_rounds: int = 64,
        with_health: bool = False,
    ) -> Callable:
        """Jitted global driver: ``(q0_stacked, aux0) -> (q, aux, rounds,
        done, …)``.  ``done`` is True when the drive terminated cleanly
        (global in-flight count hit zero), False when ``max_rounds``
        truncated it with work still in flight.

        ``round_fn(in_queue, aux, round_idx) -> (out_queue, aux)`` is per-rank
        traced code using the device interface (enqueue/get_incoming).

        With ``overflow="retain"`` on the context, the final per-lane ``age``
        vector (sharded ``(R·C,)``) follows ``done`` — on a truncated run
        these are the live rounds-waiting counters of the still-queued rows,
        so a continuation preserves the FIFO anti-starvation clock.  With
        ``telemetry`` on the context, the rank-stacked ``telemetry.StatsRing``
        of the burst's last ``telemetry_window`` rounds is the last output
        (leaves ``(R, window, …)`` on the host) — feed it to
        ``telemetry.summarize`` / ``tune.plan_capacities``.

        ``with_health=True`` makes the returned callable accept a third
        argument: a replicated ``(R,) bool`` rank-health mask re-addressing
        traffic away from unhealthy ranks (see ``repro.core.health``).
        """
        cfg = self.cfg
        retain = cfg.overflow == "retain"

        def drive(q0_stacked, aux0, health=None):
            q0 = _unstack_queue(q0_stacked)
            out = term.run_until_done(
                round_fn, q0, aux0, cfg, max_rounds=max_rounds, health=health
            )
            q, aux, rounds, done = out[:4]
            rest = out[4:]
            packed = (_stack_queue(q), aux, rounds, done)
            if retain:
                packed = packed + (rest[0],)
                rest = rest[1:]
            if cfg.telemetry:
                packed = packed + (TS.stack_ring(rest[0]),)
            return packed

        out_specs = (self._queue_out_specs(), aux_specs, P(), P())
        if retain:
            out_specs = out_specs + (self._spec,)
        if cfg.telemetry:
            out_specs = out_specs + (self._ring_specs(),)
        in_specs = (self._queue_out_specs(), aux_specs)
        if with_health:
            in_specs = in_specs + (P(),)
            drive_p = self.shard(drive, in_specs=in_specs, out_specs=out_specs)
        else:
            drive_p = self.shard(
                lambda q0s, aux0: drive(q0s, aux0),
                in_specs=in_specs,
                out_specs=out_specs,
            )

        # Observation hook (host-side only — the traced program is untouched,
        # so the lowered HLO is bit-identical with tracing on or off): each
        # burst invocation becomes one span carrying the drive's outcome.
        def traced_drive(*args):
            from repro.obs import trace as OT

            if not OT.enabled():
                return drive_p(*args)
            with OT.span(
                "drive.run_until_done", OT.CAT_DRIVE,
                exchange=cfg.exchange, flow=cfg.flow, overflow=cfg.overflow,
                max_rounds=max_rounds, num_ranks=self.num_ranks,
            ) as sp:
                out = drive_p(*args)
                sp.set(rounds=out[2], done=out[3])
            return out

        # keep the jit inspection surface (tests lower the drive to audit
        # its collective inventory; the host-side span wrapper must not
        # hide it)
        traced_drive.lower = drive_p.lower
        return traced_drive

    # -- segmented (checkpointable) drive ------------------------------------
    def carry_specs(self, aux_specs: Any, *, accounting: bool = True):
        """PartitionSpecs of the *stacked* drive-loop carry dict (see
        ``termination.drive_start``): per-rank leaves sharded over the
        context axis, ``total``/``rnd`` replicated."""
        cfg = self.cfg
        specs = {
            "q": self._queue_out_specs(),
            "aux": aux_specs,
            "total": P(),
            "rnd": P(),
            "drops": self._spec,
        }
        if cfg.overflow == "retain":
            specs["age"] = self._spec
        if cfg.flow == "credit":
            # per-rank (R,) credit vector stacks to (R·R,), like age's lanes
            specs["credits"] = self._spec
        if cfg.telemetry:
            specs["ring"] = self._ring_specs()
        if accounting:
            specs["emitted"] = self._spec
            specs["delivered"] = self._spec
        return specs

    def checkpoint_drive_programs(
        self, round_fn: Callable, *, aux_specs: Any, accounting: bool = True
    ) -> Tuple[Callable, Callable]:
        """The segmented drive as TWO jitted programs (the recovery law's
        device side — ``repro.core.recovery`` owns the host loop):

          ``start(q0_stacked, aux0, health) -> carry``   (initial forward)
          ``segment(carry, seg_end, health) -> carry``   (rounds until
                                                          ``rnd == seg_end``
                                                          or termination)

        The carry is the stacked ``termination`` dict carry — a plain pytree
        the host can snapshot with ``repro.ckpt`` between segments.
        ``seg_end`` and ``health`` are *traced* (replicated) arguments, so
        every segment of every length reuses one compiled program and the
        segmented trajectory is bit-identical to ``run_until_done``'s.  With
        ``accounting`` the carry grows the ``emitted``/``delivered`` counters
        the recovery watchdog closes at each boundary.
        """
        cfg = self.cfg

        def start(q0_stacked, aux0, health):
            carry = term.drive_start(
                _unstack_queue(q0_stacked), aux0, cfg,
                health=health, accounting=accounting,
            )
            return _stack_carry(carry)

        def segment(carry_stacked, seg_end, health):
            carry = term.drive_segment(
                round_fn, _unstack_carry(carry_stacked), cfg,
                seg_end=seg_end, health=health,
            )
            return _stack_carry(carry)

        cspecs = self.carry_specs(aux_specs, accounting=accounting)
        start_p = self.shard(
            start,
            in_specs=(self._queue_out_specs(), aux_specs, P()),
            out_specs=cspecs,
        )
        segment_p = self.shard(
            segment, in_specs=(cspecs, P(), P()), out_specs=cspecs
        )
        return start_p, segment_p

    def _queue_out_specs(self):
        return Q.WorkQueue(
            items=jax.tree.map(lambda _: self._spec, self.proto),
            dest=self._spec,
            count=self._spec,
            drops=self._spec,
        )

    def _stats_specs(self):
        """Specs of a rank-stacked ``RoundStats`` (every leaf sharded on the
        prepended rank dim)."""
        proto = TS.make_stats(TS.num_tiers(self.cfg), self.cfg.telemetry_buckets)
        return jax.tree.map(lambda _: self._spec, proto)

    def _ring_specs(self):
        """Specs of a rank-stacked ``StatsRing``."""
        proto = TS.make_ring(
            TS.num_tiers(self.cfg),
            window=self.cfg.telemetry_window,
            buckets=self.cfg.telemetry_buckets,
        )
        return jax.tree.map(lambda _: self._spec, proto)


def _stack_queue(q: Q.WorkQueue) -> Q.WorkQueue:
    """Per-rank queue -> globally concatenable form (scalars become (1,))."""
    return Q.WorkQueue(
        items=q.items, dest=q.dest, count=q.count[None], drops=q.drops[None]
    )


def _unstack_queue(q: Q.WorkQueue) -> Q.WorkQueue:
    return Q.WorkQueue(
        items=q.items, dest=q.dest, count=q.count[0], drops=q.drops[0]
    )


def _stack_carry(carry: dict) -> dict:
    """Per-rank drive carry -> globally concatenable form: per-rank scalars
    become (1,) (so the stacked leaf is (R,)), the ring gains a leading rank
    dim; ``total``/``rnd`` stay replicated scalars; ``age`` is already a
    per-lane vector."""
    out = dict(carry)
    out["q"] = _stack_queue(carry["q"])
    out["drops"] = carry["drops"][None]
    if "ring" in carry:
        out["ring"] = TS.stack_ring(carry["ring"])
    if "emitted" in carry:
        out["emitted"] = carry["emitted"][None]
        out["delivered"] = carry["delivered"][None]
    return out


def _unstack_carry(carry: dict) -> dict:
    out = dict(carry)
    out["q"] = _unstack_queue(carry["q"])
    out["drops"] = carry["drops"][0]
    if "ring" in carry:
        out["ring"] = jax.tree.map(lambda a: a[0], carry["ring"])
    if "emitted" in carry:
        out["emitted"] = carry["emitted"][0]
        out["delivered"] = carry["delivered"][0]
    return out

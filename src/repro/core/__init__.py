"""repro.core — the paper's contribution: a work-forwarding infrastructure.

Public surface (the JAX analogue of RaFI's two headers):

Device interface (usable inside any traced kernel):
  WorkQueue, make_queue, enqueue, get_incoming, num_incoming, DISCARD

Host context:
  RafiContext (mesh plumbing), ForwardConfig, forward_work (inside shard_map),
  run_until_done (on-device drive loop), rebalance (beyond-paper).

Recovery (ISSUE 7): run_checkpointed / resume_run (segmented drive with
  atomic checkpoints, elastic restore, conservation watchdog),
  health_table / remap_dest (rank-draining destination remap).

Item typing:
  work_item (dataclass registry), item_nbytes.
"""
from repro.core.context import RafiContext
from repro.core.cycling import cycle_step, deliver_by_cycling
from repro.core.forwarding import ForwardConfig, forward_work
from repro.core.health import health_table, remap_dest
from repro.core.queue import (
    DISCARD,
    WorkQueue,
    clear,
    enqueue,
    get_incoming,
    make_queue,
    num_incoming,
)
from repro.core.rebalance import rebalance
from repro.core.recovery import conservation_check, resume_run, run_checkpointed
from repro.core.termination import run_until_done
from repro.core.types import (
    PackSpec,
    batched_zeros,
    item_nbytes,
    item_spec,
    pack_payload,
    pack_spec,
    unpack_payload,
    work_item,
)

__all__ = [
    "DISCARD",
    "ForwardConfig",
    "PackSpec",
    "RafiContext",
    "WorkQueue",
    "batched_zeros",
    "clear",
    "conservation_check",
    "enqueue",
    "forward_work",
    "get_incoming",
    "health_table",
    "item_nbytes",
    "item_spec",
    "make_queue",
    "num_incoming",
    "pack_payload",
    "pack_spec",
    "rebalance",
    "remap_dest",
    "resume_run",
    "run_checkpointed",
    "run_until_done",
    "unpack_payload",
    "work_item",
]

"""Sort-by-destination — the TPU adaptation of RaFI §4.2.1 — and its
sort-free successor, the bucket-scatter marshal plan.

The paper packs ``dest << 32 | idx`` into a uint64, radix-sorts the keys with
cub, then permutes the payload ("each ray gets read exactly once and written
exactly once").  Destinations occupy very few bits (≤1024 ranks → 10 bits),
so on TPU we adapt rather than port.  Two marshal modes share this module
(selected by ``ForwardConfig(marshal=...)``):

``marshal="sort"`` — the paper-faithful path:

* **pack**  — keys ``(dest << idx_bits) | idx`` in a single uint32 (x64 is
  off by default in JAX; 32 bits suffice whenever
  ``log2(R+1) + log2(C) ≤ 32``), sorted with ``jax.lax.sort`` (XLA's native
  TPU sorter, the cub analogue).  Sorting a packed key is bit-identical to a
  stable sort on ``dest``.
* **argsort** — stable argsort on the destination vector; fallback when the
  packed key would not fit 32 bits.

``marshal="scatter"`` — the counting-sort observation: destination ranks live
in a tiny domain (R ≤ a few hundred), so a generic O(C log C) key sort is
overkill.  :func:`destination_rank` computes, in ONE pass over the (cheap,
1-word-per-item) destination vector, everything the send marshal needs — the
sanitized destination, each item's stable rank *within* its destination
bucket, and the histogram (send counts fall out for free).  The exchange then
scatters packed payload rows straight into the send-buffer layout
(``base[dest] + rank``): no key materialization, no sort, no separate gather
— one payload pass pre-collective.  The sort path is kept as the
bit-exactness oracle (the scatter placement must reproduce its lexicographic
stable source order end to end; property-tested in
``tests/test_core_scatter.py``).

Shared pieces:

* the per-destination histogram is computed with a one-hot contraction (MXU
  friendly) / scatter-add, replacing the paper's boundary-detection kernel;
  ``segment_bounds_from_sorted`` keeps the paper's exact begin/end-detection
  formulation for cross-validation only (property-tested equal) — the
  exchanges derive every segment bound in O(R) from the one histogram
  (:func:`segment_bounds_from_histogram`), never by re-scanning the sorted
  destination vector per tier.

Invalid items (lane ≥ count, or dest < 0) get destination ``R`` (one past the
last rank) so they sort to the tail and fall out of every segment.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import types as T

__all__ = [
    "sort_by_destination",
    "sort_permutation",
    "sort_permutation_hierarchical",
    "destination_histogram",
    "destination_rank",
    "segment_offsets",
    "segment_bounds_from_sorted",
    "segment_bounds_from_histogram",
    "pack_keys",
    "pack_keys_hierarchical",
    "unpack_keys",
    "unpack_keys_hierarchical",
]


def _idx_bits(capacity: int) -> int:
    return max(1, (capacity - 1).bit_length())


def pack_keys(dest: jax.Array, count: jax.Array, num_ranks: int) -> jax.Array:
    """Pack (dest, lane) into uint32 keys; invalid lanes get dest=num_ranks."""
    cap = dest.shape[0]
    ib = _idx_bits(cap)
    if (num_ranks + 1).bit_length() + ib > 32:
        raise ValueError(
            f"packed key needs {(num_ranks + 1).bit_length()}+{ib} bits > 32; "
            "use method='argsort'"
        )
    lane = jnp.arange(cap, dtype=jnp.uint32)
    valid = (lane < count.astype(jnp.uint32)) & (dest >= 0) & (dest < num_ranks)
    d = jnp.where(valid, dest, num_ranks).astype(jnp.uint32)
    return (d << ib) | lane


def unpack_keys(keys: jax.Array, capacity: int, num_ranks: int) -> Tuple[jax.Array, jax.Array]:
    """Inverse of :func:`pack_keys` → (dest, lane)."""
    ib = _idx_bits(capacity)
    dest = (keys >> ib).astype(jnp.int32)
    lane = (keys & jnp.uint32((1 << ib) - 1)).astype(jnp.int32)
    return dest, lane


def _field_bits(n_values: int) -> int:
    return max(1, (n_values - 1).bit_length())


def pack_keys_hierarchical(
    dest: jax.Array, count: jax.Array, level_sizes: Tuple[int, ...]
) -> jax.Array:
    """Lexicographic N-level keys ``(d_0, d_1, …, d_{L-1}, slot)`` — one
    bit-field per mesh tier, slowest first.

    One sort of these keys yields EVERY stage permutation of the N-level
    hierarchical exchange: the bit-field layout is lexicographic in
    ``(d_0, …, d_{L-1}, slot)``, so the sorted order simultaneously groups
    items per destination digit at every tier (each stage's send layout is a
    pure segment permutation of it) while keeping every destination run in
    stable slot order — exactly the per-segment contiguity each slower stage
    re-exchanges.

    Global ranks are lexicographic in the digits (``rank = ((d_0·A_1 + d_1)·A_2
    + …)``, slowest-major — "node-major" in the 2-level case), so the key order
    coincides with the flat :func:`pack_keys` order — cross-validated in
    tests — but the field split makes the ``level_sizes``-shaped count tensor
    and every stage layout directly addressable.

    Invalid lanes (lane >= count, dest out of range) get slowest digit
    ``A_0`` (one past the last value) and sort past every valid key.
    """
    level_sizes = tuple(int(a) for a in level_sizes)
    cap = dest.shape[0]
    ib = _idx_bits(cap)
    bits = [_field_bits(level_sizes[0] + 1)] + [
        _field_bits(a) for a in level_sizes[1:]
    ]
    if sum(bits) + ib > 32:
        raise ValueError(
            f"hierarchical key needs {'+'.join(map(str, bits))}+{ib} bits > 32; "
            "use method='argsort'"
        )
    num_ranks = 1
    for a in level_sizes:
        num_ranks *= a
    lane = jnp.arange(cap, dtype=jnp.uint32)
    valid = (lane < count.astype(jnp.uint32)) & (dest >= 0) & (dest < num_ranks)
    d = jnp.where(valid, dest, 0).astype(jnp.uint32)
    key = lane
    shift = ib
    # fastest digit sits just above the slot bits; slowest ends up on top
    for a, b in zip(reversed(level_sizes[1:]), reversed(bits[1:])):
        key = key | ((d % jnp.uint32(a)) << shift)
        d = d // jnp.uint32(a)
        shift += b
    slowest = jnp.where(valid, d, jnp.uint32(level_sizes[0]))
    return key | (slowest << shift)


def unpack_keys_hierarchical(
    keys: jax.Array, capacity: int, level_sizes: Tuple[int, ...]
) -> Tuple[Tuple[jax.Array, ...], jax.Array]:
    """Inverse of :func:`pack_keys_hierarchical` → ``((d_0, …, d_{L-1}), slot)``
    with digits slowest-first."""
    level_sizes = tuple(int(a) for a in level_sizes)
    ib = _idx_bits(capacity)
    bits = [_field_bits(level_sizes[0] + 1)] + [
        _field_bits(a) for a in level_sizes[1:]
    ]
    slot = (keys & jnp.uint32((1 << ib) - 1)).astype(jnp.int32)
    digits = []
    shift = ib
    for b in reversed(bits[1:]):
        digits.append(((keys >> shift) & jnp.uint32((1 << b) - 1)).astype(jnp.int32))
        shift += b
    digits.append((keys >> shift).astype(jnp.int32))
    return tuple(reversed(digits)), slot


def sort_permutation_hierarchical(
    dest: jax.Array,
    count: jax.Array,
    level_sizes: Tuple[int, ...],
    *,
    method: str = "pack",
) -> Tuple[jax.Array, jax.Array]:
    """The hierarchical exchange's §4.2.1 analogue: ONE key sort that yields
    every stage permutation of the N-level route.

    Returns ``(perm, count_tensor)`` where ``perm`` is the lexicographic
    (slowest-major) destination-sort permutation (identical to the flat
    :func:`sort_permutation` order, since global ranks are lexicographic in
    the digits) and ``count_tensor`` is the ``level_sizes``-shaped
    per-destination-digit histogram — the only control-plane input any stage
    of ``exchange_hierarchical`` needs.
    """
    level_sizes = tuple(int(a) for a in level_sizes)
    num_ranks = 1
    for a in level_sizes:
        num_ranks *= a
    cap = dest.shape[0]
    if method == "pack":
        keys = pack_keys_hierarchical(dest, count, level_sizes)
        sorted_keys = jax.lax.sort(keys)
        _digits, perm = unpack_keys_hierarchical(sorted_keys, cap, level_sizes)
    elif method == "argsort":
        lane = jnp.arange(cap, dtype=jnp.int32)
        valid = (lane < count) & (dest >= 0) & (dest < num_ranks)
        d = jnp.where(valid, dest, num_ranks)
        perm = jnp.argsort(d, stable=True).astype(jnp.int32)
    else:
        raise ValueError(f"unknown sort method {method!r}")
    hist = destination_histogram(dest, count, num_ranks)
    return perm, hist[:num_ranks].reshape(level_sizes)


def destination_histogram(dest: jax.Array, count: jax.Array, num_ranks: int) -> jax.Array:
    """(num_ranks+1,) int32 counts per destination; slot R = invalid/discard."""
    cap = dest.shape[0]
    lane = jnp.arange(cap, dtype=jnp.int32)
    valid = (lane < count) & (dest >= 0) & (dest < num_ranks)
    d = jnp.where(valid, dest, num_ranks)
    return jnp.zeros((num_ranks + 1,), jnp.int32).at[d].add(1)


def destination_rank(
    dest: jax.Array, count: jax.Array, num_ranks: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The bucket-scatter marshal plan — ONE pass over the destination vector.

    Returns ``(d_clean, rank, hist)``:

    * ``d_clean`` (C,) int32 — the sanitized destination (invalid lanes → R);
    * ``rank``    (C,) int32 — the lane's stable rank among earlier lanes with
      the SAME sanitized destination (the counting-sort position: item ``i``
      of the sorted order is exactly the item with ``rank == i - off[d]``, so
      ``base[d_clean] + rank`` reproduces the §4.2.1 stable sort placement
      without materializing keys or sorting);
    * ``hist``    (R+1,) int32 — the per-destination histogram (slot R =
      invalid/discard), identical to :func:`destination_histogram` — the send
      counts fall out of the same pass for free.

    Formulation: one-hot exclusive prefix sum over the lane axis — via
    ``lax.associative_scan`` rather than ``jnp.cumsum``, deliberately:
    XLA:CPU lowers a 2-D axis-0 cumsum to *parallel* reduce-window calls
    whose thread-pool fork/join contends with the SPMD ranks sharing the
    host (measurably slower inside an 8-way shard_map round), while the
    log-depth scan lowers to plain fused adds/slices.  (The Pallas kernel of
    ``kernels/bucket_scatter`` computes the identical quantities with
    chunked MXU prefix matmuls; its pure-jnp ``ref`` keeps the naive cumsum
    as a third, independent formulation.)
    """
    cap = dest.shape[0]
    lane = jnp.arange(cap, dtype=jnp.int32)
    valid = (lane < count) & (dest >= 0) & (dest < num_ranks)
    d = jnp.where(valid, dest, num_ranks).astype(jnp.int32)
    onehot = (
        d[:, None] == jnp.arange(num_ranks + 1, dtype=jnp.int32)[None, :]
    ).astype(jnp.int32)
    incl = jax.lax.associative_scan(jnp.add, onehot, axis=0)
    excl = incl - onehot  # earlier same-bucket lanes
    rank = jnp.take_along_axis(excl, d[:, None], axis=1)[:, 0]
    return d, rank.astype(jnp.int32), incl[-1].astype(jnp.int32)


def segment_offsets(send_counts: jax.Array) -> jax.Array:
    """Exclusive prefix sum → start offset of each rank's segment."""
    return jnp.cumsum(send_counts) - send_counts


def segment_bounds_from_histogram(send_counts: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(begin, end) of every rank's segment, derived in O(R) from the ONE
    histogram — no pass over the (sorted) destination vector at all.

    This is what the exchanges use at every hierarchical tier: stage ``l``
    reshapes the histogram-derived counts and prefix-sums them per sub-
    segment, so the L-stage route re-reads the destination vector ZERO times
    after the single histogram pass.  :func:`segment_bounds_from_sorted`
    (the paper's neighbor-compare boundary detection, one O(C) pass per call)
    survives only as the cross-validation oracle — property-tested equal.
    """
    off = segment_offsets(send_counts)
    return off, off + send_counts


def segment_bounds_from_sorted(sorted_dest: jax.Array, num_ranks: int) -> Tuple[jax.Array, jax.Array]:
    """The paper's §4.2.2-step-1 boundary detection, kept verbatim for
    cross-validation: for each rank, find begin/end of its segment in the
    sorted destination array by comparing neighbours (sentinel ``-1`` where a
    rank received nothing, then gap-filled).  Returns (begin, end), each
    ``(num_ranks,) int32``; ``end - begin`` equals the histogram counts.
    """
    n = sorted_dest.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    prev = jnp.concatenate([jnp.array([-1], jnp.int32), sorted_dest[:-1]])
    nxt = jnp.concatenate([sorted_dest[1:], jnp.full((1,), num_ranks + 1, jnp.int32)])
    is_begin = sorted_dest != prev
    is_end = sorted_dest != nxt
    begin = jnp.full((num_ranks + 1,), -1, jnp.int32)
    end = jnp.full((num_ranks + 1,), -1, jnp.int32)
    d = jnp.clip(sorted_dest, 0, num_ranks)
    begin = begin.at[jnp.where(is_begin, d, num_ranks)].max(i, mode="drop")
    # (each begin/end found by exactly one lane — max is a no-op combiner)
    end = end.at[jnp.where(is_end, d, num_ranks)].max(i + 1, mode="drop")
    begin, end = begin[:num_ranks], end[:num_ranks]
    # gap fill (paper: "fill in any gaps — some ranks may not have received
    # any rays"): empty ranks get begin=end=next segment's begin.
    def fill(carry, be):
        b, e = be
        nxt_begin = carry
        b = jnp.where(b < 0, nxt_begin, b)
        e = jnp.where(e < 0, nxt_begin, e)
        return b, (b, e)

    total_valid = jnp.sum((sorted_dest >= 0) & (sorted_dest < num_ranks)).astype(jnp.int32)
    _, (begin, end) = jax.lax.scan(fill, total_valid, (begin, end), reverse=True)
    return begin, end


def sort_permutation(
    dest: jax.Array,
    count: jax.Array,
    num_ranks: int,
    *,
    method: str = "pack",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """§4.2.1 key sort WITHOUT touching the payload.

    Returns ``(perm, sorted_dest, send_counts)`` — ``perm[i]`` is the source
    lane of sorted position ``i`` (a stable sort by sanitized destination;
    invalid lanes sort to the tail with dest == num_ranks), and
    ``send_counts`` is the ``(num_ranks+1,)`` histogram (slot R = invalid).

    The payload permutation is deliberately NOT applied here: the hot path
    composes ``perm`` with the exchange's send-layout gather so the packed
    payload is read exactly once and written exactly once per round (the
    paper's §4.2.1 contract, now including the marshal step).
    """
    cap = dest.shape[0]
    if method == "pack":
        keys = pack_keys(dest, count, num_ranks)
        sorted_keys = jax.lax.sort(keys)
        d_sorted, perm = unpack_keys(sorted_keys, cap, num_ranks)
    elif method == "argsort":
        lane = jnp.arange(cap, dtype=jnp.int32)
        valid = (lane < count) & (dest >= 0) & (dest < num_ranks)
        d = jnp.where(valid, dest, num_ranks)
        perm = jnp.argsort(d, stable=True).astype(jnp.int32)
        d_sorted = d[perm]
    else:
        raise ValueError(f"unknown sort method {method!r}")
    send_counts = destination_histogram(dest, count, num_ranks)
    return perm, d_sorted, send_counts


def sort_by_destination(
    items: Any,
    dest: jax.Array,
    count: jax.Array,
    num_ranks: int,
    *,
    method: str = "pack",
) -> Tuple[Any, jax.Array, jax.Array]:
    """§4.2.1: stable-sort (items, dest) by destination rank.

    Returns ``(sorted_items, sorted_dest, send_counts)``.  Convenience form
    of :func:`sort_permutation` that applies the permutation leaf-wise; the
    forwarding hot path uses :func:`sort_permutation` directly and folds the
    permutation into the packed-payload marshal gather instead.
    """
    perm, d_sorted, send_counts = sort_permutation(
        dest, count, num_ranks, method=method
    )
    sorted_items = T.tree_take(items, perm)
    return sorted_items, d_sorted, send_counts

"""Fixed-capacity work queues — the TPU adaptation of RaFI's ray queues (§3.2).

The paper's output queue grows via ``atomicAdd`` on a device counter; each
emit appends ``(ray, destRank)``.  TPUs have no global atomics, so the queue
is adapted to the vector paradigm:

* a queue is a pytree buffer of static capacity ``C`` plus an active ``count``;
  entries ``[0, count)`` are valid and contiguous (same invariant the paper's
  sorted/compacted arrays maintain);
* kernels *emit* by producing per-lane ``(item, dest, mask)`` triples; an
  ``enqueue`` performs prefix-sum stream compaction and appends — the
  deterministic, order-stable equivalent of the atomic append.  A kernel
  round may call ``enqueue`` several times (a shaded ray emitting both a
  bounce ray and a shadow ray — §3.3 "threads can emit more than one").
* emits beyond capacity are dropped and counted, exactly matching §3.3
  ("calls that would exceed the output queue size will simply get dropped").

Destination ``-1`` marks an invalid / discarded item (the paper's early
single-array design used the same sentinel; we keep it as the tombstone).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import types as T

__all__ = ["WorkQueue", "make_queue", "enqueue", "num_incoming", "get_incoming", "clear"]

DISCARD = -1  # sentinel destination: item goes nowhere (paper §3.2)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WorkQueue:
    """A bounded queue of work items with per-item destination ranks.

    Attributes:
      items: pytree, every leaf shaped ``(capacity, ...)``.
      dest:  ``(capacity,) int32`` destination rank per item; ``-1`` = discard.
      count: ``() int32`` number of valid items at the front.
      drops: ``() int32`` cumulative overflow-dropped emits (observability).
    """

    items: Any
    dest: jax.Array
    count: jax.Array
    drops: jax.Array

    @property
    def capacity(self) -> int:
        return jax.tree.leaves(self.items)[0].shape[0]


def make_queue(proto, capacity: int) -> WorkQueue:
    """An empty queue for items shaped like ``proto`` (a single-item pytree).

    ``capacity`` must be a positive Python int — it is the queue's static
    shape, so a traced or non-positive value is a config bug worth a clear
    error here rather than an opaque reshape failure downstream.
    """
    if not isinstance(capacity, (int, jnp.integer)) or isinstance(capacity, bool):
        raise ValueError(
            f"capacity must be a static Python int (got {type(capacity).__name__}): "
            "it fixes the queue's buffer shapes"
        )
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    return WorkQueue(
        items=T.batched_zeros(proto, capacity),
        dest=jnp.full((capacity,), DISCARD, dtype=jnp.int32),
        count=jnp.zeros((), jnp.int32),
        drops=jnp.zeros((), jnp.int32),
    )


def num_incoming(q: WorkQueue) -> jax.Array:
    """Paper's ``DeviceInterface::numIncoming()``."""
    return q.count


def get_incoming(q: WorkQueue, i) -> Any:
    """Paper's ``DeviceInterface::getIncoming(rayID)`` — reads item ``i``."""
    return jax.tree.map(lambda a: a[i], q.items)


def enqueue(q: WorkQueue, items, dest, mask, *, num_ranks: int = None) -> WorkQueue:
    """Paper's ``DeviceInterface::emitOutgoing(ray, dest)``, vectorised.

    Appends the masked lanes of ``items``/``dest`` to the queue in lane order
    (stable).  ``mask`` lanes that would land past capacity are dropped and
    counted.  ``dest`` must be a valid rank (or ``DISCARD`` to drop).

    Args:
      items: pytree with leaves ``(n, ...)``.
      dest:  ``(n,)`` integer dtype.  A float dest raises at trace time — it
        would silently truncate-cast and misroute (a real emit-kernel bug
        class); the marshal's deep sanitize is a backstop, not an API.
      mask:  ``(n,)`` bool — which lanes actually emit.  Integer masks are
        accepted with nonzero-is-emit semantics: the mask is normalised to
        bool BEFORE combining with the dest check, because ``int_mask &
        (dest >= 0)`` is a BITWISE and (an int mask value of 2 & True == 0 —
        a silently lost emit) and an un-normalised int mask would also make
        the prefix-sum count each lane ``mask`` times.  Bool and {0, 1}
        int32 masks are regression-tested equivalent, drops included.
      num_ranks: optional mesh size for an eager out-of-range check: when
        ``dest`` is a CONCRETE array (not traced), any masked lane with
        ``dest >= num_ranks`` raises here instead of being sanitized to a
        silent drop deep in the marshal.  Traced dests skip the value check
        (values don't exist at trace time) — the marshal sanitize still
        guards execution.
    """
    cap = q.capacity
    dest = jnp.asarray(dest)
    if not jnp.issubdtype(dest.dtype, jnp.integer):
        raise ValueError(
            f"dest must have an integer dtype, got {dest.dtype}: a float "
            "dest would truncate-cast and misroute emits silently"
        )
    if num_ranks is not None and not isinstance(dest, jax.core.Tracer):
        m = (jnp.asarray(mask) != 0) & (dest >= 0)
        bad = jnp.where(m, dest, 0) >= num_ranks
        if bool(jnp.any(bad)):
            raise ValueError(
                f"enqueue got dest >= num_ranks ({num_ranks}): max offending "
                f"value {int(jnp.max(jnp.where(bad, dest, 0)))} — emits must "
                "target a rank on the mesh (or DISCARD)"
            )
    mask = (jnp.asarray(mask) != 0) & (dest >= 0)
    m32 = mask.astype(jnp.int32)
    pos = q.count + jnp.cumsum(m32) - m32  # exclusive prefix sum → append slots
    ok = mask & (pos < cap)
    slot = jnp.where(ok, pos, cap)  # cap → mode="drop" discards
    new_items = T.tree_scatter(q.items, slot, items, capacity=cap)
    new_dest = q.dest.at[slot].set(dest.astype(jnp.int32), mode="drop")
    n_emit = jnp.sum(m32)
    new_count = jnp.minimum(q.count + n_emit, cap)
    dropped = q.count + n_emit - new_count
    return WorkQueue(new_items, new_dest, new_count, q.drops + dropped)


def clear(q: WorkQueue) -> WorkQueue:
    """Reset to empty (the paper's post-forward counter reset, §4.2.3)."""
    return WorkQueue(
        items=q.items,
        dest=jnp.full_like(q.dest, DISCARD),
        count=jnp.zeros_like(q.count),
        drops=q.drops,
    )

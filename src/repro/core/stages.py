"""Composable exchange stages — the round as a graph, not a monolith.

Every packed-payload exchange backend (``core.exchange``) is the same five
stages in a row, whatever the fabric layout:

  SpillExtract   the §3.3 clamp site: truncate per-segment counts to the
                 slot budget; in ``overflow="retain"`` mode extract the cut
                 rows as a pending spill block (the lossless law), in drop
                 mode count them.
  Marshal        the send-side payload pass: place rows into the stage's
                 (peers, slot, words) wire layout — sort-composed gather or
                 sort-free scatter (the marshal law: ONE pass either way).
  CountExchange  the control plane: the tiny per-peer count collective.
  PayloadExchange the payload collective: ONE all_to_all of the send buffer.
  Unmarshal      receive-side compaction into the destination queue
                 (``out[roff[g] + s] = recv[g, s]``), rows past capacity
                 dropped; retain mode lands arrivals behind the spill front.

Pre-refactor each backend inlined all five; here they are small stage
objects over an explicit :class:`RoundState`, and the backends are thin
compositions (``compose`` for bulk-synchronous, :class:`Pipelined` for
micro-sharded).  The hierarchical route runs one
SpillExtract→Marshal→CountExchange→PayloadExchange sequence per mesh axis
(``kind="tier"``), advancing the sub-segment bookkeeping between tiers.

Micro-shard pipelining (the overlap law, ISSUE 8): with
``ForwardConfig(pipeline_shards=S)`` every shard-aware stage also exposes
``.shard(state, k)`` issuing shard ``k``'s slice of the work — the per-peer
slot rows ``[k·S/chunks, (k+1)·S/chunks)`` — and :class:`Pipelined`
interleaves the per-shard chains in issue order:

  marshal(0) count(0) payload(0) unmarshal(0) marshal(1) payload(1) …

The S per-shard chains are mutually independent except for the output-queue
accumulator, so an async-collective backend can keep shard k's payload
collective in flight while shard k−1 compacts and shard k+1 marshals.  Each
shard's count collective ships the FULL clamped count vector (control-plane
bytes, replicated ×S) so every shard derives its own landing offsets
``roff[g] + k·chunk + s`` without waiting on its siblings — which is also
why the sharded round is bit-exact with the bulk one by construction: the
union of shard writes is exactly the bulk compaction's writes.  Payload
wire bytes are conserved exactly (S collectives of chunk-rows vs one of
S·chunk rows); the inventory becomes S payload + S count collectives per
mesh axis (guarded in ``tests/test_collective_budget.py``).

The positional arithmetic every clamp site shares (segment-tail spill
extraction, stacked sub-segment truncation, composed layout gathers) lives
here once — ``spill_positions`` / ``lanes_spill`` / ``clamp_subsegments`` /
``subsegment_gather`` / ``compact_blocks`` — and is regression-covered by
the PR-4/PR-6 exact drop-count tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "RoundState",
    "CreditGate",
    "SpillExtract",
    "Marshal",
    "CountExchange",
    "PayloadExchange",
    "Unmarshal",
    "Reassemble",
    "AdvanceTier",
    "Pipelined",
    "compose",
    "a2a",
    "scatter_rows",
    "spill_positions",
    "lanes_spill",
    "clamp_subsegments",
    "subsegment_gather",
    "compact_blocks",
    "compact_shard",
    "ragged_control_plane",
    "padded_send_buffer",
    "padded_send_shard",
]


# =====================================================================
# shared positional arithmetic (the stage library's primitive layer)
# =====================================================================


def a2a(x: jax.Array, axis_name) -> jax.Array:
    """all_to_all over leading axis: out[p] = what peer p sent me (block p)."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


def scatter_rows(
    buf: jax.Array, dstpos: jax.Array, n_slots: int, *, use_pallas: bool
) -> jax.Array:
    """The scatter marshal's single payload pass: ``out[dstpos[i]] = buf[i]``.

    Positions at/past ``n_slots`` (the caller's drop/trash sentinel) are
    discarded — §3.3 semantics.  The Pallas kernel
    (``kernels/bucket_scatter.scatter_rows``) stores rows at their slots
    directly; the XLA fallback scatters only the 1-word LANE INDEX and reads
    the payload back through the inverse — XLA lowers a W-word row scatter
    far worse than the equivalent gather, and the index scatter is
    control-plane-sized (like the histogram), so the payload still moves in
    exactly ONE pass.  Slots no lane claimed hold garbage on this path (row 0)
    and zeros on the Pallas path — both are masked downstream by the
    exchanged counts, exactly like the sort path's past-the-segment slots.
    """
    if use_pallas:
        from repro.kernels.bucket_scatter import ops as bs_ops

        return bs_ops.scatter_rows(buf, dstpos, num_slots=n_slots)
    lane = jnp.arange(buf.shape[0], dtype=jnp.int32)
    inv = jnp.zeros((n_slots,), jnp.int32).at[dstpos].set(lane, mode="drop")
    return jnp.take(buf, inv, axis=0)


def spill_positions(n_slots, cut, seg_start):
    """Source positions of a clamp site's cut rows, compacted segment-major.

    ``cut[k]`` rows were clamped off segment ``k``; they sit contiguously
    from ``seg_start[k]`` (the first position past the segment's allowance).
    Spill slot ``j`` maps to segment ``k = #{inclusive-cumulative cut <= j}``
    and position ``seg_start[k] + j - spill_off[k]`` — the same composed
    positional arithmetic as the send gather, so extracting the spill is
    just a second index vector into the marshal's source space.  In-segment
    order is preserved (stable rank order = FIFO).  Returns ``(k, pos)``;
    slots at/past the total cut hold clamped garbage the caller bounds by
    the spill count.
    """
    incl = jnp.cumsum(cut)
    j = jnp.arange(n_slots, dtype=jnp.int32)
    k = jnp.sum((j[:, None] >= incl[None, :]).astype(jnp.int32), axis=1)
    k = jnp.clip(k, 0, cut.shape[0] - 1)
    pos = jnp.take(seg_start, k) + j - jnp.take(incl - cut, k)
    return k, pos


def lanes_spill(
    packed, perm, age, allow_tbl, cut, seg_start, n_spill, *,
    num_ranks, marshal, dest_clean, dest_rank,
):
    """Pending-spill block for a sender-side clamp over the INPUT lanes.

    ``allow_tbl[d]``/``cut[d]``: per-destination allowance and cut count;
    ``seg_start[d]``: first cut position of destination ``d`` in the
    MARSHALLED (sorted) order.  Sort mode reads the cut rows straight
    through ``perm``; scatter mode inverts the (dest, in-bucket rank) plan
    with one 1-word scatter.  Returns ``(rows, dest, age, n_spill)`` —
    rows/dest/age are valid on the ``[0, n_spill)`` prefix only (the caller
    bounds every read), ages carried forward +1.
    """
    C = packed.shape[0]
    k, pos = spill_positions(C, cut, seg_start)
    if marshal == "scatter":
        lanes = jnp.arange(C, dtype=jnp.int32)
        d = jnp.clip(dest_clean, 0, num_ranks - 1)
        al = jnp.take(allow_tbl, d)
        tgt = jnp.where(
            (dest_clean < num_ranks) & (dest_rank >= al),
            jnp.take(jnp.cumsum(cut) - cut, d) + dest_rank - al,
            C,
        )
        src = jnp.zeros((C,), jnp.int32).at[tgt].set(lanes, mode="drop")
    else:
        src = jnp.take(perm, jnp.clip(pos, 0, C - 1))
    # segment index in marshalled order IS the global destination (flat and
    # first hierarchical stage alike: lexicographic rank order)
    return (
        jnp.take(packed, src, axis=0),
        k.astype(jnp.int32),
        jnp.take(age, src).astype(jnp.int32) + 1,
        n_spill,
    )


def clamp_subsegments(cnt: jax.Array, slot: int) -> Tuple[jax.Array, jax.Array]:
    """Truncate stacked sub-segments (rows of ``cnt``, concatenated in row
    order) to a ``slot``-row budget per column.

    ``cnt[i, j]``: rows of sub-segment ``i`` bound for slot column ``j``.
    Returns ``(allowed, starts)`` with the same shape: ``allowed`` keeps a
    contiguous prefix of each column's concatenation (any segment or segment
    tail past ``slot`` is cut — the §3.3 drop rule), ``starts`` is where each
    surviving sub-segment begins inside its slot.
    """
    raw_pref = jnp.cumsum(cnt, axis=0) - cnt
    allowed = jnp.clip(jnp.minimum(cnt, slot - raw_pref), 0)
    starts = jnp.cumsum(allowed, axis=0) - allowed
    return allowed, starts


def subsegment_gather(
    allowed: jax.Array,  # (G, K) surviving sub-segment sizes per slot column k
    starts: jax.Array,  # (G, K) slot-local sub-segment starts
    src_base: jax.Array,  # (G, K) source offset of sub-segment (g, k)
    slot: int,
) -> jax.Array:
    """Source row index for every (slot column k, slot position s).

    Returns ``(K, slot)`` int32: the flat source row feeding slot ``k``'s
    position ``s`` — rows past a column's total are clamped garbage, masked
    downstream by the exchanged counts.  This is the composed two-stage
    layout: one gather materialises a whole stage's send buffer.
    """
    G, K = allowed.shape
    s_idx = jnp.arange(slot, dtype=jnp.int32)
    incl = jnp.cumsum(allowed, axis=0)  # (G, K) inclusive prefix per column
    # sub-segment owning position s = number of fully-completed predecessors
    g_of = jnp.sum(s_idx[None, :, None] >= incl.T[:, None, :], axis=-1)  # (K, slot)
    g_c = jnp.clip(g_of, 0, G - 1)
    k_grid = jnp.arange(K, dtype=jnp.int32)[:, None]
    s_local = s_idx[None, :] - starts[g_c, k_grid]
    return src_base[g_c, k_grid] + s_local


def ragged_control_plane(
    cnt: jax.Array, me: jax.Array, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """From the (R_src, R_dst) count matrix, derive my ragged-a2a parameters.

    Receiver-capacity clamp, replicated identically on all ranks: at each
    destination column ``d`` the senders' segments land at the exclusive
    prefix of the column; any segment (or segment tail) past ``capacity`` is
    cut — the §3.3 drop rule (:func:`clamp_subsegments`), decided without a
    round trip.

    Returns ``(send_sizes (R,), output_offsets (R,), recv_sizes (R,))``.
    """
    allowed, roff = clamp_subsegments(cnt, capacity)
    send_sizes = allowed[me]  # my row: what each peer lets me deliver
    output_offsets = roff[me]  # where my block lands on each peer
    recv_sizes = allowed[:, me]  # my column: what each peer delivers to me
    return send_sizes, output_offsets, recv_sizes


def compact_blocks(
    recv_buf: jax.Array,  # (G, S, W) received padded blocks
    recv_counts: jax.Array,  # (G,) valid rows per block
    capacity: int,
    *,
    use_pallas: bool,
    front=None,  # retain mode: rows [0, front) are reserved for the spill
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Receive-side compaction shared by the padded-slot exchanges:
    ``out[roff[g] + s] = recv_buf[g, s]`` for ``s < recv_counts[g]``, rows
    past ``capacity`` dropped (§3.3).  Returns ``(out, new_count, drops)``.

    With ``front`` the arrivals land shifted by that many rows — the same
    scatter places them BEHIND the retained spill at zero extra cost, and
    ``new_count``/``drops`` account against the reduced room.
    """
    G, S, W = recv_buf.shape
    roff = jnp.cumsum(recv_counts) - recv_counts
    if front is not None:
        roff = roff + front
    if use_pallas:
        from repro.kernels.marshal import ops as marshal_ops

        out = marshal_ops.fused_unmarshal(recv_buf, roff, recv_counts, capacity=capacity)
    else:
        g_idx = jnp.repeat(jnp.arange(G, dtype=jnp.int32), S)
        s_idx = jnp.tile(jnp.arange(S, dtype=jnp.int32), G)
        dstpos = roff[g_idx] + s_idx
        ok = s_idx < recv_counts[g_idx]
        slot = jnp.where(ok & (dstpos < capacity), dstpos, capacity)
        out = jnp.zeros((capacity, W), recv_buf.dtype)
        out = out.at[slot].set(recv_buf.reshape(G * S, W), mode="drop")
    total_recv = jnp.sum(recv_counts)
    room = capacity if front is None else jnp.clip(capacity - front, 0)
    new_count = jnp.minimum(total_recv, room)
    return out, new_count, total_recv - new_count


def compact_shard(
    out: jax.Array,  # (capacity, W) accumulator shared by all shards
    recv_buf: jax.Array,  # (G, chunk, W) shard k's received blocks
    recv_counts: jax.Array,  # (G,) FULL per-block counts (shard-independent)
    capacity: int,
    *,
    row_offset: int,  # k·chunk — where this shard's rows sit in each block
    front=None,
) -> jax.Array:
    """One micro-shard's slice of the receive compaction: shard rows land at
    the SAME final positions the bulk compaction gives them
    (``roff[g] + row_offset + s``, valid while ``row_offset + s <
    recv_counts[g]``), so the union over shards is bit-exact with
    :func:`compact_blocks`.  Always the XLA scatter path — per-shard
    accumulation into a shared queue has no fused-unmarshal kernel.
    """
    G, chunk, W = recv_buf.shape
    roff = jnp.cumsum(recv_counts) - recv_counts
    if front is not None:
        roff = roff + front
    g_idx = jnp.repeat(jnp.arange(G, dtype=jnp.int32), chunk)
    s_idx = jnp.tile(jnp.arange(chunk, dtype=jnp.int32), G) + row_offset
    dstpos = roff[g_idx] + s_idx
    ok = s_idx < recv_counts[g_idx]
    slot = jnp.where(ok & (dstpos < capacity), dstpos, capacity)
    return out.at[slot].set(recv_buf.reshape(G * chunk, W), mode="drop")


def padded_send_buffer(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,  # (C,) sort mode: destination-sort permutation
    send_counts: jax.Array,  # (R,) valid-destination counts
    *,
    num_ranks: int,
    peer_capacity: int,
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,  # (C,) scatter mode: sanitized destination
    dest_rank: jax.Array = None,  # (C,) scatter mode: stable in-bucket rank
) -> jax.Array:
    """The padded exchange's send-side marshal — the round's ONE payload pass
    (isolated so ``benchmarks/run.py --profile`` can time it standalone).

    Sort mode gathers ``packed[perm[off[r] + s]]``; scatter mode scatters row
    ``i`` to ``dest_clean[i]·S + dest_rank[i]`` (rank ≥ S → §3.3 drop).
    Returns the ``(R, S, W)`` send buffer; rows past each segment's clamped
    count are garbage (sort) or zeros (scatter) and masked by the exchanged
    counts downstream.
    """
    R, S = num_ranks, peer_capacity
    cap = packed.shape[0]
    if marshal == "scatter":
        keep = (dest_clean < R) & (dest_rank < S)
        dstpos = jnp.where(keep, dest_clean * S + dest_rank, R * S)
        send_buf = scatter_rows(packed, dstpos, R * S, use_pallas=use_pallas)
        return send_buf.reshape(R, S, -1)
    off = jnp.cumsum(send_counts) - send_counts  # segment starts, sorted order
    r_idx = jnp.repeat(jnp.arange(R, dtype=jnp.int32), S)
    s_idx = jnp.tile(jnp.arange(S, dtype=jnp.int32), R)
    slotpos = jnp.clip(off[r_idx] + s_idx, 0, cap - 1)  # position in sorted order
    src = jnp.take(perm, slotpos)  # compose with the sort → source lane
    if use_pallas:
        from repro.kernels.marshal import ops as marshal_ops

        return marshal_ops.fused_marshal(packed, src, num_ranks=R, slot=S)
    return jnp.take(packed, src, axis=0).reshape(R, S, -1)


def padded_send_shard(
    packed, perm, send_counts, *,
    num_ranks, peer_capacity, shards, k,
    use_pallas=False, marshal="sort", dest_clean=None, dest_rank=None,
):
    """Micro-shard ``k`` of the padded marshal: slot rows ``[k·chunk,
    (k+1)·chunk)`` of every peer segment, as an ``(R, chunk, W)`` buffer.
    The union over shards is row-for-row the :func:`padded_send_buffer`
    layout, so the sharded exchange ships exactly the bulk wire bytes.
    """
    R, S = num_ranks, peer_capacity
    chunk = S // shards
    cap = packed.shape[0]
    if marshal == "scatter":
        inwin = (dest_rank >= k * chunk) & (dest_rank < (k + 1) * chunk)
        keep = (dest_clean < R) & inwin
        dstpos = jnp.where(keep, dest_clean * chunk + dest_rank - k * chunk, R * chunk)
        send = scatter_rows(packed, dstpos, R * chunk, use_pallas=use_pallas)
        return send.reshape(R, chunk, -1)
    off = jnp.cumsum(send_counts) - send_counts
    r_idx = jnp.repeat(jnp.arange(R, dtype=jnp.int32), chunk)
    s_idx = jnp.tile(jnp.arange(chunk, dtype=jnp.int32), R) + k * chunk
    slotpos = jnp.clip(off[r_idx] + s_idx, 0, cap - 1)
    src = jnp.take(perm, slotpos)
    if use_pallas:
        from repro.kernels.marshal import ops as marshal_ops

        return marshal_ops.fused_marshal(packed, src, num_ranks=R, slot=chunk)
    return jnp.take(packed, src, axis=0).reshape(R, chunk, -1)


# =====================================================================
# carried state + the five stage objects
# =====================================================================


@dataclasses.dataclass
class RoundState:
    """Explicit carried state a stage composition threads stage to stage.

    Built once per round from the marshal plan ``forward_work`` computed;
    every field a stage writes is named here rather than flowing through
    positional locals — that is what lets the same five stage objects
    assemble four backends (and lets :class:`Pipelined` interleave per-shard
    slices of them without re-deriving anything).
    """

    # marshal plan + payload (round inputs)
    packed: Any = None
    perm: Any = None
    send_counts: Any = None
    marshal: str = "sort"
    dest_clean: Any = None
    dest_rank: Any = None
    use_pallas: bool = False
    retain: bool = False
    age: Any = None

    # credit flow (the backpressure law, ISSUE 9) — all None/"open" unless
    # ForwardConfig(flow="credit"); the branches they feed are Python-static
    # so the open-flow lowering is byte-identical with or without them.
    flow: str = "open"
    credits: Any = None  # carried-in (R,) per-destination free estimates
    credit_allow: Any = None  # (R,) this round's per-destination grant
    credits_out: Any = None  # working/updated (R,) estimates (returned)
    my_free: Any = None  # this rank's advertised receive room this round
    stage_held: Any = None  # rows the current clamp held locally (telemetry)

    # clamp site (written by SpillExtract)
    clamped: Any = None  # flat: (R,) per-destination clamped counts
    allowed: Any = None  # tier: (G, A) surviving sub-segment sizes
    starts: Any = None  # tier: slot-local sub-segment starts
    send_drops: Any = None
    stage_drops: Any = None  # tier: this tier's clamp loss (telemetry reads it)
    pending: List[Any] = dataclasses.field(default_factory=list)
    front: Any = None
    spill_run: Any = None  # hierarchical: rows parked so far (spill front)
    drops: Any = None  # hierarchical: accumulated stage drops

    # sub-segment bookkeeping (hierarchical tiers)
    cnt: Any = None  # per-sub-segment counts in current buffer order
    base: Any = None  # per-sub-segment start offsets
    buf: Any = None  # current payload buffer (packed, then stage receives)
    n_rows: int = 0
    via_perm: bool = True  # True until the round's first payload pass
    seg_dest: Any = None  # retain: sub-segment → global destination map
    stage_pos: Any = None  # cached (A, S) source positions (sharded gathers)

    # exchange working set (Marshal / CountExchange / PayloadExchange)
    send_buf: Any = None
    recv_counts: Any = None
    recv_buf: Any = None
    rcv: Any = None  # tier count exchange: (A, G) per-sub-segment survivors
    recv_blocks: List[Any] = dataclasses.field(default_factory=list)

    # results (Unmarshal)
    out: Any = None
    new_count: Any = None
    recv_drops: Any = None


@dataclasses.dataclass(frozen=True)
class CreditGate:
    """The backpressure law's sender gate (``flow="credit"``, ISSUE 9).

    Deterministically apportions each destination's one-round-stale
    advertised free space across the R contending senders: rank ``me`` may
    ship ``free[d] // R + (me < free[d] % R)`` rows to destination ``d`` —
    floor share plus rank-ordered residual.  The grants over all senders sum
    to EXACTLY the advertised space, so an incast can never overshoot the
    receiver, and every rank computes every grant locally from the same
    replicated credit vector (collective-free, deterministic across marshal
    modes and shard counts).  The grant tightens the §3.3 sender clamp in
    :class:`SpillExtract`; the un-credited tail of each segment follows the
    ``overflow="retain"`` spill path instead of shipping — no wire byte is
    spent on a row its receiver cannot admit.
    """

    axis_name: Any  # FLAT mesh axis name(s): global rank index
    num_ranks: int

    def __call__(self, st: RoundState) -> RoundState:
        me = jax.lax.axis_index(self.axis_name)
        free = jnp.clip(st.credits, 0)
        st.credit_allow = (
            free // self.num_ranks
            + (me < free % self.num_ranks).astype(jnp.int32)
        ).astype(jnp.int32)
        st.credits_out = st.credits
        return st

    def shard(self, st: RoundState, k: int) -> RoundState:
        # grants are shard-independent (the slot chunking happens downstream)
        return self(st) if k == 0 else st


@dataclasses.dataclass(frozen=True)
class SpillExtract:
    """The §3.3 clamp site.  ``kind="flat"``: the sender clamp of the flat
    backends (per-destination counts vs the ``slot`` budget).
    ``kind="tier"``: a hierarchical stage clamp (stacked sub-segments vs the
    tier's segment budget) — input LANES spill through the marshal plan while
    ``state.via_perm``, mid-route BUFFER rows park in place after it.
    Drop mode counts the cut; retain mode extracts it as a pending block."""

    num_ranks: int
    capacity: int
    slot: int
    retain: bool = False
    kind: str = "flat"
    extent: int = 0  # tier: A_l, the stage's axis size
    reserve: int = 0  # credit: receive room withheld for local emissions

    def __call__(self, st: RoundState) -> RoundState:
        if self.kind == "tier":
            return self._tier(st)
        S = self.slot
        st.clamped = jnp.minimum(st.send_counts, S)
        if st.flow == "credit":
            # the credit gate's per-destination grant tightens the slot
            # clamp; the extra cut rows ride the same retain spill below
            st.clamped = jnp.minimum(st.clamped, st.credit_allow)
        send_drops = jnp.sum(st.send_counts - st.clamped)
        if self.retain:
            # The clamp's cut rows are the per-destination segment TAILS of
            # the marshalled order — extract them with the same positional
            # arithmetic the send gather uses (one extra (C, W) gather, no
            # conditional, no mask machinery) and reserve the queue front
            # for them.
            if st.age is None:
                st.age = jnp.zeros((st.packed.shape[0],), jnp.int32)
            off = jnp.cumsum(st.send_counts) - st.send_counts
            st.pending.append(lanes_spill(
                st.packed, st.perm, st.age, st.clamped,
                st.send_counts - st.clamped, off + st.clamped, send_drops,
                num_ranks=self.num_ranks, marshal=st.marshal,
                dest_clean=st.dest_clean, dest_rank=st.dest_rank,
            ))
            st.front = jnp.minimum(send_drops, self.capacity)
            st.stage_held = send_drops
            if st.flow == "credit":
                # my advertisement: the receive room left behind the spill
                # front, MINUS the reserve withheld for next round's local
                # emissions.  Senders use it one round stale — with the
                # drive's emission gate (retained + emitted + advert ≤
                # capacity) next round's spill front can never grow into
                # the room advertised here, so granted arrivals always fit:
                # the flat credit path is receiver-drop-free by construction.
                # The liveness floor (min(room, R)) keeps up to one credit
                # PER SENDER alive whenever room exists: the floor never
                # exceeds room, so advert + front still never exceeds
                # capacity (the drop-free proof is untouched), but a backlog
                # that ate into the emission reserve can no longer pin the
                # advert at zero — and because the floor covers all R
                # senders, the rank-ordered residual cannot starve high
                # ranks when every queue saturates at once (a floor of 1
                # would hand the single credit to rank 0 every round and
                # collapse sustained-overload drain to ~1 row/round).
                room = self.capacity - st.front
                st.my_free = jnp.maximum(
                    jnp.clip(room - self.reserve, 0),
                    jnp.minimum(room, self.num_ranks),
                ).astype(jnp.int32)
            send_drops = jnp.zeros_like(send_drops)
        st.send_drops = send_drops
        return st

    def _tier(self, st: RoundState) -> RoundState:
        A, S, R = self.extent, self.slot, self.num_ranks
        cnt2d = st.cnt.reshape(R // A, A)  # rows: buffer order, cols: peer digit
        cnt_eff = cnt2d
        if st.flow == "credit" and st.via_perm:
            # The route's FIRST clamp is the credit gate: at stage one the
            # buffer is in destination order, so the per-destination grant
            # reshapes straight onto the sub-segment grid.  Gating here
            # means the un-credited tail never enters ANY fabric tier — a
            # saturated node throttles the slow/DCN stage at the source,
            # not just the last hop.
            cnt_eff = jnp.minimum(cnt2d, st.credit_allow.reshape(R // A, A))
        st.allowed, st.starts = clamp_subsegments(cnt_eff, S)
        stage_drops = jnp.sum(cnt2d - st.allowed)
        if self.retain:
            alf = st.allowed.reshape(-1)  # flat, current buffer/destination order
            if st.via_perm:
                # Sender-clamp spill from the INPUT lanes: the cut rows are
                # the per-destination segment tails of the sorted order
                # (allowed is indexed [d // A, d % A], so its row-major
                # flatten is the per-destination allowance; at the first
                # stage buffer order == destination order, and the stable
                # in-bucket rank against the full destination IS the
                # in-sub-segment rank — the scatter marshal's equivalence).
                st.pending.append(lanes_spill(
                    st.packed, st.perm, st.age, alf, st.cnt - alf,
                    st.base + alf, stage_drops, num_ranks=R,
                    marshal=st.marshal, dest_clean=st.dest_clean,
                    dest_rank=st.dest_rank,
                ))
            else:
                # Mid-route park: buffer rows whose sub-segment tail this
                # stage cut stay HERE; destination routing resumes them next
                # round.  Tails are read straight out of the stage buffer
                # (marshal-mode-agnostic: positions, not lanes) and
                # re-addressed through ``seg_dest``; ages restart at 1 (age
                # cannot ride the wire without changing the payload bytes).
                k, pos = spill_positions(self.capacity, st.cnt - alf, st.base + alf)
                src = jnp.clip(pos, 0, st.n_rows - 1)
                st.pending.append((
                    jnp.take(st.buf, src, axis=0),
                    jnp.take(st.seg_dest, k),
                    jnp.ones((self.capacity,), jnp.int32),
                    stage_drops,
                ))
            st.spill_run = st.spill_run + stage_drops
            st.stage_held = stage_drops
            stage_drops = jnp.zeros_like(stage_drops)
        st.stage_drops = stage_drops
        st.drops = st.drops + stage_drops
        return st


@dataclasses.dataclass(frozen=True)
class Marshal:
    """The send-side payload pass.  ``kind="flat"``: the padded (R, S, W)
    peer-slot layout.  ``kind="tier"``: a hierarchical stage's (A, S, W)
    layout — sort permutation composed into the first stage's gather, or the
    sort-free scatter straight into sub-segment slots; later stages gather
    from the received buffer.  ``.shard(st, k)`` builds only slot rows
    ``[k·chunk, (k+1)·chunk)`` of every segment."""

    num_peers: int  # flat: R ranks; tier: A_l, the stage's axis size
    slot: int
    shards: int = 1
    kind: str = "flat"
    num_ranks: int = 0  # tier: the global rank count R

    def __call__(self, st: RoundState) -> RoundState:
        if self.kind == "tier":
            return self._tier(st, None)
        st.send_buf = padded_send_buffer(
            st.packed, st.perm, st.send_counts,
            num_ranks=self.num_peers, peer_capacity=self.slot,
            use_pallas=st.use_pallas, marshal=st.marshal,
            dest_clean=st.dest_clean, dest_rank=st.dest_rank,
        )
        return st

    def shard(self, st: RoundState, k: int) -> RoundState:
        if self.kind == "tier":
            return self._tier(st, k)
        st.send_buf = padded_send_shard(
            st.packed, st.perm, st.send_counts,
            num_ranks=self.num_peers, peer_capacity=self.slot,
            shards=self.shards, k=k, use_pallas=st.use_pallas,
            marshal=st.marshal, dest_clean=st.dest_clean,
            dest_rank=st.dest_rank,
        )
        return st

    def _gather(self, st, buf, rows, n_slots, slot):
        W = buf.shape[-1]
        if st.use_pallas:
            from repro.kernels.marshal import ops as marshal_ops

            return marshal_ops.fused_marshal(buf, rows, num_ranks=n_slots, slot=slot)
        return jnp.take(buf, rows, axis=0).reshape(n_slots, slot, W)

    def _tier(self, st: RoundState, k: Optional[int]) -> RoundState:
        A, S, R = self.num_peers, self.slot, self.num_ranks
        chunk = S if k is None else S // self.shards
        lo = 0 if k is None else k * chunk
        W = st.packed.shape[-1]
        if st.via_perm and st.marshal == "scatter":
            # first non-trivial stage, sort-free: scatter each row straight
            # into the stage layout — the payload's single local pass of the
            # round.  Sub-segment (rest, d_l) holds exactly one destination,
            # so the in-bucket rank IS the in-sub-segment position; ranks at
            # or past the stage clamp land in the trash slot (§3.3).
            row = jnp.clip(st.dest_clean // A, 0, R // A - 1)
            col = jnp.clip(st.dest_clean % A, 0, A - 1)
            keep = (st.dest_clean < R) & (st.dest_rank < st.allowed[row, col])
            if k is None:
                dstpos = jnp.where(
                    keep, col * S + st.starts[row, col] + st.dest_rank, A * S
                )
            else:
                s_in = st.starts[row, col] + st.dest_rank  # slot pos in column
                keep = keep & (s_in >= lo) & (s_in < lo + chunk)
                dstpos = jnp.where(keep, col * chunk + (s_in - lo), A * chunk)
            send = scatter_rows(st.packed, dstpos, A * chunk, use_pallas=st.use_pallas)
            st.send_buf = send.reshape(A, chunk, W)
            return st
        if k is None or st.stage_pos is None:
            st.stage_pos = subsegment_gather(
                st.allowed, st.starts, st.base.reshape(R // A, A), S
            )
        pos = st.stage_pos if k is None else st.stage_pos[:, lo:lo + chunk]
        if st.via_perm:
            # first non-trivial stage: compose the sort permutation straight
            # into the send gather — the payload's single read of the round
            C = st.packed.shape[0]
            rows = jnp.take(st.perm, jnp.clip(pos, 0, C - 1).reshape(-1))
            st.send_buf = self._gather(st, st.packed, rows, A, chunk)
        else:
            rows = jnp.clip(pos, 0, st.n_rows - 1).reshape(-1)
            st.send_buf = self._gather(st, st.buf, rows, A, chunk)
        return st


@dataclasses.dataclass(frozen=True)
class CountExchange:
    """The control-plane collective.  ``kind="flat"``: all_to_all of the
    clamped per-peer counts.  ``kind="tier"``: all_to_all of the per-sub-
    segment survivor counts (so the receiver can address every sub-segment
    of each incoming block).  ``kind="final"``: per-source-group totals —
    blocks are contiguous prefixes at the last tier.  Sharded flat/final
    runs repeat the FULL vector per shard (each micro-shard's chain derives
    its own landing offsets — control-plane bytes ×S, payload bytes exact);
    sharded tier runs ship each shard's own chunk counts and sum them back
    on receive.

    Credit flow (ISSUE 9): with ``st.flow == "credit"`` the count matrix
    widens by ONE i32 column carrying the credit advertisement — the SAME
    collective the round already runs, nothing payload-sized, so the budget
    law's inventory is unchanged.  Flat: every rank ships its own receive
    room and reads back all R advertisements.  Hierarchical: credits
    aggregate per tier — at tier ``l`` each peer ships the MIN cached
    estimate over its tier-l subtree (the ranks its already-run faster-tier
    exchanges aggregated: ``r // stride_l == me // stride_l``), the final
    tier folding in its own fresh post-spill headroom first; receivers fan
    the aggregate back over the peer's subtree.  A saturated rank drags its
    node's aggregate down within one round, throttling remote senders at
    the route's FIRST clamp — before the slow fabric.  Conservative by
    construction (a min under-, never over-states any member's room; only
    staleness can overshoot, absorbed by the retain spill)."""

    axis_name: Any
    kind: str = "flat"
    shards: int = 1
    slot: int = 0  # tier: full per-peer slot rows (shard chunking)
    num_ranks: int = 0  # credit: global rank count R
    stride: int = 1  # credit tier: Π level_sizes[l+1:] — the tier's stride
    capacity: int = 0  # credit final: queue capacity (fresh headroom)
    flat_axes: Any = None  # credit hierarchical: flattened axis names
    reserve: int = 0  # credit: receive room withheld for local emissions

    def __call__(self, st: RoundState) -> RoundState:
        if self.kind == "tier":
            if st.flow == "credit":
                st.rcv = self._credit_recv(st, st.allowed.T)
            else:
                st.rcv = a2a(st.allowed.T, self.axis_name)  # (A, G): [src digit, sub-seg]
        elif self.kind == "final":
            sums = jnp.sum(st.allowed, axis=0)[:, None]
            if st.flow == "credit":
                st.recv_counts = self._credit_recv(st, sums).reshape(-1)
            else:
                st.recv_counts = a2a(sums, self.axis_name).reshape(-1)
        else:
            if st.flow == "credit":
                # widen (R, 1) → (R, 2): column 1 carries my receive room to
                # every peer; received column 1 is all R advertisements
                wide = jnp.stack(
                    [st.clamped,
                     jnp.full_like(st.clamped, st.my_free)], axis=1
                )
                recv = a2a(wide, self.axis_name)
                st.recv_counts = recv[:, 0]
                st.credits_out = recv[:, 1]
            else:
                st.recv_counts = a2a(st.clamped[:, None], self.axis_name).reshape(-1)
        return st

    def _credit_recv(self, st: RoundState, counts: jax.Array) -> jax.Array:
        """Run the tier/final count a2a widened with the advertisement
        column, apply the received aggregates to ``st.credits_out``, and
        return the un-widened count block."""
        A = counts.shape[0]
        me = jax.lax.axis_index(self.flat_axes)
        if self.kind == "final":
            # fold my own fresh post-spill headroom into the carried view
            # before aggregating (spill_run is complete at the final tier —
            # this is exactly the room the final Unmarshal grants arrivals)
            room = jnp.clip(self.capacity - st.spill_run, 0)
            # reserve withheld for local emissions + the per-sender liveness
            # floor (see SpillExtract's flat advert)
            fresh = jnp.maximum(
                jnp.clip(room - self.reserve, 0),
                jnp.minimum(room, self.num_ranks),
            ).astype(jnp.int32)
            st.my_free = fresh
            st.credits_out = st.credits_out.at[me].set(fresh)
        r = jnp.arange(self.num_ranks, dtype=jnp.int32)
        sub = (r // self.stride) == (me // self.stride)  # my tier-l subtree
        adv = jnp.min(
            jnp.where(sub, st.credits_out, jnp.int32(self.capacity))
        )
        wide = jnp.concatenate(
            [counts, jnp.full((A, 1), adv, counts.dtype)], axis=1
        )
        recv = a2a(wide, self.axis_name)
        # peer a's aggregate covers ranks sharing my slower digits with
        # digit_l = a; my own subtree keeps its fresher per-rank entries
        dig = (r // self.stride) % A
        me_dig = (me // self.stride) % A
        blk = (r // (self.stride * A)) == (me // (self.stride * A))
        upd = blk & (dig != me_dig)
        st.credits_out = jnp.where(
            upd, jnp.take(recv[:, -1], dig), st.credits_out
        )
        return recv[:, :-1]

    def shard(self, st: RoundState, k: int) -> RoundState:
        if self.kind != "tier":
            return self(st)
        # Ship each shard's OWN chunk counts; the receiver sums them back to
        # the full survivor vector: Σ_k clip(allowed − k·chunk, 0, chunk) =
        # allowed.  Keeps every shard's count collective live (the flat and
        # final kinds instead repeat the full vector — each shard derives
        # its landing offsets without waiting on siblings).
        chunk = self.slot // self.shards
        allowed_k = jnp.clip(st.allowed - k * chunk, 0, chunk)
        if st.flow == "credit":
            # same widened collective per shard; the advertisement column is
            # shard-independent, so only shard 0's read updates the credits
            saved = st.credits_out
            part = self._credit_recv(st, allowed_k.T)
            if k > 0:
                st.credits_out = saved
        else:
            part = a2a(allowed_k.T, self.axis_name)
        st.rcv = part if k == 0 else st.rcv + part
        return st


@dataclasses.dataclass(frozen=True)
class PayloadExchange:
    """The payload collective: ONE all_to_all of the (current shard's) send
    buffer.  With ``collect=True`` (sharded non-final tiers) the received
    blocks are accumulated for :class:`Reassemble`."""

    axis_name: Any
    collect: bool = False

    def __call__(self, st: RoundState) -> RoundState:
        st.recv_buf = a2a(st.send_buf, self.axis_name)
        if self.collect:
            st.recv_blocks.append(st.recv_buf)
        return st

    def shard(self, st: RoundState, k: int) -> RoundState:
        return self(st)


@dataclasses.dataclass(frozen=True)
class Unmarshal:
    """Receive-side compaction into the destination queue.  ``kind="flat"``
    reads the spill front SpillExtract reserved; ``kind="final"`` (the last
    hierarchical tier) reserves the accumulated mid-route spill run.  Sharded
    mode accumulates each shard's rows at their bulk positions
    (:func:`compact_shard`) and closes the count/drop accounting on the last
    shard."""

    capacity: int
    shards: int = 1
    slot: int = 0  # full per-peer slot rows (shard row offsets)
    kind: str = "flat"

    def _front(self, st: RoundState):
        if self.kind == "final":
            return jnp.minimum(st.spill_run, self.capacity) if st.retain else None
        return st.front

    def __call__(self, st: RoundState) -> RoundState:
        st.out, st.new_count, st.recv_drops = compact_blocks(
            st.recv_buf, st.recv_counts, self.capacity,
            use_pallas=st.use_pallas, front=self._front(st),
        )
        return st

    def shard(self, st: RoundState, k: int) -> RoundState:
        chunk = self.slot // self.shards
        if k == 0:
            W = st.recv_buf.shape[-1]
            st.out = jnp.zeros((self.capacity, W), st.recv_buf.dtype)
        st.out = compact_shard(
            st.out, st.recv_buf, st.recv_counts, self.capacity,
            row_offset=k * chunk, front=self._front(st),
        )
        if k == self.shards - 1:
            total_recv = jnp.sum(st.recv_counts)
            front = self._front(st)
            room = (
                self.capacity if front is None
                else jnp.clip(self.capacity - front, 0)
            )
            st.new_count = jnp.minimum(total_recv, room)
            st.recv_drops = total_recv - st.new_count
        return st


@dataclasses.dataclass(frozen=True)
class Reassemble:
    """Stitch a sharded tier's received chunk blocks back into the bulk
    (A, S, W) stage buffer: ``full[a, k·chunk + s] = recv_k[a, s]`` — pure
    local data movement, zero collectives, bit-exact with the bulk receive
    by construction."""

    extent: int
    slot: int

    def __call__(self, st: RoundState) -> RoundState:
        A, S = self.extent, self.slot
        W = st.recv_blocks[0].shape[-1]
        stacked = jnp.stack(st.recv_blocks, axis=1)  # (A, shards, chunk, W)
        st.recv_buf = stacked.reshape(A, S, W)
        st.recv_blocks = []
        return st


@dataclasses.dataclass(frozen=True)
class AdvanceTier:
    """Between hierarchical stages: reinterpret the received blocks as the
    next tier's buffer and derive its sub-segment counts/offsets from the
    count exchange — new buffer order ``(s_l, previous order − d_l)``."""

    extent: int
    slot: int
    axis_name: Any
    retain: bool = False
    num_ranks: int = 0

    def __call__(self, st: RoundState) -> RoundState:
        A, S, R = self.extent, self.slot, self.num_ranks
        W = st.recv_buf.shape[-1]
        st.cnt = st.rcv.reshape(-1)  # new buffer order: (s_l, previous − d_l)
        st.base = (
            jnp.cumsum(st.rcv, axis=1) - st.rcv
            + jnp.arange(A, dtype=jnp.int32)[:, None] * S
        ).reshape(-1)
        st.buf = st.recv_buf.reshape(A * S, W)
        st.n_rows = A * S
        st.via_perm = False
        st.stage_pos = None
        if self.retain:
            # Sub-segment k of the NEW buffer order (s_l, rest) holds the
            # destination whose digit l equals MINE — shared with every peer
            # of the remaining (slower) stages, so the map stays
            # rank-consistent with zero extra communication.
            me_l = jax.lax.axis_index(self.axis_name)
            st.seg_dest = jnp.tile(st.seg_dest.reshape(R // A, A)[:, me_l], A)
        return st


@dataclasses.dataclass(frozen=True)
class Pipelined:
    """Software-pipeline shard-aware stages: issue the per-shard chains
    interleaved (marshal k → counts k → payload k → unmarshal k → marshal
    k+1 → …).  The chains share only the output-queue accumulator, so an
    async-collective backend overlaps shard k's payload collective with
    shard k−1's unmarshal and shard k+1's marshal — the overlap law's
    schedule."""

    stages: Tuple[Any, ...]
    shards: int

    def __call__(self, st: RoundState) -> RoundState:
        for k in range(self.shards):
            for stage in self.stages:
                st = stage.shard(st, k)
        return st


def compose(*stage_seq):
    """Run stages in sequence over a :class:`RoundState` — the bulk graph."""

    def run(st: RoundState) -> RoundState:
        for stage in stage_seq:
            st = stage(st)
        return st

    return run

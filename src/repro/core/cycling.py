"""Ray-queue cycling — the paper's §6.3 alternative communication pattern.

"…the NVIDIA Barney renderer instead uses *ray queue cycling*, in which
every rank always communicates with exactly one other rank."  Instead of a
sorted all-to-all, the *entire* queue migrates around a ring; each rank
absorbs the items addressed to it and forwards the rest on the next cycle.
One `collective_permute` per round — the cheapest possible collective, at
the cost of R rounds for full delivery.

Provided as a first-class alternative so applications can trade latency
(forwarding: 1 round) against collective simplicity (cycling: R rounds of
nearest-neighbour traffic) — useful when the interconnect is a ring and
all-to-all congestion dominates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.core.forwarding import ForwardConfig, flatten_axis_names
from repro.core.queue import DISCARD, WorkQueue, enqueue, make_queue
from repro.telemetry import stats as TS

__all__ = ["cycle_step", "deliver_by_cycling"]


def _ring_permute(x: jax.Array, axis_name, num_ranks: int) -> jax.Array:
    """One hop of the node-major ring: ONE ``collective_permute``.

    ``axis_name`` may be a single flat axis or a ``(slowest, …, fastest)``
    tuple (entries may themselves be joint-tier tuples).  On a multi-tier
    mesh the linearised rank order is lexicographic (node-major), so the
    ring's source-target pairs are fastest-axis (intra-node) hops everywhere
    except the pairs that wrap a group boundary — those are the only hops
    routed over a slower fabric.  One collective, no payload bytes crossing
    the slow tiers from non-boundary ranks.
    """
    perm = [(i, (i + 1) % num_ranks) for i in range(num_ranks)]
    return jax.lax.ppermute(x, flatten_axis_names(axis_name), perm)


def cycle_step(q: WorkQueue, absorbed: WorkQueue, cfg: ForwardConfig):
    """One ring hop: absorb items addressed to this rank, pass the rest on.

    The hop uses the same packed wire format as ``forward_work``: the item
    payload AND the in-flight destination vector are packed into one
    ``(C, W+1)`` uint32 buffer, compacted in ONE payload pass (items and
    dests used to be sorted in two separate passes), and shipped with ONE
    ``collective_permute`` — one payload pass, one payload collective,
    exactly like the forwarding round.  The compaction honours
    ``cfg.marshal``: the sort mode runs a single-bucket key sort and gathers
    through the permutation; the scatter mode skips the sort — the passing
    mask's exclusive prefix sum IS the compacted position, and rows are
    scattered there directly.

    Returns ``(in_flight_queue_after_hop, absorbed_queue)``; both fixed
    capacity.  Must run inside shard_map.  With ``cfg.telemetry`` a trailing
    ``RoundStats`` rides along: a hop has ONE send segment (the whole passing
    queue shipped to the ring successor), so segment demand is the passing
    count measured against the queue capacity — the occupancy signal that
    tells the controller how hard the ring is loaded per hop.  The hop's
    ``recv_drops`` records what the ABSORB enqueue overflowed (the ship
    itself is lossless, so ``stage_drops`` stays 0) — the stats sum to the
    absorbed queue's drop counter, same contract as the exchanges.

    With ``cfg.overflow == "retain"`` the absorb enqueue is the hop's only
    loss site, so it backpressures instead: items addressed to me that the
    absorbed queue has no room for stay IN FLIGHT (they keep cycling and are
    re-offered every ``num_ranks`` hops) rather than overflowing into a drop.
    Absorption stays FIFO in lane order — exactly the rows that fit are
    taken, front first.
    """
    if cfg.pipeline_shards > 1:
        raise ValueError(
            "cycling cannot micro-shard: a ring hop ships the WHOLE queue in "
            "one collective_permute (there is no per-peer segment to split), "
            f"so pipeline_shards={cfg.pipeline_shards} has nothing to overlap "
            "— use pipeline_shards=1 with the cycling pattern"
        )
    me = jax.lax.axis_index(flatten_axis_names(cfg.axis_name))
    lane = jnp.arange(q.capacity)
    valid = lane < q.count
    mine = valid & (q.dest == me)
    if cfg.overflow == "retain":
        # absorb only what fits — the rest keeps cycling (no drop ever)
        free = jnp.maximum(absorbed.capacity - absorbed.count, 0)
        m32 = mine.astype(jnp.int32)
        mine_rank = jnp.cumsum(m32) - m32
        absorb_ok = mine & (mine_rank < free)
    else:
        absorb_ok = mine
    passing = valid & ~absorb_ok

    absorb_drops0 = absorbed.drops
    absorbed = enqueue(
        absorbed, q.items, jnp.where(absorb_ok, me, DISCARD).astype(jnp.int32), valid
    )

    packed, spec = T.pack_payload({"dest": q.dest, "items": q.items})
    if cfg.marshal == "scatter":
        from repro.core.stages import scatter_rows as _scatter

        # sort-free stable compaction: position = exclusive prefix of the
        # passing mask (the 1-bucket counting sort), one payload scatter
        p32 = passing.astype(jnp.int32)
        rank = jnp.cumsum(p32) - p32
        n_pass = jnp.sum(p32)
        packed_c = _scatter(
            packed,
            jnp.where(passing, rank, q.capacity),
            q.capacity,
            use_pallas=cfg.use_pallas,
        )
    else:
        from repro.core.sorting import sort_permutation

        # stable compaction: give passing items key 0, others key 1 (tail) —
        # ONE key sort, ONE payload gather for items+dest together
        fake_dest = jnp.where(passing, 0, DISCARD).astype(jnp.int32)
        perm, _, counts = sort_permutation(fake_dest, q.count, 1)
        n_pass = counts[0]
        packed_c = jnp.take(packed, perm, axis=0)

    shipped = _ring_permute(packed_c, cfg.axis_name, cfg.num_ranks)
    shipped_count = _ring_permute(n_pass, cfg.axis_name, cfg.num_ranks)
    bundle = T.unpack_payload(shipped, spec)
    nq = WorkQueue(
        items=bundle["items"],
        dest=bundle["dest"],
        count=shipped_count.astype(jnp.int32),
        drops=q.drops,
    )
    if cfg.telemetry:
        stats = TS.single_tier_stats(
            n_pass[None], q.capacity, cfg.telemetry_buckets,
            sent_rows=n_pass, stage_drops=jnp.zeros((), jnp.int32),
            recv_total=shipped_count,
            recv_drops=absorbed.drops - absorb_drops0,
        )
        return nq, absorbed, stats
    return nq, absorbed


def deliver_by_cycling(q: WorkQueue, cfg: ForwardConfig):
    """Deliver every item by cycling the queue through the full ring (R-1
    hops) — the drop-in 'Barney-style' replacement for one forward_work
    round.  Returns (absorbed_queue, total_delivered_globally); with
    ``cfg.telemetry`` also a ``StatsRing`` recording one ``RoundStats`` per
    ring hop (the per-hop in-flight occupancy trace).  The ring's window is
    ``num_ranks`` — one slot per hop, regardless of ``telemetry_window`` —
    so the full trace always survives (a 16-round default window on a
    32-rank ring would silently overwrite the first half).

    With ``cfg.overflow == "retain"`` the ring is lossless: the absorb
    backpressure in :func:`cycle_step` keeps not-yet-absorbable items in
    flight, and after the full circuit (every item has revisited its owner
    once; absorbed space never grows mid-circuit, so further laps cannot
    help) the leftovers — each back at its source rank — are PARKED in the
    absorbed queue with their ``dest`` intact, for the caller to drain and
    re-offer.  Parking overflows only when a rank's absorbed queue is
    genuinely full (the same receiver-admission bound as the forwarding
    path), and then it is counted in ``drops``, never silent."""
    from repro.core.termination import _vary
    from repro.obs import trace as OT

    if OT.enabled():
        # trace-time record: the ring's hop count is static (R-1 permutes)
        OT.event(
            "route.deliver_by_cycling", OT.CAT_ROUTE,
            num_ranks=cfg.num_ranks, hops=cfg.num_ranks,
            overflow=cfg.overflow, telemetry=cfg.telemetry,
        )

    absorbed = make_queue(jax.tree.map(lambda a: a[0], q.items), cfg.capacity)

    def body(i, c):
        if cfg.telemetry:
            nq, na, stats = cycle_step(c[0], c[1], cfg)
            return (
                _vary(nq, cfg.axis_name),
                _vary(na, cfg.axis_name),
                _vary(TS.ring_push(c[2], stats), cfg.axis_name),
            )
        nq, na = cycle_step(c[0], c[1], cfg)
        return _vary(nq, cfg.axis_name), _vary(na, cfg.axis_name)

    carry = (_vary(q, cfg.axis_name), _vary(absorbed, cfg.axis_name))
    if cfg.telemetry:
        ring0 = TS.make_ring(
            1, window=cfg.num_ranks, buckets=cfg.telemetry_buckets
        )
        carry = carry + (_vary(ring0, cfg.axis_name),)
    out = jax.lax.fori_loop(0, cfg.num_ranks, body, carry)
    absorbed = out[1]
    if cfg.overflow == "retain":
        leftover = out[0]
        lane = jnp.arange(leftover.capacity)
        absorbed = enqueue(
            absorbed, leftover.items, leftover.dest, lane < leftover.count
        )
    total = jax.lax.psum(absorbed.count, flatten_axis_names(cfg.axis_name))
    if cfg.telemetry:
        return absorbed, total, out[2]
    return absorbed, total

"""``forwardRays()`` — the full RaFI §4.2 pipeline, on-device.

Per round, inside ``shard_map`` (so collectives bind to a real mesh axis):

  1. marshal plan (§4.2.1, ``core.sorting``) — one of two modes:
     ``marshal="sort"`` packs (dest, lane) keys, sorts them, and keeps only
     the *permutation* (the payload is not touched); ``marshal="scatter"``
     skips the sort entirely — one counting-sort pass over the destination
     vector yields each item's stable in-bucket rank plus the histogram
     (send counts for free), enough to place every row directly;
  2. pack the work-item pytree into ONE ``(capacity, words)`` uint32 buffer
     (``core.types.pack_payload`` — the paper's contiguous trivially-copyable
     ray on the wire);
  3. exchange (§4.2.2, ``core.exchange``): ONE count collective plus ONE
     payload collective move the packed buffer; the send-side marshal is ONE
     payload pass — a single gather composing the sort permutation with the
     send layout (sort mode), or a single scatter straight into the send
     layout at ``base[dest] + rank`` (scatter mode) — so each ray is read
     exactly once and written exactly once (§6.1) either way;
  4. wrap up (§4.2.3): the received buffer is unpacked back into the item
     pytree and becomes the next input queue, destinations reset to DISCARD,
     the emit counter resets, and a ``psum`` of received counts yields the
     *global* in-flight total for distributed termination.

The two marshal modes are bit-exact end to end (the scatter placement
reproduces the sort's lexicographic stable source order — property-tested in
``tests/test_core_scatter.py``); the sort path is kept as the oracle.

Beyond the paper: because sort, exchange and termination test are all traced
into one XLA program, a full multi-round computation runs under a single
``jax.lax.while_loop`` with zero host round-trips (the CUDA/MPI original
synchronises with the host every round to read back segment offsets).  And
where the original issues one RDMA per peer, the packed wire format means
the whole round is one collective regardless of how many leaves the item
type has.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import exchange as X
from repro.core import sorting as S
from repro.core import types as T
from repro.core.health import remap_dest
from repro.core.queue import DISCARD, WorkQueue

__all__ = ["ForwardConfig", "credit_reserve_rows", "flatten_axis_names", "forward_work"]

_EXCHANGES = {
    "padded": X.exchange_padded,
    "ragged": X.exchange_ragged,
    "hierarchical": X.exchange_hierarchical,
    "onehot": X.exchange_onehot,
}


def flatten_axis_names(axis_name) -> Tuple[Any, ...]:
    """``axis_name`` as a flat tuple of plain mesh axis names.

    Hierarchical configs may group several mesh axes into one tier
    (``axis_name=(("pod", "node"), "device")``); collectives that span the
    whole joint axis (``psum``/``all_gather``/``axis_index``) need the
    flattened form.
    """
    if not isinstance(axis_name, (tuple, list)):
        return (axis_name,)
    out = []
    for a in axis_name:
        out.extend(a if isinstance(a, (tuple, list)) else (a,))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class ForwardConfig:
    """Static configuration of a forwarding context.

    Attributes:
      axis_name: mesh axis (or tuple of axes) the queue is distributed over.
        The hierarchical exchange takes a tuple of ≥2 tiers ordered slowest
        fabric first — e.g. ``("node", "device")`` or ``("pod", "node",
        "device")``; an entry may itself be a tuple of mesh axes treated as
        one joint tier.  Every other backend accepts a single axis or a tuple
        treated as one joint flat axis.
      num_ranks: number of shards on that axis (R).
      capacity: per-rank queue capacity (paper: ``resizeRayQueues(N)``).
      peer_capacity: padded exchange only — per-peer slot rows for the send
        buffer (default 2·ceil(C/R): the flat fan-out is R per-rank slots).
        For hierarchical configs this field mirrors ``level_capacities[-1]``
        (the fastest tier) and may be passed as a legacy alias for it.
      level_sizes: hierarchical only — ranks per mesh tier, slowest first;
        must multiply to ``num_ranks``.  For 2-level configs it may be given
        via the legacy ``fast_size`` alias instead.
      level_capacities: hierarchical only — stage-``l`` padded rows per peer
        segment on tier ``l`` (default 2·ceil(C/level_sizes[l]) each: the
        tier-``l`` fan-out is ``level_sizes[l]`` aggregated segments).
      fast_size: legacy 2-level alias, mirrors ``level_sizes[-1]``.
      node_capacity: legacy 2-level alias, mirrors ``level_capacities[0]``
        (the slowest tier's per-segment rows).
      exchange: "ragged" (TPU production) | "padded" (portable) |
        "hierarchical" (N-stage, N-D meshes) | "onehot" (test oracle).
      marshal: "sort" (§4.2.1 key sort + composed send gather — the
        bit-exactness oracle) | "scatter" (sort-free bucket scatter: one
        counting-sort pass over the destination vector, then packed rows are
        scattered straight into the send layout — one payload pass per round
        pre-collective).  The two modes place items identically.
      sort_method: "pack" (paper-faithful packed keys) | "argsort".  Only
        consulted by ``marshal="sort"`` (the scatter plan has no keys).
      use_pallas: route the marshal-plan and payload-pass kernels through
        Pallas (``kernels/sort_keys`` + ``kernels/marshal`` for the sort
        mode, ``kernels/bucket_scatter`` for the scatter mode).
      telemetry: record a ``repro.telemetry.RoundStats`` traffic snapshot per
        round (per-tier segment-demand histograms, max demand, per-stage §3.3
        clamp drops) from control-plane values the round already computes —
        zero additional collectives.  ``forward_work`` then returns the stats
        as a third output and ``run_until_done`` carries a ``StatsRing`` of
        the last ``telemetry_window`` rounds through its while-loop,
        returning it as a fourth output for ``repro.tune`` to re-plan
        capacities from.
      telemetry_window: rounds the on-device ring keeps (oldest overwritten).
      telemetry_buckets: demand-histogram buckets per tier; bucket B-1 is the
        at-or-above-capacity overflow bucket (see ``telemetry.bucket_width``).
      overflow: what a §3.3 capacity clamp does to the rows it cuts.
        ``"drop"`` (default) discards and counts them — the paper's literal
        contract and the bit-exact oracle.  ``"retain"`` keeps every row a
        sender- or tier-clamp would cut in the LOCAL queue with its ``dest``
        intact, to be retried next round (on hierarchical routes a row
        clamped at stage ``l`` stays resident at the intermediate rank it
        reached, where destination routing resumes it).  Retained lanes are
        compacted to the FRONT of the queue, so the marshal's stable
        source-order rank gives them FIFO oldest-first send-slot priority —
        a bounded-delay anti-starvation guarantee (the lossless law; see
        ROADMAP).  Retention is pure local compaction: the lowered
        collective inventory is bit-identical to ``"drop"`` (guarded in
        ``tests/test_collective_budget.py``).  The only remaining loss sites
        are receiver-side (arrivals beyond what the queue can admit next to
        the retained rows, and the onehot oracle's receiver clamp) — both
        still counted in ``drops``; size ``capacity`` at the §6.3 worst case
        to make them unreachable.
      pipeline_shards: micro-shard count S for software-pipelined forwarding
        (the overlap law; default 1 = the bulk-synchronous oracle).  The
        exchange's per-peer slot rows are split into S chunks whose
        marshal→counts→payload→unmarshal chains are issued interleaved, so
        an async-collective backend keeps shard k's payload collective in
        flight while shard k−1 unmarshals and shard k+1 marshals (on
        hierarchical routes, stage-l of shard k additionally overlaps
        stage-(l−1) of shard k+1).  Placement is bit-exact with S=1 and
        payload wire bytes are conserved; the collective inventory becomes
        S payload + S count collectives per mesh axis.  Must divide the
        queue capacity (and each per-tier slot budget); the bulk-synchronous
        backends without a slot dimension — the onehot oracle and ring
        cycling — reject S > 1.
      flow: wire admission policy — the backpressure law.  ``"open"``
        (default) ships every clamped segment regardless of receiver state:
        the §3.3 contract and the bit-exactness oracle.  ``"credit"`` makes
        each receiver advertise its free queue space on the count collective
        the round already runs (the count ``all_to_all`` widens from one i32
        column to two — nothing payload-sized, so the budget law's
        collective inventory is unchanged), and senders spend wire ONLY on
        rows the advertised credit admits: one-round-stale credits are
        apportioned deterministically across the R contending senders
        (floor share + rank-ordered residual, so an incast can never
        overshoot the receiver), and the un-credited tail of each
        destination segment is held locally through the ``overflow="retain"``
        spill/compaction machinery — which credit mode therefore requires —
        instead of being shipped and bounced.  On hierarchical routes each
        tier advertises its own aggregated headroom, so a saturated node
        throttles the slow-fabric stage, not just the last hop.  Credits ride
        the drive's while-loop carry (``forward_work`` takes ``credits=`` and
        returns ``credits_out``); the onehot oracle has no sender clamp to
        gate and rejects credit flow.
      emit_reserve: credit mode only — receive-queue rows each advertisement
        WITHHOLDS for the rank's own next-round emissions (``-1``, the
        default, resolves to ``capacity // 2``).  The drive's emission gate
        hands the app exactly this budget back as per-round headroom, so
        retained backlog + gated emissions + advertised credits never exceed
        ``capacity``: granted arrivals always fit and the flat credit path
        is receiver-drop-free by construction (hierarchical adverts are
        min-aggregated and tier-stale — bounded, counted overshoot).
    """

    axis_name: Any
    num_ranks: int
    capacity: int
    peer_capacity: int = 0
    exchange: str = "padded"
    marshal: str = "sort"
    sort_method: str = "pack"
    use_pallas: bool = False
    fast_size: int = 0
    node_capacity: int = 0
    level_sizes: Tuple[int, ...] = ()
    level_capacities: Tuple[int, ...] = ()
    telemetry: bool = False
    telemetry_window: int = 16
    telemetry_buckets: int = 8
    overflow: str = "drop"
    pipeline_shards: int = 1
    flow: str = "open"
    emit_reserve: int = -1

    def __post_init__(self):
        if self.exchange not in _EXCHANGES:
            raise ValueError(f"unknown exchange {self.exchange!r}")
        if self.overflow not in ("drop", "retain"):
            raise ValueError(
                f"unknown overflow {self.overflow!r} (expected 'drop' — the "
                "§3.3 oracle — or 'retain': spill-and-retry, the lossless law)"
            )
        if self.flow not in ("open", "credit"):
            raise ValueError(
                f"unknown flow {self.flow!r} (expected 'open' — ship every "
                "clamped segment, the §3.3 oracle — or 'credit': "
                "receiver-advertised admission, the backpressure law)"
            )
        if self.flow == "credit" and self.overflow != "retain":
            raise ValueError(
                "flow='credit' requires overflow='retain': the un-credited "
                "tail of each destination segment is held locally through "
                "the retain spill/compaction machinery — with overflow="
                "'drop' the credit gate would convert backpressure into "
                "silent sender-side loss"
            )
        if self.flow == "credit" and self.exchange == "onehot":
            raise ValueError(
                "flow='credit' is not supported by exchange='onehot': the "
                "all-gather oracle ships whole queues (no per-destination "
                "sender clamp exists for a credit gate to tighten)"
            )
        if self.emit_reserve != -1 and not (
            0 <= self.emit_reserve < self.capacity
        ):
            raise ValueError(
                f"emit_reserve ({self.emit_reserve}) must be -1 (auto: "
                f"capacity // 2) or in [0, capacity) — reserving the whole "
                "queue would advertise zero credit forever"
            )
        if self.marshal not in ("sort", "scatter"):
            raise ValueError(f"unknown marshal {self.marshal!r}")
        if self.sort_method not in ("pack", "argsort"):
            raise ValueError(f"unknown sort_method {self.sort_method!r}")
        if self.telemetry_window < 1:
            raise ValueError(
                f"telemetry_window ({self.telemetry_window}) must be >= 1"
            )
        if self.telemetry_buckets < 2:
            raise ValueError(
                f"telemetry_buckets ({self.telemetry_buckets}) must be >= 2 "
                "(bucket B-1 is the at-capacity overflow bucket)"
            )
        if self.num_ranks <= 0 or self.capacity <= 0:
            raise ValueError(
                f"num_ranks ({self.num_ranks}) and capacity ({self.capacity}) "
                "must be positive"
            )
        if self.pipeline_shards < 1:
            raise ValueError(
                f"pipeline_shards ({self.pipeline_shards}) must be >= 1 "
                "(1 = the bulk-synchronous round)"
            )
        if self.capacity % self.pipeline_shards:
            raise ValueError(
                f"pipeline_shards ({self.pipeline_shards}) must divide the "
                f"queue capacity ({self.capacity}) so every micro-shard "
                "covers an equal slice of the wavefront"
            )
        if self.pipeline_shards > 1 and self.exchange == "onehot":
            raise ValueError(
                "pipeline_shards > 1 is not supported by exchange='onehot': "
                "the all-gather oracle is bulk-synchronous by design (whole "
                "queues ship at once — no per-peer slot rows to micro-shard)"
            )
        if self.exchange == "hierarchical":
            self._init_hierarchical()
            return
        # Flat backends ignore the hierarchical fields; passing them is a
        # config bug (the caller expects topology routing they won't get).
        for field in ("fast_size", "node_capacity", "level_sizes", "level_capacities"):
            if getattr(self, field):  # 0 and () are both falsy
                raise ValueError(
                    f"{field} only applies to exchange='hierarchical'; the "
                    f"{self.exchange!r} exchange routes over one flat axis "
                    "and would silently ignore it"
                )
        if self.exchange == "padded":
            if self.peer_capacity <= 0:
                # flat fan-out: R per-rank slots
                object.__setattr__(
                    self, "peer_capacity",
                    max(1, -(-self.capacity // self.num_ranks) * 2),
                )
            if self.peer_capacity % self.pipeline_shards:
                raise ValueError(
                    f"pipeline_shards ({self.pipeline_shards}) must divide "
                    f"peer_capacity ({self.peer_capacity}): micro-shards are "
                    "equal slices of the per-peer slot rows"
                )
        elif self.peer_capacity:
            # ragged segments are contiguous (no slots); onehot gathers all
            raise ValueError(
                f"peer_capacity does not apply to exchange={self.exchange!r} "
                "(no padded per-peer slots exist there) and would be "
                "silently ignored"
            )

    def _init_hierarchical(self):
        n_axes = (
            len(self.axis_name)
            if isinstance(self.axis_name, (tuple, list))
            else 1
        )
        if n_axes < 2:
            raise ValueError(
                "hierarchical exchange routes over a multi-tier mesh and "
                "needs axis_name=(slowest, …, fastest), e.g. "
                f"('node', 'device'); got {self.axis_name!r} ({n_axes} axis)"
            )
        sizes = tuple(int(a) for a in self.level_sizes)
        if sizes:
            if len(sizes) != n_axes:
                raise ValueError(
                    f"level_sizes {sizes} must give one rank count per "
                    f"axis_name tier ({n_axes} tiers: {self.axis_name!r})"
                )
            prod = 1
            for a in sizes:
                if a < 1:
                    raise ValueError(f"level_sizes entries must be >= 1, got {sizes}")
                prod *= a
            if prod != self.num_ranks:
                raise ValueError(
                    f"level_sizes {sizes} multiply to {prod}, not num_ranks "
                    f"{self.num_ranks}"
                )
            if self.fast_size and self.fast_size != sizes[-1]:
                raise ValueError(
                    f"fast_size {self.fast_size} contradicts level_sizes "
                    f"{sizes} (it aliases the fastest tier, {sizes[-1]})"
                )
        else:
            if n_axes != 2:
                raise ValueError(
                    f"a {n_axes}-level hierarchical exchange needs "
                    "level_sizes=(slowest, …, fastest) — fast_size alone only "
                    "determines a 2-level (slow, fast) split"
                )
            if self.fast_size <= 0:
                raise ValueError(
                    "hierarchical exchange needs level_sizes (or the 2-level "
                    "fast_size alias: the number of ranks on the fast mesh axis)"
                )
            if self.num_ranks % self.fast_size:
                raise ValueError(
                    f"fast_size {self.fast_size} must divide num_ranks "
                    f"{self.num_ranks} (ranks are node-major over (slow, fast))"
                )
            sizes = (self.num_ranks // self.fast_size, self.fast_size)

        caps = tuple(int(c) for c in self.level_capacities)
        if caps and len(caps) != len(sizes):
            raise ValueError(
                f"level_capacities {caps} must give one segment size per "
                f"tier ({len(sizes)} tiers)"
            )
        if not caps:
            # tier-l fan-out: level_sizes[l] aggregated segments, 2× headroom
            caps = tuple(max(1, -(-self.capacity // a) * 2) for a in sizes)
            if self.peer_capacity > 0:  # legacy alias: fastest tier
                caps = caps[:-1] + (self.peer_capacity,)
            if self.node_capacity > 0:  # legacy alias: slowest tier
                caps = (self.node_capacity,) + caps[1:]
        else:
            if any(c < 1 for c in caps):
                raise ValueError(f"level_capacities entries must be >= 1, got {caps}")
            if self.peer_capacity and self.peer_capacity != caps[-1]:
                raise ValueError(
                    f"peer_capacity {self.peer_capacity} contradicts "
                    f"level_capacities {caps} (it aliases the fastest tier)"
                )
            if self.node_capacity and self.node_capacity != caps[0]:
                raise ValueError(
                    f"node_capacity {self.node_capacity} contradicts "
                    f"level_capacities {caps} (it aliases the slowest tier)"
                )
        if any(c % self.pipeline_shards for c in caps):
            raise ValueError(
                f"pipeline_shards ({self.pipeline_shards}) must divide every "
                f"level_capacities entry ({caps}): micro-shards are equal "
                "slices of each tier's per-segment slot rows"
            )
        object.__setattr__(self, "level_sizes", sizes)
        object.__setattr__(self, "level_capacities", caps)
        # keep the legacy aliases live so 2-level callers read either form
        object.__setattr__(self, "fast_size", sizes[-1])
        object.__setattr__(self, "peer_capacity", caps[-1])
        object.__setattr__(self, "node_capacity", caps[0])


def credit_reserve_rows(cfg: ForwardConfig) -> int:
    """Resolved ``emit_reserve``: receive rows every credit advertisement
    withholds for the rank's own emissions (the drive's per-round emission
    headroom).  ``-1`` auto-sizes to half the queue."""
    return cfg.capacity // 2 if cfg.emit_reserve < 0 else cfg.emit_reserve


def forward_work(
    q: WorkQueue, cfg: ForwardConfig, *, age=None, health=None, credits=None
):
    """One collective forwarding round. Must run inside ``shard_map``.

    Returns ``(new_queue, total_in_flight)`` where ``total_in_flight`` is the
    paper's §4.2.3 global reduce — the number of items alive across *all*
    ranks after the exchange, used for distributed-termination detection.
    With ``cfg.telemetry`` the round's ``RoundStats`` snapshot rides along as
    a third output (``(new_queue, total, stats)``) — the arity is static in
    the config, so traced callers thread it without cost.

    With ``cfg.overflow == "retain"`` the returns become
    ``(new_queue, total, age_out[, stats])``: clamp-cut rows come back
    compacted to the FRONT of ``new_queue`` with their ``dest`` intact
    (arrivals fill in behind, dest reset to DISCARD as usual), ``total``
    counts retained rows so termination can't fire with spilled work, and
    ``age_out`` is the per-lane rounds-waiting counter (feed it back via
    ``age=`` on the next call; ``None`` means all lanes are fresh).  Arrivals
    that don't fit next to the retained rows are the one remaining loss site
    — counted into ``drops``.

    With ``cfg.flow == "credit"`` the returns grow ``credits_out`` after
    ``age_out`` (``(new_queue, total, age_out, credits_out[, stats])``):
    ``credits_out[d]`` is destination ``d``'s free-space advertisement
    received on this round's count collective, to be fed back via
    ``credits=`` on the next call so the sender clamp spends wire only on
    admissible rows.  ``credits=None`` means every receiver starts fully
    credited (``capacity`` each) — the uncontended single-shot assumption
    (benchmarks, examples).  The termination drive instead cold-starts its
    carried credits at ZERO — the first round is advert-only, so no wire is
    risked before any receiver has spoken (see ``drive_start``).

    ``health`` (optional ``(R,) bool``, replicated) drains sick ranks: every
    destination on an unhealthy rank is re-addressed pre-marshal through the
    pure local ``core.health.remap_dest`` law, so unhealthy ranks receive
    nothing while the collective inventory stays bit-identical to the plain
    round (retained rows keep the REMAPPED destination — once re-addressed,
    a row stays re-addressed).  ``None`` and an all-healthy mask are
    bit-identical.
    """
    R = cfg.num_ranks
    retain = cfg.overflow == "retain"
    if health is not None:
        q = dataclasses.replace(q, dest=remap_dest(q.dest, health))
    perm = dest_clean = dest_rank = None
    if cfg.marshal == "scatter":
        # Sort-free bucket plan: ONE counting-sort pass over the (cheap,
        # 1-word-per-item) destination vector yields the sanitized dest, each
        # item's stable in-bucket rank, and the histogram — the send counts
        # fall out for free and every exchange stage derives its layout from
        # them (no keys, no sort, no per-tier boundary detection).  Works for
        # flat AND hierarchical routes: ranks are lexicographic in the tier
        # digits, so in-bucket rank against the full destination IS the
        # in-sub-segment rank at every tier.
        if cfg.use_pallas:
            from repro.kernels.bucket_scatter import ops as bs_ops

            dest_clean, dest_rank, hist = bs_ops.rank_and_histogram(
                q.dest, q.count, num_ranks=R
            )
        else:
            dest_clean, dest_rank, hist = S.destination_rank(q.dest, q.count, R)
        send_counts = hist[:R]
    elif cfg.exchange == "hierarchical":
        # Lexicographic N-level keys: ONE sort yields every stage permutation.
        # The Pallas path is routed explicitly through kernels/sort_keys (the
        # flat packed key sorts identically because ranks are lexicographic
        # in the tier digits) — it must never silently fall back to the flat
        # branch below, which would skip the level-shaped count tensor.
        if cfg.use_pallas:
            from repro.kernels.sort_keys import ops as sk_ops

            perm, count_tensor = sk_ops.sort_permutation_hierarchical(
                q.dest, q.count, cfg.level_sizes
            )
        else:
            perm, count_tensor = S.sort_permutation_hierarchical(
                q.dest, q.count, cfg.level_sizes, method=cfg.sort_method
            )
        send_counts = count_tensor.reshape(-1)
    elif cfg.use_pallas:
        from repro.kernels.sort_keys import ops as sk_ops

        perm, sorted_dest, send_counts = sk_ops.sort_permutation(q.dest, q.count, R)
        send_counts = send_counts[:R]
        del sorted_dest  # segments are fully described by the histogram
    else:
        perm, sorted_dest, send_counts = S.sort_permutation(
            q.dest, q.count, R, method=cfg.sort_method
        )
        send_counts = send_counts[:R]
        del sorted_dest

    packed, spec = T.pack_payload(q.items)  # (C, W) uint32 — the wire format

    kwargs = dict(
        axis_name=cfg.axis_name,
        num_ranks=R,
        capacity=cfg.capacity,
        use_pallas=cfg.use_pallas,
        marshal=cfg.marshal,
        dest_clean=dest_clean,
        dest_rank=dest_rank,
        telemetry=cfg.telemetry,
        telemetry_buckets=cfg.telemetry_buckets,
        pipeline_shards=cfg.pipeline_shards,
    )
    if cfg.exchange == "hierarchical":
        kwargs.update(
            level_sizes=cfg.level_sizes, level_capacities=cfg.level_capacities
        )
    else:
        kwargs.update(peer_capacity=cfg.peer_capacity)
    if retain:
        if age is None:
            age = jnp.zeros((cfg.capacity,), jnp.int32)
        kwargs.update(overflow="retain", age=age)
    credit = cfg.flow == "credit"
    if credit:
        if credits is None:
            # single-shot call: assume uncontended, fully credited receivers
            credits = jnp.full((R,), cfg.capacity, jnp.int32)
        kwargs.update(
            flow="credit", credits=credits,
            credit_reserve=credit_reserve_rows(cfg),
        )
    fn = _EXCHANGES[cfg.exchange]
    stats = pending = credits_out = None
    res = fn(packed, perm, send_counts, **kwargs)
    if credit and cfg.telemetry:
        recv_packed, recv_counts, new_count, drops, pending, credits_out, stats = res
    elif credit:
        recv_packed, recv_counts, new_count, drops, pending, credits_out = res
    elif retain and cfg.telemetry:
        recv_packed, recv_counts, new_count, drops, pending, stats = res
    elif retain:
        recv_packed, recv_counts, new_count, drops, pending = res
    elif cfg.telemetry:
        recv_packed, recv_counts, new_count, drops, stats = res
    else:
        recv_packed, recv_counts, new_count, drops = res
    del recv_counts

    if retain:
        # Merge: retained lanes FIRST (their dest survives), arrivals behind
        # (dest reset to DISCARD).  Pure local compaction — zero collectives.
        # The exchange did the heavy lifting in-pass: each clamp site hands
        # back its cut rows as an already-compacted spill block (rows, dest,
        # age, n) — segment tails read with the send gather's own positional
        # arithmetic — and the receive compaction has already landed the
        # arrivals BEHIND the reserved spill front.  All that's left here is
        # selecting each block into its slice of the front (stable
        # block-then-row order = FIFO oldest-first).  Measured on the 8-way
        # shard_map CPU benchmark the round is dispatch-bound (op count, not
        # bytes), so the few selects below — and no lax.cond, whose fixed
        # thunk cost alone breaks the happy-path budget — are what keeps
        # retention free when nothing spills.  Arrivals that didn't fit next
        # to the spill were counted by the exchange; a spill past C
        # (unreachable when capacity bounds the resident population) is
        # counted here as spill_over.
        C = cfg.capacity
        lane = jnp.arange(C, dtype=jnp.int32)
        run = jnp.zeros((), jnp.int32)
        for entry in pending:
            run = run + entry[-1].astype(jnp.int32)
        ret_count = jnp.minimum(run, C)
        spill_over = run - ret_count

        if len(pending) == 1:
            # Flat exchanges: one block at offset 0 — a single select, no
            # index arithmetic at all.
            rows_e, dest_e, age_e, n_e = pending[0]
            sel = lane < n_e
            merged = jnp.where(sel[:, None], rows_e, recv_packed)
            dest_out = jnp.where(sel, dest_e, DISCARD)
            age_out = jnp.where(sel, age_e, 0)
        else:
            # Multi-stage routes: index into the VIRTUAL concatenation
            # [block_0 | block_1 | … | arrivals] with one payload gather
            # instead of a per-block gather+select chain — the lane→source
            # map is all (C,) integer math, so the payload-scale op count
            # stays flat in the number of stages.
            sizes = [r.shape[0] for r, _, _, _ in pending]
            src = lane + sum(sizes)  # default: the arrivals region
            start = jnp.zeros((), jnp.int32)
            off = 0
            for (rows_e, _, _, n_e), sz in zip(pending, sizes):
                sel = (lane >= start) & (lane < start + n_e)
                src = jnp.where(sel, off + lane - start, src)
                start = start + n_e.astype(jnp.int32)
                off += sz
            merged = jnp.take(
                jnp.concatenate([r for r, _, _, _ in pending] + [recv_packed]),
                src,
                axis=0,
            )
            dest_out = jnp.take(
                jnp.concatenate(
                    [d for _, d, _, _ in pending]
                    + [jnp.full((C,), DISCARD, jnp.int32)]
                ),
                src,
            )
            age_out = jnp.take(
                jnp.concatenate(
                    [a for _, _, a, _ in pending] + [jnp.zeros((C,), jnp.int32)]
                ),
                src,
            )
        new_q = WorkQueue(
            items=T.unpack_payload(merged, spec),
            dest=dest_out,
            count=(ret_count + new_count).astype(jnp.int32),
            drops=q.drops + drops.astype(jnp.int32) + spill_over,
        )
        total = jax.lax.psum(new_q.count, flatten_axis_names(cfg.axis_name))
        if cfg.telemetry:
            stats = dataclasses.replace(
                stats,
                retained_rows=ret_count,
                age_max=jnp.max(age_out).astype(jnp.int32),
            )
            if credit:
                return new_q, total, age_out, credits_out, stats
            return new_q, total, age_out, stats
        if credit:
            return new_q, total, age_out, credits_out
        return new_q, total, age_out

    new_q = WorkQueue(
        items=T.unpack_payload(recv_packed, spec),
        dest=jnp.full((cfg.capacity,), DISCARD, jnp.int32),
        count=new_count.astype(jnp.int32),
        drops=q.drops + drops.astype(jnp.int32),
    )
    # §4.2.3: "a final MPI reduce-add on the number of rays received" —
    # the global in-flight total for distributed termination.
    total = jax.lax.psum(new_q.count, flatten_axis_names(cfg.axis_name))
    if cfg.telemetry:
        return new_q, total, stats
    return new_q, total

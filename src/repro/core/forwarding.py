"""``forwardRays()`` — the full RaFI §4.2 pipeline, on-device.

Per round, inside ``shard_map`` (so collectives bind to a real mesh axis):

  1. sort emitted items by destination (§4.2.1, ``core.sorting``),
  2. exchange per-peer counts (MPI_Alltoall analogue) and the payload
     (MPI_Alltoallv analogue) (§4.2.2, ``core.exchange``),
  3. wrap up (§4.2.3): the received buffer becomes the next input queue,
     destinations reset to DISCARD, the emit counter resets, and a ``psum``
     of received counts yields the *global* in-flight total for distributed
     termination.

Beyond the paper: because sort, exchange and termination test are all traced
into one XLA program, a full multi-round computation runs under a single
``jax.lax.while_loop`` with zero host round-trips (the CUDA/MPI original
synchronises with the host every round to read back segment offsets).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import exchange as X
from repro.core import sorting as S
from repro.core.queue import DISCARD, WorkQueue

__all__ = ["ForwardConfig", "forward_work"]

_EXCHANGES = {
    "padded": X.exchange_padded,
    "ragged": X.exchange_ragged,
    "onehot": X.exchange_onehot,
}


@dataclasses.dataclass(frozen=True)
class ForwardConfig:
    """Static configuration of a forwarding context.

    Attributes:
      axis_name: mesh axis (or tuple of axes) the queue is distributed over.
      num_ranks: number of shards on that axis (R).
      capacity: per-rank queue capacity (paper: ``resizeRayQueues(N)``).
      peer_capacity: per-(src,dst) slot size for the padded backend.
      exchange: "ragged" (TPU production) | "padded" (portable) | "onehot".
      sort_method: "pack" (paper-faithful packed keys) | "argsort".
      use_pallas: route sort/compact hot spots through the Pallas kernels.
    """

    axis_name: Any
    num_ranks: int
    capacity: int
    peer_capacity: int = 0
    exchange: str = "padded"
    sort_method: str = "pack"
    use_pallas: bool = False

    def __post_init__(self):
        if self.exchange not in _EXCHANGES:
            raise ValueError(f"unknown exchange {self.exchange!r}")
        if self.peer_capacity <= 0 and self.exchange == "padded":
            object.__setattr__(
                self, "peer_capacity", max(1, -(-self.capacity // self.num_ranks) * 2)
            )


def forward_work(q: WorkQueue, cfg: ForwardConfig) -> Tuple[WorkQueue, jax.Array]:
    """One collective forwarding round. Must run inside ``shard_map``.

    Returns ``(new_queue, total_in_flight)`` where ``total_in_flight`` is the
    paper's §4.2.3 global reduce — the number of items alive across *all*
    ranks after the exchange, used for distributed-termination detection.
    """
    R = cfg.num_ranks
    if cfg.use_pallas:
        from repro.kernels.sort_keys import ops as sk_ops

        sorted_items, sorted_dest, send_counts = sk_ops.sort_by_destination(
            q.items, q.dest, q.count, R
        )
    else:
        sorted_items, sorted_dest, send_counts = S.sort_by_destination(
            q.items, q.dest, q.count, R, method=cfg.sort_method
        )
    del sorted_dest  # segments are fully described by the histogram

    fn = _EXCHANGES[cfg.exchange]
    recv_items, recv_counts, new_count, drops = fn(
        sorted_items,
        send_counts[:R],
        axis_name=cfg.axis_name,
        num_ranks=R,
        capacity=cfg.capacity,
        peer_capacity=cfg.peer_capacity,
    )
    del recv_counts

    new_q = WorkQueue(
        items=recv_items,
        dest=jnp.full((cfg.capacity,), DISCARD, jnp.int32),
        count=new_count.astype(jnp.int32),
        drops=q.drops + drops.astype(jnp.int32),
    )
    # §4.2.3: "a final MPI reduce-add on the number of rays received" —
    # the global in-flight total for distributed termination.
    total = jax.lax.psum(new_q.count, cfg.axis_name)
    return new_q, total

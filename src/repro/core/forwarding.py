"""``forwardRays()`` — the full RaFI §4.2 pipeline, on-device.

Per round, inside ``shard_map`` (so collectives bind to a real mesh axis):

  1. key sort (§4.2.1, ``core.sorting``): pack (dest, lane) keys, sort them,
     and keep only the *permutation* — the payload is not touched;
  2. pack the work-item pytree into ONE ``(capacity, words)`` uint32 buffer
     (``core.types.pack_payload`` — the paper's contiguous trivially-copyable
     ray on the wire);
  3. exchange (§4.2.2, ``core.exchange``): ONE count collective plus ONE
     payload collective move the packed buffer; the send-side marshal is a
     single gather that composes the sort permutation with the send layout,
     so each ray is read exactly once and written exactly once (§6.1);
  4. wrap up (§4.2.3): the received buffer is unpacked back into the item
     pytree and becomes the next input queue, destinations reset to DISCARD,
     the emit counter resets, and a ``psum`` of received counts yields the
     *global* in-flight total for distributed termination.

Beyond the paper: because sort, exchange and termination test are all traced
into one XLA program, a full multi-round computation runs under a single
``jax.lax.while_loop`` with zero host round-trips (the CUDA/MPI original
synchronises with the host every round to read back segment offsets).  And
where the original issues one RDMA per peer, the packed wire format means
the whole round is one collective regardless of how many leaves the item
type has.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import exchange as X
from repro.core import sorting as S
from repro.core import types as T
from repro.core.queue import DISCARD, WorkQueue

__all__ = ["ForwardConfig", "forward_work"]

_EXCHANGES = {
    "padded": X.exchange_padded,
    "ragged": X.exchange_ragged,
    "hierarchical": X.exchange_hierarchical,
    "onehot": X.exchange_onehot,
}


@dataclasses.dataclass(frozen=True)
class ForwardConfig:
    """Static configuration of a forwarding context.

    Attributes:
      axis_name: mesh axis (or tuple of axes) the queue is distributed over.
        The hierarchical exchange requires a 2-tuple ``(slow, fast)`` — slow
        (inter-node) axis first; every other backend accepts a single axis or
        a tuple treated as one joint flat axis.
      num_ranks: number of shards on that axis (R).
      capacity: per-rank queue capacity (paper: ``resizeRayQueues(N)``).
      peer_capacity: per-peer slot rows for the padded send buffer.  The
        default accounts for the backend's true fan-out: the flat padded
        exchange fans out to R per-rank slots (2·ceil(C/R) rows each), the
        hierarchical stage-A exchange to ``fast_size`` fast-axis peers
        (2·ceil(C/fast_size) rows each).
      node_capacity: hierarchical only — stage-B rows per destination-node
        segment (the slow axis fans out to R/fast_size per-NODE segments;
        default 2·ceil(C/num_nodes)).
      fast_size: hierarchical only — number of ranks on the fast axis (must
        divide num_ranks; num_ranks // fast_size is the node count).
      exchange: "ragged" (TPU production) | "padded" (portable) |
        "hierarchical" (two-stage, 2-D meshes) | "onehot" (test oracle).
      sort_method: "pack" (paper-faithful packed keys) | "argsort".
      use_pallas: route the key-sort and the fused pack+permute marshal
        through the Pallas kernels (``kernels/sort_keys``, ``kernels/marshal``).
    """

    axis_name: Any
    num_ranks: int
    capacity: int
    peer_capacity: int = 0
    exchange: str = "padded"
    sort_method: str = "pack"
    use_pallas: bool = False
    fast_size: int = 0
    node_capacity: int = 0

    def __post_init__(self):
        if self.exchange not in _EXCHANGES:
            raise ValueError(f"unknown exchange {self.exchange!r}")
        n_axes = (
            len(self.axis_name)
            if isinstance(self.axis_name, (tuple, list))
            else 1
        )
        if self.exchange == "hierarchical":
            if n_axes != 2:
                raise ValueError(
                    "hierarchical exchange routes over a 2-D mesh and needs "
                    f"axis_name=(slow, fast), e.g. ('node', 'device'); got "
                    f"{self.axis_name!r} ({n_axes} axis/axes)"
                )
            if self.fast_size <= 0:
                raise ValueError(
                    "hierarchical exchange needs fast_size > 0 (the number of "
                    "ranks on the fast mesh axis)"
                )
            if self.num_ranks % self.fast_size:
                raise ValueError(
                    f"fast_size {self.fast_size} must divide num_ranks "
                    f"{self.num_ranks} (ranks are node-major over (slow, fast))"
                )
            num_nodes = self.num_ranks // self.fast_size
            if self.peer_capacity <= 0:
                # stage-A fan-out: fast_size per-lane slots, not R per-rank ones
                object.__setattr__(
                    self, "peer_capacity",
                    max(1, -(-self.capacity // self.fast_size) * 2),
                )
            if self.node_capacity <= 0:
                # stage-B fan-out: per-NODE segments over the slow axis
                object.__setattr__(
                    self, "node_capacity",
                    max(1, -(-self.capacity // num_nodes) * 2),
                )
        elif self.exchange == "padded":
            if self.peer_capacity <= 0:
                # flat fan-out: R per-rank slots
                object.__setattr__(
                    self, "peer_capacity",
                    max(1, -(-self.capacity // self.num_ranks) * 2),
                )


def forward_work(q: WorkQueue, cfg: ForwardConfig) -> Tuple[WorkQueue, jax.Array]:
    """One collective forwarding round. Must run inside ``shard_map``.

    Returns ``(new_queue, total_in_flight)`` where ``total_in_flight`` is the
    paper's §4.2.3 global reduce — the number of items alive across *all*
    ranks after the exchange, used for distributed-termination detection.
    """
    R = cfg.num_ranks
    if cfg.use_pallas:
        from repro.kernels.sort_keys import ops as sk_ops

        perm, sorted_dest, send_counts = sk_ops.sort_permutation(q.dest, q.count, R)
        send_counts = send_counts[:R]
        del sorted_dest  # segments are fully described by the histogram
    elif cfg.exchange == "hierarchical":
        # node-major two-level keys: ONE sort yields both stage permutations
        perm, count_matrix = S.sort_permutation_hierarchical(
            q.dest, q.count, R // cfg.fast_size, cfg.fast_size,
            method=cfg.sort_method,
        )
        send_counts = count_matrix.reshape(-1)
    else:
        perm, sorted_dest, send_counts = S.sort_permutation(
            q.dest, q.count, R, method=cfg.sort_method
        )
        send_counts = send_counts[:R]
        del sorted_dest

    packed, spec = T.pack_payload(q.items)  # (C, W) uint32 — the wire format

    kwargs = dict(
        axis_name=cfg.axis_name,
        num_ranks=R,
        capacity=cfg.capacity,
        peer_capacity=cfg.peer_capacity,
        use_pallas=cfg.use_pallas,
    )
    if cfg.exchange == "hierarchical":
        kwargs.update(fast_size=cfg.fast_size, node_capacity=cfg.node_capacity)
    fn = _EXCHANGES[cfg.exchange]
    recv_packed, recv_counts, new_count, drops = fn(packed, perm, send_counts, **kwargs)
    del recv_counts

    new_q = WorkQueue(
        items=T.unpack_payload(recv_packed, spec),
        dest=jnp.full((cfg.capacity,), DISCARD, jnp.int32),
        count=new_count.astype(jnp.int32),
        drops=q.drops + drops.astype(jnp.int32),
    )
    # §4.2.3: "a final MPI reduce-add on the number of rays received" —
    # the global in-flight total for distributed termination.
    total = jax.lax.psum(new_q.count, cfg.axis_name)
    return new_q, total

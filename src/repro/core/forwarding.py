"""``forwardRays()`` — the full RaFI §4.2 pipeline, on-device.

Per round, inside ``shard_map`` (so collectives bind to a real mesh axis):

  1. key sort (§4.2.1, ``core.sorting``): pack (dest, lane) keys, sort them,
     and keep only the *permutation* — the payload is not touched;
  2. pack the work-item pytree into ONE ``(capacity, words)`` uint32 buffer
     (``core.types.pack_payload`` — the paper's contiguous trivially-copyable
     ray on the wire);
  3. exchange (§4.2.2, ``core.exchange``): ONE count collective plus ONE
     payload collective move the packed buffer; the send-side marshal is a
     single gather that composes the sort permutation with the send layout,
     so each ray is read exactly once and written exactly once (§6.1);
  4. wrap up (§4.2.3): the received buffer is unpacked back into the item
     pytree and becomes the next input queue, destinations reset to DISCARD,
     the emit counter resets, and a ``psum`` of received counts yields the
     *global* in-flight total for distributed termination.

Beyond the paper: because sort, exchange and termination test are all traced
into one XLA program, a full multi-round computation runs under a single
``jax.lax.while_loop`` with zero host round-trips (the CUDA/MPI original
synchronises with the host every round to read back segment offsets).  And
where the original issues one RDMA per peer, the packed wire format means
the whole round is one collective regardless of how many leaves the item
type has.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import exchange as X
from repro.core import sorting as S
from repro.core import types as T
from repro.core.queue import DISCARD, WorkQueue

__all__ = ["ForwardConfig", "forward_work"]

_EXCHANGES = {
    "padded": X.exchange_padded,
    "ragged": X.exchange_ragged,
    "onehot": X.exchange_onehot,
}


@dataclasses.dataclass(frozen=True)
class ForwardConfig:
    """Static configuration of a forwarding context.

    Attributes:
      axis_name: mesh axis (or tuple of axes) the queue is distributed over.
      num_ranks: number of shards on that axis (R).
      capacity: per-rank queue capacity (paper: ``resizeRayQueues(N)``).
      peer_capacity: per-(src,dst) slot size for the padded backend.
      exchange: "ragged" (TPU production) | "padded" (portable) | "onehot".
      sort_method: "pack" (paper-faithful packed keys) | "argsort".
      use_pallas: route the key-sort and the fused pack+permute marshal
        through the Pallas kernels (``kernels/sort_keys``, ``kernels/marshal``).
    """

    axis_name: Any
    num_ranks: int
    capacity: int
    peer_capacity: int = 0
    exchange: str = "padded"
    sort_method: str = "pack"
    use_pallas: bool = False

    def __post_init__(self):
        if self.exchange not in _EXCHANGES:
            raise ValueError(f"unknown exchange {self.exchange!r}")
        if self.peer_capacity <= 0 and self.exchange == "padded":
            object.__setattr__(
                self, "peer_capacity", max(1, -(-self.capacity // self.num_ranks) * 2)
            )


def forward_work(q: WorkQueue, cfg: ForwardConfig) -> Tuple[WorkQueue, jax.Array]:
    """One collective forwarding round. Must run inside ``shard_map``.

    Returns ``(new_queue, total_in_flight)`` where ``total_in_flight`` is the
    paper's §4.2.3 global reduce — the number of items alive across *all*
    ranks after the exchange, used for distributed-termination detection.
    """
    R = cfg.num_ranks
    if cfg.use_pallas:
        from repro.kernels.sort_keys import ops as sk_ops

        perm, sorted_dest, send_counts = sk_ops.sort_permutation(q.dest, q.count, R)
    else:
        perm, sorted_dest, send_counts = S.sort_permutation(
            q.dest, q.count, R, method=cfg.sort_method
        )
    del sorted_dest  # segments are fully described by the histogram

    packed, spec = T.pack_payload(q.items)  # (C, W) uint32 — the wire format

    fn = _EXCHANGES[cfg.exchange]
    recv_packed, recv_counts, new_count, drops = fn(
        packed,
        perm,
        send_counts[:R],
        axis_name=cfg.axis_name,
        num_ranks=R,
        capacity=cfg.capacity,
        peer_capacity=cfg.peer_capacity,
        use_pallas=cfg.use_pallas,
    )
    del recv_counts

    new_q = WorkQueue(
        items=T.unpack_payload(recv_packed, spec),
        dest=jnp.full((cfg.capacity,), DISCARD, jnp.int32),
        count=new_count.astype(jnp.int32),
        drops=q.drops + drops.astype(jnp.int32),
    )
    # §4.2.3: "a final MPI reduce-add on the number of rays received" —
    # the global in-flight total for distributed termination.
    total = jax.lax.psum(new_q.count, cfg.axis_name)
    return new_q, total

"""Preemption-tolerant drive loop (ISSUE 7 — the recovery law).

At thousand-rank scale a burst's survival is probabilistic: spot instances
are reclaimed, hosts brown out, maintenance windows drain racks.  The
recovery law makes the forwarding drive itself restartable:

  * **Segmented drive** — ``run_checkpointed`` runs the SAME traced loop
    body as ``run_until_done`` (``termination.drive_segment``), but in
    W-round segments with the carry surfacing to the host at each boundary.
    The carry — queue, cumulative drops, retained-row ages, telemetry ring,
    round counter, app aux — is snapshotted with ``repro.ckpt``'s atomic
    integrity-checked writer, so a kill at ANY point leaves a resumable
    prefix.  Because segmentation changes only WHERE the while-loop pauses,
    never what the body computes, a resumed trajectory is bit-exact with the
    uninterrupted one, round for round (the carry is integer state: uid
    checksums, counts, ages; float payloads are moved, never reduced).
  * **Elastic restore** — checkpoints store the queue in its logical
    rank-stacked layout plus a structure-free manifest ``meta`` (rank count,
    capacity, overflow mode), so ``resume_run`` can land a burst saved on R
    ranks onto R′ ≠ R: surviving ranks keep their rows, rows stranded on
    retired ranks are dealt out toward the emptiest survivors, and
    destinations addressed beyond R′ are re-destinated by the same
    deficit-fill rule.  Conservation closes across the relayout (rows that
    no longer fit are counted as drops, never vanished).
  * **Watchdog** — every boundary asserts the conservation identity
    ``Σ emitted == Σ delivered + in-flight + Σ drops`` from counters the
    loop computes anyway (``termination.drive_start(accounting=True)``).  A
    violated identity means corrupted forwarding state; failing loudly at
    the boundary beats checkpointing the corruption and resuming it forever.
  * **Draining** — ``health`` may be a mask or a host callable ``rnd →
    mask`` re-evaluated at every segment boundary, so a rank reported
    unhealthy stops receiving work within one segment (the pure local remap
    of ``repro.core.health`` — zero collective-inventory change).  Resident
    work is evacuated with ``rebalance(…, health=…)`` before the drain.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro import ckpt
from repro.core import queue as Q
from repro.telemetry import stats as TS

__all__ = [
    "conservation_check",
    "resume_run",
    "run_checkpointed",
]

_SCHEMA = "rafi-drive-carry-v1"


# ----------------------------------------------------------------- watchdog
def conservation_check(carry: Dict[str, Any], *, where: str = "") -> None:
    """Raise ``RuntimeError`` unless the stacked carry closes the books:
    ``Σ emitted == Σ delivered + in-flight + Σ drops`` (uint64 sums — the
    per-rank counters are int32 and a long burst could wrap a 32-bit
    total)."""
    emitted = int(np.asarray(carry["emitted"]).astype(np.uint64).sum())
    delivered = int(np.asarray(carry["delivered"]).astype(np.uint64).sum())
    inflight = int(np.asarray(carry["total"]))
    drops = int(np.asarray(carry["drops"]).astype(np.uint64).sum())
    if emitted != delivered + inflight + drops:
        raise RuntimeError(
            f"conservation violated{' at ' + where if where else ''}: "
            f"emitted={emitted} != delivered={delivered} + "
            f"in-flight={inflight} + drops={drops} "
            f"(leak of {emitted - delivered - inflight - drops} rows) — "
            f"refusing to checkpoint corrupted forwarding state"
        )


# ------------------------------------------------------------ carry plumbing
def _carry_like(ctx, aux_like: Any, *, accounting: bool = True) -> Dict[str, Any]:
    """Host zeros tree with the structure/shape/dtype of the STACKED drive
    carry for ``ctx``'s mesh — the ``like`` target ``ckpt.restore_checkpoint``
    validates against."""
    cfg = ctx.cfg
    R, C = ctx.num_ranks, cfg.capacity
    q = Q.make_queue(ctx.proto, R * C)
    like: Dict[str, Any] = {
        "q": Q.WorkQueue(
            items=jax.tree.map(np.asarray, q.items),
            dest=np.asarray(q.dest),
            count=np.zeros((R,), np.int32),
            drops=np.zeros((R,), np.int32),
        ),
        "aux": jax.tree.map(np.asarray, aux_like),
        "total": np.zeros((), np.int32),
        "rnd": np.zeros((), np.int32),
        "drops": np.zeros((R,), np.int32),
    }
    if cfg.overflow == "retain":
        like["age"] = np.zeros((R * C,), np.int32)
    if cfg.flow == "credit":
        like["credits"] = np.zeros((R * R,), np.int32)
    if cfg.telemetry:
        ring = TS.make_ring(
            TS.num_tiers(cfg),
            window=cfg.telemetry_window,
            buckets=cfg.telemetry_buckets,
        )
        like["ring"] = jax.tree.map(
            lambda a: np.zeros((R,) + a.shape, a.dtype), ring
        )
    if accounting:
        like["emitted"] = np.zeros((R,), np.int32)
        like["delivered"] = np.zeros((R,), np.int32)
    return like


def _meta_of(ctx, rnd: int) -> Dict[str, Any]:
    cfg = ctx.cfg
    return {
        "schema": _SCHEMA,
        "round": int(rnd),
        "num_ranks": int(ctx.num_ranks),
        "capacity": int(cfg.capacity),
        "overflow": cfg.overflow,
        "flow": cfg.flow,
        "telemetry": bool(cfg.telemetry),
        "telemetry_window": int(cfg.telemetry_window),
        "pipeline_shards": int(cfg.pipeline_shards),
    }


def _health_at(health, R: int, rnd: int) -> np.ndarray:
    """Resolve the drive's ``health`` argument at a segment boundary:
    ``None`` → all healthy; a mask → constant; a host callable ``rnd →
    mask`` → re-evaluated (how a brownout enters a running burst)."""
    if health is None:
        return np.ones((R,), bool)
    if callable(health):
        health = health(rnd)
    h = np.asarray(health).astype(bool)
    if h.shape != (R,):
        raise ValueError(f"health mask shape {h.shape} != ({R},)")
    return h


def _finalize(ctx, carry: Dict[str, Any], *, step: int) -> Dict[str, Any]:
    """Stacked carry → host result dict (the segmented analogue of
    ``termination.drive_finalize``)."""
    cfg = ctx.cfg
    carry = jax.device_get(carry)
    q = carry["q"]
    res: Dict[str, Any] = {
        "q": Q.WorkQueue(
            items=q.items, dest=q.dest, count=q.count,
            drops=np.asarray(carry["drops"]),
        ),
        "aux": carry["aux"],
        "rounds": int(np.asarray(carry["rnd"])),
        "done": int(np.asarray(carry["total"])) == 0,
        "emitted": int(np.asarray(carry["emitted"]).astype(np.uint64).sum()),
        "delivered": int(np.asarray(carry["delivered"]).astype(np.uint64).sum()),
        "step": step,
        "preempted": False,
    }
    if cfg.overflow == "retain":
        res["age"] = carry["age"]
    if cfg.telemetry:
        res["ring"] = carry["ring"]
    return res


# ------------------------------------------------------------ the host loop
def _drive_loop(
    ctx,
    segment_p: Callable,
    carry,
    *,
    ckpt_dir,
    checkpoint_every: int,
    max_rounds: int,
    health,
    keep: int,
    halt_after_round: Optional[int],
):
    """Boundary loop shared by fresh and resumed drives: watchdog → save →
    (maybe simulated preemption) → next segment.  Returns the result dict,
    or ``None`` if the drive halted at a boundary (state is on disk; call
    :func:`resume_run` to continue)."""
    from repro.obs import trace as OT

    R = ctx.num_ranks
    last_step = None
    prev_health = None
    while True:
        rnd = int(np.asarray(carry["rnd"]))
        total = int(np.asarray(carry["total"]))
        OT.event(
            "recovery.boundary", OT.CAT_RECOVERY, round=rnd, total=total
        )
        host_carry = jax.device_get(carry)
        conservation_check(host_carry, where=f"round {rnd}")
        if ckpt_dir is not None:
            ckpt.save_checkpoint(
                ckpt_dir, rnd, host_carry, keep=keep, meta=_meta_of(ctx, rnd)
            )
            last_step = rnd
            if OT.enabled():
                man = ckpt.load_manifest(ckpt_dir, rnd)
                leaves = man.get("leaves", [])
                OT.event(
                    "recovery.save", OT.CAT_RECOVERY, step=rnd,
                    leaves=len(leaves),
                    bytes=sum(
                        int(np.prod(e["shape"]) * np.dtype(e["dtype"]).itemsize)
                        for e in leaves
                    ),
                    digest=leaves[0]["sha256"][:16] if leaves else "",
                )
        if total == 0 or rnd >= max_rounds:
            return _finalize(ctx, carry, step=last_step)
        seg_end = min(rnd + checkpoint_every, max_rounds)
        if halt_after_round is not None and seg_end > halt_after_round:
            OT.event(
                "recovery.preempt", OT.CAT_RECOVERY, round=rnd, step=last_step
            )
            return None  # preempted: the boundary just saved is the restart point
        mask = _health_at(health, R, rnd)
        if OT.enabled() and mask is not None:
            cur = np.asarray(mask).astype(bool).tolist()
            if prev_health is not None and cur != prev_health:
                OT.event(
                    "health.transition", OT.CAT_HEALTH, round=rnd,
                    before=prev_health, after=cur,
                )
            prev_health = cur
        carry = segment_p(carry, np.int32(seg_end), mask)


def run_checkpointed(
    ctx,
    round_fn: Callable,
    q0_stacked,
    aux0,
    *,
    aux_specs,
    ckpt_dir,
    checkpoint_every: int = 8,
    max_rounds: int = 64,
    health=None,
    keep: int = 3,
    halt_after_round: Optional[int] = None,
) -> Optional[Dict[str, Any]]:
    """Drive ``round_fn`` to termination with a checkpoint every
    ``checkpoint_every`` rounds (the boundary also runs the conservation
    watchdog).  Same contract as ``RafiContext.run_until_done`` — the traced
    body is literally the same code — plus:

      * ``ckpt_dir``: checkpoints land here (``None`` → segmented drive with
        no saves, the apples-to-apples baseline for overhead measurement);
      * ``health``: ``(R,) bool`` mask OR host callable ``rnd → mask``,
        re-read at every segment boundary (draining / brownout);
      * ``halt_after_round``: simulate preemption — stop at the first
        boundary whose next segment would pass this round and return
        ``None`` (the test/chaos hook; a REAL preemption is just the process
        dying, which leaves the same on-disk state).

    Returns the result dict ``{"q", "aux", "rounds", "done"[, "age"]
    [, "ring"], "emitted", "delivered", "step", "preempted"}`` or ``None``
    when halted.
    """
    from repro.obs import trace as OT

    start_p, segment_p = ctx.checkpoint_drive_programs(
        round_fn, aux_specs=aux_specs, accounting=True
    )
    carry = start_p(
        q0_stacked, aux0, _health_at(health, ctx.num_ranks, 0)
    )
    with OT.span(
        "recovery.run_checkpointed", OT.CAT_RECOVERY,
        checkpoint_every=checkpoint_every, max_rounds=max_rounds,
        num_ranks=ctx.num_ranks,
    ) as sp:
        res = _drive_loop(
            ctx, segment_p, carry,
            ckpt_dir=ckpt_dir, checkpoint_every=checkpoint_every,
            max_rounds=max_rounds, health=health, keep=keep,
            halt_after_round=halt_after_round,
        )
        sp.set(preempted=res is None,
               rounds=None if res is None else res["rounds"])
    return res


def resume_run(
    ctx,
    round_fn: Callable,
    ckpt_dir,
    *,
    aux_specs,
    aux_like,
    step: Optional[int] = None,
    checkpoint_every: int = 8,
    max_rounds: int = 64,
    health=None,
    keep: int = 3,
    halt_after_round: Optional[int] = None,
    aux_restore: Optional[Callable] = None,
) -> Optional[Dict[str, Any]]:
    """Continue a checkpointed drive from ``ckpt_dir`` (latest boundary, or
    an explicit ``step``).

    ``ctx`` is the RESUME-side context — it may span a different rank count
    than the one that saved (elastic restore; see :func:`_elastic_restore`
    for the relayout law).  ``aux_like`` is a host zeros-tree of the aux in
    the NEW mesh's shape (structure must match the saved aux); on an elastic
    resume the aux leaves are refitted with ``aux_restore(old_aux, R_new)``
    if given, else by the default modular fold (new rank ``r`` sums old
    ranks ``o ≡ r (mod R′)`` along each leaf's leading rank axis — correct
    for the additive per-rank accumulators the chaos harness uses; pass
    ``aux_restore`` for anything else).
    """
    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no published checkpoint under {ckpt_dir}")
    manifest = ckpt.load_manifest(ckpt_dir, step)
    meta = manifest.get("meta", {})
    if meta.get("schema") != _SCHEMA:
        raise ValueError(
            f"checkpoint at step {step} is not a drive carry "
            f"(schema={meta.get('schema')!r})"
        )
    cfg = ctx.cfg
    if meta.get("overflow") != cfg.overflow or bool(meta.get("telemetry")) != bool(
        cfg.telemetry
    ):
        raise ValueError(
            f"resume context disagrees with checkpoint: overflow "
            f"{cfg.overflow!r} vs {meta.get('overflow')!r}, telemetry "
            f"{cfg.telemetry} vs {meta.get('telemetry')}"
        )
    # pre-backpressure checkpoints have no "flow" key: they are open-flow
    if meta.get("flow", "open") != cfg.flow:
        raise ValueError(
            f"resume context disagrees with checkpoint: flow "
            f"{cfg.flow!r} vs {meta.get('flow', 'open')!r}"
        )
    like_new = _carry_like(ctx, aux_like, accounting=True)
    R_old, C_old = int(meta["num_ranks"]), int(meta["capacity"])
    if R_old == ctx.num_ranks and C_old == cfg.capacity:
        carry = ckpt.restore_checkpoint(ckpt_dir, step, like_new)
    else:
        # same STRUCTURE, different leaf shapes: borrow the new carry's
        # treedef and take the saved shapes/dtypes from the manifest
        _, treedef = jax.tree.flatten(like_new)
        like_old = jax.tree.unflatten(
            treedef,
            [
                np.zeros(tuple(e["shape"]), np.dtype(e["dtype"]))
                for e in manifest["leaves"]
            ],
        )
        old_carry = ckpt.restore_checkpoint(ckpt_dir, step, like_old)
        carry = _elastic_restore(
            old_carry, ctx, R_old=R_old, C_old=C_old, aux_restore=aux_restore
        )
    from repro.obs import trace as OT

    _, segment_p = ctx.checkpoint_drive_programs(
        round_fn, aux_specs=aux_specs, accounting=True
    )
    with OT.span(
        "recovery.resume_run", OT.CAT_RECOVERY, step=step,
        elastic=R_old != ctx.num_ranks or C_old != cfg.capacity,
        num_ranks=ctx.num_ranks,
    ) as sp:
        res = _drive_loop(
            ctx, segment_p, carry,
            ckpt_dir=ckpt_dir, checkpoint_every=checkpoint_every,
            max_rounds=max_rounds, health=health, keep=keep,
            halt_after_round=halt_after_round,
        )
        sp.set(preempted=res is None,
               rounds=None if res is None else res["rounds"])
    return res


# ------------------------------------------------------------ elastic restore
def _fold_rank_counter(a: np.ndarray, R_new: int) -> np.ndarray:
    """New rank ``r`` absorbs old ranks ``o ≡ r (mod R_new)`` — the modular
    fold for additive per-rank counters (uint64 accumulate, cast back)."""
    out = np.zeros((R_new,) + a.shape[1:], np.uint64)
    for o in range(a.shape[0]):
        out[o % R_new] += a[o].astype(np.uint64)
    return (out % (1 << 32)).astype(a.dtype)


def _default_aux_restore(aux, R_new: int):
    return jax.tree.map(lambda a: _fold_rank_counter(np.asarray(a), R_new), aux)


def _elastic_restore(
    old: Dict[str, Any], ctx, *, R_old: int, C_old: int, aux_restore
) -> Dict[str, Any]:
    """Relayout a carry saved on ``R_old`` ranks onto ``ctx``'s mesh.

    The relayout law (host numpy, deterministic):

      * rows resident on a surviving rank (``o < R′``) stay put;
      * rows stranded on retired ranks are dealt to survivors in old-rank /
        lane order, each row to the survivor furthest below the even-split
        quota ``ceil(total/R′)`` (ties → lowest rank);
      * destinations addressed beyond R′ are re-pointed by the same
        deficit-fill rule over the pending per-destination load;
      * per rank, retained rows (``dest >= 0``) are packed FIRST, keeping
        their ages — ``termination._split_retained`` requires the retained
        block front-contiguous — then residents with age 0;
      * rows past the new capacity are cut INTO the drop counter (the
        conservation identity closes: in-flight shrinks by exactly what
        drops grows by);
      * the telemetry ring restarts empty (per-rank round history has no
        meaning across a rank-count change);
      * ``emitted`` / ``delivered`` / ``drops`` fold modularly
        (new ``r`` sums old ``o ≡ r mod R′``).
    """
    cfg = ctx.cfg
    R_new, C_new = ctx.num_ranks, cfg.capacity
    retain = cfg.overflow == "retain"
    q = old["q"]
    counts = np.asarray(q.count).astype(np.int64)
    dest = np.asarray(q.dest).copy()
    age_old = (
        np.asarray(old["age"]).copy() if retain else np.zeros_like(dest)
    )
    item_leaves, item_def = jax.tree.flatten(
        jax.tree.map(np.asarray, q.items)
    )

    # live rows in deterministic (old rank, lane) order
    rows = []  # (old_rank, global_lane, dest, age)
    for o in range(R_old):
        base = o * C_old
        for lane in range(int(counts[o])):
            rows.append([o, base + lane, int(dest[base + lane]), int(age_old[base + lane])])

    # re-destinate addresses beyond the new mesh: deficit fill over the
    # pending per-destination load (out-of-range rows go wherever the least
    # work is already headed)
    load = np.zeros((R_new,), np.int64)
    for r in rows:
        if 0 <= r[2] < R_new:
            load[r[2]] += 1
    for r in rows:
        if r[2] >= R_new:
            d = int(np.argmin(load))
            r[2] = d
            load[d] += 1

    # deal stranded rows to survivors, emptiest-first toward the even split
    occupancy = np.zeros((R_new,), np.int64)
    for r in rows:
        if r[0] < R_new:
            occupancy[r[0]] += 1
    placed = []  # (new_rank, global_lane, dest, age)
    for o, gl, d, ag in rows:
        if o < R_new:
            placed.append((o, gl, d, ag))
        else:
            nr = int(np.argmin(occupancy))
            occupancy[nr] += 1
            placed.append((nr, gl, d, ag))

    # pack per new rank: retained first (stable), cut at capacity → drops
    new_dest = np.full((R_new * C_new,), Q.DISCARD, np.int32)
    new_age = np.zeros((R_new * C_new,), np.int32)
    new_count = np.zeros((R_new,), np.int32)
    cut = np.zeros((R_new,), np.int32)
    new_leaves = [
        np.zeros((R_new * C_new,) + l.shape[1:], l.dtype) for l in item_leaves
    ]
    for nr in range(R_new):
        mine = [p for p in placed if p[0] == nr]
        mine = [p for p in mine if p[2] >= 0] + [p for p in mine if p[2] < 0]
        kept = mine[:C_new]
        cut[nr] = len(mine) - len(kept)
        new_count[nr] = len(kept)
        for j, (_, gl, d, ag) in enumerate(kept):
            tl = nr * C_new + j
            new_dest[tl] = d
            new_age[tl] = ag
            for leaf, src in zip(new_leaves, item_leaves):
                leaf[tl] = src[gl]

    new_drops = _fold_rank_counter(np.asarray(old["drops"]), R_new)
    new_drops = (new_drops.astype(np.int64) + cut).astype(np.int32)
    aux_fit = aux_restore if aux_restore is not None else _default_aux_restore
    carry: Dict[str, Any] = {
        "q": Q.WorkQueue(
            items=jax.tree.unflatten(item_def, new_leaves),
            dest=new_dest,
            count=new_count,
            drops=new_drops,  # queue drops mirror the cumulative carry
        ),
        "aux": aux_fit(old["aux"], R_new),
        "total": np.int32(new_count.sum()),
        "rnd": np.asarray(old["rnd"]).astype(np.int32),
        "drops": new_drops,
        "emitted": _fold_rank_counter(np.asarray(old["emitted"]), R_new),
        "delivered": _fold_rank_counter(np.asarray(old["delivered"]), R_new),
    }
    if retain:
        carry["age"] = new_age
    if cfg.flow == "credit":
        # conservative cold restart: zero credits → the first resumed round
        # is advert-only, exactly like a fresh drive_start (no wire risked
        # against adverts computed for the retired mesh shape)
        carry["credits"] = np.zeros((R_new * R_new,), np.int32)
    if cfg.telemetry:
        ring = TS.make_ring(
            TS.num_tiers(cfg),
            window=cfg.telemetry_window,
            buckets=cfg.telemetry_buckets,
        )
        carry["ring"] = jax.tree.map(
            lambda a: np.zeros((R_new,) + a.shape, a.dtype), ring
        )
    return carry

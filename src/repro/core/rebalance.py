"""Work rebalancing — beyond-paper straggler mitigation on the RaFI core.

The paper notes (§6.3) that RaFI "does not inherently address issues such as
bottlenecks, starvation, or long-tail problems".  This module adds exactly
that, *using the forwarding machinery itself*: given a (possibly wildly
imbalanced) per-rank queue population, compute a balanced target layout and
re-destination the surplus so one ``forward_work`` round equalises load.

Flat strategy (deterministic, collective-free planning):
  * global layout via ``all_gather`` of per-rank resident counts (R ints);
  * target per rank = ceil(total / R);
  * ranks are laid out on a virtual line of cumulative counts; resident item
    ``j`` of the global order moves to rank ``j // target`` — an
    order-preserving balanced re-assignment (comparable to work-stealing,
    but oblivious and single-round, which suits a lock-step SPMD machine).

Topology-aware strategy (``exchange="hierarchical"`` configs): locality-aware
placement — keep traffic on the fast fabric, cross the slow links only with
true surplus.  The plan first equalises within each fastest-axis group (the
"node"), then moves ONLY each group's surplus/deficit across the slower
tiers:

  * groups of ``F = level_sizes[-1]`` ranks keep up to the balanced group
    quota ``ceil(total / num_groups)`` of their own residents, spread
    order-preservingly over their lanes;
  * each group's surplus beyond the quota fills other groups' deficits in
    group order — so a skew confined to one node produces zero cross-node
    item movement, and a cross-node skew moves exactly the surplus.

``scope="intra"`` restricts both the plan AND the forwarding round to the
fastest tier: every collective (the count all_gather, the payload exchange)
binds to the fast axis only, so the lowered program ships ZERO payload bytes
over any slower fabric — the right tool when skew is known to be node-local
(guarded by ``tests/test_core_rebalance.py`` via the per-tier collective
accounting of ``roofline.analysis``).  Pending items addressed within the
group are delivered (their global rank translates to a fast-axis lane);
pending items addressed across groups cannot ride a fast-axis-only round and
stay in the local queue, destination intact, for a later global round.

Items whose destination is already set (``dest >= 0``) are left alone; only
"resident" work (dest == DISCARD after a round, i.e. work the rank would
process locally next round) is rebalanced.  Pending items ride the same
forwarding round to their original destinations.

Cost: one ``forward_work`` round — with the packed wire format that is one
payload collective + one count collective per mesh axis, plus the tiny
all_gather of the plan, so rebalancing every round is cheap enough to use as
a matter of course on skewed workloads.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.forwarding import ForwardConfig, flatten_axis_names, forward_work
from repro.core.queue import DISCARD, WorkQueue, enqueue

__all__ = ["plan_rebalance", "plan_rebalance_hierarchical", "rebalance"]


def _ceil_div(a: jax.Array, b) -> jax.Array:
    return (a + b - 1) // b


def plan_rebalance(count: jax.Array, axis_name, num_ranks: int) -> Tuple[jax.Array, jax.Array]:
    """Per-rank (start, target): my items [0,count) map to global positions
    [start, start+count) and global position j belongs on rank j // target."""
    axes = flatten_axis_names(axis_name)
    counts = jax.lax.all_gather(count, axes)  # (R,)
    me = jax.lax.axis_index(axes)
    start = (jnp.cumsum(counts) - counts)[me]
    total = jnp.sum(counts)
    target = jnp.maximum(_ceil_div(total, num_ranks), 1)
    return start.astype(jnp.int32), target.astype(jnp.int32)


def plan_rebalance_hierarchical(
    count: jax.Array, axis_name, level_sizes: Tuple[int, ...]
) -> dict:
    """The topology-aware plan: one all_gather of per-rank resident counts
    over the joint mesh, from which every rank derives — replicated,
    collective-free — the group quotas, surplus/deficit line, and per-group
    lane targets.

    Returns the plan arrays (all per-GROUP, ``G = R // F`` groups of
    ``F = level_sizes[-1]`` fastest-axis lanes):

      ``start``      my items' global in-GROUP position offset (scalar)
      ``group``      my group index (scalar)
      ``kept``       (G,) residents each group keeps (≤ group quota)
      ``lane_target``(G,) ceil assignment stride inside each group
      ``sur_start``  (G,) exclusive prefix of the groups' surplus line
      ``cum_def``    (G,) inclusive prefix of the groups' deficit slots
    """
    axes = flatten_axis_names(axis_name)
    F = int(level_sizes[-1])
    counts = jax.lax.all_gather(count, axes)  # (R,) lexicographic
    R = counts.shape[0]
    G = R // F
    me = jax.lax.axis_index(axes)
    grp = me // F

    gcnt = counts.reshape(G, F)
    gtot = jnp.sum(gcnt, axis=1)  # (G,) residents per group
    total = jnp.sum(gtot)
    quota = jnp.maximum(_ceil_div(total, G), 1)  # balanced group share
    kept = jnp.minimum(gtot, quota)  # what stays in-group
    surplus = gtot - kept
    deficit = quota - kept
    cum_sur = jnp.cumsum(surplus)
    cum_def = jnp.cumsum(deficit)
    s_total = cum_sur[-1]
    # what each group actually receives: its deficit, first-come in group
    # order, until the global surplus line is exhausted
    recv = jnp.clip(
        jnp.minimum(cum_def, s_total) - jnp.minimum(cum_def - deficit, s_total), 0
    )
    final = kept + recv  # (G,) post-rebalance group population
    lane_target = jnp.maximum(_ceil_div(final, F), 1)

    off = jnp.cumsum(counts) - counts  # (R,) global resident offsets
    start = off[me] - off[grp * F]  # my offset within my group's line
    return {
        "start": start.astype(jnp.int32),
        "group": grp.astype(jnp.int32),
        "kept": kept.astype(jnp.int32),
        "lane_target": lane_target.astype(jnp.int32),
        "sur_start": (cum_sur - surplus).astype(jnp.int32),
        "cum_def": cum_def.astype(jnp.int32),
    }


def _hierarchical_dest(plan: dict, pos: jax.Array, fast_size: int) -> jax.Array:
    """Destination rank for my resident item at in-group position ``pos``."""
    F = fast_size
    g = plan["group"]
    G = plan["kept"].shape[0]
    stay = pos < plan["kept"][g]
    # in-group keepers: order-preserving ceil assignment over the group lanes
    dest_stay = g * F + jnp.minimum(pos // plan["lane_target"][g], F - 1)
    # surplus: position on the global surplus line → deficit slot → group m
    j = plan["sur_start"][g] + (pos - plan["kept"][g])
    m = jnp.clip(jnp.searchsorted(plan["cum_def"], j, side="right"), 0, G - 1)
    k = j - jnp.where(m > 0, plan["cum_def"][m - 1], 0)
    lane = jnp.minimum((plan["kept"][m] + k) // plan["lane_target"][m], F - 1)
    dest_move = m * F + lane
    return jnp.where(stay, dest_stay, dest_move).astype(jnp.int32)


def _resident_positions(q: WorkQueue) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(resident_mask, rank-among-residents per lane, resident count)."""
    lane = jnp.arange(q.capacity, dtype=jnp.int32)
    resident = (lane < q.count) & (q.dest == DISCARD)
    r32 = resident.astype(jnp.int32)
    idx = jnp.cumsum(r32) - r32  # stable order-preserving local index
    return resident, idx, jnp.sum(r32)


def _intra_config(cfg: ForwardConfig) -> ForwardConfig:
    """The fastest-tier sub-mesh as a flat padded config: every collective of
    a round forwarded with it binds to the fast axis only."""
    return ForwardConfig(
        axis_name=cfg.axis_name[-1],
        num_ranks=cfg.level_sizes[-1],
        capacity=cfg.capacity,
        peer_capacity=cfg.level_capacities[-1],
        exchange="padded",
        marshal=cfg.marshal,
        sort_method=cfg.sort_method,
        use_pallas=cfg.use_pallas,
        telemetry=cfg.telemetry,
        telemetry_window=cfg.telemetry_window,
        telemetry_buckets=cfg.telemetry_buckets,
        overflow=cfg.overflow,
        pipeline_shards=cfg.pipeline_shards,
    )


def rebalance(
    q: WorkQueue, cfg: ForwardConfig, *, scope: str = "global", health=None
):
    """One balanced redistribution round.  Must run inside ``shard_map``.

    Only resident items (``dest == DISCARD``) are re-destinated — pending
    items (``dest >= 0``) keep their destinations and ride the same round.
    Returns ``(balanced_queue, total)`` with ``total`` the global in-flight
    count (plus the round's ``RoundStats`` when ``cfg.telemetry`` — an
    intra-scope round records against the fast-axis sub-config's single
    tier).  A ``scope="global"`` call with ``cfg.overflow == "retain"``
    passes ``forward_work``'s retain arity straight through (the per-lane
    ``age`` rides between total and stats); an intra-scope retain round keeps
    its clamp-cut rows local with their GLOBAL destination restored, ages
    restarting (rebalance is an out-of-band round, not part of the aged FIFO
    drive).  After this call every rank holds either ``floor`` or ``ceil`` of
    the mean resident population (subject to the usual capacity clamps) plus
    whatever pending work was addressed to it.

    ``scope``:
      * ``"global"`` — equalise across all ranks.  Hierarchical configs use
        the topology-aware surplus/deficit plan (module docstring): balance
        within each fastest-axis group first, cross slower tiers only with
        true surplus.
      * ``"intra"`` — hierarchical configs only: equalise within each
        fastest-axis group and forward over the fast axis alone; the lowered
        round ships zero payload bytes over any slower fabric.  In-group
        pending items are delivered; cross-group pending items sit the round
        out and keep their destination (see the module docstring).

    ``health`` (global scope only): a replicated ``(R,) bool`` rank mask —
    the plan's destinations AND the ride-along pending destinations are
    re-addressed away from unhealthy ranks via the ``core.health`` remap,
    which is how resident work EVACUATES a draining rank: mark it unhealthy,
    run one health-aware global rebalance, and its queue empties onto the
    survivors while nothing new is routed to it (the ISSUE 7 drain recipe).
    Note the unhealthy rank still participates in the collective (the mesh
    is intact — it is draining, not dead), so the lowered inventory is
    unchanged.
    """
    from repro.obs import trace as OT

    if OT.enabled():
        # trace-time record (this runs under shard_map tracing): static
        # routing facts only — no device values are materialisable here
        OT.event(
            "route.rebalance", OT.CAT_ROUTE,
            scope=scope, exchange=cfg.exchange,
            num_ranks=cfg.num_ranks, health_aware=health is not None,
        )
    resident, idx, n_res = _resident_positions(q)
    if health is not None and scope != "global":
        raise ValueError(
            "health-aware rebalance is global-scope only: an intra round's "
            "rank space is the fast-axis group, where a global health mask "
            "has no meaning"
        )

    if scope == "intra":
        if cfg.exchange != "hierarchical":
            raise ValueError(
                "scope='intra' needs a hierarchical ForwardConfig — a flat "
                "config has no topology to restrict the rebalance to"
            )
        sub = _intra_config(cfg)
        F = sub.num_ranks
        me = jax.lax.axis_index(flatten_axis_names(cfg.axis_name))
        lane = jnp.arange(q.capacity, dtype=jnp.int32)
        # Pending items carry GLOBAL rank destinations but the intra round's
        # rank space is the F fast-axis lanes: in-group pending translate to
        # their lane and are delivered; pending addressed OUTSIDE the group
        # cannot ride a fast-axis-only round, so they sit the round out and
        # are re-appended afterwards with their destination intact (a later
        # global round delivers them).
        pending = (lane < q.count) & (q.dest >= 0)
        in_group = pending & (q.dest // F == me // F)
        held_back = pending & ~in_group
        start, target = plan_rebalance(n_res, sub.axis_name, F)
        plan_dest = jnp.minimum((start + idx) // target, F - 1)
        new_dest = jnp.where(
            resident, plan_dest, jnp.where(in_group, q.dest % F, DISCARD)
        )
        q_round = dataclasses.replace(q, dest=new_dest.astype(jnp.int32))
        res = forward_work(q_round, sub)
        balanced, stats = res[0], (res[-1] if cfg.telemetry else None)
        if sub.overflow == "retain":
            # The sub-round's retained front carries FAST-LANE destinations
            # (its rank space is the F in-group lanes): translate back to
            # global ranks so they coexist with the held-back pending items.
            # Ages are not threaded across rebalance calls — a retained
            # rebalance row re-enters the next round as fresh (age restarts).
            lane = jnp.arange(q.capacity, dtype=jnp.int32)
            ret = (lane < balanced.count) & (balanced.dest >= 0)
            balanced = dataclasses.replace(
                balanced,
                dest=jnp.where(
                    ret, (me // F) * F + balanced.dest, balanced.dest
                ).astype(jnp.int32),
            )
        balanced = enqueue(balanced, q.items, q.dest, held_back)
        total = jax.lax.psum(
            balanced.count, flatten_axis_names(cfg.axis_name)
        )
        if cfg.telemetry:
            return balanced, total, stats
        return balanced, total
    if scope != "global":
        raise ValueError(f"unknown rebalance scope {scope!r}")

    if cfg.exchange == "hierarchical":
        plan = plan_rebalance_hierarchical(n_res, cfg.axis_name, cfg.level_sizes)
        new_dest = _hierarchical_dest(plan, plan["start"] + idx, cfg.level_sizes[-1])
    else:
        start, target = plan_rebalance(n_res, cfg.axis_name, cfg.num_ranks)
        new_dest = jnp.minimum((start + idx) // target, cfg.num_ranks - 1)
    new_dest = jnp.where(resident, new_dest, q.dest).astype(jnp.int32)
    q = dataclasses.replace(q, dest=new_dest)
    return forward_work(q, cfg, health=health)

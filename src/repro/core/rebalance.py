"""Work rebalancing — beyond-paper straggler mitigation on the RaFI core.

The paper notes (§6.3) that RaFI "does not inherently address issues such as
bottlenecks, starvation, or long-tail problems".  This module adds exactly
that, *using the forwarding machinery itself*: given a (possibly wildly
imbalanced) per-rank queue population, compute a balanced target layout and
re-destination the surplus so one ``forward_work`` round equalises load.

Strategy (deterministic, collective-free planning):
  * global layout via ``all_gather`` of per-rank counts (R ints — tiny);
  * target per rank = ceil(total / R);
  * ranks are laid out on a virtual line of cumulative counts; item ``j`` of
    the global order moves to rank ``j // target`` — an order-preserving
    balanced re-assignment (comparable to work-stealing, but oblivious and
    single-round, which suits a lock-step SPMD machine).

Items whose destination is already set (``dest >= 0``) are left alone; only
"resident" work (dest == DISCARD after a round, i.e. work the rank would
process locally next round) is rebalanced.

Cost: one ``forward_work`` round — with the packed wire format that is one
payload collective + one count collective + the R-int all_gather of the
plan, so rebalancing every round is cheap enough to use as a matter of
course on skewed workloads.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.forwarding import ForwardConfig, forward_work
from repro.core.queue import DISCARD, WorkQueue

__all__ = ["plan_rebalance", "rebalance"]


def plan_rebalance(count: jax.Array, axis_name, num_ranks: int) -> Tuple[jax.Array, jax.Array]:
    """Per-rank (start, target): my items [0,count) map to global positions
    [start, start+count) and global position j belongs on rank j // target."""
    counts = jax.lax.all_gather(count, axis_name)  # (R,)
    me = jax.lax.axis_index(axis_name)
    start = (jnp.cumsum(counts) - counts)[me]
    total = jnp.sum(counts)
    target = jnp.maximum((total + num_ranks - 1) // num_ranks, 1)
    return start.astype(jnp.int32), target.astype(jnp.int32)


def rebalance(q: WorkQueue, cfg: ForwardConfig) -> Tuple[WorkQueue, jax.Array]:
    """One balanced redistribution round.  Must run inside ``shard_map``.

    Returns ``(balanced_queue, total)``.  After this call every rank holds
    either ``floor`` or ``ceil`` of the mean population (subject to the usual
    capacity clamps).
    """
    start, target = plan_rebalance(q.count, cfg.axis_name, cfg.num_ranks)
    lane = jnp.arange(q.capacity, dtype=jnp.int32)
    valid = lane < q.count
    new_dest = jnp.where(valid, (start + lane) // target, DISCARD)
    new_dest = jnp.minimum(new_dest, cfg.num_ranks - 1)
    q = WorkQueue(items=q.items, dest=new_dest.astype(jnp.int32), count=q.count, drops=q.drops)
    return forward_work(q, cfg)

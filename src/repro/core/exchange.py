"""Packed-payload exchange — the TPU adaptation of RaFI §4.2.2 (MPI_Alltoallv).

Wire format: the caller packs the whole work-item pytree into ONE
``(capacity, words)`` uint32 buffer (``core.types.pack_payload`` — the
paper's contiguous 44-byte ray).  Every backend moves that single buffer with
a SINGLE payload collective per round, and the send-side marshal composes the
destination-sort permutation with the send-layout gather so the payload is
read exactly once and written exactly once (§4.2.1/§6.1) — there is no
separate "sort the payload, then gather the segments" double pass, and no
per-pytree-leaf collective fan-out.

Collective budget per ``forward_work`` round (guarded by
``tests/test_collective_budget.py``):

  payload   1 × all_to_all (padded) / 1 × ragged_all_to_all (ragged)
  counts    1 × all_to_all of per-peer counts (padded) /
            1 × all_gather of the (R,) send-count vector (ragged — every rank
            reconstructs the full R×R count matrix locally and derives ALL
            offsets/clamps without further communication, replacing the three
            chained count all-to-alls of the naive Alltoallv control plane)

Three interchangeable backends, all called *inside* ``shard_map`` with a
bound mesh axis:

* ``ragged`` — ``ragged_all_to_all``: the exact XLA analogue of
  ``MPI_Alltoallv`` and the TPU production path (single variable-size
  exchange over contiguous per-peer segments — the whole point of sorting
  first).  XLA:CPU cannot execute the op, so on CPU this backend is only
  ``.lower()``-validated; on JAX builds without the op it raises.
* ``padded`` — fixed per-peer slots of size ``peer_capacity`` exchanged with
  a single tiled ``all_to_all`` of the packed buffer.  Portable (runs on
  CPU; used by the dry-run compile) at the cost of padding bandwidth.  This
  is also the natural MoE-dispatch form (capacity-factor semantics).
* ``onehot`` — an all-gather reference oracle with a deliberately different
  code path, used only by tests.

All backends share the contract: inputs are the *unsorted* packed payload
plus the destination-sort permutation and per-destination send counts;
output is a compacted packed receive buffer plus per-peer receive counts.
Segment overflow (sender-side ``> peer_capacity``, or receiver-side total
``> capacity``) is dropped and counted — the queue-capacity contract of
§3.3/§6.3.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat

__all__ = [
    "exchange_counts",
    "exchange_count_matrix",
    "exchange_padded",
    "exchange_ragged",
    "exchange_onehot",
]


def _a2a(x: jax.Array, axis_name) -> jax.Array:
    """all_to_all over leading axis: out[p] = what peer p sent me (block p)."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


def exchange_counts(send_counts: jax.Array, axis_name) -> jax.Array:
    """§4.2.2 step 2 — MPI_Alltoall of per-peer counts.

    ``send_counts``: (R,) — how many items *I* send to each peer.
    Returns (R,): how many items each peer sends *me*.
    """
    return _a2a(send_counts[:, None], axis_name).reshape(-1)


def exchange_count_matrix(send_counts: jax.Array, axis_name) -> jax.Array:
    """All-gather the per-rank send-count vectors into the full (R, R) count
    matrix ``M[s, d] = items s sends to d``.

    One tiny collective (R² int32 — 256 KiB even at R=256) buys the ENTIRE
    ragged control plane: every rank derives every rank's receive layout,
    capacity clamps, and landing offsets locally, so no chained count
    exchanges are needed before the payload collective.
    """
    return jax.lax.all_gather(send_counts, axis_name)


def _ragged_control_plane(
    cnt: jax.Array, me: jax.Array, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """From the (R_src, R_dst) count matrix, derive my ragged-a2a parameters.

    Receiver-capacity clamp, replicated identically on all ranks: at each
    destination column ``d`` the senders' segments land at the exclusive
    prefix of the column; any segment (or segment tail) past ``capacity`` is
    cut — the §3.3 drop rule, decided without a round trip.

    Returns ``(send_sizes (R,), output_offsets (R,), recv_sizes (R,))``.
    """
    roff_raw = jnp.cumsum(cnt, axis=0) - cnt  # excl. prefix per dst column
    allowed = jnp.clip(jnp.minimum(cnt, capacity - roff_raw), 0)
    roff = jnp.cumsum(allowed, axis=0) - allowed
    send_sizes = allowed[me]  # my row: what each peer lets me deliver
    output_offsets = roff[me]  # where my block lands on each peer
    recv_sizes = allowed[:, me]  # my column: what each peer delivers to me
    return send_sizes, output_offsets, recv_sizes


def exchange_padded(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,  # (C,) destination-sort permutation (sorted pos → lane)
    send_counts: jax.Array,  # (R,) valid-destination counts (histogram[:R])
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Padded-slot exchange of the packed payload.

    Single-pass marshal: the send buffer row for (peer r, slot s) is
    ``packed[perm[off[r] + s]]`` — destination sort and slot layout composed
    into ONE gather, so the payload is read once and written once on the send
    side.  Returns ``(recv_packed, recv_counts, total, drops)``.
    """
    R, S = num_ranks, peer_capacity
    cap = packed.shape[0]
    clamped = jnp.minimum(send_counts, S)
    send_drops = jnp.sum(send_counts - clamped)
    off = jnp.cumsum(send_counts) - send_counts  # segment starts, sorted order

    r_idx = jnp.repeat(jnp.arange(R, dtype=jnp.int32), S)
    s_idx = jnp.tile(jnp.arange(S, dtype=jnp.int32), R)
    slotpos = jnp.clip(off[r_idx] + s_idx, 0, cap - 1)  # position in sorted order
    src = jnp.take(perm, slotpos)  # compose with the sort → source lane
    if use_pallas:
        from repro.kernels.marshal import ops as marshal_ops

        send_buf = marshal_ops.fused_marshal(packed, src, num_ranks=R, slot=S)
    else:
        send_buf = jnp.take(packed, src, axis=0).reshape(R, S, -1)

    recv_counts = exchange_counts(clamped, axis_name)  # the ONE count collective
    recv_buf = _a2a(send_buf, axis_name)  # the ONE payload collective

    # Compact: out[roff[p] + s] = recv_buf[p, s] for s < recv_counts[p].
    roff = jnp.cumsum(recv_counts) - recv_counts
    if use_pallas:
        from repro.kernels.marshal import ops as marshal_ops

        out = marshal_ops.fused_unmarshal(recv_buf, roff, recv_counts, capacity=capacity)
    else:
        dstpos = roff[r_idx] + s_idx
        ok = s_idx < recv_counts[r_idx]
        slot = jnp.where(ok & (dstpos < capacity), dstpos, capacity)
        out = jnp.zeros((capacity, packed.shape[1]), packed.dtype)
        out = out.at[slot].set(recv_buf.reshape(R * S, -1), mode="drop")

    total_recv = jnp.sum(recv_counts)
    new_count = jnp.minimum(total_recv, capacity)
    recv_drops = total_recv - new_count
    return out, recv_counts, new_count, send_drops + recv_drops


def exchange_ragged(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,
    send_counts: jax.Array,  # (R,)
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,  # unused; signature parity
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """ragged_all_to_all exchange — the MPI_Alltoallv / GPU-RDMA analogue.

    The packed payload is permuted ONCE into destination order (contiguous
    per-peer segments) and shipped in ONE variable-size collective; the
    receive side is written compacted directly (no unpack pass), which is the
    paper's "large contiguous blocks at very high bandwidth" property.  The
    control plane is one all-gather of the send-count vector (see
    :func:`exchange_count_matrix`).
    """
    del peer_capacity, use_pallas  # segments are contiguous: no slot gather
    me = jax.lax.axis_index(axis_name)
    off = jnp.cumsum(send_counts) - send_counts

    cnt = exchange_count_matrix(send_counts, axis_name)  # the ONE count collective
    send_sizes, output_offsets, recv_sizes = _ragged_control_plane(cnt, me, capacity)
    send_drops = jnp.sum(send_counts - send_sizes)

    sorted_packed = jnp.take(packed, perm, axis=0)  # the ONE payload permute
    out = jnp.zeros((capacity, packed.shape[1]), packed.dtype)
    out = compat.ragged_all_to_all(  # the ONE payload collective
        sorted_packed,
        out,
        input_offsets=off,
        send_sizes=send_sizes,
        output_offsets=output_offsets,
        recv_sizes=recv_sizes,
        axis_name=axis_name,
    )
    new_count = jnp.sum(recv_sizes)
    return out, recv_sizes, new_count, send_drops


def exchange_onehot(
    packed: jax.Array,
    perm: jax.Array,
    send_counts: jax.Array,
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """All-gather reference oracle (tests only): every rank sees everything,
    selects what is addressed to it, and compacts stably by (source, lane).
    Deliberately a different code path from the production backends.
    """
    del peer_capacity, use_pallas
    R = num_ranks
    me = jax.lax.axis_index(axis_name)
    off = jnp.cumsum(send_counts) - send_counts
    cap = packed.shape[0]
    sorted_packed = jnp.take(packed, perm, axis=0)
    lane = jnp.arange(cap, dtype=jnp.int32)
    # reconstruct per-item dest from segments: dest[i] = r iff off[r] <= i < off[r]+cnt
    seg_end = off + send_counts
    dest = jnp.sum((lane[:, None] >= seg_end[None, :]).astype(jnp.int32), axis=1)
    dest = jnp.where(lane < jnp.sum(send_counts), dest, R)

    all_packed = jax.lax.all_gather(sorted_packed, axis_name)  # (R, cap, W)
    all_dest = jax.lax.all_gather(dest, axis_name)  # (R, cap)
    mine = (all_dest == me).reshape(-1)
    order = jnp.argsort(~mine, stable=True)  # mine first, stable (src, lane) order
    flat = all_packed.reshape(R * cap, -1)
    gathered = jnp.take(flat, order[:capacity], axis=0, mode="clip")
    total = jnp.sum(mine.astype(jnp.int32))
    new_count = jnp.minimum(total, capacity)
    recv_counts = jnp.sum((all_dest == me).astype(jnp.int32), axis=1)
    return gathered, recv_counts, new_count, total - new_count

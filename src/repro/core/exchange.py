"""Packed-payload exchange — the TPU adaptation of RaFI §4.2.2 (MPI_Alltoallv).

Wire format: the caller packs the whole work-item pytree into ONE
``(capacity, words)`` uint32 buffer (``core.types.pack_payload`` — the
paper's contiguous 44-byte ray).  Every backend moves that single buffer with
a SINGLE payload collective per round, and the send-side marshal composes the
destination-sort permutation with the send-layout gather so the payload is
read exactly once and written exactly once (§4.2.1/§6.1) — there is no
separate "sort the payload, then gather the segments" double pass, and no
per-pytree-leaf collective fan-out.

Collective budget per ``forward_work`` round (guarded by
``tests/test_collective_budget.py``):

  payload   1 × all_to_all (padded) / 1 × ragged_all_to_all (ragged) /
            2 × all_to_all (hierarchical: one per mesh axis — see below)
  counts    1 × all_to_all of per-peer counts (padded) /
            1 × all_gather of the (R,) send-count vector (ragged — every rank
            reconstructs the full R×R count matrix locally and derives ALL
            offsets/clamps without further communication, replacing the three
            chained count all-to-alls of the naive Alltoallv control plane) /
            2 × tiny all_to_all (hierarchical: one per mesh axis)

The ``(slow, fast)`` contract (hierarchical backend): ``axis_name`` is a
2-tuple of mesh axis names, slow first — e.g. ``("node", "device")`` where
"node" spans the inter-node (DCN-class) fabric and "device" the fast
intra-node fabric (ICI/NVLink).  Global ranks are node-major
(``rank = node * fast_size + lane``, i.e. ``jax.lax.axis_index((slow,
fast))``), and the round runs in two stages:

  stage A  one padded all_to_all over the FAST axis: each rank ships, per
           fast peer ``f``, the node-major concatenation of its (dest_node,
           dest_lane == f) sub-segments.  Afterwards rank ``(n, f)`` holds
           exactly the rows of node ``n`` bound for its "column" — lane ``f``
           of every destination node — already grouped per node.
  stage B  ONE padded all_to_all over the SLOW axis: the per-node aggregated
           segments (``node_capacity`` rows each) move inter-node in a single
           collective; a local unpermute delivers final placement.

All bulk bytes cross the slow fabric exactly once, and the slow-axis padding
is per-NODE segment, not per-rank slot — with R ranks over N nodes that is an
R/N× reduction in worst-case slow-link padding waste versus routing the flat
padded exchange across nodes.

Four interchangeable backends, all called *inside* ``shard_map`` with a
bound mesh axis:

* ``ragged`` — ``ragged_all_to_all``: the exact XLA analogue of
  ``MPI_Alltoallv`` and the TPU production path (single variable-size
  exchange over contiguous per-peer segments — the whole point of sorting
  first).  XLA:CPU cannot execute the op, so on CPU this backend is only
  ``.lower()``-validated; on JAX builds without the op it raises.
* ``padded`` — fixed per-peer slots of size ``peer_capacity`` exchanged with
  a single tiled ``all_to_all`` of the packed buffer.  Portable (runs on
  CPU; used by the dry-run compile) at the cost of padding bandwidth.  This
  is also the natural MoE-dispatch form (capacity-factor semantics).
* ``hierarchical`` — the two-stage padded exchange over a 2-D ``(slow,
  fast)`` mesh described above: fast-axis combine, then one slow-axis
  collective.  Placement is bit-identical to the flat backends (node-major
  rank order is preserved end to end).
* ``onehot`` — an all-gather reference oracle with a deliberately different
  code path, used only by tests.

All backends share the contract: inputs are the *unsorted* packed payload
plus the destination-sort permutation and per-destination send counts;
output is a compacted packed receive buffer plus per-peer receive counts.
Segment overflow (sender-side ``> peer_capacity``, or receiver-side total
``> capacity``) is dropped and counted — the queue-capacity contract of
§3.3/§6.3.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat

__all__ = [
    "exchange_counts",
    "exchange_count_matrix",
    "exchange_padded",
    "exchange_ragged",
    "exchange_hierarchical",
    "exchange_onehot",
]


def _a2a(x: jax.Array, axis_name) -> jax.Array:
    """all_to_all over leading axis: out[p] = what peer p sent me (block p)."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


def exchange_counts(send_counts: jax.Array, axis_name) -> jax.Array:
    """§4.2.2 step 2 — MPI_Alltoall of per-peer counts.

    ``send_counts``: (R,) — how many items *I* send to each peer.
    Returns (R,): how many items each peer sends *me*.
    """
    return _a2a(send_counts[:, None], axis_name).reshape(-1)


def exchange_count_matrix(send_counts: jax.Array, axis_name) -> jax.Array:
    """All-gather the per-rank send-count vectors into the full (R, R) count
    matrix ``M[s, d] = items s sends to d``.

    One tiny collective (R² int32 — 256 KiB even at R=256) buys the ENTIRE
    ragged control plane: every rank derives every rank's receive layout,
    capacity clamps, and landing offsets locally, so no chained count
    exchanges are needed before the payload collective.
    """
    return jax.lax.all_gather(send_counts, axis_name)


def _clamp_subsegments(cnt: jax.Array, slot: int) -> Tuple[jax.Array, jax.Array]:
    """Truncate stacked sub-segments (rows of ``cnt``, concatenated in row
    order) to a ``slot``-row budget per column.

    ``cnt[i, j]``: rows of sub-segment ``i`` bound for slot column ``j``.
    Returns ``(allowed, starts)`` with the same shape: ``allowed`` keeps a
    contiguous prefix of each column's concatenation (any segment or segment
    tail past ``slot`` is cut — the §3.3 drop rule), ``starts`` is where each
    surviving sub-segment begins inside its slot.
    """
    raw_pref = jnp.cumsum(cnt, axis=0) - cnt
    allowed = jnp.clip(jnp.minimum(cnt, slot - raw_pref), 0)
    starts = jnp.cumsum(allowed, axis=0) - allowed
    return allowed, starts


def _ragged_control_plane(
    cnt: jax.Array, me: jax.Array, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """From the (R_src, R_dst) count matrix, derive my ragged-a2a parameters.

    Receiver-capacity clamp, replicated identically on all ranks: at each
    destination column ``d`` the senders' segments land at the exclusive
    prefix of the column; any segment (or segment tail) past ``capacity`` is
    cut — the §3.3 drop rule (:func:`_clamp_subsegments`), decided without a
    round trip.

    Returns ``(send_sizes (R,), output_offsets (R,), recv_sizes (R,))``.
    """
    allowed, roff = _clamp_subsegments(cnt, capacity)
    send_sizes = allowed[me]  # my row: what each peer lets me deliver
    output_offsets = roff[me]  # where my block lands on each peer
    recv_sizes = allowed[:, me]  # my column: what each peer delivers to me
    return send_sizes, output_offsets, recv_sizes


def _compact_blocks(
    recv_buf: jax.Array,  # (G, S, W) received padded blocks
    recv_counts: jax.Array,  # (G,) valid rows per block
    capacity: int,
    *,
    use_pallas: bool,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Receive-side compaction shared by the padded-slot exchanges:
    ``out[roff[g] + s] = recv_buf[g, s]`` for ``s < recv_counts[g]``, rows
    past ``capacity`` dropped (§3.3).  Returns ``(out, new_count, drops)``.
    """
    G, S, W = recv_buf.shape
    roff = jnp.cumsum(recv_counts) - recv_counts
    if use_pallas:
        from repro.kernels.marshal import ops as marshal_ops

        out = marshal_ops.fused_unmarshal(recv_buf, roff, recv_counts, capacity=capacity)
    else:
        g_idx = jnp.repeat(jnp.arange(G, dtype=jnp.int32), S)
        s_idx = jnp.tile(jnp.arange(S, dtype=jnp.int32), G)
        dstpos = roff[g_idx] + s_idx
        ok = s_idx < recv_counts[g_idx]
        slot = jnp.where(ok & (dstpos < capacity), dstpos, capacity)
        out = jnp.zeros((capacity, W), recv_buf.dtype)
        out = out.at[slot].set(recv_buf.reshape(G * S, W), mode="drop")
    total_recv = jnp.sum(recv_counts)
    new_count = jnp.minimum(total_recv, capacity)
    return out, new_count, total_recv - new_count


def exchange_padded(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,  # (C,) destination-sort permutation (sorted pos → lane)
    send_counts: jax.Array,  # (R,) valid-destination counts (histogram[:R])
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Padded-slot exchange of the packed payload.

    Single-pass marshal: the send buffer row for (peer r, slot s) is
    ``packed[perm[off[r] + s]]`` — destination sort and slot layout composed
    into ONE gather, so the payload is read once and written once on the send
    side.  Returns ``(recv_packed, recv_counts, total, drops)``.
    """
    R, S = num_ranks, peer_capacity
    cap = packed.shape[0]
    clamped = jnp.minimum(send_counts, S)
    send_drops = jnp.sum(send_counts - clamped)
    off = jnp.cumsum(send_counts) - send_counts  # segment starts, sorted order

    r_idx = jnp.repeat(jnp.arange(R, dtype=jnp.int32), S)
    s_idx = jnp.tile(jnp.arange(S, dtype=jnp.int32), R)
    slotpos = jnp.clip(off[r_idx] + s_idx, 0, cap - 1)  # position in sorted order
    src = jnp.take(perm, slotpos)  # compose with the sort → source lane
    if use_pallas:
        from repro.kernels.marshal import ops as marshal_ops

        send_buf = marshal_ops.fused_marshal(packed, src, num_ranks=R, slot=S)
    else:
        send_buf = jnp.take(packed, src, axis=0).reshape(R, S, -1)

    recv_counts = exchange_counts(clamped, axis_name)  # the ONE count collective
    recv_buf = _a2a(send_buf, axis_name)  # the ONE payload collective

    out, new_count, recv_drops = _compact_blocks(
        recv_buf, recv_counts, capacity, use_pallas=use_pallas
    )
    return out, recv_counts, new_count, send_drops + recv_drops


def _subsegment_gather(
    allowed: jax.Array,  # (G, K) surviving sub-segment sizes per slot column k
    starts: jax.Array,  # (G, K) slot-local sub-segment starts
    src_base: jax.Array,  # (G, K) source offset of sub-segment (g, k)
    slot: int,
) -> jax.Array:
    """Source row index for every (slot column k, slot position s).

    Returns ``(K, slot)`` int32: the flat source row feeding slot ``k``'s
    position ``s`` — rows past a column's total are clamped garbage, masked
    downstream by the exchanged counts.  This is the composed two-stage
    layout: one gather materialises a whole stage's send buffer.
    """
    G, K = allowed.shape
    s_idx = jnp.arange(slot, dtype=jnp.int32)
    incl = jnp.cumsum(allowed, axis=0)  # (G, K) inclusive prefix per column
    # sub-segment owning position s = number of fully-completed predecessors
    g_of = jnp.sum(s_idx[None, :, None] >= incl.T[:, None, :], axis=-1)  # (K, slot)
    g_c = jnp.clip(g_of, 0, G - 1)
    k_grid = jnp.arange(K, dtype=jnp.int32)[:, None]
    s_local = s_idx[None, :] - starts[g_c, k_grid]
    return src_base[g_c, k_grid] + s_local


def exchange_hierarchical(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,  # (C,) node-major destination-sort permutation
    send_counts: jax.Array,  # (R,) valid-destination counts, node-major
    *,
    axis_name,  # (slow, fast) mesh axis names
    num_ranks: int,
    capacity: int,
    peer_capacity: int,  # stage-A rows per fast-axis peer slot
    node_capacity: int,  # stage-B rows per destination-node segment
    fast_size: int,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Two-stage packed exchange over a 2-D ``(slow, fast)`` mesh.

    Stage A combines traffic within the fast axis (rank ``(n, f)`` ends up
    holding node ``n``'s rows bound for lane ``f`` of every node, grouped per
    node); stage B moves the aggregated per-node segments with ONE padded
    collective over the slow axis; a local unpermute delivers final placement
    in node-major source order — bit-identical to the flat backends.

    Budget: 2 payload collectives + 2 count collectives per round; bulk bytes
    cross the slow axis exactly once, padded per NODE (``node_capacity``
    rows), never per rank.  Returns ``(recv_packed, recv_node_counts, total,
    drops)`` — counts are per *source node* (the slow-axis peers), unlike the
    flat backends' per-rank counts.
    """
    slow_ax, fast_ax = axis_name
    F, S_a, S_b = fast_size, peer_capacity, node_capacity
    N = num_ranks // F
    C, W = packed.shape

    def gather(buf, rows, n_slots, slot):
        if use_pallas:
            from repro.kernels.marshal import ops as marshal_ops

            return marshal_ops.fused_marshal(buf, rows, num_ranks=n_slots, slot=slot)
        return jnp.take(buf, rows, axis=0).reshape(n_slots, slot, W)

    cnt = send_counts.reshape(N, F)  # [dest_node, dest_lane]
    off = (jnp.cumsum(send_counts) - send_counts).reshape(N, F)  # sorted-order starts

    # ---- stage A: fast-peer slot f = node-major sub-segments (n, f)
    if F == 1:
        # degenerate fast axis: stage A is the identity — no clamp, no
        # collective, no payload pass.  The sort permutation is composed
        # straight into the stage-B gather below instead.
        rcv_a = cnt.T  # (1, N)
        in_starts = off.T
        stage_b_rows = lambda pos: jnp.take(perm, jnp.clip(pos, 0, C - 1))
        flat_a = packed
        drops_a = jnp.zeros((), send_counts.dtype)
    else:
        allowed_a, starts_a = _clamp_subsegments(cnt, S_a)  # both (N, F)
        drops_a = jnp.sum(cnt - allowed_a)
        sortedpos = _subsegment_gather(allowed_a, starts_a, off, S_a)  # (F, S_a)
        src_a = jnp.take(perm, jnp.clip(sortedpos, 0, C - 1).reshape(-1))
        send_a = gather(packed, src_a, F, S_a)
        # count collective 1 (fast axis): per-dest-node survivor counts, so
        # the receiver can address every sub-segment of each incoming block
        rcv_a = _a2a(allowed_a.T, fast_ax)  # (F, N): from src lane f, for node n
        recv_a = _a2a(send_a, fast_ax)  # payload collective 1 (fast axis)
        in_starts = jnp.cumsum(rcv_a, axis=1) - rcv_a  # (F, N) offsets in block f
        in_starts = in_starts + jnp.arange(F, dtype=jnp.int32)[:, None] * S_a
        stage_b_rows = lambda pos: jnp.clip(pos, 0, F * S_a - 1)
        flat_a = recv_a.reshape(F * S_a, W)

    # ---- stage B: node slot n = lane-major sub-segments out of stage A
    if N == 1:
        # degenerate slow axis: stage B is the identity — clamp at receiver
        # capacity and compact straight out of the stage-A buffer (this keeps
        # the single-node cost at flat-exchange parity, the --compare gate)
        allowed_b, starts_b = _clamp_subsegments(rcv_a, capacity)
        drops_b = jnp.sum(rcv_a - allowed_b)
        src_b = stage_b_rows(
            _subsegment_gather(allowed_b, starts_b, in_starts, capacity).reshape(-1)
        )
        out = gather(flat_a, src_b, 1, capacity)[0]
        recv_counts = jnp.sum(allowed_b)[None]
        return out, recv_counts, recv_counts[0], drops_a + drops_b

    allowed_b, starts_b = _clamp_subsegments(rcv_a, S_b)  # both (F, N)
    drops_b = jnp.sum(rcv_a - allowed_b)
    src_b = stage_b_rows(
        _subsegment_gather(allowed_b, starts_b, in_starts, S_b).reshape(-1)
    )
    send_b = gather(flat_a, src_b, N, S_b)

    # count collective 2 (slow axis) + payload collective 2 (slow axis): the
    # ONLY bulk bytes crossing the inter-node fabric, padded per node
    recv_counts = _a2a(jnp.sum(allowed_b, axis=0)[:, None], slow_ax).reshape(-1)
    recv_b = _a2a(send_b, slow_ax)

    # Compact: blocks arrive node-major, sub-segments lane-major inside each —
    # global source-rank order, so placement matches the flat backends.
    out, new_count, recv_drops = _compact_blocks(
        recv_b, recv_counts, capacity, use_pallas=use_pallas
    )
    return out, recv_counts, new_count, drops_a + drops_b + recv_drops


def exchange_ragged(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,
    send_counts: jax.Array,  # (R,)
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,  # unused; signature parity
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """ragged_all_to_all exchange — the MPI_Alltoallv / GPU-RDMA analogue.

    The packed payload is permuted ONCE into destination order (contiguous
    per-peer segments) and shipped in ONE variable-size collective; the
    receive side is written compacted directly (no unpack pass), which is the
    paper's "large contiguous blocks at very high bandwidth" property.  The
    control plane is one all-gather of the send-count vector (see
    :func:`exchange_count_matrix`).
    """
    del peer_capacity, use_pallas  # segments are contiguous: no slot gather
    me = jax.lax.axis_index(axis_name)
    off = jnp.cumsum(send_counts) - send_counts

    cnt = exchange_count_matrix(send_counts, axis_name)  # the ONE count collective
    send_sizes, output_offsets, recv_sizes = _ragged_control_plane(cnt, me, capacity)
    send_drops = jnp.sum(send_counts - send_sizes)

    sorted_packed = jnp.take(packed, perm, axis=0)  # the ONE payload permute
    out = jnp.zeros((capacity, packed.shape[1]), packed.dtype)
    out = compat.ragged_all_to_all(  # the ONE payload collective
        sorted_packed,
        out,
        input_offsets=off,
        send_sizes=send_sizes,
        output_offsets=output_offsets,
        recv_sizes=recv_sizes,
        axis_name=axis_name,
    )
    new_count = jnp.sum(recv_sizes)
    return out, recv_sizes, new_count, send_drops


def exchange_onehot(
    packed: jax.Array,
    perm: jax.Array,
    send_counts: jax.Array,
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """All-gather reference oracle (tests only): every rank sees everything,
    selects what is addressed to it, and compacts stably by (source, lane).
    Deliberately a different code path from the production backends.
    """
    del peer_capacity, use_pallas
    R = num_ranks
    me = jax.lax.axis_index(axis_name)
    off = jnp.cumsum(send_counts) - send_counts
    cap = packed.shape[0]
    sorted_packed = jnp.take(packed, perm, axis=0)
    lane = jnp.arange(cap, dtype=jnp.int32)
    # reconstruct per-item dest from segments: dest[i] = r iff off[r] <= i < off[r]+cnt
    seg_end = off + send_counts
    dest = jnp.sum((lane[:, None] >= seg_end[None, :]).astype(jnp.int32), axis=1)
    dest = jnp.where(lane < jnp.sum(send_counts), dest, R)

    all_packed = jax.lax.all_gather(sorted_packed, axis_name)  # (R, cap, W)
    all_dest = jax.lax.all_gather(dest, axis_name)  # (R, cap)
    mine = (all_dest == me).reshape(-1)
    order = jnp.argsort(~mine, stable=True)  # mine first, stable (src, lane) order
    flat = all_packed.reshape(R * cap, -1)
    gathered = jnp.take(flat, order[:capacity], axis=0, mode="clip")
    total = jnp.sum(mine.astype(jnp.int32))
    new_count = jnp.minimum(total, capacity)
    recv_counts = jnp.sum((all_dest == me).astype(jnp.int32), axis=1)
    return gathered, recv_counts, new_count, total - new_count

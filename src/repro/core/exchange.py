"""Payload exchange — the TPU adaptation of RaFI §4.2.2 (MPI_Alltoallv).

Three interchangeable backends, all called *inside* ``shard_map`` with a bound
mesh axis:

* ``ragged`` — ``jax.lax.ragged_all_to_all``: the exact XLA analogue of
  ``MPI_Alltoallv`` and the TPU production path (single variable-size
  exchange over contiguous per-peer segments — the whole point of sorting
  first).  XLA:CPU cannot execute the op (verified UNIMPLEMENTED), so on CPU
  this backend is only ``.lower()``-validated.
* ``padded`` — fixed per-peer slots of size ``peer_capacity`` exchanged with a
  single tiled ``jax.lax.all_to_all``.  Portable (runs on CPU; used by the
  dry-run compile) at the cost of padding bandwidth.  This is also the
  natural MoE-dispatch form (capacity-factor semantics).
* ``onehot`` — an all-gather reference oracle with a deliberately different
  code path, used only by tests.

All backends share the contract: input items are *sorted by destination*
(contiguous per-peer segments, offsets = exclusive-cumsum of counts); output
is a compacted receive buffer plus per-peer receive counts.  Segment overflow
(sender-side ``> peer_capacity``, or receiver-side total ``> capacity``) is
dropped and counted — the queue-capacity contract of §3.3/§6.3.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import types as T

__all__ = ["exchange_counts", "exchange_padded", "exchange_ragged", "exchange_onehot"]


def _a2a(x: jax.Array, axis_name) -> jax.Array:
    """all_to_all over leading axis: out[p] = what peer p sent me (block p)."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


def exchange_counts(send_counts: jax.Array, axis_name) -> jax.Array:
    """§4.2.2 step 2 — MPI_Alltoall of per-peer counts.

    ``send_counts``: (R,) — how many items *I* send to each peer.
    Returns (R,): how many items each peer sends *me*.
    """
    return _a2a(send_counts[:, None], axis_name).reshape(-1)


def exchange_padded(
    sorted_items: Any,
    send_counts: jax.Array,  # (R,) valid-destination counts (histogram[:R])
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int,
) -> Tuple[Any, jax.Array, jax.Array, jax.Array]:
    """Padded-slot exchange. Returns (recv_items, recv_counts, total, drops)."""
    R, S = num_ranks, peer_capacity
    clamped = jnp.minimum(send_counts, S)
    send_drops = jnp.sum(send_counts - clamped)
    off = jnp.cumsum(send_counts) - send_counts  # segment starts in sorted buffer

    # Marshal: gather each peer's segment into its fixed (S,) slot.  src index
    # for (peer r, slot s) is off[r] + s; lanes s >= clamped[r] carry garbage
    # that the receiver masks out via counts.
    r_idx = jnp.repeat(jnp.arange(R, dtype=jnp.int32), S)
    s_idx = jnp.tile(jnp.arange(S, dtype=jnp.int32), R)
    src = off[r_idx] + s_idx
    send_buf = T.tree_take(sorted_items, src)  # leaves (R*S, ...)

    recv_counts = exchange_counts(clamped, axis_name)  # (R,)
    recv_buf = jax.tree.map(
        lambda a: _a2a(a.reshape((R, S) + a.shape[1:]), axis_name), send_buf
    )  # leaves (R, S, ...): block p = segment from peer p

    # Compact: out[roff[p] + s] = recv_buf[p, s] for s < recv_counts[p].
    roff = jnp.cumsum(recv_counts) - recv_counts
    dstpos = roff[r_idx] + s_idx
    ok = s_idx < recv_counts[r_idx]
    slot = jnp.where(ok & (dstpos < capacity), dstpos, capacity)
    out = T.batched_zeros(jax.tree.map(lambda a: a[0], sorted_items), capacity)
    flat_recv = jax.tree.map(lambda a: a.reshape((R * S,) + a.shape[2:]), recv_buf)
    out = T.tree_scatter(out, slot, flat_recv, capacity=capacity)

    total_recv = jnp.sum(recv_counts)
    new_count = jnp.minimum(total_recv, capacity)
    recv_drops = total_recv - new_count
    return out, recv_counts, new_count, send_drops + recv_drops


def exchange_ragged(
    sorted_items: Any,
    send_counts: jax.Array,  # (R,)
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,  # unused; signature parity
) -> Tuple[Any, jax.Array, jax.Array, jax.Array]:
    """ragged_all_to_all exchange — the MPI_Alltoallv / GPU-RDMA analogue.

    Contiguous per-peer segments go out in ONE variable-size collective; the
    receive side is written compacted directly (no unpack pass), which is the
    paper's "large contiguous blocks at very high bandwidth" property.
    """
    del peer_capacity
    R = num_ranks
    off = jnp.cumsum(send_counts) - send_counts

    # Receiver-capacity clamp: compute receive layout first, clamp segments to
    # fit ``capacity``, and tell senders the allowed sizes (one tiny a2a).
    recv_counts_raw = exchange_counts(send_counts, axis_name)
    roff_raw = jnp.cumsum(recv_counts_raw) - recv_counts_raw
    allowed_recv = jnp.clip(jnp.minimum(recv_counts_raw, capacity - roff_raw), 0)
    roff = jnp.cumsum(allowed_recv) - allowed_recv
    allowed_send = exchange_counts(allowed_recv, axis_name)  # my clamped send sizes
    output_offsets = exchange_counts(roff, axis_name)  # where my block lands on peer r
    send_drops = jnp.sum(send_counts - allowed_send)

    proto = jax.tree.map(lambda a: a[0], sorted_items)
    out = T.batched_zeros(proto, capacity)
    out = jax.tree.map(
        lambda op, o: jax.lax.ragged_all_to_all(
            op,
            o,
            input_offsets=off,
            send_sizes=allowed_send,
            output_offsets=output_offsets,
            recv_sizes=allowed_recv,
            axis_name=axis_name,
        ),
        sorted_items,
        out,
    )
    new_count = jnp.sum(allowed_recv)
    return out, allowed_recv, new_count, send_drops


def exchange_onehot(
    sorted_items: Any,
    send_counts: jax.Array,
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,
) -> Tuple[Any, jax.Array, jax.Array, jax.Array]:
    """All-gather reference oracle (tests only): every rank sees everything,
    selects what is addressed to it, and compacts stably by (source, lane).
    Deliberately a different code path from the production backends.
    """
    del peer_capacity
    R = num_ranks
    me = jax.lax.axis_index(axis_name)
    off = jnp.cumsum(send_counts) - send_counts
    cap = jax.tree.leaves(sorted_items)[0].shape[0]
    lane = jnp.arange(cap, dtype=jnp.int32)
    # reconstruct per-item dest from segments: dest[i] = r iff off[r] <= i < off[r]+cnt
    seg_end = off + send_counts
    dest = jnp.sum((lane[:, None] >= seg_end[None, :]).astype(jnp.int32), axis=1)
    dest = jnp.where(lane < jnp.sum(send_counts), dest, R)

    all_items = jax.tree.map(lambda a: jax.lax.all_gather(a, axis_name), sorted_items)
    all_dest = jax.lax.all_gather(dest, axis_name)  # (R, cap)
    mine = (all_dest == me).reshape(-1)
    order = jnp.argsort(~mine, stable=True)  # mine first, stable (src, lane) order
    flat = jax.tree.map(lambda a: a.reshape((R * cap,) + a.shape[2:]), all_items)
    gathered = T.tree_take(flat, order[:capacity])
    total = jnp.sum(mine.astype(jnp.int32))
    new_count = jnp.minimum(total, capacity)
    recv_counts = jnp.sum((all_dest == me).astype(jnp.int32), axis=1)
    return gathered, recv_counts, new_count, total - new_count

"""Packed-payload exchange — the TPU adaptation of RaFI §4.2.2 (MPI_Alltoallv).

Wire format: the caller packs the whole work-item pytree into ONE
``(capacity, words)`` uint32 buffer (``core.types.pack_payload`` — the
paper's contiguous 44-byte ray).  Every backend moves that single buffer with
a SINGLE payload collective per round (per mesh axis; per micro-shard under
pipelining — see below), and the send-side marshal is ONE payload pass
(§4.2.1/§6.1) in either of two bit-exact modes:

* ``marshal="sort"`` — the destination-sort permutation is composed with the
  send-layout gather (``packed[perm[off[r] + s]]``): no separate "sort the
  payload, then gather the segments" double pass;
* ``marshal="scatter"`` — sort-free: the caller supplies the counting-sort
  plan (``dest_clean``, in-bucket ``dest_rank`` — one cheap pass over the
  destination vector, ``core.sorting.destination_rank``) and each packed row
  is scattered straight to its send-layout slot ``base[dest] + rank``.  No
  keys, no O(C log C) sort, and the histogram IS the send-count vector.

Both modes place items identically (the scatter reproduces the sort's
lexicographic stable source order), and neither fans out per pytree leaf.
The marshal law, alongside the collective budget below: ONE payload pass per
round pre-collective, whichever mode runs.

Since ISSUE 8 the backends are THIN COMPOSITIONS of the stage objects in
``core.stages`` (SpillExtract → Marshal → CountExchange → PayloadExchange →
Unmarshal over an explicit ``RoundState``): the marshal/clamp/spill/compact
arithmetic lives there exactly once, shared by every backend.  The same
layer supplies the overlap law: ``pipeline_shards=S`` splits each exchange's
per-peer slot rows into S micro-shards whose send/recv chains are issued
interleaved (``stages.Pipelined``) — S payload + S count collectives per
mesh axis, payload wire bytes exactly conserved, placement bit-exact with
the bulk-synchronous path (S=1), which remains the oracle.

Collective budget per ``forward_work`` round (guarded by
``tests/test_collective_budget.py``; multiply by ``pipeline_shards``):

  payload   1 × all_to_all (padded) / 1 × ragged_all_to_all (ragged) /
            1 × all_to_all PER MESH AXIS (hierarchical — see below)
  counts    1 × all_to_all of per-peer counts (padded) /
            1 × all_gather of the (R,) send-count vector (ragged — every rank
            reconstructs the full R×R count matrix locally and derives ALL
            offsets/clamps without further communication, replacing the three
            chained count all-to-alls of the naive Alltoallv control plane) /
            1 × tiny all_to_all PER MESH AXIS (hierarchical)

The N-level contract (hierarchical backend): ``axis_name`` is a tuple of
mesh axis names ordered slowest fabric first — e.g. ``("pod", "node",
"device")`` where "pod" spans the DCN, "node" the inter-host fabric, and
"device" the fast intra-node ICI/NVLink (an entry may itself be a tuple of
mesh axes treated as one joint tier).  ``level_sizes`` gives the rank count
per tier; global ranks are lexicographic in the tier digits (slowest-major —
"node-major" in the 2-level case), i.e. ``jax.lax.axis_index(flattened
axes)``.  The round is dimension-ordered routing over the padded wire
format, FASTEST axis first:

  stage l  (for l = L-1 … 0, extent-1 tiers skipped) one padded all_to_all
           over axis ``l``: each rank ships, per peer ``j`` on that axis, the
           concatenation of its sub-segments whose destination digit
           ``d_l == j``, in buffer order.  After the stage, every item sits
           on a rank whose digit ``l`` equals its destination's digit —
           slower stages never revisit the faster fabric.

The routing invariant (proved inductively; property-tested against the
``onehot`` oracle): before stage ``l`` the buffer is ordered lexicographically
by ``(s_{l+1}, …, s_{L-1}, d_0, …, d_l)`` — provenance digits of the already
routed tiers first, then the remaining destination digits.  Gathering each
peer's sub-segments in buffer order and concatenating received blocks in
source-digit order preserves it, so after the final stage items sit in global
source-rank order — bit-identical placement to the flat backends.

Bulk bytes cross each fabric tier exactly once, and padding at tier ``l`` is
per aggregated SEGMENT (``level_capacities[l]`` rows per peer on that axis),
not per rank: with R ranks over N slowest-tier groups that is an R/N×
reduction in worst-case slow-link padding versus routing the flat padded
exchange across the whole mesh.  The 2-level ``(slow, fast)`` route of PR 2
is exactly the L=2 instance.

Four interchangeable backends, all called *inside* ``shard_map`` with a
bound mesh axis:

* ``ragged`` — ``ragged_all_to_all``: the exact XLA analogue of
  ``MPI_Alltoallv`` and the TPU production path (single variable-size
  exchange over contiguous per-peer segments — the whole point of sorting
  first).  XLA:CPU cannot execute the op, so on CPU this backend is only
  ``.lower()``-validated; on JAX builds without the op it raises.
* ``padded`` — fixed per-peer slots of size ``peer_capacity`` exchanged with
  a single tiled ``all_to_all`` of the packed buffer.  Portable (runs on
  CPU; used by the dry-run compile) at the cost of padding bandwidth.  This
  is also the natural MoE-dispatch form (capacity-factor semantics).
* ``hierarchical`` — the N-stage padded exchange over an N-D ``(slowest, …,
  fastest)`` mesh described above: per-tier combine from the fastest axis
  inward, one collective per axis.  Placement is bit-identical to the flat
  backends (lexicographic rank order is preserved end to end).
* ``onehot`` — an all-gather reference oracle with a deliberately different
  code path, used only by tests.  Bulk-synchronous by design: it has no
  per-peer slot structure to micro-shard, so ``pipeline_shards > 1`` is
  rejected.

All backends share the contract: inputs are the *unsorted* packed payload
plus the marshal plan — the destination-sort permutation (``marshal="sort"``)
or the sanitized-dest/in-bucket-rank pair (``marshal="scatter"``) — and the
per-destination send counts; output is a compacted packed receive buffer plus
per-peer receive counts.  Segment overflow (sender-side ``> peer_capacity``,
or receiver-side total ``> capacity``) is dropped and counted EXACTLY ONCE —
the queue-capacity contract of §3.3/§6.3: every drop site clamps counts
*before* they feed any later stage, so an item clamped at one tier never
reappears in a later tier's (or the receiver's) overflow accounting
(regression-tested across stacked tier clamps in
``tests/test_core_scatter.py``).

Telemetry (ISSUE 5): every backend accepts ``telemetry=True`` (plus
``telemetry_buckets``) and then returns a FIFTH element, a
``repro.telemetry.RoundStats`` snapshot of the round's traffic — per-tier
segment-demand histograms, exact max demand, shipped rows, and per-stage
clamp drops.  Everything recorded is derived from control-plane values the
round computes anyway (the marshal histogram, the per-stage count
collectives' results, the clamp arithmetic): stats capture issues ZERO
additional collectives and never touches the payload, so the collective
budget above is bit-for-bit unchanged with telemetry on (guarded in
``tests/test_collective_budget.py``).

Spill-and-retry (ISSUE 6): every backend also accepts ``overflow="retain"``
(plus the per-lane ``age`` counter) and then returns, right before the
stats, a tuple of pending spill blocks ``(rows, dest, age, n_spill)`` — the
rows each sender- or tier-clamp would have cut, already compacted, with
their global destination and aged waiting counter.  The key cost trick: a
clamp's cut rows are exactly the per-segment TAILS of the marshalled order,
so each block is extracted with the same composed positional arithmetic the
send gather uses (one extra gather per clamp site — no conditional, no
per-lane masks, no scatter), and the receive-side compaction lands arrivals
BEHIND a reserved queue front (a shifted offset in the scatter it already
runs).  ``forward_work`` then just selects the blocks into that front
(stable block-then-row order = FIFO oldest-first) and retries them next
round: the lossless law.  Retention is pure local compaction: what ships is
the exact clamped traffic the drop path ships (the wire bytes and the
collective inventory are bit-identical; only the drop counters move to the
spill blocks).  On the hierarchical route a row clamped at stage ``l`` is
parked at the intermediate rank it reached — the stage-l sub-segment →
destination map (``seg_dest``) needed to re-address it is derived
rank-consistently from digits every later-stage peer shares, so no extra
collective is spent on it either.  The onehot oracle has no sender clamp,
so its plan is empty by construction (its receiver clamp stays a counted
drop).  Spill extraction always reads the FULL clamp (cut rows never ship),
so retention is unchanged — and bit-exact — under pipelining.

Credit flow (ISSUE 9): with ``flow="credit"`` (requires ``overflow=
"retain"``; the default ``"open"`` ships every clamped segment and stays
the bit-exactness oracle) every backend additionally enforces the
backpressure law — no wire byte is spent on a row its receiver cannot
admit.  Receivers advertise their free queue room ON the count collective
the round already runs (the padded count ``all_to_all`` widens from
``(A_l, R/A_l)`` to ``(A_l, R/A_l + 1)`` i32; the ragged count
``all_gather`` from ``(R,)`` to ``(R+1,)`` — nothing payload-sized, so the
collective *inventory* above is unchanged), senders deterministically
apportion the one-round-stale credits across the R contending peers (floor
share + rank-ordered residual — incast cannot overshoot the advertised
room by design), and the un-credited tail of each destination segment is
parked through the retain spill machinery instead of shipped-and-bounced.
The updated ``(R,)`` credit estimate rides back as an extra ``credits_out``
element right before the stats, to be carried into the next round.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import stages as ST
from repro.telemetry import stats as TS

__all__ = [
    "exchange_counts",
    "exchange_count_matrix",
    "exchange_padded",
    "exchange_ragged",
    "exchange_hierarchical",
    "exchange_onehot",
    "padded_send_buffer",
]

# The shared stage-library arithmetic (ISSUE 8 moved it to ``core.stages``);
# re-exported under the historic private names for callers that composed
# against the monolith (benchmark phase profiles, cycling's ring hop).
padded_send_buffer = ST.padded_send_buffer
_a2a = ST.a2a
_scatter = ST.scatter_rows
_spill_positions = ST.spill_positions
_lanes_spill = ST.lanes_spill
_clamp_subsegments = ST.clamp_subsegments
_subsegment_gather = ST.subsegment_gather
_compact_blocks = ST.compact_blocks
_ragged_control_plane = ST.ragged_control_plane


def exchange_counts(send_counts: jax.Array, axis_name) -> jax.Array:
    """§4.2.2 step 2 — MPI_Alltoall of per-peer counts.

    ``send_counts``: (R,) — how many items *I* send to each peer.
    Returns (R,): how many items each peer sends *me*.
    """
    return ST.a2a(send_counts[:, None], axis_name).reshape(-1)


def exchange_count_matrix(send_counts: jax.Array, axis_name) -> jax.Array:
    """All-gather the per-rank send-count vectors into the full (R, R) count
    matrix ``M[s, d] = items s sends to d``.

    One tiny collective (R² int32 — 256 KiB even at R=256) buys the ENTIRE
    ragged control plane: every rank derives every rank's receive layout,
    capacity clamps, and landing offsets locally, so no chained count
    exchanges are needed before the payload collective.
    """
    return jax.lax.all_gather(send_counts, axis_name)


def exchange_padded(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,  # (C,) destination-sort permutation (sorted pos → lane)
    send_counts: jax.Array,  # (R,) valid-destination counts (histogram[:R])
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int,
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,  # (C,) scatter mode: sanitized destination
    dest_rank: jax.Array = None,  # (C,) scatter mode: stable in-bucket rank
    telemetry: bool = False,
    telemetry_buckets: int = 8,
    overflow: str = "drop",
    age: jax.Array = None,  # (C,) retain mode: rounds each lane has waited
    pipeline_shards: int = 1,
    flow: str = "open",
    credits: jax.Array = None,  # (R,) credit mode: advertised free, 1-round stale
    credit_reserve: int = 0,  # credit mode: receive room withheld from adverts
):
    """Padded-slot exchange of the packed payload, as a stage composition:

      [CreditGate →] SpillExtract(sender clamp) → Marshal → CountExchange →
      PayloadExchange → Unmarshal

    Single-pass marshal, either mode: in sort mode the send buffer row for
    (peer r, slot s) is ``packed[perm[off[r] + s]]`` — destination sort and
    slot layout composed into ONE gather; in scatter mode row ``i`` goes
    straight to slot ``dest_clean[i]·S + dest_rank[i]`` (rank ≥ S → the §3.3
    sender clamp) — ONE scatter, no sort at all.  Either way the payload is
    read once and written once on the send side.  Returns ``(recv_packed,
    recv_counts, total, drops)``, plus a trailing ``RoundStats`` when
    ``telemetry`` (segment demand here = the per-peer send counts, measured
    against ``peer_capacity``).  With ``overflow="retain"`` the sender
    clamp's cut rows come back as a pending spill block ``(rows, dest, age,
    n_spill)`` inserted before the stats — extracted as the marshalled
    order's segment tails in the same pass style as the send gather — and
    the receive compaction lands arrivals BEHIND the reserved spill front,
    so ``drops`` reduces to the receiver-side admission count.

    With ``pipeline_shards=S > 1`` the Marshal→…→Unmarshal chain runs S
    times over slot-row micro-shards, interleaved (``stages.Pipelined``):
    S payload + S count collectives, payload bytes conserved, placement
    bit-exact with S=1 (each shard lands its rows at their bulk positions).
    """
    R, S = num_ranks, peer_capacity
    retain = overflow == "retain"
    credit = flow == "credit"
    st = ST.RoundState(
        packed=packed, perm=perm, send_counts=send_counts, marshal=marshal,
        dest_clean=dest_clean, dest_rank=dest_rank, use_pallas=use_pallas,
        retain=retain, age=age, flow=flow, credits=credits,
    )
    inner = (
        ST.Marshal(R, S, shards=pipeline_shards),
        ST.CountExchange(axis_name, num_ranks=R, capacity=capacity,
                         flat_axes=axis_name),
        ST.PayloadExchange(axis_name),
        ST.Unmarshal(capacity, shards=pipeline_shards, slot=S),
    )
    if pipeline_shards > 1:
        inner = (ST.Pipelined(inner, pipeline_shards),)
    head = (ST.CreditGate(axis_name, R),) if credit else ()
    st = ST.compose(
        *head,
        ST.SpillExtract(R, capacity, S, retain=retain, reserve=credit_reserve),
        *inner,
    )(st)
    drops = st.send_drops + st.recv_drops
    if telemetry:
        tkw = {}
        if retain:
            tkw["rows_held"] = st.stage_held
        if credit:
            tkw["credits_granted"] = jnp.sum(jnp.minimum(st.credit_allow, S))
        stats = TS.single_tier_stats(
            send_counts, S, telemetry_buckets,
            sent_rows=jnp.sum(st.clamped), stage_drops=st.send_drops,
            recv_total=jnp.sum(st.recv_counts), recv_drops=st.recv_drops,
            **tkw,
        )
        if credit:
            return (st.out, st.recv_counts, st.new_count, drops,
                    tuple(st.pending), st.credits_out, stats)
        if retain:
            return st.out, st.recv_counts, st.new_count, drops, tuple(st.pending), stats
        return st.out, st.recv_counts, st.new_count, drops, stats
    if credit:
        return (st.out, st.recv_counts, st.new_count, drops,
                tuple(st.pending), st.credits_out)
    if retain:
        return st.out, st.recv_counts, st.new_count, drops, tuple(st.pending)
    return st.out, st.recv_counts, st.new_count, drops


def exchange_hierarchical(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,  # (C,) lexicographic destination-sort permutation
    send_counts: jax.Array,  # (R,) valid-destination counts, slowest-major
    *,
    axis_name,  # (slowest, …, fastest) mesh axis names, one per tier
    num_ranks: int,
    capacity: int,
    level_sizes: Tuple[int, ...],  # ranks per tier, slowest first
    level_capacities: Tuple[int, ...],  # padded rows per peer segment, per tier
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,  # (C,) scatter mode: sanitized destination
    dest_rank: jax.Array = None,  # (C,) scatter mode: stable in-bucket rank
    telemetry: bool = False,
    telemetry_buckets: int = 8,
    overflow: str = "drop",
    age: jax.Array = None,  # (C,) retain mode: rounds each lane has waited
    pipeline_shards: int = 1,
    flow: str = "open",
    credits: jax.Array = None,  # (R,) credit mode: advertised free, 1-round stale
    credit_reserve: int = 0,  # credit mode: receive room withheld from adverts
):
    """N-stage packed exchange over an N-D ``(slowest, …, fastest)`` mesh —
    one SpillExtract → Marshal → CountExchange → PayloadExchange composition
    per mesh axis, ``AdvanceTier`` threading the sub-segment bookkeeping
    between tiers and ``Unmarshal`` closing the final one.

    Dimension-ordered routing, fastest axis first: stage ``l`` combines
    traffic within axis ``l`` so every item lands on a rank whose digit ``l``
    equals its destination's — slower stages re-exchange only aggregated,
    already-packed segments, and bulk bytes cross each fabric tier exactly
    once, padded per peer SEGMENT at that tier (``level_capacities[l]``
    rows), never per rank.

    Budget: one payload + one count collective per mesh axis (× the
    micro-shard count under pipelining); extent-1 axes skip their stage
    entirely (so a single-node mesh degenerates to flat-exchange cost
    parity).  Returns ``(recv_packed, recv_counts, total, drops)`` — counts
    are per *source group* of the slowest non-trivial axis, unlike the flat
    backends' per-rank counts.

    Marshal modes: the first non-trivial stage is the round's single local
    payload pass — in sort mode the destination-sort permutation is composed
    into that stage's send gather; in scatter mode each row is scattered
    straight to its stage slot ``d_l·S + starts[rest, d_l] + rank`` (the
    in-bucket rank against the FULL destination is exactly the in-sub-segment
    rank, because every sub-segment holds one destination).  Every stage's
    sub-segment counts/offsets derive from the ONE histogram (reshaped per
    tier) and the per-stage count collectives — the sorted destination vector
    is never re-scanned (no per-tier ``segment_bounds_from_sorted`` neighbor
    compares), on either marshal path.

    With ``pipeline_shards=S > 1`` each tier's Marshal/CountExchange/
    PayloadExchange chain runs S times over ``level_capacities[l]/S``-row
    micro-shards (interleaved — stage-l of shard k overlaps stage-(l−1) of
    shard k+1 on an async fabric), non-final tiers reassemble the bulk
    stage buffer locally (``stages.Reassemble`` — zero extra collectives),
    and the final tier's shards compact straight into the receive queue at
    their bulk positions.  Placement stays bit-exact with S=1.

    With ``telemetry`` a trailing ``RoundStats`` is returned: tier ``l``'s
    segment demand is the pre-clamp row total per peer slot COLUMN of stage
    ``l`` (the concatenated sub-segments one ``level_capacities[l]`` budget
    clamps), measured against that budget; extent-1 tiers skip their stage
    and stay zero.  Demand at tier ``l`` is post-clamp of the faster tiers —
    exactly the traffic the stage observes (and the reason the capacity
    controller converges over a few bursts rather than in one).

    With ``overflow="retain"`` every stage clamp parks its cut rows at the
    rank they currently sit on instead of dropping them: the first stage
    spills input LANES (sender clamp — the per-destination segment tails of
    the sorted order, ages carried forward); later stages spill mid-route
    BUFFER rows (sub-segment tails read straight out of the stage buffer)
    re-addressed through ``seg_dest`` — the sub-segment → global-destination
    map, maintained locally because after stage ``l`` every peer of the
    remaining stages shares the already-routed digits (mid-route rows
    restart at age 1: age cannot ride the wire without changing the payload
    bytes).  One pending ``(rows, dest, age, n)`` spill block per non-trivial
    stage rides back before the stats, the final compaction lands arrivals
    behind the reserved spill front, and stage drops move into the blocks —
    ``drops`` reduces to the receiver-side admission count.

    With ``flow="credit"`` (the backpressure law; requires retain) the
    carried ``credits`` vector gates the route's FIRST clamp: the per-
    destination grant (floor share + rank-ordered residual over the R
    contending senders) caps each sub-segment before the fastest tier's
    clamp, so a saturated destination throttles every downstream fabric —
    including the DCN stage — at the source, and the un-granted tail parks
    in the sender's own spill blocks.  Credits aggregate per tier: each
    tier's count ``all_to_all`` widens by ONE i32 column carrying the
    min-aggregated free space of the sender's destination SUBTREE on that
    axis (the final tier folds in this rank's fresh post-spill room first),
    and receivers scatter the advertised column back into their estimate of
    every subtree member — every rank's estimate of every destination
    refreshes every round, conservatively (min over the subtree), with no
    payload-sized traffic added.  The updated ``credits_out`` rides back
    right before the stats.
    """
    level_sizes = tuple(int(a) for a in level_sizes)
    R = num_ranks
    C, W = packed.shape
    rec = TS.make_stats(len(level_sizes), telemetry_buckets) if telemetry else None
    retain = overflow == "retain"
    credit = flow == "credit"
    # One flattened axis spec covering every tier — the global rank index
    # (slowest-major) that the credit bookkeeping addresses by.
    flat_axes = []
    for ax in axis_name:
        flat_axes.extend(ax) if isinstance(ax, (tuple, list)) else flat_axes.append(ax)
    flat_axes = tuple(flat_axes)
    st = ST.RoundState(
        packed=packed, perm=perm, send_counts=send_counts, marshal=marshal,
        dest_clean=dest_clean, dest_rank=dest_rank, use_pallas=use_pallas,
        retain=retain, age=age, flow=flow, credits=credits,
    )
    if credit:
        st = ST.CreditGate(flat_axes, R)(st)
    st.spill_run = jnp.zeros((), send_counts.dtype)  # total rows parked so far
    st.drops = jnp.zeros((), send_counts.dtype)
    if retain:
        if st.age is None:
            st.age = jnp.zeros((C,), jnp.int32)
        # Which global destination does sub-segment k of the current buffer
        # hold?  Identity at the start (sorted destination order); updated
        # after each non-final stage from digits all later-stage peers share.
        st.seg_dest = jnp.arange(R, dtype=jnp.int32)

    # Sub-segment state, always exactly R entries: counts and buffer offsets
    # in the current buffer order (initially the sorted destination order,
    # digits slowest-major).  Each stage reinterprets the vector as
    # (rest, A_l) — its peer digit is the fastest-varying non-trivial field —
    # and afterwards prepends the source digit: (A_l, rest) flattened.
    st.cnt = send_counts
    st.base = jnp.cumsum(st.cnt) - st.cnt
    st.buf, st.n_rows, st.via_perm = packed, C, True

    tiers = [l for l in reversed(range(len(level_sizes))) if level_sizes[l] > 1]
    if not tiers:
        # 1-rank mesh: the round is a local compaction — no collectives
        allowed = jnp.minimum(st.cnt, capacity)
        credits_out = (capacity - allowed).astype(jnp.int32) if credit else None
        if marshal == "scatter":
            keep = (dest_clean < R) & (dest_rank < capacity)
            out = ST.scatter_rows(
                packed,
                jnp.where(keep, dest_rank, capacity),
                capacity,
                use_pallas=use_pallas,
            )
        else:
            rows = jnp.take(perm, jnp.clip(jnp.arange(capacity), 0, C - 1))
            if use_pallas:
                from repro.kernels.marshal import ops as marshal_ops

                out = marshal_ops.fused_marshal(
                    packed, rows, num_ranks=1, slot=capacity
                )[0]
            else:
                out = jnp.take(packed, rows, axis=0).reshape(1, capacity, W)[0]
        local_drops = jnp.sum(st.cnt - allowed)
        if telemetry:
            # no stage ran: only the receiver-side compaction is observable
            rec = dataclasses.replace(
                rec,
                recv_total=jnp.sum(st.cnt).astype(jnp.int32),
                recv_drops=local_drops.astype(jnp.int32),
            )
            if credit:
                return out, allowed, allowed[0], local_drops, (), credits_out, rec
            if retain:  # no stage clamp ran either: nothing to spill
                return out, allowed, allowed[0], local_drops, (), rec
            return out, allowed, allowed[0], local_drops, rec
        if credit:
            return out, allowed, allowed[0], local_drops, (), credits_out
        if retain:
            return out, allowed, allowed[0], local_drops, ()
        return out, allowed, allowed[0], local_drops

    for i, l in enumerate(tiers):
        A, S = level_sizes[l], level_capacities[l]
        stride = 1
        for sz in level_sizes[l + 1:]:
            stride *= sz
        st = ST.SpillExtract(
            R, capacity, S, retain=retain, kind="tier", extent=A
        )(st)
        if telemetry:
            # segment demand at tier l = pre-clamp rows per peer slot column
            col_demand = jnp.sum(st.cnt.reshape(R // A, A), axis=0)
            rec = dataclasses.replace(
                rec,
                demand_hist=rec.demand_hist.at[l].set(
                    TS.occupancy_histogram(col_demand, S, telemetry_buckets)
                ),
                demand_max=rec.demand_max.at[l].set(jnp.max(col_demand)),
                demand_total=rec.demand_total.at[l].set(jnp.sum(col_demand)),
                sent_rows=rec.sent_rows.at[l].set(jnp.sum(st.allowed)),
                stage_drops=rec.stage_drops.at[l].set(st.stage_drops),
            )
            if retain:
                rec = dataclasses.replace(
                    rec, rows_held=rec.rows_held.at[l].set(st.stage_held)
                )
            if credit and i == 0:
                rec = dataclasses.replace(
                    rec,
                    credits_granted=rec.credits_granted.at[l].set(
                        jnp.sum(jnp.minimum(st.credit_allow, S))
                    ),
                )
        mar = ST.Marshal(A, S, shards=pipeline_shards, kind="tier", num_ranks=R)
        if i == len(tiers) - 1:
            # final stage: per-source-group totals suffice — blocks are
            # contiguous prefixes, compacted straight into the receive queue
            chain = (
                mar,
                ST.CountExchange(axis_name[l], kind="final", num_ranks=R,
                                 stride=stride, capacity=capacity,
                                 flat_axes=flat_axes, reserve=credit_reserve),
                ST.PayloadExchange(axis_name[l]),
                ST.Unmarshal(capacity, shards=pipeline_shards, slot=S, kind="final"),
            )
            if pipeline_shards > 1:
                st = ST.Pipelined(chain, pipeline_shards)(st)
            else:
                st = ST.compose(*chain)(st)
            total_drops = st.drops + st.recv_drops
            if telemetry:
                # wasted wire = every row discarded AFTER crossing a wire:
                # the receiver-admission cut plus any stage clamp past the
                # first hop (tiers[0] clamps pre-wire rows — not waste; a
                # tiers[i>0] clamp cuts rows that already spent the earlier
                # tiers' fabric).  Under retain the late stages hold instead
                # of dropping, so their recorded stage_drops are zero and
                # the term collapses to the receiver cut.
                late_drops = jnp.zeros((), jnp.int32)
                for j in tiers[1:]:
                    late_drops = late_drops + rec.stage_drops[j]
                rec = dataclasses.replace(
                    rec,
                    recv_total=jnp.sum(st.recv_counts).astype(jnp.int32),
                    recv_drops=st.recv_drops.astype(jnp.int32),
                    wasted_wire_rows=(
                        st.recv_drops.astype(jnp.int32) + late_drops
                    ),
                )
                if credit:
                    return (st.out, st.recv_counts, st.new_count,
                            total_drops, tuple(st.pending), st.credits_out, rec)
                if retain:
                    return (st.out, st.recv_counts, st.new_count,
                            total_drops, tuple(st.pending), rec)
                return st.out, st.recv_counts, st.new_count, total_drops, rec
            if credit:
                return (st.out, st.recv_counts, st.new_count,
                        total_drops, tuple(st.pending), st.credits_out)
            if retain:
                return (st.out, st.recv_counts, st.new_count,
                        total_drops, tuple(st.pending))
            return st.out, st.recv_counts, st.new_count, total_drops

        # count collective for axis l: per-sub-segment survivor counts, so
        # the receiver can address every sub-segment of each incoming block
        chain = (
            mar,
            ST.CountExchange(
                axis_name[l], kind="tier", shards=pipeline_shards, slot=S,
                num_ranks=R, stride=stride, capacity=capacity,
                flat_axes=flat_axes,
            ),
            ST.PayloadExchange(axis_name[l], collect=pipeline_shards > 1),
        )
        if pipeline_shards > 1:
            st = ST.Pipelined(chain, pipeline_shards)(st)
            st = ST.Reassemble(A, S)(st)
        else:
            st = ST.compose(*chain)(st)
        st = ST.AdvanceTier(A, S, axis_name[l], retain=retain, num_ranks=R)(st)


def exchange_ragged(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,
    send_counts: jax.Array,  # (R,)
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,  # unused; signature parity
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,  # (C,) scatter mode: sanitized destination
    dest_rank: jax.Array = None,  # (C,) scatter mode: stable in-bucket rank
    telemetry: bool = False,
    telemetry_buckets: int = 8,
    overflow: str = "drop",
    age: jax.Array = None,  # (C,) retain mode: rounds each lane has waited
    pipeline_shards: int = 1,
    flow: str = "open",
    credits: jax.Array = None,  # (R,) credit mode: advertised free, 1-round stale
    credit_reserve: int = 0,  # credit mode: receive room withheld from adverts
):
    """ragged_all_to_all exchange — the MPI_Alltoallv / GPU-RDMA analogue.

    The packed payload is placed ONCE into destination order (contiguous
    per-peer segments) — a gather through the sort permutation, or a sort-free
    scatter to ``off[dest] + rank`` — and shipped in ONE variable-size
    collective; the receive side is written compacted directly (no unpack
    pass), which is the paper's "large contiguous blocks at very high
    bandwidth" property.  The control plane is one all-gather of the
    send-count vector (see :func:`exchange_count_matrix`).  With
    ``overflow="retain"`` the rows past each segment's control-plane
    allowance (``send_sizes``) come back as a pending spill block instead
    of being dropped — the shipped segments are unchanged.

    With ``pipeline_shards=S > 1`` the single collective becomes S: shard
    ``k`` ships rows ``[k·capacity/S, (k+1)·capacity/S)`` of every
    destination segment (offsets shifted, sizes clipped — the union of the
    shard segments is exactly the bulk segments at the same landing
    offsets), each with its own count all-gather.  The marshal stays ONE
    local pass; only the wire movement is sharded.

    With ``flow="credit"`` (requires retain) the carried ``credits`` vector
    gates each sender's per-destination counts BEFORE the count all-gather
    (floor share + rank-ordered residual), so the replicated control plane —
    and the wire — only ever sees granted traffic; the un-granted tail parks
    in the spill block with the control-plane cut.  The gather widens by ONE
    i32 column carrying each rank's own-entry advert (its post-spill free
    room from last round), and this rank's fresh advert replaces its own
    entry in the returned ``credits_out`` — every rank's estimate of every
    receiver refreshes every round with no payload-sized traffic added.
    """
    del peer_capacity  # segments are contiguous: no slot gather
    retain = overflow == "retain"
    credit = flow == "credit"
    R = num_ranks
    me = jax.lax.axis_index(axis_name)
    off = jnp.cumsum(send_counts) - send_counts

    credits_out = grant = None
    send_gated = send_counts
    if credit:
        free = jnp.clip(credits, 0)
        grant = (free // R + (me < free % R)).astype(send_counts.dtype)
        send_gated = jnp.minimum(send_counts, grant)
        # shard 0's count collective, widened by this rank's own-entry advert
        wide = jnp.concatenate(
            [send_gated, jnp.take(credits, me)[None].astype(send_gated.dtype)]
        )
        gath = jax.lax.all_gather(wide, axis_name)  # (R, R+1)
        cnt, credits_out = gath[:, :R], gath[:, R].astype(jnp.int32)
    else:
        cnt = exchange_count_matrix(send_counts, axis_name)  # shard 0's count collective
    send_sizes, output_offsets, recv_sizes = ST.ragged_control_plane(
        cnt, me, capacity
    )
    send_drops = jnp.sum(send_counts - send_sizes)
    front = None
    if retain:
        # Segment-tail spill extraction, exactly as exchange_padded — the
        # allowance here is the control plane's ``send_sizes``.
        if age is None:
            age = jnp.zeros((packed.shape[0],), jnp.int32)
        pending = (ST.lanes_spill(
            packed, perm, age, send_sizes, send_counts - send_sizes,
            off + send_sizes, send_drops, num_ranks=num_ranks,
            marshal=marshal, dest_clean=dest_clean, dest_rank=dest_rank,
        ),)
        front = jnp.minimum(send_drops, capacity)
        held_rows = send_drops
        if credit:
            # fresh advert: the room left behind the reserved spill front,
            # minus the reserve withheld for next round's local emissions,
            # floored at one row per sender whenever room exists (liveness
            # — see stages.SpillExtract's flat advert)
            room = capacity - front
            credits_out = credits_out.at[me].set(
                jnp.maximum(
                    jnp.clip(room - credit_reserve, 0),
                    jnp.minimum(room, num_ranks),
                ).astype(jnp.int32)
            )
        send_drops = jnp.zeros_like(send_drops)

    if marshal == "scatter":  # the ONE payload pass, sort-free
        keep = dest_clean < num_ranks
        pos = off[jnp.clip(dest_clean, 0, num_ranks - 1)] + dest_rank
        dstpos = jnp.where(keep, pos, packed.shape[0])
        sorted_packed = ST.scatter_rows(
            packed, dstpos, packed.shape[0], use_pallas=use_pallas
        )
    else:
        sorted_packed = jnp.take(packed, perm, axis=0)  # the ONE payload permute
    out = jnp.zeros((capacity, packed.shape[1]), packed.dtype)
    if pipeline_shards == 1:
        out = compat.ragged_all_to_all(  # the ONE payload collective
            sorted_packed,
            out,
            input_offsets=off,
            send_sizes=send_sizes,
            output_offsets=output_offsets,
            recv_sizes=recv_sizes,
            axis_name=axis_name,
        )
    else:
        chunk = capacity // pipeline_shards
        for k in range(pipeline_shards):
            if k > 0:
                # shard k's own count collective + replicated control plane
                cnt_k = exchange_count_matrix(send_gated, axis_name)
                s_ss, s_oo, s_rs = ST.ragged_control_plane(cnt_k, me, capacity)
            else:
                s_ss, s_oo, s_rs = send_sizes, output_offsets, recv_sizes
            out = compat.ragged_all_to_all(  # shard k's payload collective
                sorted_packed,
                out,
                input_offsets=off + jnp.minimum(k * chunk, s_ss),
                send_sizes=jnp.clip(s_ss - k * chunk, 0, chunk),
                output_offsets=s_oo + jnp.minimum(k * chunk, s_ss),
                recv_sizes=jnp.clip(s_rs - k * chunk, 0, chunk),
                axis_name=axis_name,
            )
    new_count = jnp.sum(recv_sizes)
    recv_cut = jnp.zeros((), send_counts.dtype)
    if retain:
        # The collective's landing offsets are fixed by the replicated
        # control plane, so the spill front is opened AFTER the exchange by
        # one local gather (this backend is lower-only on CPU, so the extra
        # pass is off the walltime gate); arrivals pushed past capacity are
        # the receiver-admission loss.
        lane = jnp.arange(capacity, dtype=jnp.int32)
        out = jnp.take(out, jnp.clip(lane - front, 0, capacity - 1), axis=0)
        admitted = jnp.minimum(new_count, capacity - front)
        recv_cut = new_count - admitted
        new_count = admitted
    if telemetry:
        # No per-peer slots here — the §3.3 clamp is the receiver queue, so
        # segment demand = the count matrix's per-destination column totals
        # (replicated identically on every rank; quantiles/maxima are
        # unaffected, totals are ×R — documented in telemetry.summarize's
        # population semantics).  Senders own the drop accounting on this
        # backend (each counts what the control plane cut from its row), so
        # recv_drops stays 0 — stats sum to the exchange's drops return.
        col_demand = jnp.sum(cnt, axis=0)
        tkw = {}
        if retain:
            tkw["rows_held"] = held_rows
        if credit:
            tkw["credits_granted"] = jnp.sum(jnp.minimum(grant, send_counts))
        stats = TS.single_tier_stats(
            col_demand, capacity, telemetry_buckets,
            sent_rows=jnp.sum(send_sizes), stage_drops=send_drops,
            recv_total=col_demand[me], recv_drops=recv_cut.astype(jnp.int32),
            **tkw,
        )
        if credit:
            return (out, recv_sizes, new_count, send_drops + recv_cut,
                    pending, credits_out, stats)
        if retain:
            return out, recv_sizes, new_count, send_drops + recv_cut, pending, stats
        return out, recv_sizes, new_count, send_drops, stats
    if credit:
        return (out, recv_sizes, new_count, send_drops + recv_cut,
                pending, credits_out)
    if retain:
        return out, recv_sizes, new_count, send_drops + recv_cut, pending
    return out, recv_sizes, new_count, send_drops


def exchange_onehot(
    packed: jax.Array,
    perm: jax.Array,
    send_counts: jax.Array,
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,
    dest_rank: jax.Array = None,
    telemetry: bool = False,
    telemetry_buckets: int = 8,
    overflow: str = "drop",
    age: jax.Array = None,  # unused: the oracle has no sender clamp
    pipeline_shards: int = 1,
):
    """All-gather reference oracle (tests only): every rank sees everything,
    selects what is addressed to it, and compacts stably by (source, lane).
    Deliberately a different code path from the production backends (in
    scatter mode only the initial into-destination-order placement differs).
    With ``overflow="retain"`` the pending spill plan is empty by
    construction — there is no sender clamp to spill from; the receiver
    clamp stays a counted drop (there is no bounded place left to keep those
    rows).  Bulk-synchronous by design: the all-gather has no per-peer slot
    rows to micro-shard, so ``pipeline_shards > 1`` raises.
    """
    del peer_capacity, age
    if pipeline_shards != 1:
        raise ValueError(
            "exchange='onehot' is the bulk-synchronous reference oracle: the "
            "all-gather ships whole queues, so there is no per-peer slot "
            "dimension to micro-shard — pipeline_shards must be 1 "
            f"(got {pipeline_shards})"
        )
    retain = overflow == "retain"
    R = num_ranks
    me = jax.lax.axis_index(axis_name)
    off = jnp.cumsum(send_counts) - send_counts
    cap = packed.shape[0]
    if marshal == "scatter":
        keep = dest_clean < R
        pos = off[jnp.clip(dest_clean, 0, R - 1)] + dest_rank
        sorted_packed = ST.scatter_rows(
            packed, jnp.where(keep, pos, cap), cap, use_pallas=use_pallas
        )
    else:
        sorted_packed = jnp.take(packed, perm, axis=0)
    lane = jnp.arange(cap, dtype=jnp.int32)
    # reconstruct per-item dest from segments: dest[i] = r iff off[r] <= i < off[r]+cnt
    seg_end = off + send_counts
    dest = jnp.sum((lane[:, None] >= seg_end[None, :]).astype(jnp.int32), axis=1)
    dest = jnp.where(lane < jnp.sum(send_counts), dest, R)

    all_packed = jax.lax.all_gather(sorted_packed, axis_name)  # (R, cap, W)
    all_dest = jax.lax.all_gather(dest, axis_name)  # (R, cap)
    mine = (all_dest == me).reshape(-1)
    order = jnp.argsort(~mine, stable=True)  # mine first, stable (src, lane) order
    flat = all_packed.reshape(R * cap, -1)
    gathered = jnp.take(flat, order[:capacity], axis=0, mode="clip")
    total = jnp.sum(mine.astype(jnp.int32))
    new_count = jnp.minimum(total, capacity)
    recv_counts = jnp.sum((all_dest == me).astype(jnp.int32), axis=1)
    if telemetry:
        # oracle capture: my per-destination send counts vs the receiver
        # queue (the only clamp this backend has)
        stats = TS.single_tier_stats(
            send_counts, capacity, telemetry_buckets,
            sent_rows=jnp.sum(send_counts), stage_drops=jnp.zeros((), jnp.int32),
            recv_total=total, recv_drops=total - new_count,
        )
        if retain:
            return gathered, recv_counts, new_count, total - new_count, (), stats
        return gathered, recv_counts, new_count, total - new_count, stats
    if retain:
        return gathered, recv_counts, new_count, total - new_count, ()
    return gathered, recv_counts, new_count, total - new_count

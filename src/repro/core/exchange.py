"""Packed-payload exchange — the TPU adaptation of RaFI §4.2.2 (MPI_Alltoallv).

Wire format: the caller packs the whole work-item pytree into ONE
``(capacity, words)`` uint32 buffer (``core.types.pack_payload`` — the
paper's contiguous 44-byte ray).  Every backend moves that single buffer with
a SINGLE payload collective per round, and the send-side marshal is ONE
payload pass (§4.2.1/§6.1) in either of two bit-exact modes:

* ``marshal="sort"`` — the destination-sort permutation is composed with the
  send-layout gather (``packed[perm[off[r] + s]]``): no separate "sort the
  payload, then gather the segments" double pass;
* ``marshal="scatter"`` — sort-free: the caller supplies the counting-sort
  plan (``dest_clean``, in-bucket ``dest_rank`` — one cheap pass over the
  destination vector, ``core.sorting.destination_rank``) and each packed row
  is scattered straight to its send-layout slot ``base[dest] + rank``.  No
  keys, no O(C log C) sort, and the histogram IS the send-count vector.

Both modes place items identically (the scatter reproduces the sort's
lexicographic stable source order), and neither fans out per pytree leaf.
The marshal law, alongside the collective budget below: ONE payload pass per
round pre-collective, whichever mode runs.

Collective budget per ``forward_work`` round (guarded by
``tests/test_collective_budget.py``):

  payload   1 × all_to_all (padded) / 1 × ragged_all_to_all (ragged) /
            1 × all_to_all PER MESH AXIS (hierarchical — see below)
  counts    1 × all_to_all of per-peer counts (padded) /
            1 × all_gather of the (R,) send-count vector (ragged — every rank
            reconstructs the full R×R count matrix locally and derives ALL
            offsets/clamps without further communication, replacing the three
            chained count all-to-alls of the naive Alltoallv control plane) /
            1 × tiny all_to_all PER MESH AXIS (hierarchical)

The N-level contract (hierarchical backend): ``axis_name`` is a tuple of
mesh axis names ordered slowest fabric first — e.g. ``("pod", "node",
"device")`` where "pod" spans the DCN, "node" the inter-host fabric, and
"device" the fast intra-node ICI/NVLink (an entry may itself be a tuple of
mesh axes treated as one joint tier).  ``level_sizes`` gives the rank count
per tier; global ranks are lexicographic in the tier digits (slowest-major —
"node-major" in the 2-level case), i.e. ``jax.lax.axis_index(flattened
axes)``.  The round is dimension-ordered routing over the padded wire
format, FASTEST axis first:

  stage l  (for l = L-1 … 0, extent-1 tiers skipped) one padded all_to_all
           over axis ``l``: each rank ships, per peer ``j`` on that axis, the
           concatenation of its sub-segments whose destination digit
           ``d_l == j``, in buffer order.  After the stage, every item sits
           on a rank whose digit ``l`` equals its destination's digit —
           slower stages never revisit the faster fabric.

The routing invariant (proved inductively; property-tested against the
``onehot`` oracle): before stage ``l`` the buffer is ordered lexicographically
by ``(s_{l+1}, …, s_{L-1}, d_0, …, d_l)`` — provenance digits of the already
routed tiers first, then the remaining destination digits.  Gathering each
peer's sub-segments in buffer order and concatenating received blocks in
source-digit order preserves it, so after the final stage items sit in global
source-rank order — bit-identical placement to the flat backends.

Bulk bytes cross each fabric tier exactly once, and padding at tier ``l`` is
per aggregated SEGMENT (``level_capacities[l]`` rows per peer on that axis),
not per rank: with R ranks over N slowest-tier groups that is an R/N×
reduction in worst-case slow-link padding versus routing the flat padded
exchange across the whole mesh.  The 2-level ``(slow, fast)`` route of PR 2
is exactly the L=2 instance.

Four interchangeable backends, all called *inside* ``shard_map`` with a
bound mesh axis:

* ``ragged`` — ``ragged_all_to_all``: the exact XLA analogue of
  ``MPI_Alltoallv`` and the TPU production path (single variable-size
  exchange over contiguous per-peer segments — the whole point of sorting
  first).  XLA:CPU cannot execute the op, so on CPU this backend is only
  ``.lower()``-validated; on JAX builds without the op it raises.
* ``padded`` — fixed per-peer slots of size ``peer_capacity`` exchanged with
  a single tiled ``all_to_all`` of the packed buffer.  Portable (runs on
  CPU; used by the dry-run compile) at the cost of padding bandwidth.  This
  is also the natural MoE-dispatch form (capacity-factor semantics).
* ``hierarchical`` — the N-stage padded exchange over an N-D ``(slowest, …,
  fastest)`` mesh described above: per-tier combine from the fastest axis
  inward, one collective per axis.  Placement is bit-identical to the flat
  backends (lexicographic rank order is preserved end to end).
* ``onehot`` — an all-gather reference oracle with a deliberately different
  code path, used only by tests.

All backends share the contract: inputs are the *unsorted* packed payload
plus the marshal plan — the destination-sort permutation (``marshal="sort"``)
or the sanitized-dest/in-bucket-rank pair (``marshal="scatter"``) — and the
per-destination send counts; output is a compacted packed receive buffer plus
per-peer receive counts.  Segment overflow (sender-side ``> peer_capacity``,
or receiver-side total ``> capacity``) is dropped and counted EXACTLY ONCE —
the queue-capacity contract of §3.3/§6.3: every drop site clamps counts
*before* they feed any later stage, so an item clamped at one tier never
reappears in a later tier's (or the receiver's) overflow accounting
(regression-tested across stacked tier clamps in
``tests/test_core_scatter.py``).

Telemetry (ISSUE 5): every backend accepts ``telemetry=True`` (plus
``telemetry_buckets``) and then returns a FIFTH element, a
``repro.telemetry.RoundStats`` snapshot of the round's traffic — per-tier
segment-demand histograms, exact max demand, shipped rows, and per-stage
clamp drops.  Everything recorded is derived from control-plane values the
round computes anyway (the marshal histogram, the per-stage count
collectives' results, the clamp arithmetic): stats capture issues ZERO
additional collectives and never touches the payload, so the collective
budget above is bit-for-bit unchanged with telemetry on (guarded in
``tests/test_collective_budget.py``).

Spill-and-retry (ISSUE 6): every backend also accepts ``overflow="retain"``
(plus the per-lane ``age`` counter) and then returns, right before the
stats, a tuple of pending spill blocks ``(rows, dest, age, n_spill)`` — the
rows each sender- or tier-clamp would have cut, already compacted, with
their global destination and aged waiting counter.  The key cost trick: a
clamp's cut rows are exactly the per-segment TAILS of the marshalled order,
so each block is extracted with the same composed positional arithmetic the
send gather uses (one extra gather per clamp site — no conditional, no
per-lane masks, no scatter), and the receive-side compaction lands arrivals
BEHIND a reserved queue front (a shifted offset in the scatter it already
runs).  ``forward_work`` then just selects the blocks into that front
(stable block-then-row order = FIFO oldest-first) and retries them next
round: the lossless law.  Retention is pure local compaction: what ships is
the exact clamped traffic the drop path ships (the wire bytes and the
collective inventory are bit-identical; only the drop counters move to the
spill blocks).  On the hierarchical route a row clamped at stage ``l`` is
parked at the intermediate rank it reached — the stage-l sub-segment →
destination map (``seg_dest``) needed to re-address it is derived
rank-consistently from digits every later-stage peer shares, so no extra
collective is spent on it either.  The onehot oracle has no sender clamp,
so its plan is empty by construction (its receiver clamp stays a counted
drop).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.telemetry import stats as TS

__all__ = [
    "exchange_counts",
    "exchange_count_matrix",
    "exchange_padded",
    "exchange_ragged",
    "exchange_hierarchical",
    "exchange_onehot",
    "padded_send_buffer",
]


def _a2a(x: jax.Array, axis_name) -> jax.Array:
    """all_to_all over leading axis: out[p] = what peer p sent me (block p)."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


def exchange_counts(send_counts: jax.Array, axis_name) -> jax.Array:
    """§4.2.2 step 2 — MPI_Alltoall of per-peer counts.

    ``send_counts``: (R,) — how many items *I* send to each peer.
    Returns (R,): how many items each peer sends *me*.
    """
    return _a2a(send_counts[:, None], axis_name).reshape(-1)


def exchange_count_matrix(send_counts: jax.Array, axis_name) -> jax.Array:
    """All-gather the per-rank send-count vectors into the full (R, R) count
    matrix ``M[s, d] = items s sends to d``.

    One tiny collective (R² int32 — 256 KiB even at R=256) buys the ENTIRE
    ragged control plane: every rank derives every rank's receive layout,
    capacity clamps, and landing offsets locally, so no chained count
    exchanges are needed before the payload collective.
    """
    return jax.lax.all_gather(send_counts, axis_name)


def _scatter(
    buf: jax.Array, dstpos: jax.Array, n_slots: int, *, use_pallas: bool
) -> jax.Array:
    """The scatter marshal's single payload pass: ``out[dstpos[i]] = buf[i]``.

    Positions at/past ``n_slots`` (the caller's drop/trash sentinel) are
    discarded — §3.3 semantics.  The Pallas kernel
    (``kernels/bucket_scatter.scatter_rows``) stores rows at their slots
    directly; the XLA fallback scatters only the 1-word LANE INDEX and reads
    the payload back through the inverse — XLA lowers a W-word row scatter
    far worse than the equivalent gather, and the index scatter is
    control-plane-sized (like the histogram), so the payload still moves in
    exactly ONE pass.  Slots no lane claimed hold garbage on this path (row 0)
    and zeros on the Pallas path — both are masked downstream by the
    exchanged counts, exactly like the sort path's past-the-segment slots.
    """
    if use_pallas:
        from repro.kernels.bucket_scatter import ops as bs_ops

        return bs_ops.scatter_rows(buf, dstpos, num_slots=n_slots)
    lane = jnp.arange(buf.shape[0], dtype=jnp.int32)
    inv = jnp.zeros((n_slots,), jnp.int32).at[dstpos].set(lane, mode="drop")
    return jnp.take(buf, inv, axis=0)


def _spill_positions(n_slots, cut, seg_start):
    """Source positions of a clamp site's cut rows, compacted segment-major.

    ``cut[k]`` rows were clamped off segment ``k``; they sit contiguously
    from ``seg_start[k]`` (the first position past the segment's allowance).
    Spill slot ``j`` maps to segment ``k = #{inclusive-cumulative cut <= j}``
    and position ``seg_start[k] + j - spill_off[k]`` — the same composed
    positional arithmetic as the send gather, so extracting the spill is
    just a second index vector into the marshal's source space.  In-segment
    order is preserved (stable rank order = FIFO).  Returns ``(k, pos)``;
    slots at/past the total cut hold clamped garbage the caller bounds by
    the spill count.
    """
    incl = jnp.cumsum(cut)
    j = jnp.arange(n_slots, dtype=jnp.int32)
    k = jnp.sum((j[:, None] >= incl[None, :]).astype(jnp.int32), axis=1)
    k = jnp.clip(k, 0, cut.shape[0] - 1)
    pos = jnp.take(seg_start, k) + j - jnp.take(incl - cut, k)
    return k, pos


def _lanes_spill(
    packed, perm, age, allow_tbl, cut, seg_start, n_spill, *,
    num_ranks, marshal, dest_clean, dest_rank,
):
    """Pending-spill block for a sender-side clamp over the INPUT lanes.

    ``allow_tbl[d]``/``cut[d]``: per-destination allowance and cut count;
    ``seg_start[d]``: first cut position of destination ``d`` in the
    MARSHALLED (sorted) order.  Sort mode reads the cut rows straight
    through ``perm``; scatter mode inverts the (dest, in-bucket rank) plan
    with one 1-word scatter.  Returns ``(rows, dest, age, n_spill)`` —
    rows/dest/age are valid on the ``[0, n_spill)`` prefix only (the caller
    bounds every read), ages carried forward +1.
    """
    C = packed.shape[0]
    k, pos = _spill_positions(C, cut, seg_start)
    if marshal == "scatter":
        lanes = jnp.arange(C, dtype=jnp.int32)
        d = jnp.clip(dest_clean, 0, num_ranks - 1)
        al = jnp.take(allow_tbl, d)
        tgt = jnp.where(
            (dest_clean < num_ranks) & (dest_rank >= al),
            jnp.take(jnp.cumsum(cut) - cut, d) + dest_rank - al,
            C,
        )
        src = jnp.zeros((C,), jnp.int32).at[tgt].set(lanes, mode="drop")
    else:
        src = jnp.take(perm, jnp.clip(pos, 0, C - 1))
    # segment index in marshalled order IS the global destination (flat and
    # first hierarchical stage alike: lexicographic rank order)
    return (
        jnp.take(packed, src, axis=0),
        k.astype(jnp.int32),
        jnp.take(age, src).astype(jnp.int32) + 1,
        n_spill,
    )


def _clamp_subsegments(cnt: jax.Array, slot: int) -> Tuple[jax.Array, jax.Array]:
    """Truncate stacked sub-segments (rows of ``cnt``, concatenated in row
    order) to a ``slot``-row budget per column.

    ``cnt[i, j]``: rows of sub-segment ``i`` bound for slot column ``j``.
    Returns ``(allowed, starts)`` with the same shape: ``allowed`` keeps a
    contiguous prefix of each column's concatenation (any segment or segment
    tail past ``slot`` is cut — the §3.3 drop rule), ``starts`` is where each
    surviving sub-segment begins inside its slot.
    """
    raw_pref = jnp.cumsum(cnt, axis=0) - cnt
    allowed = jnp.clip(jnp.minimum(cnt, slot - raw_pref), 0)
    starts = jnp.cumsum(allowed, axis=0) - allowed
    return allowed, starts


def _ragged_control_plane(
    cnt: jax.Array, me: jax.Array, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """From the (R_src, R_dst) count matrix, derive my ragged-a2a parameters.

    Receiver-capacity clamp, replicated identically on all ranks: at each
    destination column ``d`` the senders' segments land at the exclusive
    prefix of the column; any segment (or segment tail) past ``capacity`` is
    cut — the §3.3 drop rule (:func:`_clamp_subsegments`), decided without a
    round trip.

    Returns ``(send_sizes (R,), output_offsets (R,), recv_sizes (R,))``.
    """
    allowed, roff = _clamp_subsegments(cnt, capacity)
    send_sizes = allowed[me]  # my row: what each peer lets me deliver
    output_offsets = roff[me]  # where my block lands on each peer
    recv_sizes = allowed[:, me]  # my column: what each peer delivers to me
    return send_sizes, output_offsets, recv_sizes


def _compact_blocks(
    recv_buf: jax.Array,  # (G, S, W) received padded blocks
    recv_counts: jax.Array,  # (G,) valid rows per block
    capacity: int,
    *,
    use_pallas: bool,
    front=None,  # retain mode: rows [0, front) are reserved for the spill
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Receive-side compaction shared by the padded-slot exchanges:
    ``out[roff[g] + s] = recv_buf[g, s]`` for ``s < recv_counts[g]``, rows
    past ``capacity`` dropped (§3.3).  Returns ``(out, new_count, drops)``.

    With ``front`` the arrivals land shifted by that many rows — the same
    scatter places them BEHIND the retained spill at zero extra cost, and
    ``new_count``/``drops`` account against the reduced room.
    """
    G, S, W = recv_buf.shape
    roff = jnp.cumsum(recv_counts) - recv_counts
    if front is not None:
        roff = roff + front
    if use_pallas:
        from repro.kernels.marshal import ops as marshal_ops

        out = marshal_ops.fused_unmarshal(recv_buf, roff, recv_counts, capacity=capacity)
    else:
        g_idx = jnp.repeat(jnp.arange(G, dtype=jnp.int32), S)
        s_idx = jnp.tile(jnp.arange(S, dtype=jnp.int32), G)
        dstpos = roff[g_idx] + s_idx
        ok = s_idx < recv_counts[g_idx]
        slot = jnp.where(ok & (dstpos < capacity), dstpos, capacity)
        out = jnp.zeros((capacity, W), recv_buf.dtype)
        out = out.at[slot].set(recv_buf.reshape(G * S, W), mode="drop")
    total_recv = jnp.sum(recv_counts)
    room = capacity if front is None else jnp.clip(capacity - front, 0)
    new_count = jnp.minimum(total_recv, room)
    return out, new_count, total_recv - new_count


def padded_send_buffer(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,  # (C,) sort mode: destination-sort permutation
    send_counts: jax.Array,  # (R,) valid-destination counts
    *,
    num_ranks: int,
    peer_capacity: int,
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,  # (C,) scatter mode: sanitized destination
    dest_rank: jax.Array = None,  # (C,) scatter mode: stable in-bucket rank
) -> jax.Array:
    """The padded exchange's send-side marshal — the round's ONE payload pass
    (isolated so ``benchmarks/run.py --profile`` can time it standalone).

    Sort mode gathers ``packed[perm[off[r] + s]]``; scatter mode scatters row
    ``i`` to ``dest_clean[i]·S + dest_rank[i]`` (rank ≥ S → §3.3 drop).
    Returns the ``(R, S, W)`` send buffer; rows past each segment's clamped
    count are garbage (sort) or zeros (scatter) and masked by the exchanged
    counts downstream.
    """
    R, S = num_ranks, peer_capacity
    cap = packed.shape[0]
    if marshal == "scatter":
        keep = (dest_clean < R) & (dest_rank < S)
        dstpos = jnp.where(keep, dest_clean * S + dest_rank, R * S)
        send_buf = _scatter(packed, dstpos, R * S, use_pallas=use_pallas)
        return send_buf.reshape(R, S, -1)
    off = jnp.cumsum(send_counts) - send_counts  # segment starts, sorted order
    r_idx = jnp.repeat(jnp.arange(R, dtype=jnp.int32), S)
    s_idx = jnp.tile(jnp.arange(S, dtype=jnp.int32), R)
    slotpos = jnp.clip(off[r_idx] + s_idx, 0, cap - 1)  # position in sorted order
    src = jnp.take(perm, slotpos)  # compose with the sort → source lane
    if use_pallas:
        from repro.kernels.marshal import ops as marshal_ops

        return marshal_ops.fused_marshal(packed, src, num_ranks=R, slot=S)
    return jnp.take(packed, src, axis=0).reshape(R, S, -1)


def exchange_padded(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,  # (C,) destination-sort permutation (sorted pos → lane)
    send_counts: jax.Array,  # (R,) valid-destination counts (histogram[:R])
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int,
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,  # (C,) scatter mode: sanitized destination
    dest_rank: jax.Array = None,  # (C,) scatter mode: stable in-bucket rank
    telemetry: bool = False,
    telemetry_buckets: int = 8,
    overflow: str = "drop",
    age: jax.Array = None,  # (C,) retain mode: rounds each lane has waited
):
    """Padded-slot exchange of the packed payload.

    Single-pass marshal, either mode: in sort mode the send buffer row for
    (peer r, slot s) is ``packed[perm[off[r] + s]]`` — destination sort and
    slot layout composed into ONE gather; in scatter mode row ``i`` goes
    straight to slot ``dest_clean[i]·S + dest_rank[i]`` (rank ≥ S → the §3.3
    sender clamp) — ONE scatter, no sort at all.  Either way the payload is
    read once and written once on the send side.  Returns ``(recv_packed,
    recv_counts, total, drops)``, plus a trailing ``RoundStats`` when
    ``telemetry`` (segment demand here = the per-peer send counts, measured
    against ``peer_capacity``).  With ``overflow="retain"`` the sender
    clamp's cut rows come back as a pending spill block ``(rows, dest, age,
    n_spill)`` inserted before the stats — extracted as the marshalled
    order's segment tails in the same pass style as the send gather — and
    the receive compaction lands arrivals BEHIND the reserved spill front,
    so ``drops`` reduces to the receiver-side admission count.
    """
    R, S = num_ranks, peer_capacity
    retain = overflow == "retain"
    clamped = jnp.minimum(send_counts, S)
    send_drops = jnp.sum(send_counts - clamped)
    front = None
    if retain:
        # The clamp's cut rows are the per-destination segment TAILS of the
        # marshalled order — extract them with the same positional
        # arithmetic the send gather uses (one extra (C, W) gather, no
        # conditional, no mask machinery) and reserve the queue front for
        # them.
        if age is None:
            age = jnp.zeros((packed.shape[0],), jnp.int32)
        off = jnp.cumsum(send_counts) - send_counts
        pending = (_lanes_spill(
            packed, perm, age, clamped, send_counts - clamped, off + clamped,
            send_drops, num_ranks=R, marshal=marshal,
            dest_clean=dest_clean, dest_rank=dest_rank,
        ),)
        front = jnp.minimum(send_drops, capacity)
        send_drops = jnp.zeros_like(send_drops)
    send_buf = padded_send_buffer(
        packed, perm, send_counts, num_ranks=R, peer_capacity=S,
        use_pallas=use_pallas, marshal=marshal,
        dest_clean=dest_clean, dest_rank=dest_rank,
    )
    recv_counts = exchange_counts(clamped, axis_name)  # the ONE count collective
    recv_buf = _a2a(send_buf, axis_name)  # the ONE payload collective

    out, new_count, recv_drops = _compact_blocks(
        recv_buf, recv_counts, capacity, use_pallas=use_pallas, front=front
    )
    drops = send_drops + recv_drops
    if telemetry:
        stats = TS.single_tier_stats(
            send_counts, S, telemetry_buckets,
            sent_rows=jnp.sum(clamped), stage_drops=send_drops,
            recv_total=jnp.sum(recv_counts), recv_drops=recv_drops,
        )
        if retain:
            return out, recv_counts, new_count, drops, pending, stats
        return out, recv_counts, new_count, drops, stats
    if retain:
        return out, recv_counts, new_count, drops, pending
    return out, recv_counts, new_count, drops


def _subsegment_gather(
    allowed: jax.Array,  # (G, K) surviving sub-segment sizes per slot column k
    starts: jax.Array,  # (G, K) slot-local sub-segment starts
    src_base: jax.Array,  # (G, K) source offset of sub-segment (g, k)
    slot: int,
) -> jax.Array:
    """Source row index for every (slot column k, slot position s).

    Returns ``(K, slot)`` int32: the flat source row feeding slot ``k``'s
    position ``s`` — rows past a column's total are clamped garbage, masked
    downstream by the exchanged counts.  This is the composed two-stage
    layout: one gather materialises a whole stage's send buffer.
    """
    G, K = allowed.shape
    s_idx = jnp.arange(slot, dtype=jnp.int32)
    incl = jnp.cumsum(allowed, axis=0)  # (G, K) inclusive prefix per column
    # sub-segment owning position s = number of fully-completed predecessors
    g_of = jnp.sum(s_idx[None, :, None] >= incl.T[:, None, :], axis=-1)  # (K, slot)
    g_c = jnp.clip(g_of, 0, G - 1)
    k_grid = jnp.arange(K, dtype=jnp.int32)[:, None]
    s_local = s_idx[None, :] - starts[g_c, k_grid]
    return src_base[g_c, k_grid] + s_local


def exchange_hierarchical(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,  # (C,) lexicographic destination-sort permutation
    send_counts: jax.Array,  # (R,) valid-destination counts, slowest-major
    *,
    axis_name,  # (slowest, …, fastest) mesh axis names, one per tier
    num_ranks: int,
    capacity: int,
    level_sizes: Tuple[int, ...],  # ranks per tier, slowest first
    level_capacities: Tuple[int, ...],  # padded rows per peer segment, per tier
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,  # (C,) scatter mode: sanitized destination
    dest_rank: jax.Array = None,  # (C,) scatter mode: stable in-bucket rank
    telemetry: bool = False,
    telemetry_buckets: int = 8,
    overflow: str = "drop",
    age: jax.Array = None,  # (C,) retain mode: rounds each lane has waited
):
    """N-stage packed exchange over an N-D ``(slowest, …, fastest)`` mesh.

    Dimension-ordered routing, fastest axis first: stage ``l`` combines
    traffic within axis ``l`` so every item lands on a rank whose digit ``l``
    equals its destination's — slower stages re-exchange only aggregated,
    already-packed segments, and bulk bytes cross each fabric tier exactly
    once, padded per peer SEGMENT at that tier (``level_capacities[l]``
    rows), never per rank.

    Budget: one payload + one count collective per mesh axis; extent-1 axes
    skip their stage entirely (so a single-node mesh degenerates to
    flat-exchange cost parity).  Returns ``(recv_packed, recv_counts, total,
    drops)`` — counts are per *source group* of the slowest non-trivial axis,
    unlike the flat backends' per-rank counts.

    Marshal modes: the first non-trivial stage is the round's single local
    payload pass — in sort mode the destination-sort permutation is composed
    into that stage's send gather; in scatter mode each row is scattered
    straight to its stage slot ``d_l·S + starts[rest, d_l] + rank`` (the
    in-bucket rank against the FULL destination is exactly the in-sub-segment
    rank, because every sub-segment holds one destination).  Every stage's
    sub-segment counts/offsets derive from the ONE histogram (reshaped per
    tier) and the per-stage count collectives — the sorted destination vector
    is never re-scanned (no per-tier ``segment_bounds_from_sorted`` neighbor
    compares), on either marshal path.

    With ``telemetry`` a trailing ``RoundStats`` is returned: tier ``l``'s
    segment demand is the pre-clamp row total per peer slot COLUMN of stage
    ``l`` (the concatenated sub-segments one ``level_capacities[l]`` budget
    clamps), measured against that budget; extent-1 tiers skip their stage
    and stay zero.  Demand at tier ``l`` is post-clamp of the faster tiers —
    exactly the traffic the stage observes (and the reason the capacity
    controller converges over a few bursts rather than in one).

    With ``overflow="retain"`` every stage clamp parks its cut rows at the
    rank they currently sit on instead of dropping them: the first stage
    spills input LANES (sender clamp — the per-destination segment tails of
    the sorted order, ages carried forward); later stages spill mid-route
    BUFFER rows (sub-segment tails read straight out of the stage buffer)
    re-addressed through ``seg_dest`` — the sub-segment → global-destination
    map, maintained locally because after stage ``l`` every peer of the
    remaining stages shares the already-routed digits (mid-route rows
    restart at age 1: age cannot ride the wire without changing the payload
    bytes).  One pending ``(rows, dest, age, n)`` spill block per non-trivial
    stage rides back before the stats, the final compaction lands arrivals
    behind the reserved spill front, and stage drops move into the blocks —
    ``drops`` reduces to the receiver-side admission count.
    """
    level_sizes = tuple(int(a) for a in level_sizes)
    R = num_ranks
    C, W = packed.shape
    rec = TS.make_stats(len(level_sizes), telemetry_buckets) if telemetry else None
    retain = overflow == "retain"
    seg_dest = None
    pending = []  # pending spill blocks: one (rows, dest, age, n) per stage
    spill_run = jnp.zeros((), send_counts.dtype)  # total rows parked so far
    if retain:
        if age is None:
            age = jnp.zeros((C,), jnp.int32)
        # Which global destination does sub-segment k of the current buffer
        # hold?  Identity at the start (sorted destination order); updated
        # after each non-final stage from digits all later-stage peers share.
        seg_dest = jnp.arange(R, dtype=jnp.int32)

    def gather(buf, rows, n_slots, slot):
        if use_pallas:
            from repro.kernels.marshal import ops as marshal_ops

            return marshal_ops.fused_marshal(buf, rows, num_ranks=n_slots, slot=slot)
        return jnp.take(buf, rows, axis=0).reshape(n_slots, slot, W)

    # Sub-segment state, always exactly R entries: counts and buffer offsets
    # in the current buffer order (initially the sorted destination order,
    # digits slowest-major).  Each stage reinterprets the vector as
    # (rest, A_l) — its peer digit is the fastest-varying non-trivial field —
    # and afterwards prepends the source digit: (A_l, rest) flattened.
    cnt = send_counts
    base = jnp.cumsum(cnt) - cnt
    buf, n_rows, via_perm = packed, C, True
    drops = jnp.zeros((), send_counts.dtype)

    stages = [l for l in reversed(range(len(level_sizes))) if level_sizes[l] > 1]
    if not stages:
        # 1-rank mesh: the round is a local compaction — no collectives
        allowed = jnp.minimum(cnt, capacity)
        if marshal == "scatter":
            keep = (dest_clean < R) & (dest_rank < capacity)
            out = _scatter(
                packed,
                jnp.where(keep, dest_rank, capacity),
                capacity,
                use_pallas=use_pallas,
            )
        else:
            rows = jnp.take(perm, jnp.clip(jnp.arange(capacity), 0, C - 1))
            out = gather(packed, rows, 1, capacity)[0]
        local_drops = jnp.sum(cnt - allowed)
        if telemetry:
            # no stage ran: only the receiver-side compaction is observable
            rec = dataclasses.replace(
                rec,
                recv_total=jnp.sum(cnt).astype(jnp.int32),
                recv_drops=local_drops.astype(jnp.int32),
            )
            if retain:  # no stage clamp ran either: nothing to spill
                return out, allowed, allowed[0], local_drops, (), rec
            return out, allowed, allowed[0], local_drops, rec
        if retain:
            return out, allowed, allowed[0], local_drops, ()
        return out, allowed, allowed[0], local_drops

    for i, l in enumerate(stages):
        A, S = level_sizes[l], level_capacities[l]
        cnt2d = cnt.reshape(R // A, A)  # rows: buffer order, cols: peer digit
        allowed, starts = _clamp_subsegments(cnt2d, S)
        stage_drops = jnp.sum(cnt2d - allowed)
        if retain:
            alf = allowed.reshape(-1)  # flat, current buffer/destination order
            if via_perm:
                # Sender-clamp spill from the INPUT lanes: the cut rows are
                # the per-destination segment tails of the sorted order
                # (allowed is indexed [d // A, d % A], so its row-major
                # flatten is the per-destination allowance; at the first
                # stage buffer order == destination order, and the stable
                # in-bucket rank against the full destination IS the
                # in-sub-segment rank — the scatter marshal's equivalence).
                pending.append(_lanes_spill(
                    packed, perm, age, alf, cnt - alf, base + alf,
                    stage_drops, num_ranks=R, marshal=marshal,
                    dest_clean=dest_clean, dest_rank=dest_rank,
                ))
            else:
                # Mid-route park: buffer rows whose sub-segment tail this
                # stage cut stay HERE; destination routing resumes them next
                # round.  Tails are read straight out of the stage buffer
                # (marshal-mode-agnostic: positions, not lanes) and
                # re-addressed through ``seg_dest``; ages restart at 1 (age
                # cannot ride the wire without changing the payload bytes).
                k, pos = _spill_positions(capacity, cnt - alf, base + alf)
                src = jnp.clip(pos, 0, n_rows - 1)
                pending.append((
                    jnp.take(buf, src, axis=0),
                    jnp.take(seg_dest, k),
                    jnp.ones((capacity,), jnp.int32),
                    stage_drops,
                ))
            spill_run = spill_run + stage_drops
            stage_drops = jnp.zeros_like(stage_drops)
        drops = drops + stage_drops
        if telemetry:
            # segment demand at tier l = pre-clamp rows per peer slot column
            col_demand = jnp.sum(cnt2d, axis=0)
            rec = dataclasses.replace(
                rec,
                demand_hist=rec.demand_hist.at[l].set(
                    TS.occupancy_histogram(col_demand, S, telemetry_buckets)
                ),
                demand_max=rec.demand_max.at[l].set(jnp.max(col_demand)),
                demand_total=rec.demand_total.at[l].set(jnp.sum(col_demand)),
                sent_rows=rec.sent_rows.at[l].set(jnp.sum(allowed)),
                stage_drops=rec.stage_drops.at[l].set(stage_drops),
            )
        if via_perm and marshal == "scatter":
            # first non-trivial stage, sort-free: scatter each row straight
            # into the stage layout — the payload's single local pass of the
            # round.  Sub-segment (rest, d_l) holds exactly one destination,
            # so the in-bucket rank IS the in-sub-segment position; ranks at
            # or past the stage clamp land in the trash slot (§3.3).
            row = jnp.clip(dest_clean // A, 0, R // A - 1)
            col = jnp.clip(dest_clean % A, 0, A - 1)
            keep = (dest_clean < R) & (dest_rank < allowed[row, col])
            dstpos = jnp.where(
                keep, col * S + starts[row, col] + dest_rank, A * S
            )
            send = _scatter(packed, dstpos, A * S, use_pallas=use_pallas)
            send = send.reshape(A, S, W)
        else:
            pos = _subsegment_gather(allowed, starts, base.reshape(R // A, A), S)
            if via_perm:
                # first non-trivial stage: compose the sort permutation
                # straight into the send gather — the payload's single read
                # of the round
                rows = jnp.take(perm, jnp.clip(pos, 0, C - 1).reshape(-1))
            else:
                rows = jnp.clip(pos, 0, n_rows - 1).reshape(-1)
            send = gather(buf, rows, A, S)

        if i == len(stages) - 1:
            # final stage: per-source-group totals suffice — blocks are
            # contiguous prefixes, compacted straight into the receive queue
            recv_counts = _a2a(jnp.sum(allowed, axis=0)[:, None], axis_name[l])
            recv_counts = recv_counts.reshape(-1)
            recv = _a2a(send, axis_name[l])
            out, new_count, recv_drops = _compact_blocks(
                recv, recv_counts, capacity, use_pallas=use_pallas,
                front=jnp.minimum(spill_run, capacity) if retain else None,
            )
            if telemetry:
                rec = dataclasses.replace(
                    rec,
                    recv_total=jnp.sum(recv_counts).astype(jnp.int32),
                    recv_drops=recv_drops.astype(jnp.int32),
                )
                if retain:
                    return (out, recv_counts, new_count,
                            drops + recv_drops, tuple(pending), rec)
                return out, recv_counts, new_count, drops + recv_drops, rec
            if retain:
                return (out, recv_counts, new_count,
                        drops + recv_drops, tuple(pending))
            return out, recv_counts, new_count, drops + recv_drops

        # count collective for axis l: per-sub-segment survivor counts, so
        # the receiver can address every sub-segment of each incoming block
        rcv = _a2a(allowed.T, axis_name[l])  # (A, R//A): [src digit, sub-seg]
        recv = _a2a(send, axis_name[l])  # payload collective for axis l
        cnt = rcv.reshape(-1)  # new buffer order: (s_l, previous order − d_l)
        base = (
            jnp.cumsum(rcv, axis=1) - rcv
            + jnp.arange(A, dtype=jnp.int32)[:, None] * S
        ).reshape(-1)
        buf, n_rows, via_perm = recv.reshape(A * S, W), A * S, False
        if retain:
            # Sub-segment k of the NEW buffer order (s_l, rest) holds the
            # destination whose digit l equals MINE — shared with every peer
            # of the remaining (slower) stages, so the map stays
            # rank-consistent with zero extra communication.
            me_l = jax.lax.axis_index(axis_name[l])
            seg_dest = jnp.tile(seg_dest.reshape(R // A, A)[:, me_l], A)


def exchange_ragged(
    packed: jax.Array,  # (C, W) uint32 — UNSORTED packed payload
    perm: jax.Array,
    send_counts: jax.Array,  # (R,)
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,  # unused; signature parity
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,  # (C,) scatter mode: sanitized destination
    dest_rank: jax.Array = None,  # (C,) scatter mode: stable in-bucket rank
    telemetry: bool = False,
    telemetry_buckets: int = 8,
    overflow: str = "drop",
    age: jax.Array = None,  # (C,) retain mode: rounds each lane has waited
):
    """ragged_all_to_all exchange — the MPI_Alltoallv / GPU-RDMA analogue.

    The packed payload is placed ONCE into destination order (contiguous
    per-peer segments) — a gather through the sort permutation, or a sort-free
    scatter to ``off[dest] + rank`` — and shipped in ONE variable-size
    collective; the receive side is written compacted directly (no unpack
    pass), which is the paper's "large contiguous blocks at very high
    bandwidth" property.  The control plane is one all-gather of the
    send-count vector (see :func:`exchange_count_matrix`).  With
    ``overflow="retain"`` the rows past each segment's control-plane
    allowance (``send_sizes``) come back as a pending spill block instead
    of being dropped — the shipped segments are unchanged.
    """
    del peer_capacity  # segments are contiguous: no slot gather
    retain = overflow == "retain"
    me = jax.lax.axis_index(axis_name)
    off = jnp.cumsum(send_counts) - send_counts

    cnt = exchange_count_matrix(send_counts, axis_name)  # the ONE count collective
    send_sizes, output_offsets, recv_sizes = _ragged_control_plane(cnt, me, capacity)
    send_drops = jnp.sum(send_counts - send_sizes)
    front = None
    if retain:
        # Segment-tail spill extraction, exactly as exchange_padded — the
        # allowance here is the control plane's ``send_sizes``.
        if age is None:
            age = jnp.zeros((packed.shape[0],), jnp.int32)
        pending = (_lanes_spill(
            packed, perm, age, send_sizes, send_counts - send_sizes,
            off + send_sizes, send_drops, num_ranks=num_ranks,
            marshal=marshal, dest_clean=dest_clean, dest_rank=dest_rank,
        ),)
        front = jnp.minimum(send_drops, capacity)
        send_drops = jnp.zeros_like(send_drops)

    if marshal == "scatter":  # the ONE payload pass, sort-free
        keep = dest_clean < num_ranks
        pos = off[jnp.clip(dest_clean, 0, num_ranks - 1)] + dest_rank
        dstpos = jnp.where(keep, pos, packed.shape[0])
        sorted_packed = _scatter(
            packed, dstpos, packed.shape[0], use_pallas=use_pallas
        )
    else:
        sorted_packed = jnp.take(packed, perm, axis=0)  # the ONE payload permute
    out = jnp.zeros((capacity, packed.shape[1]), packed.dtype)
    out = compat.ragged_all_to_all(  # the ONE payload collective
        sorted_packed,
        out,
        input_offsets=off,
        send_sizes=send_sizes,
        output_offsets=output_offsets,
        recv_sizes=recv_sizes,
        axis_name=axis_name,
    )
    new_count = jnp.sum(recv_sizes)
    recv_cut = jnp.zeros((), send_counts.dtype)
    if retain:
        # The collective's landing offsets are fixed by the replicated
        # control plane, so the spill front is opened AFTER the exchange by
        # one local gather (this backend is lower-only on CPU, so the extra
        # pass is off the walltime gate); arrivals pushed past capacity are
        # the receiver-admission loss.
        lane = jnp.arange(capacity, dtype=jnp.int32)
        out = jnp.take(out, jnp.clip(lane - front, 0, capacity - 1), axis=0)
        admitted = jnp.minimum(new_count, capacity - front)
        recv_cut = new_count - admitted
        new_count = admitted
    if telemetry:
        # No per-peer slots here — the §3.3 clamp is the receiver queue, so
        # segment demand = the count matrix's per-destination column totals
        # (replicated identically on every rank; quantiles/maxima are
        # unaffected, totals are ×R — documented in telemetry.summarize's
        # population semantics).  Senders own the drop accounting on this
        # backend (each counts what the control plane cut from its row), so
        # recv_drops stays 0 — stats sum to the exchange's drops return.
        col_demand = jnp.sum(cnt, axis=0)
        stats = TS.single_tier_stats(
            col_demand, capacity, telemetry_buckets,
            sent_rows=jnp.sum(send_sizes), stage_drops=send_drops,
            recv_total=col_demand[me], recv_drops=recv_cut.astype(jnp.int32),
        )
        if retain:
            return out, recv_sizes, new_count, send_drops + recv_cut, pending, stats
        return out, recv_sizes, new_count, send_drops, stats
    if retain:
        return out, recv_sizes, new_count, send_drops + recv_cut, pending
    return out, recv_sizes, new_count, send_drops


def exchange_onehot(
    packed: jax.Array,
    perm: jax.Array,
    send_counts: jax.Array,
    *,
    axis_name,
    num_ranks: int,
    capacity: int,
    peer_capacity: int = 0,
    use_pallas: bool = False,
    marshal: str = "sort",
    dest_clean: jax.Array = None,
    dest_rank: jax.Array = None,
    telemetry: bool = False,
    telemetry_buckets: int = 8,
    overflow: str = "drop",
    age: jax.Array = None,  # unused: the oracle has no sender clamp
):
    """All-gather reference oracle (tests only): every rank sees everything,
    selects what is addressed to it, and compacts stably by (source, lane).
    Deliberately a different code path from the production backends (in
    scatter mode only the initial into-destination-order placement differs).
    With ``overflow="retain"`` the pending spill plan is empty by
    construction — there is no sender clamp to spill from; the receiver
    clamp stays a counted drop (there is no bounded place left to keep those
    rows).
    """
    del peer_capacity, age
    retain = overflow == "retain"
    R = num_ranks
    me = jax.lax.axis_index(axis_name)
    off = jnp.cumsum(send_counts) - send_counts
    cap = packed.shape[0]
    if marshal == "scatter":
        keep = dest_clean < R
        pos = off[jnp.clip(dest_clean, 0, R - 1)] + dest_rank
        sorted_packed = _scatter(
            packed, jnp.where(keep, pos, cap), cap, use_pallas=use_pallas
        )
    else:
        sorted_packed = jnp.take(packed, perm, axis=0)
    lane = jnp.arange(cap, dtype=jnp.int32)
    # reconstruct per-item dest from segments: dest[i] = r iff off[r] <= i < off[r]+cnt
    seg_end = off + send_counts
    dest = jnp.sum((lane[:, None] >= seg_end[None, :]).astype(jnp.int32), axis=1)
    dest = jnp.where(lane < jnp.sum(send_counts), dest, R)

    all_packed = jax.lax.all_gather(sorted_packed, axis_name)  # (R, cap, W)
    all_dest = jax.lax.all_gather(dest, axis_name)  # (R, cap)
    mine = (all_dest == me).reshape(-1)
    order = jnp.argsort(~mine, stable=True)  # mine first, stable (src, lane) order
    flat = all_packed.reshape(R * cap, -1)
    gathered = jnp.take(flat, order[:capacity], axis=0, mode="clip")
    total = jnp.sum(mine.astype(jnp.int32))
    new_count = jnp.minimum(total, capacity)
    recv_counts = jnp.sum((all_dest == me).astype(jnp.int32), axis=1)
    if telemetry:
        # oracle capture: my per-destination send counts vs the receiver
        # queue (the only clamp this backend has)
        stats = TS.single_tier_stats(
            send_counts, capacity, telemetry_buckets,
            sent_rows=jnp.sum(send_counts), stage_drops=jnp.zeros((), jnp.int32),
            recv_total=total, recv_drops=total - new_count,
        )
        if retain:
            return gathered, recv_counts, new_count, total - new_count, (), stats
        return gathered, recv_counts, new_count, total - new_count, stats
    if retain:
        return gathered, recv_counts, new_count, total - new_count, ()
    return gathered, recv_counts, new_count, total - new_count

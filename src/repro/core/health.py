"""Rank-health remap law — the draining half of the recovery law (ISSUE 7).

A ``health: (R,) bool`` mask marks ranks that should stop RECEIVING work
(draining before a maintenance window, browned-out, about to be preempted).
The contract is a **pure local destination remap** applied pre-marshal:

  * a destination on a healthy rank is untouched;
  * a destination on an unhealthy rank ``d`` is rewritten to the fixed
    fallback ``healthy[d % n_healthy]`` where ``healthy`` is the ascending
    list of healthy ranks — deterministic, replicated arithmetic on the
    (R,) mask, so every rank computes the identical table and the routed
    traffic stays consistent without ANY coordination;
  * ``DISCARD`` lanes (and anything negative) pass through untouched.

Because the remap is (C,)-vector integer math on values the marshal already
reads, it adds ZERO collectives and ZERO payload passes: the lowered
collective inventory of a health-masked round is bit-identical to the plain
round (guarded in ``tests/test_collective_budget.py``).  With every rank
healthy the table is the identity, so ``health=None`` and an all-True mask
produce bit-identical results.

Degenerate case: an all-unhealthy mask has no fallback to route to — the
table falls back to the identity (traffic flows as addressed).  Draining the
whole mesh is a shutdown, not a remap; callers that mean "stop everything"
should stop driving rounds instead.

The same law is applied host-side by the chaos oracle's numpy twin
(``repro.chaos.oracle``) — one definition, verified twice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["health_table", "remap_dest"]


def health_table(health: jax.Array) -> jax.Array:
    """``(R,) int32`` destination-rewrite table for a ``(R,) bool`` mask.

    ``table[d] == d`` for healthy ``d``; ``table[d] == healthy[d % n_h]``
    for unhealthy ``d`` (identity when no rank is healthy).  Pure replicated
    arithmetic — no collectives, no data-dependent shapes.
    """
    h = health.astype(bool)
    R = h.shape[0]
    rank = jnp.arange(R, dtype=jnp.int32)
    n_h = jnp.sum(h.astype(jnp.int32))
    # ascending healthy ranks, scatter-built (traced nonzero has no static
    # shape): healthy rank r lands at its slot cumsum(h)[r]-1, unhealthy
    # ranks aim past the end and are dropped
    slot = jnp.where(h, jnp.cumsum(h.astype(jnp.int32)) - 1, R)
    healthy = (
        jnp.zeros((R,), jnp.int32).at[slot].set(rank, mode="drop")
    )
    fallback = healthy[rank % jnp.maximum(n_h, 1)]
    table = jnp.where(h, rank, fallback)
    return jnp.where(n_h > 0, table, rank).astype(jnp.int32)


def remap_dest(dest: jax.Array, health: jax.Array) -> jax.Array:
    """Re-address a destination vector through :func:`health_table`.

    ``dest`` entries in ``[0, R)`` are rewritten; negative entries
    (``DISCARD`` lanes) pass through.  Entries beyond the queue's valid
    ``count`` may hold junk — they are clamped for the table lookup and the
    marshal's own count-based sanitization ignores them, exactly as it does
    without the remap.
    """
    table = health_table(health)
    R = table.shape[0]
    looked = table[jnp.clip(dest, 0, R - 1)]
    return jnp.where(dest >= 0, looked, dest).astype(jnp.int32)

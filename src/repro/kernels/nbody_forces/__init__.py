from repro.kernels.nbody_forces import kernel, ops, ref  # noqa: F401

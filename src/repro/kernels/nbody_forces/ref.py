"""Pure-jnp oracle for the nbody_forces kernel."""
import jax.numpy as jnp


def pairwise_accel(xi, xj, mj, *, eps2=1e-4):
    dx = xj[None, :, :] - xi[:, None, :]
    r2 = jnp.sum(dx * dx, axis=-1) + eps2
    w = mj[None, :] * r2 ** (-1.5)
    return jnp.sum(w[:, :, None] * dx, axis=1)

"""Pallas kernel: tiled O(N·M) pairwise gravity for the N-body app (§5.5).

Computes softened monopole accelerations of N target particles due to M
sources (sources = local particles ∪ received VirtualParticles).  Classic
two-level tiling: grid (N/TI, M/TJ) with the source loop innermost; the
(TI, 3) accumulator lives in the revisited output block (sequential TPU grid
⇒ safe).  All math is rank-2 broadcasts on the VPU with TI×TJ inner shapes —
multiples of 128 keep the lanes full.

VMEM per step: TI·4·4 + TJ·4·4 + TI·TJ·(3+1)·4 B ≈ 1.1 MB at TI=TJ=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import sds


def _forces_kernel(xi_ref, xj_ref, mj_ref, out_ref, *, eps2, nj_steps):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xi = xi_ref[...]  # (TI, 3)
    xj = xj_ref[...]  # (TJ, 3)
    mj = mj_ref[...]  # (TJ,)
    dx = xj[None, :, :] - xi[:, None, :]  # (TI, TJ, 3)
    r2 = jnp.sum(dx * dx, axis=-1) + eps2  # (TI, TJ)
    inv = jax.lax.rsqrt(r2)
    w = mj[None, :] * inv * inv * inv  # G·m_j / r³ (G folded in by caller)
    out_ref[...] += jnp.sum(w[:, :, None] * dx, axis=1)


@functools.partial(jax.jit, static_argnames=("eps2", "ti", "tj", "interpret"))
def pairwise_accel(
    xi: jax.Array,  # (N, 3) targets
    xj: jax.Array,  # (M, 3) sources
    mj: jax.Array,  # (M,) source masses (zero mass ⇒ inert padding lane)
    *,
    eps2: float = 1e-4,
    ti: int = 256,
    tj: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """(N, 3) accelerations: a_i = Σ_j m_j (x_j − x_i) / (|x_j − x_i|² + ε²)^{3/2}."""
    n, m = xi.shape[0], xj.shape[0]
    ti = min(ti, n)
    while n % ti:
        ti //= 2
    tj = min(tj, m)
    while m % tj:
        tj //= 2
    grid = (n // ti, m // tj)
    return pl.pallas_call(
        functools.partial(_forces_kernel, eps2=eps2, nj_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((tj, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((tj,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((ti, 3), lambda i, j: (i, 0)),
        out_shape=sds((n, 3), jnp.float32, xi, xj, mj),
        interpret=interpret,
    )(xi, xj, mj)

"""Public wrapper for pairwise gravity."""
from __future__ import annotations

import jax

from repro.kernels import default_interpret
from repro.kernels.nbody_forces import kernel as K


def pairwise_accel(xi, xj, mj, *, eps2: float = 1e-4, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return K.pairwise_accel(xi, xj, mj, eps2=eps2, interpret=interpret)

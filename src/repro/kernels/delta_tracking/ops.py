"""Public wrapper for Woodcock tracking."""
from __future__ import annotations

from repro.kernels import default_interpret
from repro.kernels.delta_tracking import kernel as K

STILL, HIT, EXITED = K.STILL, K.HIT, K.EXITED


def track(origins, dirs, t0, t_exit, uniforms, blobs, *, majorant, steps=8, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return K.track(
        origins, dirs, t0, t_exit, uniforms, blobs,
        majorant=majorant, steps=steps, interpret=interpret,
    )

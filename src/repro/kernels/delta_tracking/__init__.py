from repro.kernels.delta_tracking import kernel, ops, ref  # noqa: F401

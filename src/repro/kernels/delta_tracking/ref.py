"""Pure-jnp oracle for the delta_tracking kernel."""
import jax.numpy as jnp

STILL, HIT, EXITED = 0, 1, 2


def density(p, blobs):
    d = p[..., None, :] - blobs[None, :, :3]
    r2 = jnp.sum(d * d, axis=-1)
    s2 = blobs[None, :, 3] ** 2
    return jnp.sum(blobs[None, :, 4] * jnp.exp(-0.5 * r2 / s2), axis=-1)


def track(origins, dirs, t0, t_exit, uniforms, blobs, *, majorant, steps=8):
    t = t0
    status = jnp.zeros(t.shape, jnp.int32)
    for k in range(steps):
        active = status == STILL
        t_new = t - jnp.log1p(-uniforms[:, k, 0]) / majorant
        p = origins + t_new[:, None] * dirs
        dens = density(p, blobs)
        exited = active & (t_new >= t_exit)
        hit = active & ~exited & (uniforms[:, k, 1] * majorant < dens)
        t = jnp.where(active, t_new, t)
        status = jnp.where(exited, EXITED, jnp.where(hit, HIT, status))
    return t, status

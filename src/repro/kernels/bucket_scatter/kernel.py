"""Pallas kernels: the fused single-pass bucket-scatter marshal.

``rank_and_histogram`` — the counting-sort control plane, replacing key
pack + ``jax.lax.sort``: one pass over the destination vector yields the
sanitized destination, each lane's stable rank among earlier lanes of the
SAME destination, and the per-destination histogram (= the exchange's send
counts, for free).  ``base[dest] + rank`` then reproduces the §4.2.1 stable
sort placement exactly — no key materialization, no O(C log C) sort.

The prefix is computed in CHUNK-row blocks mapped onto the MXU: a
strictly-lower-triangular (CHUNK, CHUNK) mask matmul'd with the chunk's
one-hot destination matrix gives every lane its exclusive same-bucket count
inside the chunk; chunk totals roll into a running histogram between blocks,
and the running histogram itself is carried across grid steps in the
revisited histogram output block (TPU grid steps run sequentially — the
canonical Pallas reduction pattern, as in ``kernels/sort_keys``).

VMEM budget per step: TILE·3·4 B (dest, d_clean, rank) + CHUNK²·4 B (the
64 KiB triangular mask at CHUNK=128) + CHUNK·(R+1)·4 B (one-hot) — for
TILE=2048, R=512: ~120 KiB, far inside a v5e core's ~16 MB.

``scatter_rows`` — the single payload pass: ``out[dstpos[i]] = src[i]``.
The caller composes the bucket plan with the send layout
(``dstpos = base[dest] + rank``); each grid step stores a TILE of rows at
dynamically-addressed offsets of the revisited output block (grid steps are
sequential, so the read-modify-write is race-free — same contract as
``kernels/marshal.unmarshal``).  A trash row past the last slot absorbs
dropped lanes (invalid destination, or rank beyond the segment clamp — the
§3.3 drop rule) and is cut from the result.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import sds


def _rank_hist_kernel(
    dest_ref, count_ref, dclean_ref, rank_ref, hist_ref, *, num_ranks, tile, chunk
):
    step = pl.program_id(0)
    lane0 = step * tile
    lane = lane0 + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    d = dest_ref[...]
    count = count_ref[0]
    valid = (lane < count) & (d >= 0) & (d < num_ranks)
    d_clean = jnp.where(valid, d, num_ranks)
    dclean_ref[...] = d_clean

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ).astype(jnp.float32)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, num_ranks + 1), 1)
    run = hist_ref[...].astype(jnp.float32)  # totals of all previous lanes
    for c in range(tile // chunk):  # static unroll: CHUNK-row prefix blocks
        d_c = jax.lax.dynamic_slice(d_clean, (c * chunk,), (chunk,))
        onehot = (d_c[:, None] == r_iota).astype(jnp.float32)
        excl = jax.lax.dot_general(  # strictly-lower tri → exclusive prefix
            tri, onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        rank_c = jnp.sum((excl + run[None, :]) * onehot, axis=1)
        rank_ref[pl.ds(c * chunk, chunk)] = rank_c.astype(jnp.int32)
        run = run + jnp.sum(onehot, axis=0)
    hist_ref[...] = run.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("num_ranks", "tile", "chunk", "interpret")
)
def rank_and_histogram(
    dest: jax.Array,
    count: jax.Array,
    *,
    num_ranks: int,
    tile: int = 2048,
    chunk: int = 0,
    interpret: bool = False,
):
    """Returns ``(d_clean (C,) i32, rank (C,) i32, hist (R+1,) i32)``; invalid
    lanes get destination R and rank among the R-bucket tail.

    Counts ride the MXU in float32, exact only below 2**24 — larger
    capacities raise (the scatter analogue of ``pack_keys``'s 32-bit key
    overflow ValueError; use the XLA path, which scans in int32).
    """
    cap = dest.shape[0]
    if cap > 1 << 24:
        raise ValueError(
            f"capacity {cap} exceeds the float32-exact count range (2**24); "
            "in-bucket ranks would silently collide — use the XLA path "
            "(core.sorting.destination_rank)"
        )
    tile = min(tile, cap)
    if cap % tile:
        raise ValueError(f"capacity {cap} not divisible by tile {tile}")
    chunk = chunk or math.gcd(tile, 128)
    if tile % chunk:
        raise ValueError(f"tile {tile} not divisible by chunk {chunk}")
    kern = functools.partial(
        _rank_hist_kernel, num_ranks=num_ranks, tile=tile, chunk=chunk
    )
    return pl.pallas_call(
        kern,
        grid=(cap // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((num_ranks + 1,), lambda i: (0,)),
        ],
        out_shape=[
            sds((cap,), jnp.int32, dest, count),
            sds((cap,), jnp.int32, dest, count),
            sds((num_ranks + 1,), jnp.int32, dest, count),
        ],
        interpret=interpret,
    )(dest, count.reshape(1).astype(jnp.int32))


def _scatter_rows_kernel(idx_ref, in_ref, out_ref, *, tile):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    for t in range(tile):  # static unroll: `tile` dynamic row stores per step
        out_ref[pl.ds(idx_ref[i * tile + t], 1), :] = in_ref[pl.ds(t, 1), :]


@functools.partial(jax.jit, static_argnames=("num_slots", "interpret", "tile"))
def scatter_rows(
    src: jax.Array,  # (N, D) packed payload rows
    dstpos: jax.Array,  # (N,) int32 send-layout row per source row
    *,
    num_slots: int,
    interpret: bool = False,
    tile: int = 8,
) -> jax.Array:
    """The fused single-pass scatter marshal: ``out[dstpos[i]] = src[i]``.

    ``dstpos`` is the bucket plan composed with the send layout
    (``base[dest] + rank``), so this one scatter subsumes what used to be
    key-sort-then-segment-gather — each payload row is read exactly once and
    written exactly once.  Rows with ``dstpos`` at/past ``num_slots`` (or
    negative) land in a trash row that is cut from the result (§3.3 drops);
    untouched slots are zero.  The index vector lands in SMEM by scalar
    prefetch; each grid step stores a TILE of rows (padded up to a whole
    tile, padding aimed at the trash row).
    """
    n, d = src.shape
    pos = dstpos.astype(jnp.int32)
    # out-of-range EITHER side (negative, or at/past num_slots) → trash row
    idx = jnp.where((pos < 0) | (pos > num_slots), num_slots, pos)
    n_pad = -(-n // tile) * tile
    if n_pad != n:
        idx = jnp.concatenate([idx, jnp.full((n_pad - n,), num_slots, jnp.int32)])
        src = jnp.concatenate([src, jnp.zeros((n_pad - n, d), src.dtype)])
    out = pl.pallas_call(
        functools.partial(_scatter_rows_kernel, tile=tile),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_pad // tile,),
            in_specs=[pl.BlockSpec((tile, d), lambda i, idx: (i, 0))],
            out_specs=pl.BlockSpec((num_slots + 1, d), lambda i, idx: (0, 0)),
        ),
        out_shape=sds((num_slots + 1, d), src.dtype, src, idx),
        interpret=interpret,
    )(idx, src)
    return out[:num_slots]

"""Fused bucket-scatter marshal: histogram + prefix-scan + scatter, sort-free.

The §4.2.1 sort exists only to make per-destination segments contiguous for
the exchange.  Destination ranks live in a tiny domain (R ≤ a few hundred),
so a counting sort wins outright: ``rank_and_histogram`` computes each item's
stable rank within its destination bucket AND the per-destination histogram
in one pass over the (1-word-per-item) destination vector, and
``scatter_rows`` places packed payload rows directly at ``base[dest] + rank``
in the send-buffer layout — one payload pass, no keys, no sort, no separate
gather.  ``ForwardConfig(marshal="scatter")`` routes here; the sort path
stays as the bit-exactness oracle.
"""

"""Pure-jnp oracle for the bucket-scatter kernels."""
import jax.numpy as jnp


def rank_and_histogram(dest, count, *, num_ranks):
    """(d_clean, rank-within-bucket, histogram) via one-hot exclusive cumsum."""
    cap = dest.shape[0]
    lane = jnp.arange(cap, dtype=jnp.int32)
    valid = (lane < count) & (dest >= 0) & (dest < num_ranks)
    d = jnp.where(valid, dest, num_ranks).astype(jnp.int32)
    onehot = (
        d[:, None] == jnp.arange(num_ranks + 1, dtype=jnp.int32)[None, :]
    ).astype(jnp.int32)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    rank = jnp.take_along_axis(excl, d[:, None], axis=1)[:, 0]
    return d, rank.astype(jnp.int32), jnp.sum(onehot, axis=0).astype(jnp.int32)


def scatter_rows(src, dstpos, *, num_slots):
    """out[dstpos[i]] = src[i]; out-of-range rows (negative or at/past
    num_slots) are dropped.  (``.at[].set`` WRAPS negative indices even with
    mode="drop", so negatives are redirected past the end explicitly.)"""
    pos = dstpos.astype(jnp.int32)
    idx = jnp.where(pos < 0, num_slots, pos)
    out = jnp.zeros((num_slots, src.shape[1]), src.dtype)
    return out.at[idx].set(src, mode="drop")

"""Public wrapper: the sort-free bucket-scatter marshal plan + payload pass.

``ForwardConfig(marshal="scatter", use_pallas=True)`` routes here:
``rank_and_histogram`` replaces the ``sort_keys`` pack+sort (same control
data — sanitized destination, stable in-bucket rank, histogram — no keys, no
sort), and ``scatter_rows`` is the round's single payload pass (the scatter
dual of ``kernels/marshal.gather_rows``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.bucket_scatter import kernel as K


def rank_and_histogram(
    dest: jax.Array,
    count: jax.Array,
    *,
    num_ranks: int,
    tile: int = 2048,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pallas-path equivalent of ``core.sorting.destination_rank``:
    ``(d_clean, rank, hist)`` in one kernel pass over the destination
    vector."""
    if interpret is None:
        interpret = default_interpret()
    cap = dest.shape[0]
    # pick a tile that divides the capacity
    t = min(tile, cap)
    while cap % t:
        t //= 2
    return K.rank_and_histogram(
        dest, count, num_ranks=num_ranks, tile=t, interpret=interpret
    )


def scatter_rows(
    src: jax.Array,
    dstpos: jax.Array,
    *,
    num_slots: int,
    interpret: bool | None = None,
) -> jax.Array:
    """(N, W) packed payload + composed send-layout positions → (num_slots, W)
    send buffer in ONE payload pass (see ``kernel.scatter_rows``)."""
    if interpret is None:
        interpret = default_interpret()
    return K.scatter_rows(src, dstpos, num_slots=num_slots, interpret=interpret)


def compact_rows(
    src: jax.Array,
    mask: jax.Array,
    *,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stable front-compaction of the masked rows — the spill-and-retry
    primitive (``overflow="retain"``): the marked rows move to the front of
    an ``(N, W)`` buffer in their original relative order, unmarked slots
    stay zero.  The position plan is the 1-bucket counting sort (the mask's
    exclusive prefix sum); the payload moves in ONE ``scatter_rows`` pass.

    Returns ``(out, slot, n_kept)`` — ``slot`` is each source row's compacted
    position (``N`` for unmarked rows, the kernel's discard sentinel), handed
    back so callers can scatter side-band vectors (dest, age) to the same
    layout without a second plan."""
    n = src.shape[0]
    m32 = mask.astype(jnp.int32)
    pos = jnp.cumsum(m32) - m32
    slot = jnp.where(mask, pos, n)
    out = scatter_rows(src, slot, num_slots=n, interpret=interpret)
    return out, slot, jnp.sum(m32)

"""Public wrapper: per-leaf marshal/unmarshal over work-item pytrees."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.marshal import kernel as K


def _to2d(a: jax.Array):
    lead = a.shape[0]
    return a.reshape(lead, -1), a.shape[1:]


def marshal_items(
    sorted_items: Any, offsets: jax.Array, *, num_ranks: int, slot: int,
    interpret: bool | None = None,
) -> Any:
    """Pytree of (C, ...) destination-sorted leaves → pytree of (R, S, ...)."""
    if interpret is None:
        interpret = default_interpret()

    def one(a):
        flat, tail = _to2d(a)
        buf = K.marshal(flat, offsets, num_ranks=num_ranks, slot=slot, interpret=interpret)
        return buf.reshape((num_ranks, slot) + tail)

    return jax.tree.map(one, sorted_items)


def fused_marshal(
    packed: jax.Array, src_rows: jax.Array, *, num_ranks: int, slot: int,
    interpret: bool | None = None,
) -> jax.Array:
    """(C, W) packed payload + composed gather indices → (R, S, W) send
    buffer in ONE payload pass (see ``kernel.gather_rows``)."""
    if interpret is None:
        interpret = default_interpret()
    buf = K.gather_rows(packed, src_rows, interpret=interpret)
    return buf.reshape(num_ranks, slot, packed.shape[1])


def fused_unmarshal(
    recv_buf: jax.Array, recv_offsets: jax.Array, recv_counts: jax.Array,
    *, capacity: int, interpret: bool | None = None,
) -> jax.Array:
    """(R, S, W) received packed blocks → (capacity, W) compacted buffer."""
    if interpret is None:
        interpret = default_interpret()
    return K.unmarshal(
        recv_buf, recv_offsets, recv_counts, capacity=capacity, interpret=interpret
    )


def unmarshal_items(
    recv_buf: Any, recv_offsets: jax.Array, recv_counts: jax.Array, *, capacity: int,
    interpret: bool | None = None,
) -> Any:
    """Pytree of (R, S, ...) received blocks → pytree of (capacity, ...)."""
    if interpret is None:
        interpret = default_interpret()

    def one(a):
        r, s = a.shape[:2]
        tail = a.shape[2:]
        flat = a.reshape(r, s, -1)
        out = K.unmarshal(flat, recv_offsets, recv_counts, capacity=capacity, interpret=interpret)
        return out.reshape((capacity,) + tail)

    return jax.tree.map(one, recv_buf)

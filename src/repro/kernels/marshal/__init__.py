from repro.kernels.marshal import kernel, ops, ref  # noqa: F401

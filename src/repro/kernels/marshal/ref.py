"""Pure-jnp oracle for the marshal/unmarshal kernels."""
import jax.numpy as jnp


def marshal(sorted_flat, offsets, *, num_ranks, slot):
    cap, _ = sorted_flat.shape
    off = jnp.clip(offsets.astype(jnp.int32), 0, cap - slot)
    src = off[:, None] + jnp.arange(slot, dtype=jnp.int32)[None, :]
    return jnp.take(sorted_flat, src.reshape(-1), axis=0, mode="clip").reshape(
        num_ranks, slot, -1
    )


def gather_rows(src, row_idx):
    cap = src.shape[0]
    idx = jnp.clip(row_idx.astype(jnp.int32), 0, cap - 1)
    return jnp.take(src, idx, axis=0)


def unmarshal(recv_buf, recv_offsets, recv_counts, *, capacity):
    num_ranks, slot, d = recv_buf.shape
    off = jnp.clip(recv_offsets.astype(jnp.int32), 0, capacity)
    s = jnp.arange(slot, dtype=jnp.int32)
    dstpos = off[:, None] + s[None, :]
    ok = s[None, :] < recv_counts[:, None]
    dstpos = jnp.where(ok & (dstpos < capacity), dstpos, capacity)
    out = jnp.zeros((capacity, d), recv_buf.dtype)
    return out.at[dstpos.reshape(-1)].set(recv_buf.reshape(-1, d), mode="drop")

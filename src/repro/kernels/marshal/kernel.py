"""Pallas kernels: §4.2.2 marshal / unmarshal around the packed exchange.

``gather_rows`` — the production hot path, the SINGLE-pass marshal: the
caller composes the destination-sort permutation with the padded send layout
(``src[i] = perm[off[r] + s]``) and this kernel materialises
``out[i] = packed[src[i]]`` in one gather.  The index vector lands in SMEM by
scalar prefetch; each grid step copies one dynamically-addressed row of the
VMEM-resident packed buffer.  Sort-then-segment-copy used to be two payload
passes; folding the permutation into the gather makes "each ray gets read
exactly once and written exactly once" (§4.2.1/§6.1) hold through the
marshal step too.

``marshal`` — the two-pass formulation kept for cross-validation: gather each
peer's *contiguous* segment of an already-sorted buffer into its fixed
(peer_capacity,) slot via scalar-prefetched dynamic slices (the TPU analogue
of the paper's observation that RDMA needs "single, consistent blocks of
(GPU) data").

``unmarshal``: the inverse — scatter received (R, S) blocks into a compact
buffer at data-dependent offsets via dynamic-slice stores.  Segments are
written whole; lanes past the per-peer count are masked by a
load-blend-store (grid steps are sequential, so the read-modify-write is
race-free).  A trash tail of S rows absorbs receiver-side overflow, keeping
the §3.3 drop semantics.

Payload layout: all kernels act on the packed wire format of
``core.types.pack_payload`` — the whole work-item pytree bitcast into one
(C, words) uint32 buffer, mirroring the paper's "trivially copyable struct"
contract on the wire.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import sds


def _marshal_kernel(off_ref, in_ref, out_ref, *, slot):
    r = pl.program_id(0)
    start = off_ref[r]
    out_ref[...] = in_ref[pl.ds(start, slot), :][None]


@functools.partial(jax.jit, static_argnames=("num_ranks", "slot", "interpret"))
def marshal(
    sorted_flat: jax.Array,  # (C, D) destination-sorted payload view
    offsets: jax.Array,  # (R,) int32 segment starts (will be clamped to C-S)
    *,
    num_ranks: int,
    slot: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns the (R, S, D) padded send buffer."""
    cap, d = sorted_flat.shape
    if slot > cap:
        raise ValueError(f"peer slot {slot} exceeds capacity {cap}")
    off = jnp.clip(offsets.astype(jnp.int32), 0, cap - slot)
    return pl.pallas_call(
        functools.partial(_marshal_kernel, slot=slot),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(num_ranks,),
            in_specs=[pl.BlockSpec((cap, d), lambda r, off: (0, 0))],
            out_specs=pl.BlockSpec((1, slot, d), lambda r, off: (r, 0, 0)),
        ),
        out_shape=sds((num_ranks, slot, d), sorted_flat.dtype, sorted_flat, off),
        interpret=interpret,
    )(off, sorted_flat)


def _gather_rows_kernel(idx_ref, in_ref, out_ref, *, tile):
    i = pl.program_id(0)
    for t in range(tile):  # static unroll: `tile` dynamic row copies per step
        out_ref[pl.ds(t, 1), :] = in_ref[pl.ds(idx_ref[i * tile + t], 1), :]


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def gather_rows(
    src: jax.Array,  # (C, D) packed payload
    row_idx: jax.Array,  # (N,) int32 source row per output row (clamped)
    *,
    interpret: bool = False,
    tile: int = 8,
) -> jax.Array:
    """The fused single-pass marshal: ``out[i] = src[row_idx[i]]``.

    ``row_idx`` is the destination-sort permutation already composed with the
    send-slot layout (``perm[off[r] + s]`` for the flat exchange; either
    stage's layout for the hierarchical one), so this one gather subsumes
    what used to be payload-sort-then-segment-copy — each payload row is read
    exactly once and written exactly once.  The index vector lands in SMEM by
    scalar prefetch; each grid step copies a TILE of ``tile`` (default 8)
    dynamically-addressed rows of the VMEM-resident packed buffer, amortising
    the Mosaic per-step grid overhead the one-row-per-step formulation paid
    (rows are not contiguous, unlike :func:`marshal`, because the sort
    permutation is folded in).  ``row_idx`` is padded up to a whole tile; the
    padded tail is cut from the result.
    """
    cap, d = src.shape
    n = row_idx.shape[0]
    idx = jnp.clip(row_idx.astype(jnp.int32), 0, cap - 1)
    n_pad = -(-n // tile) * tile
    if n_pad != n:
        idx = jnp.concatenate([idx, jnp.zeros((n_pad - n,), jnp.int32)])
    out = pl.pallas_call(
        functools.partial(_gather_rows_kernel, tile=tile),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_pad // tile,),
            in_specs=[pl.BlockSpec((cap, d), lambda i, idx: (0, 0))],
            out_specs=pl.BlockSpec((tile, d), lambda i, idx: (i, 0)),
        ),
        out_shape=sds((n_pad, d), src.dtype, src, idx),
        interpret=interpret,
    )(idx, src)
    return out[:n] if n_pad != n else out


def _unmarshal_kernel(off_ref, cnt_ref, in_ref, out_ref, *, slot):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    start = off_ref[r]
    cnt = cnt_ref[r]
    blk = in_ref[0]
    cur = out_ref[pl.ds(start, slot), :]
    lane = jax.lax.broadcasted_iota(jnp.int32, (slot, 1), 0)
    out_ref[pl.ds(start, slot), :] = jnp.where(lane < cnt, blk, cur)


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def unmarshal(
    recv_buf: jax.Array,  # (R, S, D) received padded blocks
    recv_offsets: jax.Array,  # (R,) compact output offsets
    recv_counts: jax.Array,  # (R,) valid rows per block
    *,
    capacity: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns the (capacity, D) compacted receive buffer (drop-tail applied)."""
    num_ranks, slot, d = recv_buf.shape
    # Trash tail: segments that start past `capacity` (or spill over it) write
    # into the extra S rows, which are cut off below — §3.3 drop semantics.
    padded = capacity + slot
    off = jnp.clip(recv_offsets.astype(jnp.int32), 0, capacity)
    out = pl.pallas_call(
        functools.partial(_unmarshal_kernel, slot=slot),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(num_ranks,),
            in_specs=[pl.BlockSpec((1, slot, d), lambda r, off, cnt: (r, 0, 0))],
            out_specs=pl.BlockSpec((padded, d), lambda r, off, cnt: (0, 0)),
        ),
        out_shape=sds((padded, d), recv_buf.dtype, recv_buf, off),
        interpret=interpret,
    )(off, recv_counts.astype(jnp.int32), recv_buf)
    return out[:capacity]

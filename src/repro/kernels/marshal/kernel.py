"""Pallas kernels: §4.2.2 segment marshal / unmarshal around the exchange.

``marshal``: gather each peer's contiguous segment of the destination-sorted
buffer into its fixed (peer_capacity,) slot of the padded send buffer.  The
per-peer offsets are *data-dependent*, which Pallas expresses with
scalar-prefetch: the offset vector lands in SMEM before the grid runs, and
each grid step r copies ``sorted[off[r] : off[r]+S]`` with a dynamic slice —
one sequential VMEM-resident pass, no gather unit involved.  This is the TPU
analogue of the paper's observation that RDMA needs "single, consistent
blocks of (GPU) data".

``unmarshal``: the inverse — scatter received (R, S) blocks into a compact
buffer at data-dependent offsets via dynamic-slice stores.  Segments are
written whole; lanes past the per-peer count are masked by a
load-blend-store (grid steps are sequential, so the read-modify-write is
race-free).  A trash tail of S rows absorbs receiver-side overflow, keeping
the §3.3 drop semantics.

Payload layout: items are marshalled as a flat (C, D) f32/int view — ops.py
packs the work-item pytree into lanes (bitcast), mirroring the paper's
"trivially copyable struct" contract on the wire.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import sds


def _marshal_kernel(off_ref, in_ref, out_ref, *, slot):
    r = pl.program_id(0)
    start = off_ref[r]
    out_ref[...] = in_ref[pl.ds(start, slot), :][None]


@functools.partial(jax.jit, static_argnames=("num_ranks", "slot", "interpret"))
def marshal(
    sorted_flat: jax.Array,  # (C, D) destination-sorted payload view
    offsets: jax.Array,  # (R,) int32 segment starts (will be clamped to C-S)
    *,
    num_ranks: int,
    slot: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns the (R, S, D) padded send buffer."""
    cap, d = sorted_flat.shape
    if slot > cap:
        raise ValueError(f"peer slot {slot} exceeds capacity {cap}")
    off = jnp.clip(offsets.astype(jnp.int32), 0, cap - slot)
    return pl.pallas_call(
        functools.partial(_marshal_kernel, slot=slot),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(num_ranks,),
            in_specs=[pl.BlockSpec((cap, d), lambda r, off: (0, 0))],
            out_specs=pl.BlockSpec((1, slot, d), lambda r, off: (r, 0, 0)),
        ),
        out_shape=sds((num_ranks, slot, d), sorted_flat.dtype, sorted_flat, off),
        interpret=interpret,
    )(off, sorted_flat)


def _unmarshal_kernel(off_ref, cnt_ref, in_ref, out_ref, *, slot):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    start = off_ref[r]
    cnt = cnt_ref[r]
    blk = in_ref[0]
    cur = out_ref[pl.ds(start, slot), :]
    lane = jax.lax.broadcasted_iota(jnp.int32, (slot, 1), 0)
    out_ref[pl.ds(start, slot), :] = jnp.where(lane < cnt, blk, cur)


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def unmarshal(
    recv_buf: jax.Array,  # (R, S, D) received padded blocks
    recv_offsets: jax.Array,  # (R,) compact output offsets
    recv_counts: jax.Array,  # (R,) valid rows per block
    *,
    capacity: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns the (capacity, D) compacted receive buffer (drop-tail applied)."""
    num_ranks, slot, d = recv_buf.shape
    # Trash tail: segments that start past `capacity` (or spill over it) write
    # into the extra S rows, which are cut off below — §3.3 drop semantics.
    padded = capacity + slot
    off = jnp.clip(recv_offsets.astype(jnp.int32), 0, capacity)
    out = pl.pallas_call(
        functools.partial(_unmarshal_kernel, slot=slot),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(num_ranks,),
            in_specs=[pl.BlockSpec((1, slot, d), lambda r, off, cnt: (r, 0, 0))],
            out_specs=pl.BlockSpec((padded, d), lambda r, off, cnt: (0, 0)),
        ),
        out_shape=sds((padded, d), recv_buf.dtype, recv_buf, off),
        interpret=interpret,
    )(off, recv_counts.astype(jnp.int32), recv_buf)
    return out[:capacity]

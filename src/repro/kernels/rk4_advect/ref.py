"""Pure-jnp oracle for the rk4_advect kernel."""
import jax.numpy as jnp

ABC, TORNADO, TAYLOR_GREEN = 0, 1, 2


def velocity(p, field_id, params=(1.0, 0.8, 0.6)):
    x, y, z = p[..., 0], p[..., 1], p[..., 2]
    a, b, c = params
    if field_id == ABC:
        return jnp.stack(
            [a * jnp.sin(z) + c * jnp.cos(y),
             b * jnp.sin(x) + a * jnp.cos(z),
             c * jnp.sin(y) + b * jnp.cos(x)],
            axis=-1,
        )
    if field_id == TORNADO:
        r2 = x * x + y * y + 1e-3
        swirl = a / r2
        return jnp.stack([-y * swirl, x * swirl, b + c * jnp.sqrt(r2)], axis=-1)
    if field_id == TAYLOR_GREEN:
        return jnp.stack(
            [a * jnp.cos(x) * jnp.sin(y) * jnp.sin(z),
             -a * jnp.sin(x) * jnp.cos(y) * jnp.sin(z),
             c * jnp.sin(x) * jnp.sin(y) * jnp.cos(z)],
            axis=-1,
        )
    raise ValueError(field_id)


def rk4_step(pos, *, dt, field_id=ABC, params=(1.0, 0.8, 0.6)):
    k1 = velocity(pos, field_id, params)
    k2 = velocity(pos + 0.5 * dt * k1, field_id, params)
    k3 = velocity(pos + 0.5 * dt * k2, field_id, params)
    k4 = velocity(pos + dt * k3, field_id, params)
    return pos + (dt / 6.0) * (k1 + 2 * k2 + 2 * k3 + k4), k1

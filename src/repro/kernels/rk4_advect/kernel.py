"""Pallas kernel: RK4 particle advection for the streamlines app (§5.4).

One Runge-Kutta-4 update per particle per round ("each rank/GPU
independently performs an update step on each particle — one GPU thread per
particle").  The TPU mapping is one *lane* per particle: a (TILE, 3) block of
positions is advanced through the four stages entirely in registers/VMEM.

The velocity field is *procedural* (gather-free — the TPU-friendly choice):
  field 0: ABC (Arnold–Beltrami–Childress) flow — the classic streamline demo
  field 1: a swirling "tornado" column around the z axis
  field 2: Taylor–Green-like cellular vortex
Grid-sampled fields go through the XLA-gather path in the app instead; the
kernel covers the compute-bound analytic case (cf. DESIGN.md on TPU gather).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import sds

ABC, TORNADO, TAYLOR_GREEN = 0, 1, 2


def _velocity(p, field_id: int, params):
    x, y, z = p[:, 0], p[:, 1], p[:, 2]
    a, b, c = params
    if field_id == ABC:
        return jnp.stack(
            [a * jnp.sin(z) + c * jnp.cos(y),
             b * jnp.sin(x) + a * jnp.cos(z),
             c * jnp.sin(y) + b * jnp.cos(x)],
            axis=-1,
        )
    if field_id == TORNADO:
        r2 = x * x + y * y + 1e-3
        swirl = a / r2
        return jnp.stack([-y * swirl, x * swirl, b + c * jnp.sqrt(r2)], axis=-1)
    if field_id == TAYLOR_GREEN:
        return jnp.stack(
            [a * jnp.cos(x) * jnp.sin(y) * jnp.sin(z),
             -a * jnp.sin(x) * jnp.cos(y) * jnp.sin(z),
             c * jnp.sin(x) * jnp.sin(y) * jnp.cos(z)],
            axis=-1,
        )
    raise ValueError(f"unknown field {field_id}")


def _rk4_kernel(pos_ref, out_ref, vel_ref, *, dt, field_id, params):
    p = pos_ref[...]
    k1 = _velocity(p, field_id, params)
    k2 = _velocity(p + 0.5 * dt * k1, field_id, params)
    k3 = _velocity(p + 0.5 * dt * k2, field_id, params)
    k4 = _velocity(p + dt * k3, field_id, params)
    out_ref[...] = p + (dt / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4)
    vel_ref[...] = k1


@functools.partial(jax.jit, static_argnames=("dt", "field_id", "params", "tile", "interpret"))
def rk4_step(
    pos: jax.Array,  # (N, 3)
    *,
    dt: float,
    field_id: int = ABC,
    params: tuple = (1.0, 0.8, 0.6),
    tile: int = 1024,
    interpret: bool = False,
):
    """One RK4 step. Returns (new_pos (N,3), velocity-at-pos (N,3))."""
    n = pos.shape[0]
    tile = min(tile, n)
    while n % tile:
        tile //= 2
    return pl.pallas_call(
        functools.partial(_rk4_kernel, dt=dt, field_id=field_id, params=params),
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile, 3), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile, 3), lambda i: (i, 0)),
            pl.BlockSpec((tile, 3), lambda i: (i, 0)),
        ],
        out_shape=[
            sds((n, 3), jnp.float32, pos),
            sds((n, 3), jnp.float32, pos),
        ],
        interpret=interpret,
    )(pos)

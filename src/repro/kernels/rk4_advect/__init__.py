from repro.kernels.rk4_advect import kernel, ops, ref  # noqa: F401

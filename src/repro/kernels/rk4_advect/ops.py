"""Public wrapper for RK4 advection."""
from __future__ import annotations

from repro.kernels import default_interpret
from repro.kernels.rk4_advect import kernel as K

ABC, TORNADO, TAYLOR_GREEN = K.ABC, K.TORNADO, K.TAYLOR_GREEN


def rk4_step(pos, *, dt, field_id=K.ABC, params=(1.0, 0.8, 0.6), interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return K.rk4_step(pos, dt=dt, field_id=field_id, params=tuple(params), interpret=interpret)

"""Pure-jnp oracle for the compact kernel."""
import jax.numpy as jnp


def compact_positions(mask):
    m = mask.astype(jnp.int32)
    cs = jnp.cumsum(m)
    return cs - m, cs[-1:].astype(jnp.int32)

"""Public wrapper: Pallas stream compaction + scatter-apply helpers."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.kernels import default_interpret
from repro.kernels.compact import kernel as K


def compact_positions(mask: jax.Array, *, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    pos, total = K.compact_positions(mask, interpret=interpret)
    return pos, total[0]


def compact(items: Any, mask: jax.Array, capacity: int, *, interpret: bool | None = None):
    """Dense-pack the masked lanes of ``items`` into a (capacity, ...) buffer.

    Returns (packed_items, count). Overflow lanes are dropped (§3.3)."""
    pos, count = compact_positions(mask, interpret=interpret)
    slot = jnp.where(mask & (pos < capacity), pos, capacity)
    proto = jax.tree.map(lambda a: a[0], items)
    out = T.batched_zeros(proto, capacity)
    out = T.tree_scatter(out, slot, items, capacity=capacity)
    return out, jnp.minimum(count, capacity)

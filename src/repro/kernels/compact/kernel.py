"""Pallas kernel: cross-tile exclusive-prefix-sum stream compaction.

This is the TPU replacement for RaFI's ``atomicAdd``-append queue (§3.2): a
mask of emitting lanes becomes a dense list of append positions.  The scan
carry rides across sequential grid steps in SMEM scratch — the canonical
Mosaic pattern for a decoupled-lookback-free prefix sum (TPU grid steps are
sequential, so no lookback is needed at all; this is *simpler* than the GPU
equivalent, which is the point of the adaptation).

Outputs: positions (C,) int32 (exclusive prefix sum of the mask — the append
slot for every emitting lane) and total (1,) int32 (the final counter value).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import sds


def _compact_kernel(mask_ref, pos_ref, total_ref, carry_ref, *, tile, nsteps):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        carry_ref[0] = 0

    m = mask_ref[...].astype(jnp.int32)
    cs = jnp.cumsum(m)
    pos_ref[...] = carry_ref[0] + cs - m
    carry_ref[0] = carry_ref[0] + cs[-1]

    @pl.when(step == nsteps - 1)
    def _fin():
        total_ref[0] = carry_ref[0]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def compact_positions(mask: jax.Array, *, tile: int = 2048, interpret: bool = False):
    """Exclusive prefix-sum of a boolean mask. Returns (pos (C,), total (1,))."""
    cap = mask.shape[0]
    tile = min(tile, cap)
    while cap % tile:
        tile //= 2
    nsteps = cap // tile
    kern = functools.partial(_compact_kernel, tile=tile, nsteps=nsteps)
    return pl.pallas_call(
        kern,
        grid=(nsteps,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            sds((cap,), jnp.int32, mask),
            sds((1,), jnp.int32, mask),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(mask)

from repro.kernels.compact import kernel, ops, ref  # noqa: F401

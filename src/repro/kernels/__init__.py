"""Pallas TPU kernels for the forwarding hot spots and app compute cores.

Layout: one subpackage per kernel —

  sort_keys/       §4.2.1 key-pack + per-destination histogram (MXU one-hot)
  bucket_scatter/  sort-free marshal: in-bucket rank + histogram in one pass,
                   payload scattered straight into the send layout
  compact/         cross-tile prefix-sum stream compaction (the TPU "atomic queue")
  marshal/         §4.2.2 segment marshal/unmarshal via scalar-prefetch dynamic slices
  nbody_forces/    §5.5 tiled O(N²) pairwise gravity (MXU-aligned)
  rk4_advect/      §5.4 RK4 particle advection on analytic vector fields
  delta_tracking/  §5.1 Woodcock tracking through a procedural density field

Each subpackage has ``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling),
``ops.py`` (jit'd public wrapper with an ``interpret`` switch), and ``ref.py``
(pure-jnp oracle).  On this CPU container kernels run with ``interpret=True``;
on TPU they compile via Mosaic.  The ``RAFI_PALLAS_INTERPRET`` env var
overrides the default ("1"/"true" forces interpret mode even on TPU, "0"
forces Mosaic) — CI uses it (via the ``pallas_interpret`` pytest marker in
``tests/conftest.py``) to exercise every kernel in tier-1 without a TPU.
"""
import os

import jax

from repro.compat import sds  # noqa: F401  (re-export: kernels build out_shapes with it)

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """Interpret Pallas kernels unless we are actually on TPU; the
    ``RAFI_PALLAS_INTERPRET`` env var overrides in either direction."""
    env = os.environ.get("RAFI_PALLAS_INTERPRET", "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    return jax.default_backend() != "tpu"

"""Pallas TPU kernels for the forwarding hot spots and app compute cores.

Layout: one subpackage per kernel —

  sort_keys/       §4.2.1 key-pack + per-destination histogram (MXU one-hot)
  compact/         cross-tile prefix-sum stream compaction (the TPU "atomic queue")
  marshal/         §4.2.2 segment marshal/unmarshal via scalar-prefetch dynamic slices
  nbody_forces/    §5.5 tiled O(N²) pairwise gravity (MXU-aligned)
  rk4_advect/      §5.4 RK4 particle advection on analytic vector fields
  delta_tracking/  §5.1 Woodcock tracking through a procedural density field

Each subpackage has ``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling),
``ops.py`` (jit'd public wrapper with an ``interpret`` switch), and ``ref.py``
(pure-jnp oracle).  On this CPU container kernels run with ``interpret=True``;
on TPU they compile via Mosaic.
"""
import jax

from repro.compat import sds  # noqa: F401  (re-export: kernels build out_shapes with it)


def default_interpret() -> bool:
    """Interpret Pallas kernels unless we are actually on TPU."""
    return jax.default_backend() != "tpu"

"""Public wrapper: Pallas-accelerated sort-by-destination (§4.2.1).

Key pack + histogram run in the Pallas kernel; the key sort uses
``jax.lax.sort`` (XLA's native TPU sorter — the cub analogue) and the payload
permute is an XLA gather ("each ray gets read exactly once and written
exactly once").  Drop-in replacement for ``repro.core.sorting
.sort_by_destination`` — ``ForwardConfig(use_pallas=True)`` routes here.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.sort_keys import kernel as K
from repro.core import types as T


def _idx_bits(capacity: int) -> int:
    return max(1, (capacity - 1).bit_length())


def sort_permutation(
    dest: jax.Array,
    count: jax.Array,
    num_ranks: int,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pallas-path equivalent of ``core.sorting.sort_permutation``: key pack +
    histogram in one kernel pass, key sort via ``jax.lax.sort`` — the payload
    is never touched (the caller composes ``perm`` into its single marshal
    gather)."""
    if interpret is None:
        interpret = default_interpret()
    cap = dest.shape[0]
    ib = _idx_bits(cap)
    if (num_ranks + 1).bit_length() + ib > 32:
        raise ValueError("packed key exceeds 32 bits; reduce capacity or ranks")
    # pick a tile that divides the capacity
    t = min(tile, cap)
    while cap % t:
        t //= 2
    keys, hist = K.pack_and_histogram(
        dest, count, num_ranks=num_ranks, idx_bits=ib, tile=t, interpret=interpret
    )
    sorted_keys = jax.lax.sort(keys)
    d_sorted = (sorted_keys >> ib).astype(jnp.int32)
    perm = (sorted_keys & jnp.uint32((1 << ib) - 1)).astype(jnp.int32)
    return perm, d_sorted, hist


def sort_permutation_hierarchical(
    dest: jax.Array,
    count: jax.Array,
    level_sizes,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Pallas-path equivalent of ``core.sorting.sort_permutation_hierarchical``
    — the N-level key layout routed through the ``sort_keys`` kernel.

    Global ranks are lexicographic in the mesh digits (slowest-major), so the
    flat packed key ``(dest << idx_bits) | lane`` and the multi-field key
    ``(d_0, …, d_{L-1}, slot)`` induce the SAME sort order: concatenating the
    digit bit-fields of a lexicographic rank IS the rank field (cross-validated
    against the XLA path in tests).  The kernel therefore packs the flat key —
    one pack+histogram pass — and this wrapper reshapes the histogram into the
    ``level_sizes``-shaped count tensor every stage of the hierarchical
    exchange addresses.

    Returns ``(perm, count_tensor)``; raises like the flat path when the
    packed key exceeds 32 bits.
    """
    level_sizes = tuple(int(a) for a in level_sizes)
    num_ranks = 1
    for a in level_sizes:
        num_ranks *= a
    perm, _d_sorted, hist = sort_permutation(
        dest, count, num_ranks, tile=tile, interpret=interpret
    )
    return perm, hist[:num_ranks].reshape(level_sizes)


def sort_by_destination(
    items: Any,
    dest: jax.Array,
    count: jax.Array,
    num_ranks: int,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> Tuple[Any, jax.Array, jax.Array]:
    """Pallas-path equivalent of core.sorting.sort_by_destination."""
    perm, d_sorted, hist = sort_permutation(
        dest, count, num_ranks, tile=tile, interpret=interpret
    )
    sorted_items = T.tree_take(items, perm)
    return sorted_items, d_sorted, hist

"""Pallas kernel: §4.2.1 sort-key packing + per-destination histogram.

The paper launches a CUDA kernel that writes ``(dest << 32) | i`` uint64 keys
and then radix-sorts them with cub.  The TPU adaptation packs into 32 bits
(rank count ≤ 1024 needs ≤ 10 bits; x64 is off in JAX anyway) and — because
the key distribution is tiny — replaces the generic radix sort with a
counting sort whose histogram is computed *in the same pass* as the key pack,
mapping the one-hot contraction onto the MXU:

    hist[r] = Σ_lanes one_hot(dest_clean[lane], R+1)          (T,R+1)·(T,)→(R+1,)

Tiling: the destination vector is processed in VMEM tiles of ``TILE`` lanes;
the histogram output block is revisited by every grid step (TPU grid steps
run sequentially, so accumulation into the output block is safe — the
canonical Pallas reduction pattern).

VMEM budget per step: TILE·4 B (dest) + TILE·4 B (keys) + TILE·(R+1)·4 B
(one-hot) — for TILE=2048, R=512: ~4.2 MB, comfortably inside the ~16 MB
VMEM of a v5e core; matmul dims are multiples of 128 when TILE is.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import sds


def _pack_hist_kernel(dest_ref, count_ref, keys_ref, hist_ref, *, num_ranks, idx_bits, tile):
    step = pl.program_id(0)
    lane0 = step * tile
    lane = lane0 + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    d = dest_ref[...]
    count = count_ref[0]
    valid = (lane < count) & (d >= 0) & (d < num_ranks)
    d_clean = jnp.where(valid, d, num_ranks)
    keys_ref[...] = (d_clean.astype(jnp.uint32) << idx_bits) | lane.astype(jnp.uint32)

    # One-hot histogram on the MXU: ones(T) · one_hot(d,(T,R+1)) → (R+1,)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (tile, num_ranks + 1), 1)
    onehot = (d_clean[:, None] == r_iota).astype(jnp.float32)
    part = jax.lax.dot_general(
        jnp.ones((tile,), jnp.float32),
        onehot,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = part

    @pl.when(step > 0)
    def _accum():
        hist_ref[...] += part


@functools.partial(jax.jit, static_argnames=("num_ranks", "idx_bits", "tile", "interpret"))
def pack_and_histogram(
    dest: jax.Array,
    count: jax.Array,
    *,
    num_ranks: int,
    idx_bits: int,
    tile: int = 2048,
    interpret: bool = False,
):
    """Returns (keys uint32 (C,), hist int32 (R+1,)); invalid lanes → dest R."""
    cap = dest.shape[0]
    tile = min(tile, cap)
    if cap % tile:
        raise ValueError(f"capacity {cap} not divisible by tile {tile}")
    grid = (cap // tile,)
    kern = functools.partial(
        _pack_hist_kernel, num_ranks=num_ranks, idx_bits=idx_bits, tile=tile
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((num_ranks + 1,), lambda i: (0,)),
        ],
        out_shape=[
            sds((cap,), jnp.uint32, dest, count),
            sds((num_ranks + 1,), jnp.int32, dest, count),
        ],
        interpret=interpret,
    )(dest, count.reshape(1).astype(jnp.int32))

from repro.kernels.sort_keys import kernel, ops, ref  # noqa: F401

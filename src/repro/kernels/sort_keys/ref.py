"""Pure-jnp oracle for the sort_keys kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_and_histogram(dest, count, *, num_ranks: int, idx_bits: int):
    cap = dest.shape[0]
    lane = jnp.arange(cap, dtype=jnp.int32)
    valid = (lane < count) & (dest >= 0) & (dest < num_ranks)
    d_clean = jnp.where(valid, dest, num_ranks)
    keys = (d_clean.astype(jnp.uint32) << idx_bits) | lane.astype(jnp.uint32)
    hist = jnp.zeros((num_ranks + 1,), jnp.int32).at[d_clean].add(1)
    return keys, hist

"""AdamW with parameter-sharded optimizer states.

States (m, v) inherit the parameter PartitionSpecs (so with FSDP on, the
optimizer shards ZeRO-style for free).  m/v are kept in f32 even for bf16
params (standard mixed-precision practice); the master copy IS the param
tree (bf16 train is tolerated for the dry-run; a flag enables f32 masters).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    f32_master: bool = False
    compress_grads: bool = False  # bf16 gradient reduction + error feedback


def adamw_init(params, cfg: AdamWConfig):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.f32_master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    if cfg.compress_grads:
        from repro.optim.grad_compress import init_residuals

        state["residual"] = init_residuals(params)
    return state


def opt_state_specs(param_specs, cfg: AdamWConfig):
    from jax.sharding import PartitionSpec as P

    spec = {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }
    if cfg.f32_master:
        spec["master"] = param_specs
    if cfg.compress_grads:
        spec["residual"] = param_specs
    return spec


def _schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_update(params, grads, state, cfg: AdamWConfig) -> Tuple[Any, Any, jax.Array]:
    """Returns (new_params, new_state, grad_global_norm)."""
    new_residual = None
    if cfg.compress_grads:
        # bf16 all-reduce payload with error feedback: the cast happens before
        # the (implicit) data-axis reduction boundary, halving its bytes; the
        # quantization error re-enters next step's gradient.
        from repro.optim.grad_compress import compress_gradients

        grads, new_residual = compress_gradients(grads, state["residual"])
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(g32)) + 1e-20
    )
    scale = jnp.minimum(1.0, cfg.grad_clip / gnorm)
    g32 = jax.tree.map(lambda g: g * scale, g32)

    step = state["step"] + 1
    lr = _schedule(step, cfg)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["m"], g32)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["v"], g32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    base = state["master"] if cfg.f32_master else params
    new_base = jax.tree.map(upd, base, new_m, new_v)
    new_params = (
        jax.tree.map(lambda b, p: b.astype(p.dtype), new_base, params)
        if cfg.f32_master
        else new_base
    )
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.f32_master:
        new_state["master"] = new_base
    if new_residual is not None:
        new_state["residual"] = new_residual
    return new_params, new_state, gnorm

"""Gradient compression with error feedback (distributed-optimization trick).

Under pure data parallelism XLA all-reduces gradients in their native dtype.
Compressing the all-reduced payload to bf16 halves the dominant collective's
bytes; the quantization residual is fed back into the next step's gradient
(error feedback), which keeps SGD-style convergence guarantees.

Implementation: a value-and-residual transform applied to the gradient tree
*before* the psum boundary.  In jit/GSPMD the reduction is implicit, so the
hook is structured as: cast-with-feedback → (implicit all-reduce) → use.
The residual rides in the optimizer state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residuals(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_gradients(grads, residuals) -> Tuple[Any, Any]:
    """bf16-compress grads with error feedback. Returns (bf16 grads, new res)."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q = corrected.astype(jnp.bfloat16)
        new_r = corrected - q.astype(jnp.float32)
        return q, new_r

    out = jax.tree.map(one, grads, residuals)
    qs = jax.tree.map(lambda pair: pair[0], out, is_leaf=lambda x: isinstance(x, tuple))
    rs = jax.tree.map(lambda pair: pair[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return qs, rs

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, opt_state_specs  # noqa: F401
from repro.optim.grad_compress import compress_gradients  # noqa: F401

"""Render the dry-run artifacts into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def load(mesh_tag: str = "pod1", tag: str = ""):
    recs = []
    for p in sorted(ARTIFACTS.glob(f"*__{mesh_tag}{tag}.json")):
        r = json.loads(p.read_text())
        if r.get("tag", "") == tag:
            recs.append(r)
    return recs


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def roofline_table(mesh_tag: str = "pod1", tag: str = "") -> str:
    rows = [
        "| arch | shape | step | t_comp | t_mem | t_coll | bound | HBM/chip | useful_F | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh_tag, tag):
        if r["status"] == "skip":
            rows.append(
                f"| {r['arch']} | {r['shape']} | skip | - | - | - | - | - | - | {r['reason'][:60]} |"
            )
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | ERR | - | - | - | - | - | - | {r['error'][:60]} |")
            continue
        t = r["roofline"]
        mem_gb = r["memory"]["peak_bytes_per_device"] / 1e9
        uf = r.get("useful_flops_ratio")
        note = _note(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | {_fmt_s(t['t_compute'])} | "
            f"{_fmt_s(t['t_memory'])} | {_fmt_s(t['t_collective'])} | **{t['dominant'][:4]}** | "
            f"{mem_gb:.1f}GB | {uf:.2f} | {note} |"
        )
    return "\n".join(rows)


def _note(r) -> str:
    """One sentence: what would move the dominant term down."""
    t = r["roofline"]
    dom = t["dominant"]
    frac = roofline_fraction(r)
    if dom == "memory":
        return f"cf={frac:.2f}; cut bytes: fused/banded attention, bf16 CE, less remat"
    if dom == "collective":
        cb = t["coll_breakdown"]
        worst = max(cb, key=cb.get)
        return f"cf={frac:.2f}; dominant coll={worst}: reshard/overlap or shrink TP"
    return f"cf={frac:.2f}; near compute roofline"


def roofline_fraction(r) -> float:
    """compute-term / bound-time: 1.0 == compute-roofline-limited."""
    t = r["roofline"]
    bound = max(t["t_compute"], t["t_memory"], t["t_collective"])
    return t["t_compute"] / bound if bound else 0.0


def summary(mesh_tag: str = "pod1"):
    recs = [r for r in load(mesh_tag) if r["status"] == "ok"]
    recs.sort(key=roofline_fraction)
    out = []
    for r in recs:
        t = r["roofline"]
        out.append(
            (r["arch"], r["shape"], r["step"], t["dominant"],
             round(roofline_fraction(r), 3),
             round(r["memory"]["peak_bytes_per_device"] / 1e9, 1))
        )
    return out


if __name__ == "__main__":
    import sys

    tag = sys.argv[1] if len(sys.argv) > 1 else "pod1"
    print(roofline_table(tag))
    print()
    for row in summary(tag):
        print(row)

"""HLO inspector — the dry-run 'profiler' for the perf hillclimb.

Per §Perf methodology: with no TPU wall clock, the profile is the compiled
HLO. This tool surfaces what the roofline terms are made of:

  * top-k collective ops by result bytes (with shapes) — what to reshard,
  * duplicate-fusion counts — remat-inserted recompute,
  * largest temp buffers — what busts HBM.

Usage:
  PYTHONPATH=src python -m repro.roofline.inspect --arch qwen2-7b --shape train_4k
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import collections
import dataclasses
import re


def top_collectives(hlo: str, k: int = 12):
    # result shapes come in two spellings: a bare shape list (StableHLO /
    # unoptimized HLO) or a parenthesized tuple (the optimized CPU/TPU HLO
    # tuple-form collectives, one component per participant) — bytes are
    # summed over every component either way
    pat = re.compile(
        r"=\s*(\([^()]*\)|(?:[a-z0-9]+\[[0-9,]*\][^\s]*\s*,?\s*)+)\s*"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|ragged-all-to-all)"
        r"(?:-start)?\("
    )
    shape_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    dt_bytes = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "f16": 2, "pred": 1, "s8": 1}
    agg = collections.Counter()
    examples = {}
    for line in hlo.splitlines():
        m = pat.search(line)
        if not m:
            continue
        total = 0
        for dt, dims in shape_re.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dt_bytes.get(dt, 4)
        key = (m.group(2), m.group(1).strip()[:70])
        agg[key] += total
        examples[key] = line.strip()[:160]
    return agg.most_common(k)


def buffer_report(compiled):
    try:
        mem = compiled.memory_analysis()
        return (
            f"args={mem.argument_size_in_bytes/1e9:.2f}GB "
            f"out={mem.output_size_in_bytes/1e9:.2f}GB "
            f"temp={mem.temp_size_in_bytes/1e9:.2f}GB"
        )
    except Exception as e:  # noqa: BLE001
        return str(e)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="inspect the 1-period unrolled probe (per-layer view)")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides key=value (e.g. fsdp=True)")
    args = ap.parse_args()

    from repro.configs.registry import get_config, input_specs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell
    from repro.models.api import build_model

    cfg = get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        cur = getattr(cfg, k)
        overrides[k] = type(cur)(eval(v)) if not isinstance(cur, str) else v
    if args.probe:
        period = len(cfg.pattern)
        overrides.update(num_layers=period, scan_unroll=True)
        if cfg.kind == "encdec":
            overrides.update(encoder_layers=1, num_layers=1)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    cell = input_specs(args.arch, args.shape, cfg)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    model = build_model(cfg)
    with mesh:
        lowered = lower_cell(model, mesh, cell)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    print("== memory:", buffer_report(compiled))
    cost = compiled.cost_analysis()
    print(f"== cost: flops={cost.get('flops', 0):.3e} bytes={cost.get('bytes accessed', 0):.3e}")
    print("== top collectives (bytes aggregated over identical shapes):")
    for (kind, shape), b in top_collectives(hlo):
        print(f"  {b/1e9:9.3f} GB  {kind:<18} {shape}")
    # remat duplicates: fusions with identical shape signatures
    fus = collections.Counter(
        re.sub(r"%\w+", "%", l.split("=", 1)[1])[:100]
        for l in hlo.splitlines()
        if " fusion(" in l
    )
    dups = [(c, s) for s, c in fus.items() if c > 2]
    dups.sort(reverse=True)
    print("== most-duplicated fusion signatures (recompute indicator):")
    for c, s in dups[:6]:
        print(f"  ×{c}  {s[:120]}")


if __name__ == "__main__":
    main()

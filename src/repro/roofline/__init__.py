from repro.roofline.analysis import HW, RooflineTerms, analyze_lowered, model_flops  # noqa: F401

"""Roofline-term derivation from a compiled dry-run artifact.

  compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
  memory     = HLO_bytes   / (chips × HBM_bw)
  collective = collective_bytes / (chips × link_bw)

``cost_analysis`` supplies FLOPs and bytes-accessed; collective bytes are
NOT in cost_analysis, so we parse the compiled (post-SPMD) HLO text and sum
the operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute / ragged-all-to-all.  Hardware constants
are the TPU v5e targets given in the assignment.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

HW = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # B/s per chip
    "link_bw": 50e9,        # B/s per ICI link (the fast, intra-node axis)
    "dcn_bw": 25e9,         # B/s per chip across the slow inter-node fabric
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
)

# e.g.  %x = bf16[16,512,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^\s]*\s*,?\s*)+)\s*"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_SHLO_OPS = {
    "stablehlo.all_to_all": "all-to-all",
    "stablehlo.all_reduce": "all-reduce",
    "stablehlo.all_gather": "all-gather",
    "stablehlo.reduce_scatter": "reduce-scatter",
    "stablehlo.collective_permute": "collective-permute",
    "ragged_all_to_all": "ragged-all-to-all",
}
_TENSOR_RE = re.compile(r"tensor<([0-9x]*?)x?(f64|f32|bf16|f16|i64|i32|i16|i8|ui32|i1)>")
_SHLO_DTYPES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "i64": 8, "i32": 4,
                "ui32": 4, "i16": 2, "i8": 1, "i1": 1}


# replica groups / source-target pairs, both dialects:
#   StableHLO:  replica_groups = dense<[[0, 1, 2, 3], [4, 5, 6, 7]]> : ...
#               source_target_pairs = dense<[[0, 1], [1, 2]]> : ...
#   post-SPMD:  replica_groups={{0,1,2,3},{4,5,6,7}}
_SHLO_GROUPS_RE = re.compile(
    r"(?:replica_groups|source_target_pairs)\s*=\s*dense<\s*\[(.*?)\]\s*>"
)
_HLO_GROUPS_RE = re.compile(
    r"(?:replica_groups|source_target_pairs)=\{(\{[0-9,\s]*\}(?:\s*,\s*\{[0-9,\s]*\})*)\}"
)
_GROUP_RE = re.compile(r"[\[{]([0-9,\s]*)[\]}]")


def _parse_groups(line: str):
    """The line's replica groups (or permute pairs) as a tuple of int tuples;
    ``None`` when the op carries neither attribute."""
    m = _SHLO_GROUPS_RE.search(line) or _HLO_GROUPS_RE.search(line)
    if not m:
        return None
    groups = []
    for g in _GROUP_RE.findall(m.group(1)):
        ids = tuple(int(t) for t in g.replace(",", " ").split())
        if ids:
            groups.append(ids)
    return tuple(groups) or None


def _rank_digits(rank: int, level_sizes) -> tuple:
    """Decompose a lexicographic (slowest-major) rank id into its per-tier
    digits.  The slowest tier's extent is not needed — its digit is whatever
    remains above the faster strides — so ``level_sizes[0]`` may be 0."""
    ds = []
    for a in reversed(tuple(level_sizes)[1:]):
        ds.append(rank % a)
        rank //= a
    ds.append(rank)
    return tuple(reversed(ds))


def group_tier(groups, level_sizes):
    """Classify one collective's participant groups against a lexicographic
    N-level mesh (``level_sizes`` ranks per tier, slowest first).

    Returns the tier index (0 = slowest) when every group varies in exactly
    ONE tier digit — the pure single-fabric pattern of a hierarchical-
    exchange stage — or ``"cross"`` (some group spans several tiers, e.g. a
    flat all_to_all routed over the whole mesh, or a global psum),
    ``"local"`` (singleton groups), or ``"unknown"`` (no group info)."""
    if not groups:
        return "unknown"
    tiers = set()
    for g in groups:
        if len(g) <= 1:
            continue
        digits = [_rank_digits(i, level_sizes) for i in g]
        varying = {
            t
            for t in range(len(level_sizes))
            if len({d[t] for d in digits}) > 1
        }
        tiers.add(next(iter(varying)) if len(varying) == 1 else "cross")
    if not tiers:
        return "local"
    return tiers.pop() if len(tiers) == 1 else "cross"


def group_axis(groups, fast_size: int) -> str:
    """2-level wrapper over :func:`group_tier` for node-major ``(slow, fast)``
    meshes with ``fast_size`` ranks per node.

    Returns ``"fast"`` (every group stays inside one node), ``"slow"`` (every
    group holds one lane across nodes — the pure inter-node pattern),
    ``"cross"`` (groups span nodes AND lanes), ``"local"`` (singleton
    groups), or ``"unknown"`` (no group info)."""
    tier = group_tier(groups, (0, fast_size))
    return {0: "slow", 1: "fast"}.get(tier, tier)


def collective_ops(hlo_text: str, *, with_groups: bool = False) -> list:
    """Per-op collective inventory in program order.  Handles both post-SPMD
    HLO and StableHLO.  This is the basis of the collective-budget regression
    tests (one payload collective + one count collective per forwarding
    round; two of each for the hierarchical two-stage exchange).

    Returns ``[(kind, result_bytes), ...]``, or with ``with_groups=True``
    ``[(kind, result_bytes, groups), ...]`` where ``groups`` is the op's
    replica groups (permute source-target pairs for collective-permute) as a
    tuple of int tuples — the input of :func:`group_axis` / the per-axis
    accounting of :func:`per_axis_collective_bytes`."""
    ops = []
    if "stablehlo." in hlo_text:
        for line in hlo_text.splitlines():
            kind = next((v for k, v in _SHLO_OPS.items() if k in line), None)
            if kind is None or "->" not in line:
                continue
            result = line.split("->", 1)[1]
            nbytes = 0
            for dims, dt in _TENSOR_RE.findall(result):
                n = 1
                for d in dims.split("x"):
                    if d:
                        n *= int(d)
                nbytes += n * _SHLO_DTYPES.get(dt, 4)
            ops.append(
                (kind, nbytes, _parse_groups(line)) if with_groups else (kind, nbytes)
            )
        return ops
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        if "-done(" in line and kind + "-done" in line:
            continue  # counted at -start
        shapes = _SHAPE_RE.findall(m.group(1))
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        ops.append(
            (kind, nbytes, _parse_groups(line)) if with_groups else (kind, nbytes)
        )
    return ops


def per_axis_collective_bytes(hlo_text: str, fast_size: int) -> Dict[str, int]:
    """Collective result bytes bucketed by which mesh fabric they traverse
    (see :func:`group_axis`): ``fast`` stays on the intra-node links, ``slow``
    is the pure inter-node pattern, ``cross`` spans both (flat collectives
    routed over the whole 2-D mesh pay slow-fabric cost too)."""
    out: Dict[str, int] = {
        "fast": 0, "slow": 0, "cross": 0, "local": 0, "unknown": 0
    }
    for _kind, nbytes, groups in collective_ops(hlo_text, with_groups=True):
        out[group_axis(groups, fast_size)] += nbytes
    return out


def per_tier_collective_bytes(
    hlo_text: str, level_sizes, *, min_bytes: int = 0
) -> Dict:
    """Collective result bytes bucketed by mesh tier (see :func:`group_tier`):
    integer keys ``0 … L-1`` (0 = slowest fabric) for single-tier patterns,
    plus ``"cross"`` / ``"local"`` / ``"unknown"``.

    ``min_bytes`` filters the inventory to payload-sized ops — the natural
    form of "zero slow-fabric payload bytes" assertions, which must ignore
    the tiny count/termination control plane."""
    out: Dict = {t: 0 for t in range(len(tuple(level_sizes)))}
    out.update({"cross": 0, "local": 0, "unknown": 0})
    for _kind, nbytes, groups in collective_ops(hlo_text, with_groups=True):
        if nbytes >= min_bytes:
            out[group_tier(groups, level_sizes)] += nbytes
    return out


def tier_bytes_model(level_sizes, level_capacities, item_bytes: int) -> list:
    """Model: bulk payload bytes ONE rank pushes across each mesh tier per
    hierarchical forwarding round, slowest tier first.

    Stage ``l`` ships ``level_sizes[l]`` padded segments of
    ``level_capacities[l]`` rows over tier ``l``'s fabric; the
    ``level_sizes[l] - 1`` segments addressed off-group actually cross it
    (extent-1 tiers skip their stage: 0 bytes)."""
    return [
        float((a - 1) * s * item_bytes) if a > 1 else 0.0
        for a, s in zip(level_sizes, level_capacities)
    ]


def slow_axis_bytes_model(
    exchange: str,
    *,
    num_ranks: int,
    fast_size: int,
    item_bytes: int,
    peer_capacity: int = 0,
    node_capacity: int = 0,
    n_items: int = 0,
) -> float:
    """Model: bulk payload bytes ONE rank pushes across the slow (inter-node)
    fabric per forwarding round.

    * flat ``padded`` routed over the joint 2-D axis: R per-rank slots of
      ``peer_capacity`` rows; the ``R - fast_size`` slots addressed to remote
      nodes cross the slow fabric, each padded per RANK.
    * ``hierarchical``: only stage B crosses — ``num_nodes - 1`` per-NODE
      segments of ``node_capacity`` rows.  At equal burst tolerance K per
      destination (``peer_capacity == node_capacity == K``) the padded rows
      crossing the slow fabric shrink from (R - F)·K to (N - 1)·K — exactly
      R/N×, since R - F = F·(N - 1).
    * ``ragged``: data-dependent — exactly the useful bytes headed off-node
      (uniform-destination estimate from ``n_items``).
    """
    num_nodes = num_ranks // fast_size
    if exchange in ("padded", "flat"):
        return float((num_ranks - fast_size) * peer_capacity * item_bytes)
    if exchange == "hierarchical":
        return float((num_nodes - 1) * node_capacity * item_bytes)
    if exchange == "ragged":
        return float(n_items * item_bytes) * (num_ranks - fast_size) / num_ranks
    raise ValueError(f"no slow-axis model for exchange {exchange!r}")


def padded_wire_rows(level_sizes, level_capacities) -> list:
    """Padded send rows ONE rank puts on the wire per round, per tier: stage
    ``l`` always ships ``level_sizes[l]`` segments of ``level_capacities[l]``
    rows regardless of demand (that is the price of the padded format);
    extent-1 tiers skip their stage entirely.  A flat padded exchange is the
    1-tier instance ``(num_ranks,), (peer_capacity,)``."""
    return [
        a * s if a > 1 else 0
        for a, s in zip(tuple(level_sizes), tuple(level_capacities))
    ]


def occupancy_waste_model(
    level_sizes,
    level_capacities,
    item_bytes: int,
    *,
    useful_rows=None,
    rounds: int = 1,
    num_ranks: int = 1,
) -> Dict:
    """The telemetry subsystem's cost side: padded wire bytes vs useful bytes
    per tier, the quantity the capacity controller trades against drops.

    ``wire_B`` covers ``num_ranks`` senders over ``rounds`` rounds (each rank
    pays :func:`padded_wire_rows` per round regardless of demand).  MATCH THE
    POPULATIONS when passing ``useful_rows``: ``telemetry.summarize(...)
    ["sent_rows"]`` is summed over every rank and recorded round, so pass
    ``num_ranks=R`` and ``rounds=window_filled`` alongside it — the defaults
    (1, 1) are the single-rank single-round static view, and mixing a
    rank-summed ``useful_rows`` into them would inflate ``useful_B`` by R
    (waste_frac could even go negative).  Pass ``useful_rows=None`` for the
    pure static-wire view.  Returns per-tier ``wire_B`` (always paid),
    ``useful_B`` and ``waste_frac`` (padding fraction of the wire), plus
    totals — the "modeled padded bytes" gated by the autotune benchmark: a
    tuned config must never pay more wire than the static worst-case config
    it replaces.
    """
    rows = padded_wire_rows(level_sizes, level_capacities)
    wire = [float(r * rounds * num_ranks * item_bytes) for r in rows]
    out = {"tiers": []}
    for l, w in enumerate(wire):
        useful = (
            float(useful_rows[l]) * item_bytes if useful_rows is not None else None
        )
        out["tiers"].append(
            {
                "wire_B": w,
                "useful_B": useful,
                "waste_frac": (
                    1.0 - useful / w if useful is not None and w else None
                ),
            }
        )
    out["wire_B"] = sum(wire)
    if useful_rows is not None:
        total_useful = float(sum(useful_rows)) * item_bytes
        out["useful_B"] = total_useful
        out["waste_frac"] = (
            1.0 - total_useful / out["wire_B"] if out["wire_B"] else 0.0
        )
    return out


def spill_drain_model(backlog_rows: int, allowance_rows_per_round: int) -> Dict:
    """Model: bounded-delay drain of a spill-and-retry backlog (the lossless
    law's analytical half, gated by the chaos benchmark).

    Under ``overflow="retain"`` a clamp never loses a row — it re-queues it
    at the FRONT of the carry (FIFO oldest-first), so a backlog of
    ``backlog_rows`` rows contending for one destination drains at
    ``allowance_rows_per_round`` rows per round (the per-destination clamp
    budget — ``peer_capacity`` flat, the stage's segment capacity per tier
    hierarchically).  Every budget is ≥ 1 row, so the oldest row always
    ships within ``ceil(backlog / allowance)`` rounds:

        rounds = age_bound = ceil(backlog_rows / allowance_rows_per_round)

    The chaos harness asserts the measured ``age_max`` never exceeds this
    bound (+ the emission span, since the backlog builds over the scenario's
    emitting rounds rather than all at once)."""
    if allowance_rows_per_round < 1:
        raise ValueError(
            "allowance must be >= 1 row/round — every clamp budget admits at "
            f"least one row (got {allowance_rows_per_round})"
        )
    rounds = -(-int(backlog_rows) // int(allowance_rows_per_round))
    return {"rounds": rounds, "age_bound": rounds}


def goodput_model(
    offered_rows_per_round: int,
    drain_rows_per_round: int,
    *,
    rounds: int = 1,
    item_bytes: int = 1,
) -> Dict:
    """Model: wire goodput under sustained overload, open vs credit flow
    (the backpressure law's analytical half, gated by the chaos benchmark).

    ``offered_rows_per_round`` rows per round contend for a receiver that
    can consume (drain) ``drain_rows_per_round``.  With ``flow="open"`` the
    senders ship the full offered load every round; once the receiver's
    bounded queue saturates it admits only what it drains, so every other
    shipped row is wire spent on a row the receiver throws away:

        goodput_open  →  min(1, drain / offered)

    With ``flow="credit"`` senders ship only rows the receiver's advertised
    free space admits — a shipped row is an admitted row by construction:

        goodput_credit = 1.0

    at the price of the excess being HELD at the source through the retain
    spill path (``held_rows``), draining after the overload subsides.  The
    chaos gate asserts the measured goodputs respect this ordering on every
    overload scenario: credit ≥ open, with open below 0.7 where the
    scenario offers ≥ 1.43× the drain rate.

    Returns ``{"open": {wire_B, admitted_B, wasted_B, goodput},
    "credit": {wire_B, admitted_B, wasted_B, goodput, held_rows},
    "goodput_gain"}`` — totals over ``rounds`` rounds.
    """
    if drain_rows_per_round < 1:
        raise ValueError(
            "drain must be >= 1 row/round — every clamp/credit budget admits "
            f"at least one row (got {drain_rows_per_round})"
        )
    offered = float(offered_rows_per_round) * rounds
    admitted = float(min(offered_rows_per_round, drain_rows_per_round)) * rounds
    open_flow = {
        "wire_B": offered * item_bytes,
        "admitted_B": admitted * item_bytes,
        "wasted_B": (offered - admitted) * item_bytes,
        "goodput": admitted / offered if offered else 1.0,
    }
    credit_flow = {
        "wire_B": admitted * item_bytes,
        "admitted_B": admitted * item_bytes,
        "wasted_B": 0.0,
        "goodput": 1.0,
        "held_rows": offered - admitted,
    }
    return {
        "open": open_flow,
        "credit": credit_flow,
        "goodput_gain": credit_flow["goodput"] - open_flow["goodput"],
    }


def marshal_cost_model(
    marshal: str,
    *,
    capacity: int,
    item_bytes: int,
    send_rows: int,
    num_ranks: int = 0,
) -> Dict[str, float]:
    """Model: send-side marshal work ONE rank does per forwarding round —
    the §6.1 "all of [sort/marshal] are trivially cheap" claim, made
    checkable next to the collective byte models.

    Both modes obey the marshal law — exactly ONE pass over the PACKED
    PAYLOAD pre-collective (read C rows, write ``send_rows`` padded rows);
    what ``marshal="scatter"`` deletes is everything the sort did to the KEY
    vector first:

    * ``sort``: key pack (read C dest words, write C keys) + the
      compare-exchange sort — modeled as ``ceil(log2 C)`` read+write passes
      over the C-word key vector (XLA's bitonic/merge family) — then the one
      composed payload gather.
    * ``scatter``: the counting-sort plan (read C dest words, write C ranks +
      C sanitized dests, accumulate the (R+1)-word histogram) — a single
      O(C) pass, no keys — then the one payload scatter.

    Returns ``{"payload_passes", "payload_bytes", "plan_bytes",
    "total_bytes"}`` (bytes are on-chip traffic, not wire bytes; compare
    against the exchange's collective bytes to see marshal overhead shrink
    from O(C log C) + 2-passes-equivalent to the single-pass floor).
    """
    payload_bytes = float((capacity + send_rows) * item_bytes)
    word = 4.0
    if marshal == "sort":
        log2c = max(1, int(np.ceil(np.log2(max(capacity, 2)))))
        plan = capacity * word * 2  # key pack: read dest, write keys
        plan += log2c * 2 * capacity * word  # sort passes over the keys
    elif marshal == "scatter":
        plan = capacity * word  # read dest
        plan += 2 * capacity * word  # write d_clean + in-bucket rank
        plan += (num_ranks + 1) * word  # histogram accumulator
    else:
        raise ValueError(f"no marshal model for {marshal!r}")
    return {
        "payload_passes": 1.0,  # the marshal law, either mode
        "payload_bytes": payload_bytes,
        "plan_bytes": float(plan),
        "total_bytes": payload_bytes + float(plan),
    }


def overlap_efficiency_model(
    phase_us: Dict[str, float],
    shards: int,
    *,
    wire_phases=("count_collective", "payload_collective"),
    async_fraction: float = 1.0,
) -> Dict[str, float]:
    """Model: the overlap law's walltime — software-pipelining one forwarding
    round into ``shards`` micro-shards (``ForwardConfig.pipeline_shards``).

    Input is the measured per-phase breakdown of ONE bulk round (the
    ``fwd_profile_*`` rows: marshal, count_collective, payload_collective,
    unmarshal).  Phases in ``wire_phases`` are collective time ``w``; the
    rest is send/receive compute ``c``.  With S shards each phase splits into
    S chunks of 1/S the work, and a fabric that can ship one chunk while the
    VPU marshals the next hides ``async_fraction`` of the wire time behind
    compute.  The classic fill/drain pipeline bound:

        T(S, a) = (1 - a)·w  +  (c + a·w)/S  +  (S - 1)/S · max(c, a·w)

    * ``a = 1`` (DMA/NIC fabric — TPU ICI, the paper's target): steady state
      overlaps perfectly, T → max(c, w) as S grows; speedup caps at
      ``(c + w)/max(c, w)``.
    * ``a = 0`` (synchronous fabric — XLA:CPU's memcpy collectives): T equals
      the bulk round — the model predicts NO overlap win, so any measured
      gain there is the locality corollary (each 1/S chunk is marshalled,
      shipped and compacted while still cache-resident) and any loss is the
      S× launch overhead.  The gate brackets measurements with both bounds.

    Returns ``{"bulk_us", "pipelined_us", "speedup", "efficiency",
    "compute_us", "wire_us"}`` — ``efficiency`` is the achieved fraction of
    the perfect-overlap bound ``max(c, w)``.
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if not 0.0 <= async_fraction <= 1.0:
        raise ValueError(f"async_fraction must be in [0, 1], got {async_fraction}")
    w = float(sum(us for ph, us in phase_us.items() if ph in wire_phases))
    c = float(sum(us for ph, us in phase_us.items() if ph not in wire_phases))
    bulk = c + w
    a = float(async_fraction)
    hidden = a * w
    pipelined = (
        (1.0 - a) * w
        + (c + hidden) / shards
        + (shards - 1) / shards * max(c, hidden)
    )
    return {
        "bulk_us": bulk,
        "pipelined_us": pipelined,
        "speedup": bulk / pipelined if pipelined > 0 else float("inf"),
        "efficiency": max(c, w) / pipelined if pipelined > 0 else 1.0,
        "compute_us": c,
        "wire_us": w,
    }


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of result-shape bytes per collective kind; handles both post-SPMD
    HLO (``all-gather(...)``) and StableHLO (``"stablehlo.all_gather"``)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for kind, nbytes in collective_ops(hlo_text):
        out[kind] += nbytes
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    coll_breakdown: Dict[str, int]
    bytes_per_chip: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * HW["peak_flops"])

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HW["hbm_bw"])

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * HW["link_bw"])

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "coll_breakdown": self.coll_breakdown,
            "bytes_per_chip": self.bytes_per_chip,
        }


def analyze_lowered(lowered, compiled, chips: int) -> RooflineTerms:
    """Derive the three terms from (lowered, compiled) jit artifacts.

    cost_analysis FLOPs/bytes are per-device on SPMD modules (XLA reports
    the per-partition HLO); we convert to whole-job numbers by × chips.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) * chips
    bytes_accessed = float(cost.get("bytes accessed", 0.0)) * chips
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)
    coll_total = float(sum(coll.values())) * chips

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
        )
    except Exception:
        pass
    return RooflineTerms(
        flops=flops,
        bytes_accessed=bytes_accessed,
        coll_bytes=coll_total,
        chips=chips,
        coll_breakdown=coll,
        bytes_per_chip=mem,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), D = processed tokens.

    For prefill/decode the factor is 2·N per token (forward only)."""
    import jax

    from repro.models.api import build_model

    model = build_model(cfg)
    n_params = model.param_count()
    if cfg.kind == "moe":
        # active params: replace expert count by top_k in the FFN share
        e, k = cfg.num_experts, cfg.top_k
        ffn = 3 * cfg.d_model * cfg.d_ff * e * cfg.num_layers
        active_ffn = ffn * k / e
        n_active = n_params - ffn + active_ffn
    else:
        n_active = n_params
    tokens = shape.global_batch * (shape.seq_len if shape.step != "decode" else 1)
    factor = 6.0 if shape.step == "train" else 2.0
    return factor * n_active * tokens

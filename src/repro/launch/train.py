"""End-to-end training driver: data → jitted train_step → checkpoints.

Fault-tolerance contract exercised here (and in tests/test_train_e2e.py):
  * auto-resume: on start, the trainer restores the latest checkpoint and
    continues from its step; the data pipeline is a pure function of step,
    so a killed-and-restarted run reproduces the uninterrupted run exactly;
  * periodic atomic checkpoints (``--ckpt-every``);
  * elastic restart: pass a different mesh factorization and restore lands
    the same logical tensors on the new layout.

Usage (CPU demo, ~25M params):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_train_step
from repro.models.api import build_model
from repro.optim import AdamWConfig, adamw_init


def train(
    *,
    arch: str = "qwen2-7b",
    smoke: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    ckpt_dir: str = "/tmp/repro_ckpt",
    ckpt_every: int = 20,
    mesh=None,
    log_every: int = 10,
    opt_cfg: AdamWConfig = AdamWConfig(warmup_steps=20),
    verbose: bool = True,
):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    mesh = mesh or make_test_mesh()
    ds = SyntheticLM(cfg.vocab_size, seq, batch)

    step_fn, shardings = build_train_step(model, mesh, opt_cfg)
    # out_shardings pins the state outputs to the same layout as the inputs:
    # the loop feeds outputs straight back in, and older JAX rejects (rather
    # than auto-reshards) args whose committed sharding drifts from in_shardings.
    jitted = jax.jit(
        step_fn,
        in_shardings=(shardings["params"], shardings["opt"], None),
        out_shardings=(shardings["params"], shardings["opt"], None),
        donate_argnums=(0, 1),
    )

    start = latest_step(ckpt_dir)
    if start is not None:
        if verbose:
            print(f"[train] resuming from checkpoint step {start}")
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params, opt_cfg)
        state = restore_checkpoint(ckpt_dir, start, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start_step = start
    else:
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params, opt_cfg)
        start_step = 0

    params = jax.device_put(params, shardings["params"])
    opt = jax.device_put(opt, shardings["opt"])

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch_np = ds.batch_at(step)
        params, opt, metrics = jitted(params, opt, batch_np)
        loss = float(metrics["loss"])
        losses.append((step, loss))
        if verbose and (step % log_every == 0 or step == steps - 1):
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {loss:8.4f} ({dt:.1f}s)", flush=True)
        if ckpt_every and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, {"params": params, "opt": opt})
    if ckpt_every:
        save_checkpoint(ckpt_dir, steps, {"params": params, "opt": opt})
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()
    train(
        arch=args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )


if __name__ == "__main__":
    main()

"""Batched serving loop with straggler-aware slot rebalancing.

A fixed pool of decode slots (continuous-batching-lite): requests with
heterogeneous remaining lengths occupy batch slots; each engine step decodes
one token for every active slot.  Per-slot remaining-work counts double as
the load signal — under multi-engine (data-axis) serving, the RaFI
``rebalance`` primitive can redistribute queued requests so no engine idles
while another has a backlog (the §6.3 starvation problem, solved with the
paper's own machinery).

This module provides the single-engine loop used by the example and the
``serve_step`` shape that the dry-run lowers at production scale.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray         # (L,) int32
    max_new_tokens: int = 16
    output: Optional[List[int]] = None


def reset_slot(caches, slot: int):
    """Zero a slot's decode positions (and recurrent states) so a freed slot
    can be reused by a new request — stale KV rows past pos are masked out."""
    import jax

    def visit(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        if keys and keys[-1] == "pos":
            return leaf.at[..., slot].set(0)
        return leaf

    # (attention caches only need the position reset — stale K/V rows past
    # pos are masked; recurrent-state models would zero their h/S rows here)
    return jax.tree_util.tree_map_with_path(visit, caches)


class BatchedEngine:
    """Slot-synchronous engine: all slots step together; finished slots are
    refilled from the queue.  Remaining-work histogram is the rebalance
    signal exported to the multi-engine scheduler."""

    def __init__(self, model: Model, params, *, slots: int = 4, max_len: int = 128, mesh=None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.step_fn = jax.jit(model.decode_fn(mesh=mesh))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {r.rid: [] for r in requests}
        pending = list(requests)
        caches = self.model.init_caches(self.slots, self.max_len)
        slot_req: List[Optional[Request]] = [None] * self.slots
        left = np.zeros(self.slots, np.int64)
        cur = np.zeros((self.slots, 1), np.int32)

        # simple admission: prompts are replayed token-by-token (slots step
        # in lockstep, so admission happens between engine steps)
        prompt_pos = np.zeros(self.slots, np.int64)

        def admit():
            nonlocal caches
            for s in range(self.slots):
                if slot_req[s] is None and pending:
                    slot_req[s] = pending.pop(0)
                    left[s] = slot_req[s].max_new_tokens
                    prompt_pos[s] = 0
                    caches = reset_slot(caches, s)  # reuse slot: fresh prefix

        admit()
        steps = 0
        while any(r is not None for r in slot_req) and steps < 10_000:
            # feed either the next prompt token or the last generated token
            for s, req in enumerate(slot_req):
                if req is None:
                    cur[s, 0] = 0
                elif prompt_pos[s] < len(req.prompt):
                    cur[s, 0] = req.prompt[prompt_pos[s]]
            logits, caches = self.step_fn(self.params, jnp.asarray(cur), caches)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s, req in enumerate(slot_req):
                if req is None:
                    continue
                if prompt_pos[s] < len(req.prompt):
                    prompt_pos[s] += 1  # still consuming the prompt
                    if prompt_pos[s] == len(req.prompt):
                        cur[s, 0] = nxt[s]
                        out[req.rid].append(int(nxt[s]))
                        left[s] -= 1
                else:
                    cur[s, 0] = nxt[s]
                    out[req.rid].append(int(nxt[s]))
                    left[s] -= 1
                if left[s] <= 0 and prompt_pos[s] >= len(req.prompt):
                    slot_req[s] = None
            admit()
            steps += 1
        return out

    def load_signal(self, slot_req, left) -> int:
        """Remaining tokens across slots — the rebalance metric."""
        return int(sum(max(0, l) for l in left))

"""Jitted step builders shared by the trainer, server, and dry-run.

Each builder returns (fn, in_shardings, out_shardings, abstract_inputs) so
the dry-run can ``jax.jit(fn, ...).lower(*abstract).compile()`` without
allocating anything, and the real trainer can feed concrete arrays through
the identical code path.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import Cell
from repro.models.api import Model
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, opt_state_specs


def resolve_spec(shape, spec: P, mesh, *, allow_move: bool = True) -> P:
    """Make a PartitionSpec legal for ``shape`` on ``mesh``.

    pjit input shardings require every sharded dim to divide evenly.  Axes
    that don't fit are dropped from that dim and — when ``allow_move`` —
    relocated to the first unsharded dim they do divide (e.g. a KV cache
    whose 4 heads can't split 16 ways shards its 128-wide head_dim instead;
    rwkv's 40-head ``u`` shards its channel dim; a 256206 vocab embedding
    shards d_model).  This keeps memory balanced instead of silently
    replicating whole tensors.
    """
    parts = list(spec) + [None] * (len(shape) - len(spec))
    placed = []
    pending = []
    for dim, part in zip(shape, parts):
        axes = () if part is None else (part if isinstance(part, tuple) else (part,))
        keep = []
        factor = 1
        for ax in axes:
            size = mesh.shape[ax]
            if dim % (factor * size) == 0:
                keep.append(ax)
                factor *= size
            else:
                pending.append(ax)
        placed.append(tuple(keep))
    if allow_move:
        for ax in pending:
            used = {a for p in placed for a in p}
            if ax in used:
                continue
            for i, dim in enumerate(shape):
                if not placed[i] and dim % mesh.shape[ax] == 0 and mesh.shape[ax] > 1:
                    placed[i] = (ax,)
                    break
    return P(*[(p[0] if len(p) == 1 else p) if p else None for p in placed])


def _named(mesh, tree_specs, tree_shapes, *, allow_move: bool = True):
    """NamedShardings with divisibility resolution against abstract shapes."""
    specs = jax.tree.map(
        lambda s, a: resolve_spec(a.shape, s, mesh, allow_move=allow_move),
        tree_specs,
        tree_shapes,
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def _batch_shardings(mesh, batch: Dict[str, Any], cfg=None):
    baxes = tuple(
        a for a in mesh.axis_names
        if a != "model" or (cfg is not None and cfg.dp_over_model)
    )
    return {
        k: NamedSharding(
            mesh,
            resolve_spec(
                v.shape,
                P(baxes, *([None] * (len(v.shape) - 1))),
                mesh,
                allow_move=False,
            ),
        )
        for k, v in batch.items()
    }


def build_train_step(model: Model, mesh, opt_cfg: AdamWConfig = AdamWConfig()):
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``cfg.microbatches > 1`` enables gradient accumulation: the global batch
    is scanned in slices, which divides activation memory by the slice count
    at the cost of re-reading the weights per slice (compute/comm overlap
    across slices is XLA's job — the slices are a sequential scan)."""
    loss_fn = model.loss_fn(mesh=mesh)
    m = max(1, model.cfg.microbatches)

    def train_step(params, opt_state, batch):
        if m == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return jax.tree.map(jnp.add, acc, (l, g)), None

            zero = (
                jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params),
            )
            (loss_sum, gsum), _ = jax.lax.scan(body, zero, micro)
            loss = loss_sum / m
            grads = jax.tree.map(lambda g: g / m, gsum)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    pspecs = model.specs()
    ospecs = opt_state_specs(pspecs, opt_cfg)
    pabs = model.abstract()
    oabs = abstract_opt_state(model, opt_cfg)
    shardings = {
        "params": _named(mesh, pspecs, pabs),
        "opt": _named(mesh, ospecs, oabs),
    }
    return train_step, shardings


def build_prefill_step(model: Model, mesh):
    fn = model.prefill_fn(mesh=mesh)
    return fn, {"params": _named(mesh, model.specs(serve=True), model.abstract())}


def build_decode_step(model: Model, mesh, *, batch: int = 1, max_len: int = 128):
    fn = model.decode_fn(mesh=mesh)
    caches_abs = abstract_caches(model, batch, max_len)
    return fn, {
        "params": _named(mesh, model.specs(serve=True), model.abstract()),
        "caches": _named(mesh, model.cache_specs(), caches_abs),
    }


def abstract_opt_state(model: Model, opt_cfg: AdamWConfig = AdamWConfig()):
    """ShapeDtypeStructs of the optimizer state (no allocation) — mirrors
    adamw_init exactly (incl. optional master copies / compression residuals)."""
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), model.abstract())


def abstract_caches(model: Model, batch: int, max_len: int):
    return jax.eval_shape(lambda: model.init_caches(batch, max_len))


def lower_cell(model: Model, mesh, cell: Cell, *, donate: bool = True):
    """Lower the cell's step with fully-abstract inputs. Returns `lowered`."""
    cfg = model.cfg
    params_abs = model.abstract()
    batch_shard = _batch_shardings(mesh, cell.batch, model.cfg)

    if cell.step == "train":
        step, shardings = build_train_step(model, mesh)
        opt_abs = abstract_opt_state(model)  # default cfg matches build_train_step default
        jitted = jax.jit(
            step,
            in_shardings=(shardings["params"], shardings["opt"], batch_shard),
            donate_argnums=(0, 1) if donate else (),
        )
        return jitted.lower(params_abs, opt_abs, cell.batch)

    if cell.step == "prefill":
        fn, shardings = build_prefill_step(model, mesh)
        jitted = jax.jit(fn, in_shardings=(shardings["params"], batch_shard))
        return jitted.lower(params_abs, cell.batch)

    # decode
    fn, shardings = build_decode_step(
        model, mesh, batch=cell.shape.global_batch, max_len=cell.shape.seq_len
    )
    caches_abs = abstract_caches(model, cell.shape.global_batch, cell.shape.seq_len)
    baxes = tuple(
        a for a in mesh.axis_names if a != "model" or cfg.dp_over_model
    )
    token_shard = NamedSharding(
        mesh,
        resolve_spec(
            cell.batch["token"].shape, P(baxes, None), mesh, allow_move=False
        ),
    )
    if cfg.kind == "encdec":
        mem_shard = NamedSharding(
            mesh,
            resolve_spec(
                cell.batch["memory"].shape, P(baxes, None, None), mesh,
                allow_move=False,
            ),
        )
        jitted = jax.jit(
            fn,
            in_shardings=(
                shardings["params"], token_shard, shardings["caches"], mem_shard
            ),
            donate_argnums=(2,) if donate else (),
        )
        return jitted.lower(
            params_abs, cell.batch["token"], caches_abs, cell.batch["memory"]
        )
    jitted = jax.jit(
        fn,
        in_shardings=(shardings["params"], token_shard, shardings["caches"]),
        donate_argnums=(2,) if donate else (),
    )
    return jitted.lower(params_abs, cell.batch["token"], caches_abs)

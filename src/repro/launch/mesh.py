"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips of a
v5e pod; multi-pod adds a leading "pod" axis (2 × 256 = 512 chips), which is
pure data parallelism across the pod boundary (DCN-class links).

Forwarding over multi-node jobs uses the 2-D ``(node, device)`` meshes below:
"node" spans the slow inter-node fabric (DCN), "device" the fast intra-node
fabric (ICI/NVLink) — the axis order the hierarchical exchange's
``(slow, fast)`` contract expects (see ``core.exchange``).  Ranks are
node-major: ``jax.lax.axis_index(("node", "device")) == node * devices + dev``.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_production_node_mesh(nodes: int = 2, devices_per_node: int = 256):
    """Multi-node forwarding mesh: (node, device) with DCN across nodes.

    The default is 2 × 256 = 512 chips — the multi-pod job shaped for the
    hierarchical exchange instead of a flat joint axis.
    """
    return compat.make_mesh((nodes, devices_per_node), ("node", "device"))


def make_node_mesh(nodes: int = 2, devices_per_node: int = 4):
    """Small 2-D (node, device) CPU mesh for tests/benchmarks of the
    hierarchical exchange; 2×4 and 4×2 both fit the 8-device test platform."""
    return compat.make_mesh((nodes, devices_per_node), ("node", "device"))


def make_pod_mesh(pods: int = 2, nodes: int = 2, devices_per_node: int = 2):
    """Small 3-D (pod, node, device) CPU mesh — the N-level exchange's
    (slowest, …, fastest) shape; the (2, 2, 2) default fits the 8-device
    test platform.  "pod" spans the DCN, "node" the inter-host fabric,
    "device" the intra-node ICI/NVLink."""
    return compat.make_mesh((pods, nodes, devices_per_node), ("pod", "node", "device"))


def make_production_pod_mesh(pods: int = 2, nodes: int = 2, devices_per_node: int = 128):
    """Multi-pod forwarding mesh: (pod, node, device) with DCN across pods,
    host fabric across nodes, ICI within — 2 × 2 × 128 = 512 chips shaped
    for the 3-level hierarchical route instead of a flat joint axis."""
    return compat.make_mesh((pods, nodes, devices_per_node), ("pod", "node", "device"))


def make_test_mesh(data: int = 2, model: int = 4):
    """Small CPU mesh for tests/examples."""
    return compat.make_mesh((data, model), ("data", "model"))

"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips of a
v5e pod; multi-pod adds a leading "pod" axis (2 × 256 = 512 chips), which is
pure data parallelism across the pod boundary (DCN-class links).
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small CPU mesh for tests/examples."""
    return compat.make_mesh((data, model), ("data", "model"))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh).

The two lines above MUST precede any other import (jax locks the device
count on first init); 512 placeholder host devices let ``jax.make_mesh``
build the production meshes.  For every cell this driver:

  1. builds the full-size config and abstract inputs (ShapeDtypeStruct — no
     allocation anywhere),
  2. ``jax.jit(step).lower(...)`` with the production in/out shardings,
  3. ``.compile()`` — sharding mismatches, OOM-at-compile, or unsupported
     collectives fail HERE, which is the point,
  4. records memory_analysis / cost_analysis / per-collective bytes and the
     derived roofline terms into ``artifacts/dryrun/<cell>.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --sweep [--multi-pod] [--force]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _probe_costs(cfg, arch, shape_name, mesh, chips, overrides=None):
    """Per-pattern-period incremental cost via differencing two shallow models.

    XLA's cost_analysis counts a while-loop (scan) body ONCE regardless of
    trip count, so deep scanned models under-report flops/bytes/collectives.
    Lowering the same cell at 1 and 2 pattern periods and differencing gives
    the exact per-period increment; the full-depth totals are then
      total = full_reported + (n_blocks - 1) · period_increment.
    """
    import dataclasses

    from repro.configs.registry import input_specs
    from repro.launch.steps import lower_cell
    from repro.models.api import build_model
    from repro.roofline.analysis import collective_bytes

    period = len(cfg.pattern)
    out = []
    for mult in (1, 2):
        repl = {"num_layers": period * mult, "scan_unroll": True}
        if cfg.kind == "encdec":
            repl["encoder_layers"] = mult
            repl["num_layers"] = mult
        pcfg = dataclasses.replace(cfg, **repl)
        cell = input_specs(arch, shape_name, pcfg)
        model = build_model(pcfg)
        with mesh:
            lowered = lower_cell(model, mesh, cell)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
        out.append(
            {
                "flops": float(cost.get("flops", 0.0)) * chips,
                "bytes": float(cost.get("bytes accessed", 0.0)) * chips,
                "coll": float(sum(coll.values())) * chips,
            }
        )
    inc = {k: max(out[1][k] - out[0][k], 0.0) for k in out[0]}
    return inc


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, force: bool = False,
             overrides: dict | None = None, tag: str = "") -> dict:
    import jax

    from repro.configs.registry import get_config, input_specs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell
    from repro.models.api import build_model
    from repro.roofline.analysis import analyze_lowered, model_flops

    mesh_tag = "pod2" if multi_pod else "pod1"
    name = f"{arch}__{shape_name}__{mesh_tag}{tag}"
    out_path = ARTIFACTS / f"{name}.json"
    if out_path.exists() and not force:
        cached = json.loads(out_path.read_text())
        if cached.get("status") != "error":  # errors are retried after fixes
            return cached

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    cfg = get_config(arch)
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    cell = input_specs(arch, shape_name, cfg)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag,
        "step": cell.step,
        "tag": tag,
    }
    if cell.skip:
        rec["status"] = "skip"
        rec["reason"] = cell.skip
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(mesh.devices.size)
        model = build_model(cfg)
        with mesh:
            lowered = lower_cell(model, mesh, cell)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            print(f"[{name}] memory_analysis:", mem)
            cost = compiled.cost_analysis()
            print(f"[{name}] cost_analysis: flops={cost.get('flops', 0):.3e} "
                  f"bytes={cost.get('bytes accessed', 0):.3e}")
            terms = analyze_lowered(lowered, compiled, chips)
        # scan-body trip-count correction (see _probe_costs)
        period = len(cfg.pattern)
        n_blocks = (
            cfg.num_layers if cfg.kind == "encdec" else cfg.num_layers // period
        )
        if n_blocks > 1:
            inc = _probe_costs(cfg, arch, shape_name, mesh, chips, overrides)
            extra = n_blocks - 1
            terms.flops += extra * inc["flops"]
            terms.bytes_accessed += extra * inc["bytes"]
            terms.coll_bytes += extra * inc["coll"]
        if cell.step == "train" and cfg.microbatches > 1:
            # the gradient-accumulation scan is another once-counted loop;
            # everything except the (small) optimizer update runs m times
            m = cfg.microbatches
            terms.flops *= m
            terms.bytes_accessed *= m
            terms.coll_bytes *= m
        mf = model_flops(cfg, cell.shape)
        rec.update(
            status="ok",
            chips=chips,
            n_params=model.param_count(),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "peak_bytes_per_device": int(
                    mem.argument_size_in_bytes + mem.temp_size_in_bytes
                ),
            },
            roofline=terms.as_dict(),
            model_flops=mf,
            useful_flops_ratio=(mf / terms.flops if terms.flops else None),
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded result
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        print(f"[{name}] FAILED: {rec['error']}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    from repro.configs.registry import ARCHS, shape_suite

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for variants")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig overrides key=value (repeatable)")
    args = ap.parse_args()

    overrides = {}
    if args.set:
        from repro.configs.registry import get_config

        for kv in args.set:
            k, v = kv.split("=", 1)
            overrides[k] = eval(v)  # noqa: S307 — trusted CLI input

    if args.sweep:
        results = []
        for arch in ARCHS:
            for shape_name in shape_suite(arch):
                r = run_cell(arch, shape_name, multi_pod=args.multi_pod, force=args.force)
                status = r.get("status")
                extra = (
                    f" dominant={r['roofline']['dominant']}"
                    if status == "ok" else f" ({r.get('reason', r.get('error', ''))[:60]})"
                )
                print(f"{arch:>22} × {shape_name:<12} [{r['mesh']}] → {status}{extra}",
                      flush=True)
                results.append(r)
        ok = sum(1 for r in results if r["status"] == "ok")
        skip = sum(1 for r in results if r["status"] == "skip")
        err = sum(1 for r in results if r["status"] == "error")
        print(f"\nsweep done: {ok} ok, {skip} skip, {err} error")
        raise SystemExit(1 if err else 0)

    r = run_cell(args.arch, args.shape, multi_pod=args.multi_pod, force=args.force,
                 overrides=overrides, tag=args.tag)
    print(json.dumps(r, indent=1))


if __name__ == "__main__":
    main()

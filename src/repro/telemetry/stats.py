"""On-device traffic flight recorder — the measurement half of ISSUE 5.

Every forwarding round already computes the full traffic picture as part of
its control plane: the marshal histogram is the per-destination demand, the
hierarchical route's per-stage count ``all_to_all`` results are the
per-sub-segment demands at every tier, and the §3.3 clamps know exactly what
they cut.  ``RoundStats`` snapshots those values — and NOTHING else: stats
capture issues ZERO additional collectives and never touches the payload, so
the per-axis budget law (one payload + one count collective per mesh axis
per round) is unchanged with telemetry enabled (guarded in
``tests/test_collective_budget.py``).

The recorded quantities, per round, per rank:

* ``demand_hist``  (L, B) — per-tier histogram of *segment demand*: for each
  send segment at tier ``l`` (a per-peer slot of the padded exchange, a
  per-peer-digit slot column of a hierarchical stage), the rows the workload
  WANTED to put there, pre-clamp.  Bucketing is fixed-width relative to that
  tier's configured capacity (:func:`occupancy_bucket`), with the last bucket
  collecting everything at or above capacity — the demand that §3.3 clamps.
* ``demand_max`` / ``demand_total`` (L,) — exact max / sum of those demands
  (the max survives bucketing exactly, so a drop-free capacity plan never
  depends on bucket resolution).
* ``sent_rows``    (L,) — rows actually shipped post-clamp (the useful wire
  rows; ``level_sizes[l]·level_capacities[l] - sent_rows[l]`` is padding).
* ``stage_drops``  (L,) — rows the tier-``l`` send clamp cut (§3.3).  Summed
  with ``recv_drops`` this reproduces the exchange's drop return exactly
  (the PR-4 count-each-drop-exactly-once accounting, per stage).
* ``recv_total`` / ``recv_drops`` — rows arriving at the receiver pre-clamp,
  and what the receiver-capacity compaction cut.
* ``wasted_wire_rows`` — rows that CROSSED a wire and were then discarded:
  the receiver-clamp cut plus, hierarchically, any stage clamp past the
  first wire crossing (a row clamped at stage ``l > 0`` of the route already
  spent stage-``0..l-1`` wire).  This is the PR-9 goodput ledger's waste
  term as a first-class per-round field — the open-flow identity
  ``drops == emit_overflow + wasted_wire_rows`` is checkable from the
  recorder alone (previously reconstructed ad-hoc by the chaos driver and
  bench gates from ``recv_drops``, which undercounts multi-hop routes).
  For flat backends it equals ``recv_drops``; the ragged backend's sender
  clamps cut rows BEFORE the wire, so its waste stays the receiver cut.
* ``retained_rows`` / ``age_max`` — spill-and-retry observability (ISSUE 6,
  ``ForwardConfig(overflow="retain")``): rows the round RETAINED locally
  instead of dropping, and the oldest retained lane's rounds-waiting counter
  (the anti-starvation bound the chaos gate asserts on).  Zero under
  ``overflow="drop"``.
* ``credits_granted`` / ``rows_held`` (L,) — backpressure observability
  (ISSUE 9, ``ForwardConfig(flow="credit")``): the wire allowance the credit
  apportionment granted this rank at the gating tier, and the rows each
  tier's clamp held locally this round (under open flow ``rows_held`` is the
  retain spill count; under credit it includes the un-credited tails).  Like
  every other field, derived from control-plane values the round already
  computes — zero added collectives.
* ``emit_overflow`` — rows the LOCAL emission path discarded (the
  application enqueued past the queue capacity, or the retained-rows merge
  clipped at capacity).  Previously folded silently into ``drops``; surfaced
  separately so the chaos uid accounting can attribute it (retain + credit
  together must drive it to zero — the graceful-degradation half of the
  backpressure law).  Stamped by the drive, not the exchange.

Tier indexing matches ``ForwardConfig``: hierarchical configs record one row
per ``level_sizes`` entry (slowest first; extent-1 tiers skip their stage and
stay zero), flat configs record a single tier.  The bucketing reference per
tier is :func:`tier_capacities` — ``level_capacities`` / ``peer_capacity`` /
the receiver ``capacity`` for the backends without per-peer slots.

A ``StatsRing`` keeps the last ``window`` rounds of ``RoundStats`` as a
fixed-shape pytree so it can ride a ``jax.lax.while_loop`` carry (the
``run_until_done`` drive loop records every round on device; the host reads
the ring back between bursts).  Unwritten slots are all-zero and contribute
nothing to any aggregate, so no validity mask is needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "RoundStats",
    "StatsRing",
    "attach_emit_overflow",
    "bucket_width",
    "bucket_upper_edges",
    "occupancy_bucket",
    "occupancy_histogram",
    "make_stats",
    "single_tier_stats",
    "make_ring",
    "ring_push",
    "ring_filled",
    "stack_ring",
    "tier_capacities",
    "num_tiers",
    "summarize",
    "demand_quantile",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoundStats:
    """One forwarding round's traffic snapshot (module docstring for fields).

    All leaves are int32 with static shapes ``(L, B)`` / ``(L,)`` / ``()`` —
    a ``RoundStats`` is a plain pytree and rides loop carries unchanged.
    """

    demand_hist: jax.Array   # (L, B) segments per demand bucket, per tier
    demand_max: jax.Array    # (L,) exact max single-segment demand
    demand_total: jax.Array  # (L,) total rows presented to the tier
    sent_rows: jax.Array     # (L,) rows actually shipped post-clamp
    stage_drops: jax.Array   # (L,) rows the tier's §3.3 send clamp cut
    recv_total: jax.Array    # () rows arriving pre receiver clamp
    recv_drops: jax.Array    # () rows the receiver compaction cut
    wasted_wire_rows: jax.Array  # () post-wire discards (recv + late stages)
    retained_rows: jax.Array  # () rows retained locally (overflow="retain")
    age_max: jax.Array       # () oldest retained lane's rounds waiting
    credits_granted: jax.Array  # (L,) credit allowance granted (flow="credit")
    rows_held: jax.Array     # (L,) rows each tier's clamp held locally
    emit_overflow: jax.Array  # () local emission rows clipped (drive-stamped)

    @property
    def tiers(self) -> int:
        return self.demand_hist.shape[-2]

    @property
    def buckets(self) -> int:
        return self.demand_hist.shape[-1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StatsRing:
    """Last-``window`` rounds of :class:`RoundStats`, device-resident.

    ``stats`` leaves carry a leading ``(window,)`` dim; ``pos`` is the number
    of rounds recorded so far (the next write lands at ``pos % window``).
    """

    stats: RoundStats  # leaves (window, ...)
    pos: jax.Array     # () int32 rounds recorded so far

    @property
    def window(self) -> int:
        return self.stats.demand_hist.shape[-3]


# ------------------------------------------------------------ bucketing law
def bucket_width(capacity: int, num_buckets: int) -> int:
    """Fixed bucket width so buckets ``0 … B-2`` tile ``[0, capacity)``.
    Shared by the recorder, the controller's quantile inversion, and the
    oracle property tests — there is exactly one bucketing definition in
    the codebase (see :func:`occupancy_bucket` for the overflow rule)."""
    return max(1, -(-int(capacity) // (int(num_buckets) - 1)))


def bucket_upper_edges(capacity: int, num_buckets: int) -> np.ndarray:
    """Exclusive upper demand edge of every bucket (host-side, for the
    controller's conservative quantile → capacity inversion).  The overflow
    bucket ``B-1`` is genuinely unbounded — its entry is clamped to
    ``capacity`` here only as a placeholder; :func:`demand_quantile` answers
    from the exact recorded max whenever a quantile lands there."""
    w = bucket_width(capacity, num_buckets)
    return np.minimum(np.arange(1, num_buckets + 1) * w, capacity)


def occupancy_bucket(occ: jax.Array, capacity: int, num_buckets: int) -> jax.Array:
    """Bucket index of each demand value (traced).  Bucket ``B-1`` is the
    §3.3 overflow bucket: EVERY demand at or above ``capacity`` lands there
    explicitly (``capacity`` is rarely divisible by ``B-1``, so the plain
    ``occ // width`` quotient alone would file an exactly-at-clamp segment
    into an interior bucket and host tooling reading ``demand_hist[:, -1]``
    as 'segments that hit the clamp' would undercount)."""
    w = bucket_width(capacity, num_buckets)
    return jnp.where(
        occ >= capacity,
        num_buckets - 1,
        jnp.minimum(occ // w, num_buckets - 2),
    ).astype(jnp.int32)


def occupancy_histogram(occ: jax.Array, capacity: int, num_buckets: int) -> jax.Array:
    """(B,) int32 — segments per demand bucket.  ``occ`` is the (A,) vector
    of per-segment demands at one tier; control-plane sized, no collective."""
    b = occupancy_bucket(occ, capacity, num_buckets)
    return jnp.zeros((num_buckets,), jnp.int32).at[b].add(1)


# --------------------------------------------------------------- builders
def make_stats(tiers: int, buckets: int) -> RoundStats:
    """All-zero stats — the builder the exchanges fill tier by tier."""
    z = jnp.zeros((), jnp.int32)
    return RoundStats(
        demand_hist=jnp.zeros((tiers, buckets), jnp.int32),
        demand_max=jnp.zeros((tiers,), jnp.int32),
        demand_total=jnp.zeros((tiers,), jnp.int32),
        sent_rows=jnp.zeros((tiers,), jnp.int32),
        stage_drops=jnp.zeros((tiers,), jnp.int32),
        recv_total=z,
        recv_drops=z,
        wasted_wire_rows=z,
        retained_rows=z,
        age_max=z,
        credits_granted=jnp.zeros((tiers,), jnp.int32),
        rows_held=jnp.zeros((tiers,), jnp.int32),
        emit_overflow=z,
    )


def single_tier_stats(
    demand: jax.Array,      # (A,) per-segment demand, pre-clamp
    capacity: int,          # the tier's configured segment capacity
    buckets: int,
    *,
    sent_rows: jax.Array,   # () rows shipped post-clamp
    stage_drops: jax.Array,  # () send-clamp drops
    recv_total: jax.Array,  # () rows arriving pre receiver clamp
    recv_drops: jax.Array,  # () receiver compaction drops
    credits_granted: jax.Array = None,  # () credit allowance granted
    rows_held: jax.Array = None,  # () rows the send clamp held locally
    wasted_wire_rows: jax.Array = None,  # () post-wire discards (≠ recv_drops
    # only where a backend discards shipped rows somewhere other than the
    # receiver compaction — every current flat backend defaults)
) -> RoundStats:
    """The flat-backend capture: one tier, filled in one call.  The retain
    fields start zero — ``forward_work`` stamps them after the merge (the
    exchange doesn't see the receiver-side admission)."""
    z = jnp.zeros((), jnp.int32)
    return RoundStats(
        demand_hist=occupancy_histogram(demand, capacity, buckets)[None, :],
        demand_max=jnp.max(demand).astype(jnp.int32)[None],
        demand_total=jnp.sum(demand).astype(jnp.int32)[None],
        sent_rows=sent_rows.astype(jnp.int32)[None],
        stage_drops=stage_drops.astype(jnp.int32)[None],
        recv_total=recv_total.astype(jnp.int32),
        recv_drops=recv_drops.astype(jnp.int32),
        wasted_wire_rows=(
            recv_drops if wasted_wire_rows is None else wasted_wire_rows
        ).astype(jnp.int32),
        retained_rows=z,
        age_max=z,
        credits_granted=(
            z if credits_granted is None else credits_granted.astype(jnp.int32)
        )[None],
        rows_held=(z if rows_held is None else rows_held.astype(jnp.int32))[None],
        emit_overflow=z,
    )


# ------------------------------------------------------------- ring buffer
def make_ring(tiers: int, *, window: int, buckets: int) -> StatsRing:
    """Empty ring — host- or trace-constructible (pure zeros)."""
    proto = make_stats(tiers, buckets)
    return StatsRing(
        stats=jax.tree.map(
            lambda a: jnp.zeros((window,) + a.shape, a.dtype), proto
        ),
        pos=jnp.zeros((), jnp.int32),
    )


def attach_emit_overflow(stats: RoundStats, n) -> RoundStats:
    """Stamp the round's local emission loss onto a snapshot — the drive
    owns this number (round_fn's enqueue overflow plus the retained-rows
    merge cut), so the exchange backends leave the field zero and the
    termination loop stamps it just before the ring push."""
    return dataclasses.replace(
        stats, emit_overflow=jnp.asarray(n).astype(jnp.int32)
    )


def ring_push(ring: StatsRing, stats: RoundStats) -> StatsRing:
    """Record one round (overwrites the oldest once the window is full)."""
    idx = ring.pos % ring.window
    return StatsRing(
        stats=jax.tree.map(lambda buf, s: buf.at[idx].set(s), ring.stats, stats),
        pos=ring.pos + 1,
    )


def ring_filled(ring: StatsRing) -> jax.Array:
    """Number of valid (written) slots."""
    return jnp.minimum(ring.pos, ring.window)


def stack_ring(ring):
    """Per-rank ring (or bare ``RoundStats``) → globally concatenable form:
    every leaf (incl. ``pos``) gains a leading rank dim of 1, so a
    ``shard_map`` out_spec over the context axis stacks the pytree as
    ``(R, …)`` for the host-side controller (``summarize`` accepts either
    the per-rank or the rank-stacked layout)."""
    return jax.tree.map(lambda a: a[None], ring)


# --------------------------------------------------------- config plumbing
def num_tiers(cfg: Any) -> int:
    """Recorded tiers of a ``ForwardConfig`` (duck-typed: no core import)."""
    if cfg.exchange == "hierarchical":
        return len(cfg.level_sizes)
    return 1


def tier_capacities(cfg: Any) -> Tuple[int, ...]:
    """The bucketing reference per recorded tier: the capacity knob whose
    demand each tier's histogram is measured against."""
    if cfg.exchange == "hierarchical":
        return tuple(int(c) for c in cfg.level_capacities)
    if cfg.exchange == "padded":
        return (int(cfg.peer_capacity),)
    # ragged / onehot: no per-peer slots — the receiver queue is the clamp
    return (int(cfg.capacity),)


# ---------------------------------------------------------- host-side view
def summarize(ring: StatsRing, *, tier_capacities: Tuple[int, ...]) -> Dict:
    """Aggregate a ring (per-rank, or rank-stacked via :func:`stack_ring` +
    shard_map) into the controller's host-side view.  Unwritten ring slots
    are all-zero and vacuously contribute nothing, so no masking is needed;
    quantiles are over the SEGMENT population (every segment of every
    recorded round on every rank), which is exactly the population the
    per-tier capacity clamp applies to."""
    hist = np.asarray(ring.stats.demand_hist)
    L, B = hist.shape[-2], hist.shape[-1]
    hist = hist.reshape(-1, L, B)
    demand_max = np.asarray(ring.stats.demand_max).reshape(-1, L).max(axis=0)
    stage_drops = np.asarray(ring.stats.stage_drops).reshape(-1, L).sum(axis=0)
    recv_drops = int(np.asarray(ring.stats.recv_drops).sum())
    return {
        "tier_capacities": tuple(int(c) for c in tier_capacities),
        "buckets": B,
        "rounds": int(np.asarray(ring.pos).max()),
        "window_filled": int(np.asarray(ring_filled(ring)).max()),
        "demand_hist": hist.sum(axis=0),
        "demand_max": demand_max,
        "demand_total": np.asarray(ring.stats.demand_total).reshape(-1, L).sum(axis=0),
        "sent_rows": np.asarray(ring.stats.sent_rows).reshape(-1, L).sum(axis=0),
        "stage_drops": stage_drops,
        "recv_total_max": int(np.asarray(ring.stats.recv_total).max()),
        "recv_drops": recv_drops,
        # the goodput ledger's waste term (rows shipped then discarded) —
        # first-class so `drops == emit_overflow + wasted_wire_rows` is
        # checkable from the recorder alone on open-flow overload runs
        "wasted_wire_rows": int(np.asarray(ring.stats.wasted_wire_rows).sum()),
        "drops": int(stage_drops.sum()) + recv_drops,
        # spill-and-retry pressure (zero under overflow="drop"): total
        # retained row-rounds in the window, and the oldest wait observed —
        # the controller treats retained != 0 like drops != 0 (not converged)
        "retained_rows": int(np.asarray(ring.stats.retained_rows).sum()),
        "age_max": int(np.asarray(ring.stats.age_max).max()),
        # backpressure-law observability (ISSUE 9): credit allowance granted
        # and rows held per tier; local emission clips; and goodput — the
        # fraction of rows put on the wire that the receivers admitted
        # (1.0 when nothing is clipped; flow="credit" must keep it at or
        # above the open-flow value on every overload scenario)
        "credits_granted": np.asarray(ring.stats.credits_granted)
        .reshape(-1, L).sum(axis=0),
        "rows_held": np.asarray(ring.stats.rows_held).reshape(-1, L).sum(axis=0),
        "emit_overflow": int(np.asarray(ring.stats.emit_overflow).sum()),
        "goodput": (
            1.0
            if int(np.asarray(ring.stats.recv_total).sum()) == 0
            else 1.0
            - recv_drops / int(np.asarray(ring.stats.recv_total).sum())
        ),
    }


def ring_trace(ring: StatsRing) -> Dict:
    """Chronological per-round trace of a ring's scalar counters (host-side
    numpy; accepts the per-rank or rank-stacked layout).

    Returns arrays of length ``window_filled`` — one entry per recorded
    forwarding round, oldest first, aggregated across ranks the way each
    counter composes: ``retained_rows`` / ``recv_total`` / ``recv_drops``
    summed, ``age_max`` maxed.  This is the trajectory view the chaos tests
    diff against the numpy twin's round-for-round ``retained_trace`` /
    ``age_trace`` — and what the recovery tests use to prove a
    preempt-resumed run replayed the SAME rounds, not merely reached the
    same totals."""
    pos_all = np.asarray(ring.pos).reshape(-1)
    if pos_all.size == 0 or not (pos_all == pos_all[0]).all():
        raise ValueError(
            f"ring positions diverge across ranks: {pos_all} — ranks push in "
            f"lockstep inside the drive loop, so this ring was not produced "
            f"by one drive"
        )
    pos = int(pos_all[0])

    def per_round(leaf, reduce):
        a = np.asarray(leaf)
        if a.ndim == 1:  # per-rank layout: (window,) → (1, window)
            a = a[None]
        W = a.shape[1]
        if pos > W:  # wrapped: oldest surviving push sits at slot pos % W
            idx = (np.arange(W) + pos % W) % W
        else:
            idx = np.arange(pos)
        return reduce(a[:, idx], axis=0)

    return {
        "retained_rows": per_round(ring.stats.retained_rows, np.sum),
        "age_max": per_round(ring.stats.age_max, np.max),
        "recv_total": per_round(ring.stats.recv_total, np.sum),
        "recv_drops": per_round(ring.stats.recv_drops, np.sum),
        "wasted_wire_rows": per_round(ring.stats.wasted_wire_rows, np.sum),
        "emit_overflow": per_round(ring.stats.emit_overflow, np.sum),
    }


def demand_quantile(summary: Dict, tier: int, q: float) -> int:
    """Conservative demand at quantile ``q`` of tier ``tier``'s recorded
    segment population: the smallest demand ``d`` such that at least a
    ``q``-fraction of segments demanded ``< d``, read off the histogram's
    exclusive bucket upper edges.  ``q >= 1`` (and any quantile landing in
    the overflow bucket) returns the EXACT recorded max, so a drop-free plan
    never depends on bucket resolution."""
    hist = np.asarray(summary["demand_hist"][tier], dtype=np.int64)
    dmax = int(summary["demand_max"][tier])
    total = int(hist.sum())
    if total == 0:
        return 0
    if q >= 1.0:
        return dmax
    edges = bucket_upper_edges(
        summary["tier_capacities"][tier], summary["buckets"]
    )
    cum = np.cumsum(hist)
    b = int(np.searchsorted(cum, q * total))
    if b >= len(hist) - 1:
        return dmax
    return int(min(edges[b], max(dmax, 1)))

"""repro.telemetry — the traffic flight recorder (ISSUE 5, measurement half).

``RoundStats`` snapshots, per forwarding round, the per-tier segment-demand
histograms, exact max demand, per-stage §3.3 clamp drops and shipped rows —
all from values the exchange's control plane already computes, with ZERO
additional collectives.  A ``StatsRing`` keeps the last ``window`` rounds on
device inside the ``run_until_done`` while-loop carry; the host summarizes a
ring between bursts and feeds ``repro.tune`` to re-plan capacities.

Enable with ``ForwardConfig(telemetry=True)`` (knobs: ``telemetry_window``,
``telemetry_buckets``); ``forward_work`` / ``run_until_done`` /
``RafiContext`` then return the stats / ring as an extra trailing output.
"""
from repro.telemetry.stats import (
    RoundStats,
    StatsRing,
    bucket_upper_edges,
    bucket_width,
    demand_quantile,
    make_ring,
    make_stats,
    num_tiers,
    occupancy_bucket,
    occupancy_histogram,
    ring_filled,
    ring_push,
    ring_trace,
    single_tier_stats,
    stack_ring,
    summarize,
    tier_capacities,
)

__all__ = [
    "RoundStats",
    "StatsRing",
    "bucket_upper_edges",
    "bucket_width",
    "demand_quantile",
    "make_ring",
    "make_stats",
    "num_tiers",
    "occupancy_bucket",
    "occupancy_histogram",
    "ring_filled",
    "ring_push",
    "ring_trace",
    "single_tier_stats",
    "stack_ring",
    "summarize",
    "tier_capacities",
]

"""Adaptive capacity controller — the planning half of ISSUE 5.

Every capacity knob in the forwarding stack (``peer_capacity``, the N-level
route's ``level_capacities``) is a burst-tolerance bet: too small and §3.3
clamps DROP work under a hot-spot, too large and every round pays the
padding on the wire.  The paper picks these by hand from a provable upper
bound (§6.3: "it was always possible to compute an upper bound ... so queues
could be sized accordingly") — which for a drifting workload means paying
worst-case padding on EVERY tier, EVERY round.

This module closes the loop instead, in the spirit of Lightning's measured
resource planning and Choi et al.'s traffic-adapted communication layer: the
``repro.telemetry`` flight recorder captures per-tier segment-demand
histograms for free (the count collectives already move the traffic matrix),
and between bursts the host solves, per tier,

    capacity = ceil(headroom · demand_quantile(q)),  rounded to granularity

— the smallest segment budget such that a ``q``-fraction of observed
segments fit, with ``headroom`` absorbing drift between bursts.  ``q = 1``
(the default) targets drop-free forwarding and uses the EXACT recorded max
(never bucket-resolution-limited); ``q < 1`` deliberately trades a drop tail
for less padding — the drop-probability/padding-waste dial.

``autotune_forward`` drives the loop: run a burst, summarize the rings,
re-plan, re-jit (a ``ForwardConfig`` is static, so a new config is a new
compiled program), repeat until the plan is stable and drop-free.  Multi-tier
routes genuinely need the iteration: tier ``l`` records demand POST-clamp of
the faster tiers, so opening a starved fast tier reveals new slow-tier
demand on the next burst — convergence takes a few bursts, not one (and is
regression-tested on a rotating hot-spot in ``tests/test_tune.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Tuple

from repro.core.forwarding import ForwardConfig
from repro.telemetry import stats as TS

__all__ = [
    "TunePolicy",
    "TuneStep",
    "TuneReport",
    "solve_capacities",
    "plan_capacities",
    "autotune_forward",
]


@dataclasses.dataclass(frozen=True)
class TunePolicy:
    """The drop-probability / padding-waste trade-off, as knobs.

    Attributes:
      quantile: fraction of observed segments that must fit the planned
        capacity.  ``1.0`` = drop-free (plans from the exact recorded max);
        lower values accept a drop tail to cut padding.
      headroom: multiplier on the quantile demand — absorbs drift between
        the measuring burst and the next one.
      granularity: capacities are rounded UP to a multiple of this (8 keeps
        segment rows tile-aligned for the Pallas marshal kernels).
      min_capacity: floor, so a silent tier can never plan a 0-row segment.
      allow_shrink: when False the plan only ever grows capacities —
        guarantees monotone convergence at the cost of keeping padding from
        a cold start's over-estimate.
    """

    quantile: float = 1.0
    headroom: float = 1.25
    granularity: int = 8
    min_capacity: int = 8
    allow_shrink: bool = True

    def __post_init__(self):
        if not 0.0 < self.quantile <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {self.quantile}")
        if self.headroom < 1.0:
            raise ValueError(f"headroom must be >= 1, got {self.headroom}")
        if self.granularity < 1 or self.min_capacity < 1:
            raise ValueError("granularity and min_capacity must be >= 1")


@dataclasses.dataclass
class TuneStep:
    """One burst of the autotune loop (history row of :class:`TuneReport`)."""

    burst: int
    capacities: Tuple[int, ...]   # what the burst ran with
    planned: Tuple[int, ...]      # what the summary asked for next
    drops: int                    # total clamp drops observed in the burst
    demand_max: Tuple[int, ...]   # exact per-tier max segment demand
    rounds: int                   # forwarding rounds the burst recorded
    retained: int = 0             # spill-and-retry row-rounds (overflow="retain")


@dataclasses.dataclass
class TuneReport:
    """The autotune trajectory: per-burst history + the convergence verdict."""

    steps: List[TuneStep]
    converged: bool

    @property
    def bursts(self) -> int:
        return len(self.steps)

    @property
    def final_drops(self) -> int:
        return self.steps[-1].drops if self.steps else 0


def _round_up(x: int, granularity: int) -> int:
    return -(-int(x) // granularity) * granularity


def solve_capacities(
    summary: Dict,
    current: Tuple[int, ...],
    policy: TunePolicy,
    *,
    bounds: Tuple[int, ...] = None,
) -> Tuple[int, ...]:
    """Per-tier capacity from one burst summary (see the module docstring's
    law).  Tiers with no recorded segments (extent-1 tiers skip their stage;
    a backend may be idle) keep their current capacity — no observation is
    not evidence of no demand.

    ``bounds`` is the optional per-tier PROVABLE worst-case segment demand
    (the paper's §6.3 upper bound, e.g. ``n_emit ×`` the source sub-segments
    feeding a slot): headroom never pushes a plan past what the workload
    could possibly present, so a tuned config is ≤ the static worst-case
    config tier by tier."""
    out = []
    for l, cap in enumerate(current):
        if int(summary["demand_hist"][l].sum()) == 0:
            out.append(int(cap))
            continue
        occ = TS.demand_quantile(summary, l, policy.quantile)
        new = _round_up(
            max(policy.min_capacity, math.ceil(occ * policy.headroom)),
            policy.granularity,
        )
        if not policy.allow_shrink:
            new = max(new, int(cap))
        if bounds is not None:
            new = min(new, int(bounds[l]))
        out.append(int(new))
    return tuple(out)


def plan_capacities(
    summary: Dict,
    cfg: ForwardConfig,
    *,
    policy: TunePolicy = TunePolicy(),
    bounds: Tuple[int, ...] = None,
) -> ForwardConfig:
    """Re-plan ``cfg``'s per-tier capacities from a burst summary.

    Returns a fresh ``ForwardConfig`` (same topology, marshal, telemetry
    knobs) with ``level_capacities`` (hierarchical) or ``peer_capacity``
    (flat padded) replaced by the solved sizes.  The receiver ``capacity``
    is deliberately NOT tuned — it is the application's queue shape (§3.2)
    and changing it re-shapes every kernel, not just the wire format.
    """
    if cfg.exchange not in ("padded", "hierarchical"):
        raise ValueError(
            f"exchange {cfg.exchange!r} has no per-peer segment capacities to "
            "tune (ragged segments are exact; onehot is the test oracle)"
        )
    current = TS.tier_capacities(cfg)
    solved = solve_capacities(summary, current, policy, bounds=bounds)
    kw = dict(
        axis_name=cfg.axis_name,
        num_ranks=cfg.num_ranks,
        capacity=cfg.capacity,
        exchange=cfg.exchange,
        marshal=cfg.marshal,
        sort_method=cfg.sort_method,
        use_pallas=cfg.use_pallas,
        telemetry=cfg.telemetry,
        telemetry_window=cfg.telemetry_window,
        telemetry_buckets=cfg.telemetry_buckets,
        overflow=cfg.overflow,
    )
    if cfg.exchange == "hierarchical":
        kw.update(level_sizes=cfg.level_sizes, level_capacities=solved)
    else:
        kw.update(peer_capacity=solved[0])
    return ForwardConfig(**kw)


def autotune_forward(
    run_burst: Callable[[ForwardConfig], Tuple[Any, TS.StatsRing]],
    cfg: ForwardConfig,
    *,
    policy: TunePolicy = TunePolicy(),
    bounds: Tuple[int, ...] = None,
    max_bursts: int = 8,
) -> Tuple[ForwardConfig, TuneReport]:
    """Converge the per-tier capacities over repeated bursts.

    ``run_burst(cfg) -> (drops, ring)`` runs one workload burst under the
    given (static → freshly jitted) config with telemetry on and returns the
    burst's CUMULATIVE §3.3 drop count (the queue's drop counter summed over
    ranks) plus the recorded ``StatsRing`` (per-rank or rank-stacked).  The
    drop count must come from the queue counter, not the ring: the ring only
    keeps the last ``telemetry_window`` rounds, so a burst longer than the
    window could clamp early, have the evidence overwritten, and read as
    drop-free from the summary alone.  Pass ``drops=None`` to explicitly
    accept the windowed ``summary["drops"]`` as the verdict (only sound when
    the window covers the whole burst).

    The loop re-plans after every burst and stops when the burst was
    drop-free AND the plan is a fixed point (re-planning from the new burst
    asks for the capacities it already ran with) — so the final config is
    *verified* drop-free on the measured workload, not just predicted.
    Under ``overflow="retain"`` clamped rows spill back into the queue
    instead of dropping, so a burst can be "drop-free" while still starved
    for capacity; the verdict therefore also requires the burst's summed
    ``retained_rows`` (spill pressure, recorded per round in telemetry) to
    be zero — retained demand keeps driving capacity growth exactly like
    drops do in drop mode.
    Returns ``(final_cfg, report)``; ``report.converged`` is False when
    ``max_bursts`` ran out first (e.g. a workload whose drift outruns the
    headroom).
    """
    if not cfg.telemetry:
        raise ValueError(
            "autotune needs ForwardConfig(telemetry=True) — the controller "
            "plans from the recorded StatsRing"
        )
    from repro.obs import trace as OT

    steps: List[TuneStep] = []
    converged = False
    with OT.span(
        "tune.autotune_forward", OT.CAT_TUNE,
        max_bursts=max_bursts, exchange=cfg.exchange,
    ) as sp:
        for burst in range(max_bursts):
            burst_drops, ring = run_burst(cfg)
            summary = TS.summarize(ring, tier_capacities=TS.tier_capacities(cfg))
            drops = int(summary["drops"] if burst_drops is None else burst_drops)
            retained = int(summary.get("retained_rows", 0))
            planned = plan_capacities(summary, cfg, policy=policy, bounds=bounds)
            cur_caps = TS.tier_capacities(cfg)
            new_caps = TS.tier_capacities(planned)
            if new_caps != cur_caps:
                # the observation law's re-plan record: old → new capacities
                OT.event(
                    "tune.replan", OT.CAT_TUNE, burst=burst,
                    old=list(cur_caps), new=list(new_caps),
                    drops=drops, retained=retained,
                )
            steps.append(
                TuneStep(
                    burst=burst,
                    capacities=cur_caps,
                    planned=new_caps,
                    drops=drops,
                    demand_max=tuple(int(d) for d in summary["demand_max"]),
                    rounds=int(summary["rounds"]),
                    retained=retained,
                )
            )
            if drops == 0 and retained == 0 and new_caps == cur_caps:
                converged = True
                break
            cfg = planned
        sp.set(bursts=len(steps), converged=converged)
    return cfg, TuneReport(steps=steps, converged=converged)

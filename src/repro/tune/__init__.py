"""repro.tune — the adaptive capacity controller (ISSUE 5, planning half).

Reads ``repro.telemetry`` ring summaries between bursts and solves the
per-tier segment capacities (``peer_capacity`` / ``level_capacities``) to a
target drop-probability / padding-waste trade-off; ``autotune_forward``
drives the re-plan → re-jit → re-measure loop to a verified drop-free fixed
point.  See ``tune.controller`` for the law.
"""
from repro.tune.controller import (
    TunePolicy,
    TuneReport,
    TuneStep,
    autotune_forward,
    plan_capacities,
    solve_capacities,
)

__all__ = [
    "TunePolicy",
    "TuneReport",
    "TuneStep",
    "autotune_forward",
    "plan_capacities",
    "solve_capacities",
]

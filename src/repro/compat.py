"""Version-gated JAX imports, centralized (one shim, no scattered try/excepts).

The repo targets the newest JAX surface (``jax.shard_map``, explicit
``AxisType`` meshes, ``jax.typeof(...).vma`` + ``pcast`` for manual-axes
typing, ``lax.ragged_all_to_all``), but must also run on older releases
(0.4.x) where none of those exist.  Every feature-probe lives here; the rest
of the codebase imports *this* module and never touches ``jax.__version__``.

Exported surface:

  AxisType, HAS_AXIS_TYPE     sharding axis types (None / False when absent)
  make_mesh(...)              ``jax.make_mesh`` that drops ``axis_types`` when
                              the installed JAX does not accept it
  abstract_mesh(...)          device-free mesh for lowering-only benchmarks,
                              papering over the AbstractMesh signature change
  shard_map(...)              ``jax.shard_map`` when present, else the
                              ``jax.experimental.shard_map`` fallback; the
                              ``check_vma`` kwarg maps onto old ``check_rep``
  HAS_RAGGED_ALL_TO_ALL       feature flag for ``lax.ragged_all_to_all``
  ragged_all_to_all(...)      the op, or a loud NotImplementedError stub
  vma_of(x)                   ``jax.typeof(x).vma`` or ``frozenset()``
  pcast_varying(x, axes)      ``lax.pcast(..., to="varying")`` or identity
  sds(shape, dtype, *like)    ShapeDtypeStruct carrying the union of the
                              inputs' varying-manual-axes when supported

Tests that *require* a missing feature should gate on the ``HAS_*`` flags
with ``pytest.skip`` rather than erroring at import time.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax

__all__ = [
    "AxisType",
    "HAS_AXIS_TYPE",
    "HAS_RAGGED_ALL_TO_ALL",
    "HAS_SHARD_MAP_VMA",
    "abstract_mesh",
    "make_mesh",
    "pcast_varying",
    "ragged_all_to_all",
    "sds",
    "shard_map",
    "vma_of",
]

# ---------------------------------------------------------------- AxisType
try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPE = True
except ImportError:
    AxisType = None
    HAS_AXIS_TYPE = False


# ---------------------------------------------------------------- make_mesh
@functools.lru_cache(maxsize=1)
def _make_mesh_takes_axis_types() -> bool:
    import inspect

    return "axis_types" in inspect.signature(jax.make_mesh).parameters


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *, axis_types=None):
    """``jax.make_mesh`` with ``axis_types`` applied only where supported.

    ``axis_types=None`` (the default) means implicit Auto axes — on older JAX
    that is exactly what dropping the argument gives, so the fallback is
    silent.  EXPLICITLY requested axis_types on a JAX that cannot honor them
    raise rather than silently changing sharding semantics.
    """
    if HAS_AXIS_TYPE and _make_mesh_takes_axis_types():
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(tuple(axis_names))
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    if axis_types is not None:
        raise TypeError(
            f"this JAX ({jax.__version__}) cannot honor axis_types={axis_types!r}; "
            "omit the argument for implicit Auto axes"
        )
    return jax.make_mesh(axis_shapes, axis_names)


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """A device-free mesh usable for ``.lower()`` (no execution).

    Handles both AbstractMesh signatures: new ``(shapes, names, axis_types=)``
    and old ``(shape_tuple,)``.  Returns None when AbstractMesh is absent.
    """
    try:
        from jax.sharding import AbstractMesh
    except ImportError:
        return None
    if HAS_AXIS_TYPE:
        try:
            return AbstractMesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(AxisType.Auto,) * len(tuple(axis_names)),
            )
        except TypeError:
            pass
    try:
        return AbstractMesh(tuple(zip(tuple(axis_names), tuple(axis_shapes))))
    except TypeError:
        return None


# ---------------------------------------------------------------- shard_map
HAS_SHARD_MAP_VMA = hasattr(jax, "shard_map")

if HAS_SHARD_MAP_VMA:
    _shard_map_impl = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    _shard_map_impl = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Uniform shard_map entry point.

    ``check_vma`` maps to the new-style varying-manual-axes check; on legacy
    JAX the analogous ``check_rep`` is force-disabled — the legacy checker
    predates several collectives used here (sort, ragged exchange) and
    rejects valid programs.
    """
    if _shard_map_impl is not None:
        return _shard_map_impl(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    return _legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


# ------------------------------------------------------- ragged_all_to_all
HAS_RAGGED_ALL_TO_ALL = hasattr(jax.lax, "ragged_all_to_all")

if HAS_RAGGED_ALL_TO_ALL:
    ragged_all_to_all = jax.lax.ragged_all_to_all
else:

    def ragged_all_to_all(*args, **kwargs):
        raise NotImplementedError(
            "jax.lax.ragged_all_to_all is not available in this JAX "
            f"({jax.__version__}); use the 'padded' exchange backend or "
            "upgrade JAX"
        )


# --------------------------------------------------- manual-axes vma typing
_HAS_TYPEOF = hasattr(jax, "typeof")
_HAS_PCAST = hasattr(jax.lax, "pcast")


def vma_of(x) -> frozenset:
    """The varying-manual-axes set of ``x`` (empty set when untyped JAX)."""
    if _HAS_TYPEOF:
        try:
            return frozenset(jax.typeof(x).vma)
        except (AttributeError, TypeError):
            pass
    return frozenset()


def pcast_varying(x, axes):
    """Cast ``x`` to device-varying over ``axes`` where the type system
    exists; identity elsewhere (legacy shard_map carries no vma types)."""
    if not (_HAS_TYPEOF and _HAS_PCAST):
        return x
    missing = tuple(a for a in axes if a not in vma_of(x))
    return jax.lax.pcast(x, missing, to="varying") if missing else x


@functools.lru_cache(maxsize=1)
def _sds_accepts_vma() -> bool:
    try:
        jax.ShapeDtypeStruct((1,), "int32", vma=frozenset())
        return True
    except TypeError:
        return False


def sds(shape, dtype, *like: Any) -> jax.ShapeDtypeStruct:
    """ShapeDtypeStruct whose vma is the union of the inputs' — required so
    pallas_call composes with shard_map(check_vma=True).  Plain struct on
    JAX versions without vma typing."""
    if _sds_accepts_vma():
        vma = frozenset()
        for x in like:
            vma = vma | vma_of(x)
        return jax.ShapeDtypeStruct(shape, dtype, vma=vma)
    return jax.ShapeDtypeStruct(shape, dtype)

"""End-to-end LM training driver (deliverable b): ~100M-parameter model,
a few hundred steps on a (2, 4) data×model mesh, with periodic atomic
checkpoints and auto-resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(CPU-bound: ~100M params × seq 256 runs at a few steps/sec.)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses

from repro.launch.mesh import make_test_mesh
from repro.launch.train import train
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig

# ~100M params: 12L × d=640 × ff=2560, 32k vocab (≈ 63M body + 41M embeddings)
CONFIG_100M = ModelConfig(
    name="repro-100m", kind="dense",
    num_layers=12, d_model=640, num_heads=10, num_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=32000, rope_theta=1e4,
    pattern=("global",), dtype="float32", remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # monkey-patch the registry-driven train() with an explicit config
    import repro.launch.train as T

    orig_smoke = T.get_smoke_config
    T.get_smoke_config = lambda arch: CONFIG_100M
    try:
        from repro.models.api import build_model

        n = build_model(CONFIG_100M).param_count()
        print(f"training {CONFIG_100M.name}: {n/1e6:.1f}M params")
        train(
            arch="repro-100m", smoke=True,
            steps=args.steps, batch=args.batch, seq=args.seq,
            ckpt_dir=args.ckpt_dir, ckpt_every=50,
            mesh=make_test_mesh(),
            opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=30),
        )
    finally:
        T.get_smoke_config = orig_smoke


if __name__ == "__main__":
    main()

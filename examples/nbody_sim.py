"""N-body example (§5.5): three simultaneous forwarding contexts.

Runs the distributed Barnes-Hut-style simulation on 8 ranks (2×2×2 grid
decomposition) and reports conservation + accuracy against direct sum —
the Fig. 7 analogue.

Run:  PYTHONPATH=src python examples/nbody_sim.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
from repro import compat

from repro.apps import nbody

mesh = compat.make_mesh((8,), ("data",))
cfg = nbody.NBodyConfig(num_particles=256, steps=8, dt=5e-4, theta=0.3)

pos, vel, stats = nbody.run(mesh, cfg)
po, vo = nbody.oracle(cfg)

print(f"rank grid: {stats['dims']}, particles per step: {stats['totals']}")
print(f"queue drops: {stats['drops']}")
print(f"max position error vs direct sum: {np.abs(pos-po).max():.2e}")
print(f"rms velocity error vs direct sum: {np.sqrt(((vel-vo)**2).mean()):.2e}")
assert stats["totals"][-1] == cfg.num_particles, "particles lost!"
print("OK — particles conserved through migration, three contexts coexisting")

"""Quickstart: the RaFI-JAX work-forwarding core in ~60 lines.

Mirrors the paper's introductory usage: define a work-item type, emit items
to destination ranks from per-rank kernels, call the forwarding collective,
and drive a multi-round computation to distributed termination — here with
the sort-free ``marshal="scatter"`` hot path and the traffic flight recorder
(``telemetry=True``) on, printing the burst's traffic summary at the end,
then closing with the observation law: capture a burst, export the Perfetto
timeline, and run the flight-data analyzer over it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import telemetry as TM

from repro.core import (
    DISCARD, ForwardConfig, enqueue, forward_work, make_queue,
    run_until_done, work_item,
)


def section(n, title):
    print(f"== {n}. {title}")


# 1. A work item is any dataclass of arrays — RaFI never looks inside (§3.1).
section(1, "work-item type")
@work_item
@dataclasses.dataclass
class Ray:
    value: jax.Array
    hops: jax.Array


PROTO = Ray(value=jnp.zeros(()), hops=jnp.zeros((), jnp.int32))
R, CAP = 8, 128
mesh = compat.make_mesh((R,), ("data",))
# scatter marshal = the sort-free single-pass hot path (PR 4); telemetry =
# the per-round traffic flight recorder (PR 5) riding the while-loop carry
cfg = ForwardConfig(
    axis_name="data", num_ranks=R, capacity=CAP, exchange="padded",
    marshal="scatter", telemetry=True, telemetry_window=8,
)


# 2. A per-rank "kernel": read incoming work, emit outgoing work (§3.3).
section(2, "per-rank round kernel")


def round_fn(q_in, acc, rnd):
    me = jax.lax.axis_index("data")
    lane = jnp.arange(CAP)
    valid = lane < q_in.count
    items = q_in.items
    moved = Ray(value=items.value * 0.5, hops=items.hops + 1)
    keep = valid & (moved.hops < 4)                      # retire after 4 hops
    dest = jnp.where(keep, (me + 1) % R, DISCARD)        # ring forwarding
    out = make_queue(PROTO, CAP)
    out = enqueue(out, moved, dest.astype(jnp.int32), valid)
    acc = acc + jnp.sum(jnp.where(valid & ~keep, moved.value, 0.0))
    return out, acc


# 3. Drive to distributed termination (§4.2.3) — all on device.  With
#    telemetry on, the StatsRing of the last W rounds rides the loop carry.
section(3, "drive to distributed termination")


def drive(_):
    me = jax.lax.axis_index("data")
    q0 = make_queue(PROTO, CAP)
    q0 = enqueue(
        q0,
        Ray(value=jnp.ones(4) * (me + 1), hops=jnp.zeros(4, jnp.int32)),
        me * jnp.ones(4, jnp.int32),
        jnp.ones(4, bool),
    )
    q, acc, rounds, _done, ring = run_until_done(round_fn, q0, jnp.zeros(()), cfg, max_rounds=16)
    return acc[None], rounds[None], TM.stack_ring(ring)


ring_specs = jax.tree.map(
    lambda _: P("data"),
    TM.make_ring(TM.num_tiers(cfg), window=cfg.telemetry_window,
                 buckets=cfg.telemetry_buckets),
)
f = jax.jit(compat.shard_map(
    drive, mesh=mesh, in_specs=P("data"),
    out_specs=(P("data"), P("data"), ring_specs),
))
acc, rounds, ring = f(jnp.arange(float(R)))
print(f"deposited per rank: {acc}")
print(f"rounds to distributed termination: {int(rounds[0])}")
expected = sum((r + 1) * 4 for r in range(R)) * 0.5**4
print(f"total deposited: {float(acc.sum()):.3f}  (expected {expected:.3f})")
assert abs(float(acc.sum()) - expected) < 1e-3

# 4. Read the flight recorder back on the host — what the burst's traffic
#    looked like, and what repro.tune would size the send slots to.
section(4, "telemetry summary")
summary = TM.summarize(ring, tier_capacities=TM.tier_capacities(cfg))
print(
    f"telemetry: {summary['rounds']} rounds recorded, "
    f"max segment demand {summary['demand_max'][0]} "
    f"(peer slots sized {summary['tier_capacities'][0]}), "
    f"clamp drops {summary['drops']}"
)
assert summary["drops"] == 0

# 5. The overlap law (PR 8): ``pipeline_shards=S`` splits every peer segment
#    into S micro-shards, each on its own payload+count collective pair, so
#    marshal of shard k+1 can overlap the wire time of shard k on an async
#    fabric.  Pipelining changes the SCHEDULE, never the ANSWER — the same
#    drive is bit-exact with the bulk round.
section(5, "pipelined overlap, bit-exact")
cfg = dataclasses.replace(cfg, pipeline_shards=2)
f2 = jax.jit(compat.shard_map(
    drive, mesh=mesh, in_specs=P("data"),
    out_specs=(P("data"), P("data"), ring_specs),
))
acc2, rounds2, _ = f2(jnp.arange(float(R)))
assert (acc2 == acc).all() and int(rounds2[0]) == int(rounds[0])
print(f"pipelined (S=2) drive bit-exact with bulk: {float(acc2.sum()):.3f}")

# 6. The backpressure law (PR 9): under sustained overload, open flow ships
#    rows its receivers must clamp — wire bytes spent on work that is thrown
#    away.  ``flow="credit"`` piggybacks each receiver's free space on the
#    count collective and gates senders on it, so every shipped row lands:
#    slower to drain (credits are one round stale), but goodput 1.0 and zero
#    loss where open flow drops almost half the traffic.
from repro.chaos import run_scenario, sustained_overload
from repro.obs import report as OR
from repro.obs import trace as OT

section(6, "backpressure under sustained overload")
sc = sustained_overload()  # 2 of 8 ranks hot: concentration that persists
results = {}
# ...captured under the ambient span tracer (PR 10): tracing rides the HOST
# side only, so the device program — and every number below — is unchanged.
with OT.capture() as tracer:
    for flow in ("open", "credit"):
        r = results[flow] = run_scenario(
            mesh, sc, capacity=16, max_rounds=256, flow=flow,
            overflow="retain", pipeline_shards=4,
        )
        print(
            f"overload [{flow:6s}]: delivered {r['delivered_total']}/{r['emitted']}"
            f" in {r['rounds']} rounds, goodput {r['goodput']:.3f},"
            f" drops {r['drops']}"
        )
        if flow == "open":
            assert r["goodput"] < 0.9  # wire wasted on clamped rows
        else:
            assert r["goodput"] == 1.0 and r["drops"] == 0 and r["done"]

# 7. The observation law (PR 10): the burst above became flight data.  Export
#    the host span timeline as Perfetto JSON (load it at ui.perfetto.dev),
#    write the chaos runs into a capture file, and let the analyzer re-derive
#    the ledger and flag the degraded run — open flow, and only open flow.
section(7, "observation law: trace export + flight-data report")
import tempfile

outdir = tempfile.mkdtemp(prefix="rafi_quickstart_")
trace_path = os.path.join(outdir, "trace.perfetto.json")
tracer.save(trace_path)
print(f"perfetto timeline: {trace_path} ({len(tracer.events)} events)")

capture_path = os.path.join(outdir, "capture.json")
OR.save_capture(
    capture_path,
    [
        OR.chaos_capture(
            f"{sc.name}_{flow}", results[flow], flow=flow,
            tier_capacities=(4,), capacity=16,
        )
        for flow in ("open", "credit")
    ],
    meta={"source": "quickstart"},
)
report = OR.analyze(OR.load_capture(capture_path))
print(OR.render(report))
assert report["degraded_runs"] == [f"{sc.name}_open"]
print("OK")

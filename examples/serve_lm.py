"""Batched serving example: heterogeneous requests through the slot engine.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import BatchedEngine, Request
from repro.models.api import build_model

cfg = get_smoke_config("qwen2-7b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
requests = [
    Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, rng.integers(2, 12)),
            max_new_tokens=int(rng.integers(4, 12)))
    for i in range(10)
]
engine = BatchedEngine(model, params, slots=4, max_len=64)
out = engine.run(requests)
for rid in sorted(out):
    print(f"request {rid}: prompt_len={len(requests[rid].prompt):2d} -> {out[rid]}")
print(f"served {len(out)} requests through 4 slots")

"""Streamlines example (§5.4): RK4 particle advection with forwarding.

Advects particle sets through three analytic vector fields (ABC flow,
tornado, Taylor-Green) on an 8-rank slab partition — the Fig. 6 analogue —
and verifies against the single-device oracle.

Run:  PYTHONPATH=src python examples/streamlines_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
from repro import compat

from repro.apps import streamlines as sl
from repro.kernels.rk4_advect import ops as rk4

mesh = compat.make_mesh((8,), ("data",))

for name, fid in [("ABC", rk4.ABC), ("tornado", rk4.TORNADO), ("taylor-green", rk4.TAYLOR_GREEN)]:
    cfg = sl.StreamlineConfig(num_particles=48, max_steps=60, dt=0.12, field_id=fid)
    traces, lengths, stats = sl.run(mesh, cfg)
    orc = sl.oracle(cfg)
    m = np.isfinite(traces) & np.isfinite(orc)
    err = np.abs(traces[m] - orc[m]).max() if m.any() else 0.0
    ok = np.array_equal(np.isfinite(traces), np.isfinite(orc)) and err < 5e-4
    print(
        f"{name:>13}: mean streamline length {lengths.mean():6.1f} steps, "
        f"rounds {stats['rounds']:3d}, oracle max err {err:.1e} -> {'OK' if ok else 'FAIL'}"
    )

"""VoPaT example: distributed volume path tracing with ray forwarding (§5.1).

Renders the blob scene on 1 rank and on 8 ranks, checks the images are
bitwise identical (the paper's "images will not differ in any way"), and
writes PPMs — the Fig. 2 analogue.

The 8-rank render runs the sort-free ``marshal="scatter"`` hot path with the
traffic flight recorder on (``telemetry=True``) and prints the burst's
traffic summary — demand vs the worst-case §6.3 queue sizing this example
uses, i.e. exactly the padding ``repro.tune`` would reclaim.  The marshal
law keeps scatter bit-exact with the sort path, so the cross-rank-count
bitwise check also pins scatter placement against the 1-rank sort render.

Run:  PYTHONPATH=src python examples/vopat_render.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np
from repro import compat

from repro.apps import vopat
from repro.apps.fields import write_ppm

scene = vopat.VopatScene(width=96, height=96, spp=1, max_bounces=4, albedo=0.85)
m1 = compat.make_mesh((1,), ("data",))
m8 = compat.make_mesh((8,), ("data",))

t0 = time.time()
img8, s8 = vopat.render(m8, scene, marshal="scatter", telemetry=True)
print(f"8-rank render: {time.time()-t0:.1f}s  rounds={s8['rounds']} drops={s8['drops']}")
tm = s8["telemetry"]
print(
    f"telemetry: {tm['rounds']} rounds recorded (window {tm['window_filled']}), "
    f"max segment demand {tm['demand_max'][0]} of {tm['tier_capacities'][0]} "
    f"worst-case slot rows, clamp drops {tm['drops']}"
)
t0 = time.time()
img1, s1 = vopat.render(m1, scene)
print(f"1-rank render: {time.time()-t0:.1f}s  rounds={s1['rounds']}")
print("bitwise identical across rank counts:", np.array_equal(img1, img8))

out = os.path.join(os.path.dirname(__file__), "vopat_8rank.ppm")
write_ppm(out, img8)
print("wrote", out)

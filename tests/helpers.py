"""Shared work-item types and utilities for the test suite."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import work_item


@work_item
@dataclasses.dataclass
class Ray:
    """A paper-style forwardable ray (cf. Listing 1: SchlieRaFI's FWDRay)."""

    origin: jax.Array      # (3,) f32
    direction: jax.Array   # (3,) f32
    tmin: jax.Array        # () f32
    pixel: jax.Array       # () i32
    integral: jax.Array    # () f32


@work_item
@dataclasses.dataclass
class Particle:
    """Paper §5.4's particle: unique ID + position."""

    uid: jax.Array  # () i32
    pos: jax.Array  # (3,) f32


def ray_proto():
    return Ray(
        origin=jnp.zeros(3),
        direction=jnp.zeros(3),
        tmin=jnp.zeros(()),
        pixel=jnp.zeros((), jnp.int32),
        integral=jnp.zeros(()),
    )


def particle_proto():
    return Particle(uid=jnp.zeros((), jnp.int32), pos=jnp.zeros(3))


def make_rays(n, seed=0, pixel_base=0):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    return Ray(
        origin=jax.random.normal(k1, (n, 3)),
        direction=jax.random.normal(k2, (n, 3)),
        tmin=jax.random.uniform(k3, (n,)),
        pixel=jnp.arange(n, dtype=jnp.int32) + pixel_base,
        integral=jnp.zeros((n,)),
    )

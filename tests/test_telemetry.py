"""Traffic-telemetry flight recorder (ISSUE 5): oracle consistency + ring.

The acceptance properties:

* recorded ``RoundStats`` agree with an ONEHOT-DERIVED oracle — per-segment
  demands recomputed in numpy from the global (source, dest) picture, using
  the routing invariant (before stage ``l`` an item sits on the rank whose
  faster digits match its destination and slower digits match its source),
  bucketed with the ONE shared bucketing law (``telemetry.bucket_width``);
* per-stage recorded drops reproduce the PR-4 count-each-drop-exactly-once
  numbers (one segment clamped at every tier of a (2, 2, 2) route: 48 at the
  device stage, 16 at the node stage, 8 at the pod stage), per rank;
* ``stage_drops + recv_drops`` always equals the queue's drop counter (the
  stats and the §3.3 accounting are the same numbers, never a second count);
* the ``StatsRing`` in the ``run_until_done`` while-loop carry records every
  round (initial routing round included) and overwrites beyond the window.

Everything here runs with both marshal modes where it matters — the stats
are derived from the control plane, which the marshal law keeps identical.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import telemetry as TM
from repro.core import (
    DISCARD,
    ForwardConfig,
    WorkQueue,
    enqueue,
    forward_work,
    make_queue,
    run_until_done,
)

from helpers import make_rays, ray_proto

pytestmark = pytest.mark.telemetry

R, CAP = 8, 64
AXES3 = ("pod", "node", "device")
BUCKETS = 8


# ----------------------------------------------------------------- plumbing
def _stats_specs(cfg, axes):
    proto = TM.make_stats(TM.num_tiers(cfg), cfg.telemetry_buckets)
    return jax.tree.map(lambda _: P(axes), proto)


def _forward_fn(mesh, cfg, axes="data"):
    """Jitted: (dest (R*CAP,), counts (R,)) -> (counts, drops, stacked stats)."""

    def fwd(dest, counts):
        me = jax.lax.axis_index(axes)
        q = WorkQueue(
            items=make_rays(CAP),
            dest=dest,
            count=counts[0],
            drops=jnp.zeros((), jnp.int32),
        )
        nq, _total, stats = forward_work(q, cfg)
        return nq.count[None], nq.drops[None], TM.stack_ring(stats)

    return jax.jit(
        compat.shard_map(
            fwd, mesh=mesh,
            in_specs=(P(axes), P(axes)),
            out_specs=(P(axes), P(axes), _stats_specs(cfg, axes)),
        )
    )


# ------------------------------------------------------------------ oracles
def _digits(rank, level_sizes):
    ds = []
    for a in reversed(level_sizes[1:]):
        ds.append(rank % a)
        rank //= a
    ds.append(rank)
    return tuple(reversed(ds))


def _hier_demand_oracle(dest, counts, level_sizes):
    """No-clamp per-rank, per-tier, per-slot-column demand from the global
    (source, dest) picture.  Routing invariant: before stage ``l`` (stages
    run fastest first) an item (s, d) sits on the rank with digits
    ``(s_0, …, s_l, d_{l+1}, …, d_{L-1})``; stage ``l``'s slot column ``j``
    collects the ones with ``d_l == j``."""
    L = len(level_sizes)
    items = [
        (s, int(d))
        for s in range(R)
        for lane, d in enumerate(dest[s])
        if lane < counts[s] and 0 <= d < R
    ]
    digits = {r: _digits(r, level_sizes) for r in range(R)}
    demand = {}
    for l in range(L):
        if level_sizes[l] <= 1:
            continue
        for r in range(R):
            rd = digits[r]
            col = np.zeros(level_sizes[l], np.int64)
            for s, d in items:
                sd, dd = digits[s], digits[d]
                if all(sd[m] == rd[m] for m in range(l + 1)) and all(
                    dd[m] == rd[m] for m in range(l + 1, L)
                ):
                    col[dd[l]] += 1
            demand[(r, l)] = col
    return demand


def _oracle_hist(demands, cap, buckets):
    w = TM.bucket_width(cap, buckets)
    hist = np.zeros(buckets, np.int64)
    for d in demands:
        # the shared bucketing law: bucket B-1 is exactly the at-or-above-
        # capacity (clamping) segments, interior buckets tile [0, capacity)
        b = buckets - 1 if d >= cap else min(int(d) // w, buckets - 2)
        hist[b] += 1
    return hist


def test_overflow_bucket_collects_exactly_at_capacity_demand():
    """demand_hist[:, -1] is read as 'segments that hit the §3.3 clamp' —
    an exactly-at-capacity demand must land there even when capacity is not
    divisible by buckets-1 (e.g. cap 8, 8 buckets, width ceil(8/7) = 2)."""
    hist = np.asarray(TM.occupancy_histogram(jnp.array([7, 8, 9]), 8, 8))
    assert hist[-1] == 2, hist        # 8 and 9 clamp; 7 does not
    assert hist.sum() == 3
    assert int(TM.occupancy_bucket(jnp.array([8]), 8, 8)[0]) == 7


def _spread_dest(seed, hot=None, hot_frac=0.0):
    """(R, CAP) destinations + per-rank counts; optionally a hot-spot."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(4, 13, R).astype(np.int32)
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    if hot is not None:
        mask = rng.random((R, CAP)) < hot_frac
        dest = np.where(mask, hot, dest).astype(np.int32)
    return dest, counts


# ------------------------------------------------- flat-backend consistency
@pytest.mark.parametrize("marshal", ["sort", "scatter"])
def test_padded_stats_match_destination_oracle(mesh8, marshal):
    """Flat tier demand == my per-destination send counts, oracle-derived
    from the raw dest vector; hist/max/total all agree; drops conserve."""
    cfg = ForwardConfig(
        "data", R, CAP, exchange="padded", marshal=marshal,
        telemetry=True, telemetry_buckets=BUCKETS,
    )
    fn = _forward_fn(mesh8, cfg)
    dest, counts = _spread_dest(seed=1, hot=3, hot_frac=0.4)
    _cnt, drops, st = fn(jnp.asarray(dest).reshape(-1), jnp.asarray(counts))
    hist = np.asarray(st.demand_hist)      # (R, 1, B)
    dmax = np.asarray(st.demand_max)       # (R, 1)
    dtot = np.asarray(st.demand_total)
    sdrop = np.asarray(st.stage_drops)
    rdrop = np.asarray(st.recv_drops)
    for r in range(R):
        valid = dest[r][: counts[r]]
        valid = valid[(valid >= 0) & (valid < R)]
        per_dest = np.bincount(valid, minlength=R)
        np.testing.assert_array_equal(
            hist[r, 0], _oracle_hist(per_dest, cfg.peer_capacity, BUCKETS)
        )
        assert dmax[r, 0] == per_dest.max()
        assert dtot[r, 0] == per_dest.sum()
    # stats drops ARE the queue drops — same numbers, counted once
    assert int(sdrop.sum() + rdrop.sum()) == int(np.asarray(drops).sum())


def test_padded_stats_identical_across_marshal_modes(mesh8):
    """The stats come from the control plane, which the marshal law keeps
    identical — sort and scatter must record the same RoundStats."""
    dest, counts = _spread_dest(seed=2, hot=0, hot_frac=0.5)
    got = {}
    for marshal in ("sort", "scatter"):
        cfg = ForwardConfig(
            "data", R, CAP, exchange="padded", marshal=marshal,
            telemetry=True, telemetry_buckets=BUCKETS,
        )
        fn = _forward_fn(mesh8, cfg)
        *_rest, st = fn(jnp.asarray(dest).reshape(-1), jnp.asarray(counts))
        got[marshal] = st
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        got["sort"],
        got["scatter"],
    )


# ----------------------------------------------- hierarchical consistency
@pytest.mark.parametrize(
    "mesh_fixture,axes,sizes",
    [
        ("mesh_pods222", AXES3, (2, 2, 2)),
        ("mesh_nodes24", ("node", "device"), (2, 4)),
        ("mesh_nodes42", ("node", "device"), (4, 2)),
    ],
)
@pytest.mark.parametrize("marshal", ["sort", "scatter"])
def test_hierarchical_stats_match_routing_oracle(
    request, mesh_fixture, axes, sizes, marshal
):
    """Per-tier recorded demand (ample capacities, so no clamp distorts any
    stage) equals the numpy routing oracle at EVERY tier, histogram included
    — the 'onehot-derived per-segment counts' acceptance property."""
    mesh = request.getfixturevalue(mesh_fixture)
    cfg = ForwardConfig(
        axes, R, CAP, exchange="hierarchical", level_sizes=sizes,
        marshal=marshal, telemetry=True, telemetry_buckets=BUCKETS,
    )
    fn = _forward_fn(mesh, cfg, axes)
    dest, counts = _spread_dest(seed=3, hot=5, hot_frac=0.3)
    _cnt, drops, st = fn(jnp.asarray(dest).reshape(-1), jnp.asarray(counts))
    oracle = _hier_demand_oracle(dest, counts, sizes)
    hist = np.asarray(st.demand_hist)   # (R, L, B)
    dmax = np.asarray(st.demand_max)
    dtot = np.asarray(st.demand_total)
    for (r, l), col in oracle.items():
        np.testing.assert_array_equal(
            hist[r, l],
            _oracle_hist(col, cfg.level_capacities[l], BUCKETS),
            err_msg=f"rank {r} tier {l}",
        )
        assert dmax[r, l] == col.max(), (r, l, col)
        assert dtot[r, l] == col.sum(), (r, l, col)
    assert int(
        np.asarray(st.stage_drops).sum() + np.asarray(st.recv_drops).sum()
    ) == int(np.asarray(drops).sum())


def test_extent1_tier_records_nothing(mesh_pods222):
    """A skipped (extent-1) stage must leave its tier row all-zero — the
    controller reads 'no observation', never 'zero demand'."""
    from repro.launch.mesh import make_pod_mesh

    sizes = (2, 1, 4)
    mesh = make_pod_mesh(*sizes)
    cfg = ForwardConfig(
        AXES3, R, CAP, exchange="hierarchical", level_sizes=sizes,
        telemetry=True, telemetry_buckets=BUCKETS,
    )
    fn = _forward_fn(mesh, cfg, AXES3)
    dest, counts = _spread_dest(seed=4)
    *_rest, st = fn(jnp.asarray(dest).reshape(-1), jnp.asarray(counts))
    assert np.asarray(st.demand_hist)[:, 1].sum() == 0
    assert np.asarray(st.demand_max)[:, 1].max() == 0
    assert np.asarray(st.demand_hist)[:, 0].sum() > 0
    assert np.asarray(st.demand_hist)[:, 2].sum() > 0


# --------------------------------------------- per-stage drop attribution
@pytest.mark.parametrize("marshal", ["sort", "scatter"])
def test_stage_drops_reproduce_multi_tier_clamp_numbers(mesh_pods222, marshal):
    """The PR-4 drop-accounting scenario, now attributed per stage by the
    recorder: everyone sends 10 rows to rank 0 through a (2, 2, 2) route with
    level_capacities=(4, 4, 4).  Device stage drops 6 on every rank (48),
    node stage 4 on each device-digit-0 rank (16), pod stage 4 on ranks 0
    and 4 (8) — and the recorded post-clamp demands at the later stages see
    exactly the survivors (8 rows), never the clamped originals."""
    cfg = ForwardConfig(
        AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 2, 2),
        level_capacities=(4, 4, 4), marshal=marshal,
        telemetry=True, telemetry_buckets=BUCKETS,
    )
    fn = _forward_fn(mesh_pods222, cfg, AXES3)
    counts = np.full(R, 10, np.int32)
    dest = np.zeros((R, CAP), np.int32)
    _cnt, drops, st = fn(jnp.asarray(dest).reshape(-1), jnp.asarray(counts))
    sdrop = np.asarray(st.stage_drops)  # (R, 3) — tier 0 = pod (slowest)
    np.testing.assert_array_equal(sdrop[:, 2], np.full(R, 6))     # device
    np.testing.assert_array_equal(sdrop[:, 1], [4, 0, 4, 0, 4, 0, 4, 0])
    np.testing.assert_array_equal(sdrop[:, 0], [4, 0, 0, 0, 4, 0, 0, 0])
    assert sdrop.sum() == 48 + 16 + 8
    assert np.asarray(st.recv_drops).sum() == 0  # 8 arrivals ≤ capacity
    assert int(np.asarray(drops).sum()) == 72
    # post-clamp demand: device stage saw the raw 10-row segment, node and
    # pod stages see only the 4+4 survivors of the faster clamp
    dmax = np.asarray(st.demand_max)
    np.testing.assert_array_equal(dmax[:, 2], np.full(R, 10))
    np.testing.assert_array_equal(dmax[:, 1], [8, 0, 8, 0, 8, 0, 8, 0])
    np.testing.assert_array_equal(dmax[:, 0], [8, 0, 0, 0, 8, 0, 0, 0])


# -------------------------------------------------------- ring in the loop
def test_run_until_done_carries_ring_and_overwrites_window(mesh8):
    """5 hops + the initial routing round = 6 recorded rounds through a
    window of 4: pos counts all 6, the ring keeps the last 4."""
    cfg = ForwardConfig(
        "data", R, CAP, exchange="padded",
        telemetry=True, telemetry_window=4, telemetry_buckets=BUCKETS,
    )

    def round_fn(q_in, acc, rnd):
        me = jax.lax.axis_index("data")
        out = make_queue(ray_proto(), CAP)
        lane = jnp.arange(CAP)
        valid = lane < q_in.count
        keep = valid & (rnd < 4)
        dest = jnp.where(keep, (me + 1) % R, DISCARD).astype(jnp.int32)
        return enqueue(out, q_in.items, dest, valid), acc

    def drive(_x):
        me = jax.lax.axis_index("data")
        q0 = make_queue(ray_proto(), CAP)
        q0 = enqueue(q0, make_rays(3), me * jnp.ones(3, jnp.int32), jnp.ones(3, bool))
        q, acc, rounds, _done, ring = run_until_done(
            round_fn, q0, jnp.zeros(()), cfg, max_rounds=16
        )
        return rounds[None], TM.stack_ring(ring)

    ring_proto = TM.make_ring(1, window=4, buckets=BUCKETS)
    f = jax.jit(
        compat.shard_map(
            drive, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), jax.tree.map(lambda _: P("data"), ring_proto)),
        )
    )
    rounds, ring = f(jnp.arange(8.0))
    assert int(np.asarray(rounds)[0]) == 5
    np.testing.assert_array_equal(np.asarray(ring.pos), np.full(R, 6))
    assert ring.window == 4
    # 6 pushes through a window of 4 leave slots holding rounds [4, 5, 2, 3];
    # every round forwards 3 rows per rank except the final empty
    # termination round (push 5, landing in slot 1)
    np.testing.assert_array_equal(
        np.asarray(ring.stats.demand_total).reshape(R, 4),
        np.tile([3, 0, 3, 3], (R, 1)),
    )
    summary = TM.summarize(ring, tier_capacities=TM.tier_capacities(cfg))
    assert summary["rounds"] == 6
    assert summary["window_filled"] == 4
    assert summary["demand_max"][0] == 3
    assert summary["drops"] == 0


def test_summarize_and_quantile_roundtrip():
    """Host-side quantile inversion: q=1 returns the exact max; a mid
    quantile lands on a conservative bucket upper edge."""
    ring = TM.make_ring(1, window=8, buckets=BUCKETS)
    for occ in (1, 2, 2, 3, 3, 3, 50):
        st = TM.single_tier_stats(
            jnp.array([occ], jnp.int32), 32, BUCKETS,
            sent_rows=jnp.int32(occ), stage_drops=jnp.int32(0),
            recv_total=jnp.int32(occ), recv_drops=jnp.int32(0),
        )
        ring = TM.ring_push(ring, st)
    summary = TM.summarize(ring, tier_capacities=(32,))
    assert summary["demand_max"][0] == 50
    assert TM.demand_quantile(summary, 0, 1.0) == 50
    # 6 of 7 demands are <= 3; the 0.8 quantile sits in the first bucket
    # (width ceil(32/7) = 5) whose exclusive upper edge is 5
    q80 = TM.demand_quantile(summary, 0, 0.8)
    assert 3 <= q80 <= TM.bucket_width(32, BUCKETS)
    # any quantile reaching the overflow bucket falls back to the exact max
    assert TM.demand_quantile(summary, 0, 0.999) == 50


def test_cycling_records_per_hop_occupancy(mesh8):
    """deliver_by_cycling with telemetry: one RoundStats per ring hop, the
    in-flight occupancy trace shrinking as ranks absorb their items.  The
    ring window is num_ranks (one slot per hop) REGARDLESS of
    telemetry_window, so the full trace survives even when the configured
    window is smaller than the ring."""
    from repro.core import deliver_by_cycling

    cfg = ForwardConfig(
        "data", R, CAP, exchange="padded",
        telemetry=True, telemetry_window=R // 2, telemetry_buckets=BUCKETS,
    )

    def drive(_x):
        me = jax.lax.axis_index("data")
        q = make_queue(ray_proto(), CAP)
        n = 4
        q = enqueue(
            q, make_rays(n), ((me + 1 + jnp.arange(n)) % R).astype(jnp.int32),
            jnp.ones(n, bool),
        )
        absorbed, total, ring = deliver_by_cycling(q, cfg)
        return absorbed.count[None], total, TM.stack_ring(ring)

    ring_proto = TM.make_ring(1, window=R, buckets=BUCKETS)
    f = jax.jit(
        compat.shard_map(
            drive, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P(), jax.tree.map(lambda _: P("data"), ring_proto)),
        )
    )
    cnt, total, ring = f(jnp.arange(8.0))
    assert int(total) == 8 * 4
    np.testing.assert_array_equal(np.asarray(ring.pos), np.full(R, R))
    # hop occupancies are monotonically non-increasing per rank as the ring
    # drains (each rank absorbs one of the 4 items per hop window)
    occ = np.asarray(ring.stats.demand_total).reshape(R, R)
    assert (np.diff(occ, axis=1) <= 0).all(), occ
    assert occ[:, 0].max() == 4 and occ[:, -1].max() == 0


def test_rebalance_returns_stats_with_telemetry(mesh_pods222):
    """rebalance() propagates telemetry on both the global topology-aware
    round and the intra-scope round (whose stats bind to the fast tier)."""
    from repro.core import rebalance

    cfg = ForwardConfig(
        AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 2, 2),
        telemetry=True, telemetry_buckets=BUCKETS,
    )

    def drive_scope(scope):
        def bal(_x):
            me = jax.lax.axis_index(AXES3)
            n = jnp.where(me % 2 == 0, 20, 2)
            q = WorkQueue(
                items=make_rays(CAP),
                dest=jnp.full((CAP,), DISCARD, jnp.int32),
                count=n.astype(jnp.int32),
                drops=jnp.zeros((), jnp.int32),
            )
            nq, total, stats = rebalance(q, cfg, scope=scope)
            return nq.count[None], total, TM.stack_ring(stats)

        sub_tiers = 3 if scope == "global" else 1
        proto = TM.make_stats(sub_tiers, BUCKETS)
        return jax.jit(
            compat.shard_map(
                bal, mesh=mesh_pods222, in_specs=P(AXES3),
                out_specs=(P(AXES3), P(), jax.tree.map(lambda _: P(AXES3), proto)),
            )
        )

    cnt, total, st = drive_scope("global")(jnp.arange(8.0))
    assert int(total) == 8 * 11  # 88 residents spread 11 per rank
    assert np.asarray(st.demand_hist).sum() > 0
    cnt_i, total_i, st_i = drive_scope("intra")(jnp.arange(8.0))
    assert int(total_i) == 8 * 11
    assert st_i.tiers == 1  # intra stats bind to the fast-axis sub-config

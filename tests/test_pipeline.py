"""Overlap-law property tests (ISSUE 8): pipelined forwarding is bit-exact.

``ForwardConfig.pipeline_shards=S`` splits every peer segment of a round
into S micro-shards, each shipped by its own payload+count collective pair
so shard k+1's marshal can overlap shard k's wire time (the stage graph in
``repro.core.stages``).  The law under test: pipelining changes the
SCHEDULE, never the ANSWER —

  * placement, counts, drops, ages and totals are bit-exact with the bulk
    (S=1) round on every backend that supports sharding (flat padded,
    2-/3-level hierarchical, ragged when available), for BOTH marshal
    modes, BOTH overflow modes, and adversarial traffic (hotspot overflow
    included);
  * configs that cannot shard fail loudly at construction/call time with a
    message naming the limitation (onehot oracle, cycling ring), and the
    shard count must divide every capacity it tiles — never a silent
    rounding.

The collective-budget side of the law (S payload + S count collectives per
mesh axis, S=1 lowering bit-identical to the pre-stage-graph HLO) lives in
``test_collective_budget.py``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic stub
    from _hypothesis_stub import given, settings, st
from jax.sharding import PartitionSpec as P

from helpers import make_rays, ray_proto
from repro import compat
from repro.core import (
    DISCARD,
    ForwardConfig,
    WorkQueue,
    enqueue,
    forward_work,
    make_queue,
    work_item,
)
from repro.core.cycling import cycle_step

pytestmark = pytest.mark.pipeline

R, CAP = 8, 64


# ---------------------------------------------------------------- runners
def _dest_fn(pattern, seed, n_emit):
    """Per-rank destination pattern (traced inside shard_map)."""

    def f(me):
        i = jnp.arange(n_emit)
        if pattern == "uniform":
            # includes out-of-range dests (R, R+1) — the enqueue discard path
            return ((me * 7 + seed + i**2) % (R + 2)).astype(jnp.int32)
        if pattern == "hotspot":
            # every rank floods one destination — clamp/spill under pressure
            return jnp.full((n_emit,), seed % R, jnp.int32)
        return ((me + 1 + (i % 2)) % R).astype(jnp.int32)  # neighbour

    return f


def _run(mesh, cfg, pattern="uniform", seed=0, n_emit=24):
    """One forwarding round; returns every observable of the result."""
    axes = cfg.axis_name
    flat = axes if isinstance(axes, str) else tuple(axes)

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index(axes)
        q = enqueue(
            q, make_rays(n_emit), _dest_fn(pattern, seed, n_emit)(me),
            jnp.ones(n_emit, bool),
        )
        res = forward_work(q, cfg)
        nq = res[0]
        out = [
            nq.count[None], nq.drops[None], nq.dest, nq.items.tmin,
            nq.items.pixel, nq.items.integral, res[1],
        ]
        if cfg.overflow == "retain":
            out.append(res[2])  # per-lane age
        return tuple(out)

    spec = P(flat)
    n_sharded = 6
    out_specs = [spec] * n_sharded + [P()]
    if cfg.overflow == "retain":
        out_specs.append(spec)
    f = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh, in_specs=spec, out_specs=tuple(out_specs)
        )
    )
    return jax.device_get(f(jnp.arange(8.0)))


def _assert_same(ref, got, label):
    for i, (a, b) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"{label}: output {i} diverged from bulk round"
        )


_REF_CACHE = {}


def _flat_ref(mesh8, marshal, overflow, pattern, seed):
    key = (marshal, overflow, pattern, seed)
    if key not in _REF_CACHE:
        base = ForwardConfig(
            "data", R, CAP, exchange="padded", marshal=marshal,
            overflow=overflow,
        )
        _REF_CACHE[key] = _run(mesh8, base, pattern, seed)
    return _REF_CACHE[key]


# ------------------------------------------------------- flat padded exact
@pytest.mark.parametrize("S", [2, 4])
@pytest.mark.parametrize("overflow", ["drop", "retain"])
@pytest.mark.parametrize("marshal", ["sort", "scatter"])
def test_flat_padded_bitexact(mesh8, marshal, overflow, S):
    """Flat padded round: S micro-shards land every row in the SAME slot as
    the bulk round — payload, dest, count, drops, ages all equal, under
    benign and hotspot (overflowing) traffic."""
    for pattern, seed in [("uniform", 0), ("hotspot", 3)]:
        ref = _flat_ref(mesh8, marshal, overflow, pattern, seed)
        cfg = ForwardConfig(
            "data", R, CAP, exchange="padded", marshal=marshal,
            overflow=overflow, pipeline_shards=S,
        )
        got = _run(mesh8, cfg, pattern, seed)
        _assert_same(ref, got, f"{marshal}/{overflow}/{pattern}/S={S}")


@pytest.mark.pallas_interpret
def test_flat_pallas_bitexact(mesh8):
    """The Pallas kernel path shards too: fused bucket-scatter marshal per
    micro-shard, placement identical to the bulk kernel round."""
    base = ForwardConfig(
        "data", R, CAP, exchange="padded", marshal="scatter",
        overflow="retain", use_pallas=True,
    )
    cfg = dataclasses.replace(base, pipeline_shards=2)
    _assert_same(
        _run(mesh8, base, "hotspot", 3), _run(mesh8, cfg, "hotspot", 3),
        "pallas/S=2",
    )


@pytest.mark.skipif(
    not compat.HAS_RAGGED_ALL_TO_ALL,
    reason="jax.lax.ragged_all_to_all not in this JAX",
)
@pytest.mark.parametrize("S", [2, 4])
def test_flat_ragged_bitexact(mesh8, S):
    """Ragged backend: S ragged_all_to_all slices conserve placement."""
    base = ForwardConfig("data", R, CAP, exchange="ragged")
    cfg = dataclasses.replace(base, pipeline_shards=S)
    _assert_same(
        _run(mesh8, base), _run(mesh8, cfg, seed=0), f"ragged/S={S}"
    )


# ------------------------------------------------------ hierarchical exact
HIER = [
    ("mesh_nodes24", ("node", "device"), (2, 4), (6, 8)),
    ("mesh_pods222", ("pod", "node", "device"), (2, 2, 2), (4, 6, 8)),
]


@pytest.mark.parametrize("overflow", ["drop", "retain"])
@pytest.mark.parametrize("marshal", ["sort", "scatter"])
@pytest.mark.parametrize(
    "fixture,axes,sizes,caps", HIER, ids=["2level", "3level"]
)
def test_hierarchical_bitexact(
    request, fixture, axes, sizes, caps, marshal, overflow
):
    """Dimension-ordered route: per-tier micro-shards (chunk = tier slot /
    S) reassemble each stage buffer exactly, so the multi-hop placement —
    including mid-route retain parking — matches the bulk round bit for
    bit.  Uneven per-tier capacities exercise distinct chunk sizes."""
    mesh = request.getfixturevalue(fixture)
    base = ForwardConfig(
        axes, R, CAP, exchange="hierarchical", level_sizes=sizes,
        level_capacities=caps, marshal=marshal, overflow=overflow,
    )
    cfg = dataclasses.replace(base, pipeline_shards=2)
    _assert_same(
        _run(mesh, base, "hotspot", 3), _run(mesh, cfg, "hotspot", 3),
        f"hier{len(sizes)}/{marshal}/{overflow}",
    )


# -------------------------------------------------- property (hypothesis)
@work_item
@dataclasses.dataclass
class Probe:
    val: jax.Array
    src: jax.Array


def _make_pair(mesh8, S):
    """(bulk, pipelined) jitted rounds over runtime-fed queues — compiled
    once, hypothesis drives the data."""

    def build(shards):
        cfg = ForwardConfig(
            "data", R, CAP, exchange="padded", pipeline_shards=shards
        )

        def fwd(val, dest, counts):
            me = jax.lax.axis_index("data")
            q = WorkQueue(
                items=Probe(val=val, src=me * jnp.ones(CAP, jnp.int32)),
                dest=dest,
                count=counts[0],
                drops=jnp.zeros((), jnp.int32),
            )
            nq, total = forward_work(q, cfg)
            return (
                nq.items.val, nq.items.src, nq.dest, nq.count[None],
                nq.drops[None], total,
            )

        return jax.jit(
            compat.shard_map(
                fwd, mesh=mesh8,
                in_specs=(P("data"), P("data"), P("data")),
                out_specs=(
                    P("data"), P("data"), P("data"), P("data"), P("data"),
                    P(),
                ),
            )
        )

    return build(1), build(S)


@pytest.fixture(scope="module")
def fwd_pair(mesh8):
    return _make_pair(mesh8, 2)


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_pipelined_placement_property(fwd_pair, data):
    """For arbitrary queue fills — random counts, random destinations, a
    coin-flip hotspot that overflows one rank — the S=2 round equals the
    bulk round on every output array."""
    bulk, piped = fwd_pair
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = np.full((R, CAP), DISCARD, np.int32)
    for r in range(R):
        if rng.random() < 0.3:  # hotspot: everyone floods one destination
            dest[r, : counts[r]] = rng.integers(0, R)
        else:
            dest[r, : counts[r]] = rng.integers(0, R, counts[r])
    val = rng.standard_normal((R, CAP)).astype(np.float32)
    args = (
        jnp.asarray(val.reshape(-1)),
        jnp.asarray(dest.reshape(-1)),
        jnp.asarray(counts),
    )
    _assert_same(
        jax.device_get(bulk(*args)), jax.device_get(piped(*args)),
        "property/S=2",
    )


# ------------------------------------------------------------- validation
def test_pipeline_shards_must_be_positive():
    with pytest.raises(ValueError, match="pipeline_shards"):
        ForwardConfig("data", R, CAP, pipeline_shards=0)


def test_pipeline_shards_must_divide_capacity():
    with pytest.raises(ValueError, match="divide"):
        ForwardConfig("data", R, CAP, pipeline_shards=3)  # 3 does not divide 64


def test_pipeline_shards_must_divide_peer_capacity():
    with pytest.raises(ValueError, match="peer_capacity"):
        ForwardConfig("data", R, CAP, peer_capacity=6, pipeline_shards=4)


def test_pipeline_shards_must_divide_level_capacities():
    with pytest.raises(ValueError, match="level_capacities"):
        ForwardConfig(
            ("node", "device"), R, CAP, exchange="hierarchical",
            level_sizes=(2, 4), level_capacities=(7, 8), pipeline_shards=2,
        )


def test_onehot_rejects_pipelining():
    with pytest.raises(ValueError, match="onehot"):
        ForwardConfig("data", R, CAP, exchange="onehot", pipeline_shards=2)


def test_cycling_rejects_pipelining():
    cfg = ForwardConfig("data", R, CAP, pipeline_shards=2)
    q = make_queue(ray_proto(), CAP)
    with pytest.raises(ValueError, match="cycling"):
        cycle_step(q, q, cfg)

"""Recovery-law property tests (ISSUE 7): checkpoint/resume, elastic
restore, health-aware draining, and the conservation watchdog.

The load-bearing claims, each checked against independent evidence:

* **Preempt-resume is bit-exact** — a drive halted at a checkpoint boundary
  and resumed from disk publishes byte-identical checkpoints at every
  boundary the uninterrupted run also published (SHA-256 manifest digests
  over EVERY carry leaf: queue payloads, dests, ages, checksums, telemetry
  ring, counters), in every overflow × marshal combination and on a
  hierarchical route.  Not statistically equal — the same trajectory.
* **Elastic restore conserves** — a burst saved on R ranks resumed on
  R′ < R drains to completion with the global delivery checksums equal to
  the schedule's and zero loss.
* **Draining loses nothing** — a mid-burst rank brownout re-addresses
  traffic through the pure-local health remap; the device trajectory
  (deliveries, rounds, retained/age traces) matches the health-aware numpy
  twin exactly and the browned-out ranks receive nothing after the mask
  flips.
* **The watchdog bites** — a carry whose books don't balance raises before
  it can be checkpointed.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import compat
from repro.chaos import (
    boundary_digests,
    brownout_mask,
    capacity_drought,
    convergecast,
    expected_by_rank,
    rank_brownout,
    run_scenario,
    run_scenario_checkpointed,
    simulate_flat_retain,
)
from repro.core import (
    DISCARD,
    ForwardConfig,
    conservation_check,
    health_table,
    make_queue,
    rebalance,
    remap_dest,
)
from repro.core.context import RafiContext
from repro.core.queue import WorkQueue

from helpers import make_rays, ray_proto

pytestmark = pytest.mark.recovery

R = 8
S = 2
FLAT_CAP = 128
_M32 = 1 << 32


@pytest.fixture(scope="module")
def mesh4():
    """A 4-of-8-device mesh — the shrunken world elastic restore lands on."""
    return compat.make_mesh((4,), ("data",))


# ------------------------------------------------------------ health remap
def test_health_table_law():
    """table[d] == d for healthy d; == healthy[d % n_h] for unhealthy d;
    identity when everything (or nothing) is healthy."""
    h = np.array([1, 1, 0, 1, 0, 1, 1, 1], bool)
    healthy = np.nonzero(h)[0]
    table = np.asarray(health_table(jnp.asarray(h)))
    for d in range(R):
        want = d if h[d] else healthy[d % len(healthy)]
        assert table[d] == want, (d, table)
    assert (np.asarray(health_table(jnp.ones(R, bool))) == np.arange(R)).all()
    # all-unhealthy degenerates to the identity (shutdown is not a remap)
    assert (np.asarray(health_table(jnp.zeros(R, bool))) == np.arange(R)).all()


def test_remap_dest_passes_discard_through():
    h = jnp.asarray(np.array([1, 0, 1, 1, 1, 1, 1, 1], bool))
    dest = jnp.array([0, 1, DISCARD, 7, 1], jnp.int32)
    out = np.asarray(remap_dest(dest, h))
    assert out[2] == DISCARD
    assert out[0] == 0 and out[3] == 7
    assert out[1] == out[4] != 1 and bool(h[out[1]])


def test_all_healthy_mask_is_bitidentical_to_no_mask(mesh8):
    """health=None and an all-True mask must produce the same run, bit for
    bit — the remap is provably the identity, not merely harmless."""
    sc = capacity_drought()
    kw = dict(capacity=FLAT_CAP, peer_capacity=S, overflow="retain")
    a = run_scenario(mesh8, sc, **kw)
    b = run_scenario(mesh8, sc, health=np.ones(R, bool), **kw)
    np.testing.assert_array_equal(a["delivered"], b["delivered"])
    assert a["rounds"] == b["rounds"]
    np.testing.assert_array_equal(a["retained_trace"], b["retained_trace"])
    np.testing.assert_array_equal(a["age_trace"], b["age_trace"])


def test_constant_drain_matches_twin_and_starves_drained_ranks(mesh8):
    """A rank unhealthy from round 0 never receives a single row, and the
    whole trajectory matches the health-aware numpy twin."""
    sc = capacity_drought()
    h = np.ones(R, bool)
    h[[2, 5]] = False
    sim = simulate_flat_retain(sc, peer_capacity=S, capacity=FLAT_CAP, health=h)
    res = run_scenario(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        health=h,
    )
    np.testing.assert_array_equal(res["delivered"], sim["delivered"])
    assert res["drops"] == 0 and res["lost"] == 0 and res["done"]
    assert res["rounds"] == sim["rounds"]
    # the drained ranks delivered nothing; the traffic arrived elsewhere
    assert res["delivered"][2].sum() == 0 and res["delivered"][5].sum() == 0
    assert res["delivered_total"] == sc.emitted


# ------------------------------------------------- preempt-resume bit-exact
PREEMPT_CASES = [
    ("drop", "sort"),
    ("drop", "scatter"),
    ("retain", "sort"),
    ("retain", "scatter"),
]


@pytest.mark.parametrize("overflow,marshal", PREEMPT_CASES)
def test_preempt_resume_bitexact_flat(tmp_path, mesh8, overflow, marshal):
    """Kill at a boundary, resume from disk: every boundary checkpoint of
    the resumed run is BYTE-identical (manifest SHA-256 per carry leaf) to
    the uninterrupted run's, and the final accounting matches the plain
    un-checkpointed drive."""
    sc = capacity_drought()
    kw = dict(
        capacity=FLAT_CAP, peer_capacity=S, overflow=overflow, marshal=marshal,
    )
    ref = run_scenario(mesh8, sc, **kw)
    a = run_scenario_checkpointed(
        mesh8, sc, ckpt_dir=tmp_path / "a", checkpoint_every=3, keep=99, **kw
    )
    b = run_scenario_checkpointed(
        mesh8, sc, ckpt_dir=tmp_path / "b", checkpoint_every=3, keep=99,
        preempt_at=5, **kw
    )
    assert b["preempted"] and not a["preempted"]
    np.testing.assert_array_equal(a["delivered"], ref["delivered"])
    np.testing.assert_array_equal(b["delivered"], ref["delivered"])
    assert a["rounds"] == b["rounds"] == ref["rounds"]
    assert a["lost"] == b["lost"] == 0
    da, db = boundary_digests(tmp_path / "a"), boundary_digests(tmp_path / "b")
    common = sorted(set(da) & set(db))
    assert len(common) >= 3  # boundaries 0, 3, … and the final one
    for step in common:
        assert da[step] == db[step], f"state diverged at boundary {step}"
    assert a["steps"] == b["steps"]  # same boundaries published


def test_preempt_resume_bitexact_hierarchical(tmp_path, mesh_pods222):
    """The recovery law composes with the 3-level route + telemetry +
    retain — the carry is bigger (ring, ages) but the digests still agree
    at every common boundary."""
    sc = convergecast(R)
    kw = dict(
        capacity=256, axis_name=("pod", "node", "device"),
        exchange="hierarchical", level_capacities=(8, 8, 8),
        overflow="retain", max_rounds=128,
    )
    a = run_scenario_checkpointed(
        mesh_pods222, sc, ckpt_dir=tmp_path / "a", checkpoint_every=4,
        keep=99, **kw
    )
    b = run_scenario_checkpointed(
        mesh_pods222, sc, ckpt_dir=tmp_path / "b", checkpoint_every=4,
        keep=99, preempt_at=6, **kw
    )
    assert b["preempted"]
    np.testing.assert_array_equal(a["delivered"], expected_by_rank(sc))
    np.testing.assert_array_equal(b["delivered"], expected_by_rank(sc))
    da, db = boundary_digests(tmp_path / "a"), boundary_digests(tmp_path / "b")
    for step in sorted(set(da) & set(db)):
        assert da[step] == db[step], f"state diverged at boundary {step}"


def test_checkpointing_does_not_change_the_answer(tmp_path, mesh8):
    """ckpt_dir=None (segmented drive, no I/O) and a full checkpointed run
    agree with each other — segmentation alone is invisible."""
    sc = capacity_drought()
    kw = dict(capacity=FLAT_CAP, peer_capacity=S, overflow="retain")
    nockpt = run_scenario_checkpointed(
        mesh8, sc, ckpt_dir=None, checkpoint_every=3, **kw
    )
    assert nockpt["steps"] == []  # nothing was written anywhere
    withckpt = run_scenario_checkpointed(
        mesh8, sc, ckpt_dir=tmp_path, checkpoint_every=3, **kw
    )
    np.testing.assert_array_equal(nockpt["delivered"], withckpt["delivered"])
    assert nockpt["rounds"] == withckpt["rounds"]
    np.testing.assert_array_equal(
        nockpt["retained_trace"], withckpt["retained_trace"]
    )


# --------------------------------------------------------- elastic restore
def test_elastic_restore_r8_to_r4_conserves(tmp_path, mesh8, mesh4):
    """Preempt on 8 ranks in the drain phase, resume on 4: the folded
    backlog drains to completion, the GLOBAL delivery checksums equal the
    schedule's, and nothing is lost or dropped."""
    sc = capacity_drought()
    res = run_scenario_checkpointed(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        ckpt_dir=tmp_path, checkpoint_every=3, keep=99,
        preempt_at=7, resume_mesh=mesh4, resume_capacity=256,
    )
    assert res["preempted"] and res["done"]
    assert res["lost"] == 0 and res["drops"] == 0
    exp = expected_by_rank(sc).astype(np.uint64)
    got = res["delivered"].astype(np.uint64)
    assert got.shape[0] == 4  # the resumed world really is 4 ranks
    assert int(got[:, 0].sum()) == int(exp[:, 0].sum())
    assert int(got[:, 1].sum() % _M32) == int(exp[:, 1].sum() % _M32)
    assert int(got[:, 2].sum() % _M32) == int(exp[:, 2].sum() % _M32)


def test_elastic_restore_worst_case_backlog(tmp_path, mesh8, mesh4):
    """Convergecast leaves the biggest possible single-destination backlog
    at the preempt boundary; folding it onto half the ranks must still
    close the books (the slow drain is the price, not loss)."""
    sc = convergecast(R)
    res = run_scenario_checkpointed(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        ckpt_dir=tmp_path, checkpoint_every=3, keep=99,
        preempt_at=7, resume_mesh=mesh4, resume_capacity=256,
    )
    assert res["preempted"] and res["done"]
    assert res["lost"] == 0 and res["drops"] == 0
    assert res["delivered_total"] == sc.emitted


# ------------------------------------------------------------ rank brownout
def test_rank_brownout_loses_nothing_and_matches_twin(tmp_path, mesh8):
    """Mid-burst brownout via the per-segment health schedule: the device
    trajectory equals the numpy twin fed the SAME segment-quantized health
    law, zero rows are lost, and the dark ranks stop receiving within one
    segment of the mask flip."""
    sc = rank_brownout()
    W = 3
    health = brownout_mask(R, down=(2, 5), down_from=3)

    # the segmented drive re-reads health at each boundary: forward 0 uses
    # health(0); forward f >= 1 belongs to the segment starting at boundary
    # W * ((f - 1) // W)
    def twin_health(f):
        return health(0) if f == 0 else health(W * ((f - 1) // W))

    sim = simulate_flat_retain(
        sc, peer_capacity=S, capacity=FLAT_CAP, health=twin_health
    )
    res = run_scenario_checkpointed(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        ckpt_dir=tmp_path, checkpoint_every=W, keep=99, health=health,
    )
    np.testing.assert_array_equal(res["delivered"], sim["delivered"])
    assert res["rounds"] == sim["rounds"]
    assert res["lost"] == 0 and res["drops"] == 0 and res["done"]
    assert res["delivered_total"] == sc.emitted
    np.testing.assert_array_equal(res["retained_trace"], sim["retained_trace"])
    np.testing.assert_array_equal(res["age_trace"], sim["age_trace"])


# ---------------------------------------------------------------- watchdog
def test_watchdog_passes_balanced_books():
    conservation_check(
        {
            "emitted": np.array([10, 10], np.int32),
            "delivered": np.array([7, 5], np.int32),
            "total": np.int32(6),
            "drops": np.array([1, 1], np.int32),
        }
    )


def test_watchdog_raises_on_leak():
    with pytest.raises(RuntimeError, match="conservation violated"):
        conservation_check(
            {
                "emitted": np.array([10, 10], np.int32),
                "delivered": np.array([7, 5], np.int32),
                "total": np.int32(5),  # one row vanished
                "drops": np.array([1, 1], np.int32),
            },
            where="round 4",
        )


# ------------------------------------------------------- resume validation
def test_resume_rejects_mismatched_context(tmp_path, mesh8):
    """A checkpoint written by a retain drive must refuse to resume under a
    drop-mode context (silent semantic drift), with a typed error."""
    from repro.core import recovery
    from repro.chaos.driver import _make_ctx, _make_round_fn

    sc = capacity_drought()
    run_scenario_checkpointed(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        ckpt_dir=tmp_path, checkpoint_every=3, keep=99, preempt_at=5,
    )
    ctx = _make_ctx(
        mesh8, capacity=FLAT_CAP, peer_capacity=S, overflow="drop"
    )
    spec = ctx._spec
    with pytest.raises(ValueError, match="overflow"):
        recovery.resume_run(
            ctx, _make_round_fn(ctx, sc), tmp_path,
            aux_specs=(spec, spec, spec),
            aux_like=tuple(np.zeros((R,), np.uint32) for _ in range(3)),
        )
    with pytest.raises(FileNotFoundError):
        recovery.resume_run(
            ctx, _make_round_fn(ctx, sc), tmp_path / "empty",
            aux_specs=(spec, spec, spec),
            aux_like=tuple(np.zeros((R,), np.uint32) for _ in range(3)),
        )


# ------------------------------------------------- truncated-run age return
def test_truncated_retain_run_returns_live_ages(mesh8):
    """Satellite: a retain drive cut off by ``max_rounds`` hands back the
    REAL per-lane age vector of the still-queued rows, so a continuation
    keeps the FIFO anti-starvation clock instead of resetting it.

    Construction: rank 0 holds 6 rows for rank 1 behind a 2-row clamp and
    nothing else ever emits.  After the initial forward + one body round,
    exactly 2 rows remain retained on rank 0 having waited 2 forwards each
    — the returned ages must say [2, 2], not zeros."""
    ctx = RafiContext(
        mesh8, ray_proto(), capacity=FLAT_CAP, peer_capacity=S,
        exchange="padded", overflow="retain",
    )

    def round_fn(q_in, acc, rnd):
        # pure consumer: arrivals are retired, nothing new is emitted
        return make_queue(ray_proto(), FLAT_CAP), acc + q_in.count

    drive = ctx.run_until_done(
        round_fn, aux_specs=ctx._spec, max_rounds=1
    )
    uid = np.zeros((R * FLAT_CAP,), np.int32)
    dest = np.full((R * FLAT_CAP,), DISCARD, np.int32)
    count = np.zeros((R,), np.int32)
    dest[:6] = 1  # six rows on rank 0, all for rank 1
    count[0] = 6
    rays = jax.tree.map(
        lambda a: jnp.zeros((R * FLAT_CAP,) + a.shape, a.dtype), ray_proto()
    )
    q0 = WorkQueue(
        items=rays, dest=jnp.asarray(dest), count=jnp.asarray(count),
        drops=jnp.zeros((R,), jnp.int32),
    )
    q, acc, rounds, done, age = drive(q0, jnp.zeros((R,), jnp.int32))
    assert int(rounds) == 1 and not bool(done)  # truncated, work in flight
    ages = np.asarray(age)
    assert sorted(ages[ages > 0].tolist()) == [2, 2], ages[:8]
    # the two aged rows sit at rank 0's queue front, dest intact
    assert np.asarray(q.count)[0] == 2
    assert list(np.asarray(q.dest)[:2]) == [1, 1]


# ------------------------------------------------- health-aware rebalance
def test_rebalance_evacuates_unhealthy_rank(mesh8):
    """The drain recipe: mark a rank unhealthy and run one health-aware
    global rebalance — its resident rows land on survivors, it receives
    nothing, and the population stays conserved."""
    cfg = ForwardConfig("data", R, FLAT_CAP, peer_capacity=32, exchange="padded")
    n = 16

    def kernel(_x, h):
        # every rank holds n resident rows (dest DISCARD = unaddressed)
        q = WorkQueue(
            items=make_rays(FLAT_CAP),
            dest=jnp.full((FLAT_CAP,), DISCARD, jnp.int32),
            count=jnp.int32(n),
            drops=jnp.zeros((), jnp.int32),
        )
        balanced, total = rebalance(q, cfg, health=h)
        return balanced.count[None], total, balanced.drops[None]

    from jax.sharding import PartitionSpec as P
    from repro import compat

    f = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=(P("data"), P()),
            out_specs=(P("data"), P(), P("data")),
        )
    )
    h = np.ones(R, bool)
    h[3] = False
    count, total, drops = f(jnp.arange(8.0), jnp.asarray(h))
    count = np.asarray(count)
    assert count[3] == 0, count  # the draining rank is empty
    assert int(np.asarray(drops).sum()) == 0
    assert count.sum() == R * n == int(total)  # conserved, just moved
    # intra-scope health is rejected loudly, not silently ignored
    hier = ForwardConfig(
        ("node", "device"), R, FLAT_CAP, exchange="hierarchical",
        fast_size=4,
    )
    with pytest.raises(ValueError, match="global"):
        rebalance(
            make_queue(ray_proto(), FLAT_CAP), hier, scope="intra",
            health=jnp.ones(R, bool),
        )


# ------------------------------------------------- pipelined (the overlap law)
@pytest.mark.pipeline
def test_preempt_resume_bitexact_pipelined(tmp_path, mesh8):
    """Recovery law x overlap law: a micro-shard pipelined drive
    (``pipeline_shards=2``) checkpoints and resumes with byte-identical
    boundary digests, and its answer equals the bulk (unsharded) drive's —
    pipelining is invisible to the carry."""
    sc = capacity_drought()
    kw = dict(
        capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        pipeline_shards=2,
    )
    ref = run_scenario(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain"
    )
    a = run_scenario_checkpointed(
        mesh8, sc, ckpt_dir=tmp_path / "a", checkpoint_every=3, keep=99, **kw
    )
    b = run_scenario_checkpointed(
        mesh8, sc, ckpt_dir=tmp_path / "b", checkpoint_every=3, keep=99,
        preempt_at=5, **kw
    )
    assert b["preempted"] and not a["preempted"]
    np.testing.assert_array_equal(a["delivered"], ref["delivered"])
    np.testing.assert_array_equal(b["delivered"], ref["delivered"])
    assert a["rounds"] == b["rounds"] == ref["rounds"]
    assert a["lost"] == b["lost"] == 0
    da, db = boundary_digests(tmp_path / "a"), boundary_digests(tmp_path / "b")
    common = sorted(set(da) & set(db))
    assert len(common) >= 3
    for step in common:
        assert da[step] == db[step], f"state diverged at boundary {step}"

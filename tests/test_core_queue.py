"""Unit tests for the device interface: WorkQueue emit/read semantics (§3.2-3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DISCARD, clear, enqueue, get_incoming, make_queue, num_incoming

from helpers import make_rays, ray_proto


def test_empty_queue():
    q = make_queue(ray_proto(), 16)
    assert int(num_incoming(q)) == 0
    assert q.capacity == 16
    assert np.all(np.asarray(q.dest) == DISCARD)


def test_enqueue_appends_in_lane_order():
    q = make_queue(ray_proto(), 16)
    rays = make_rays(4)
    q = enqueue(q, rays, jnp.array([3, 1, 2, 0], jnp.int32), jnp.ones(4, bool))
    assert int(q.count) == 4
    np.testing.assert_array_equal(np.asarray(q.dest[:4]), [3, 1, 2, 0])
    got = get_incoming(q, 2)
    np.testing.assert_allclose(np.asarray(got.origin), np.asarray(rays.origin[2]))


def test_enqueue_masked_compacts_stably():
    q = make_queue(ray_proto(), 16)
    rays = make_rays(6)
    mask = jnp.array([True, False, True, False, True, False])
    q = enqueue(q, rays, jnp.arange(6, dtype=jnp.int32), mask)
    assert int(q.count) == 3
    np.testing.assert_array_equal(np.asarray(q.items.pixel[:3]), [0, 2, 4])
    np.testing.assert_array_equal(np.asarray(q.dest[:3]), [0, 2, 4])


def test_multiple_enqueues_accumulate():
    """A kernel may emit more than one item per lane (§3.3): e.g. a bounce
    ray and a shadow ray from the same shading event."""
    q = make_queue(ray_proto(), 16)
    q = enqueue(q, make_rays(3), jnp.zeros(3, jnp.int32), jnp.ones(3, bool))
    q = enqueue(q, make_rays(3, pixel_base=100), jnp.ones(3, jnp.int32), jnp.ones(3, bool))
    assert int(q.count) == 6
    np.testing.assert_array_equal(np.asarray(q.items.pixel[:6]), [0, 1, 2, 100, 101, 102])


def test_overflow_drops_and_counts():
    """Paper §3.3: emits past capacity 'simply get dropped'."""
    q = make_queue(ray_proto(), 4)
    q = enqueue(q, make_rays(6), jnp.zeros(6, jnp.int32), jnp.ones(6, bool))
    assert int(q.count) == 4
    assert int(q.drops) == 2
    np.testing.assert_array_equal(np.asarray(q.items.pixel[:4]), [0, 1, 2, 3])


def test_negative_dest_is_discard():
    q = make_queue(ray_proto(), 16)
    dest = jnp.array([0, -1, 1, DISCARD], jnp.int32)
    q = enqueue(q, make_rays(4), dest, jnp.ones(4, bool))
    assert int(q.count) == 2
    np.testing.assert_array_equal(np.asarray(q.items.pixel[:2]), [0, 2])


def test_clear_resets_count_keeps_drops():
    q = make_queue(ray_proto(), 4)
    q = enqueue(q, make_rays(6), jnp.zeros(6, jnp.int32), jnp.ones(6, bool))
    q = clear(q)
    assert int(q.count) == 0
    assert int(q.drops) == 2
    assert np.all(np.asarray(q.dest) == DISCARD)


def test_enqueue_is_jittable_and_donatable():
    @jax.jit
    def step(q):
        return enqueue(q, make_rays(2), jnp.zeros(2, jnp.int32), jnp.ones(2, bool))

    q = step(make_queue(ray_proto(), 8))
    assert int(q.count) == 2

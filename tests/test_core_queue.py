"""Unit tests for the device interface: WorkQueue emit/read semantics (§3.2-3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DISCARD, clear, enqueue, get_incoming, make_queue, num_incoming

from helpers import make_rays, ray_proto


def test_empty_queue():
    q = make_queue(ray_proto(), 16)
    assert int(num_incoming(q)) == 0
    assert q.capacity == 16
    assert np.all(np.asarray(q.dest) == DISCARD)


def test_enqueue_appends_in_lane_order():
    q = make_queue(ray_proto(), 16)
    rays = make_rays(4)
    q = enqueue(q, rays, jnp.array([3, 1, 2, 0], jnp.int32), jnp.ones(4, bool))
    assert int(q.count) == 4
    np.testing.assert_array_equal(np.asarray(q.dest[:4]), [3, 1, 2, 0])
    got = get_incoming(q, 2)
    np.testing.assert_allclose(np.asarray(got.origin), np.asarray(rays.origin[2]))


def test_enqueue_masked_compacts_stably():
    q = make_queue(ray_proto(), 16)
    rays = make_rays(6)
    mask = jnp.array([True, False, True, False, True, False])
    q = enqueue(q, rays, jnp.arange(6, dtype=jnp.int32), mask)
    assert int(q.count) == 3
    np.testing.assert_array_equal(np.asarray(q.items.pixel[:3]), [0, 2, 4])
    np.testing.assert_array_equal(np.asarray(q.dest[:3]), [0, 2, 4])


def test_multiple_enqueues_accumulate():
    """A kernel may emit more than one item per lane (§3.3): e.g. a bounce
    ray and a shadow ray from the same shading event."""
    q = make_queue(ray_proto(), 16)
    q = enqueue(q, make_rays(3), jnp.zeros(3, jnp.int32), jnp.ones(3, bool))
    q = enqueue(q, make_rays(3, pixel_base=100), jnp.ones(3, jnp.int32), jnp.ones(3, bool))
    assert int(q.count) == 6
    np.testing.assert_array_equal(np.asarray(q.items.pixel[:6]), [0, 1, 2, 100, 101, 102])


def test_overflow_drops_and_counts():
    """Paper §3.3: emits past capacity 'simply get dropped'."""
    q = make_queue(ray_proto(), 4)
    q = enqueue(q, make_rays(6), jnp.zeros(6, jnp.int32), jnp.ones(6, bool))
    assert int(q.count) == 4
    assert int(q.drops) == 2
    np.testing.assert_array_equal(np.asarray(q.items.pixel[:4]), [0, 1, 2, 3])


def test_negative_dest_is_discard():
    q = make_queue(ray_proto(), 16)
    dest = jnp.array([0, -1, 1, DISCARD], jnp.int32)
    q = enqueue(q, make_rays(4), dest, jnp.ones(4, bool))
    assert int(q.count) == 2
    np.testing.assert_array_equal(np.asarray(q.items.pixel[:2]), [0, 2])


def test_clear_resets_count_keeps_drops():
    q = make_queue(ray_proto(), 4)
    q = enqueue(q, make_rays(6), jnp.zeros(6, jnp.int32), jnp.ones(6, bool))
    q = clear(q)
    assert int(q.count) == 0
    assert int(q.drops) == 2
    assert np.all(np.asarray(q.dest) == DISCARD)


def test_enqueue_is_jittable_and_donatable():
    @jax.jit
    def step(q):
        return enqueue(q, make_rays(2), jnp.zeros(2, jnp.int32), jnp.ones(2, bool))

    q = step(make_queue(ray_proto(), 8))
    assert int(q.count) == 2


@pytest.mark.parametrize("truthy", [1, 2], ids=["ones", "nonunit"])
def test_enqueue_bool_and_int_masks_are_equivalent(truthy):
    """ISSUE 5 satellite: enqueue accepts any mask dtype with nonzero-is-emit
    semantics.  The regression: an int mask used to be combined with the
    dest check by BITWISE and, so a truthy value of 2 (`2 & True == 0`)
    silently lost the emit, and the raw ints leaked into the position
    prefix-sum.  Bool and int masks must produce identical queues —
    count, placement, AND the overflow drop counter."""
    rays = make_rays(6)
    dest = jnp.array([0, 1, DISCARD, 2, 3, 4], jnp.int32)
    keep = np.array([1, 0, 1, 1, 0, 1])
    masks = {
        "bool": jnp.asarray(keep, bool),
        "int32": jnp.asarray(keep * truthy, jnp.int32),
    }
    # capacity 3 < the 3 valid emits on lanes (0, 3, 5) plus the DISCARD
    # lane: the drop accounting must agree across mask dtypes too
    got = {
        name: enqueue(make_queue(ray_proto(), 3), rays, dest, m)
        for name, m in masks.items()
    }
    b, i = got["bool"], got["int32"]
    assert int(b.count) == int(i.count) == 3
    assert int(b.drops) == int(i.drops) == 0
    np.testing.assert_array_equal(np.asarray(b.dest), np.asarray(i.dest))
    np.testing.assert_array_equal(
        np.asarray(b.items.pixel), np.asarray(i.items.pixel)
    )
    np.testing.assert_array_equal(np.asarray(b.items.pixel), [0, 3, 5])
    # and with a genuine overflow: 4 emits into capacity 3 → 1 drop, both
    full = {
        name: enqueue(
            make_queue(ray_proto(), 3), rays,
            jnp.zeros(6, jnp.int32),
            jnp.asarray(np.array([1, 1, 0, 1, 0, 1]) * (truthy if name == "int32" else 1),
                        bool if name == "bool" else jnp.int32),
        )
        for name in ("bool", "int32")
    }
    assert int(full["bool"].drops) == int(full["int32"].drops) == 1
    np.testing.assert_array_equal(
        np.asarray(full["bool"].items.pixel[:3]),
        np.asarray(full["int32"].items.pixel[:3]),
    )


# -------------------------------------------------- emit-time validation
def test_make_queue_rejects_non_int_capacity():
    for bad in (16.0, "16", None, jnp.zeros(())):
        with pytest.raises(ValueError, match="static Python int"):
            make_queue(ray_proto(), bad)
    with pytest.raises(ValueError, match=">= 1"):
        make_queue(ray_proto(), 0)


def test_enqueue_rejects_float_dest():
    """A float dest would truncate-cast and misroute silently — the classic
    emit-kernel bug this check exists to catch at trace time."""
    q = make_queue(ray_proto(), 16)
    with pytest.raises(ValueError, match="integer dtype"):
        enqueue(q, make_rays(4), jnp.array([0.0, 1.0, 2.0, 3.0]), jnp.ones(4, bool))


def test_enqueue_rejects_out_of_range_concrete_dest():
    q = make_queue(ray_proto(), 16)
    dest = jnp.array([0, 9, 2, 12], jnp.int32)
    with pytest.raises(ValueError, match=r"num_ranks \(8\).*offending value 12"):
        enqueue(q, make_rays(4), dest, jnp.ones(4, bool), num_ranks=8)
    # unmasked and DISCARD lanes are exempt — only real emits are checked
    ok = enqueue(
        q, make_rays(4), jnp.array([0, 9, DISCARD, 12], jnp.int32),
        jnp.array([1, 0, 1, 0], bool), num_ranks=8,
    )
    assert int(ok.count) == 1


def test_enqueue_traced_dest_skips_value_check():
    """Values don't exist at trace time; the marshal sanitize still guards
    execution, so a traced out-of-range dest becomes a counted sanitize-drop
    rather than a trace error."""
    def emit(dest):
        return enqueue(
            make_queue(ray_proto(), 16), make_rays(4), dest,
            jnp.ones(4, bool), num_ranks=8,
        ).count

    n = jax.jit(emit)(jnp.array([0, 9, 2, 12], jnp.int32))
    assert int(n) == 4  # enqueued; forward_work's sanitize would cut 9 and 12

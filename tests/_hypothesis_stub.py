"""Deterministic fallback for the slice of `hypothesis` this suite uses.

The container image does not ship hypothesis and nothing may be installed
(ROADMAP constraint), so property tests fall back to this stub: each
``@given`` test is executed ``max_examples`` times with examples drawn from a
seeded ``random.Random`` — the same spirit (randomized inputs, fixed shapes)
minus shrinking and the example database.  Implemented: ``st.integers``,
``st.booleans``, ``st.just``, ``st.lists``, ``st.tuples``, ``st.data``, and
``Strategy.flatmap``/``map`` — exactly what the tests import.  If real
hypothesis is present it is always preferred (see the try/except at each
import site).
"""
from __future__ import annotations

import functools
import inspect
import random

__all__ = ["given", "settings", "st"]


class Strategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def _draw(self, rng: random.Random):
        return self._draw_fn(rng)

    def flatmap(self, f):
        return Strategy(lambda rng: f(self._draw(rng))._draw(rng))

    def map(self, f):
        return Strategy(lambda rng: f(self._draw(rng)))


class _DataObject:
    """The object ``st.data()`` yields; ``draw`` samples mid-test."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: Strategy):
        return strategy._draw(self._rng)


class _StrategiesModule:
    @staticmethod
    def integers(min_value, max_value):
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def just(value):
        return Strategy(lambda rng: value)

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0, max_size: int = 10):
        return Strategy(
            lambda rng: [
                elements._draw(rng) for _ in range(rng.randint(min_size, max_size))
            ]
        )

    @staticmethod
    def tuples(*strategies: Strategy):
        return Strategy(lambda rng: tuple(s._draw(rng) for s in strategies))

    @staticmethod
    def data():
        return Strategy(_DataObject)


st = _StrategiesModule()


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strat_args: Strategy, **strat_kwargs: Strategy):
    """Run the test once per example; strategy-bound params are hidden from
    the signature so pytest does not mistake them for fixtures (positional
    strategies fill the test's trailing parameters, like hypothesis)."""

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if strat_args:
            drawn_names = {p.name for p in params[-len(strat_args):]}
        else:
            drawn_names = set(strat_kwargs)
        kept = [p for p in params if p.name not in drawn_names]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", None) or getattr(
                fn, "_stub_max_examples", 20
            )
            for i in range(n):
                rng = random.Random(0xC0FFEE + i)
                drawn_pos = tuple(s._draw(rng) for s in strat_args)
                drawn_kw = {k: s._draw(rng) for k, s in strat_kwargs.items()}
                fn(*args, *drawn_pos, **kwargs, **drawn_kw)

        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__  # keep pytest off the original signature
        return wrapper

    return deco

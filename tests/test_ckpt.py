"""Checkpoint writer/reader unit tests (ISSUE 7 satellite).

``repro.ckpt`` existed since the seed but was only ever exercised through
integration paths; the recovery law now leans on every one of its promises —
atomic publish, SHA-256 integrity, crash-orphan cleanup, retention, and
typed errors (``ValueError``, never ``assert``) — so each gets a direct
test against a real filesystem."""
import json

import numpy as np
import pytest

from repro import ckpt


def _tree(step=0):
    return {
        "a": np.arange(6, dtype=np.int32).reshape(2, 3) + step,
        "b": (np.float32(1.5) * np.ones((4,), np.float32), np.int32(step)),
    }


def _like():
    return {
        "a": np.zeros((2, 3), np.int32),
        "b": (np.zeros((4,), np.float32), np.zeros((), np.int32)),
    }


def test_save_restore_roundtrip_bitexact(tmp_path):
    path = ckpt.save_checkpoint(tmp_path, 3, _tree(3))
    assert path == tmp_path / "step_00000003"
    assert (path / "manifest.json").exists()
    out = ckpt.restore_checkpoint(tmp_path, 3, _like())
    for got, want in zip(
        [out["a"], out["b"][0], out["b"][1]],
        [_tree(3)["a"], _tree(3)["b"][0], _tree(3)["b"][1]],
    ):
        np.testing.assert_array_equal(got, want)
        assert np.asarray(got).dtype == np.asarray(want).dtype


def test_meta_roundtrips_through_manifest(tmp_path):
    meta = {"round": 7, "num_ranks": 8, "overflow": "retain"}
    ckpt.save_checkpoint(tmp_path, 7, _tree(), meta=meta)
    man = ckpt.load_manifest(tmp_path, 7)
    assert man["meta"] == meta
    assert man["step"] == 7
    # manifest is readable with zero knowledge of the tree structure
    assert [e["dtype"] for e in man["leaves"]] == ["int32", "float32", "int32"]
    with pytest.raises(FileNotFoundError):
        ckpt.load_manifest(tmp_path, 99)


def test_latest_step_ignores_tmp_dirs(tmp_path):
    assert ckpt.latest_step(tmp_path) is None
    ckpt.save_checkpoint(tmp_path, 2, _tree())
    ckpt.save_checkpoint(tmp_path, 5, _tree())
    (tmp_path / "step_00000009.tmp").mkdir()  # crashed writer, never published
    assert ckpt.latest_step(tmp_path) == 5


def test_corrupted_leaf_detected_before_deserialize(tmp_path):
    ckpt.save_checkpoint(tmp_path, 1, _tree())
    victim = tmp_path / "step_00000001" / "leaf_00000.npy"
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF  # bit-rot in the tensor payload, header intact
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore_checkpoint(tmp_path, 1, _like())


def test_structure_shape_dtype_mismatches_raise_valueerror(tmp_path):
    ckpt.save_checkpoint(tmp_path, 1, _tree())
    # leaf-count mismatch (checkpoint/model drift)
    with pytest.raises(ValueError, match="leaves"):
        ckpt.restore_checkpoint(tmp_path, 1, {"a": np.zeros((2, 3), np.int32)})
    # shape mismatch
    bad_shape = _like()
    bad_shape["a"] = np.zeros((3, 2), np.int32)
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore_checkpoint(tmp_path, 1, bad_shape)
    # dtype mismatch
    bad_dtype = _like()
    bad_dtype["a"] = np.zeros((2, 3), np.float32)
    with pytest.raises(ValueError, match="dtype"):
        ckpt.restore_checkpoint(tmp_path, 1, bad_dtype)


def test_crash_mid_write_leaves_prior_checkpoint_restorable(tmp_path):
    """A writer dying mid-step must never shadow the published prefix: the
    half-written state lives in ``step_*.tmp`` (invisible to restore), the
    previous checkpoint restores clean, and the NEXT successful save sweeps
    the orphan."""
    ckpt.save_checkpoint(tmp_path, 4, _tree(4), keep=10)
    # simulate a crash while writing step 8: tmp dir with a partial leaf
    orphan = tmp_path / "step_00000008.tmp"
    orphan.mkdir()
    (orphan / "leaf_00000.npy").write_bytes(b"partial garbage")
    assert ckpt.latest_step(tmp_path) == 4
    out = ckpt.restore_checkpoint(tmp_path, 4, _like())
    np.testing.assert_array_equal(out["a"], _tree(4)["a"])
    # recovery sweep: the next publish deletes the orphan
    ckpt.save_checkpoint(tmp_path, 12, _tree(12), keep=10)
    assert not orphan.exists()
    assert ckpt.latest_step(tmp_path) == 12


def test_retention_keeps_newest_k_and_resave_overwrites(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(tmp_path, s, _tree(s), keep=3)
    steps = sorted(
        int(p.name.split("_")[1])
        for p in tmp_path.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".tmp")
    )
    assert steps == [3, 4, 5]
    # re-publishing an existing step replaces it atomically
    ckpt.save_checkpoint(tmp_path, 5, _tree(50), keep=3)
    out = ckpt.restore_checkpoint(tmp_path, 5, _like())
    np.testing.assert_array_equal(out["a"], _tree(50)["a"])


def test_manifest_hashes_witness_bit_identity(tmp_path):
    """Two saves of the SAME tree publish byte-identical leaves (the property
    ``chaos.boundary_digests`` turns into the preempt-resume bit-exactness
    proof); a one-element change flips exactly that leaf's digest."""
    ckpt.save_checkpoint(tmp_path / "x", 0, _tree(9))
    ckpt.save_checkpoint(tmp_path / "y", 0, _tree(9))
    mx = ckpt.load_manifest(tmp_path / "x", 0)
    my = ckpt.load_manifest(tmp_path / "y", 0)
    assert [e["sha256"] for e in mx["leaves"]] == [
        e["sha256"] for e in my["leaves"]
    ]
    changed = _tree(9)
    changed["a"] = changed["a"].copy()
    changed["a"][0, 0] += 1
    ckpt.save_checkpoint(tmp_path / "z", 0, changed)
    mz = ckpt.load_manifest(tmp_path / "z", 0)
    diff = [
        i
        for i, (ex, ez) in enumerate(zip(mx["leaves"], mz["leaves"]))
        if ex["sha256"] != ez["sha256"]
    ]
    assert diff == [0]

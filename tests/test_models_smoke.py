"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (the FULL configs are exercised
only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models.api import build_model

B, S = 2, 64


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    if cfg.kind == "encdec":
        return {
            "frames": jax.random.normal(ks[0], (B, S // 2, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(ks[1], (B, S // 2), 0, cfg.vocab_size),
        }
    batch = {"tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32)
        batch["labels"] = batch["tokens"][:, 1:]
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss(arch, mesh24):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = mesh24 if cfg.kind == "moe" else None
    loss = jax.jit(model.loss_fn(mesh=mesh))(params, _batch(cfg, jax.random.PRNGKey(1)))
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_grad_step(arch, mesh24):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = mesh24 if cfg.kind == "moe" else None
    g = jax.jit(jax.grad(model.loss_fn(mesh=mesh)))(
        params, _batch(cfg, jax.random.PRNGKey(1))
    )
    leaves = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves), f"{arch}: NaN grads"
    # at least the embedding must receive gradient signal
    gsum = sum(float(jnp.sum(jnp.abs(l))) for l in leaves)
    assert gsum > 0, f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, mesh24):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = mesh24 if cfg.kind == "moe" else None
    caches = model.init_caches(B, 32)
    token = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(model.decode_fn(mesh=mesh))
    if cfg.kind == "encdec":
        memory = jax.random.normal(jax.random.PRNGKey(2), (B, 16, cfg.d_model), jnp.float32)
        logits, caches2 = step(params, token, caches, memory)
    else:
        logits, caches2 = step(params, token, caches)
        logits, caches3 = step(params, token, caches2)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN decode logits"


def test_rwkv_chunk_matches_naive_scan():
    from repro.models import rwkv6

    rng = np.random.default_rng(0)
    b, s, h, dh = 2, 96, 4, 16
    mk = lambda: jnp.array(rng.normal(size=(b, s, h, dh)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    logw = jnp.clip(
        jnp.array(-np.abs(rng.normal(size=(b, s, h, dh))), jnp.float32),
        rwkv6.W_MIN, -1e-4,
    )
    u = jnp.array(rng.normal(size=(h, dh)), jnp.float32) * 0.5
    o_chunk = rwkv6._chunk_scan(r, k, v, logw, u)
    o_naive = rwkv6.naive_scan_oracle(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_naive), atol=2e-4, rtol=1e-4)


def test_rwkv_decode_matches_train_forward():
    """Running the chunk form over S tokens == stepping the recurrence S times."""
    from repro.configs import get_smoke_config
    from repro.models import rwkv6

    cfg = get_smoke_config("rwkv6-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    layer = params["blocks"]["k0_rwkv"]
    lp = jax.tree.map(lambda a: a[0], layer)["rwkv"] if "rwkv" in jax.tree.map(lambda a: a[0], layer) else None
    lp = jax.tree.map(lambda a: a[0], layer)["rwkv"]
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg.d_model), jnp.float32)
    o_par, _ = rwkv6.rwkv_block(lp, x, cfg, state=None)
    state = rwkv6.rwkv_state(cfg, 1)
    outs = []
    for t in range(32):
        o_t, state = rwkv6.rwkv_block(lp, x[:, t : t + 1], cfg, state=state)
        outs.append(o_t)
    o_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_par), np.asarray(o_seq), atol=2e-4, rtol=1e-3)


def test_griffin_decode_matches_train_forward():
    from repro.configs import get_smoke_config
    from repro.models import griffin

    cfg = get_smoke_config("recurrentgemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda a: a[0], params["blocks"]["k0_recurrent"])["rglru"]
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, cfg.d_model), jnp.float32)
    o_par, _ = griffin.griffin_block(lp, x, cfg, state=None)
    state = griffin.griffin_state(cfg, 1)
    outs = []
    for t in range(16):
        o_t, state = griffin.griffin_block(lp, x[:, t : t + 1], cfg, state=state)
        outs.append(o_t)
    o_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_par), np.asarray(o_seq), atol=1e-5, rtol=1e-4)


def test_moe_rafi_matches_dense_tp(mesh24):
    """The forwarding dispatch and the dense baseline compute the same MoE."""
    import dataclasses

    from repro.models import moe

    cfg = get_smoke_config("dbrx-132b")
    cfg_tp = dataclasses.replace(cfg, moe_dispatch="dense_tp", capacity_factor=8.0)
    cfg_ep = dataclasses.replace(cfg, moe_dispatch="rafi_ep", capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    from repro.models.common import init_params

    params = init_params(moe.moe_defs(cfg_tp), key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
    y_tp, d_tp = jax.jit(lambda p, x: moe.moe_block(p, x, cfg_tp))(params, x)
    y_ep, d_ep = jax.jit(lambda p, x: moe.moe_block(p, x, cfg_ep, mesh=mesh24))(params, x)
    assert int(d_tp) == 0 and int(d_ep) == 0  # generous capacity: no drops
    np.testing.assert_allclose(np.asarray(y_tp), np.asarray(y_ep), atol=2e-4, rtol=1e-3)


def test_decode_cache_consistency_dense():
    """Prefill logits at position t == decode-with-cache logits at t."""
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    from repro.models import transformer as TF

    logits_par, _, _ = TF.forward(params, toks, cfg)
    caches = model.init_caches(1, 16)
    step = jax.jit(model.decode_fn())
    for t in range(8):
        logits_t, caches = step(params, toks[:, t : t + 1], caches)
    np.testing.assert_allclose(
        np.asarray(logits_t), np.asarray(logits_par[:, -1]), atol=1e-4, rtol=1e-3
    )

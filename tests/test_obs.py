"""ISSUE 10 — the observation law (``repro.obs``).

Host-side span tracing, typed metrics export, and the cross-law flight-data
analyzer.  The load-bearing claims, each checked against independent
evidence:

* **Tracing is host-only and opt-in** — the module-level hooks are no-ops
  until a tracer is installed (explicitly via ``trace.capture()`` or
  ambiently via ``RAFI_TRACE``, exercised through the ``obs`` marker), and
  the HLO bit-identity half of the law lives in
  ``test_collective_budget.py``.
* **Every drive entry point records its span** — a chaos burst, a
  checkpointed+preempted recovery drive, and the route layers all leave
  their typed events in one capture, and the merged Perfetto export is
  structurally valid ``trace_event`` JSON.
* **The recorder's per-round drop chronology is complete** (satellite 2):
  on both PR-9 overload scenarios the queue's own drop counter — an
  accounting system independent of the telemetry ring — equals
  ``Σ (emit_trace + wasted_trace)``, i.e. per round every dropped row is
  either an emission clip or a receiver wire cut; credit flow zeroes the
  waste column elementwise.
* **The analyzer reproduces the PR-9 ledger from the capture alone** — the
  incast-collapse open/credit pair round-trips through
  ``save_capture``/``load_capture``; ``analyze`` re-derives the exact
  goodput and wasted-wire numbers and flags the open run (and only it) as
  degraded; the CLI exit code counts degraded runs.
"""
import json

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.chaos import incast_collapse, run_scenario, sustained_overload
from repro.obs import metrics as OM
from repro.obs import trace as OT

R = 8

# The PR-9 overload gauntlet points (see test_backpressure.OVERLOAD).
OVERLOAD = [
    (sustained_overload, 16, 4),
    (incast_collapse, 32, 8),
]
_IDS = ["sustained", "incast"]


# ------------------------------------------------------------- tracer core
def test_module_hooks_are_noops_when_disabled(monkeypatch):
    monkeypatch.delenv(OT.ENV_VAR, raising=False)
    monkeypatch.setattr(OT, "_ENV_CHECKED", True)
    OT.uninstall()
    assert not OT.enabled() and OT.current() is None
    OT.event("never.recorded", OT.CAT_DRIVE, x=1)  # must not raise
    with OT.span("never.recorded") as sp:
        assert sp.set(y=2) is sp  # the no-op span chains like a real one


@pytest.mark.obs
def test_env_toggle_installs_ambient_tracer():
    """The ``obs`` marker sets RAFI_TRACE=1 through the conftest fixture —
    the lazy env check must install a live tracer, and module-level hooks
    must record into it."""
    assert OT.enabled()
    tr = OT.current()
    n0 = len(tr.events)
    OT.event("toggle.probe", OT.CAT_DRIVE, via="env")
    assert len(tr.events) == n0 + 1
    assert tr.select(name="toggle.probe")[0]["args"]["via"] == "env"


def test_capture_span_event_select_and_restore():
    with OT.capture() as outer:
        OT.event("a", OT.CAT_CHAOS, k=1)
        with OT.capture() as inner:  # nested capture shadows, then restores
            OT.event("b", OT.CAT_TUNE)
            assert OT.current() is inner
        assert OT.current() is outer
        with OT.span("s", OT.CAT_DRIVE, cfg="x") as sp:
            sp.set(result=7)
    assert not OT.enabled()
    assert [e["name"] for e in outer.events] == ["a", "s"]
    (ev,) = outer.select(cat=OT.CAT_CHAOS)
    assert ev["ph"] == "i" and ev["args"] == {"k": 1}
    (sp_ev,) = outer.select(name="s")
    assert sp_ev["ph"] == "X" and sp_ev["dur"] >= 0
    assert sp_ev["args"] == {"cfg": "x", "result": 7}
    assert [e["name"] for e in inner.events] == ["b"]


def test_tracer_ring_is_bounded():
    tr = OT.Tracer(max_events=4)
    for i in range(10):
        tr.event(f"e{i}")
    assert [e["name"] for e in tr.events] == ["e6", "e7", "e8", "e9"]


def test_perfetto_export_structure(tmp_path):
    with OT.capture() as tr:
        with OT.span("burst", OT.CAT_DRIVE, rounds=3):
            OT.event("fault", OT.CAT_CHAOS, mask=[0, 1])
        tr.phase_event("marshal", ts_us=1.0, dur_us=5.0, rank=2, tier=1)
    doc = tr.to_perfetto()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    rows = doc["traceEvents"]
    by_ph = {}
    for r in rows:
        by_ph.setdefault(r["ph"], []).append(r)
    assert {r["name"] for r in by_ph["X"]} == {"burst", "marshal"}
    (inst,) = by_ph["i"]
    assert inst["s"] == "t" and inst["args"]["mask"] == [0, 1]
    # track metadata: one process row per rank, one thread row per tier
    meta = {(r["name"], r["pid"], r["tid"]) for r in by_ph["M"]}
    assert ("process_name", 2, 0) in meta and ("thread_name", 2, 1) in meta
    # the whole document is JSON-serializable and save() round-trips it
    path = tr.save(str(tmp_path / "trace.json"))
    assert json.loads(open(path).read()) == json.loads(json.dumps(doc))


# ------------------------------------------------------- drive entry spans
@pytest.mark.obs
@pytest.mark.chaos
def test_chaos_burst_records_span_and_health_mask(mesh8):
    sc = sustained_overload(R)
    tr = OT.current()
    health = np.ones((R,), bool)
    health[3] = False
    run_scenario(
        mesh8, sc, capacity=64, max_rounds=64, overflow="retain",
        health=health,
    )
    (sp,) = tr.select(name="chaos.run_scenario")
    assert sp["cat"] == OT.CAT_CHAOS and sp["ph"] == "X"
    a = sp["args"]
    assert a["scenario"] == sc.name and a["flow"] == "open"
    assert a["done"] is True and a["rounds"] >= 1
    assert a["delivered_total"] > 0
    (hm,) = tr.select(name="chaos.health_mask")
    assert hm["args"]["unhealthy"] == [3]


@pytest.mark.obs
def test_route_layers_record_trace_time_events(mesh8):
    """``rebalance`` and ``deliver_by_cycling`` run INSIDE shard_map, where
    host wall-clock spans are meaningless — they record one trace-time
    event each (static routing facts only), captured while the program is
    being traced."""
    import dataclasses as DC

    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.core import (
        DISCARD, ForwardConfig, WorkQueue, deliver_by_cycling, rebalance,
        work_item,
    )

    @work_item
    @DC.dataclass
    class Item:
        val: jax.Array

    CAP = 16
    cfg = ForwardConfig("data", R, CAP, exchange="padded")

    def kern(_x):
        me = jax.lax.axis_index("data")
        lane = jnp.arange(CAP, dtype=jnp.int32)
        q = WorkQueue(
            items=Item(val=lane.astype(jnp.float32)),
            dest=jnp.where(lane < 4, (me + 1) % R, DISCARD).astype(jnp.int32),
            count=jnp.int32(4), drops=jnp.zeros((), jnp.int32),
        )
        nq, _total = rebalance(q, cfg)
        absorbed, total = deliver_by_cycling(nq, cfg)
        return absorbed.count[None], total

    with OT.capture() as tr:
        jax.jit(compat.shard_map(
            kern, mesh=mesh8, in_specs=P("data"), out_specs=(P("data"), P()),
        )).lower(jnp.arange(8.0))
    (rb,) = tr.select(name="route.rebalance")
    assert rb["cat"] == OT.CAT_ROUTE and rb["args"]["num_ranks"] == R
    (cy,) = tr.select(name="route.deliver_by_cycling")
    assert cy["args"]["hops"] == R


@pytest.mark.obs
@pytest.mark.recovery
def test_checkpointed_drive_records_recovery_events(mesh8, tmp_path):
    from repro.chaos import run_scenario_checkpointed
    from repro.chaos.scenarios import rotating_hotspot

    sc = rotating_hotspot(num_ranks=R, rounds=8, emits_per_round=2, seed=0)
    tr = OT.current()
    res = run_scenario_checkpointed(
        mesh8, sc, capacity=64, ckpt_dir=tmp_path, checkpoint_every=2,
        preempt_at=4, max_rounds=64,
    )
    assert res["done"]
    names = {e["name"] for e in tr.events}
    assert {
        "chaos.run_scenario_checkpointed", "chaos.preempt_scheduled",
        "chaos.elastic_resume", "recovery.run_checkpointed",
        "recovery.boundary", "recovery.save", "recovery.preempt",
        "recovery.resume_run",
    } <= names
    saves = tr.select(name="recovery.save")
    assert all(s["args"]["bytes"] > 0 for s in saves)
    assert all(len(s["args"]["digest"]) == 16 for s in saves)
    (top,) = tr.select(name="chaos.run_scenario_checkpointed")
    assert top["args"]["preempted"] is True


# ------------------------------------------------------------ metrics side
def _toy_summary():
    """A minimal-but-complete ``telemetry.summarize`` dict (flat route)."""
    return {
        "tier_capacities": (4,), "buckets": 8, "rounds": 3,
        "window_filled": 3,
        "demand_hist": np.zeros((1, 8), np.int64),
        "demand_max": np.array([5]), "demand_total": np.array([12]),
        "sent_rows": np.array([10]), "stage_drops": np.array([1]),
        "recv_total_max": 6, "recv_drops": 2, "wasted_wire_rows": 2,
        "drops": 3, "retained_rows": 4, "age_max": 2,
        "credits_granted": np.array([7]), "rows_held": np.array([1]),
        "emit_overflow": 5, "goodput": 0.75,
    }


def test_metrics_from_summary_and_exports():
    ms = OM.from_summary(_toy_summary())
    d = OM.metrics_dict(ms)
    assert d["rafi_wasted_wire_rows_total"] == 2
    assert d["rafi_goodput_ratio"] == 0.75
    assert d["rafi_demand_max_rows{tier=0}"] == 5
    assert d["rafi_tier_capacity_rows{tier=0}"] == 4
    text = OM.to_prometheus(ms)
    assert "# TYPE rafi_goodput_ratio gauge" in text
    assert "# TYPE rafi_wasted_wire_rows_total counter" in text
    assert 'rafi_demand_max_rows{tier="0"} 5' in text
    # deterministic: same metrics render byte-identically (golden property)
    assert text == OM.to_prometheus(OM.from_summary(_toy_summary()))
    back = json.loads(OM.to_json(ms))
    assert {m["name"] for m in back} == {m.name for m in ms}


def test_checkpoint_metrics_derive_bytes_from_shapes():
    manifest = {
        "step": 6,
        "leaves": [
            {"file": "a.npy", "shape": [4, 2], "dtype": "int32"},
            {"file": "b.npy", "shape": [3], "dtype": "float64"},
        ],
    }
    d = OM.metrics_dict(OM.checkpoint_metrics(manifest))
    assert d['rafi_checkpoint_bytes{step=6}'] == 4 * 2 * 4 + 3 * 8
    assert d['rafi_checkpoint_leaves{step=6}'] == 2


def test_round_stats_wasted_wire_defaults_to_recv_drops():
    """Satellite 2, unit level: the flat single-tier recorder stamps
    ``wasted_wire_rows == recv_drops`` unless a route provides the wider
    (hierarchical) accounting."""
    import jax.numpy as jnp

    from repro.telemetry import stats as TS

    st = TS.single_tier_stats(
        jnp.array([3]), 4, 8, sent_rows=jnp.array(3),
        stage_drops=jnp.zeros((), jnp.int32), recv_total=jnp.array(6),
        recv_drops=jnp.array(2),
    )
    assert int(st.wasted_wire_rows) == 2
    st2 = TS.single_tier_stats(
        jnp.array([3]), 4, 8, sent_rows=jnp.array(3),
        stage_drops=jnp.zeros((), jnp.int32), recv_total=jnp.array(6),
        recv_drops=jnp.array(2), wasted_wire_rows=jnp.array(5),
    )
    assert int(st2.wasted_wire_rows) == 5


# ------------------------------------- satellite 2: per-round drop ledger
@pytest.mark.obs
@pytest.mark.chaos
@pytest.mark.parametrize("factory,cap,S", OVERLOAD, ids=_IDS)
@pytest.mark.parametrize("flow", ["open", "credit"])
def test_per_round_drop_chronology_is_complete(mesh8, factory, cap, S, flow):
    """``drops == Σ (emit_trace + wasted_trace)``: the queue's drop counter
    (maintained by the enqueue path, independent of the telemetry ring)
    must be fully attributed, round by round, by the recorder's two
    per-round columns — emission clips and receiver wire cuts.  Credit flow
    never wastes wire, so its waste column is zero ELEMENTWISE, not just in
    total."""
    sc = factory(R)
    res = run_scenario(
        mesh8, sc, capacity=cap, peer_capacity=S, overflow="retain",
        flow=flow, max_rounds=256,
    )
    emit_t = np.asarray(res["emit_trace"], np.int64)
    waste_t = np.asarray(res["wasted_trace"], np.int64)
    # one chronology slot per recorded round (the recorder may hold a few
    # trailing all-zero slots past the final round)
    assert emit_t.shape == waste_t.shape and emit_t.size >= res["rounds"]
    assert not emit_t[res["rounds"]:].any()
    assert not waste_t[res["rounds"]:].any()
    # burst ledger closes against the independent queue counter
    assert res["drops"] == int(emit_t.sum() + waste_t.sum())
    # the recorder's own totals are the column sums
    assert res["emit_overflow"] == int(emit_t.sum())
    assert res["wasted_wire_rows"] == int(waste_t.sum())
    if flow == "credit":
        assert not waste_t.any(), waste_t  # zero waste per round
        assert res["goodput"] == 1.0
    else:
        assert waste_t.sum() > 0  # both overload points waste wire openly
        assert (waste_t >= 0).all() and (emit_t >= 0).all()


@pytest.mark.obs
@pytest.mark.chaos
def test_hierarchical_wasted_wire_counts_late_stage_cuts(mesh_nodes24):
    """On a tiered drop-mode route the first-class ``wasted_wire_rows`` is
    WIDER than the receiver cut: a row clamped at any post-first-hop stage
    already crossed a fabric, so the recorder attributes it to wasted wire
    on top of ``recv_drops``.  The flat-route identity loosens to an
    inequality here — the queue's drop counter additionally includes the
    tier-0 pre-wire clamp, which is NOT waste (those rows never shipped)."""
    sc = sustained_overload(R)
    res = run_scenario(
        mesh_nodes24, sc, capacity=16, max_rounds=256,
        axis_name=("node", "device"), exchange="hierarchical",
        level_capacities=(4, 4), overflow="drop",
    )
    emit_t = np.asarray(res["emit_trace"], np.int64)
    waste_t = np.asarray(res["wasted_trace"], np.int64)
    assert res["wasted_wire_rows"] == int(waste_t.sum()) > 0
    # late-stage cuts are attributed: waste strictly exceeds the recv cut
    assert res["wasted_wire_rows"] > res["recv_drops"] >= 0
    # every dropped row is an emission clip, counted waste, or a tier-0
    # pre-wire clamp — so the queue counter bounds the chronology from above
    assert res["drops"] >= int(emit_t.sum() + waste_t.sum())
    assert res["emit_overflow"] == int(emit_t.sum())


# ------------------------------------------------- flight-data analyzer
def _incast_captures(mesh8):
    from repro.obs import report as OR

    sc = incast_collapse(R)
    runs, results = [], {}
    for flow in ("open", "credit"):
        with OT.capture():
            res = run_scenario(
                mesh8, sc, capacity=32, peer_capacity=8, overflow="retain",
                flow=flow, max_rounds=256,
            )
        results[flow] = res
        runs.append(OR.chaos_capture(
            f"{sc.name}_{flow}", res, flow=flow, tier_capacities=(8,),
            capacity=32,
        ))
    return sc, runs, results


@pytest.mark.obs
@pytest.mark.chaos
@pytest.mark.backpressure
def test_flight_report_reproduces_pr9_ledger(mesh8, tmp_path, capsys):
    """ISSUE 10 acceptance: the analyzer, reading ONLY the round-tripped
    capture file, re-derives the PR-9 goodput/wasted-wire numbers and flags
    the open-flow incast run — and only it — as degraded; the CLI exits
    with the degraded-run count."""
    from repro.obs import report as OR

    sc, runs, results = _incast_captures(mesh8)
    path = str(tmp_path / "capture.json")
    OR.save_capture(path, runs, meta={"source": "test_obs"})
    report = OR.analyze(OR.load_capture(path))
    assert report["degraded_runs"] == [f"{sc.name}_open"]
    by_name = {r["name"]: r for r in report["runs"]}
    for flow in ("open", "credit"):
        r = by_name[f"{sc.name}_{flow}"]
        assert abs(r["goodput"] - results[flow]["goodput"]) < 1e-9
        assert r["wasted_wire_rows"] == results[flow]["wasted_wire_rows"]
        assert all(c["ok"] for c in r["checks"]), [
            c for c in r["checks"] if not c["ok"]
        ]
    open_run = by_name[f"{sc.name}_open"]
    assert "degraded_goodput" in open_run["flags"]
    # starvation is NOT flagged: incast is a single-sink shape by design
    assert "starvation" not in open_run["flags"]
    text = OR.render(report)
    assert "DEGRADED" in text and "healthy" in text
    # the CLI is the same analysis: exit code == number of degraded runs
    rc = OR.main([path])
    assert rc == 1
    assert "flight-data report" in capsys.readouterr().out


@pytest.mark.obs
def test_analyzer_flags_ledger_violation(mesh8, tmp_path):
    """Tampering with the conservation ledger must trip the watchdog — the
    analyzer re-adds the books instead of trusting the recorded verdict."""
    from repro.obs import report as OR

    _sc, runs, _results = _incast_captures(mesh8)
    bad = json.loads(json.dumps(runs[1]))  # the healthy credit run
    bad["name"] = "tampered"
    bad["ledger"]["emitted"] += 5
    report = OR.analyze({"runs": [bad]})
    (r,) = report["runs"]
    assert "ledger_violation" in r["flags"] and r["degraded"]
    assert "tampered" in report["degraded_runs"]


# ----------------------------------------------------------- obs.phases
@pytest.mark.obs
@pytest.mark.parametrize(
    "kw,want",
    [
        (
            dict(exchange="padded", peer_capacity=8),
            {"marshal", "count_collective", "payload_collective",
             "unmarshal"},
        ),
        (
            dict(exchange="padded", peer_capacity=8, pipeline_shards=2),
            {"marshal", "count_collective", "payload_collective",
             "unmarshal"}
            | {f"shard{k}_{p}" for k in range(2)
               for p in ("marshal", "payload_collective", "unmarshal")},
        ),
    ],
    ids=["padded", "pipelined"],
)
def test_profile_phases_key_vocabulary(mesh8, kw, want):
    from repro.core import ForwardConfig
    from repro.obs.phases import profile_phases, tier_of_phase

    from helpers import ray_proto

    cfg = ForwardConfig("data", R, 64, **kw)
    calls = []

    def timeit(f, x):
        calls.append(f)
        return 1.0, f(x)

    phase_us = profile_phases(
        cfg, mesh8, n_emit=8, cap=64, proto=ray_proto(), timeit=timeit
    )
    assert set(phase_us) == want
    assert len(calls) == len(want)  # one timed program per phase
    assert all(tier_of_phase(k) == 0 for k in phase_us)


@pytest.mark.obs
def test_phases_to_perfetto_tracks():
    from repro.obs import phases as OP

    doc = OP.to_perfetto(
        {"marshal": 10.0, "tier1_payload_collective": 20.0},
        num_ranks=2, tag="t", t0_us=0.0,
    )
    rows = [r for r in doc["traceEvents"] if r["ph"] == "X"]
    # every rank gets its own copy of the measured phase timeline
    assert {r["pid"] for r in rows} == {0, 1}
    # span names carry the tag prefix; tid is the phase's tier
    tiers = {r["name"]: r["tid"] for r in rows if r["pid"] == 0}
    assert tiers["t:marshal"] == 0 and tiers["t:tier1_payload_collective"] == 1
    # phases are laid end to end per rank
    starts = sorted(r["ts"] for r in rows if r["pid"] == 0)
    assert starts == [0.0, 10.0]

"""Tests for the roofline analysis layer and the launch-time spec resolver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic stub
    from _hypothesis_stub import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.launch.mesh import make_test_mesh
from repro.launch.steps import resolve_spec
from repro.roofline.analysis import (
    HW,
    RooflineTerms,
    collective_bytes,
    model_flops,
)


# -------------------------------------------------------------- HLO parsing
def test_collective_bytes_post_spmd_hlo():
    hlo = """
  %ag = bf16[16,512,128]{2,1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[256,1024]{1,0} all-reduce(%y), to_apply=%sum
  %a2a = (f32[64,32]{1,0}, f32[64,32]{1,0}) all-to-all(%a, %b)
  %rs = bf16[8,128]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(%w)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 512 * 128 * 2
    assert out["all-reduce"] == 256 * 1024 * 4
    assert out["all-to-all"] == 2 * 64 * 32 * 4
    assert out["reduce-scatter"] == 8 * 128 * 2
    assert out["collective-permute"] == 16 * 4


def test_collective_bytes_stablehlo():
    txt = """
    %5 = "stablehlo.all_to_all"(%4) <{...}> : (tensor<256x44xf32>) -> tensor<256x44xf32>
    %6 = "stablehlo.all_reduce"(%5) ({ ... }) : (tensor<128xbf16>) -> tensor<128xbf16>
"""
    out = collective_bytes(txt)
    assert out["all-to-all"] == 256 * 44 * 4
    assert out["all-reduce"] == 128 * 2


def test_marshal_cost_model_scatter_undercuts_sort():
    """The marshal law: both modes make exactly ONE payload pass, and the
    scatter plan's O(C) bytes must undercut the sort's O(C log C) key traffic
    at every size (the whole point of the bucket-scatter marshal)."""
    from repro.roofline.analysis import marshal_cost_model

    for cap in (256, 4096, 1 << 16):
        send_rows = 2 * cap
        kw = dict(capacity=cap, item_bytes=44, send_rows=send_rows, num_ranks=256)
        sort = marshal_cost_model("sort", **kw)
        scat = marshal_cost_model("scatter", **kw)
        assert sort["payload_passes"] == scat["payload_passes"] == 1.0
        assert sort["payload_bytes"] == scat["payload_bytes"]
        assert scat["plan_bytes"] < sort["plan_bytes"]
        assert scat["total_bytes"] < sort["total_bytes"]
    with pytest.raises(ValueError):
        marshal_cost_model("bogus", capacity=8, item_bytes=4, send_rows=8)


def test_roofline_terms_dominance():
    t = RooflineTerms(
        flops=197e12 * 256,          # exactly 1 s of compute on 256 chips
        bytes_accessed=819e9 * 256 * 2,  # 2 s of HBM
        coll_bytes=50e9 * 256 * 0.5,     # 0.5 s of wire
        chips=256,
        coll_breakdown={},
    )
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 2.0) < 1e-9
    assert abs(t.t_collective - 0.5) < 1e-9
    assert t.dominant == "memory"
    assert t.bound_time == t.t_memory


def test_model_flops_moe_counts_active_params_only():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES

    dense = model_flops(get_config("qwen2-7b"), SHAPES["train_4k"])
    moe = model_flops(get_config("dbrx-132b"), SHAPES["train_4k"])
    from repro.models.api import build_model

    n_dbrx = build_model(get_config("dbrx-132b")).param_count()
    # top-4 of 16 experts ⇒ active fraction of the FFN share
    assert moe < 6 * n_dbrx * 256 * 4096
    assert moe > 0.2 * 6 * n_dbrx * 256 * 4096


# ----------------------------------------------------------- resolve_spec
class TestResolveSpec:
    mesh = make_test_mesh(data=2, model=4)

    def test_passthrough_when_divisible(self):
        s = resolve_spec((8, 12), P("data", "model"), self.mesh)
        assert s == P("data", "model")

    def test_drop_when_indivisible_no_move(self):
        s = resolve_spec((3, 5), P("data", "model"), self.mesh, allow_move=False)
        assert s == P(None, None)

    def test_move_to_divisible_dim(self):
        # 4 kv heads can't split model=4? they can; use 3 heads instead
        s = resolve_spec((4, 16, 3, 128), P("data", None, "model", None), self.mesh)
        assert s == P("data", "model", None, None) or s == P(
            "data", None, None, "model"
        )

    def test_tuple_axes_partial_keep(self):
        # batch 2 divides data(2) but not data×model(8)
        s = resolve_spec((2, 7), P(("data", "model"), None), self.mesh)
        assert s == P("data", None)

    @given(
        st.tuples(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64)),
    )
    @settings(max_examples=50, deadline=None)
    def test_result_is_always_legal(self, shape):
        spec = P(("data", "model"), "model", None)
        # spec mentions model twice — dedup across dims must hold
        s = resolve_spec(shape, P(("data",), "model", None), self.mesh)
        used = []
        for i, part in enumerate(s):
            axes = () if part is None else (part if isinstance(part, tuple) else (part,))
            n = 1
            for a in axes:
                n *= self.mesh.shape[a]
                used.append(a)
            assert shape[i] % n == 0, (shape, s)
        assert len(used) == len(set(used))


# --------------------------------------------------------------- rebalance
def test_rebalance_under_heavy_skew(mesh8):
    """Straggler mitigation: 97%-skewed load ends within ±1 of the mean."""
    import dataclasses

    from repro.core import (
        DISCARD, ForwardConfig, WorkQueue, enqueue, make_queue, rebalance,
        work_item,
    )

    @work_item
    @dataclasses.dataclass
    class W:
        v: jax.Array

    proto = W(v=jnp.zeros(()))
    CAP = 256
    cfg = ForwardConfig("data", 8, CAP, peer_capacity=CAP, exchange="padded")

    def bal(_x):
        me = jax.lax.axis_index("data")
        q = make_queue(proto, CAP)
        n = jnp.where(me == 3, 199, jnp.where(me == 5, 7, 0))
        mask = jnp.arange(CAP) < n
        q = enqueue(q, W(v=jnp.arange(CAP, dtype=jnp.float32)), jnp.zeros(CAP, jnp.int32), mask)
        q = WorkQueue(items=q.items, dest=jnp.full((CAP,), DISCARD, jnp.int32),
                      count=q.count, drops=q.drops)
        nq, total = rebalance(q, cfg)
        return nq.count[None], total

    from jax.sharding import PartitionSpec as P

    f = jax.jit(compat.shard_map(bal, mesh=mesh8, in_specs=P("data"),
                              out_specs=(P("data"), P())))
    counts, total = f(jnp.arange(8.0))
    counts = np.asarray(counts)
    assert int(total) == 206
    # order-preserving ceil assignment: every rank ≤ ⌈total/R⌉, none idle
    assert counts.max() <= int(np.ceil(206 / 8))
    assert counts.sum() == 206
    assert counts.min() >= 206 - 7 * int(np.ceil(206 / 8))

"""Registry/shape-suite tests: the 40-cell matrix is exactly as assigned."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, input_specs, shape_suite
from repro.configs.shapes import SHAPES
from repro.models.api import build_model

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
    "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
    "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
    "llama4-scout-17b-16e": (48, 5120, 40, 8, 8192, 202048),
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    "rwkv6-3b": (32, 2560, 40, 0, 8960, 65536),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
}


def test_all_ten_archs_registered():
    assert set(ARCHS) == set(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_assigned_configs(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = EXPECTED[arch]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == h and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v


def test_moe_configs():
    l4 = get_config("llama4-scout-17b-16e")
    assert l4.num_experts == 16 and l4.top_k == 1
    dbrx = get_config("dbrx-132b")
    assert dbrx.num_experts == 16 and dbrx.top_k == 4
    assert dbrx.moe_dispatch == "rafi_ep"  # the paper technique is default


def test_shape_suite_skips_long500k_for_quadratic_archs():
    for arch in ARCHS:
        suite = shape_suite(arch)
        entry = suite["long_500k"]
        if arch in ("rwkv6-3b", "recurrentgemma-2b"):
            assert not isinstance(entry, str), f"{arch} must run long_500k"
        else:
            assert isinstance(entry, str) and "SKIP" in entry


def test_cell_count_is_40():
    cells = [(a, s) for a in ARCHS for s in shape_suite(a)]
    assert len(cells) == 40


@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_input_specs_shapes(shape):
    cell = input_specs("qwen2-7b", shape)
    spec = SHAPES[shape]
    if cell.skip:
        return
    if spec.step in ("train", "prefill"):
        assert cell.batch["tokens"].shape == (spec.global_batch, spec.seq_len)
    else:
        assert cell.batch["token"].shape == (spec.global_batch, 1)


def test_frontend_stubs_provide_embeddings():
    vl = input_specs("qwen2-vl-72b", "train_4k")
    assert "embeds" in vl.batch  # vision stub: precomputed patch embeddings
    sm = input_specs("seamless-m4t-medium", "train_4k")
    assert "frames" in sm.batch  # audio stub: precomputed frame embeddings


def test_param_counts_are_in_family_ballpark():
    """Sanity: full configs land within ±40% of the family's nameplate."""
    expected_b = {
        "qwen2-7b": 7.6, "qwen2.5-14b": 14.7, "glm4-9b": 9.4, "gemma3-1b": 1.0,
        "dbrx-132b": 132.0, "qwen2-vl-72b": 72.0, "rwkv6-3b": 3.1,
        "recurrentgemma-2b": 2.7, "seamless-m4t-medium": 1.2,
    }
    for arch, nb in expected_b.items():
        n = build_model(get_config(arch)).param_count() / 1e9
        assert 0.6 * nb < n < 1.4 * nb, f"{arch}: {n:.2f}B vs nameplate {nb}B"
